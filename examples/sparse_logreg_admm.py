"""Paper §5 reproduction: sparse L1 logistic regression (eq. 22) on
synthetic KDDa-like data — sync vs async vs full-vector, with the fused
Pallas gradient kernel cross-checked against autodiff.

    PYTHONPATH=src python examples/sparse_logreg_admm.py [--dim 1024]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ConsensusSession
from repro.configs.base import ADMMConfig
from repro.data import make_sparse_logreg
from repro.kernels import ops, ref


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dim", type=int, default=1024)
    ap.add_argument("--workers", type=int, default=8)
    ap.add_argument("--samples", type=int, default=96)
    ap.add_argument("--epochs", type=int, default=600)
    args = ap.parse_args()

    data = make_sparse_logreg(num_workers=args.workers,
                              samples_per_worker=args.samples,
                              dim=args.dim, density=0.08, seed=0)

    def loss_fn(z, d):
        X, y = d
        return jnp.mean(jnp.log1p(jnp.exp(-y * (X @ z))))

    def session_for(cfg: ADMMConfig) -> ConsensusSession:
        return ConsensusSession.flat(
            loss_fn, (jnp.asarray(data.X), jnp.asarray(data.y)),
            dim=args.dim, cfg=cfg, support=data.support,
            l1_coef=1e-3, clip=1e4)

    # --- kernel cross-check: fused Pallas gradient == autodiff gradient ---
    X0, y0 = jnp.asarray(data.X[0]), jnp.asarray(data.y[0])
    w = jnp.zeros(args.dim)
    g_kernel = ops.logreg_grad(X0, y0, w, interpret=True)
    g_auto = jax.grad(lambda z: loss_fn(z, (X0, y0)))(w)
    print(f"pallas logreg_grad vs autodiff: max|Δ| = "
          f"{float(jnp.max(jnp.abs(g_kernel - g_auto))):.2e}")

    variants = {
        "sync (block, D=0)": ADMMConfig(rho=2.0, gamma=0.0, max_delay=0,
                                        block_fraction=1.0, num_blocks=16),
        "AsyBADMM (D=2, 50% blocks)": ADMMConfig(rho=2.0, gamma=0.1,
                                                 max_delay=2,
                                                 block_fraction=0.5,
                                                 num_blocks=16, seed=1),
        "full-vector async (M=1)": ADMMConfig(rho=2.0, gamma=0.1,
                                              max_delay=2,
                                              block_fraction=1.0,
                                              num_blocks=1, seed=2),
    }
    print(f"\n{'variant':30s} {'epochs':>6s} {'objective':>10s} "
          f"{'P':>10s} {'s/epoch':>8s}")
    for name, cfg in variants.items():
        sess = session_for(cfg)
        t0 = time.time()
        state, hist = sess.run(args.epochs, eval_every=args.epochs)
        dt = (time.time() - t0) / args.epochs
        P = float(sess.stationarity(state)["P"])
        print(f"{name:30s} {args.epochs:6d} {hist[-1]['objective']:10.4f} "
              f"{P:10.2e} {dt:8.4f}")


if __name__ == "__main__":
    main()
