"""End-to-end driver: train a ~100M-parameter transformer with the
block-wise asynchronous consensus trainer for a few hundred steps.

    PYTHONPATH=src python examples/train_transformer_admm.py \
        [--steps 300] [--quick]

The ADMM side goes through the unified `repro.api.ConsensusSession`
pytree mode (the same generic Algorithm 1 step the flat driver uses);
it is compared against the synchronous AdamW baseline on the same
deterministic token stream (both learn a synthetic bigram language).
"""
import argparse
import json
import time

import jax

from repro.api import ConsensusSession
from repro.configs.base import ADMMConfig, ModelConfig
from repro.data import TokenPipeline
from repro.models import build_model
from repro.optim import adamw, warmup_cosine
from repro.training import SGDTrainer


def model_100m() -> ModelConfig:
    """~110M params: a qwen3-family dense decoder."""
    return ModelConfig(
        name="demo-100m", arch_type="dense", num_layers=12, d_model=768,
        num_heads=12, num_kv_heads=4, d_ff=2048, vocab_size=32000,
        head_dim=64, qk_norm=True, tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--quick", action="store_true",
                    help="tiny model + 30 steps (CI-sized)")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--block-selection", default="random",
                    choices=["random", "cyclic", "gauss_southwell"])
    args = ap.parse_args()

    cfg = model_100m()
    if args.quick:
        cfg = cfg.with_(num_layers=2, d_model=128, num_heads=4,
                        num_kv_heads=2, d_ff=256, vocab_size=1024)
        args.steps = min(args.steps, 30)
        args.seq = 32

    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {cfg.name} ({n/1e6:.1f}M params)")

    # data uses a reduced working vocabulary so the bigram structure is
    # learnable within a few hundred steps (the model vocab is unchanged)
    data_vocab = min(cfg.vocab_size, 512)
    pipe = TokenPipeline(vocab_size=data_vocab, seq_len=args.seq + 1,
                         global_batch=args.batch, seed=0, branch=2)

    # ---- AsyBADMM consensus session (the paper's technique) ----
    admm = ConsensusSession.pytree(
        model.loss, params,
        ADMMConfig(rho=8.0, gamma=0.01, max_delay=1, block_fraction=0.5,
                   num_blocks=8, block_selection=args.block_selection),
        num_workers=args.workers)
    st_admm = admm.init()
    admm_step = admm.step_fn()

    # ---- AdamW data-parallel baseline ----
    sgd = SGDTrainer(loss_fn=model.loss,
                     optimizer=adamw(warmup_cosine(3e-4, args.steps // 10,
                                                   args.steps)))
    st_sgd = sgd.init(params)
    sgd_step = jax.jit(sgd.train_step)

    t0 = time.time()
    for step in range(args.steps):
        b_admm = pipe.batch(step, num_workers=args.workers)
        b_sgd = pipe.batch(step)
        st_admm, info_a = admm_step(st_admm, b_admm)
        st_sgd, info_s = sgd_step(st_sgd, b_sgd)
        if step % max(args.steps // 15, 1) == 0 or step == args.steps - 1:
            print(json.dumps({
                "step": step,
                "admm_loss": round(float(info_a["loss"]), 4),
                "adamw_loss": round(float(info_s["loss"]), 4),
                "consensus_residual":
                    round(admm.consensus_residual(st_admm), 5),
                "elapsed_s": round(time.time() - t0, 1),
            }), flush=True)


if __name__ == "__main__":
    main()
