"""Quickstart: solve a sparse logistic regression with AsyBADMM.

    PYTHONPATH=src python examples/quickstart.py

Builds the paper's general-form consensus problem (eq. 4) on synthetic
sparse data through the unified `repro.api.ConsensusSession` surface,
runs the block-wise asynchronous algorithm (Alg. 1), and checks the KKT
conditions of Theorem 1 at the solution.
"""
import jax.numpy as jnp

from repro.api import ConsensusSession
from repro.configs.base import ADMMConfig
from repro.data import make_sparse_logreg

# ---- data: 8 workers, each touching only part of the feature space ----
data = make_sparse_logreg(num_workers=8, samples_per_worker=48, dim=512,
                          density=0.02, locality=0.8, seed=0)


def loss_fn(z, d):
    X, y = d
    return jnp.mean(jnp.log1p(jnp.exp(-y * (X @ z))))


# ---- AsyBADMM: bounded delay 2, each worker updates half its blocks ----
cfg = ADMMConfig(rho=2.0, gamma=0.1, max_delay=2, block_fraction=0.5,
                 num_blocks=32, l1_coef=1e-3, clip=1e4)  # h(z) (eq. 22)
session = ConsensusSession.flat(
    loss_fn, (jnp.asarray(data.X), jnp.asarray(data.y)), dim=512, cfg=cfg,
    support=data.support)                                # sparse edge set E

print(f"edge density |E|/(N·M) = {float(jnp.mean(session.spec.edge)):.2f}")

state, history = session.run(num_epochs=600, eval_every=100)

for h in history:
    print(f"epoch {h['epoch']:4d}  objective {h['objective']:.4f}")

print("stationarity P =", float(session.stationarity(state)["P"]))
for k, v in session.kkt_violations(state).items():
    print(f"{k:15s} = {float(v):.2e}")
