"""Batched serving demo across architecture families (dense / MoE / SSM
/ hybrid): prefill + KV-cache decode with ragged request handling.

    PYTHONPATH=src python examples/serve_decode.py [--arch mamba2-370m]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke, list_archs
from repro.models import build_model
from repro.serving import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None,
                    help="default: one per family")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()

    archs = ([args.arch] if args.arch else
             ["qwen3-1.7b", "mixtral-8x7b", "mamba2-370m", "zamba2-1.2b"])
    for arch in archs:
        cfg = get_smoke(arch)
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        engine = Engine(model, params, max_len=64)
        prompts = np.random.RandomState(0).randint(
            0, cfg.vocab_size, (args.requests, 8))
        t0 = time.time()
        res = engine.generate(prompts, max_new=args.max_new, temperature=0.7,
                              seed=1)
        dt = time.time() - t0
        toks = args.requests * args.max_new
        print(f"{arch:22s} {toks:4d} tokens in {dt:6.2f}s "
              f"({toks/dt:6.1f} tok/s)  sample: {res.tokens[0][:8].tolist()}")


if __name__ == "__main__":
    main()
