from .optimizers import Optimizer, adamw, apply_updates, sgd
from .schedule import constant, warmup_cosine
