"""Minimal pure optimizer library (no external deps): SGD(+momentum),
Adam/AdamW. Used by the non-ADMM baseline trainer the paper's method is
compared against, and by examples.

API mirrors optax: ``opt.init(params) -> state``;
``opt.update(grads, state, params) -> (updates, state)``;
apply with ``apply_updates``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: p + u.astype(p.dtype), params, updates)


def sgd(lr, momentum: float = 0.0, nesterov: bool = False) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {"step": jnp.zeros((), jnp.int32)}
        return {"step": jnp.zeros((), jnp.int32),
                "mu": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else lr
        if momentum == 0.0:
            return jax.tree.map(lambda g: -lr_t * g, grads), {"step": step}
        mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: -lr_t * (momentum * m + g), mu, grads)
        else:
            upd = jax.tree.map(lambda m: -lr_t * m, mu)
        return upd, {"step": step, "mu": mu}

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "v": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else lr
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
                         state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                         state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m_, v_, p):
            u = -lr_t * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u
        return (jax.tree.map(upd, m, v, params),
                {"step": step, "m": m, "v": v})

    return Optimizer(init, update)
