"""repro — AsyBADMM (block-wise asynchronous distributed ADMM for
general form consensus, arXiv:1802.08882) grown into a jax_pallas
system. See API.md for the user-facing surface (`repro.api`).
"""
import jax as _jax

# Sharding-invariant PRNG: delay sampling and block selection must draw
# the SAME values whether the (N, M) arrays are replicated on one device
# or sharded over a mesh — the legacy (non-partitionable) threefry
# lowering rewrites under SPMD partitioning and diverges, which broke
# the flat driver's sharded run vs its single-device reference
# (tests/test_resume_and_distributed.py::test_flat_driver_runs_spmd).
_jax.config.update("jax_threefry_partitionable", True)
