"""Synthetic KDDa-like sparse classification data (the paper's workload).

The real KDDa set (8.4M samples, 20M features, 305M nonzeros — paper §5)
is not available offline; this generator reproduces its *structure*:
extremely sparse rows, power-law feature popularity, and per-worker
locality so each worker's edge neighborhood N(i) covers only part of the
feature space — exactly what makes block-wise ADMM pay off.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class SparseLogRegData:
    X: np.ndarray          # (N_workers, m_per, d) dense-with-zeros design
    y: np.ndarray          # (N_workers, m_per) labels in {-1, +1}
    support: np.ndarray    # (N_workers, d) bool — worker feature support
    w_true: np.ndarray     # (d,) generating weights (sparse)


def make_sparse_logreg(num_workers: int, samples_per_worker: int, dim: int,
                       *, density: float = 0.1, weight_density: float = 0.2,
                       locality: float = 0.5, noise: float = 0.1,
                       seed: int = 0) -> SparseLogRegData:
    """locality in [0,1): fraction of each worker's features drawn from a
    worker-private band (creates the sparse edge set E); the rest come
    from a shared power-law pool."""
    rng = np.random.RandomState(seed)
    N, m, d = num_workers, samples_per_worker, dim

    # power-law popularity over the shared pool
    pop = 1.0 / (np.arange(d) + 1.0)
    pop /= pop.sum()

    band = d // N
    X = np.zeros((N, m, d), np.float32)
    nnz_per_row = max(1, int(density * d))
    for i in range(N):
        lo, hi = i * band, (i + 1) * band
        for r in range(m):
            k_local = int(locality * nnz_per_row)
            k_shared = nnz_per_row - k_local
            cols_local = rng.randint(lo, hi, size=k_local)
            cols_shared = rng.choice(d, size=k_shared, p=pop)
            cols = np.concatenate([cols_local, cols_shared])
            X[i, r, cols] = rng.randn(len(cols)).astype(np.float32)

    w_true = np.where(rng.rand(d) < weight_density, rng.randn(d), 0.0)
    logits = np.einsum("nmd,d->nm", X, w_true) + noise * rng.randn(N, m)
    y = np.where(rng.rand(N, m) < 1.0 / (1.0 + np.exp(-logits)), 1.0, -1.0)
    support = (np.abs(X).sum(axis=1) > 0)
    return SparseLogRegData(X=X, y=y.astype(np.float32), support=support,
                            w_true=w_true.astype(np.float32))
