"""Deterministic synthetic token pipeline.

Generates a learnable bigram language (fixed random transition table) so
training losses genuinely decrease; batches are derived from (seed, step)
so the pipeline is stateless, shardable, and resumable — the properties
a production input pipeline must have (no hidden iterator state to
checkpoint).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    branch: int = 4        # bigram branching factor (lower = more learnable)

    def _table(self):
        rng = np.random.RandomState(self.seed)
        return jnp.asarray(
            rng.randint(0, self.vocab_size, size=(self.vocab_size, self.branch)))

    def batch(self, step: int, *, num_workers: int = 1,
              enc_frames_dim: Optional[int] = None,
              enc_seq_len: int = 0) -> Dict[str, jax.Array]:
        """Returns {"tokens", "labels"} of shape (B, S) — or with a
        leading worker axis (N, B/N, S) when num_workers > 1."""
        table = self._table()
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        B, S = self.global_batch, self.seq_len
        k0, k1, k2 = jax.random.split(key, 3)
        first = jax.random.randint(k0, (B,), 0, self.vocab_size)
        choices = jax.random.randint(k1, (B, S), 0, self.branch)

        def gen(tok0, choice_row):
            def body(tok, c):
                nxt = table[tok, c]
                return nxt, nxt
            _, seq = jax.lax.scan(body, tok0, choice_row)
            return seq

        toks = jax.vmap(gen)(first, choices)              # (B, S)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if enc_frames_dim is not None:
            batch["enc_frames"] = jax.random.normal(
                k2, (B, enc_seq_len, enc_frames_dim)) * 0.1
        if num_workers > 1:
            assert B % num_workers == 0
            batch = jax.tree.map(
                lambda a: a.reshape((num_workers, B // num_workers) + a.shape[1:]),
                batch)
        return batch
