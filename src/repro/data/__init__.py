from .sparse import SparseLogRegData, make_sparse_logreg
from .synthetic import TokenPipeline
