from .engine import Engine, ServeResult
