"""Batched serving engine: prefill + step-wise decode with KV caches.

Real request plumbing at small scale (the big-shape decode paths are
exercised via the dry-run): right-padded prompt batches are prefilled in
one pass, the last-position logits seed the decode loop, and per-request
activity masks handle ragged prompt lengths / early EOS.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import Model


@dataclasses.dataclass
class ServeResult:
    tokens: np.ndarray          # (B, max_new) generated ids
    steps: int


class Engine:
    def __init__(self, model: Model, params, max_len: int = 512):
        self.model = model
        self.params = params
        self.max_len = max_len
        self._decode = jax.jit(model.decode_step)

    def _prefill_caches(self, prompts: jax.Array, enc_frames=None):
        """Run the prompt through decode_step token by token (simple,
        correct for every cache family incl. SSM state)."""
        B, P = prompts.shape
        cache = self.model.init_cache(B, self.max_len)
        if enc_frames is not None:
            cache = self._fill_cross_attn(cache, enc_frames)
        logits = None
        for t in range(P):
            logits, cache = self._decode(self.params, prompts[:, t : t + 1],
                                         cache, jnp.int32(t))
        return logits, cache, P

    def _fill_cross_attn(self, cache, enc_frames):
        from ..models import attention as A
        from ..models import transformer as T
        from ..models.layers import rmsnorm
        cfg = self.model.cfg
        p = self.params
        x = enc_frames
        pos = jnp.broadcast_to(
            jnp.arange(x.shape[1], dtype=jnp.int32)[None, :], x.shape[:2])

        def enc_body(c, lp):
            y, _ = T._dense_layer_fwd(lp, c, cfg, pos, causal=False)
            return y, None
        x, _ = jax.lax.scan(enc_body, x, p["enc_layers"])
        x = rmsnorm(x, p["enc_norm"], cfg.norm_eps)

        def kv_body(c, lp):
            k, v = A.encode_cross_kv(lp["cross"], x, cfg)
            return c, (k, v)
        _, (ck, cv) = jax.lax.scan(kv_body, 0, p["layers"])
        return dict(cache, cross_k=ck, cross_v=cv)

    def generate(self, prompts: np.ndarray, max_new: int = 32,
                 temperature: float = 0.0, eos_id: Optional[int] = None,
                 enc_frames=None, seed: int = 0) -> ServeResult:
        prompts = jnp.asarray(prompts, jnp.int32)
        B, P = prompts.shape
        assert P + max_new <= self.max_len
        logits, cache, pos = self._prefill_caches(prompts, enc_frames)
        rng = jax.random.PRNGKey(seed)
        out = []
        active = jnp.ones((B,), bool)
        tok = None
        for t in range(max_new):
            last = logits[:, -1, :]
            if temperature > 0.0:
                rng, k = jax.random.split(rng)
                tok = jax.random.categorical(k, last / temperature, axis=-1)
            else:
                tok = jnp.argmax(last, axis=-1)
            if eos_id is not None:
                tok = jnp.where(active, tok, eos_id)
                active = active & (tok != eos_id)
            out.append(tok)
            logits, cache = self._decode(self.params, tok[:, None], cache,
                                         jnp.int32(pos + t))
        return ServeResult(tokens=np.stack([np.asarray(t) for t in out], axis=1),
                           steps=max_new)
