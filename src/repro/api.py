"""User-facing builder API for AsyBADMM — one surface over both spaces.

``ConsensusSession`` binds a :class:`~repro.core.space.ConsensusSpec`
(space + policies) to an :class:`~repro.configs.base.ADMMConfig` and
exposes init/step/run. Build one with:

* ``ConsensusSession.flat(...)``   — flat-vector consensus (the paper's
  sparse workloads; fixed per-worker data, optional support/edge set);
* ``ConsensusSession.pytree(...)`` — params-pytree consensus training
  (streaming per-worker batches).

Both modes honor every ``ADMMConfig`` policy — ``block_selection``
(random | cyclic | gauss_southwell, or any callable registered with
``register_block_selector``), heterogeneous ``rho_scale``, bounded-delay
models, and general-form edge sets.

    from repro.api import ConsensusSession, solve

    sess = ConsensusSession.flat(loss_fn, (X, y), dim=512, cfg=cfg,
                                 support=support)
    state, history = sess.run(600, eval_every=100)
    z = sess.z(state)

    # or, one call:
    z, history = solve(loss_fn, (X, y), dim=512, num_epochs=600, cfg=cfg)

See API.md for the migration table from the pre-`VariableSpace` APIs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .configs.base import ADMMConfig
from .core.blocks import TreeBlocks, make_block_layout, make_tree_blocks
from .core.consensus import ConsensusProblem, make_problem
from .core.metrics import kkt_violations, stationarity
from .core.space import (ConsensusSpec, ConsensusState, TreeSpace,
                         asybadmm_epoch, consensus_residual,
                         init_consensus_state, make_spec)


@dataclasses.dataclass(frozen=True)
class ConsensusSession:
    """A configured AsyBADMM run: spec + config (+ fixed data, flat mode).

    spec    : the generic step spec (space, edge, rho_vec, policies);
    cfg     : the ADMMConfig the spec was built from;
    data    : fixed per-worker data (flat mode); ``step`` falls back to
              it when no batch is passed;
    z0      : default initial consensus value in user representation
              (params pytree in pytree mode);
    problem : the flat-mode ConsensusProblem (None in pytree mode) —
              kept so the stationarity/KKT metrics stay available.
    """
    spec: ConsensusSpec
    cfg: ADMMConfig
    data: Any = None
    z0: Any = None
    problem: Optional[ConsensusProblem] = None

    # ------------------------------------------------------------------
    # builders
    # ------------------------------------------------------------------
    @staticmethod
    def flat(loss_fn: Callable, data: Any, dim: int,
             cfg: Optional[ADMMConfig] = None, *,
             support: Optional[np.ndarray] = None,
             edge: Optional[Any] = None,
             rho_scale: Optional[Any] = None,
             l1_coef: Optional[float] = None,
             clip: Optional[float] = None,
             l2_coef: float = 0.0,
             selector=None, delay_model=None,
             backend: Optional[str] = None,
             mesh: Any = None,
             autotune: Optional[str] = None) -> "ConsensusSession":
        """Flat-vector consensus over ``dim`` coordinates split into
        ``cfg.num_blocks`` blocks. Regularizer terms default to the
        config's (``cfg.l1_coef`` / ``cfg.clip``); kwargs override.
        ``backend`` (jnp | pallas | auto) overrides ``cfg.backend`` —
        the fused-Pallas vs pure-jnp hot-path switch. ``mesh`` (a jax
        Mesh or a ``launch.mesh.resolve_mesh`` preset name) overrides
        ``cfg.mesh`` — when set, every epoch runs SPMD with workers
        sharded over the ``data`` axes and block servers over ``model``
        (see API.md's support matrix)."""
        cfg = cfg if cfg is not None else ADMMConfig()
        problem = make_problem(
            loss_fn, data, dim=dim, num_blocks=cfg.num_blocks,
            support=support, edge=edge,
            l1_coef=cfg.l1_coef if l1_coef is None else l1_coef,
            clip=cfg.clip if clip is None else clip,
            l2_coef=l2_coef, rho_scale=rho_scale)
        spec = problem.spec(cfg, selector=selector, delay_model=delay_model,
                            backend=backend, mesh=mesh, autotune=autotune)
        return ConsensusSession(spec=spec, cfg=cfg, data=problem.data,
                                problem=problem)

    @staticmethod
    def pytree(loss_fn: Callable, params: Any, cfg: Optional[ADMMConfig],
               num_workers: int, *,
               blocks: Optional[TreeBlocks] = None,
               edge: Optional[Any] = None,
               rho_scale: Optional[Any] = None,
               selector=None, delay_model=None,
               backend: Optional[str] = None,
               mesh: Any = None,
               autotune: Optional[str] = None) -> "ConsensusSession":
        """Params-pytree consensus: leaves are balanced into
        ``cfg.num_blocks`` logical blocks (or pass explicit ``blocks``);
        per-worker batches stream in through ``step``/``run``.
        ``backend`` (jnp | pallas | auto) overrides ``cfg.backend``;
        ``mesh`` overrides ``cfg.mesh`` (SPMD epoch: workers over the
        ``data`` axes, packed block servers over ``model`` — pytree
        mode shards z natively since the BlockLayout lowering; see
        API.md's support matrix)."""
        cfg = cfg if cfg is not None else ADMMConfig()
        if blocks is None:
            blocks = make_tree_blocks(params, cfg.num_blocks)
        space = TreeSpace(blocks=blocks, num_workers=num_workers,
                          layout=make_block_layout(params, blocks))
        spec = make_spec(space, cfg, loss_fn, edge=edge, rho_scale=rho_scale,
                         selector=selector, delay_model=delay_model,
                         track_x=False, backend=backend, mesh=mesh,
                         autotune=autotune)
        return ConsensusSession(spec=spec, cfg=cfg, z0=params)

    # ------------------------------------------------------------------
    # driving
    # ------------------------------------------------------------------
    def init(self, z0: Any = None) -> ConsensusState:
        return init_consensus_state(
            self.spec, z0 if z0 is not None else self.z0)

    def step(self, state: ConsensusState, batch: Any = None
             ) -> Tuple[ConsensusState, Dict]:
        """One epoch of Algorithm 1. ``batch`` defaults to the session's
        fixed data (flat mode)."""
        data = batch if batch is not None else self.data
        return asybadmm_epoch(self.spec, state, data)

    def step_fn(self):
        """Jitted (state, batch) -> (state, info)."""
        spec = self.spec
        return jax.jit(lambda s, b: asybadmm_epoch(spec, s, b))

    def run_ps(self, num_rounds: int, z0: Any = None, *,
               discipline: str = "lockfree",
               timing: Any = None,
               batches: Optional[Callable[[int], Any]] = None,
               compute: str = "real",
               seed: Optional[int] = None,
               record_z: bool = True,
               faults: Any = None,
               transport: Any = None,
               check_finite: bool = False,
               checkpoint_every: Optional[int] = None,
               checkpoint_dir: Optional[str] = None,
               resume_from: Optional[str] = None,
               telemetry: Any = None,
               metrics_every: Optional[int] = None):
        """Drive ``num_rounds`` rounds under the event-driven Parameter
        Server runtime (``repro.ps``) instead of the vectorized epoch:
        per-block ``lockfree`` servers (or the ``locked`` full-vector
        baseline), workers running the real jitted space ops, bounded
        staleness enforced by stalling (Assumption 3's T comes from the
        session's delay model), and every pull recorded into a
        :class:`~repro.ps.trace.DelayTrace`.

        ``timing`` is a :class:`~repro.ps.timing.CostProfile` (service
        times; defaults to unit worker cost). ``compute="timing"``
        skips the numerics for pure coordination studies;
        ``record_z=False`` keeps only the live staleness window of
        committed versions (long-training memory mode — ``z_final``
        still returned, ``z_versions`` not). Returns a
        :class:`~repro.ps.runtime.PSRunResult` (``z_final`` /
        ``z_versions`` in user representation) — replay its trace
        through the fast epoch with
        ``delay_model=result.to_delay_model()``.

        ``faults`` is a :class:`~repro.ps.chaos.FaultPlan` (or a path
        to its JSON) injecting worker crash/rejoin, joins/leaves,
        slowdowns and server commit spikes — the run stays
        deterministic and its trace (staleness + participation) still
        replays through the epoch; see API.md's elastic-PS section.

        ``transport`` is a :class:`~repro.ps.timing.Transport`
        (unreliable network: drop/dup/reorder probabilities +
        ack/retry/backoff) — convenience for setting ``timing.net``
        when no other cost tuning is needed; with every knob at zero it
        is inert (byte-identical to no transport). ``check_finite=True``
        arms the divergence watchdog: the run halts with a
        ``FloatingPointError`` naming the round/block the moment a
        committed z goes NaN/Inf. See API.md's transport-reliability
        section.

        Durability (``repro.ps.recovery``; API.md's "Durability &
        recovery"): ``checkpoint_every=E`` writes an atomic,
        crash-consistent snapshot of the whole runtime into
        ``checkpoint_dir`` every E rounds; ``resume_from=`` (a snapshot
        prefix or the checkpoint directory for its latest) restores one
        and continues mid-stream, with results identical to the
        uninterrupted run — and a ``server_crash`` fault event makes a
        block server lose its volatile state and rebuild it from its
        write-ahead commit log with zero committed folds lost.

        Observability (``repro.obs``; API.md's "Observability"):
        ``telemetry=`` turns the deterministic telemetry layer on —
        pass ``True`` (span tracing only), a ``.jsonl`` path /
        ``"stdout"`` / a callable (per-round record stream), or a
        :class:`~repro.obs.Telemetry` for full control (span tracer +
        sink + Chrome-trace path). Telemetry records in virtual
        sim-time only and never perturbs the schedule: the run's z, fold
        logs and makespan are bitwise identical to ``telemetry=None``.
        ``metrics_every=k`` emits every k-th round's record (plus the
        final round)."""
        import dataclasses as _dc

        from .ps import PSRuntime
        from .ps.chaos import FaultPlan
        from .ps.timing import CostProfile
        if isinstance(faults, (str, bytes)) or hasattr(faults, "__fspath__"):
            faults = FaultPlan.load(faults)
        if transport is not None:
            if timing is not None and timing.net is not None:
                raise ValueError(
                    "pass the Transport either as transport= or as "
                    "timing.net, not both")
            timing = _dc.replace(timing if timing is not None
                                 else CostProfile(), net=transport)
        rt = PSRuntime(self.spec, data=self.data, batches=batches,
                       discipline=discipline, timing=timing,
                       compute=compute, seed=seed, record_z=record_z,
                       faults=faults, check_finite=check_finite,
                       telemetry=telemetry, metrics_every=metrics_every)
        return rt.run(num_rounds, z0=z0 if z0 is not None else self.z0,
                      checkpoint_every=checkpoint_every,
                      checkpoint_dir=checkpoint_dir,
                      resume_from=resume_from)

    def run(self, num_epochs: int, z0: Any = None, *,
            batches: Optional[Callable[[int], Any]] = None,
            eval_every: int = 0,
            eval_fn: Optional[Callable] = None
            ) -> Tuple[ConsensusState, List[Dict]]:
        """Drive ``num_epochs`` epochs. ``batches(t)`` supplies the epoch-t
        per-worker batch (defaults to the fixed data). Eval records carry
        ``loss`` (+ ``objective`` in flat mode) and ``eval_fn(session,
        state)`` extras."""
        state = self.init(z0)
        step = self.step_fn()
        hist: List[Dict] = []
        for t in range(num_epochs):
            data = batches(t) if batches is not None else self.data
            state, info = step(state, data)
            if eval_every and (t + 1) % eval_every == 0:
                rec = {"epoch": t + 1, "loss": float(info["loss"])}
                if self.problem is not None:
                    rec["objective"] = float(
                        self.problem.objective(self.z(state)))
                if eval_fn is not None:
                    rec.update(eval_fn(self, state))
                hist.append(rec)
        return state, hist

    # ------------------------------------------------------------------
    # inspection
    # ------------------------------------------------------------------
    def z(self, state: ConsensusState) -> Any:
        """Newest consensus value in user representation (flat vector /
        params pytree)."""
        space = self.spec.space
        return space.to_user(space.current(state.z_hist))

    def objective(self, state: ConsensusState) -> float:
        if self.problem is None:
            raise ValueError("objective() needs flat mode (fixed data); "
                             "use step()'s info['loss'] in pytree mode")
        return float(self.problem.objective(self.z(state)))

    def consensus_residual(self, state: ConsensusState) -> float:
        """Cross-worker w-cache dispersion (0 at consensus), both modes."""
        return float(consensus_residual(self.spec, state))

    def stationarity(self, state: ConsensusState) -> Dict:
        if self.problem is None:
            raise ValueError("stationarity metrics need flat mode")
        # per-worker rho_i, so heterogeneous rho_scale runs are scored
        # against the Lagrangian they actually optimized
        return stationarity(self.problem, state, self.spec.rho_vec)

    def kkt_violations(self, state: ConsensusState) -> Dict:
        if self.problem is None:
            raise ValueError("KKT metrics need flat mode")
        return kkt_violations(self.problem, state, self.spec.rho_vec)


def solve(loss_fn: Callable, data: Any, dim: int, num_epochs: int = 500,
          cfg: Optional[ADMMConfig] = None, *, eval_every: int = 0,
          z0: Optional[jax.Array] = None, **flat_kwargs
          ) -> Tuple[jax.Array, List[Dict]]:
    """One-call flat solve: build a session, run it, return (z, history).

    ``flat_kwargs`` forward to :meth:`ConsensusSession.flat`
    (support/edge/rho_scale/l1_coef/clip/...).
    """
    sess = ConsensusSession.flat(loss_fn, data, dim, cfg, **flat_kwargs)
    state, hist = sess.run(num_epochs, z0=z0,
                           eval_every=eval_every or num_epochs)
    return sess.z(state), hist
