"""AsyBADMM update equations (paper §3) as pure functions.

These are the algebraic primitives shared by every integration level:
the flat consensus driver (consensus.py), the transformer consensus
trainer (training/trainer.py), and the Pallas kernels (kernels/ —
whose ref.py oracle is exactly these functions).

Key identity exploited throughout (appendix eq. 25): after worker i
updates block j at epoch t,

    y_ij^{t+1} = -grad_j f_i(z~^t)

so (11)+(12)+(9) collapse to one fused elementwise pass:

    x^{t+1} = z~ - (g + y)/rho
    y^{t+1} = -g
    w^{t+1} = rho*x^{t+1} + y^{t+1} = rho*z~ - 2g - y
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def worker_update(g, y, z_tilde, rho):
    """Eqs. (11), (12), (9). Returns (x_new, y_new, w_new)."""
    x_new = z_tilde - (g + y) / rho
    y_new = y + rho * (x_new - z_tilde)          # == -g
    w_new = rho * x_new + y_new                  # == rho*z_tilde - 2g - y
    return x_new, y_new, w_new


def server_update(z_tilde, w_sum, rho_sum, gamma, prox):
    """Eq. (13): z <- prox_h^mu((gamma*z~ + sum_i w~_ij) / (gamma + sum rho_i))
    with mu = gamma + rho_sum."""
    mu = gamma + rho_sum
    v = (gamma * z_tilde + w_sum) / mu
    return prox(v, mu)


def theorem1_feasible(rho: float, gamma: float, L: float, T_delay: int,
                      n_workers_per_block: int, n_blocks_per_worker: int
                      ) -> Tuple[bool, float, float]:
    """Check the Theorem 1 hyper-parameter conditions (17)/(18) for the
    homogeneous case (rho_i = rho, L_ij = L, T_ij = T).  Returns
    (feasible, alpha, beta)."""
    Nj = n_workers_per_block
    alpha = (gamma + rho
             - Nj * (0.5 + 1.0 / rho) * (L ** 2) * (T_delay + 1) ** 2
             - Nj * (4 * L + rho + 1) * (T_delay ** 2) / 2.0)
    beta = (rho - 4 * L) / (2 * max(n_blocks_per_worker, 1))
    return bool(alpha > 0 and beta > 0), float(alpha), float(beta)
