"""Stationarity metric P (eqs. 14-15) and consensus residuals.

P(X,Y,z) = ||z - z_hat||^2 + sum_E ||grad_{x_ij} L||^2 + sum_E ||x_ij - z_j||^2
z_hat    = prox_h( z - grad_z(L - h) )

P -> 0 certifies a KKT/stationary point of problem (1) (Theorem 1.3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .consensus import AsyBADMMState, ConsensusProblem


def _rho_b(rho):
    """Accept a scalar rho or a per-worker (N,) rho_i vector and return
    it broadcastable against (N, M, dblk) worker bundles."""
    rho = jnp.asarray(rho)
    return rho[:, None, None] if rho.ndim == 1 else rho


def stationarity(problem: ConsensusProblem, state: AsyBADMMState,
                 rho) -> dict:
    blocks = problem.blocks
    rho = _rho_b(rho)
    edge_m = problem.edge[..., None]                       # (N, M, 1)
    zb = state.z_hist[0]                                   # (M, dblk)

    # grad of each f_i at its own x_i (full vector)
    def gfun(xb, di):
        return jax.grad(problem.loss_fn)(blocks.from_blocks(xb), di)
    g_at_x = jax.vmap(gfun)(state.x, problem.data)         # (N, d)
    gb = blocks.to_blocks(g_at_x)                          # (N, M, dblk)

    # grad_{x_ij} L = grad_j f_i(x_i) + y_ij + rho (x_ij - z_j)
    gradL_x = jnp.where(edge_m, gb + state.y + rho * (state.x - zb[None]), 0.0)

    # grad_z (L - h) = sum_{i in N(j)} [ -y_ij - rho (x_ij - z_j) ]
    gradL_z = jnp.sum(jnp.where(edge_m, -state.y - rho * (state.x - zb[None]), 0.0),
                      axis=0)                              # (M, dblk)
    z_vec = blocks.from_blocks(zb)
    v = blocks.from_blocks(zb - gradL_z)
    z_hat = problem.reg.prox(v, 1.0)                       # eq. 15, mu = 1

    cons = jnp.where(edge_m, state.x - zb[None], 0.0)
    P = (jnp.sum(jnp.square(z_vec - z_hat))
         + jnp.sum(jnp.square(gradL_x))
         + jnp.sum(jnp.square(cons)))
    return {
        "P": P,
        "primal_residual": jnp.sqrt(jnp.sum(jnp.square(cons))),
        "grad_norm": jnp.sqrt(jnp.sum(jnp.square(gradL_x))),
        "prox_residual": jnp.sqrt(jnp.sum(jnp.square(z_vec - z_hat))),
    }


def kkt_violations(problem: ConsensusProblem, state: AsyBADMMState,
                   rho) -> dict:
    """Theorem 1.2 KKT conditions at the limit point:
    (20a) grad_j f_i(x_i*) + y_ij* = 0
    (20c) x_ij* = z_j*
    (20b) sum_i y_ij* in subdiff h_j(z_j*)  — checked via the prox
          fixed-point residual ||z - prox_h(z + sum_i y_i)||."""
    blocks = problem.blocks
    edge_m = problem.edge[..., None]
    zb = state.z_hist[0]

    def gfun(xb, di):
        return jax.grad(problem.loss_fn)(blocks.from_blocks(xb), di)
    gb = blocks.to_blocks(jax.vmap(gfun)(state.x, problem.data))

    kkt_a = jnp.max(jnp.abs(jnp.where(edge_m, gb + state.y, 0.0)))
    kkt_c = jnp.max(jnp.abs(jnp.where(edge_m, state.x - zb[None], 0.0)))
    y_sum = jnp.sum(jnp.where(edge_m, state.y, 0.0), axis=0)
    v = blocks.from_blocks(zb + y_sum)
    kkt_b = jnp.max(jnp.abs(blocks.from_blocks(zb) - problem.reg.prox(v, 1.0)))
    return {"kkt_grad": kkt_a, "kkt_consensus": kkt_c, "kkt_subgrad": kkt_b}
