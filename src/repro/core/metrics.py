"""Stationarity metric P (eqs. 14-15) and consensus residuals.

P(X,Y,z) = ||z - z_hat||^2 + sum_E ||grad_{x_ij} L||^2 + sum_E ||x_ij - z_j||^2
z_hat    = prox_h( z - grad_z(L - h) )

P -> 0 certifies a KKT/stationary point of problem (1) (Theorem 1.3).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .consensus import AsyBADMMState, ConsensusProblem


def _rho_b(rho):
    """Accept a scalar rho or a per-worker (N,) rho_i vector and return
    it broadcastable against (N, M, dblk) worker bundles."""
    rho = jnp.asarray(rho)
    return rho[:, None, None] if rho.ndim == 1 else rho


def stationarity(problem: ConsensusProblem, state: AsyBADMMState,
                 rho) -> dict:
    blocks = problem.blocks
    rho = _rho_b(rho)
    edge_m = problem.edge[..., None]                       # (N, M, 1)
    zb = state.z_hist[0]                                   # (M, dblk)

    # grad of each f_i at its own x_i (full vector)
    def gfun(xb, di):
        return jax.grad(problem.loss_fn)(blocks.from_blocks(xb), di)
    g_at_x = jax.vmap(gfun)(state.x, problem.data)         # (N, d)
    gb = blocks.to_blocks(g_at_x)                          # (N, M, dblk)

    # grad_{x_ij} L = grad_j f_i(x_i) + y_ij + rho (x_ij - z_j)
    gradL_x = jnp.where(edge_m, gb + state.y + rho * (state.x - zb[None]), 0.0)

    # grad_z (L - h) = sum_{i in N(j)} [ -y_ij - rho (x_ij - z_j) ]
    gradL_z = jnp.sum(jnp.where(edge_m, -state.y - rho * (state.x - zb[None]), 0.0),
                      axis=0)                              # (M, dblk)
    z_vec = blocks.from_blocks(zb)
    v = blocks.from_blocks(zb - gradL_z)
    z_hat = problem.reg.prox(v, 1.0)                       # eq. 15, mu = 1

    cons = jnp.where(edge_m, state.x - zb[None], 0.0)
    P = (jnp.sum(jnp.square(z_vec - z_hat))
         + jnp.sum(jnp.square(gradL_x))
         + jnp.sum(jnp.square(cons)))
    return {
        "P": P,
        "primal_residual": jnp.sqrt(jnp.sum(jnp.square(cons))),
        "grad_norm": jnp.sqrt(jnp.sum(jnp.square(gradL_x))),
        "prox_residual": jnp.sqrt(jnp.sum(jnp.square(z_vec - z_hat))),
    }


def block_residuals(z, y, x, edge, rho, reg=None, grads=None) -> dict:
    """Per-block decomposition of P over the packed representation —
    the telemetry quantities the PS runtime streams per round (and the
    signals Adaptive Consensus ADMM's residual-balancing rho updates
    consume).

    Inputs are the canonical packed arrays (block j = row j for both
    spaces): ``z`` (M, dblk), ``y``/``x`` (N, M, dblk), ``edge``
    (N, M) bool, ``rho`` scalar or per-worker (N,). ``reg`` enables
    the prox-residual term (the ``make_prox`` family is elementwise,
    so it applies to the packed table directly; zero pads are fixed
    points of l1/box/l2, so pads contribute nothing); ``grads``
    (N, M, dblk) — grad f_i at x_i in packed form — enables the
    gradient term. Returns per-block (M,) arrays ``primal``/``prox``/
    ``grad`` (residual norms; prox/grad are None when their input is
    absent) and ``P`` (the per-block sum of squares of whatever terms
    were computable; summing it over blocks reproduces ``stationarity``
     's P up to fp reassociation when all terms are present)."""
    rho = _rho_b(rho)
    edge_m = jnp.asarray(edge)[..., None]                  # (N, M, 1)
    z = jnp.asarray(z)
    cons = jnp.where(edge_m, x - z[None], 0.0)             # (N, M, dblk)
    primal_sq = jnp.sum(jnp.square(cons), axis=(0, 2))     # (M,)
    P_blocks = primal_sq
    prox_b = None
    if reg is not None:
        gradL_z = jnp.sum(jnp.where(edge_m, -y - rho * (x - z[None]), 0.0),
                          axis=0)                          # (M, dblk)
        z_hat = reg.prox(z - gradL_z, 1.0)                 # eq. 15, mu = 1
        prox_sq = jnp.sum(jnp.square(z - z_hat), axis=1)   # (M,)
        prox_b = jnp.sqrt(prox_sq)
        P_blocks = P_blocks + prox_sq
    grad_b = None
    if grads is not None:
        gradL_x = jnp.where(edge_m,
                            grads + y + rho * (x - z[None]), 0.0)
        grad_sq = jnp.sum(jnp.square(gradL_x), axis=(0, 2))
        grad_b = jnp.sqrt(grad_sq)
        P_blocks = P_blocks + grad_sq
    return {"primal": jnp.sqrt(primal_sq), "prox": prox_b,
            "grad": grad_b, "P": P_blocks}


def stationarity_blocks(problem: ConsensusProblem, state: AsyBADMMState,
                        rho) -> dict:
    """Per-block view of :func:`stationarity`: the same P (eqs. 14-15)
    decomposed over blocks via :func:`block_residuals`, with the
    gradient term evaluated exactly as ``stationarity`` does. Each
    per-block array sums (in squares) to the corresponding total up to
    fp reassociation — pinned by tests/test_metrics.py."""
    blocks = problem.blocks

    def gfun(xb, di):
        return jax.grad(problem.loss_fn)(blocks.from_blocks(xb), di)
    gb = blocks.to_blocks(jax.vmap(gfun)(state.x, problem.data))
    return block_residuals(state.z_hist[0], state.y, state.x,
                           problem.edge, rho, reg=problem.reg, grads=gb)


def kkt_violations(problem: ConsensusProblem, state: AsyBADMMState,
                   rho) -> dict:
    """Theorem 1.2 KKT conditions at the limit point:
    (20a) grad_j f_i(x_i*) + y_ij* = 0
    (20c) x_ij* = z_j*
    (20b) sum_i y_ij* in subdiff h_j(z_j*)  — checked via the prox
          fixed-point residual ||z - prox_h(z + sum_i y_i)||."""
    blocks = problem.blocks
    edge_m = problem.edge[..., None]
    zb = state.z_hist[0]

    def gfun(xb, di):
        return jax.grad(problem.loss_fn)(blocks.from_blocks(xb), di)
    gb = blocks.to_blocks(jax.vmap(gfun)(state.x, problem.data))

    kkt_a = jnp.max(jnp.abs(jnp.where(edge_m, gb + state.y, 0.0)))
    kkt_c = jnp.max(jnp.abs(jnp.where(edge_m, state.x - zb[None], 0.0)))
    y_sum = jnp.sum(jnp.where(edge_m, state.y, 0.0), axis=0)
    v = blocks.from_blocks(zb + y_sum)
    kkt_b = jnp.max(jnp.abs(blocks.from_blocks(zb) - problem.reg.prox(v, 1.0)))
    return {"kkt_grad": kkt_a, "kkt_consensus": kkt_c, "kkt_subgrad": kkt_b}
