"""Flat-mode AsyBADMM driver — the paper's Algorithm 1, end to end.

One jitted ``step`` advances every worker and every server by one epoch
under simulated bounded delay. Baselines fall out as config points:

* ``max_delay=0, block_fraction=1``  -> block-wise *synchronous* ADMM (§3.1)
* ``num_blocks=1, max_delay>0``      -> full-vector asynchronous ADMM
                                        (Zhang & Kwok 2014 style, the
                                        locking baseline the paper beats)
* ``num_blocks=M, max_delay>0``      -> AsyBADMM (the paper's algorithm)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ADMMConfig
from .admm import server_update, worker_update
from .async_sim import gather_delayed, push_history, sample_delays, select_blocks
from .blocks import FlatBlocks, make_flat_blocks
from .prox import Regularizer, make_prox


@dataclasses.dataclass(frozen=True)
class ConsensusProblem:
    """General form consensus problem (eq. 4) over a flat variable.

    loss_fn(z_vec, worker_data) -> scalar f_i; must be smooth.
    data: pytree whose leaves have leading axis N (one slice per worker).
    edge: (N, M) bool — the paper's edge set E.
    """
    loss_fn: Callable
    data: Any
    dim: int
    num_workers: int
    blocks: FlatBlocks
    edge: jnp.ndarray
    reg: Regularizer
    # per-worker penalty multipliers: effective rho_i = cfg.rho * rho_scale[i]
    # (the paper's formulation is heterogeneous-rho throughout)
    rho_scale: Optional[jnp.ndarray] = None

    def rho_vec(self, rho: float) -> jnp.ndarray:
        if self.rho_scale is None:
            return jnp.full((self.num_workers,), rho)
        return rho * self.rho_scale

    def worker_loss(self, z_vec, i):
        di = jax.tree.map(lambda a: a[i], self.data)
        return self.loss_fn(z_vec, di)

    def objective(self, z_vec):
        """Global objective (1): sum_i f_i(z) + h(z)."""
        losses = jax.vmap(lambda d: self.loss_fn(z_vec, d))(self.data)
        return jnp.sum(losses) + self.reg.value(z_vec)


def make_problem(loss_fn, data, dim: int, num_blocks: int,
                 support: Optional[np.ndarray] = None,
                 l1_coef: float = 0.0, clip: Optional[float] = None,
                 l2_coef: float = 0.0,
                 rho_scale: Optional[np.ndarray] = None) -> ConsensusProblem:
    n = jax.tree.leaves(data)[0].shape[0]
    blocks = make_flat_blocks(dim, num_blocks)
    if support is not None:
        from .blocks import edge_set_from_support
        edge = jnp.asarray(edge_set_from_support(np.asarray(support), blocks))
    else:
        edge = jnp.ones((n, num_blocks), bool)
    return ConsensusProblem(
        loss_fn=loss_fn, data=data, dim=dim, num_workers=n, blocks=blocks,
        edge=edge, reg=make_prox(l1_coef, clip, l2_coef),
        rho_scale=None if rho_scale is None else jnp.asarray(rho_scale))


class AsyBADMMState(NamedTuple):
    z_hist: jax.Array      # (D+1, M, dblk) ring buffer, index 0 = newest
    y: jax.Array           # (N, M, dblk) dual blocks (0 outside E)
    w_cache: jax.Array     # (N, M, dblk) server-side stale w~ cache
    x: jax.Array           # (N, M, dblk) last primal iterate (for metrics)
    t: jax.Array           # () int32 epoch
    rng: jax.Array

    @property
    def z_blocks(self):
        return self.z_hist[0]


def init_state(problem: ConsensusProblem, cfg: ADMMConfig,
               z0: Optional[jax.Array] = None) -> AsyBADMMState:
    M, dblk = problem.blocks.num_blocks, problem.blocks.block_dim
    N = problem.num_workers
    if z0 is None:
        z0b = jnp.zeros((M, dblk))
    else:
        z0b = problem.blocks.to_blocks(z0)
    D = cfg.max_delay
    z_hist = jnp.broadcast_to(z0b, (D + 1, M, dblk)).copy()
    rho_i = problem.rho_vec(cfg.rho)[:, None, None]
    return AsyBADMMState(
        z_hist=z_hist,
        y=jnp.zeros((N, M, dblk)),                       # Alg.1 line 2
        w_cache=rho_i * z0b[None] + jnp.zeros((N, M, dblk)),
        x=jnp.broadcast_to(z0b, (N, M, dblk)).copy(),    # Alg.1 line 1
        t=jnp.zeros((), jnp.int32),
        rng=jax.random.PRNGKey(cfg.seed),
    )


def asybadmm_step(problem: ConsensusProblem, cfg: ADMMConfig,
                  state: AsyBADMMState) -> AsyBADMMState:
    """One epoch of Algorithm 1 across all workers + servers."""
    N = problem.num_workers
    M, dblk = problem.blocks.num_blocks, problem.blocks.block_dim
    rng, r_delay, r_sel = jax.random.split(state.rng, 3)

    # --- each worker pulls (possibly stale) z~ per block (Assumption 3) ---
    delays = sample_delays(r_delay, N, M, cfg.max_delay)
    z_tilde = gather_delayed(state.z_hist, delays)       # (N, M, dblk)

    # --- local gradients at z~ (eq. 5 linearization point) ---
    def gfun(zb, di):
        zv = problem.blocks.from_blocks(zb)
        return jax.grad(problem.loss_fn)(zv, di)
    g = jax.vmap(gfun)(z_tilde, problem.data)            # (N, d)
    gb = problem.blocks.to_blocks(g)                     # (N, M, dblk)

    # --- block selection (Alg. 1 line 4; paper also cites Gauss-Seidel
    #     and Gauss-Southwell alternatives [Hong et al. 2016b]) ---
    if cfg.block_selection == "cyclic":
        j = jnp.mod(state.t, M)
        sel = jax.nn.one_hot(j, M, dtype=bool)[None, :] & problem.edge
        sel = sel | (~jnp.any(sel, axis=1, keepdims=True)
                     & select_blocks(r_sel, problem.edge, cfg.block_fraction))
    elif cfg.block_selection == "gauss_southwell":
        gnorm = jnp.sum(jnp.square(gb), axis=-1)          # (N, M)
        gnorm = jnp.where(problem.edge, gnorm, -jnp.inf)
        k = max(1, int(round(cfg.block_fraction * M)))
        thresh = jax.lax.top_k(gnorm, k)[0][:, -1:]
        sel = (gnorm >= thresh) & problem.edge
    else:
        sel = select_blocks(r_sel, problem.edge, cfg.block_fraction)
    selm = sel[..., None]

    # --- worker update (11)(12)(9), masked to selected blocks ---
    rho_i = problem.rho_vec(cfg.rho)[:, None, None]       # (N, 1, 1)
    x_new, y_new, w_new = worker_update(gb, state.y, z_tilde, rho_i)
    x = jnp.where(selm, x_new, state.x)
    y = jnp.where(selm, y_new, state.y)
    w_cache = jnp.where(selm, w_new, state.w_cache)      # push w to server j

    # --- server update (13): fresh w for pushers, stale cache otherwise ---
    edge_m = problem.edge[..., None]
    w_sum = jnp.sum(jnp.where(edge_m, w_cache, 0.0), axis=0)      # (M, dblk)
    rho_sum = jnp.sum(jnp.where(problem.edge, rho_i[:, :, 0], 0.0),
                      axis=0)[:, None]                            # (M, 1)
    z_cur = state.z_hist[0]
    z_new = server_update(z_cur, w_sum, rho_sum, cfg.gamma, problem.reg.prox)

    return AsyBADMMState(
        z_hist=push_history(state.z_hist, z_new),
        y=y, w_cache=w_cache, x=x, t=state.t + 1, rng=rng)


def make_step_fn(problem: ConsensusProblem, cfg: ADMMConfig):
    return jax.jit(lambda s: asybadmm_step(problem, cfg, s))


def run(problem: ConsensusProblem, cfg: ADMMConfig, num_epochs: int,
        z0: Optional[jax.Array] = None, eval_every: int = 0,
        eval_fn: Optional[Callable] = None):
    """Convenience driver: returns (state, history list of eval results)."""
    state = init_state(problem, cfg, z0)
    step = make_step_fn(problem, cfg)
    hist = []
    for t in range(num_epochs):
        state = step(state)
        if eval_every and (t + 1) % eval_every == 0:
            z = problem.blocks.from_blocks(state.z_blocks)
            res = {"epoch": t + 1, "objective": float(problem.objective(z))}
            if eval_fn is not None:
                res.update(eval_fn(problem, state))
            hist.append(res)
    return state, hist
