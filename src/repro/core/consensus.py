"""Flat-mode AsyBADMM driver — the paper's Algorithm 1, end to end.

Since the `VariableSpace` refactor this module is a thin adapter: the
problem description (``ConsensusProblem``) binds data + regularizer +
edge set, and every step routes through the generic
``core.space.asybadmm_epoch`` over a ``FlatSpace``. Baselines fall out
as config points:

* ``max_delay=0, block_fraction=1``  -> block-wise *synchronous* ADMM (§3.1)
* ``num_blocks=1, max_delay>0``      -> full-vector asynchronous ADMM
                                        (Zhang & Kwok 2014 style, the
                                        locking baseline the paper beats)
* ``num_blocks=M, max_delay>0``      -> AsyBADMM (the paper's algorithm)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ADMMConfig
from .blocks import FlatBlocks, make_flat_blocks
from .prox import Regularizer, make_prox
from .space import (ConsensusSpec, ConsensusState, FlatSpace, asybadmm_epoch,
                    init_consensus_state, make_spec)

# Back-compat alias: the flat driver's state is the generic one.
AsyBADMMState = ConsensusState


@dataclasses.dataclass(frozen=True)
class ConsensusProblem:
    """General form consensus problem (eq. 4) over a flat variable.

    loss_fn(z_vec, worker_data) -> scalar f_i; must be smooth.
    data: pytree whose leaves have leading axis N (one slice per worker).
    edge: (N, M) bool — the paper's edge set E.
    """
    loss_fn: Callable
    data: Any
    dim: int
    num_workers: int
    blocks: FlatBlocks
    edge: jnp.ndarray
    reg: Regularizer
    # per-worker penalty multipliers: effective rho_i = cfg.rho * rho_scale[i]
    # (the paper's formulation is heterogeneous-rho throughout)
    rho_scale: Optional[jnp.ndarray] = None

    def rho_vec(self, rho: float) -> jnp.ndarray:
        if self.rho_scale is None:
            return jnp.full((self.num_workers,), rho)
        return rho * self.rho_scale

    def space(self) -> FlatSpace:
        # backend resolution happens in make_spec (cfg.backend / override)
        return FlatSpace(blocks=self.blocks, num_workers=self.num_workers)

    def spec(self, cfg: ADMMConfig, **overrides) -> ConsensusSpec:
        """The generic step spec for this problem under ``cfg``."""
        kw = dict(edge=self.edge, rho_scale=self.rho_scale, reg=self.reg,
                  track_x=True)
        kw.update(overrides)
        return make_spec(self.space(), cfg, self.loss_fn, **kw)

    def worker_loss(self, z_vec, i):
        di = jax.tree.map(lambda a: a[i], self.data)
        return self.loss_fn(z_vec, di)

    def objective(self, z_vec):
        """Global objective (1): sum_i f_i(z) + h(z)."""
        losses = jax.vmap(lambda d: self.loss_fn(z_vec, d))(self.data)
        return jnp.sum(losses) + self.reg.value(z_vec)


def make_problem(loss_fn, data, dim: int, num_blocks: int,
                 support: Optional[np.ndarray] = None,
                 l1_coef: float = 0.0, clip: Optional[float] = None,
                 l2_coef: float = 0.0,
                 rho_scale: Optional[np.ndarray] = None,
                 edge: Optional[Any] = None) -> ConsensusProblem:
    n = jax.tree.leaves(data)[0].shape[0]
    blocks = make_flat_blocks(dim, num_blocks)
    if edge is not None:
        edge = jnp.asarray(edge, bool)
    elif support is not None:
        from .blocks import edge_set_from_support
        edge = jnp.asarray(edge_set_from_support(np.asarray(support), blocks))
    else:
        edge = jnp.ones((n, num_blocks), bool)
    return ConsensusProblem(
        loss_fn=loss_fn, data=data, dim=dim, num_workers=n, blocks=blocks,
        edge=edge, reg=make_prox(l1_coef, clip, l2_coef),
        rho_scale=None if rho_scale is None else jnp.asarray(rho_scale))


def init_state(problem: ConsensusProblem, cfg: ADMMConfig,
               z0: Optional[jax.Array] = None) -> AsyBADMMState:
    return init_consensus_state(problem.spec(cfg), z0)


def asybadmm_step(problem: ConsensusProblem, cfg: ADMMConfig,
                  state: AsyBADMMState) -> AsyBADMMState:
    """One epoch of Algorithm 1 across all workers + servers."""
    new, _ = asybadmm_epoch(problem.spec(cfg), state, problem.data)
    return new


def make_step_fn(problem: ConsensusProblem, cfg: ADMMConfig):
    spec = problem.spec(cfg)
    data = problem.data

    def step(state):
        new, _ = asybadmm_epoch(spec, state, data)
        return new
    return jax.jit(step)


def run(problem: ConsensusProblem, cfg: ADMMConfig, num_epochs: int,
        z0: Optional[jax.Array] = None, eval_every: int = 0,
        eval_fn: Optional[Callable] = None):
    """Convenience driver: returns (state, history list of eval results)."""
    state = init_state(problem, cfg, z0)
    step = make_step_fn(problem, cfg)
    hist = []
    for t in range(num_epochs):
        state = step(state)
        if eval_every and (t + 1) % eval_every == 0:
            z = problem.blocks.from_blocks(state.z_blocks)
            res = {"epoch": t + 1, "objective": float(problem.objective(z))}
            if eval_fn is not None:
                res.update(eval_fn(problem, state))
            hist.append(res)
    return state, hist
