from .admm import server_update, theorem1_feasible, worker_update
from .blocks import (LANE, BlockLayout, FlatBlocks, TreeBlocks,
                     edge_set_from_support, make_block_layout,
                     make_flat_blocks, make_tree_blocks, round_up_to_lane)
from .consensus import (AsyBADMMState, ConsensusProblem, asybadmm_step,
                        init_state, make_problem, make_step_fn, run)
from .metrics import (block_residuals, kkt_violations, stationarity,
                      stationarity_blocks)
from .prox import Regularizer, make_prox, prox_box, prox_l1, soft_threshold
from .space import (BLOCK_SELECTORS, ConsensusSpec, ConsensusState,
                    ConstantDelay, DelayModel, FlatSpace, SelectorContext,
                    TreeSpace, UniformDelay, VariableSpace, asybadmm_epoch,
                    consensus_residual, init_consensus_state, make_spec,
                    register_block_selector, resolve_block_selector)
