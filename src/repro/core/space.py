"""`VariableSpace` — one abstraction over flat-vector and pytree AsyBADMM.

The paper's Algorithm 1 is representation-agnostic: it needs a consensus
variable split into M blocks, a bounded-staleness history per block, a
per-(worker, block) edge set E, and elementwise worker/server updates.
This module owns those mechanics once, behind two interchangeable
implementations:

* ``FlatSpace``  — the decision variable is a flat vector, blocked by
  :class:`~repro.core.blocks.FlatBlocks` (the paper's own workloads:
  sparse logistic regression, eq. 22);
* ``TreeSpace``  — the decision variable is a params pytree, leaves
  assigned to logical blocks by :class:`~repro.core.blocks.TreeBlocks`
  and *lowered* onto the same packed (M, dblk) block table via
  :class:`~repro.core.blocks.BlockLayout` (consensus training of
  transformers). Both spaces share one block-server code path
  (:class:`_PackedOps`); only the user-representation codec differs.

On top of the space sit two pluggable policies:

* **block selection** (Alg. 1 line 4) — a registry shared by both modes:
  ``random`` (Gumbel top-k over the edge neighborhood), ``cyclic``
  (Gauss-Seidel sweep), ``gauss_southwell`` (largest gradient-norm
  blocks) [Hong et al. 2016b];
* **delay model** (Assumption 3) — how per-(i, j) staleness is drawn;
  ``UniformDelay`` reproduces the seed's U{0..D} semantics and
  ``ConstantDelay`` pins a worst-case lag.

``asybadmm_epoch`` is the single generic implementation of one epoch of
Algorithm 1 (all workers + all servers); the flat driver
(``core/consensus.py``), the pytree trainer (``training/trainer.py``)
and the user-facing ``repro.api.ConsensusSession`` are all thin
adapters over it.

Each space carries a **compute backend** for the epoch's elementwise
hot path (``backend="jnp" | "pallas"``, resolved from ``"auto"`` by
:func:`resolve_backend`):

* ``jnp``    — the pure-jnp reference composition (worker update, three
  sel-masked merges, edge-masked reduce, prox);
* ``pallas`` — the fused kernels in ``kernels/admm_update.py`` /
  ``kernels/prox_update.py``: ONE pass over the (N, M, dblk) worker
  bundles for update (11)(12)(9) + the select writes, and a server
  kernel that reduces over workers inside the grid so ``w_sum`` never
  materializes in HBM. Off-TPU the kernels run in interpret mode
  (validation); proxes outside the l1+box family fall back to jnp.

Each space also optionally carries a **mesh** (``mesh=`` on
``ADMMConfig`` / ``ConsensusSession`` / :func:`make_spec`): when set,
``asybadmm_epoch`` dispatches to the SPMD-sharded implementation in
``core/sharded.py`` — worker state sharded over the ``data`` axes,
block servers (both spaces — the packed (M, dblk) table) sharded over
``model``, the paper's w push lowered to a ``psum`` that lands in each
block server's local shard. See ``core/sharded.py`` and API.md's
support matrix.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kernel_ops
from .admm import server_update, worker_update
from .async_sim import (gather_delayed, push_history, sample_delays,
                        select_blocks, subsample_worker_data)
from .blocks import FlatBlocks, TreeBlocks
from .prox import Regularizer, make_prox


# ---------------------------------------------------------------------------
# compute backends (the epoch's elementwise hot path)
# ---------------------------------------------------------------------------

BACKENDS = ("jnp", "pallas", "pallas_stub")


def resolve_backend(backend: Optional[str] = None) -> str:
    """Resolve a space compute backend name.

    ``"auto"``/None picks ``pallas`` on TPU (compiled Mosaic kernels)
    and ``jnp`` everywhere else. An explicit ``"pallas"`` off-TPU runs
    the same kernels in interpret mode (jnp-parity validation — pinned
    by tests/test_backend_parity.py). ``"pallas_stub"`` is internal:
    the fused ops lower as single opaque boundary ops so
    ``analysis/hlo_cost.py`` can charge them exactly their
    operand+result HBM traffic (used by benchmarks/kernels_bench.py).
    """
    if backend in (None, "auto"):
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; "
                         f"expected one of jnp | pallas | auto")
    return backend


# ---------------------------------------------------------------------------
# delay models (Assumption 3 hook)
# ---------------------------------------------------------------------------

class DelayModel(Protocol):
    """How per-(worker, block) staleness tau_ij is drawn each epoch."""

    @property
    def depth(self) -> int:
        """Ring-buffer depth the history must keep (max delay + 1)."""

    def sample(self, rng: jax.Array, n_workers: int, n_blocks: int,
               *, t=None) -> jax.Array:
        """Return (N, M) int32 delays in [0, depth). ``t`` is the epoch
        counter — stochastic models ignore it, :class:`TraceDelay`
        indexes its recorded trace with it."""


def sample_delay_model(dm, rng, n_workers: int, n_blocks: int, t):
    """Call ``dm.sample`` passing the epoch counter, tolerating older
    custom models whose ``sample`` signature predates the ``t=``
    keyword (detected by signature inspection, so a TypeError raised
    INSIDE a t-aware model still surfaces)."""
    import inspect
    try:
        params = inspect.signature(dm.sample).parameters
        has_t = "t" in params or any(
            p.kind is inspect.Parameter.VAR_KEYWORD
            for p in params.values())
    except (TypeError, ValueError):        # builtins/partials: assume new
        has_t = True
    if has_t:
        return dm.sample(rng, n_workers, n_blocks, t=t)
    return dm.sample(rng, n_workers, n_blocks)


def participation_mask_for(dm, t) -> Optional[jax.Array]:
    """(N, 1) bool participation mask for epoch ``t``, or None when the
    delay model has no notion of partial participation (every model but
    :class:`TraceDelay` with recorded absences). Shared by the
    single-device and SPMD epochs so both apply the identical
    ``sel & mask`` contraction."""
    fn = getattr(dm, "participation_mask", None)
    return fn(t) if fn is not None else None


@dataclasses.dataclass(frozen=True)
class UniformDelay:
    """tau_ij ~ U{0..max_delay} i.i.d. per epoch — the seed's semantics."""
    max_delay: int

    @property
    def depth(self) -> int:
        return self.max_delay + 1

    def sample(self, rng, n_workers, n_blocks, *, t=None):
        return sample_delays(rng, n_workers, n_blocks, self.max_delay)


@dataclasses.dataclass(frozen=True)
class ConstantDelay:
    """Every read is exactly ``delay`` epochs stale (worst-case lag)."""
    delay: int

    @property
    def depth(self) -> int:
        return self.delay + 1

    def sample(self, rng, n_workers, n_blocks, *, t=None):
        return jnp.full((n_workers, n_blocks), self.delay, jnp.int32)


@dataclasses.dataclass(frozen=True)
class ParetoDelay:
    """Heavy-tailed straggler staleness, clipped at the history depth:

        tau_ij = clip(floor(Pareto(alpha, x_m=1)) - 1, 0, max_delay)

    Most reads are fresh, but a Pareto tail of (worker, block) pairs
    lags by the full bounded-delay window — the realistic cluster
    profile behind the paper's Table-1 speedup story (a few stragglers
    must not stall the block servers). Smaller ``alpha`` = heavier tail
    (alpha <= 1 has infinite mean before clipping); ``alpha ~ 1.1-1.5``
    matches the straggler measurements in the AD-ADMM line of work."""
    max_delay: int
    alpha: float = 1.2

    @property
    def depth(self) -> int:
        return self.max_delay + 1

    def sample(self, rng, n_workers, n_blocks, *, t=None):
        if self.max_delay == 0:
            return jnp.zeros((n_workers, n_blocks), jnp.int32)
        u = jax.random.uniform(rng, (n_workers, n_blocks),
                               minval=jnp.finfo(jnp.float32).tiny, maxval=1.0)
        tau = jnp.floor(u ** (-1.0 / self.alpha)) - 1.0
        return jnp.clip(tau, 0, self.max_delay).astype(jnp.int32)


@dataclasses.dataclass(frozen=True, eq=False)
class TraceDelay:
    """Replay the exact (rounds, N, M) staleness matrix a PS-runtime run
    recorded (``repro.ps.trace.DelayTrace``) through the fast vectorized
    epoch: ``sample`` ignores the rng draw (the key split still happens,
    so the selection chain is untouched) and returns ``delays[t]``.

    Replaying a trace through ``asybadmm_epoch`` reproduces the
    runtime's z trajectory exactly — pinned by tests/test_ps_runtime.py
    for both spaces, both backends, and the SPMD epoch. Epochs past the
    end of the trace clamp to its final round (replays are meant to run
    exactly ``num_rounds`` epochs).

    ``participation`` (optional, (rounds, N) bool) encodes partial
    participation from elastic/chaos runs: where False, worker i was
    absent for round t (crashed, left, or not yet joined) and
    contributed no edge updates. The epoch ANDs the mask into the
    block-selection matrix, so an absent worker's y / w_cache / x rows
    — and its server-cache contribution — stay frozen for that round,
    exactly matching what a dead worker leaves behind on the servers
    (the partial-participation regime of Chang et al.,
    arXiv:1509.02597). Delay entries of absent rows may be recorded as
    -1 (unobserved) and are sanitized to 0 here; they only feed the
    gather for a row whose effect the mask discards.

    Traces from runs with ``server_crash`` faults replay unchanged:
    WAL recovery (``repro.ps.recovery``) rebuilds exactly the
    committed version history, so every (t, tau) pair the trace
    records is a read of the same ``z^{t-tau}`` the epoch computes —
    the recovery gap costs sim time (stalls, retransmissions), never a
    divergent version."""
    delays: Any                       # (rounds, N, M) int array
    participation: Any = None         # (rounds, N) bool, or None = all
    max_delay: int = dataclasses.field(init=False)

    def __post_init__(self):
        d = np.asarray(self.delays, np.int32)
        if d.ndim != 3 or d.shape[0] < 1:
            raise ValueError(f"trace delays must be (rounds, N, M); "
                             f"got shape {d.shape}")
        if self.participation is not None:
            p = np.asarray(self.participation, bool)
            if p.shape != d.shape[:2]:
                raise ValueError(
                    f"participation must be (rounds, N) = {d.shape[:2]}; "
                    f"got shape {p.shape}")
            if d[p].size and d[p].min() < 0:
                raise ValueError("trace contains negative delays for "
                                 "participating (round, worker) entries")
            d = np.where(p[:, :, None], d, 0)
            # normalize full participation to None so fault-free traces
            # trace the exact pre-elasticity epoch graph
            object.__setattr__(self, "participation", None if p.all() else p)
        elif d.min() < 0:
            raise ValueError("trace contains negative delays")
        object.__setattr__(self, "delays", d)
        object.__setattr__(self, "max_delay", int(d.max()))

    @property
    def num_rounds(self) -> int:
        return self.delays.shape[0]

    @property
    def depth(self) -> int:
        return self.max_delay + 1

    @classmethod
    def load(cls, path) -> "TraceDelay":
        from ..ps.trace import DelayTrace      # lazy: ps imports core.space
        return DelayTrace.load(path).to_delay_model()

    def participation_mask(self, t) -> Optional[jax.Array]:
        """(N, 1) bool mask for epoch ``t`` (clamped like ``sample``),
        or None when the trace has full participation — the epoch then
        skips the AND entirely, keeping fault-free replay graphs
        identical to the pre-elasticity ones."""
        if self.participation is None:
            return None
        R = self.participation.shape[0]
        idx = jnp.clip(jnp.asarray(t, jnp.int32), 0, R - 1)
        return jnp.asarray(self.participation)[idx][:, None]

    def sample(self, rng, n_workers, n_blocks, *, t=None):
        if t is None:
            raise ValueError(
                "TraceDelay needs the epoch counter; drive it through "
                "asybadmm_epoch (which passes t=state.t), not directly")
        R, N, M = self.delays.shape
        if (N, M) != (n_workers, n_blocks):
            raise ValueError(
                f"trace was recorded for (N={N}, M={M}) but the epoch "
                f"asks for (N={n_workers}, M={n_blocks})")
        idx = jnp.clip(jnp.asarray(t, jnp.int32), 0, R - 1)
        return jnp.asarray(self.delays)[idx]


DELAY_MODELS = {"uniform": UniformDelay, "constant": ConstantDelay,
                "pareto": ParetoDelay, "trace": TraceDelay}


# ---------------------------------------------------------------------------
# block-selection policies (Alg. 1 line 4) — one registry for both modes
# ---------------------------------------------------------------------------

class SelectorContext(NamedTuple):
    """Everything a selection policy may look at.

    ``grad_sqnorm`` is a thunk returning the (N, M) per-block squared
    gradient norms — only Gauss-Southwell forces it, and XLA dead-code
    eliminates it otherwise.
    """
    rng: jax.Array
    edge: jax.Array              # (N, M) bool
    t: jax.Array                 # () int32 epoch counter
    block_fraction: float
    grad_sqnorm: Callable[[], jax.Array]


BlockSelector = Callable[[SelectorContext], jax.Array]

BLOCK_SELECTORS: Dict[str, BlockSelector] = {}


def register_block_selector(name: str):
    def deco(fn: BlockSelector) -> BlockSelector:
        BLOCK_SELECTORS[name] = fn
        return fn
    return deco


def resolve_block_selector(sel) -> BlockSelector:
    if callable(sel):
        return sel
    try:
        return BLOCK_SELECTORS[sel]
    except KeyError:
        raise ValueError(
            f"unknown block_selection {sel!r}; "
            f"registered: {sorted(BLOCK_SELECTORS)}") from None


@register_block_selector("random")
def random_selector(ctx: SelectorContext) -> jax.Array:
    """Each worker samples ~frac*M blocks uniformly from its neighborhood."""
    return select_blocks(ctx.rng, ctx.edge, ctx.block_fraction)


@register_block_selector("cyclic")
def cyclic_selector(ctx: SelectorContext) -> jax.Array:
    """Gauss-Seidel sweep: every worker updates block (t mod M); workers
    whose edge set misses that block fall back to a random draw."""
    M = ctx.edge.shape[1]
    j = jnp.mod(ctx.t, M)
    sel = jax.nn.one_hot(j, M, dtype=bool)[None, :] & ctx.edge
    fallback = (~jnp.any(sel, axis=1, keepdims=True)
                & select_blocks(ctx.rng, ctx.edge, ctx.block_fraction))
    return sel | fallback


def make_zipf_selector(a: float = 1.1) -> BlockSelector:
    """Hot/cold block skew: each worker still picks ~frac*M blocks from
    its edge neighborhood, but block j is drawn with weight
    ``(j+1)^-a`` — low-index blocks are hot, the tail is cold. This is
    weighted sampling WITHOUT replacement via the Gumbel-top-k trick
    (add log-weights to the Gumbel scores, then take the same top-k the
    uniform selector uses), so determinism and the exact-count property
    carry over from ``random_selector`` unchanged.

    ``a`` is the Zipf exponent: 0 recovers the uniform selector's
    distribution, ~1.1 matches web-style traffic skew, larger values
    concentrate almost all traffic on the first few blocks. Registered
    as ``"zipf"`` with the default exponent; pass
    ``make_zipf_selector(a)`` (or ``ADMMConfig(zipf_a=...)``) to tune."""
    if not np.isfinite(a) or a < 0.0:
        raise ValueError(f"zipf exponent must be finite and >= 0; got {a}")

    def zipf_selector(ctx: SelectorContext) -> jax.Array:
        N, M = ctx.edge.shape
        k = max(1, min(M, int(round(ctx.block_fraction * M))))
        logw = -a * jnp.log(jnp.arange(1, M + 1, dtype=jnp.float32))
        g = jax.random.gumbel(ctx.rng, (N, M)) + logw[None, :]
        scored = jnp.where(ctx.edge, g, -jnp.inf)
        thresh = jax.lax.top_k(scored, k)[0][:, -1:]
        return (scored >= thresh) & ctx.edge

    zipf_selector.gradient_free = True
    return zipf_selector


register_block_selector("zipf")(make_zipf_selector())


@register_block_selector("gauss_southwell")
def gauss_southwell_selector(ctx: SelectorContext) -> jax.Array:
    """Greedy: exactly the top-k blocks by gradient norm within the edge
    set. Ties are broken deterministically toward the lower block index
    (``top_k`` is stable), so the selected count per worker is always
    min(k, |edge row|) — a ``gnorm >= thresh`` test would over-select
    whole tie groups."""
    M = ctx.edge.shape[1]
    gnorm = jnp.where(ctx.edge, ctx.grad_sqnorm(), -jnp.inf)
    k = max(1, min(M, int(round(ctx.block_fraction * M))))
    _, idx = jax.lax.top_k(gnorm, k)
    sel = jnp.any(jax.nn.one_hot(idx, M, dtype=bool), axis=-2)
    return sel & ctx.edge


# ---------------------------------------------------------------------------
# the space protocol and its two implementations
# ---------------------------------------------------------------------------

class VariableSpace(Protocol):
    """Owns the representation-specific mechanics of Algorithm 1.

    Worker bundles (y, w, x, z~, g) carry a leading worker axis N; the
    consensus value z and its ring-buffer history are worker-free. All
    methods must be pure and jit-traceable.
    """
    num_workers: int

    @property
    def num_blocks(self) -> int: ...
    def init_repr(self, z0: Optional[Any]) -> Any: ...
    def to_user(self, z: Any) -> Any: ...
    def init_history(self, z0: Any, depth: int) -> Any: ...
    def current(self, z_hist: Any) -> Any: ...
    def push(self, z_hist: Any, z_new: Any) -> Any: ...
    def gather(self, z_hist: Any, delays: jax.Array) -> Any: ...
    def worker_grads(self, loss_fn, z_tilde, data, minibatch=None,
                     rng=None) -> Tuple[jax.Array, Any]: ...
    def grad_sqnorm(self, g: Any) -> jax.Array: ...
    def worker_update(self, g, y, z_tilde, rho_vec) -> Tuple[Any, Any, Any]: ...
    def select(self, sel: jax.Array, new: Any, old: Any) -> Any: ...
    def worker_select_update(self, g, y, z_tilde, w_cache, x, sel, rho_vec,
                             track_x: bool) -> Tuple[Any, Any, Any]: ...
    def reduce_workers(self, w: Any, edge: jax.Array) -> Any: ...
    def server_update(self, z_cur, w_sum, rho_sum, gamma, prox) -> Any: ...
    def server_consensus_update(self, z_cur, w_cache, edge, rho_sum, gamma,
                                reg) -> Any: ...
    def zeros_workers(self, z0: Any) -> Any: ...
    def broadcast_workers(self, z0: Any) -> Any: ...
    def workers_scaled(self, z0: Any, rho_vec: jax.Array) -> Any: ...
    def worker_leaves(self, bundle: Any) -> list: ...


class _PackedOps:
    """Shared mechanics of the canonical packed block representation.

    Both spaces lower onto the SAME layout: z is an (M, dblk) block
    table, worker bundles are (N, M, dblk) arrays — the Pallas kernels'
    native shape, so the ``pallas`` backend dispatches without reshapes,
    the SPMD epoch shards (N, M) over (data, model), and the PS runtime
    splits block servers on rows. Subclasses supply the *packer* (the
    user-representation codec: :class:`~repro.core.blocks.FlatBlocks`
    for flat vectors, :class:`~repro.core.blocks.BlockLayout` for params
    pytrees) plus ``init_repr``; everything else — history, gather,
    worker/server updates, kernel dispatch — lives here once.

    With ``mesh`` set the epoch runs SPMD: worker bundles shard
    ``(data, model)`` over their leading (N, M) axes, z_hist shards
    ``model`` over M — the kernels then see local (N/data, M/model,
    dblk) tiles (see core/sharded.py)."""

    @property
    def packer(self):
        return self.blocks

    @property
    def num_blocks(self) -> int:
        return self.packer.num_blocks

    def _use_kernels(self) -> bool:
        return self.backend != "jnp"

    def _stub(self) -> bool:
        return self.backend == "pallas_stub"

    def _tile(self, op: str, N: int, M: int, d: int):
        """Static (blk_m, blk_d) for this kernel dispatch from the
        autotuner table ("cached"/"sweep" modes); None -> the kernels'
        heuristics. Shapes are static at trace time, so this is a pure
        host-side lookup — it never enters the jaxpr."""
        if getattr(self, "autotune", "off") == "off":
            return None
        from ..kernels.autotune import lookup_tile
        return lookup_tile(op, N, M, d)

    # ---- representation -------------------------------------------------
    def to_user(self, z):
        return self.packer.from_blocks(z)

    # ---- history --------------------------------------------------------
    def init_history(self, z0, depth):
        return jnp.broadcast_to(z0, (depth,) + z0.shape).copy()

    def current(self, z_hist):
        return z_hist[0]

    def push(self, z_hist, z_new):
        return push_history(z_hist, z_new)

    def gather(self, z_hist, delays):
        return gather_delayed(z_hist, delays)

    # ---- worker side ----------------------------------------------------
    def worker_grads(self, loss_fn, z_tilde, data, minibatch=None, rng=None):
        data = subsample_worker_data(rng, data, minibatch)

        def vg(zb, di):
            zv = self.packer.from_blocks(zb)
            return jax.value_and_grad(loss_fn)(zv, di)
        losses, g = jax.vmap(vg)(z_tilde, data)
        return losses, self.packer.to_blocks(g)

    def grad_sqnorm(self, g):
        return jnp.sum(jnp.square(g), axis=-1)

    def worker_update(self, g, y, z_tilde, rho_vec):
        return worker_update(g, y, z_tilde, rho_vec[:, None, None])

    def select(self, sel, new, old):
        return jnp.where(sel[..., None], new, old)

    def worker_select_update(self, g, y, z_tilde, w_cache, x, sel, rho_vec,
                             track_x):
        if self._use_kernels():
            N, M, d = g.shape
            out = kernel_ops.admm_worker_select_update(
                g, y, z_tilde, w_cache, sel, rho_vec,
                x if track_x else None, boundary_stub=self._stub(),
                tile=self._tile("worker_select_update", N, M, d))
            return out if track_x else (out[0], out[1], x)
        x_new, y_new, w_new = self.worker_update(g, y, z_tilde, rho_vec)
        return (self.select(sel, y_new, y),
                self.select(sel, w_new, w_cache),
                self.select(sel, x_new, x) if track_x else x)

    # ---- server side ----------------------------------------------------
    def reduce_workers(self, w, edge):
        return jnp.sum(jnp.where(edge[..., None], w, 0.0), axis=0)

    def server_update(self, z_cur, w_sum, rho_sum, gamma, prox):
        return server_update(z_cur, w_sum, rho_sum[:, None], gamma, prox)

    def server_consensus_update(self, z_cur, w_cache, edge, rho_sum, gamma,
                                reg):
        if self._use_kernels() and getattr(reg, "fusable", False):
            N, M, d = w_cache.shape
            return kernel_ops.server_prox_update(
                z_cur, w_cache, edge, rho_sum, gamma, reg.l1_coef,
                0.0 if reg.clip is None else reg.clip,
                boundary_stub=self._stub(),
                tile=self._tile("server_prox_fused", N, M, d))
        w_sum = self.reduce_workers(w_cache, edge)
        return self.server_update(z_cur, w_sum, rho_sum, gamma, reg.prox)

    def server_prox(self, z_cur, w_sum, rho_sum, gamma, reg):
        """Prox step (13) from an already-reduced w_sum — the SPMD path,
        where the worker reduction is a partial sum + psum over ``data``
        and only the prox remains local to the block-server shard."""
        if self._use_kernels() and getattr(reg, "fusable", False):
            return kernel_ops.prox_consensus(
                z_cur, w_sum, rho_sum, gamma, reg.l1_coef,
                0.0 if reg.clip is None else reg.clip,
                boundary_stub=self._stub())
        return self.server_update(z_cur, w_sum, rho_sum, gamma, reg.prox)

    # ---- state construction --------------------------------------------
    def zeros_workers(self, z0):
        return jnp.zeros((self.num_workers,) + z0.shape)

    def broadcast_workers(self, z0):
        return jnp.broadcast_to(z0, (self.num_workers,) + z0.shape).copy()

    def workers_scaled(self, z0, rho_vec):
        return rho_vec[:, None, None] * jnp.broadcast_to(
            z0, (self.num_workers,) + z0.shape)

    def worker_leaves(self, bundle):
        return [bundle]


@dataclasses.dataclass(frozen=True)
class FlatSpace(_PackedOps):
    """Flat-vector consensus: z is (M, dblk) blocks of a padded vector
    (:class:`~repro.core.blocks.FlatBlocks`); worker bundles are
    (N, M, dblk) arrays. All mechanics come from :class:`_PackedOps`."""
    blocks: FlatBlocks
    num_workers: int
    backend: str = "jnp"
    mesh: Any = None
    autotune: str = "off"

    def init_repr(self, z0):
        if z0 is None:
            return jnp.zeros((self.blocks.num_blocks, self.blocks.block_dim))
        return self.blocks.to_blocks(z0)


@dataclasses.dataclass(frozen=True)
class TreeSpace(_PackedOps):
    """Pytree consensus, LOWERED onto the packed block layout: z is the
    same (M, dblk) block table flat mode uses, built by packing block
    j's leaves into row j (:class:`~repro.core.blocks.BlockLayout`,
    zero-padded, bitwise round-trip). Worker bundles are (N, M, dblk)
    arrays; arithmetic runs in the layout's float32 compute dtype and
    leaves cast back to their stored dtype at ``to_user`` (bf16-safe
    under dryrun). Packing touches only the epoch's boundary (the z~
    unpack / gradient repack inside ``worker_grads``) — the hot path,
    kernels, SPMD sharding, and PS block servers all see the packed
    table, identical to ``FlatSpace``.

    Consequences (vs the pre-layout per-leaf fork):

    * the ``pallas`` backend runs the batched (N, M, dblk) kernels
      natively — no per-leaf (N, 1, leaf) views;
    * with ``mesh`` set, z_hist + prox shard over ``model`` exactly like
      flat block servers (no replicated-z fallback);
    * ``Regularizer.fusable`` is honored once per spec (the shared
      server path), not re-decided per leaf;
    * the PS runtime's lock domains key off the layout's block ids for
      both spaces.
    """
    blocks: TreeBlocks
    num_workers: int
    backend: str = "jnp"
    mesh: Any = None
    autotune: str = "off"
    layout: Any = None                    # BlockLayout (required to run)

    @property
    def packer(self):
        if self.layout is None:
            raise ValueError(
                "TreeSpace needs its packed BlockLayout; build the space "
                "via ConsensusSession.pytree / ADMMTrainer, or pass "
                "layout=make_block_layout(params, blocks)")
        return self.layout

    def init_repr(self, z0):
        if z0 is None:
            raise ValueError("TreeSpace needs an initial params pytree")
        return self.packer.to_blocks(z0)


# ---------------------------------------------------------------------------
# the generic state / spec / epoch
# ---------------------------------------------------------------------------

class ConsensusState(NamedTuple):
    """State of Algorithm 1, shared by both spaces.

    z_hist : bounded-staleness ring buffer, leading axis depth (= D+1),
             index 0 newest;
    y      : per-(worker, block) duals (== -last gradient, appendix 25);
    w_cache: server-side stale w~ cache;
    x      : last primal iterates (kept only when the spec tracks them —
             the stationarity metric needs them; () otherwise);
    t      : epoch counter; rng: PRNG key.
    """
    z_hist: Any
    y: Any
    w_cache: Any
    x: Any
    t: jax.Array
    rng: jax.Array

    @property
    def z_blocks(self):
        """Newest consensus blocks (M, dblk) — the packed table both
        spaces share."""
        return self.z_hist[0]


@dataclasses.dataclass(frozen=True)
class ConsensusSpec:
    """Everything one epoch of Algorithm 1 needs besides state + data."""
    space: Any                         # VariableSpace
    loss_fn: Callable                  # loss_fn(z_user, worker_data) -> scalar
    edge: jax.Array                    # (N, M) bool — the paper's E
    rho_vec: jax.Array                 # (N,) per-worker penalties rho_i
    reg: Regularizer
    gamma: float
    block_fraction: float
    selector: BlockSelector
    delay_model: DelayModel
    track_x: bool = False
    seed: int = 0
    # incremental/stochastic workers (Hong 2014): fraction of each
    # worker's samples drawn fresh per epoch (None/1.0 = full batch)
    minibatch: Optional[float] = None


def epoch_keys(rng, minibatch):
    """The per-epoch key split shared by ``asybadmm_epoch``, the SPMD
    body, and the PS runtime: (next_rng, r_delay, r_sel[, r_batch]).
    The split widens to 4 only when minibatching, so full-batch runs
    keep the pre-minibatch rng chain bit-for-bit."""
    if minibatch is not None:
        return jax.random.split(rng, 4)
    return tuple(jax.random.split(rng, 3)) + (None,)


def make_spec(space, cfg, loss_fn, *, edge=None, rho_scale=None, reg=None,
              selector=None, delay_model=None, track_x=False,
              backend=None, mesh=None, minibatch=None,
              autotune=None) -> ConsensusSpec:
    """Build a ConsensusSpec from an ADMMConfig plus problem structure.

    ``backend`` (jnp | pallas | auto) overrides ``cfg.backend`` and is
    resolved onto the space — the one switch that swaps the epoch's
    elementwise hot path between the jnp composition and the fused
    Pallas kernels.

    ``mesh`` (a jax Mesh, or a preset name for
    ``repro.launch.mesh.resolve_mesh``) overrides ``cfg.mesh`` and is
    resolved onto the space — when set, ``asybadmm_epoch`` runs the
    SPMD-sharded implementation (core/sharded.py) over it.

    ``autotune`` (off | cached | sweep) overrides ``cfg.autotune`` and
    selects the kernel-tile source (kernels/autotune.py). "sweep" runs
    the deterministic tile sweep for this spec's shapes here — eagerly,
    never inside a trace — persists the winners, then dispatches like
    "cached"."""
    from ..kernels.autotune import resolve_autotune
    resolved = resolve_backend(
        backend if backend is not None else getattr(cfg, "backend", "auto"))
    from ..launch.mesh import resolve_mesh           # no cycle: mesh.py is leaf
    resolved_mesh = resolve_mesh(
        mesh if mesh is not None else getattr(cfg, "mesh", None))
    resolved_tune = resolve_autotune(
        autotune if autotune is not None else getattr(cfg, "autotune", "off"))
    if dataclasses.is_dataclass(space):
        updates = {}
        if getattr(space, "backend", None) != resolved:
            updates["backend"] = resolved
        if getattr(space, "mesh", None) is not resolved_mesh \
                and resolved_mesh is not None:
            updates["mesh"] = resolved_mesh
        if getattr(space, "autotune", None) != resolved_tune \
                and hasattr(space, "autotune"):
            updates["autotune"] = resolved_tune
        if updates:
            space = dataclasses.replace(space, **updates)
    if getattr(space, "mesh", None) is not None:
        from .sharded import validate_space_mesh
        validate_space_mesh(space)
    if resolved_tune == "sweep" and getattr(space, "autotune", None) == "sweep":
        if getattr(space, "backend", "jnp") == "pallas":
            from ..kernels.autotune import sweep_for_space
            sweep_for_space(space.num_workers, space.num_blocks,
                            space.packer.block_dim,
                            mesh=getattr(space, "mesh", None))
        # sweep happens once, here; dispatch reads the cached winners
        space = dataclasses.replace(space, autotune="cached")
    N, M = space.num_workers, space.num_blocks
    if edge is None:
        edge = jnp.ones((N, M), bool)
    else:
        edge = jnp.asarray(edge, bool)
    if rho_scale is None:
        rho_vec = jnp.full((N,), cfg.rho)
    else:
        rho_vec = cfg.rho * jnp.asarray(rho_scale)
    if reg is None:
        reg = make_prox(cfg.l1_coef, cfg.clip)
    sel_arg = selector if selector is not None else cfg.block_selection
    if sel_arg == "zipf":
        # honor the config's exponent — the registry entry carries the
        # default a=1.1 only
        sel = make_zipf_selector(getattr(cfg, "zipf_a", 1.1))
    else:
        sel = resolve_block_selector(sel_arg)
    if delay_model is None:
        delay_model = UniformDelay(cfg.max_delay)
    if minibatch is None:
        minibatch = getattr(cfg, "minibatch", None)
    if minibatch is not None:
        if not 0.0 < minibatch <= 1.0:
            raise ValueError(f"minibatch fraction must be in (0, 1]; "
                             f"got {minibatch}")
        if minibatch == 1.0:
            minibatch = None               # full batch — keep the 3-way split
    return ConsensusSpec(space=space, loss_fn=loss_fn, edge=edge,
                         rho_vec=rho_vec, reg=reg, gamma=cfg.gamma,
                         block_fraction=cfg.block_fraction, selector=sel,
                         delay_model=delay_model, track_x=track_x,
                         seed=cfg.seed, minibatch=minibatch)


def init_consensus_state(spec: ConsensusSpec, z0=None) -> ConsensusState:
    """Algorithm 1 lines 1-2 in either space. ``z0`` is in user
    representation (flat vector / params pytree; flat mode defaults to 0)."""
    space = spec.space
    z0r = space.init_repr(z0)
    state = ConsensusState(
        z_hist=space.init_history(z0r, spec.delay_model.depth),
        y=space.zeros_workers(z0r),                       # Alg. 1 line 2
        # w init: w = rho_i * x + y with x = z0, y = 0  ->  rho_i * z0
        w_cache=space.workers_scaled(z0r, spec.rho_vec),
        x=space.broadcast_workers(z0r) if spec.track_x else (),  # line 1
        t=jnp.zeros((), jnp.int32),
        rng=jax.random.PRNGKey(spec.seed),
    )
    mesh = getattr(space, "mesh", None)
    if isinstance(mesh, jax.sharding.Mesh):
        # place every state tensor on its NamedSharding up front so the
        # first sharded epoch starts from the right layout (an
        # AbstractMesh — shape-only analysis — has no devices to put to)
        from .sharded import consensus_state_shardings
        state = jax.device_put(state, consensus_state_shardings(spec, state))
    return state


# Divergence watchdog (debug): when enabled, every epoch checks the
# freshly committed z table for NaN/Inf and halts with the offending
# round + block ids (FloatingPointError from the host callback) instead
# of silently training on garbage. Off by default — the check syncs a
# device->host copy per epoch. The PS runtime has its own per-commit
# flavor (``PSRuntime(check_finite=True)``).
_EPOCH_CHECK_FINITE = False


def set_epoch_check_finite(enabled: bool) -> bool:
    """Toggle the epoch-level NaN/Inf watchdog; returns the previous
    setting (so tests/callers can restore it)."""
    global _EPOCH_CHECK_FINITE
    prev = _EPOCH_CHECK_FINITE
    _EPOCH_CHECK_FINITE = bool(enabled)
    return prev


def _raise_nonfinite(t, bad_blocks) -> None:
    bad = np.asarray(bad_blocks)
    if bad.any():
        blocks = np.nonzero(bad)[0].tolist()
        raise FloatingPointError(
            f"asybadmm_epoch divergence watchdog: the round-{int(t)} z "
            f"update produced NaN/Inf in block(s) {blocks} — the run is "
            f"training on garbage. Check rho / gamma / step sizes; "
            f"disable with set_epoch_check_finite(False).")


def asybadmm_epoch(spec: ConsensusSpec, state: ConsensusState, data
                   ) -> Tuple[ConsensusState, Dict[str, jax.Array]]:
    """One epoch of Algorithm 1 across all workers + servers — THE single
    implementation both the flat driver and the pytree trainer use.

    With a mesh on the space, the same epoch runs SPMD (shard_map over
    (data..., model); see core/sharded.py) — the z trajectory is pinned
    equal to this single-device path by tests/test_spmd_parity.py."""
    space = spec.space
    if getattr(space, "mesh", None) is not None:
        from .sharded import sharded_epoch
        return sharded_epoch(spec, state, data)
    N, M = spec.edge.shape
    rng, r_delay, r_sel, r_batch = epoch_keys(state.rng, spec.minibatch)

    # --- each worker pulls (possibly stale) z~ per block (Assumption 3) ---
    delays = sample_delay_model(spec.delay_model, r_delay, N, M, state.t)
    z_tilde = space.gather(state.z_hist, delays)

    # --- local gradients at z~ (eq. 5 linearization point), optionally on
    #     a fresh per-worker minibatch (incremental workers, Hong 2014) ---
    losses, g = space.worker_grads(spec.loss_fn, z_tilde, data,
                                   minibatch=spec.minibatch, rng=r_batch)

    # --- block selection (Alg. 1 line 4) via the shared policy registry ---
    ctx = SelectorContext(rng=r_sel, edge=spec.edge, t=state.t,
                          block_fraction=spec.block_fraction,
                          grad_sqnorm=lambda: space.grad_sqnorm(g))
    sel = spec.selector(ctx)

    # --- partial participation (elastic/chaos replay): absent workers
    #     contribute no edge updates this round — their y/w_cache/x rows
    #     and server-cache contributions stay frozen, matching what a
    #     crashed worker leaves behind on the block servers ---
    pmask = participation_mask_for(spec.delay_model, state.t)
    if pmask is not None:
        sel = sel & pmask

    # --- worker update (11)(12)(9) + the sel-masked merges, one fused
    #     pass over the worker bundles on the pallas backend ---
    y, w_cache, x = space.worker_select_update(
        g, state.y, z_tilde, state.w_cache, state.x, sel, spec.rho_vec,
        spec.track_x)

    # --- server update (13): fresh w for pushers, stale cache otherwise;
    #     pallas fuses the edge-masked reduce into the prox grid ---
    rho_sum = jnp.sum(jnp.where(spec.edge, spec.rho_vec[:, None], 0.0),
                      axis=0)                                       # (M,)
    z_new = space.server_consensus_update(
        space.current(state.z_hist), w_cache, spec.edge, rho_sum,
        spec.gamma, spec.reg)

    if _EPOCH_CHECK_FINITE:
        bad = ~jnp.all(jnp.isfinite(z_new.reshape(z_new.shape[0], -1)),
                       axis=1)
        jax.debug.callback(_raise_nonfinite, state.t, bad)

    info = {"loss": jnp.mean(losses),
            "selected_fraction": jnp.mean(sel.astype(jnp.float32))}
    return ConsensusState(z_hist=space.push(state.z_hist, z_new), y=y,
                          w_cache=w_cache, x=x, t=state.t + 1, rng=rng), info


def consensus_residual(spec: ConsensusSpec, state: ConsensusState) -> jax.Array:
    """Cross-worker dispersion of the w cache (0 at consensus) — the
    space-generic analogue of ``ADMMTrainer.consensus_residual``."""
    num = jnp.zeros((), jnp.float32)
    den = jnp.zeros((), jnp.float32)
    for leaf in spec.space.worker_leaves(state.w_cache):
        w32 = leaf.astype(jnp.float32)
        mean = jnp.mean(w32, axis=0, keepdims=True)
        num = num + jnp.sum(jnp.square(w32 - mean))
        den = den + jnp.sum(jnp.square(mean)) * leaf.shape[0]
    return jnp.sqrt(num / jnp.maximum(den, 1e-12))
