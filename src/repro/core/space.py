"""`VariableSpace` — one abstraction over flat-vector and pytree AsyBADMM.

The paper's Algorithm 1 is representation-agnostic: it needs a consensus
variable split into M blocks, a bounded-staleness history per block, a
per-(worker, block) edge set E, and elementwise worker/server updates.
This module owns those mechanics once, behind two interchangeable
implementations:

* ``FlatSpace``  — the decision variable is a flat vector, blocked by
  :class:`~repro.core.blocks.FlatBlocks` (the paper's own workloads:
  sparse logistic regression, eq. 22);
* ``TreeSpace``  — the decision variable is a params pytree, leaves
  assigned to logical blocks by :class:`~repro.core.blocks.TreeBlocks`
  (consensus training of transformers).

On top of the space sit two pluggable policies:

* **block selection** (Alg. 1 line 4) — a registry shared by both modes:
  ``random`` (Gumbel top-k over the edge neighborhood), ``cyclic``
  (Gauss-Seidel sweep), ``gauss_southwell`` (largest gradient-norm
  blocks) [Hong et al. 2016b];
* **delay model** (Assumption 3) — how per-(i, j) staleness is drawn;
  ``UniformDelay`` reproduces the seed's U{0..D} semantics and
  ``ConstantDelay`` pins a worst-case lag.

``asybadmm_epoch`` is the single generic implementation of one epoch of
Algorithm 1 (all workers + all servers); the flat driver
(``core/consensus.py``), the pytree trainer (``training/trainer.py``)
and the user-facing ``repro.api.ConsensusSession`` are all thin
adapters over it.

Each space carries a **compute backend** for the epoch's elementwise
hot path (``backend="jnp" | "pallas"``, resolved from ``"auto"`` by
:func:`resolve_backend`):

* ``jnp``    — the pure-jnp reference composition (worker update, three
  sel-masked merges, edge-masked reduce, prox);
* ``pallas`` — the fused kernels in ``kernels/admm_update.py`` /
  ``kernels/prox_update.py``: ONE pass over the (N, M, dblk) worker
  bundles for update (11)(12)(9) + the select writes, and a server
  kernel that reduces over workers inside the grid so ``w_sum`` never
  materializes in HBM. Off-TPU the kernels run in interpret mode
  (validation); proxes outside the l1+box family fall back to jnp.

Each space also optionally carries a **mesh** (``mesh=`` on
``ADMMConfig`` / ``ConsensusSession`` / :func:`make_spec`): when set,
``asybadmm_epoch`` dispatches to the SPMD-sharded implementation in
``core/sharded.py`` — worker state sharded over the ``data`` axes,
FlatSpace block servers sharded over ``model``, the paper's w push
lowered to a ``psum`` that lands in each block server's local shard.
See ``core/sharded.py`` and API.md's support matrix.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops as kernel_ops
from .admm import server_update, worker_update
from .async_sim import (gather_delayed, push_history, sample_delays,
                        select_blocks, subsample_worker_data)
from .blocks import FlatBlocks, TreeBlocks
from .prox import Regularizer, make_prox


# ---------------------------------------------------------------------------
# compute backends (the epoch's elementwise hot path)
# ---------------------------------------------------------------------------

BACKENDS = ("jnp", "pallas", "pallas_stub")


def resolve_backend(backend: Optional[str] = None) -> str:
    """Resolve a space compute backend name.

    ``"auto"``/None picks ``pallas`` on TPU (compiled Mosaic kernels)
    and ``jnp`` everywhere else. An explicit ``"pallas"`` off-TPU runs
    the same kernels in interpret mode (jnp-parity validation — pinned
    by tests/test_backend_parity.py). ``"pallas_stub"`` is internal:
    the fused ops lower as single opaque boundary ops so
    ``analysis/hlo_cost.py`` can charge them exactly their
    operand+result HBM traffic (used by benchmarks/kernels_bench.py).
    """
    if backend in (None, "auto"):
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; "
                         f"expected one of jnp | pallas | auto")
    return backend


# ---------------------------------------------------------------------------
# delay models (Assumption 3 hook)
# ---------------------------------------------------------------------------

class DelayModel(Protocol):
    """How per-(worker, block) staleness tau_ij is drawn each epoch."""

    @property
    def depth(self) -> int:
        """Ring-buffer depth the history must keep (max delay + 1)."""

    def sample(self, rng: jax.Array, n_workers: int, n_blocks: int,
               *, t=None) -> jax.Array:
        """Return (N, M) int32 delays in [0, depth). ``t`` is the epoch
        counter — stochastic models ignore it, :class:`TraceDelay`
        indexes its recorded trace with it."""


def sample_delay_model(dm, rng, n_workers: int, n_blocks: int, t):
    """Call ``dm.sample`` passing the epoch counter, tolerating older
    custom models whose ``sample`` signature predates the ``t=``
    keyword (detected by signature inspection, so a TypeError raised
    INSIDE a t-aware model still surfaces)."""
    import inspect
    try:
        params = inspect.signature(dm.sample).parameters
        has_t = "t" in params or any(
            p.kind is inspect.Parameter.VAR_KEYWORD
            for p in params.values())
    except (TypeError, ValueError):        # builtins/partials: assume new
        has_t = True
    if has_t:
        return dm.sample(rng, n_workers, n_blocks, t=t)
    return dm.sample(rng, n_workers, n_blocks)


@dataclasses.dataclass(frozen=True)
class UniformDelay:
    """tau_ij ~ U{0..max_delay} i.i.d. per epoch — the seed's semantics."""
    max_delay: int

    @property
    def depth(self) -> int:
        return self.max_delay + 1

    def sample(self, rng, n_workers, n_blocks, *, t=None):
        return sample_delays(rng, n_workers, n_blocks, self.max_delay)


@dataclasses.dataclass(frozen=True)
class ConstantDelay:
    """Every read is exactly ``delay`` epochs stale (worst-case lag)."""
    delay: int

    @property
    def depth(self) -> int:
        return self.delay + 1

    def sample(self, rng, n_workers, n_blocks, *, t=None):
        return jnp.full((n_workers, n_blocks), self.delay, jnp.int32)


@dataclasses.dataclass(frozen=True)
class ParetoDelay:
    """Heavy-tailed straggler staleness, clipped at the history depth:

        tau_ij = clip(floor(Pareto(alpha, x_m=1)) - 1, 0, max_delay)

    Most reads are fresh, but a Pareto tail of (worker, block) pairs
    lags by the full bounded-delay window — the realistic cluster
    profile behind the paper's Table-1 speedup story (a few stragglers
    must not stall the block servers). Smaller ``alpha`` = heavier tail
    (alpha <= 1 has infinite mean before clipping); ``alpha ~ 1.1-1.5``
    matches the straggler measurements in the AD-ADMM line of work."""
    max_delay: int
    alpha: float = 1.2

    @property
    def depth(self) -> int:
        return self.max_delay + 1

    def sample(self, rng, n_workers, n_blocks, *, t=None):
        if self.max_delay == 0:
            return jnp.zeros((n_workers, n_blocks), jnp.int32)
        u = jax.random.uniform(rng, (n_workers, n_blocks),
                               minval=jnp.finfo(jnp.float32).tiny, maxval=1.0)
        tau = jnp.floor(u ** (-1.0 / self.alpha)) - 1.0
        return jnp.clip(tau, 0, self.max_delay).astype(jnp.int32)


@dataclasses.dataclass(frozen=True, eq=False)
class TraceDelay:
    """Replay the exact (rounds, N, M) staleness matrix a PS-runtime run
    recorded (``repro.ps.trace.DelayTrace``) through the fast vectorized
    epoch: ``sample`` ignores the rng draw (the key split still happens,
    so the selection chain is untouched) and returns ``delays[t]``.

    Replaying a trace through ``asybadmm_epoch`` reproduces the
    runtime's z trajectory exactly — pinned by tests/test_ps_runtime.py
    for both spaces, both backends, and the SPMD epoch. Epochs past the
    end of the trace clamp to its final round (replays are meant to run
    exactly ``num_rounds`` epochs)."""
    delays: Any                       # (rounds, N, M) int array
    max_delay: int = dataclasses.field(init=False)

    def __post_init__(self):
        d = np.asarray(self.delays, np.int32)
        if d.ndim != 3 or d.shape[0] < 1:
            raise ValueError(f"trace delays must be (rounds, N, M); "
                             f"got shape {d.shape}")
        if d.min() < 0:
            raise ValueError("trace contains negative delays")
        object.__setattr__(self, "delays", d)
        object.__setattr__(self, "max_delay", int(d.max()))

    @property
    def num_rounds(self) -> int:
        return self.delays.shape[0]

    @property
    def depth(self) -> int:
        return self.max_delay + 1

    @classmethod
    def load(cls, path) -> "TraceDelay":
        from ..ps.trace import DelayTrace      # lazy: ps imports core.space
        return cls(DelayTrace.load(path).delays)

    def sample(self, rng, n_workers, n_blocks, *, t=None):
        if t is None:
            raise ValueError(
                "TraceDelay needs the epoch counter; drive it through "
                "asybadmm_epoch (which passes t=state.t), not directly")
        R, N, M = self.delays.shape
        if (N, M) != (n_workers, n_blocks):
            raise ValueError(
                f"trace was recorded for (N={N}, M={M}) but the epoch "
                f"asks for (N={n_workers}, M={n_blocks})")
        idx = jnp.clip(jnp.asarray(t, jnp.int32), 0, R - 1)
        return jnp.asarray(self.delays)[idx]


DELAY_MODELS = {"uniform": UniformDelay, "constant": ConstantDelay,
                "pareto": ParetoDelay, "trace": TraceDelay}


# ---------------------------------------------------------------------------
# block-selection policies (Alg. 1 line 4) — one registry for both modes
# ---------------------------------------------------------------------------

class SelectorContext(NamedTuple):
    """Everything a selection policy may look at.

    ``grad_sqnorm`` is a thunk returning the (N, M) per-block squared
    gradient norms — only Gauss-Southwell forces it, and XLA dead-code
    eliminates it otherwise.
    """
    rng: jax.Array
    edge: jax.Array              # (N, M) bool
    t: jax.Array                 # () int32 epoch counter
    block_fraction: float
    grad_sqnorm: Callable[[], jax.Array]


BlockSelector = Callable[[SelectorContext], jax.Array]

BLOCK_SELECTORS: Dict[str, BlockSelector] = {}


def register_block_selector(name: str):
    def deco(fn: BlockSelector) -> BlockSelector:
        BLOCK_SELECTORS[name] = fn
        return fn
    return deco


def resolve_block_selector(sel) -> BlockSelector:
    if callable(sel):
        return sel
    try:
        return BLOCK_SELECTORS[sel]
    except KeyError:
        raise ValueError(
            f"unknown block_selection {sel!r}; "
            f"registered: {sorted(BLOCK_SELECTORS)}") from None


@register_block_selector("random")
def random_selector(ctx: SelectorContext) -> jax.Array:
    """Each worker samples ~frac*M blocks uniformly from its neighborhood."""
    return select_blocks(ctx.rng, ctx.edge, ctx.block_fraction)


@register_block_selector("cyclic")
def cyclic_selector(ctx: SelectorContext) -> jax.Array:
    """Gauss-Seidel sweep: every worker updates block (t mod M); workers
    whose edge set misses that block fall back to a random draw."""
    M = ctx.edge.shape[1]
    j = jnp.mod(ctx.t, M)
    sel = jax.nn.one_hot(j, M, dtype=bool)[None, :] & ctx.edge
    fallback = (~jnp.any(sel, axis=1, keepdims=True)
                & select_blocks(ctx.rng, ctx.edge, ctx.block_fraction))
    return sel | fallback


@register_block_selector("gauss_southwell")
def gauss_southwell_selector(ctx: SelectorContext) -> jax.Array:
    """Greedy: exactly the top-k blocks by gradient norm within the edge
    set. Ties are broken deterministically toward the lower block index
    (``top_k`` is stable), so the selected count per worker is always
    min(k, |edge row|) — a ``gnorm >= thresh`` test would over-select
    whole tie groups."""
    M = ctx.edge.shape[1]
    gnorm = jnp.where(ctx.edge, ctx.grad_sqnorm(), -jnp.inf)
    k = max(1, min(M, int(round(ctx.block_fraction * M))))
    _, idx = jax.lax.top_k(gnorm, k)
    sel = jnp.any(jax.nn.one_hot(idx, M, dtype=bool), axis=-2)
    return sel & ctx.edge


# ---------------------------------------------------------------------------
# the space protocol and its two implementations
# ---------------------------------------------------------------------------

class VariableSpace(Protocol):
    """Owns the representation-specific mechanics of Algorithm 1.

    Worker bundles (y, w, x, z~, g) carry a leading worker axis N; the
    consensus value z and its ring-buffer history are worker-free. All
    methods must be pure and jit-traceable.
    """
    num_workers: int

    @property
    def num_blocks(self) -> int: ...
    def init_repr(self, z0: Optional[Any]) -> Any: ...
    def to_user(self, z: Any) -> Any: ...
    def init_history(self, z0: Any, depth: int) -> Any: ...
    def current(self, z_hist: Any) -> Any: ...
    def push(self, z_hist: Any, z_new: Any) -> Any: ...
    def gather(self, z_hist: Any, delays: jax.Array) -> Any: ...
    def worker_grads(self, loss_fn, z_tilde, data, minibatch=None,
                     rng=None) -> Tuple[jax.Array, Any]: ...
    def grad_sqnorm(self, g: Any) -> jax.Array: ...
    def worker_update(self, g, y, z_tilde, rho_vec) -> Tuple[Any, Any, Any]: ...
    def select(self, sel: jax.Array, new: Any, old: Any) -> Any: ...
    def worker_select_update(self, g, y, z_tilde, w_cache, x, sel, rho_vec,
                             track_x: bool) -> Tuple[Any, Any, Any]: ...
    def reduce_workers(self, w: Any, edge: jax.Array) -> Any: ...
    def server_update(self, z_cur, w_sum, rho_sum, gamma, prox) -> Any: ...
    def server_consensus_update(self, z_cur, w_cache, edge, rho_sum, gamma,
                                reg) -> Any: ...
    def zeros_workers(self, z0: Any) -> Any: ...
    def broadcast_workers(self, z0: Any) -> Any: ...
    def workers_scaled(self, z0: Any, rho_vec: jax.Array) -> Any: ...
    def worker_leaves(self, bundle: Any) -> list: ...


@dataclasses.dataclass(frozen=True)
class FlatSpace:
    """Flat-vector consensus: z is (M, dblk) blocks of a padded vector;
    worker bundles are (N, M, dblk) arrays — the Pallas kernels' native
    layout, so the ``pallas`` backend dispatches without reshapes.

    With ``mesh`` set the epoch runs SPMD: worker bundles shard
    ``(data, model)`` over their leading (N, M) axes, z_hist shards
    ``model`` over M — the kernels then see local (N/data, M/model,
    dblk) tiles (see core/sharded.py)."""
    blocks: FlatBlocks
    num_workers: int
    backend: str = "jnp"
    mesh: Any = None

    @property
    def num_blocks(self) -> int:
        return self.blocks.num_blocks

    def _use_kernels(self) -> bool:
        return self.backend != "jnp"

    def _stub(self) -> bool:
        return self.backend == "pallas_stub"

    # ---- representation -------------------------------------------------
    def init_repr(self, z0):
        if z0 is None:
            return jnp.zeros((self.blocks.num_blocks, self.blocks.block_dim))
        return self.blocks.to_blocks(z0)

    def to_user(self, z):
        return self.blocks.from_blocks(z)

    # ---- history --------------------------------------------------------
    def init_history(self, z0, depth):
        return jnp.broadcast_to(z0, (depth,) + z0.shape).copy()

    def current(self, z_hist):
        return z_hist[0]

    def push(self, z_hist, z_new):
        return push_history(z_hist, z_new)

    def gather(self, z_hist, delays):
        return gather_delayed(z_hist, delays)

    # ---- worker side ----------------------------------------------------
    def worker_grads(self, loss_fn, z_tilde, data, minibatch=None, rng=None):
        data = subsample_worker_data(rng, data, minibatch)

        def vg(zb, di):
            zv = self.blocks.from_blocks(zb)
            return jax.value_and_grad(loss_fn)(zv, di)
        losses, g = jax.vmap(vg)(z_tilde, data)
        return losses, self.blocks.to_blocks(g)

    def grad_sqnorm(self, g):
        return jnp.sum(jnp.square(g), axis=-1)

    def worker_update(self, g, y, z_tilde, rho_vec):
        return worker_update(g, y, z_tilde, rho_vec[:, None, None])

    def select(self, sel, new, old):
        return jnp.where(sel[..., None], new, old)

    def worker_select_update(self, g, y, z_tilde, w_cache, x, sel, rho_vec,
                             track_x):
        if self._use_kernels():
            out = kernel_ops.admm_worker_select_update(
                g, y, z_tilde, w_cache, sel, rho_vec,
                x if track_x else None, boundary_stub=self._stub())
            return out if track_x else (out[0], out[1], x)
        x_new, y_new, w_new = self.worker_update(g, y, z_tilde, rho_vec)
        return (self.select(sel, y_new, y),
                self.select(sel, w_new, w_cache),
                self.select(sel, x_new, x) if track_x else x)

    # ---- server side ----------------------------------------------------
    def reduce_workers(self, w, edge):
        return jnp.sum(jnp.where(edge[..., None], w, 0.0), axis=0)

    def server_update(self, z_cur, w_sum, rho_sum, gamma, prox):
        return server_update(z_cur, w_sum, rho_sum[:, None], gamma, prox)

    def server_consensus_update(self, z_cur, w_cache, edge, rho_sum, gamma,
                                reg):
        if self._use_kernels() and getattr(reg, "fusable", False):
            return kernel_ops.server_prox_update(
                z_cur, w_cache, edge, rho_sum, gamma, reg.l1_coef,
                0.0 if reg.clip is None else reg.clip,
                boundary_stub=self._stub())
        w_sum = self.reduce_workers(w_cache, edge)
        return self.server_update(z_cur, w_sum, rho_sum, gamma, reg.prox)

    def server_prox(self, z_cur, w_sum, rho_sum, gamma, reg):
        """Prox step (13) from an already-reduced w_sum — the SPMD path,
        where the worker reduction is a partial sum + psum over ``data``
        and only the prox remains local to the block-server shard."""
        if self._use_kernels() and getattr(reg, "fusable", False):
            return kernel_ops.prox_consensus(
                z_cur, w_sum, rho_sum, gamma, reg.l1_coef,
                0.0 if reg.clip is None else reg.clip,
                boundary_stub=self._stub())
        return self.server_update(z_cur, w_sum, rho_sum, gamma, reg.prox)

    # ---- state construction --------------------------------------------
    def zeros_workers(self, z0):
        return jnp.zeros((self.num_workers,) + z0.shape)

    def broadcast_workers(self, z0):
        return jnp.broadcast_to(z0, (self.num_workers,) + z0.shape).copy()

    def workers_scaled(self, z0, rho_vec):
        return rho_vec[:, None, None] * jnp.broadcast_to(
            z0, (self.num_workers,) + z0.shape)

    def worker_leaves(self, bundle):
        return [bundle]


@dataclasses.dataclass(frozen=True)
class TreeSpace:
    """Pytree consensus: z is a params pytree; worker bundles are pytrees
    whose leaves carry a leading worker axis N. Block j is the set of
    leaves with ``leaf_block_ids[k] == j``. Arithmetic runs in float32
    and is stored back in each leaf's dtype (bf16-safe under dryrun).

    The ``pallas`` backend routes each leaf through the batched kernels
    as an (N, 1, leaf_size) view — block masks become the single-row
    select mask, so the same fused ops serve both spaces.

    With ``mesh`` set the epoch runs SPMD with the worker axis of every
    bundle leaf sharded over the ``data`` axes; whole leaves cannot be
    split across block servers, so z stays replicated over ``model``
    (documented fallback — see API.md's support matrix)."""
    blocks: TreeBlocks
    num_workers: int
    backend: str = "jnp"
    mesh: Any = None

    @property
    def num_blocks(self) -> int:
        return self.blocks.num_blocks

    def _use_kernels(self) -> bool:
        return self.backend != "jnp"

    def _stub(self) -> bool:
        return self.backend == "pallas_stub"

    def _bid_tree(self):
        return self.blocks.block_id_tree()

    def _wshape(self, leaf):
        return (self.num_workers,) + (1,) * (leaf.ndim - 1)

    # ---- representation -------------------------------------------------
    def init_repr(self, z0):
        if z0 is None:
            raise ValueError("TreeSpace needs an initial params pytree")
        return z0

    def to_user(self, z):
        return z

    # ---- history --------------------------------------------------------
    def init_history(self, z0, depth):
        return jax.tree.map(
            lambda p: jnp.broadcast_to(p, (depth,) + p.shape).copy(), z0)

    def current(self, z_hist):
        return jax.tree.map(lambda a: a[0], z_hist)

    def push(self, z_hist, z_new):
        return jax.tree.map(push_history, z_hist, z_new)

    def gather(self, z_hist, delays):
        return jax.tree.map(lambda zh, bid: zh[delays[:, bid]],
                            z_hist, self._bid_tree())

    # ---- worker side ----------------------------------------------------
    def worker_grads(self, loss_fn, z_tilde, data, minibatch=None, rng=None):
        data = subsample_worker_data(rng, data, minibatch)
        return jax.vmap(jax.value_and_grad(loss_fn))(z_tilde, data)

    def grad_sqnorm(self, g):
        out = jnp.zeros((self.num_workers, self.num_blocks), jnp.float32)
        for leaf, bid in zip(jax.tree.leaves(g), self.blocks.leaf_block_ids):
            sq = jnp.sum(jnp.square(leaf.astype(jnp.float32)),
                         axis=tuple(range(1, leaf.ndim)))
            out = out.at[:, bid].add(sq)
        return out

    def worker_update(self, g, y, z_tilde, rho_vec):
        rho32 = rho_vec.astype(jnp.float32)

        def upd(g_l, y_l, zt_l):
            rho = rho32.reshape(self._wshape(g_l))
            return worker_update(g_l.astype(jnp.float32),
                                 y_l.astype(jnp.float32),
                                 zt_l.astype(jnp.float32), rho)
        out = jax.tree.map(upd, g, y, z_tilde)
        leaf = lambda t: isinstance(t, tuple)
        return tuple(jax.tree.map(lambda t, i=i: t[i], out, is_leaf=leaf)
                     for i in range(3))

    def select(self, sel, new, old):
        def f(n_l, o_l, bid):
            m = sel[:, bid].reshape(self._wshape(o_l))
            return jnp.where(m, n_l, o_l).astype(o_l.dtype)
        return jax.tree.map(f, new, old, self._bid_tree())

    def worker_select_update(self, g, y, z_tilde, w_cache, x, sel, rho_vec,
                             track_x):
        if not self._use_kernels():
            x_new, y_new, w_new = self.worker_update(g, y, z_tilde, rho_vec)
            return (self.select(sel, y_new, y),
                    self.select(sel, w_new, w_cache),
                    self.select(sel, x_new, x) if track_x else x)
        N = self.num_workers
        rho32 = rho_vec.astype(jnp.float32)
        stub = self._stub()
        to3 = lambda a: a.astype(jnp.float32).reshape(N, 1, -1)
        back = lambda o, like: o.reshape(like.shape).astype(like.dtype)

        def upd(g_l, y_l, zt_l, w_l, *rest):
            (x_l, bid) = rest if track_x else (None, rest[0])
            out = kernel_ops.admm_worker_select_update(
                to3(g_l), to3(y_l), to3(zt_l), to3(w_l), sel[:, bid][:, None],
                rho32, None if x_l is None else to3(x_l),
                boundary_stub=stub)
            outs = (back(out[0], y_l), back(out[1], w_l))
            return outs + ((back(out[2], x_l),) if track_x else ())

        args = (g, y, z_tilde, w_cache) + ((x,) if track_x else ())
        out = jax.tree.map(upd, *args, self._bid_tree())
        leaf = lambda t: isinstance(t, tuple)
        y_new, w_new = (jax.tree.map(lambda t, i=i: t[i], out, is_leaf=leaf)
                        for i in range(2))
        x_new = (jax.tree.map(lambda t: t[2], out, is_leaf=leaf)
                 if track_x else x)
        return y_new, w_new, x_new

    # ---- server side ----------------------------------------------------
    def reduce_workers(self, w, edge):
        def f(w_l, bid):
            m = edge[:, bid].reshape(self._wshape(w_l))
            return jnp.sum(jnp.where(m, w_l.astype(jnp.float32), 0.0), axis=0)
        return jax.tree.map(f, w, self._bid_tree())

    def server_update(self, z_cur, w_sum, rho_sum, gamma, prox):
        def f(z_l, ws_l, bid):
            z_new = server_update(z_l.astype(jnp.float32), ws_l,
                                  rho_sum[bid], gamma, prox)
            return z_new.astype(z_l.dtype)
        return jax.tree.map(f, z_cur, w_sum, self._bid_tree())

    def server_consensus_update(self, z_cur, w_cache, edge, rho_sum, gamma,
                                reg):
        if self._use_kernels() and getattr(reg, "fusable", False):
            N = self.num_workers
            stub = self._stub()
            l1 = reg.l1_coef
            clip = 0.0 if reg.clip is None else reg.clip

            def f(z_l, w_l, bid):
                out = kernel_ops.server_prox_update(
                    z_l.astype(jnp.float32).reshape(1, -1),
                    w_l.astype(jnp.float32).reshape(N, 1, -1),
                    edge[:, bid][:, None], rho_sum[bid].reshape(1),
                    gamma, l1, clip, boundary_stub=stub)
                return out.reshape(z_l.shape).astype(z_l.dtype)
            return jax.tree.map(f, z_cur, w_cache, self._bid_tree())
        w_sum = self.reduce_workers(w_cache, edge)
        return self.server_update(z_cur, w_sum, rho_sum, gamma, reg.prox)

    def server_prox(self, z_cur, w_sum, rho_sum, gamma, reg):
        """Prox step (13) from an already-reduced w_sum (SPMD path; the
        per-leaf prox is elementwise, so the jnp composition is used —
        the fused reduce+prox kernel has nothing left to fuse here)."""
        return self.server_update(z_cur, w_sum, rho_sum, gamma, reg.prox)

    # ---- state construction --------------------------------------------
    def zeros_workers(self, z0):
        return jax.tree.map(
            lambda p: jnp.zeros((self.num_workers,) + p.shape, p.dtype), z0)

    def broadcast_workers(self, z0):
        return jax.tree.map(
            lambda p: jnp.broadcast_to(
                p, (self.num_workers,) + p.shape).copy(), z0)

    def workers_scaled(self, z0, rho_vec):
        def f(p):
            rho = rho_vec.astype(jnp.float32).reshape(self._wshape(p[None]))
            return (rho * p[None].astype(jnp.float32)).astype(p.dtype)
        return jax.tree.map(f, z0)

    def worker_leaves(self, bundle):
        return list(jax.tree.leaves(bundle))


# ---------------------------------------------------------------------------
# the generic state / spec / epoch
# ---------------------------------------------------------------------------

class ConsensusState(NamedTuple):
    """State of Algorithm 1, shared by both spaces.

    z_hist : bounded-staleness ring buffer, leading axis depth (= D+1),
             index 0 newest;
    y      : per-(worker, block) duals (== -last gradient, appendix 25);
    w_cache: server-side stale w~ cache;
    x      : last primal iterates (kept only when the spec tracks them —
             the stationarity metric needs them; () otherwise);
    t      : epoch counter; rng: PRNG key.
    """
    z_hist: Any
    y: Any
    w_cache: Any
    x: Any
    t: jax.Array
    rng: jax.Array

    @property
    def z_blocks(self):
        """Flat-mode convenience: newest consensus blocks (M, dblk)."""
        return self.z_hist[0]


@dataclasses.dataclass(frozen=True)
class ConsensusSpec:
    """Everything one epoch of Algorithm 1 needs besides state + data."""
    space: Any                         # VariableSpace
    loss_fn: Callable                  # loss_fn(z_user, worker_data) -> scalar
    edge: jax.Array                    # (N, M) bool — the paper's E
    rho_vec: jax.Array                 # (N,) per-worker penalties rho_i
    reg: Regularizer
    gamma: float
    block_fraction: float
    selector: BlockSelector
    delay_model: DelayModel
    track_x: bool = False
    seed: int = 0
    # incremental/stochastic workers (Hong 2014): fraction of each
    # worker's samples drawn fresh per epoch (None/1.0 = full batch)
    minibatch: Optional[float] = None


def epoch_keys(rng, minibatch):
    """The per-epoch key split shared by ``asybadmm_epoch``, the SPMD
    body, and the PS runtime: (next_rng, r_delay, r_sel[, r_batch]).
    The split widens to 4 only when minibatching, so full-batch runs
    keep the pre-minibatch rng chain bit-for-bit."""
    if minibatch is not None:
        return jax.random.split(rng, 4)
    return tuple(jax.random.split(rng, 3)) + (None,)


def make_spec(space, cfg, loss_fn, *, edge=None, rho_scale=None, reg=None,
              selector=None, delay_model=None, track_x=False,
              backend=None, mesh=None, minibatch=None) -> ConsensusSpec:
    """Build a ConsensusSpec from an ADMMConfig plus problem structure.

    ``backend`` (jnp | pallas | auto) overrides ``cfg.backend`` and is
    resolved onto the space — the one switch that swaps the epoch's
    elementwise hot path between the jnp composition and the fused
    Pallas kernels.

    ``mesh`` (a jax Mesh, or a preset name for
    ``repro.launch.mesh.resolve_mesh``) overrides ``cfg.mesh`` and is
    resolved onto the space — when set, ``asybadmm_epoch`` runs the
    SPMD-sharded implementation (core/sharded.py) over it."""
    resolved = resolve_backend(
        backend if backend is not None else getattr(cfg, "backend", "auto"))
    from ..launch.mesh import resolve_mesh           # no cycle: mesh.py is leaf
    resolved_mesh = resolve_mesh(
        mesh if mesh is not None else getattr(cfg, "mesh", None))
    if dataclasses.is_dataclass(space):
        updates = {}
        if getattr(space, "backend", None) != resolved:
            updates["backend"] = resolved
        if getattr(space, "mesh", None) is not resolved_mesh \
                and resolved_mesh is not None:
            updates["mesh"] = resolved_mesh
        if updates:
            space = dataclasses.replace(space, **updates)
    if getattr(space, "mesh", None) is not None:
        from .sharded import validate_space_mesh
        validate_space_mesh(space)
    N, M = space.num_workers, space.num_blocks
    if edge is None:
        edge = jnp.ones((N, M), bool)
    else:
        edge = jnp.asarray(edge, bool)
    if rho_scale is None:
        rho_vec = jnp.full((N,), cfg.rho)
    else:
        rho_vec = cfg.rho * jnp.asarray(rho_scale)
    if reg is None:
        reg = make_prox(cfg.l1_coef, cfg.clip)
    sel = resolve_block_selector(
        selector if selector is not None else cfg.block_selection)
    if delay_model is None:
        delay_model = UniformDelay(cfg.max_delay)
    if minibatch is None:
        minibatch = getattr(cfg, "minibatch", None)
    if minibatch is not None:
        if not 0.0 < minibatch <= 1.0:
            raise ValueError(f"minibatch fraction must be in (0, 1]; "
                             f"got {minibatch}")
        if minibatch == 1.0:
            minibatch = None               # full batch — keep the 3-way split
    return ConsensusSpec(space=space, loss_fn=loss_fn, edge=edge,
                         rho_vec=rho_vec, reg=reg, gamma=cfg.gamma,
                         block_fraction=cfg.block_fraction, selector=sel,
                         delay_model=delay_model, track_x=track_x,
                         seed=cfg.seed, minibatch=minibatch)


def init_consensus_state(spec: ConsensusSpec, z0=None) -> ConsensusState:
    """Algorithm 1 lines 1-2 in either space. ``z0`` is in user
    representation (flat vector / params pytree; flat mode defaults to 0)."""
    space = spec.space
    z0r = space.init_repr(z0)
    state = ConsensusState(
        z_hist=space.init_history(z0r, spec.delay_model.depth),
        y=space.zeros_workers(z0r),                       # Alg. 1 line 2
        # w init: w = rho_i * x + y with x = z0, y = 0  ->  rho_i * z0
        w_cache=space.workers_scaled(z0r, spec.rho_vec),
        x=space.broadcast_workers(z0r) if spec.track_x else (),  # line 1
        t=jnp.zeros((), jnp.int32),
        rng=jax.random.PRNGKey(spec.seed),
    )
    mesh = getattr(space, "mesh", None)
    if isinstance(mesh, jax.sharding.Mesh):
        # place every state tensor on its NamedSharding up front so the
        # first sharded epoch starts from the right layout (an
        # AbstractMesh — shape-only analysis — has no devices to put to)
        from .sharded import consensus_state_shardings
        state = jax.device_put(state, consensus_state_shardings(spec, state))
    return state


def asybadmm_epoch(spec: ConsensusSpec, state: ConsensusState, data
                   ) -> Tuple[ConsensusState, Dict[str, jax.Array]]:
    """One epoch of Algorithm 1 across all workers + servers — THE single
    implementation both the flat driver and the pytree trainer use.

    With a mesh on the space, the same epoch runs SPMD (shard_map over
    (data..., model); see core/sharded.py) — the z trajectory is pinned
    equal to this single-device path by tests/test_spmd_parity.py."""
    space = spec.space
    if getattr(space, "mesh", None) is not None:
        from .sharded import sharded_epoch
        return sharded_epoch(spec, state, data)
    N, M = spec.edge.shape
    rng, r_delay, r_sel, r_batch = epoch_keys(state.rng, spec.minibatch)

    # --- each worker pulls (possibly stale) z~ per block (Assumption 3) ---
    delays = sample_delay_model(spec.delay_model, r_delay, N, M, state.t)
    z_tilde = space.gather(state.z_hist, delays)

    # --- local gradients at z~ (eq. 5 linearization point), optionally on
    #     a fresh per-worker minibatch (incremental workers, Hong 2014) ---
    losses, g = space.worker_grads(spec.loss_fn, z_tilde, data,
                                   minibatch=spec.minibatch, rng=r_batch)

    # --- block selection (Alg. 1 line 4) via the shared policy registry ---
    ctx = SelectorContext(rng=r_sel, edge=spec.edge, t=state.t,
                          block_fraction=spec.block_fraction,
                          grad_sqnorm=lambda: space.grad_sqnorm(g))
    sel = spec.selector(ctx)

    # --- worker update (11)(12)(9) + the sel-masked merges, one fused
    #     pass over the worker bundles on the pallas backend ---
    y, w_cache, x = space.worker_select_update(
        g, state.y, z_tilde, state.w_cache, state.x, sel, spec.rho_vec,
        spec.track_x)

    # --- server update (13): fresh w for pushers, stale cache otherwise;
    #     pallas fuses the edge-masked reduce into the prox grid ---
    rho_sum = jnp.sum(jnp.where(spec.edge, spec.rho_vec[:, None], 0.0),
                      axis=0)                                       # (M,)
    z_new = space.server_consensus_update(
        space.current(state.z_hist), w_cache, spec.edge, rho_sum,
        spec.gamma, spec.reg)

    info = {"loss": jnp.mean(losses),
            "selected_fraction": jnp.mean(sel.astype(jnp.float32))}
    return ConsensusState(z_hist=space.push(state.z_hist, z_new), y=y,
                          w_cache=w_cache, x=x, t=state.t + 1, rng=rng), info


def consensus_residual(spec: ConsensusSpec, state: ConsensusState) -> jax.Array:
    """Cross-worker dispersion of the w cache (0 at consensus) — the
    space-generic analogue of ``ADMMTrainer.consensus_residual``."""
    num = jnp.zeros((), jnp.float32)
    den = jnp.zeros((), jnp.float32)
    for leaf in spec.space.worker_leaves(state.w_cache):
        w32 = leaf.astype(jnp.float32)
        mean = jnp.mean(w32, axis=0, keepdims=True)
        num = num + jnp.sum(jnp.square(w32 - mean))
        den = den + jnp.sum(jnp.square(mean)) * leaf.shape[0]
    return jnp.sqrt(num / jnp.maximum(den, 1e-12))
