"""Proximal operators for the regularizer h(z) = sum_j h_j(z_j).

The paper's experiment uses h(z) = lambda*||z||_1 with the box constraint
||z||_inf <= C (eq. 22); prox_h^mu under a box is soft-threshold followed
by clipping (both separable, so the composition is exact).

``make_prox`` builds the (prox, h_value) pair consumed by the server
update (eq. 13) and the stationarity metric (eqs. 14-15).
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


def soft_threshold(v, thresh):
    return jnp.sign(v) * jnp.maximum(jnp.abs(v) - thresh, 0.0)


def prox_l1(v, lam, mu):
    """argmin_u lam*|u|_1 + mu/2 ||v-u||^2  = soft_threshold(v, lam/mu)."""
    return soft_threshold(v, lam / mu)


def prox_box(v, clip):
    return jnp.clip(v, -clip, clip)


def prox_l2(v, lam, mu):
    """h = lam/2 ||u||^2 -> shrink by mu/(mu+lam)."""
    return v * (mu / (mu + lam))


def prox_group_lasso(v, lam, mu, group_size: int):
    """h = lam * sum_g ||u_g||_2 over contiguous groups."""
    d = v.shape[-1]
    pad = (-d) % group_size
    vp = jnp.pad(v, [(0, 0)] * (v.ndim - 1) + [(0, pad)])
    g = vp.reshape(vp.shape[:-1] + (-1, group_size))
    norms = jnp.linalg.norm(g, axis=-1, keepdims=True)
    scale = jnp.maximum(1.0 - (lam / mu) / jnp.maximum(norms, 1e-12), 0.0)
    out = (g * scale).reshape(vp.shape)
    return out[..., :d]


class Regularizer(NamedTuple):
    """h(z) and its prox. ``prox(v, mu)`` solves
    argmin_u h(u) + mu/2 ||v - u||^2 subject to the box constraint.

    ``fusable`` marks the prox as belonging to the l1+box family the
    fused Pallas server kernel implements natively; anything else
    (l2 shrinkage, group lasso, custom callables) makes the pallas
    backend fall back to the jnp server path for the prox step.
    """
    prox: Callable
    value: Callable
    l1_coef: float
    clip: Optional[float]
    fusable: bool = False


def make_prox(l1_coef: float = 0.0, clip: Optional[float] = None,
              l2_coef: float = 0.0) -> Regularizer:
    def prox(v, mu):
        u = v
        if l2_coef > 0.0:
            u = prox_l2(u, l2_coef, mu)
        if l1_coef > 0.0:
            u = prox_l1(u, l1_coef, mu)
        if clip is not None:
            u = prox_box(u, clip)
        return u

    def value(z):
        h = jnp.zeros((), jnp.float32)
        if l1_coef > 0.0:
            h = h + l1_coef * jnp.sum(jnp.abs(z))
        if l2_coef > 0.0:
            h = h + 0.5 * l2_coef * jnp.sum(jnp.square(z))
        return h

    # clip=0.0 means the degenerate box {0} here, but the kernel's
    # clip-parameter encodes 0.0 as "no box" — keep that case on jnp
    return Regularizer(prox=prox, value=value, l1_coef=l1_coef, clip=clip,
                       fusable=(l2_coef == 0.0
                                and (clip is None or clip > 0.0)))
