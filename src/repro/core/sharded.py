"""SPMD-sharded epoch of Algorithm 1 over a (data..., model) mesh.

The paper's Parameter-Server picture maps onto the pod directly:

  worker i       = a shard of the ``data`` mesh axes — its duals ``y``,
                   stale-w cache and primal ``x`` live with its data;
  block server j = a shard of the ``model`` axis. BOTH spaces split the
                   canonical packed (M, dblk) block table over ``model``
                   (z_hist, prox and the server kernel all run on local
                   (M/model, dblk) tiles) — TreeSpace lowers its leaves
                   onto that table via ``core.blocks.BlockLayout``, so
                   pytree consensus gets native block servers too (the
                   old replicated-z fallback is gone);
  push w_ij      = a partial edge-masked reduce over the *local*
                   workers followed by ONE ``psum`` over ``data`` that
                   lands directly in each block server's local shard —
                   the full (M, dblk) w_sum never materializes
                   unsharded anywhere.

``sharded_epoch`` wraps the epoch body in ``jax.shard_map`` with the
:func:`consensus_state_specs` layout; the PR-2 Pallas kernels then
execute per shard on their local (N/data, M/model, dblk) tiles.

Parity contract (pinned by tests/test_spmd_parity.py): the sharded z
trajectory equals the single-device ``asybadmm_epoch`` trajectory for
both spaces and all three block selectors. Two ingredients make that
exact rather than approximate:

* delay + selection draws are computed at FULL (N, M) shape on every
  device from the replicated rng key and *sliced* to the local shard —
  identical to the single-device draw (``jax_threefry_partitionable``
  is enabled globally for the same reason);
* every elementwise update runs the same math on a slice; only the
  worker reduction's float-sum order changes (partial + psum), which is
  why the test allows fp32 tolerance there.

``_SimCollectives`` swaps the mesh collectives for single-device
shape-faithful stand-ins so ``benchmarks/kernels_bench.py`` can lower
the per-shard program WITHOUT devices and measure its HBM bytes (the
~1/(data*model) shrink gate).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from ..launch.mesh import data_axes, model_axis_size, num_workers
from .async_sim import minibatch_rows, validate_minibatch_data
from .space import (ConsensusSpec, ConsensusState, SelectorContext,
                    epoch_keys, participation_mask_for, sample_delay_model)


def _splits_model(space) -> bool:
    """Does this space shard its block axis over ``model``? Since the
    packed-layout refactor both spaces do, whenever the axis exists."""
    return model_axis_size(space.mesh) > 1


def validate_space_mesh(space) -> None:
    """Eager divisibility checks so a bad (mesh, problem) pairing fails
    with an actionable message, not a shard_map shape error."""
    mesh = space.mesh
    names = set(mesh.axis_names)
    if not names <= {"pod", "data", "model"}:
        raise ValueError(f"mesh axes {mesh.axis_names} unknown; expected a "
                         f"subset of ('pod', 'data', 'model')")
    nsh = num_workers(mesh)
    if space.num_workers % nsh != 0:
        raise ValueError(
            f"num_workers={space.num_workers} must divide over the mesh's "
            f"{nsh} data-axis shards ({data_axes(mesh)}); pad the worker "
            f"set or pick a smaller mesh")
    if _splits_model(space):
        msize = model_axis_size(mesh)
        if space.num_blocks % msize != 0:
            raise ValueError(
                f"num_blocks={space.num_blocks} must divide over "
                f"model={msize} block-server shards; choose num_blocks as "
                f"a multiple of the model axis (both spaces shard the "
                f"packed (M, dblk) block table over model)")


# ---------------------------------------------------------------------------
# NamedSharding specs for every state tensor
# ---------------------------------------------------------------------------

def worker_bundle_spec(ndim: int, daxes, mname=None) -> P:
    """Worker-bundle leaf: leading N over data axes, (flat) M over model.
    THE base rule for every (N, ...) ADMM tensor — launch/shardings.py
    overlays its tensor-parallel param dims on top of this."""
    return P(*((daxes, mname) + (None,) * (ndim - 2))[:ndim])


def ring_spec(ndim: int, mname=None) -> P:
    """History leaf: leading ring axis replicated, (flat) M over model."""
    return P(*((None, mname) + (None,) * (ndim - 2))[:ndim])


def consensus_state_specs(spec: ConsensusSpec, state) -> ConsensusState:
    """PartitionSpec for every ``ConsensusState`` tensor on the space's
    mesh — THE canonical ADMM state layout (launch/shardings.py overlays
    its tensor-parallel param dims on top of this base for the dryrun)."""
    space = spec.space
    daxes = data_axes(space.mesh)
    mname = "model" if _splits_model(space) else None
    w = lambda leaf: worker_bundle_spec(leaf.ndim, daxes, mname)
    z = lambda leaf: ring_spec(leaf.ndim, mname)
    return ConsensusState(
        z_hist=jax.tree.map(z, state.z_hist),
        y=jax.tree.map(w, state.y),
        w_cache=jax.tree.map(w, state.w_cache),
        x=jax.tree.map(w, state.x),
        t=P(), rng=P())


def grad_split_size(spec: ConsensusSpec):
    """Workers-per-device of the model-split gradient pass, or None when
    grads replicate over model (no model split, or the local worker
    count does not divide by the model axis)."""
    space = spec.space
    if not _splits_model(space):
        return None
    Nl = space.num_workers // num_workers(space.mesh)
    msize = model_axis_size(space.mesh)
    return Nl // msize if Nl and Nl % msize == 0 else None


def consensus_data_specs(spec: ConsensusSpec, data):
    """Per-worker data: leading worker axis over the data mesh axes —
    and additionally over ``model`` when the gradient pass splits the
    local workers across it (every device then holds exactly the rows
    its grad shard differentiates)."""
    daxes = data_axes(spec.space.mesh)
    ax0 = tuple(daxes) if isinstance(daxes, (tuple, list)) else (daxes,)
    if grad_split_size(spec) is not None:
        ax0 = ax0 + ("model",)
    return jax.tree.map(lambda a: P(*((ax0,) + (None,) * (a.ndim - 1))),
                        data)


def consensus_state_shardings(spec: ConsensusSpec, state) -> ConsensusState:
    """NamedSharding tree for ``jax.device_put`` of the state."""
    mesh = spec.space.mesh
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        consensus_state_specs(spec, state),
                        is_leaf=lambda v: isinstance(v, P))


# ---------------------------------------------------------------------------
# collectives — real mesh axes vs the single-device costing stand-in
# ---------------------------------------------------------------------------

class _MeshCollectives:
    """The real thing: axis-index slicing, all_gather, psum."""

    def __init__(self, mesh, daxes):
        self.mesh, self.daxes = mesh, daxes

    def worker_shard_index(self):
        wi = jnp.zeros((), jnp.int32)
        for a in self.daxes:                      # row-major over data axes
            wi = wi * self.mesh.shape[a] + lax.axis_index(a)
        return wi

    def model_index(self):
        return lax.axis_index("model")

    def all_gather_model(self, x, axis):
        return lax.all_gather(x, "model", axis=axis, tiled=True)

    def all_to_all_model(self, x, split_axis, concat_axis):
        return lax.all_to_all(x, "model", split_axis, concat_axis,
                              tiled=True)

    def all_gather_data(self, x):
        return lax.all_gather(x, self.daxes, axis=0, tiled=True)

    def psum_data(self, x):
        return lax.psum(x, self.daxes)


class _SimCollectives:
    """Single-device stand-in with the same SHAPE semantics, so the
    per-shard program can be lowered (abstractly) without any devices
    and costed by analysis/hlo_cost. Each stand-in is chosen so its
    generic operand+result charge equals what the analyzer charges the
    REAL collective op's boundary: all-gather -> one pad (local shard in,
    full buffer out), all-to-all -> one reshape (same bytes in and out),
    psum -> one multiply (shard in, shard out)."""

    def __init__(self, nsh: int, msize: int):
        self.nsh, self.msize = nsh, msize

    def worker_shard_index(self):
        return jnp.zeros((), jnp.int32)

    def model_index(self):
        return jnp.zeros((), jnp.int32)

    @staticmethod
    def _gather(x, axis, size):
        cfg = [(0, 0, 0)] * x.ndim
        cfg[axis] = (0, (size - 1) * x.shape[axis], 0)
        return lax.pad(x, jnp.zeros((), x.dtype), cfg)

    def all_gather_model(self, x, axis):
        return self._gather(x, axis, self.msize)

    def all_to_all_model(self, x, split_axis, concat_axis):
        shape = list(x.shape)
        shape[split_axis] //= self.msize
        shape[concat_axis] *= self.msize
        return x.reshape(shape)

    def all_gather_data(self, x):
        return self._gather(x, 0, self.nsh)

    def psum_data(self, x):
        return jax.tree.map(lambda a: a * jnp.float32(self.nsh), x)


# ---------------------------------------------------------------------------
# the per-shard epoch body (Algorithm 1, local view)
# ---------------------------------------------------------------------------

def _epoch_body(spec: ConsensusSpec, space_l, coll, Nl: int, Ml: int,
                state: ConsensusState, data, edge, rho_vec
                ) -> Tuple[ConsensusState, dict]:
    """One epoch on ONE shard. ``space_l`` is the space resized to the
    local worker count (num_workers=Nl, mesh=None); all worker bundles
    in ``state`` are local (Nl, [Ml,] ...) tiles; ``edge`` / ``rho_vec``
    arrive replicated at full (N, M) / (N,) shape."""
    N, M = edge.shape
    split_model = Ml < M
    msize = M // Ml if split_model else 1
    split_grads = split_model and Nl % msize == 0
    Ng = Nl // msize if split_grads else Nl       # local data rows
    rng, r_delay, r_sel, r_batch = epoch_keys(state.rng, spec.minibatch)
    wi = coll.worker_shard_index()
    mi = coll.model_index() if split_model else None

    def rows(a):                                  # full (N, ...) -> local N
        return lax.dynamic_slice_in_dim(a, wi * Nl, Nl, 0)

    def take(a):                                  # local Nl -> grad shard
        if not split_grads:
            return a
        return lax.dynamic_slice_in_dim(a, mi * Ng, Ng, 0)

    def cols(a, axis=1):                          # full M -> local blocks
        if not split_model:
            return a
        return lax.dynamic_slice_in_dim(a, mi * Ml, Ml, axis)

    # --- stale pull: FULL (N, M) replicated draw, sliced to the shard ---
    delays = sample_delay_model(spec.delay_model, r_delay, N, M, state.t)
    z_tilde = space_l.gather(state.z_hist, cols(rows(delays)))

    # --- minibatch draw, like delay/selection: FULL (N, S) replicated,
    #     sliced to the local worker rows (== the single-device draw).
    #     Data arrives sharded to the rows this device differentiates:
    #     (Nl, ...) normally, (Ng, ...) under the split gradient pass
    #     (consensus_data_specs adds the model axis). ---
    if spec.minibatch is not None and spec.minibatch < 1.0:
        shape = validate_minibatch_data(data)
        if shape is not None:              # leafless data: no-op, like
            S = shape[1]                   # subsample_worker_data
            idx_l = take(rows(minibatch_rows(r_batch, N, S, spec.minibatch)))
            data = jax.tree.map(
                lambda a: a[jnp.arange(Ng)[:, None], idx_l], data)

    # --- grads need every block of z~ for the local workers (the loss
    #     reads the whole variable). The model axis is redundant during
    #     this pass — every model shard would differentiate the same Nl
    #     workers against the same gathered z~ — so when the local
    #     workers divide evenly, split them across it (grads are
    #     per-worker: pure extra data parallelism), then route the
    #     results with one all_to_all (worker axis scattered back, block
    #     axis collected). Per-worker grads and losses are bitwise
    #     identical to the unsplit path, so the trajectory, the
    #     selection draw, and the reported loss are unchanged while the
    #     per-shard gradient traffic shrinks by 1/model instead of
    #     replicating. ---
    if split_grads:
        # NOT take-then-gather: each model shard holds DIFFERENT blocks,
        # so gathering take(z_tilde) would stitch chunk m's blocks onto
        # chunk m's workers. The all_to_all routes every shard's block
        # slice of the destination's worker rows — the exact inverse of
        # the gradient exchange below.
        zt_g = coll.all_to_all_model(z_tilde, 0, 1)   # (Ng, M, dblk)
        space_g = dataclasses.replace(space_l, num_workers=Ng)
        losses_g, g_g = space_g.worker_grads(spec.loss_fn, zt_g, data)
        losses = coll.all_gather_model(losses_g, axis=0)
        g_cols = coll.all_to_all_model(g_g, 1, 0)     # (Nl, Ml, dblk)
        gnorm_fn = lambda: coll.all_gather_data(
            coll.all_gather_model(space_g.grad_sqnorm(g_g), axis=0))
    else:
        z_tilde_full = (coll.all_gather_model(z_tilde, axis=1)
                        if split_model else z_tilde)
        losses, g = space_l.worker_grads(spec.loss_fn, z_tilde_full, data)
        g_cols = cols(g)
        gnorm_fn = lambda: coll.all_gather_data(space_l.grad_sqnorm(g))

    # --- selection at FULL (N, M), replicated — identical to the
    #     single-device draw (Gauss-Southwell additionally gathers the
    #     per-block grad norms over the data axes) ---
    ctx = SelectorContext(
        rng=r_sel, edge=edge, t=state.t,
        block_fraction=spec.block_fraction,
        grad_sqnorm=gnorm_fn)
    sel = spec.selector(ctx)

    # --- partial participation (chaos replay): same full-(N, 1) mask
    #     the single-device epoch ANDs in, applied before slicing so the
    #     local tile sees the identical selection ---
    pmask = participation_mask_for(spec.delay_model, state.t)
    if pmask is not None:
        sel = sel & pmask

    # --- worker update (11)(12)(9) + select writes on the local tile ---
    y, w_cache, x = space_l.worker_select_update(
        g_cols, state.y, z_tilde, state.w_cache, state.x,
        cols(rows(sel)), rows(rho_vec), spec.track_x)

    # --- the paper's w push: partial edge-masked reduce over the LOCAL
    #     workers, then one psum over data that lands in this block
    #     server's shard — w_sum never exists unsharded ---
    w_sum = coll.psum_data(space_l.reduce_workers(w_cache, cols(rows(edge))))
    rho_sum = cols(jnp.sum(jnp.where(edge, rho_vec[:, None], 0.0), axis=0),
                   axis=0)
    z_new = space_l.server_prox(space_l.current(state.z_hist), w_sum,
                                rho_sum, spec.gamma, spec.reg)

    loss = coll.psum_data(jnp.sum(losses)) / N
    info = {"loss": loss,
            "selected_fraction": jnp.mean(sel.astype(jnp.float32))}
    new_state = ConsensusState(
        z_hist=space_l.push(state.z_hist, z_new), y=y, w_cache=w_cache,
        x=x, t=state.t + 1, rng=rng)
    return new_state, info


def _local_sizes(spec: ConsensusSpec) -> Tuple[int, int]:
    space = spec.space
    Nl = space.num_workers // num_workers(space.mesh)
    Ml = (space.num_blocks // model_axis_size(space.mesh)
          if _splits_model(space) else space.num_blocks)
    return Nl, Ml


def _local_space(spec: ConsensusSpec, Nl: int):
    return dataclasses.replace(spec.space, num_workers=Nl, mesh=None)


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------

def sharded_epoch(spec: ConsensusSpec, state: ConsensusState, data
                  ) -> Tuple[ConsensusState, dict]:
    """``asybadmm_epoch`` over the space's mesh via shard_map."""
    space = spec.space
    mesh = space.mesh
    daxes = data_axes(mesh)
    Nl, Ml = _local_sizes(spec)
    space_l = _local_space(spec, Nl)
    coll = _MeshCollectives(mesh, daxes)

    def body(st, d, e, r):
        return _epoch_body(spec, space_l, coll, Nl, Ml, st, d, e, r)

    sspecs = consensus_state_specs(spec, state)
    in_specs = (sspecs, consensus_data_specs(spec, data), P(), P())
    out_specs = (sspecs, {"loss": P(), "selected_fraction": P()})
    fn = shard_map(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)
    return fn(state, data, spec.edge, spec.rho_vec)


def per_shard_cost_program(spec: ConsensusSpec, data, z0=None):
    """(fn, example_args) lowering ONE shard of the sharded epoch on a
    single (possibly absent) device: collectives are replaced by the
    shape-faithful :class:`_SimCollectives` and all inputs are shrunk to
    their local tile per :func:`consensus_state_specs`. Used by
    benchmarks/kernels_bench.py to measure per-shard HBM bytes — the
    mesh may be an ``AbstractMesh``, nothing is executed. ``z0`` (shape
    structs suffice) is required for TreeSpace, which has no default
    initial value."""
    from .space import init_consensus_state
    space = spec.space
    mesh = space.mesh
    Nl, Ml = _local_sizes(spec)
    space_l = _local_space(spec, Nl)
    coll = _SimCollectives(num_workers(mesh),
                           model_axis_size(mesh) if _splits_model(space)
                           else 1)

    if z0 is None:
        state = jax.eval_shape(lambda: init_consensus_state(spec))
    else:
        state = jax.eval_shape(lambda p: init_consensus_state(spec, p), z0)
    sspecs = consensus_state_specs(spec, state)

    def shrink(sds, pspec):
        shape = list(sds.shape)
        for i, entry in enumerate(pspec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                shape[i] //= mesh.shape[a]
        return jax.ShapeDtypeStruct(tuple(shape), sds.dtype)

    local_state = jax.tree.map(shrink, state, sspecs,
                               is_leaf=lambda v: isinstance(v, P))
    local_data = jax.tree.map(shrink, data, consensus_data_specs(spec, data),
                              is_leaf=lambda v: isinstance(v, P))

    def fn(st, d, e, r):
        return _epoch_body(spec, space_l, coll, Nl, Ml, st, d, e, r)

    return fn, (local_state, local_data,
                jax.ShapeDtypeStruct(spec.edge.shape, spec.edge.dtype),
                jax.ShapeDtypeStruct(spec.rho_vec.shape, spec.rho_vec.dtype))
