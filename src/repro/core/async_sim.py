"""Bounded-delay asynchrony simulation (Assumption 3).

True asynchrony does not exist inside an SPMD program; what the theory
needs is only *bounded staleness*: z~_j^t = z_j^{t-tau}, tau <= T_ij.
We reproduce exactly that semantics deterministically:

* a ring buffer keeps the last D+1 versions of every z block
  (index 0 = newest);
* each worker draws a per-(i, j) delay tau_ij ~ U{0..D} per step and
  reads z~_ij = z_hist[tau_ij, j];
* the server mixes fresh w pushes with its stale w~ cache (eq. 13).

This makes delay a *sweepable, seedable* experiment parameter — the
tests sweep it to verify the Theorem 1 convergence claims.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def push_history(z_hist, z_new):
    """z_hist: (D+1, M, dblk); insert z_new at index 0, shifting back."""
    if z_hist.shape[0] == 1:
        return z_new[None]
    return jnp.concatenate([z_new[None], z_hist[:-1]], axis=0)


def sample_delays(rng, n_workers: int, n_blocks: int, max_delay: int):
    """Per-(i,j) integer delays in [0, max_delay]."""
    if max_delay == 0:
        return jnp.zeros((n_workers, n_blocks), jnp.int32)
    return jax.random.randint(rng, (n_workers, n_blocks), 0, max_delay + 1)


def gather_delayed(z_hist, delays):
    """z_hist: (D+1, M, dblk); delays: (N, M) -> z~: (N, M, dblk)."""
    return z_hist[delays, jnp.arange(z_hist.shape[1])[None, :]]


def minibatch_rows(rng, n_workers: int, n_samples: int, fraction: float):
    """Per-worker without-replacement subsample indices (N, k) with
    k = max(1, round(fraction * n_samples)) — a uniform random-subset
    draw realized as an argsort of i.i.d. uniforms so it stays
    jit-traceable and, with ``jax_threefry_partitionable``, identical
    whether evaluated at full (N, S) shape or row-sliced per shard
    (the SPMD epoch and the PS runtime both rely on that)."""
    k = max(1, min(n_samples, int(round(fraction * n_samples))))
    u = jax.random.uniform(rng, (n_workers, n_samples))
    return jnp.argsort(u, axis=1)[:, :k]


def validate_minibatch_data(data):
    """Check every data leaf is (num_workers, samples, ...) with one
    shared sample axis; returns (num_workers, num_samples). Shared by
    the single-device and SPMD epochs so both fail identically on
    malformed pytrees (instead of JAX silently clamping gather
    indices)."""
    leaves = jax.tree.leaves(data)
    if not leaves:
        return None
    n_samples = leaves[0].shape[1] if leaves[0].ndim >= 2 else None
    for leaf in leaves:
        if leaf.ndim < 2 or leaf.shape[1] != n_samples:
            raise ValueError(
                f"minibatch subsampling needs every data leaf shaped "
                f"(num_workers, samples, ...); got {leaf.shape} vs "
                f"samples={n_samples}")
    return leaves[0].shape[0], n_samples


def subsample_worker_data(rng, data, fraction):
    """Incremental/stochastic worker gradients (Hong 2014): subsample a
    ``fraction`` of every worker's samples along axis 1 of each data
    leaf, using the SAME per-worker row indices across leaves (X and y
    stay aligned). ``fraction`` of None / >= 1 is a no-op."""
    if fraction is None or fraction >= 1.0:
        return data
    shape = validate_minibatch_data(data)
    if shape is None:
        return data
    n_workers, n_samples = shape
    idx = minibatch_rows(rng, n_workers, n_samples, fraction)
    rows = jnp.arange(n_workers)[:, None]
    return jax.tree.map(lambda a: a[rows, idx], data)


def select_blocks(rng, edge, block_fraction: float):
    """Per-worker random block selection (Alg. 1 line 4).

    edge: (N, M) bool.  block_fraction == 1 selects every block in N(i)
    (the synchronous full-sweep limit); otherwise each worker samples
    ~max(1, frac*|N(i)|) blocks uniformly from its neighborhood without
    replacement (Gumbel top-k over the edge support).
    """
    N, M = edge.shape
    if block_fraction >= 1.0:
        return edge
    k = max(1, int(round(block_fraction * M)))
    gumbel = jax.random.gumbel(rng, (N, M))
    scored = jnp.where(edge, gumbel, -jnp.inf)
    thresh = jax.lax.top_k(scored, k)[0][:, -1:]
    sel = (scored >= thresh) & edge
    return sel
