"""Bounded-delay asynchrony simulation (Assumption 3).

True asynchrony does not exist inside an SPMD program; what the theory
needs is only *bounded staleness*: z~_j^t = z_j^{t-tau}, tau <= T_ij.
We reproduce exactly that semantics deterministically:

* a ring buffer keeps the last D+1 versions of every z block
  (index 0 = newest);
* each worker draws a per-(i, j) delay tau_ij ~ U{0..D} per step and
  reads z~_ij = z_hist[tau_ij, j];
* the server mixes fresh w pushes with its stale w~ cache (eq. 13).

This makes delay a *sweepable, seedable* experiment parameter — the
tests sweep it to verify the Theorem 1 convergence claims.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def push_history(z_hist, z_new):
    """z_hist: (D+1, M, dblk); insert z_new at index 0, shifting back."""
    if z_hist.shape[0] == 1:
        return z_new[None]
    return jnp.concatenate([z_new[None], z_hist[:-1]], axis=0)


def sample_delays(rng, n_workers: int, n_blocks: int, max_delay: int):
    """Per-(i,j) integer delays in [0, max_delay]."""
    if max_delay == 0:
        return jnp.zeros((n_workers, n_blocks), jnp.int32)
    return jax.random.randint(rng, (n_workers, n_blocks), 0, max_delay + 1)


def gather_delayed(z_hist, delays):
    """z_hist: (D+1, M, dblk); delays: (N, M) -> z~: (N, M, dblk)."""
    return z_hist[delays, jnp.arange(z_hist.shape[1])[None, :]]


def select_blocks(rng, edge, block_fraction: float):
    """Per-worker random block selection (Alg. 1 line 4).

    edge: (N, M) bool.  block_fraction == 1 selects every block in N(i)
    (the synchronous full-sweep limit); otherwise each worker samples
    ~max(1, frac*|N(i)|) blocks uniformly from its neighborhood without
    replacement (Gumbel top-k over the edge support).
    """
    N, M = edge.shape
    if block_fraction >= 1.0:
        return edge
    k = max(1, int(round(block_fraction * M)))
    gumbel = jax.random.gumbel(rng, (N, M))
    scored = jnp.where(edge, gumbel, -jnp.inf)
    thresh = jax.lax.top_k(scored, k)[0][:, -1:]
    sel = (scored >= thresh) & edge
    return sel
