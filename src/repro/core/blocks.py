"""Block partitioning for general form consensus (paper §2.2).

Two representations:

* **Flat mode** (the paper's own workloads — sparse logistic regression):
  the decision variable is a flat vector of dim ``d`` padded and reshaped
  to ``(M, d/M)``; block j is row j. The edge set E is an (N, M) bool
  matrix: worker i touches block j iff its local data has support there.

* **Pytree mode** (transformer consensus training): every parameter leaf
  is assigned to one of M logical blocks, balanced by parameter count
  (greedy LPT). Per-block masks are realized as per-leaf scalar 0/1
  multipliers so masked updates stay fully vectorized under jit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# flat mode
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FlatBlocks:
    dim: int          # original vector dim
    num_blocks: int   # M
    block_dim: int    # padded per-block dim

    @property
    def padded_dim(self) -> int:
        return self.num_blocks * self.block_dim

    def to_blocks(self, v):
        """(..., d) -> (..., M, block_dim)."""
        pad = self.padded_dim - self.dim
        vp = jnp.pad(v, [(0, 0)] * (v.ndim - 1) + [(0, pad)])
        return vp.reshape(v.shape[:-1] + (self.num_blocks, self.block_dim))

    def from_blocks(self, b):
        """(..., M, block_dim) -> (..., d)."""
        flat = b.reshape(b.shape[:-2] + (self.padded_dim,))
        return flat[..., : self.dim]


def make_flat_blocks(dim: int, num_blocks: int) -> FlatBlocks:
    block_dim = -(-dim // num_blocks)
    return FlatBlocks(dim=dim, num_blocks=num_blocks, block_dim=block_dim)


def edge_set_from_support(support: np.ndarray, blocks: FlatBlocks) -> np.ndarray:
    """support: (N, d) bool — which coordinates each worker's data touches.
    Returns E: (N, M) bool (worker i, block j) — the paper's edge set."""
    N, d = support.shape
    pad = blocks.padded_dim - d
    sp = np.pad(support, [(0, 0), (0, pad)])
    return sp.reshape(N, blocks.num_blocks, blocks.block_dim).any(axis=-1)


# --------------------------------------------------------------------------
# pytree mode
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TreeBlocks:
    """Per-leaf block ids for a params pytree (greedy size-balanced)."""
    num_blocks: int
    leaf_block_ids: Tuple[int, ...]      # aligned with tree_leaves order
    treedef: Any

    def block_id_tree(self):
        return jax.tree.unflatten(self.treedef, list(self.leaf_block_ids))

    def mask_tree(self, selected):
        """selected: (M,) 0/1 array -> pytree of scalar multipliers."""
        ids = list(self.leaf_block_ids)
        leaves = [selected[i] for i in ids]
        return jax.tree.unflatten(self.treedef, leaves)

    def block_sizes(self, tree) -> np.ndarray:
        sizes = np.zeros(self.num_blocks, np.int64)
        for leaf, bid in zip(jax.tree.leaves(tree), self.leaf_block_ids):
            sizes[bid] += int(np.prod(leaf.shape))
        return sizes


def make_tree_blocks(tree, num_blocks: int) -> TreeBlocks:
    leaves, treedef = jax.tree.flatten(tree)
    sizes = [int(np.prod(l.shape)) for l in leaves]
    order = np.argsort(sizes)[::-1]                     # LPT: largest first
    load = np.zeros(num_blocks, np.int64)
    ids = [0] * len(leaves)
    for li in order:
        j = int(np.argmin(load))
        ids[int(li)] = j
        load[j] += sizes[int(li)]
    return TreeBlocks(num_blocks=num_blocks, leaf_block_ids=tuple(ids),
                      treedef=treedef)
