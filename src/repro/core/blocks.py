"""Block partitioning for general form consensus (paper §2.2).

Two user-facing representations, ONE canonical packed layout underneath:

* **Flat mode** (the paper's own workloads — sparse logistic regression):
  the decision variable is a flat vector of dim ``d`` padded and reshaped
  to ``(M, d/M)``; block j is row j. The edge set E is an (N, M) bool
  matrix: worker i touches block j iff its local data has support there.

* **Pytree mode** (transformer consensus training): every parameter leaf
  is assigned to one of M logical blocks, balanced by parameter count
  (greedy LPT, :class:`TreeBlocks`). Since the packed-layout refactor
  the pytree is *lowered* onto the same ``(M, dblk)`` block table flat
  mode uses: :class:`BlockLayout` packs each block's leaves into one
  padded row (bitwise round-trip, zero padding), so the kernels, the
  SPMD block servers and the PS lock domains all see a single
  representation — the scatter/partition structure, not the user-facing
  parameter shape (Hong et al. 1412.6058; Chang et al. 1509.02597).

**Block-id contract**: block j of a :class:`BlockLayout` is row j of the
packed table, in ``TreeBlocks.leaf_block_ids`` order. Every layer keys
off these ids — selection masks and edge sets index columns j, the SPMD
``model`` axis shards rows j, and the PS runtime's lock domains group
ids j (``repro.ps.server.DISCIPLINES``) — so a block id means the same
server in every execution mode.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

#: TPU vector-lane width. Packed block rows are rounded up to a multiple of
#: this at layout-build time so every kernel takes the no-pad (8, 128) vreg
#: fast path — alignment is a property of the layout, not a per-call pad.
LANE = 128


def round_up_to_lane(n: int, lane: int = LANE) -> int:
    """Smallest multiple of ``lane`` >= max(n, 1)."""
    return -(-max(int(n), 1) // lane) * lane


# --------------------------------------------------------------------------
# flat mode
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FlatBlocks:
    """Flat-vector block partition onto the lane-aligned ``(M, dblk)`` table.

    The coordinate partition is governed by ``used_dim`` (block j owns
    coordinates ``[j*used_dim, (j+1)*used_dim)`` of the original vector);
    ``block_dim`` is ``used_dim`` rounded up to the 128-lane boundary, so
    rows carry ``block_dim - used_dim`` trailing pad lanes (plus the usual
    tail-of-vector pad inside the last block's used region). Pad lanes are
    zero on pack, never read on unpack, and structurally inert through
    every epoch op (see :class:`BlockLayout`).
    """
    dim: int          # original vector dim
    num_blocks: int   # M
    block_dim: int    # lane-aligned per-block row width (dblk)
    used_dim: int = 0 # coordinates per block before lane padding (0 -> block_dim)

    def __post_init__(self):
        if self.used_dim == 0:
            object.__setattr__(self, "used_dim", self.block_dim)
        if not 0 < self.used_dim <= self.block_dim:
            raise ValueError(
                f"used_dim={self.used_dim} must be in (0, block_dim="
                f"{self.block_dim}]")

    @property
    def padded_dim(self) -> int:
        """Table capacity M * dblk (includes lane padding)."""
        return self.num_blocks * self.block_dim

    @property
    def logical_dim(self) -> int:
        """Coordinate capacity M * used_dim (before lane padding)."""
        return self.num_blocks * self.used_dim

    def padding_mask(self) -> np.ndarray:
        """(M, dblk) bool — True on real coordinates, False on padding."""
        mask = np.zeros((self.num_blocks, self.block_dim), bool)
        for j in range(self.num_blocks):
            used = min(self.used_dim, max(0, self.dim - j * self.used_dim))
            mask[j, :used] = True
        return mask

    def to_blocks(self, v):
        """(..., d) -> (..., M, block_dim)."""
        lead = [(0, 0)] * (v.ndim - 1)
        vp = jnp.pad(v, lead + [(0, self.logical_dim - self.dim)])
        rows = vp.reshape(v.shape[:-1] + (self.num_blocks, self.used_dim))
        if self.used_dim == self.block_dim:
            return rows
        return jnp.pad(rows, lead + [(0, 0),
                                     (0, self.block_dim - self.used_dim)])

    def from_blocks(self, b):
        """(..., M, block_dim) -> (..., d). Pad lanes are never read."""
        rows = b[..., : self.used_dim]
        flat = rows.reshape(b.shape[:-2] + (self.logical_dim,))
        return flat[..., : self.dim]


def make_flat_blocks(dim: int, num_blocks: int) -> FlatBlocks:
    used_dim = -(-dim // num_blocks)
    return FlatBlocks(dim=dim, num_blocks=num_blocks,
                      block_dim=round_up_to_lane(used_dim), used_dim=used_dim)


def edge_set_from_support(support: np.ndarray, blocks: FlatBlocks) -> np.ndarray:
    """support: (N, d) bool — which coordinates each worker's data touches.
    Returns E: (N, M) bool (worker i, block j) — the paper's edge set.
    Lane padding carries no support, so it is computed over ``used_dim``."""
    N, d = support.shape
    pad = blocks.logical_dim - d
    sp = np.pad(support, [(0, 0), (0, pad)])
    return sp.reshape(N, blocks.num_blocks, blocks.used_dim).any(axis=-1)


# --------------------------------------------------------------------------
# pytree mode
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TreeBlocks:
    """Per-leaf block ids for a params pytree (greedy size-balanced)."""
    num_blocks: int
    leaf_block_ids: Tuple[int, ...]      # aligned with tree_leaves order
    treedef: Any

    def block_id_tree(self):
        return jax.tree.unflatten(self.treedef, list(self.leaf_block_ids))

    def mask_tree(self, selected):
        """selected: (M,) 0/1 array -> pytree of scalar multipliers."""
        ids = list(self.leaf_block_ids)
        leaves = [selected[i] for i in ids]
        return jax.tree.unflatten(self.treedef, leaves)

    def block_sizes(self, tree) -> np.ndarray:
        sizes = np.zeros(self.num_blocks, np.int64)
        for leaf, bid in zip(jax.tree.leaves(tree), self.leaf_block_ids):
            sizes[bid] += int(np.prod(leaf.shape))
        return sizes


def make_tree_blocks(tree, num_blocks: int) -> TreeBlocks:
    leaves, treedef = jax.tree.flatten(tree)
    sizes = [int(np.prod(l.shape)) for l in leaves]
    order = np.argsort(sizes)[::-1]                     # LPT: largest first
    load = np.zeros(num_blocks, np.int64)
    ids = [0] * len(leaves)
    for li in order:
        j = int(np.argmin(load))
        ids[int(li)] = j
        load[j] += sizes[int(li)]
    return TreeBlocks(num_blocks=num_blocks, leaf_block_ids=tuple(ids),
                      treedef=treedef)


# --------------------------------------------------------------------------
# the canonical packed block layout (pytree -> (M, dblk) block table)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockLayout:
    """Packed block layout: a params pytree lowered onto the flat-mode
    ``(M, dblk)`` block table.

    Built ONCE per (tree structure, block assignment) by
    :func:`make_block_layout`. Block j's leaves are raveled and
    concatenated (in leaf order) into row j; rows are zero-padded to
    ``block_dim`` = the largest packed block rounded up to the 128-lane
    boundary (:data:`LANE`), so kernels always see aligned rows.
    ``to_blocks``/
    ``from_blocks`` mirror :class:`FlatBlocks` — leading batch axes
    (worker N, ring depth D+1) pass through — and round-trip bitwise:
    arithmetic happens in ``dtype`` (float32), every leaf dtype that
    embeds losslessly in it (f32/bf16/f16) casts there and back exactly.

    The padding lanes of a row are *structurally inert*: every epoch op
    is lane-local (elementwise updates, worker-axis reductions,
    separable prox), so pad lanes never mix into real coordinates, and
    ``from_blocks`` never reads them. Gradients are packed with
    explicit zero padding, so lane-reductions (``grad_sqnorm``) are
    exact too — pinned by tests/test_block_layout.py.

    Block ids are the stable contract shared by every layer (see module
    docstring): the SPMD ``model`` axis shards rows j and the PS
    runtime's lock domains group ids j.
    """
    tree: TreeBlocks
    leaf_shapes: Tuple[Tuple[int, ...], ...]
    leaf_dtypes: Tuple[str, ...]          # dtype names (hashable/comparable)
    leaf_offsets: Tuple[int, ...]         # per leaf: offset within its row
    block_leaves: Tuple[Tuple[int, ...], ...]  # per block: leaf idx, pack order
    block_sizes: Tuple[int, ...]          # per block: packed (pad-free) size
    block_dim: int                        # dblk (max packed block size)
    dtype: str = "float32"                # packed compute dtype

    @property
    def num_blocks(self) -> int:
        return self.tree.num_blocks

    @property
    def block_ids(self) -> Tuple[int, ...]:
        """Per-leaf block assignment — THE block-id contract."""
        return self.tree.leaf_block_ids

    def padding_mask(self) -> np.ndarray:
        """(M, dblk) bool — True on real coordinates, False on padding."""
        mask = np.zeros((self.num_blocks, self.block_dim), bool)
        for j, used in enumerate(self.block_sizes):
            mask[j, :used] = True
        return mask

    def _lead(self, leaves) -> Tuple[int, ...]:
        lead = leaves[0].ndim - len(self.leaf_shapes[0])
        batch = tuple(leaves[0].shape[:lead])
        for k, leaf in enumerate(leaves):
            if tuple(leaf.shape) != batch + self.leaf_shapes[k]:
                raise ValueError(
                    f"leaf {k} has shape {leaf.shape}; expected batch "
                    f"{batch} + {self.leaf_shapes[k]} (layout built for a "
                    f"different tree?)")
        return batch

    def to_blocks(self, tree_val):
        """Pack a pytree (leaves ``batch + leaf_shape``) into the block
        table ``batch + (M, dblk)`` in the packed compute dtype."""
        leaves, treedef = jax.tree.flatten(tree_val)
        if treedef != self.tree.treedef:
            raise ValueError(f"tree structure {treedef} does not match the "
                             f"layout's {self.tree.treedef}")
        batch = self._lead(leaves)
        dt = jnp.dtype(self.dtype)
        rows = []
        for j, kidx in enumerate(self.block_leaves):
            parts = [leaves[k].astype(dt).reshape(batch + (-1,))
                     for k in kidx]
            used = self.block_sizes[j]
            if used < self.block_dim:
                parts.append(jnp.zeros(batch + (self.block_dim - used,), dt))
            rows.append(parts[0] if len(parts) == 1
                        else jnp.concatenate(parts, axis=-1))
        return jnp.stack(rows, axis=-2)

    def leaf_starts(self) -> Tuple[int, ...]:
        """Per leaf: start offset within the row-major flattened table."""
        return tuple(self.block_ids[k] * self.block_dim + self.leaf_offsets[k]
                     for k in range(len(self.leaf_shapes)))

    def from_blocks(self, arr):
        """Unpack a block table ``batch + (M, dblk)`` back to the pytree
        (leaves cast back to their stored dtypes; padding dropped).

        Flattens the table once and takes one contiguous slice per leaf
        at a static offset — each leaf reads only its own window, so the
        unpack's HBM traffic is proportional to the model, not to
        num_leaves x the whole table.
        """
        batch = tuple(arr.shape[:-2])
        flat = arr.reshape(batch + (self.num_blocks * self.block_dim,))
        leaves = []
        for k, (shape, dt) in enumerate(zip(self.leaf_shapes,
                                            self.leaf_dtypes)):
            size = int(np.prod(shape, dtype=np.int64))
            start = self.leaf_starts()[k]
            piece = jax.lax.slice_in_dim(flat, start, start + size, axis=-1)
            leaves.append(piece.reshape(batch + shape).astype(dt))
        return jax.tree.unflatten(self.tree.treedef, leaves)


def make_block_layout(tree, blocks: TreeBlocks = None, *,
                      num_blocks: int = None, dtype="float32") -> BlockLayout:
    """Build the packed layout for ``tree`` (arrays or ShapeDtypeStructs;
    only shapes/dtypes are read). ``blocks`` defaults to the LPT
    assignment of :func:`make_tree_blocks` over ``num_blocks``."""
    leaves, treedef = jax.tree.flatten(tree)
    if not leaves:
        raise ValueError("cannot build a BlockLayout for an empty pytree")
    if blocks is None:
        blocks = make_tree_blocks(tree, num_blocks)
    if treedef != blocks.treedef:
        raise ValueError(f"tree structure {treedef} does not match the "
                         f"TreeBlocks' {blocks.treedef}")
    sizes = [int(np.prod(l.shape, dtype=np.int64)) for l in leaves]
    block_leaves = tuple(
        tuple(k for k, b in enumerate(blocks.leaf_block_ids) if b == j)
        for j in range(blocks.num_blocks))
    offsets = [0] * len(leaves)
    block_sizes = []
    for kidx in block_leaves:
        off = 0
        for k in kidx:
            offsets[k] = off
            off += sizes[k]
        block_sizes.append(off)
    return BlockLayout(
        tree=blocks,
        leaf_shapes=tuple(tuple(l.shape) for l in leaves),
        leaf_dtypes=tuple(np.dtype(l.dtype).name for l in leaves),
        leaf_offsets=tuple(offsets),
        block_leaves=block_leaves,
        block_sizes=tuple(block_sizes),
        block_dim=round_up_to_lane(max(1, max(block_sizes))),
        dtype=np.dtype(dtype).name)
