"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — smoke tests must keep seeing the
single real CPU device; only dryrun.py forces 512 host devices.

Production target: TPU v5e, 256 chips/pod (16x16), optionally 2 pods.
  single pod : (data=16, model=16)            axes ("data", "model")
  multi pod  : (pod=2, data=16, model=16)     axes ("pod", "data", "model")
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices (set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count=512 before importing jax); have {len(devs)}")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def make_test_mesh(devices: int = 8):
    """Small host-device mesh for CPU integration tests (requires the
    test to have set xla_force_host_platform_device_count)."""
    model = 2
    data = devices // model
    return jax.make_mesh((data, model), ("data", "model"))


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def num_workers(mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n


def model_axis_size(mesh) -> int:
    return mesh.shape.get("model", 1)
