"""Production mesh construction + mesh-shape helpers.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — smoke tests must keep seeing the
single real CPU device; only dryrun.py forces 512 host devices.

Axis convention (consumed by the SPMD epoch in ``core/sharded.py``):
``data`` (+ optional outer ``pod``) shards the *worker* axis of the
consensus state — each worker's duals/w-cache live with its data shard —
and ``model`` shards the *block-server* axis (FlatSpace blocks; the
dryrun's tensor-parallel param dims in pytree mode).

Production target: TPU v5e, 256 chips/pod (16x16), optionally 2 pods.
  single pod : (data=16, model=16)            axes ("data", "model")
  multi pod  : (pod=2, data=16, model=16)     axes ("pod", "data", "model")
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    import numpy as np
    n = int(np.prod(shape))
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"need {n} devices (set XLA_FLAGS=--xla_force_host_platform_"
            f"device_count=512 before importing jax); have {len(devs)}")
    return jax.make_mesh(shape, axes, devices=devs[:n])


def make_test_mesh(devices: int = 8, model: int = 2):
    """Small (data, model) host-device mesh for CPU integration tests.

    Requires the test process to have forced enough host devices
    (``XLA_FLAGS=--xla_force_host_platform_device_count=<devices>``
    before jax is first imported) and ``devices`` to split evenly into
    ``model`` columns — both are validated eagerly so a bad count fails
    with an actionable message instead of an opaque reshape error."""
    if model <= 0 or devices <= 0:
        raise ValueError(f"devices={devices} and model={model} must be >= 1")
    if devices % model != 0:
        raise ValueError(
            f"make_test_mesh: devices={devices} does not divide into "
            f"model={model} columns (devices % model == {devices % model}); "
            f"pick devices as a multiple of the model axis")
    have = len(jax.devices())
    if have < devices:
        raise RuntimeError(
            f"make_test_mesh: need {devices} devices but jax sees {have}; "
            f"set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{devices} before importing jax")
    return jax.make_mesh((devices // model, model), ("data", "model"))


MESH_PRESETS = ("none", "test", "pod", "multipod")


def resolve_mesh(mesh):
    """Resolve an ``ADMMConfig.mesh`` / CLI value to a Mesh or None.

    Accepts None / "none" (single-device epoch), an already-built mesh
    (anything with ``axis_names`` — ``jax.sharding.Mesh`` or an
    ``AbstractMesh`` for shape-only analysis), or a preset name:
    ``test`` (8 host devices, data=4 x model=2), ``pod``, ``multipod``.
    """
    if mesh is None or mesh == "none":
        return None
    if hasattr(mesh, "axis_names"):
        return mesh
    if mesh == "test":
        return make_test_mesh()
    if mesh == "pod":
        return make_production_mesh()
    if mesh == "multipod":
        return make_production_mesh(multi_pod=True)
    raise ValueError(f"unknown mesh {mesh!r}; expected None, a jax Mesh, "
                     f"or one of {MESH_PRESETS}")


def data_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def num_workers(mesh) -> int:
    n = 1
    for a in data_axes(mesh):
        n *= mesh.shape[a]
    return n


def model_axis_size(mesh) -> int:
    return mesh.shape.get("model", 1)
