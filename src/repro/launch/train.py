"""End-to-end training driver.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
      --steps 200 --trainer admm [--workers 4] [--ckpt out/ckpt]

Uses the smoke (reduced) config by default on CPU; pass --full plus a
mesh flag on a real pod. Supports both trainers so the paper's ADMM can
be compared to the synchronous SGD/Adam baseline on the same stream.
"""
import argparse
import contextlib
import json
import time

import jax
import jax.numpy as jnp

from ..api import ConsensusSession
from ..checkpoint import save
from ..configs import get_config, get_smoke, list_archs
from ..configs.base import ADMMConfig
from ..core.space import (DELAY_MODELS, ConstantDelay, ParetoDelay,
                          TraceDelay)
from ..data import TokenPipeline
from ..models import build_model
from ..optim import adamw, warmup_cosine
from ..training import SGDTrainer
from .mesh import MESH_PRESETS


def run_ps_training(session, args, pipe, enc_kw) -> None:
    """--runtime ps: drive the event-driven Parameter Server runtime
    (repro.ps) instead of the vectorized epoch — real jitted numerics
    under lock-free (or locked) block servers, bounded staleness
    enforced by stalling, optional network latency on every
    worker<->server message (an unreliable lossy transport with
    ack/retry when --drop-rate/--dup-rate/--reorder-rate are set), and
    a replayable DelayTrace out."""
    timing = None
    lossy = (args.drop_rate > 0.0 or args.dup_rate > 0.0
             or args.reorder_rate > 0.0)
    if lossy:
        from ..ps import CostProfile, Transport
        timing = CostProfile(net=Transport(
            args.net_latency, args.net_jitter,
            drop_rate=args.drop_rate, dup_rate=args.dup_rate,
            reorder_rate=args.reorder_rate, ack_timeout=args.ack_timeout))
    elif args.net_latency > 0.0 or args.net_jitter > 0.0:
        from ..ps import CostProfile, NetworkModel
        timing = CostProfile(net=NetworkModel(args.net_latency,
                                              args.net_jitter))
    telemetry = None
    if args.telemetry or args.telemetry_path:
        from ..obs import Telemetry
        if args.telemetry_path:
            sink = f"{args.telemetry_path}.jsonl"
            trace_path = f"{args.telemetry_path}.trace.json"
        else:
            sink, trace_path = "stdout", None
        telemetry = Telemetry(spans=True, sink=sink,
                              trace_path=trace_path,
                              metrics_every=max(args.metrics_every, 1))
    prof = jax.profiler.trace(args.profile_dir) if args.profile_dir \
        else contextlib.nullcontext()
    t0 = time.time()
    with prof:
        result = session.run_ps(
            args.steps, discipline=args.discipline, record_z=False,
            timing=timing, faults=args.faults,
            checkpoint_every=args.checkpoint_every,
            checkpoint_dir=args.checkpoint_dir,
            resume_from=args.resume,
            telemetry=telemetry,
            batches=lambda t: pipe.batch(t, num_workers=args.workers,
                                         **enc_kw))
    # the machine-readable stream carries FULL float precision — a
    # convergence analysis downstream must not eat a 4-decimal
    # truncation; rounding is for the human summary line only
    for step in range(0, args.steps, max(args.log_every, 1)):
        print(json.dumps({"round": step, "loss": result.losses[step]}),
              flush=True)
    m = result.metrics
    print(json.dumps({
        "runtime": "ps", "discipline": args.discipline,
        "rounds": args.steps, "makespan": round(result.makespan, 3),
        "final_loss": round(result.losses[-1], 4),
        "stall_count": m["stall_count"],
        "stall_time": round(m["stall_time"], 3),
        "max_served_tau": m["max_served_tau"],
        "commits": m["commits"], "pushes": m["pushes"],
        "crashes": m.get("crashes", 0), "rejoins": m.get("rejoins", 0),
        "server_recoveries": m.get("server_recoveries", 0),
        "snapshots": len(m.get("snapshots", [])),
        "elapsed_s": round(time.time() - t0, 1)}), flush=True)
    if args.telemetry_path:
        print(f"telemetry: round records in {args.telemetry_path}.jsonl, "
              f"Perfetto trace in {args.telemetry_path}.trace.json "
              f"(load at https://ui.perfetto.dev)")
    if args.profile_dir:
        print(f"XLA profile in {args.profile_dir} "
              f"(view: tensorboard --logdir {args.profile_dir})")
    if m.get("snapshots"):
        print(f"crash-consistent snapshots in {args.checkpoint_dir} "
              f"(resume: --runtime ps --resume {m['snapshots'][-1]})")
    if args.save_trace:
        path = result.trace.save(args.save_trace)
        print(f"delay trace saved to {path} "
              f"(replay: --delay-model trace --trace-path {path})")
    if args.ckpt:
        save(args.ckpt, result.z_final, step=args.steps)
        print(f"checkpoint saved to {args.ckpt}.npz")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list_archs())
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--trainer", default="admm", choices=["admm", "sgd"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--rho", type=float, default=20.0)
    ap.add_argument("--gamma", type=float, default=0.01)
    ap.add_argument("--max-delay", type=int, default=1)
    ap.add_argument("--block-fraction", type=float, default=1.0)
    ap.add_argument("--num-blocks", type=int, default=8)
    ap.add_argument("--block-selection", default="random",
                    choices=["random", "cyclic", "gauss_southwell", "zipf"])
    ap.add_argument("--zipf-a", type=float, default=1.1,
                    help="skew exponent for --block-selection zipf "
                         "(block j sampled with weight (j+1)^-a; higher "
                         "= hotter head blocks)")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "jnp", "pallas"],
                    help="epoch hot-path backend: fused Pallas kernels "
                         "(native on TPU, interpret mode elsewhere) or "
                         "the pure-jnp composition")
    ap.add_argument("--mesh", default="none",
                    choices=list(MESH_PRESETS),
                    help="SPMD mesh for the sharded epoch: none (single "
                         "device), test (8 host devices, data=4 x "
                         "model=2), pod (data=16 x model=16), multipod; "
                         "workers shard over the data axes")
    ap.add_argument("--autotune", default="off",
                    choices=["off", "cached", "sweep"],
                    help="kernel tile autotuning (kernels/autotune.py): "
                         "off = static heuristics; cached = winners from "
                         "benchmarks/kernels_tuned.json; sweep = measure "
                         "this run's shapes up front, persist, then run "
                         "cached")
    ap.add_argument("--delay-model", default="uniform",
                    choices=sorted(DELAY_MODELS),
                    help="Assumption-3 staleness: uniform U{0..D}, "
                         "constant worst-case lag D, pareto heavy-tailed "
                         "stragglers clipped at D, or trace (replay a "
                         "recorded PS-runtime trace; needs --trace-path)")
    ap.add_argument("--pareto-alpha", type=float, default=1.2,
                    help="tail exponent for --delay-model pareto "
                         "(smaller = heavier straggler tail)")
    ap.add_argument("--trace-path", default=None,
                    help="DelayTrace .npz for --delay-model trace "
                         "(recorded by --runtime ps --save-trace or "
                         "ConsensusSession.run_ps)")
    ap.add_argument("--minibatch", type=float, default=None,
                    help="incremental workers (Hong 2014): fraction of "
                         "each worker's samples drawn fresh per step")
    ap.add_argument("--runtime", default="epoch", choices=["epoch", "ps"],
                    help="epoch: the vectorized asybadmm_epoch (fast "
                         "path); ps: the event-driven Parameter Server "
                         "runtime (repro.ps) — lock-free block servers, "
                         "stall-enforced bounded staleness, delay-trace "
                         "recording")
    ap.add_argument("--discipline", default="lockfree",
                    choices=["lockfree", "locked", "per_push"],
                    help="--runtime ps coordination: per-block lock-free "
                         "servers (the paper), one locked full-vector "
                         "server (the prior-work baseline), or per-block "
                         "servers paying commit work eagerly per push")
    ap.add_argument("--faults", default=None,
                    help="--runtime ps: FaultPlan JSON injecting worker "
                         "crash/rejoin, join/leave churn, slowdowns, "
                         "server commit spikes, link loss, and block-"
                         "server crashes (server_crash; recovered by "
                         "WAL replay — see API.md's elastic-PS and "
                         "durability sections for the schema)")
    ap.add_argument("--save-trace", default=None,
                    help="path to save the --runtime ps DelayTrace "
                         "(.npz) for later --delay-model trace replay")
    ap.add_argument("--net-latency", type=float, default=0.0,
                    help="--runtime ps: constant network latency (sim "
                         "seconds) charged on every worker<->server "
                         "message (pull responses, declarations/pushes)")
    ap.add_argument("--net-jitter", type=float, default=0.0,
                    help="--runtime ps: +/- uniform jitter around "
                         "--net-latency per message")
    ap.add_argument("--drop-rate", type=float, default=0.0,
                    help="--runtime ps: probability a worker<->server "
                         "message is lost (engages the ack/retry "
                         "transport layer; see API.md transport section)")
    ap.add_argument("--dup-rate", type=float, default=0.0,
                    help="--runtime ps: probability a delivered message "
                         "arrives twice (commit-gate dedup folds it once)")
    ap.add_argument("--reorder-rate", type=float, default=0.0,
                    help="--runtime ps: probability a delivered message "
                         "is held back an extra random delay (reordered "
                         "past later traffic on the same link)")
    ap.add_argument("--ack-timeout", type=float, default=1.0,
                    help="--runtime ps: sim seconds before an unacked "
                         "message retransmits (capped exponential "
                         "backoff on retries)")
    ap.add_argument("--checkpoint-every", type=int, default=None,
                    help="--runtime ps: write a crash-consistent "
                         "snapshot of the full runtime every K rounds "
                         "(quiescent barrier; needs --checkpoint-dir). "
                         "A killed run resumes mid-stream with --resume, "
                         "deterministically (see API.md durability "
                         "section)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="directory for --checkpoint-every snapshots "
                         "(snap-NNNNNN.npz/.json pairs, written "
                         "atomically)")
    ap.add_argument("--resume", default=None,
                    help="--runtime ps: resume from a snapshot file (or "
                         "a directory, taking the latest snapshot) "
                         "written by --checkpoint-every; the run "
                         "continues mid-stream and its tail is "
                         "identical to the uninterrupted run's")
    ap.add_argument("--telemetry", action="store_true",
                    help="--runtime ps: turn on deterministic telemetry "
                         "(repro.obs) — virtual-time span tracing plus "
                         "a per-round record stream (loss, per-block "
                         "stationarity residuals, queue depths, stall/"
                         "transport totals) to stdout. Never perturbs "
                         "the schedule: results are bitwise identical "
                         "with or without it")
    ap.add_argument("--telemetry-path", default=None,
                    help="--runtime ps: stream the per-round records to "
                         "PREFIX.jsonl and save the Chrome trace to "
                         "PREFIX.trace.json (loadable in Perfetto) "
                         "instead of stdout; implies --telemetry")
    ap.add_argument("--metrics-every", type=int, default=1,
                    help="--runtime ps --telemetry: emit every K-th "
                         "round's record (the final round always "
                         "emits)")
    ap.add_argument("--profile-dir", default=None,
                    help="--runtime ps: wrap the run in "
                         "jax.profiler.trace(DIR) — a wall-clock XLA "
                         "profile of the jitted numerics (view with "
                         "tensorboard), orthogonal to the sim-time "
                         "telemetry spans")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.runtime == "ps" and args.trainer != "admm":
        raise SystemExit("--runtime ps is the AsyBADMM Parameter Server "
                         "runtime; use --trainer admm")

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M trainer={args.trainer}")

    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=args.seq + 1,
                         global_batch=args.batch, seed=args.seed)
    enc_kw = {}
    if cfg.is_enc_dec:
        enc_kw = dict(enc_frames_dim=cfg.d_model,
                      enc_seq_len=cfg.encoder_seq_len)

    if args.trainer == "admm":
        acfg = ADMMConfig(rho=args.rho, gamma=args.gamma,
                          max_delay=args.max_delay,
                          block_fraction=args.block_fraction,
                          num_blocks=args.num_blocks,
                          block_selection=args.block_selection,
                          zipf_a=args.zipf_a,
                          backend=args.backend,
                          mesh=args.mesh,
                          minibatch=args.minibatch,
                          autotune=args.autotune,
                          seed=args.seed)
        delay_model = None                       # uniform == config default
        if args.delay_model == "constant":
            delay_model = ConstantDelay(args.max_delay)
        elif args.delay_model == "pareto":
            delay_model = ParetoDelay(args.max_delay, alpha=args.pareto_alpha)
        elif args.delay_model == "trace":
            if args.trace_path is None:
                raise SystemExit("--delay-model trace needs --trace-path")
            delay_model = TraceDelay.load(args.trace_path)
        session = ConsensusSession.pytree(model.loss, params, acfg,
                                          num_workers=args.workers,
                                          delay_model=delay_model)
        if args.runtime == "ps":
            run_ps_training(session, args, pipe, enc_kw)
            return
        state = session.init()
        step_fn = session.step_fn()
        get_params = session.z
        batch_kw = dict(num_workers=args.workers, **enc_kw)
    else:
        sched = warmup_cosine(args.lr, args.steps // 10, args.steps)
        trainer = SGDTrainer(loss_fn=model.loss, optimizer=adamw(sched))
        state = trainer.init(params)
        step_fn = jax.jit(trainer.train_step)
        get_params = lambda st: st.params
        batch_kw = dict(**enc_kw)
    t0 = time.time()
    for step in range(args.steps):
        batch = pipe.batch(step, **batch_kw)
        state, info = step_fn(state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            # machine stream: full float precision (see run_ps_training)
            print(json.dumps({"step": step, "loss": float(info["loss"]),
                              "elapsed_s": round(time.time() - t0, 1)}),
                  flush=True)

    if args.ckpt:
        save(args.ckpt, get_params(state), step=args.steps)
        print(f"checkpoint saved to {args.ckpt}.npz")


if __name__ == "__main__":
    main()
