"""Serving driver: batched generation with the Engine.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-370m \
      --requests 4 --prompt-len 16 --max-new 32
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_smoke, list_archs
from ..models import build_model
from ..serving import Engine


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=list_archs())
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = Engine(model, params,
                    max_len=args.prompt_len + args.max_new + 8)

    rng = np.random.RandomState(args.seed)
    prompts = rng.randint(0, cfg.vocab_size,
                          size=(args.requests, args.prompt_len))
    enc = None
    if cfg.is_enc_dec:
        enc = jnp.asarray(
            rng.randn(args.requests, cfg.encoder_seq_len, cfg.d_model),
            jnp.float32) * 0.1

    t0 = time.time()
    res = engine.generate(prompts, max_new=args.max_new,
                          temperature=args.temperature, enc_frames=enc,
                          seed=args.seed)
    dt = time.time() - t0
    toks = args.requests * args.max_new
    print(f"generated {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s batched)")
    for i in range(min(2, args.requests)):
        print(f"req{i}: {res.tokens[i][:16].tolist()} ...")


if __name__ == "__main__":
    main()
