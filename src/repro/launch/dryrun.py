import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count at first init). 512 host devices back both production
# meshes; single-pod runs slice the first 256.
"""Multi-pod dry-run: lower + compile every (arch x input-shape) step on
the production meshes, record memory/cost/collective analysis.

  PYTHONPATH=src python -m repro.launch.dryrun --mesh pod [--arch A] \
      [--shape S] [--out out.jsonl] [--perf-variant NAME]

Shapes map to programs:
  train_4k              -> ADMM consensus train_step (the paper's technique)
  prefill_32k           -> full-sequence forward (serving prefill)
  decode_32k, long_500k -> one-token decode_step against a full KV cache

long_500k is skipped for pure full-attention archs (DESIGN.md §5).

``--variant sharded_epoch`` lowers train_4k through the SPMD-sharded
``asybadmm_epoch`` itself (core/sharded.py: shard_map over
(data..., model), packed block servers over ``model``) instead of the
GSPMD-partitioned trainer step — production-shape cost estimates for
the runtime path ``ConsensusSession`` actually executes.
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..analysis.hlo import collective_bytes, count_ops
from ..analysis.hlo_cost import analyze_hlo
from ..analysis.roofline import Roofline, model_flops
from ..configs import INPUT_SHAPES, get_config, list_archs
from ..configs.base import ADMMConfig
from ..models import build_model
from ..training.trainer import ADMMTrainer
from . import shardings as sh
from .mesh import data_axes, make_production_mesh, num_workers

DTYPE = "bfloat16"


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, shardings attached — no
# device allocation anywhere)
# ---------------------------------------------------------------------------

def _sds(shape, dtype, mesh, spec):
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _with_sharding(shape_tree, spec_tree, mesh):
    return jax.tree.map(
        lambda s, p: jax.ShapeDtypeStruct(s.shape, s.dtype,
                                          sharding=NamedSharding(mesh, p)),
        shape_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def input_specs(cfg, shape, mesh, *, worker_axis: bool,
                batch_over_model: bool = False):
    """Training/prefill batch specs for one input shape."""
    daxes = data_axes(mesh)
    N = num_workers(mesh)
    B, S = shape.global_batch, shape.seq_len
    if worker_axis:
        assert B % N == 0, (B, N)
        tok_shape = (N, B // N, S)
        ms = mesh.shape.get("model", 1)
        if batch_over_model and (B // N) % ms == 0:
            spec = P(daxes, "model", None)
        else:
            spec = P(daxes, None, None)
    else:
        tok_shape = (B, S)
        spec = P(daxes, None) if B % N == 0 else P(None, None)
    batch = {
        "tokens": _sds(tok_shape, jnp.int32, mesh, spec),
        "labels": _sds(tok_shape, jnp.int32, mesh, spec),
    }
    if cfg.is_enc_dec:
        # stubbed modality frontend: precomputed frame embeddings
        fr_shape = tok_shape[:-1] + (cfg.encoder_seq_len, cfg.d_model)
        fr_spec = P(*((daxes,) + (None,) * (len(fr_shape) - 1)))
        batch["enc_frames"] = _sds(fr_shape, jnp.dtype(DTYPE), mesh, fr_spec)
    return batch


def admm_config(mesh) -> ADMMConfig:
    """Paper-faithful baseline: block-wise consensus with bounded delay 1,
    full block sweep per round (see EXPERIMENTS.md §Perf for the
    block-selection variants)."""
    return ADMMConfig(rho=100.0, gamma=0.01, max_delay=1,
                      block_fraction=1.0, num_blocks=mesh.shape["model"])


# ---------------------------------------------------------------------------
# program builders — each returns (fn, example_args) ready to lower
# ---------------------------------------------------------------------------

def _apply_cfg_variants(cfg, tokens):
    if "chunked_attn" in tokens:
        cfg = cfg.with_(attn_impl="chunked", attn_chunk=1024)
    if "qchunk_attn" in tokens:
        cfg = cfg.with_(attn_impl="qchunk", attn_chunk=2048)
    if "moe_scatter" in tokens:
        cfg = cfg.with_(moe_impl="scatter")
    if "no_remat" in tokens:
        cfg = cfg.with_(remat=False)
    for t in tokens:
        if t.startswith("ssm_chunk_") and cfg.ssm is not None:
            cfg = cfg.with_(ssm=dataclasses.replace(
                cfg.ssm, chunk_size=int(t.rsplit("_", 1)[1])))
    return cfg


def build_train(cfg, shape, mesh, variant: str = "baseline"):
    tokens = set(variant.split("+"))
    cfg = _apply_cfg_variants(cfg.with_(dtype=DTYPE, param_dtype=DTYPE,
                                        remat=True), tokens)
    from ..models import set_activation_sharding
    if "act_replicated" in tokens:
        # pin the residual stream replicated over the model axis: the
        # column/row-parallel einsums then need no activation all-gather
        # (only the row-parallel partial-sum all-reduce remains)
        def _constrain(x):
            if x.ndim >= 2:
                return jax.lax.with_sharding_constraint(
                    x, P(*([None] * x.ndim)))
            return x
        set_activation_sharding(_constrain)
    else:
        set_activation_sharding(None)
    model = build_model(cfg)
    N = num_workers(mesh)
    acfg = admm_config(mesh)
    if "sync" in tokens or "cyclic" in tokens:
        acfg = dataclasses.replace(acfg, max_delay=0)
    trainer = ADMMTrainer(loss_fn=model.loss, admm=acfg, num_workers=N)
    cyclic = "cyclic" in tokens
    mode = "fsdp" if "fsdp" in tokens else "tp"

    params_shape = model.param_specs()
    state_shape = jax.eval_shape(lambda p: trainer.init(p, cyclic=cyclic),
                                 params_shape)
    state_spec = sh.admm_state_specs(state_shape, mesh, mode=mode,
                                     expert_parallel="expert_parallel" in tokens)
    state_in = _with_sharding(state_shape, state_spec, mesh)
    batch_in = input_specs(cfg, shape, mesh, worker_axis=True,
                           batch_over_model="batch_over_model" in tokens)

    out_sh = (jax.tree.map(lambda s: NamedSharding(mesh, s), state_spec,
                           is_leaf=lambda x: isinstance(x, P)), None)
    if cyclic:
        # Gauss-Seidel round for block 0 — representative of every round
        fn = jax.jit(lambda st, b: trainer.train_step_block(st, b, 0),
                     out_shardings=out_sh, donate_argnums=(0,))
    else:
        fn = jax.jit(trainer.train_step, out_shardings=out_sh,
                     donate_argnums=(0,))
    return fn, (state_in, batch_in)


def build_train_epoch(cfg, shape, mesh, variant: str = "baseline"):
    """``sharded_epoch`` variant: lower the SPMD-sharded
    ``asybadmm_epoch`` (the path ``ConsensusSession.pytree(mesh=...)``
    runs) at production shape — worker state over the data axes, the
    packed (M, dblk) block table over ``model`` (TreeSpace lowered via
    ``core.blocks.BlockLayout``), the w push one psum into the block
    server's shard."""
    from ..core import sharded
    from ..core.blocks import make_block_layout, make_tree_blocks
    from ..core.space import (TreeSpace, asybadmm_epoch,
                              init_consensus_state, make_spec)

    tokens = set(variant.split("+"))
    cfg = _apply_cfg_variants(cfg.with_(dtype=DTYPE, param_dtype=DTYPE,
                                        remat=True), tokens)
    model = build_model(cfg)
    N = num_workers(mesh)
    acfg = admm_config(mesh)
    params_shape = model.param_specs()
    blocks = make_tree_blocks(params_shape, acfg.num_blocks)
    space = TreeSpace(blocks=blocks, num_workers=N,
                      layout=make_block_layout(params_shape, blocks))
    spec = make_spec(space, acfg, model.loss, mesh=mesh)

    # shapes via a mesh-detached twin (no device_put during eval_shape),
    # then the canonical packed-state shardings attached for lowering
    spec_local = dataclasses.replace(
        spec, space=dataclasses.replace(spec.space, mesh=None))
    state_shape = jax.eval_shape(
        lambda p: init_consensus_state(spec_local, p), params_shape)
    sspecs = sharded.consensus_state_specs(spec, state_shape)
    state_in = _with_sharding(state_shape, sspecs, mesh)
    batch_in = input_specs(cfg, shape, mesh, worker_axis=True)

    out_sh = (jax.tree.map(lambda s: NamedSharding(mesh, s), sspecs,
                           is_leaf=lambda x: isinstance(x, P)), None)
    fn = jax.jit(lambda st, b: asybadmm_epoch(spec, st, b),
                 out_shardings=out_sh, donate_argnums=(0,))
    return fn, (state_in, batch_in)


def build_prefill(cfg, shape, mesh, variant: str = "baseline"):
    tokens = set(variant.split("+"))
    cfg = _apply_cfg_variants(cfg.with_(dtype=DTYPE, param_dtype=DTYPE),
                              tokens)
    model = build_model(cfg)
    params_shape = model.param_specs()
    pspec = sh.param_specs(params_shape, mesh,
                           mode="fsdp" if "fsdp" in tokens else "tp",
                           expert_parallel="expert_parallel" in tokens)
    params_in = _with_sharding(params_shape, pspec, mesh)
    batch = input_specs(cfg, shape, mesh, worker_axis=False)

    logits_mode = "last" if "last_logits" in tokens else "all"

    def prefill(params, tokens, enc_frames=None):
        return model.prefill(params, tokens, enc_frames=enc_frames,
                             logits_mode=logits_mode)

    args = (params_in, batch["tokens"])
    if cfg.is_enc_dec:
        fn = jax.jit(lambda p, t, e: model.prefill(p, t, enc_frames=e,
                                                   logits_mode=logits_mode))
        return fn, args + (batch["enc_frames"],)
    return jax.jit(prefill), args


def build_decode(cfg, shape, mesh):
    cfg = cfg.with_(dtype=DTYPE, param_dtype=DTYPE)
    model = build_model(cfg)
    params_shape = model.param_specs()
    pspec = sh.param_specs(params_shape, mesh)
    params_in = _with_sharding(params_shape, pspec, mesh)

    B, S = shape.global_batch, shape.seq_len
    cache_shape = model.cache_specs(B, S)
    cspec = sh.cache_specs_tree(cache_shape, mesh, B)
    cache_in = _with_sharding(cache_shape, cspec, mesh)

    daxes = data_axes(mesh)
    N = num_workers(mesh)
    tok_spec = P(daxes, None) if B % N == 0 else P(None, None)
    token_in = _sds((B, 1), jnp.int32, mesh, tok_spec)
    pos_in = _sds((), jnp.int32, mesh, P())

    fn = jax.jit(
        lambda p, t, c, pos: model.decode_step(p, t, c, pos),
        out_shardings=(None, jax.tree.map(
            lambda s: NamedSharding(mesh, s), cspec,
            is_leaf=lambda x: isinstance(x, P))),
        donate_argnums=(2,))
    return fn, (params_in, token_in, cache_in, pos_in)


def build(arch: str, shape_name: str, mesh, variant: str = "baseline"):
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    if shape.kind == "train":
        if "sharded_epoch" in variant.split("+"):
            return build_train_epoch(cfg, shape, mesh, variant)
        return build_train(cfg, shape, mesh, variant)
    if shape.kind == "prefill":
        return build_prefill(cfg, shape, mesh, variant)
    return build_decode(cfg, shape, mesh)


def skip_reason(arch: str, shape_name: str) -> Optional[str]:
    cfg = get_config(arch)
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        return "full-attention arch: 500k decode requires sub-quadratic attention (DESIGN.md §5)"
    return None


# ---------------------------------------------------------------------------
# analysis of one compiled program
# ---------------------------------------------------------------------------

def analyze(arch: str, shape_name: str, mesh_name: str, mesh, lowered,
            compiled, elapsed: Dict[str, float]) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    chips = int(np.prod(list(mesh.shape.values())))

    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):          # pre-0.4.3x jax returned
        cost = cost[0] if cost else {}           # a one-element list
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))

    mem: Dict[str, float] = {}
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "alias_size_in_bytes",
                      "generated_code_size_in_bytes"):
                v = getattr(ma, k, None)
                if v is not None:
                    mem[k] = float(v)
    except Exception as e:                                    # CPU backend gaps
        mem["error"] = str(e)

    hlo = compiled.as_text()
    ops = count_ops(hlo)
    # trip-count-aware analysis (XLA cost_analysis counts while bodies
    # once — see analysis/hlo_cost.py)
    hc = analyze_hlo(hlo)
    coll = {k: int(v) for k, v in hc.coll.items()}
    coll["total"] = int(sum(hc.coll.values()))

    rl = Roofline(arch=arch, shape=shape_name, mesh=mesh_name,
                  flops_per_device=hc.flops,
                  hbm_bytes_per_device=hc.hbm_bytes,
                  collective_bytes=coll, chips=chips,
                  model_flops_total=model_flops(cfg, shape))
    row = rl.row()
    row.update({
        "collectives": coll, "op_counts": ops, "memory_analysis": mem,
        "collectives_unscaled": collective_bytes(hlo),
        "xla_cost_analysis": {"flops": xla_flops, "bytes": xla_bytes},
        "hlo_bytes": len(hlo),
        "compile_s": elapsed,
        "per_device_state_bytes": mem.get("argument_size_in_bytes", 0),
    })
    return row


def run_one(arch: str, shape_name: str, mesh_name: str,
            variant: str = "baseline") -> Dict[str, Any]:
    mesh = make_production_mesh(multi_pod=(mesh_name == "multipod"))
    reason = skip_reason(arch, shape_name)
    base = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
            "variant": variant}
    if reason:
        return dict(base, status="skipped", reason=reason)
    t0 = time.time()
    fn, args = build(arch, shape_name, mesh, variant)
    with mesh:
        lowered = fn.lower(*args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    row = analyze(arch, shape_name, mesh_name, mesh, lowered, compiled,
                  {"lower": t1 - t0, "compile": t2 - t1})
    row.update(base)
    row["status"] = "ok"
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out", default="experiments/dryrun.jsonl")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else list_archs()
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    n_fail = 0
    with open(args.out, "a") as f:
        for mesh_name in meshes:
            for arch in archs:
                for shape_name in shapes:
                    tag = f"{arch} x {shape_name} x {mesh_name} [{args.variant}]"
                    t0 = time.time()
                    try:
                        row = run_one(arch, shape_name, mesh_name, args.variant)
                    except Exception as e:
                        n_fail += 1
                        row = {"arch": arch, "shape": shape_name,
                               "mesh": mesh_name, "variant": args.variant,
                               "status": "error", "error": repr(e),
                               "traceback": traceback.format_exc()[-3000:]}
                    row["wall_s"] = time.time() - t0
                    f.write(json.dumps(row) + "\n")
                    f.flush()
                    status = row["status"]
                    extra = (f" bottleneck={row.get('bottleneck')}"
                             f" t=({row.get('t_compute_s', 0):.2e},"
                             f"{row.get('t_memory_s', 0):.2e},"
                             f"{row.get('t_collective_s', 0):.2e})s"
                             if status == "ok" else
                             row.get("reason", row.get("error", "")))
                    print(f"[{status:7s}] {tag:60s} {row['wall_s']:6.1f}s {extra}",
                          flush=True)
    print(f"done; {n_fail} failures")
    sys.exit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
