"""Sharding rules: map params / optimizer state / inputs / caches to
PartitionSpecs on the production mesh.

Parameter rule (tensor parallelism over the ``model`` axis):
  * stacked layer leaves carry a leading (num_layers,) scan axis — skipped;
  * shard the *last* dim divisible by the model-axis size, preferring the
    largest; replicate if nothing divides (tiny norms/biases).

ADMM state rule:
  * the BASE layout (z_hist ring replicated, y / w_cache worker axis
    over the data axes) is owned by ``core.sharded`` — the same
    canonical specs the SPMD epoch's shard_map uses; this module only
    *overlays* the tensor-parallel ``model``-axis param dims on top for
    the dryrun's GSPMD-partitioned trainer — per-device cost
    2P/model_size (DESIGN.md §4).

Input rule:
  * worker-batched train inputs (N, b, ...): N over the data axes;
  * flat batch (B, ...): B over data axes if divisible, else replicated;
  * decode KV caches: batch over data axes if divisible; the *sequence*
    dim over ``model`` (decode attention then auto-partitions into
    per-shard partial softmax + a tiny cross-shard combine).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from ..core.sharded import ring_spec, worker_bundle_spec
from .mesh import data_axes, model_axis_size


def _shard_param_dims(shape, model_size: int, skip_leading: int = 0):
    spec = [None] * len(shape)
    # prefer the largest dim (ties -> later dim); require divisibility
    best, best_size = None, 0
    for i in range(skip_leading, len(shape)):
        if shape[i] % model_size == 0 and shape[i] >= model_size:
            if shape[i] >= best_size:
                best, best_size = i, shape[i]
    if best is not None:
        spec[best] = "model"
    return spec


def _is_stacked(path) -> bool:
    """Leaves under 'layers'/'enc_layers' carry a leading scan axis."""
    for p in path:
        key = getattr(p, "key", None)
        if key in ("layers", "enc_layers"):
            return True
    return False


def _is_moe_expert(path) -> bool:
    keys = [getattr(p, "key", None) for p in path]
    return "moe" in keys and keys[-1] in ("w_gate", "w_up", "w_down")


def param_specs(params_shape, mesh, *, mode: str = "tp",
                expert_parallel: bool = False) -> Any:
    """mode="tp"   — Megatron-style tensor parallel (shard a weight dim);
    mode="fsdp" — shard the stacked *layer* axis over ``model``: the layer
    scan gathers one layer's weights per step (ZeRO-3 over depth) and
    activations stay replicated on the model axis — zero activation
    collectives, weight gathers only (EXPERIMENTS.md §Perf).
    expert_parallel — shard MoE expert stacks on the *expert* dim instead
    of the tiny per-expert ff dim; dispatch becomes an all-to-all."""
    ms = model_axis_size(mesh)

    def spec_for(path, leaf):
        stacked = _is_stacked(path)
        if expert_parallel and _is_moe_expert(path):
            edim = 1 if stacked else 0           # (L, E, a, b) / (E, a, b)
            if leaf.shape[edim] % ms == 0:
                spec = [None] * len(leaf.shape)
                spec[edim] = "model"
                return P(*spec)
        if mode == "fsdp" and stacked and leaf.shape[0] % ms == 0:
            return P(*(["model"] + [None] * (len(leaf.shape) - 1)))
        skip = 1 if stacked else 0
        return P(*_shard_param_dims(leaf.shape, ms, skip))

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def _overlay(base: P, dims) -> P:
    """Overlay model-axis dims onto a base spec (None entries keep the
    base's assignment — in practice the lead worker/ring axis)."""
    out = list(base) + [None] * (len(dims) - len(base))
    for i, d in enumerate(dims):
        if d is not None:
            out[i] = d
    return P(*out)


def admm_state_specs(state_shape, mesh, *, mode: str = "tp",
                     expert_parallel: bool = False) -> Any:
    """Specs for ADMMTrainState(z_hist, y, w_cache, step, rng): the
    canonical ``core.sharded`` base layout + this module's TP overlay."""
    ms = model_axis_size(mesh)
    daxes = data_axes(mesh)

    def _ep_spec(path, leaf, lead):
        if expert_parallel and _is_moe_expert(path):
            stacked = _is_stacked(path)
            edim = lead + (1 if stacked else 0)
            if edim < len(leaf.shape) and leaf.shape[edim] % ms == 0:
                spec = [None] * len(leaf.shape)
                spec[edim] = "model"
                return spec
        return None

    def _model_dims(path, leaf):
        """The TP overlay: which (non-lead) dim carries ``model``."""
        ep = _ep_spec(path, leaf, 1)
        if ep is not None:
            return ep
        stacked = _is_stacked(path)
        if mode == "fsdp" and stacked and len(leaf.shape) > 1 \
                and leaf.shape[1] % ms == 0:
            return [None, "model"] + [None] * (len(leaf.shape) - 2)
        skip = 2 if stacked else 1                 # (lead, [L], ...)
        return [None] + _shard_param_dims(leaf.shape, ms, skip)[1:]

    def z_spec(path, leaf):
        return _overlay(ring_spec(leaf.ndim), _model_dims(path, leaf))

    def worker_spec(path, leaf):
        return _overlay(worker_bundle_spec(leaf.ndim, daxes),
                        _model_dims(path, leaf))

    from ..training.train_state import ADMMTrainState
    return ADMMTrainState(
        z_hist=jax.tree_util.tree_map_with_path(z_spec, state_shape.z_hist),
        y=jax.tree_util.tree_map_with_path(worker_spec, state_shape.y),
        w_cache=jax.tree_util.tree_map_with_path(worker_spec, state_shape.w_cache),
        step=P(), rng=P())


def batch_specs(batch_shape, mesh, *, worker_axis: bool) -> Any:
    daxes = data_axes(mesh)
    ndev = int(np.prod([mesh.shape[a] for a in daxes]))

    def spec_for(leaf):
        if worker_axis:
            return P(*([daxes] + [None] * (len(leaf.shape) - 1)))
        if leaf.shape and leaf.shape[0] % ndev == 0 and leaf.shape[0] >= ndev:
            return P(*([daxes] + [None] * (len(leaf.shape) - 1)))
        return P(*([None] * len(leaf.shape)))

    return jax.tree.map(spec_for, batch_shape)


def cache_specs_tree(cache_shape, mesh, batch: int) -> Any:
    """Decode cache sharding. Leaves (layer-stacked):
       gqa k/v:      (L, B, S, nkv, hd)   -> B over data (if divisible),
                                            S over model
       mla c_kv:     (L, B, S, rank)      -> same
       ssm conv:     (L, B, W-1, convdim) -> B data, convdim model
       ssm state:    (L, B, h, n, p)      -> B data, h over model if div.
       cross k/v:    (L, B, T, nkv, hd)   -> B data, T model
    Heuristic: leading (L,) skipped; batch dim -> data if divisible;
    the largest remaining dim divisible by model size -> model."""
    daxes = data_axes(mesh)
    ndev = int(np.prod([mesh.shape[a] for a in daxes]))
    ms = model_axis_size(mesh)

    def spec_for(leaf):
        dims = [None] * len(leaf.shape)
        # dim 0 = layer stack, dim 1 = batch
        if len(leaf.shape) >= 2 and leaf.shape[1] % ndev == 0 and leaf.shape[1] >= ndev:
            dims[1] = daxes
        best, best_size = None, 0
        for i in range(2, len(leaf.shape)):
            if leaf.shape[i] % ms == 0 and leaf.shape[i] >= ms and leaf.shape[i] > best_size:
                best, best_size = i, leaf.shape[i]
        if best is not None:
            dims[best] = "model"
        return P(*dims)

    return jax.tree.map(spec_for, cache_shape)


def named(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
