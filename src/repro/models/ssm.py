"""Mamba2 — state-space duality (SSD) block [arXiv:2405.21060].

Training / prefill uses the chunked SSD algorithm: quadratic
attention-like compute *within* chunks of length Q plus a linear
recurrence *across* chunks (scanned), giving O(L·Q) work and O(1)-state
decode. Decode is the pure SSM recurrence: one state update per token —
this is what makes the ssm/hybrid archs eligible for the long_500k
shape (DESIGN.md §5).

Layout notation: b=batch, l=seq, c=chunks, q=chunk pos, h=heads,
p=head channels, n=state dim, g=groups (we use g=1, broadcast to h).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init, rmsnorm_gated


def _dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    conv_dim = d_inner + 2 * s.state_dim  # x + B + C (g=1)
    return d_inner, nheads, conv_dim


def init_mamba2(key, cfg):
    s = cfg.ssm
    d = cfg.d_model
    d_inner, nheads, conv_dim = _dims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    in_dim = 2 * d_inner + 2 * s.state_dim + nheads  # z, xBC, dt
    return {
        "w_in": dense_init(ks[0], d, in_dim, dt),
        "conv_w": (jax.random.normal(ks[1], (s.conv_width, conv_dim)) * 0.1).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(dt),
        "D": jnp.ones((nheads,), dt),
        "dt_bias": jnp.zeros((nheads,), dt),
        "norm_w": jnp.ones((d_inner,), dt),
        "w_out": dense_init(ks[4], d_inner, d, dt),
    }


def _split_in(params, u, cfg):
    s = cfg.ssm
    d_inner, nheads, conv_dim = _dims(cfg)
    zxbcdt = u @ params["w_in"]
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner : d_inner + conv_dim]
    dt_raw = zxbcdt[..., d_inner + conv_dim :]
    return z, xBC, dt_raw


def _causal_conv(params, xBC, cfg, conv_state=None):
    """Depthwise causal conv over seq. conv_state: (B, W-1, conv_dim) or None."""
    W = cfg.ssm.conv_width
    if conv_state is None:
        pad = jnp.zeros((xBC.shape[0], W - 1, xBC.shape[-1]), xBC.dtype)
    else:
        pad = conv_state
    xp = jnp.concatenate([pad, xBC], axis=1)              # (B, L+W-1, C)
    out = sum(xp[:, i : i + xBC.shape[1], :] * params["conv_w"][i] for i in range(W))
    out = jax.nn.silu(out + params["conv_b"])
    new_state = xp[:, -(W - 1):, :]
    return out, new_state


def ssd_chunked(x, dt, A, B, C, chunk: int, init_state=None):
    """Chunked SSD scan.
    x: (b,l,h,p)  dt: (b,l,h)  A: (h,)  B,C: (b,l,n)  (g=1, broadcast to h)
    Returns y: (b,l,h,p), final_state: (b,h,n,p)."""
    b, l, h, p = x.shape
    n = B.shape[-1]
    Q = min(chunk, l)
    pad = (-l) % Q
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    L = l + pad
    nc = L // Q
    xc = x.reshape(b, nc, Q, h, p)
    dtc = dt.reshape(b, nc, Q, h)
    Bc = B.reshape(b, nc, Q, n)
    Cc = C.reshape(b, nc, Q, n)

    dA = dtc * A[None, None, None, :]                     # (b,nc,Q,h) negative
    cum = jnp.cumsum(dA, axis=2)
    # intra-chunk: (scores ∘ decay ∘ causal) @ (dt*x)
    # mask the exponent BEFORE exp: for j > i the difference is positive
    # and exp overflows to inf, which poisons the backward pass even
    # under a post-hoc where (inf * 0 = nan in the VJP).
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,nc,i,j,h)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    diff = jnp.where(causal[None, None, :, :, None], diff, -jnp.inf)
    decay = jnp.exp(diff)
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)        # (b,nc,Q,Q)
    dtx = xc * dtc[..., None]                             # (b,nc,Q,h,p)
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", scores, decay, dtx)

    # chunk end states
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)       # (b,nc,Q,h)
    S = jnp.einsum("bcjn,bcjh,bcjhp->bchnp", Bc, decay_to_end, dtx)
    chunk_decay = jnp.exp(cum[:, :, -1, :])               # (b,nc,h)

    if init_state is None:
        init_state = jnp.zeros((b, h, n, p), x.dtype)

    def step(carry, inp):
        S_c, cd = inp                                     # (b,h,n,p), (b,h)
        new = carry * cd[:, :, None, None] + S_c
        return new, carry                                 # emit state *before* chunk

    final, states_before = jax.lax.scan(
        step, init_state, (jnp.moveaxis(S, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    states_before = jnp.moveaxis(states_before, 0, 1)     # (b,nc,h,n,p)

    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp", Cc, jnp.exp(cum), states_before)
    y = (y_intra + y_inter).reshape(b, L, h, p)
    return y[:, :l], final


def mamba2_forward(params, u, cfg, *, return_cache=False, init_cache=None):
    """u: (B, L, d_model) -> (B, L, d_model)."""
    s = cfg.ssm
    d_inner, nheads, _ = _dims(cfg)
    B_, L, _ = u.shape
    z, xBC, dt_raw = _split_in(params, u, cfg)
    conv_state = None if init_cache is None else init_cache["conv"]
    xBC, new_conv = _causal_conv(params, xBC, cfg, conv_state)
    x = xBC[..., :d_inner].reshape(B_, L, nheads, s.head_dim)
    Bmat = xBC[..., d_inner : d_inner + s.state_dim]
    Cmat = xBC[..., d_inner + s.state_dim :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    init_state = None if init_cache is None else init_cache["ssm"]
    y, final_state = ssd_chunked(
        x.astype(jnp.float32), dt, A, Bmat.astype(jnp.float32),
        Cmat.astype(jnp.float32), s.chunk_size, init_state)
    y = y + x.astype(jnp.float32) * params["D"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(B_, L, d_inner).astype(u.dtype)
    y = rmsnorm_gated(y, params["norm_w"], z, cfg.norm_eps)
    out = y @ params["w_out"]
    if return_cache:
        return out, {"conv": new_conv, "ssm": final_state.astype(jnp.float32)}
    return out


def mamba2_decode(params, u, cfg, cache):
    """One-token step. u: (B,1,d); cache: {"conv": (B,W-1,convdim),
    "ssm": (B,h,n,p)}."""
    s = cfg.ssm
    d_inner, nheads, _ = _dims(cfg)
    B_ = u.shape[0]
    z, xBC, dt_raw = _split_in(params, u, cfg)
    xBC, new_conv = _causal_conv(params, xBC, cfg, cache["conv"])
    x = xBC[:, 0, :d_inner].reshape(B_, nheads, s.head_dim)
    Bmat = xBC[:, 0, d_inner : d_inner + s.state_dim].astype(jnp.float32)
    Cmat = xBC[:, 0, d_inner + s.state_dim :].astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A[None, :])                         # (B,h)
    state = cache["ssm"]
    dtx = x.astype(jnp.float32) * dt[..., None]           # (B,h,p)
    new_state = state * dA[:, :, None, None] + jnp.einsum("bn,bhp->bhnp", Bmat, dtx)
    y = jnp.einsum("bn,bhnp->bhp", Cmat, new_state)
    y = y + x.astype(jnp.float32) * params["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B_, 1, d_inner).astype(u.dtype)
    y = rmsnorm_gated(y, params["norm_w"], z, cfg.norm_eps)
    return y @ params["w_out"], {"conv": new_conv, "ssm": new_state}


def mamba2_cache_spec(cfg, batch: int):
    s = cfg.ssm
    d_inner, nheads, conv_dim = _dims(cfg)
    return {
        "conv": jax.ShapeDtypeStruct((batch, s.conv_width - 1, conv_dim), cfg.jnp_dtype()),
        "ssm": jax.ShapeDtypeStruct((batch, nheads, s.state_dim, s.head_dim), jnp.float32),
    }
