"""Mixture-of-Experts layer (Mixtral / Granite-MoE families).

TPU-idiomatic GShard-style capacity dispatch: tokens are routed into an
(experts, capacity, d_model) buffer with one-hot dispatch/combine
einsums, so compiled FLOPs reflect *active* experts (top-k), not all
experts — the dense-compute alternative would inflate the roofline by
E/k. The expert dimension is a natural ADMM *block* axis: a worker batch
only routes into a subset of experts, giving a genuinely sparse edge set
E exactly like the paper's sparse-feature examples (DESIGN.md §5).

Router auxiliary load-balance loss follows Switch/Mixtral:
  aux = E * sum_e( mean_tokens(gate_e) * frac_tokens_routed_to_e )
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import dense_init


def init_moe(key, cfg):
    m = cfg.moe
    d = cfg.d_model
    dt = jnp.dtype(cfg.param_dtype)
    kr, k1, k2, k3 = jax.random.split(key, 4)
    # experts stacked on a leading axis: (E, d, ff) / (E, ff, d)
    def expert_stack(k, a, b):
        keys = jax.random.split(k, m.num_experts)
        return jnp.stack([dense_init(kk, a, b, dt) for kk in keys])
    return {
        "router": dense_init(kr, d, m.num_experts, dt, scale=0.02),
        "w_gate": expert_stack(k1, d, m.expert_ff),
        "w_up": expert_stack(k2, d, m.expert_ff),
        "w_down": expert_stack(k3, m.expert_ff, d),
    }


def moe_forward(params, x, cfg):
    """x: (B, S, d) -> (y, aux_loss)."""
    m = cfg.moe
    capacity_factor = m.capacity_factor
    B, S, d = x.shape
    T = B * S
    E, K = m.num_experts, m.top_k
    xt = x.reshape(T, d)

    logits = (xt @ params["router"]).astype(jnp.float32)        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, K)                      # (T, K)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)      # renormalize

    # ---- load balance auxiliary (Switch eq. 4) ----
    me = jnp.mean(probs, axis=0)                                # (E,)
    routed = jax.nn.one_hot(top_e, E, dtype=jnp.float32)        # (T, K, E)
    ce = jnp.mean(jnp.sum(routed, axis=1), axis=0)              # frac per expert
    aux = E * jnp.sum(me * ce) * m.router_aux_coef

    # ---- capacity-based dispatch ----
    C = max(int(T * K / E * capacity_factor), 4)
    # position of each (token, k) within its expert queue
    flat_e = top_e.reshape(-1)                                  # (T*K,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)         # (T*K, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) * onehot - 1          # (T*K, E)
    pos = jnp.sum(pos_in_e * onehot, axis=-1)                   # (T*K,)
    keep = pos < C
    gate = top_p.reshape(-1) * keep                             # dropped -> 0

    if cfg.moe_impl == "scatter":
        # index-based dispatch: O(T*K*d) scatter/gather instead of the
        # O(T*E*C*d) one-hot einsums (EXPERIMENTS.md §Perf iteration)
        pos_c = jnp.where(keep, pos, C - 1)
        x_rep = jnp.repeat(xt, K, axis=0) * keep[:, None].astype(xt.dtype)
        expert_in = jnp.zeros((E, C, d), xt.dtype).at[flat_e, pos_c].add(x_rep)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, params["w_gate"]))
        h = h * jnp.einsum("ecd,edf->ecf", expert_in, params["w_up"])
        expert_out = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
        picked = expert_out[flat_e, pos_c] * gate[:, None].astype(xt.dtype)
        yt = picked.reshape(T, K, d).sum(axis=1)
        return yt.reshape(B, S, d), aux

    disp = (
        jax.nn.one_hot(flat_e, E, dtype=xt.dtype)[..., None]
        * jax.nn.one_hot(jnp.where(keep, pos, 0), C, dtype=xt.dtype)[:, None, :]
        * keep[:, None, None].astype(xt.dtype)
    )                                                           # (T*K, E, C)
    disp_t = disp.reshape(T, K, E, C).sum(axis=1)               # (T, E, C)
    expert_in = jnp.einsum("tec,td->ecd", disp_t, xt)           # (E, C, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, params["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", expert_in, params["w_up"])
    expert_out = jnp.einsum("ecf,efd->ecd", h, params["w_down"])  # (E, C, d)

    comb = (disp.reshape(T, K, E, C) * gate.reshape(T, K)[..., None, None]
            .astype(xt.dtype)).sum(axis=1)                      # (T, E, C)
    yt = jnp.einsum("tec,ecd->td", comb, expert_out)
    return yt.reshape(B, S, d), aux
