"""Attention variants used by the assigned architectures.

Supports: MHA / GQA (grouped KV heads), QKV bias (Qwen1.5 / ChatGLM),
qk-norm (Qwen3 / Chameleon), partial RoPE (ChatGLM "2d"), sliding-window
(Mixtral), cross-attention (Whisper), and MLA — Multi-head Latent
Attention (MiniCPM3 / DeepSeek-V2) with the *absorbed* decode path that
attends directly over the latent cache.

Two entry points per variant:
  *_forward : full-sequence (training / prefill); optionally returns a cache
  *_decode  : one-token step against a pre-filled cache (decode shapes)
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .layers import apply_rope, causal_mask, dense_init, rmsnorm


# --------------------------------------------------------------------------
# parameter init
# --------------------------------------------------------------------------

def init_attention(key, cfg, *, cross: bool = False):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 8)
    dt = jnp.dtype(cfg.param_dtype)
    if cfg.mla is not None and not cross:
        m = cfg.mla
        qk_dim = m.qk_nope_head_dim + m.qk_rope_head_dim
        return {
            "w_dq": dense_init(ks[0], d, m.q_lora_rank, dt),
            "q_norm": jnp.ones((m.q_lora_rank,), dt),
            "w_uq": dense_init(ks[1], m.q_lora_rank, nq * qk_dim, dt),
            "w_dkv": dense_init(ks[2], d, m.kv_lora_rank + m.qk_rope_head_dim, dt),
            "kv_norm": jnp.ones((m.kv_lora_rank,), dt),
            "w_uk": dense_init(ks[3], m.kv_lora_rank, nq * m.qk_nope_head_dim, dt),
            "w_uv": dense_init(ks[4], m.kv_lora_rank, nq * m.v_head_dim, dt),
            "w_o": dense_init(ks[5], nq * m.v_head_dim, d, dt),
        }
    p = {
        "w_q": dense_init(ks[0], d, nq * hd, dt),
        "w_k": dense_init(ks[1], d, nkv * hd, dt),
        "w_v": dense_init(ks[2], d, nkv * hd, dt),
        "w_o": dense_init(ks[3], nq * hd, d, dt),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((nq * hd,), dt)
        p["b_k"] = jnp.zeros((nkv * hd,), dt)
        p["b_v"] = jnp.zeros((nkv * hd,), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


# --------------------------------------------------------------------------
# GQA core
# --------------------------------------------------------------------------

def _project_qkv(params, x, cfg, positions, *, rope: bool = True):
    B, S, _ = x.shape
    hd, nq, nkv = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
    q = x @ params["w_q"]
    k = x @ params["w_k"]
    v = x @ params["w_v"]
    if cfg.qkv_bias:
        q, k, v = q + params["b_q"], k + params["b_k"], v + params["b_v"]
    q = q.reshape(B, S, nq, hd)
    k = k.reshape(B, S, nkv, hd)
    v = v.reshape(B, S, nkv, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.rope_fraction)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.rope_fraction)
    return q, k, v


def _sdpa(q, k, v, mask, nq, nkv):
    """q: (B,S,nq,hd) k/v: (B,T,nkv,hd); mask broadcastable (S,T) or None."""
    hd = q.shape[-1]
    group = nq // nkv
    B, S = q.shape[:2]
    T = k.shape[1]
    q = q.reshape(B, S, nkv, group, hd)
    scores = jnp.einsum("bsngh,btnh->bngst", q, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask[None, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bngst,btnh->bsngh", probs, v)
    return out.reshape(B, S, nq * hd)


def _sdpa_chunked(q, k, v, nq, nkv, *, causal=True, window=None,
                  chunk=1024):
    """Flash-style online-softmax attention: O(S*chunk) memory instead of
    O(S^2). Pure JAX (lax.scan over query and kv chunks) so XLA/SPMD can
    partition it; running (max, sum, out) accumulators in f32."""
    B, S, _, hd = q.shape
    T = k.shape[1]
    group = nq // nkv
    qc = min(chunk, S)
    kc = min(chunk, T)
    pad_q, pad_k = (-S) % qc, (-T) % kc
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    nQ, nK = (S + pad_q) // qc, (T + pad_k) // kc
    qb = jnp.moveaxis(q.reshape(B, nQ, qc, nkv, group, hd), 1, 0)
    kb = jnp.moveaxis(k.reshape(B, nK, kc, nkv, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nK, kc, nkv, hd), 1, 0)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    def q_step(_, qi_and_idx):
        qt, qi = qi_and_idx                          # (B,qc,nkv,g,hd), ()
        q_pos = qi * qc + jnp.arange(qc)

        def kv_step(carry, kv):
            m, l, o = carry
            kt, vt, ki = kv
            k_pos = ki * kc + jnp.arange(kc)
            s = jnp.einsum("bqngh,bknh->bngqk", qt, kt).astype(jnp.float32)
            s = s * scale
            valid = k_pos[None, :] < T
            if causal:
                valid = valid & (k_pos[None, :] <= q_pos[:, None])
            if window is not None:
                valid = valid & (k_pos[None, :] > q_pos[:, None] - window)
            s = jnp.where(valid[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            o_new = (o * corr[..., None]
                     + jnp.einsum("bngqk,bknh->bngqh", p.astype(vt.dtype)
                                  .astype(jnp.float32),
                                  vt.astype(jnp.float32)))
            return (m_new, l_new, o_new), None

        m0 = jnp.full((B, nkv, group, qc), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, nkv, group, qc), jnp.float32)
        o0 = jnp.zeros((B, nkv, group, qc, hd), jnp.float32)
        (m, l, o), _ = jax.lax.scan(
            kv_step, (m0, l0, o0), (kb, vb, jnp.arange(nK)))
        o = o / jnp.maximum(l[..., None], 1e-30)
        return None, o                               # (B,nkv,g,qc,hd)

    _, outs = jax.lax.scan(q_step, None, (qb, jnp.arange(nQ)))
    out = jnp.moveaxis(outs, 0, 3)                   # (B,nkv,g,nQ,qc,hd)
    out = out.transpose(0, 3, 4, 1, 2, 5).reshape(B, nQ * qc, nq * hd)
    return out[:, :S].astype(q.dtype)


def _sdpa_qchunk(q, k, v, nq, nkv, *, causal=True, window=None,
                 chunk=2048):
    """Query-chunked attention: scan over query tiles, full-width keys.

    Unlike the kv-scanned online-softmax variant, there are NO carried
    accumulators — each scan step reads (K, V) and writes its output
    tile once, so the only large transient is one (qc, T) score tile.
    This is the better XLA realization (a while-loop carry round-trips
    HBM every iteration; ys-stacked outputs are written once).
    """
    B, S, _, hd = q.shape
    T = k.shape[1]
    group = nq // nkv
    qc = min(chunk, S)
    pad_q = (-S) % qc
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    nQ = (S + pad_q) // qc
    qb = jnp.moveaxis(q.reshape(B, nQ, qc, nkv, group, hd), 1, 0)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)
    k_pos = jnp.arange(T)

    def q_step(_, qt_and_idx):
        qt, qi = qt_and_idx                          # (B,qc,nkv,g,hd)
        q_pos = qi * qc + jnp.arange(qc)
        s = jnp.einsum("bqngh,bknh->bngqk", qt, k).astype(jnp.float32) * scale
        valid = jnp.ones((qc, T), bool)
        if causal:
            valid = valid & (k_pos[None, :] <= q_pos[:, None])
        if window is not None:
            valid = valid & (k_pos[None, :] > q_pos[:, None] - window)
        s = jnp.where(valid[None, None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        o = jnp.einsum("bngqk,bknh->bqngh", p, v)
        return None, o.reshape(o.shape[0], qc, nq * hd)

    _, outs = jax.lax.scan(q_step, None, (qb, jnp.arange(nQ)))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nQ * qc, nq * hd)
    return out[:, :S]


def _sdpa_flash(q, k, v, nq, nkv, *, causal=True):
    """Dispatch into the Pallas flash kernel (kernels/flash_attention.py).
    Interpret-mode on CPU (tests), Mosaic on TPU. Requires no sliding
    window (callers fall back to qchunk for SWA)."""
    from ..kernels.flash_attention import flash_attention_bhsd
    B, S, _, hd = q.shape
    group = nq // nkv
    kr = jnp.repeat(k, group, axis=2)                # expand GQA kv heads
    vr = jnp.repeat(v, group, axis=2)
    scale = 1.0 / (hd ** 0.5)
    pad_s = (-S) % 128
    hd_p = max(128, -(-hd // 128) * 128)
    def prep(t):
        t = jnp.pad(t, ((0, 0), (0, pad_s), (0, 0), (0, hd_p - hd)))
        return t.transpose(0, 2, 1, 3).reshape(B * nq, S + pad_s, hd_p)
    out = flash_attention_bhsd(prep(q), prep(kr), prep(vr), causal=causal,
                               scale=scale)
    out = out.reshape(B, nq, S + pad_s, hd_p)[:, :, :S, :hd]
    return out.transpose(0, 2, 1, 3).reshape(B, S, nq * hd)


def gqa_forward(params, x, cfg, positions, *, window=None, causal=True,
                return_cache=False):
    q, k, v = _project_qkv(params, x, cfg, positions)
    S = x.shape[1]
    if cfg.attn_impl == "flash" and window is None:
        out = _sdpa_flash(q, k, v, cfg.num_heads, cfg.num_kv_heads,
                          causal=causal) @ params["w_o"]
        if return_cache:
            return out, {"k": k, "v": v}
        return out
    if cfg.attn_impl == "qchunk":
        out = _sdpa_qchunk(q, k, v, cfg.num_heads, cfg.num_kv_heads,
                           causal=causal, window=window,
                           chunk=cfg.attn_chunk) @ params["w_o"]
        if return_cache:
            return out, {"k": k, "v": v}
        return out
    if cfg.attn_impl == "chunked":
        out = _sdpa_chunked(q, k, v, cfg.num_heads, cfg.num_kv_heads,
                            causal=causal, window=window,
                            chunk=cfg.attn_chunk) @ params["w_o"]
    else:
        mask = causal_mask(S, S, 0, window) if causal else None
        out = _sdpa(q, k, v, mask, cfg.num_heads,
                    cfg.num_kv_heads) @ params["w_o"]
    if return_cache:
        return out, {"k": k, "v": v}
    return out


def gqa_decode(params, x, cfg, cache, pos):
    """x: (B,1,d); cache: {"k","v"} of shape (B, max_len, nkv, hd); pos: ()
    scalar — number of tokens already in the cache. Window masking is
    applied logically (the cache for SWA archs is allocated window-sized
    by the serving layer; for dry-runs it is seq_len-sized)."""
    positions = pos + jnp.zeros(x.shape[:2], jnp.int32)
    q, k, v = _project_qkv(params, x, cfg, positions)
    ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0))
    T = ck.shape[1]
    kj = jnp.arange(T)
    m = kj <= pos
    if cfg.sliding_window is not None:
        m = m & (kj > pos - cfg.sliding_window)
    out = _sdpa(q, ck, cv, m[None, :], cfg.num_heads, cfg.num_kv_heads) @ params["w_o"]
    return out, {"k": ck, "v": cv}


def gqa_cache_spec(cfg, batch: int, max_len: int):
    hd, nkv = cfg.resolved_head_dim, cfg.num_kv_heads
    if cfg.sliding_window is not None:
        max_len = min(max_len, cfg.sliding_window)
    shape = (batch, max_len, nkv, hd)
    return {"k": jax.ShapeDtypeStruct(shape, cfg.jnp_dtype()),
            "v": jax.ShapeDtypeStruct(shape, cfg.jnp_dtype())}


# --------------------------------------------------------------------------
# cross attention (Whisper decoder)
# --------------------------------------------------------------------------

def cross_attn_forward(params, x, enc_kv, cfg):
    """enc_kv = (k, v) precomputed from encoder output."""
    B, S, _ = x.shape
    hd, nq, nkv = cfg.resolved_head_dim, cfg.num_heads, cfg.num_kv_heads
    q = (x @ params["w_q"]).reshape(B, S, nq, hd)
    k, v = enc_kv
    out = _sdpa(q, k, v, None, nq, nkv)
    return out @ params["w_o"]


def encode_cross_kv(params, enc_out, cfg):
    B, T, _ = enc_out.shape
    hd, nkv = cfg.resolved_head_dim, cfg.num_kv_heads
    k = (enc_out @ params["w_k"]).reshape(B, T, nkv, hd)
    v = (enc_out @ params["w_v"]).reshape(B, T, nkv, hd)
    return k, v


# --------------------------------------------------------------------------
# MLA — Multi-head Latent Attention
# --------------------------------------------------------------------------

def _mla_q(params, x, cfg, positions):
    m = cfg.mla
    B, S, _ = x.shape
    nq = cfg.num_heads
    cq = rmsnorm(x @ params["w_dq"], params["q_norm"], cfg.norm_eps)
    q = (cq @ params["w_uq"]).reshape(B, S, nq, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(params, x, cfg, positions):
    m = cfg.mla
    dkv = x @ params["w_dkv"]                       # (B,S,rank+rope)
    c_kv = rmsnorm(dkv[..., : m.kv_lora_rank], params["kv_norm"], cfg.norm_eps)
    k_rope = dkv[..., m.kv_lora_rank:][:, :, None, :]   # single shared rope head
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0, :]
    return c_kv, k_rope


def mla_forward(params, x, cfg, positions, *, return_cache=False):
    """Naive (materialized K/V) path — used for train / prefill."""
    m = cfg.mla
    B, S, _ = x.shape
    nq = cfg.num_heads
    q_nope, q_rope = _mla_q(params, x, cfg, positions)
    c_kv, k_rope = _mla_latent(params, x, cfg, positions)
    k_nope = (c_kv @ params["w_uk"]).reshape(B, S, nq, m.qk_nope_head_dim)
    v = (c_kv @ params["w_uv"]).reshape(B, S, nq, m.v_head_dim)
    scale = 1.0 / jnp.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = (
        jnp.einsum("bsnh,btnh->bnst", q_nope, k_nope)
        + jnp.einsum("bsnh,bth->bnst", q_rope, k_rope)
    ).astype(jnp.float32) * scale
    mask = causal_mask(S, S, 0)
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bnst,btnh->bsnh", probs, v).reshape(B, S, -1)
    out = out @ params["w_o"]
    if return_cache:
        return out, {"c_kv": c_kv, "k_rope": k_rope}
    return out


def mla_decode(params, x, cfg, cache, pos):
    """Absorbed decode: attend over the latent cache directly.
    score = (q_nope @ W_uk) @ c_kv^T + q_rope @ k_rope^T ; out via W_uv."""
    m = cfg.mla
    B = x.shape[0]
    nq = cfg.num_heads
    positions = pos + jnp.zeros(x.shape[:2], jnp.int32)
    q_nope, q_rope = _mla_q(params, x, cfg, positions)      # (B,1,nq,·)
    c_new, kr_new = _mla_latent(params, x, cfg, positions)
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_new, (0, pos, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], kr_new, (0, pos, 0))
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, nq, m.qk_nope_head_dim)
    q_abs = jnp.einsum("bsnh,rnh->bsnr", q_nope, w_uk)      # (B,1,nq,rank)
    scale = 1.0 / jnp.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    scores = (
        jnp.einsum("bsnr,btr->bnst", q_abs, c_kv)
        + jnp.einsum("bsnh,bth->bnst", q_rope, k_rope)
    ).astype(jnp.float32) * scale
    T = c_kv.shape[1]
    mask = jnp.arange(T)[None, :] <= pos
    scores = jnp.where(mask[None, None], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bnst,btr->bsnr", probs, c_kv)         # (B,1,nq,rank)
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, nq, m.v_head_dim)
    out = jnp.einsum("bsnr,rnh->bsnh", ctx, w_uv).reshape(B, 1, -1)
    return out @ params["w_o"], {"c_kv": c_kv, "k_rope": k_rope}


def mla_cache_spec(cfg, batch: int, max_len: int):
    m = cfg.mla
    return {
        "c_kv": jax.ShapeDtypeStruct((batch, max_len, m.kv_lora_rank), cfg.jnp_dtype()),
        "k_rope": jax.ShapeDtypeStruct((batch, max_len, m.qk_rope_head_dim), cfg.jnp_dtype()),
    }
