"""Core layer primitives: inits, norms, rotary embeddings, MLPs.

Everything is functional: params are plain nested dicts of jnp arrays,
layers are pure functions. Layer stacks are scanned (see transformer.py)
so compile time is depth-independent.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------

def dense_init(key, in_dim: int, out_dim: int, dtype, scale: Optional[float] = None):
    if scale is None:
        scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rmsnorm(x, weight, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * weight.astype(jnp.float32)).astype(dt)


def rmsnorm_gated(x, weight, gate, eps: float = 1e-5):
    """Mamba2 gated RMSNorm: norm(x * silu(gate))."""
    return rmsnorm(x * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype), weight, eps)


# --------------------------------------------------------------------------
# rotary position embeddings
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float, fraction: float = 1.0):
    """Return inverse frequencies for the rotary fraction of the head dim.

    ``fraction < 1`` implements partial rotary ("2d RoPE", ChatGLM style):
    only the first ``fraction * head_dim`` channels rotate.
    """
    rot_dim = int(head_dim * fraction)
    rot_dim -= rot_dim % 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim))
    return inv, rot_dim


def apply_rope(x, positions, theta: float, fraction: float = 1.0):
    """x: (..., seq, heads, head_dim); positions: (..., seq) int32."""
    head_dim = x.shape[-1]
    inv, rot_dim = rope_freqs(head_dim, theta, fraction)
    if rot_dim == 0:
        return x
    ang = positions[..., :, None].astype(jnp.float32) * inv[None, :]  # (..., seq, rot/2)
    cos = jnp.cos(ang)[..., :, None, :]   # (..., seq, 1, rot/2)
    sin = jnp.sin(ang)[..., :, None, :]
    xr, xp = x[..., :rot_dim], x[..., rot_dim:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([out.astype(x.dtype), xp], axis=-1)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, act: str, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    if act == "swiglu":
        return {
            "w_gate": dense_init(k1, d_model, d_ff, dtype),
            "w_up": dense_init(k2, d_model, d_ff, dtype),
            "w_down": dense_init(k3, d_ff, d_model, dtype),
        }
    return {
        "w_up": dense_init(k1, d_model, d_ff, dtype),
        "w_down": dense_init(k2, d_ff, d_model, dtype),
    }


def mlp(params, x, act: str = "swiglu"):
    if act == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    else:
        h = jax.nn.gelu(x @ params["w_up"])
    return h @ params["w_down"]


# --------------------------------------------------------------------------
# misc
# --------------------------------------------------------------------------

def causal_mask(q_len: int, kv_len: int, q_offset, window: Optional[int] = None):
    """Boolean (q_len, kv_len) mask. q position i sits at absolute index
    q_offset + i; kv index j is absolute j.  window = sliding-window width."""
    qi = q_offset + jnp.arange(q_len)[:, None]
    kj = jnp.arange(kv_len)[None, :]
    m = kj <= qi
    if window is not None:
        m = m & (kj > qi - window)
    return m


def cross_entropy(logits, labels, label_mask=None):
    """Mean token cross-entropy. logits: (B, S, V); labels: (B, S) int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if label_mask is not None:
        return jnp.sum(nll * label_mask) / jnp.maximum(jnp.sum(label_mask), 1.0)
    return jnp.mean(nll)
