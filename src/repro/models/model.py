"""Public model API: a thin facade over transformer.py.

``Model`` bundles init / loss / prefill / decode for one ModelConfig.
The ADMM trainer, serving engine, launcher and tests all go through
this facade so model families stay interchangeable.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from . import transformer
from .layers import cross_entropy


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any

    # ---- params ----
    def init(self, rng) -> Dict[str, Any]:
        return transformer.init_params(rng, self.cfg)

    def param_specs(self, rng=None):
        """ShapeDtypeStruct pytree of params without allocating."""
        return jax.eval_shape(lambda k: transformer.init_params(k, self.cfg),
                              jax.random.PRNGKey(0))

    # ---- training ----
    def loss(self, params, batch) -> jax.Array:
        """batch: {"tokens": (B,S), "labels": (B,S), ["enc_frames"]}."""
        logits, aux = transformer.forward(
            params, batch["tokens"], self.cfg,
            enc_frames=batch.get("enc_frames"))
        mask = batch.get("label_mask")
        return cross_entropy(logits, batch["labels"], mask) + aux

    def grad_fn(self):
        return jax.grad(self.loss)

    # ---- inference ----
    def prefill(self, params, tokens, enc_frames=None, logits_mode="all"):
        logits, _ = transformer.forward(params, tokens, self.cfg,
                                        enc_frames=enc_frames,
                                        logits_mode=logits_mode)
        return logits

    def decode_step(self, params, token, cache, pos):
        return transformer.decode_step(params, token, cache, pos, self.cfg)

    def init_cache(self, batch: int, max_len: int):
        return transformer.init_cache(self.cfg, batch, max_len)

    def cache_specs(self, batch: int, max_len: int):
        return transformer.init_cache_specs(self.cfg, batch, max_len)


def build_model(cfg) -> Model:
    return Model(cfg)
