from .model import Model, build_model
from .transformer import (decode_step, forward, init_cache, init_cache_specs,
                          init_params, set_activation_sharding)
