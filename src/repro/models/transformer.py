"""Transformer stack composition for all assigned architecture families.

Layer stacks are *scanned* over stacked parameter pytrees so compile time
and HLO size are depth-independent (crucial for the 62/64-layer dry-runs
on 512 host devices). Families:

  dense  — [attn + MLP] x L                       (qwen*, minicpm3(MLA),
                                                   chatglm3, chameleon)
  moe    — [attn + MoE] x L                       (mixtral, granite)
  ssm    — [mamba2] x L                           (mamba2-370m)
  hybrid — groups of k mamba2 layers, a *shared*  (zamba2)
           attention block applied after each group
  audio  — encoder (bi-attn) + decoder (self+cross) (whisper; conv
           frontend stubbed — input is frame embeddings)

Activation-sharding hook: ``set_activation_sharding(fn)`` lets the
launcher inject ``with_sharding_constraint`` at layer boundaries without
threading mesh objects through the model code.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from . import attention as attn
from . import moe as moe_mod
from . import ssm as ssm_mod
from .layers import dense_init, embed_init, init_mlp, mlp, rmsnorm

# ---------------------------------------------------------------------------
# activation sharding hook
# ---------------------------------------------------------------------------

_ACT_SHARD: Callable[[jax.Array], jax.Array] = lambda x: x


def set_activation_sharding(fn: Optional[Callable]) -> None:
    global _ACT_SHARD
    _ACT_SHARD = fn if fn is not None else (lambda x: x)


def _shard(x):
    return _ACT_SHARD(x)


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------

def _init_dense_layer(key, cfg, *, cross: bool = False):
    ks = jax.random.split(key, 6)
    dt = jnp.dtype(cfg.param_dtype)
    p = {
        "attn": attn.init_attention(ks[0], cfg),
        "norm1": jnp.ones((cfg.d_model,), dt),
        "norm2": jnp.ones((cfg.d_model,), dt),
    }
    if cfg.moe is not None:
        p["moe"] = moe_mod.init_moe(ks[1], cfg)
    else:
        p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.act, dt)
    if cross:
        p["cross"] = attn.init_attention(ks[2], cfg, cross=True)
        p["norm_cross"] = jnp.ones((cfg.d_model,), dt)
    return p


def _init_ssm_layer(key, cfg):
    dt = jnp.dtype(cfg.param_dtype)
    return {
        "mamba": ssm_mod.init_mamba2(key, cfg),
        "norm1": jnp.ones((cfg.d_model,), dt),
    }


def _stack_init(fn, rng, n):
    keys = jax.random.split(rng, n)
    return jax.tree.map(lambda *xs: jnp.stack(xs), *[fn(k) for k in keys])


def init_params(rng, cfg) -> Dict[str, Any]:
    ks = jax.random.split(rng, 8)
    dt = jnp.dtype(cfg.param_dtype)
    params: Dict[str, Any] = {
        "embed": embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[1], cfg.d_model, cfg.vocab_size, dt)

    if cfg.arch_type == "ssm":
        params["layers"] = _stack_init(lambda k: _init_ssm_layer(k, cfg), ks[2], cfg.num_layers)
    elif cfg.arch_type == "hybrid":
        params["layers"] = _stack_init(lambda k: _init_ssm_layer(k, cfg), ks[2], cfg.num_layers)
        params["shared_attn"] = _init_dense_layer(ks[3], cfg)
    elif cfg.is_enc_dec:
        params["layers"] = _stack_init(
            lambda k: _init_dense_layer(k, cfg, cross=True), ks[2], cfg.num_layers)
        params["enc_layers"] = _stack_init(
            lambda k: _init_dense_layer(k, cfg), ks[3], cfg.encoder_layers)
        params["enc_norm"] = jnp.ones((cfg.d_model,), dt)
    else:
        params["layers"] = _stack_init(lambda k: _init_dense_layer(k, cfg), ks[2], cfg.num_layers)
    return params


# ---------------------------------------------------------------------------
# layer application (full sequence)
# ---------------------------------------------------------------------------

def _dense_layer_fwd(lp, x, cfg, positions, *, causal=True, enc_kv=None):
    h = rmsnorm(x, lp["norm1"], cfg.norm_eps)
    if cfg.mla is not None:
        a = attn.mla_forward(lp["attn"], h, cfg, positions)
    else:
        a = attn.gqa_forward(lp["attn"], h, cfg, positions,
                             window=cfg.sliding_window, causal=causal)
    x = _shard(x + a)
    aux = jnp.zeros((), jnp.float32)
    if enc_kv is not None:
        c = attn.cross_attn_forward(lp["cross"], rmsnorm(x, lp["norm_cross"], cfg.norm_eps),
                                    enc_kv, cfg)
        x = _shard(x + c)
    h = rmsnorm(x, lp["norm2"], cfg.norm_eps)
    if cfg.moe is not None:
        y, aux = moe_mod.moe_forward(lp["moe"], h, cfg)
    else:
        y = mlp(lp["mlp"], h, cfg.act)
    return _shard(x + y), aux


def _ssm_layer_fwd(lp, x, cfg):
    h = rmsnorm(x, lp["norm1"], cfg.norm_eps)
    return _shard(x + ssm_mod.mamba2_forward(lp["mamba"], h, cfg))


def _scan_layers(body, x, stacked, cfg, extra=None):
    """Scan `body(carry, layer_params)` over the stacked layer axis."""
    if cfg.remat:
        body = jax.checkpoint(body, prevent_cse=False)

    def f(carry, lp):
        return body(carry, lp)

    return jax.lax.scan(f, x, stacked)


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(params, tokens, cfg, *, enc_frames=None, logits_mode="all"):
    """tokens: (B, S) int32 -> logits (B, S, V).

    enc_frames: (B, T_enc, d_model) precomputed frame/patch embeddings
    (audio/vlm frontend stub) — required for enc-dec archs.
    logits_mode="last": project only the final position (serving
    prefill needs one next-token distribution, not S of them — skips
    the (B, S, V) logit tensor entirely).
    """
    B, S = tokens.shape
    x = params["embed"][tokens]
    x = _shard(x)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None, :], (B, S))
    aux_total = jnp.zeros((), jnp.float32)

    if cfg.arch_type == "ssm":
        def body(carry, lp):
            return _ssm_layer_fwd(lp, carry, cfg), None
        x, _ = _scan_layers(body, x, params["layers"], cfg)

    elif cfg.arch_type == "hybrid":
        x = _hybrid_forward(params, x, cfg, positions)

    elif cfg.is_enc_dec:
        enc = _shard(enc_frames.astype(x.dtype))
        enc_pos = jnp.broadcast_to(
            jnp.arange(enc.shape[1], dtype=jnp.int32)[None, :], enc.shape[:2])

        def enc_body(carry, lp):
            y, _ = _dense_layer_fwd(lp, carry, cfg, enc_pos, causal=False)
            return y, None
        enc, _ = _scan_layers(enc_body, enc, params["enc_layers"], cfg)
        enc = rmsnorm(enc, params["enc_norm"], cfg.norm_eps)

        def dec_body(carry, lp):
            enc_kv = attn.encode_cross_kv(lp["cross"], enc, cfg)
            y, _ = _dense_layer_fwd(lp, carry, cfg, positions, enc_kv=enc_kv)
            return y, None
        x, _ = _scan_layers(dec_body, x, params["layers"], cfg)

    else:
        def body(carry, lp):
            y, aux = _dense_layer_fwd(lp, carry[0], cfg, positions)
            return (y, carry[1] + aux), None
        (x, aux_total), _ = jax.lax.scan(
            jax.checkpoint(body, prevent_cse=False) if cfg.remat else body,
            (x, aux_total), params["layers"])

    if logits_mode == "last":
        x = x[:, -1:]
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, x, cfg)
    return logits, aux_total


def _hybrid_forward(params, x, cfg, positions):
    k = max(cfg.hybrid_attn_every, 1)
    n_groups = cfg.num_layers // k
    rem = cfg.num_layers - n_groups * k
    stacked = params["layers"]

    def body(carry, lp):
        return _ssm_layer_fwd(lp, carry, cfg), None

    for g in range(n_groups):
        group = jax.tree.map(lambda a: a[g * k : (g + 1) * k], stacked)
        x, _ = _scan_layers(body, x, group, cfg)
        x, _ = _dense_layer_fwd(params["shared_attn"], x, cfg, positions)
    if rem:
        tail = jax.tree.map(lambda a: a[n_groups * k :], stacked)
        x, _ = _scan_layers(body, x, tail, cfg)
    return x


def _logits(params, x, cfg):
    if cfg.tie_embeddings:
        return _shard(x @ params["embed"].T)
    return _shard(x @ params["lm_head"])


# ---------------------------------------------------------------------------
# decode (one token against a pre-filled cache)
# ---------------------------------------------------------------------------

def init_cache_specs(cfg, batch: int, max_len: int):
    """ShapeDtypeStruct pytree for the decode cache (dry-run input_specs)."""
    L = cfg.num_layers

    def stack_spec(spec_tree, n):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), spec_tree)

    if cfg.arch_type == "ssm":
        return {"layers": stack_spec(ssm_mod.mamba2_cache_spec(cfg, batch), L)}
    if cfg.arch_type == "hybrid":
        k = max(cfg.hybrid_attn_every, 1)
        n_apps = L // k
        return {
            "layers": stack_spec(ssm_mod.mamba2_cache_spec(cfg, batch), L),
            "shared_attn": stack_spec(attn.gqa_cache_spec(cfg, batch, max_len), n_apps),
        }
    if cfg.mla is not None:
        return {"layers": stack_spec(attn.mla_cache_spec(cfg, batch, max_len), L)}
    cache = {"layers": stack_spec(attn.gqa_cache_spec(cfg, batch, max_len), L)}
    if cfg.is_enc_dec:
        hd, nkv = cfg.resolved_head_dim, cfg.num_kv_heads
        enc_kv_shape = (L, batch, cfg.encoder_seq_len, nkv, hd)
        cache["cross_k"] = jax.ShapeDtypeStruct(enc_kv_shape, cfg.jnp_dtype())
        cache["cross_v"] = jax.ShapeDtypeStruct(enc_kv_shape, cfg.jnp_dtype())
    return cache


def init_cache(cfg, batch: int, max_len: int):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        init_cache_specs(cfg, batch, max_len))


def decode_step(params, token, cache, pos, cfg):
    """token: (B, 1) int32; pos: () int32 — current cache fill.
    Returns (logits (B, 1, V), new_cache)."""
    x = params["embed"][token]

    if cfg.arch_type == "ssm":
        def body(carry, inp):
            lp, lc = inp
            h = rmsnorm(carry, lp["norm1"], cfg.norm_eps)
            y, nc = ssm_mod.mamba2_decode(lp["mamba"], h, cfg, lc)
            return carry + y, nc
        x, new_layer_cache = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        new_cache = {"layers": new_layer_cache}

    elif cfg.arch_type == "hybrid":
        x, new_cache = _hybrid_decode(params, x, cache, pos, cfg)

    elif cfg.is_enc_dec:
        def body(carry, inp):
            lp, lc, ck, cv = inp
            h = rmsnorm(carry, lp["norm1"], cfg.norm_eps)
            a, nc = attn.gqa_decode(lp["attn"], h, cfg, lc, pos)
            y = carry + a
            c = attn.cross_attn_forward(
                lp["cross"], rmsnorm(y, lp["norm_cross"], cfg.norm_eps), (ck, cv), cfg)
            y = y + c
            y = y + mlp(lp["mlp"], rmsnorm(y, lp["norm2"], cfg.norm_eps), cfg.act)
            return y, nc
        x, new_layer_cache = jax.lax.scan(
            body, x, (params["layers"], cache["layers"], cache["cross_k"], cache["cross_v"]))
        new_cache = dict(cache, layers=new_layer_cache)

    else:
        def body(carry, inp):
            lp, lc = inp
            h = rmsnorm(carry, lp["norm1"], cfg.norm_eps)
            if cfg.mla is not None:
                a, nc = attn.mla_decode(lp["attn"], h, cfg, lc, pos)
            else:
                a, nc = attn.gqa_decode(lp["attn"], h, cfg, lc, pos)
            y = carry + a
            h2 = rmsnorm(y, lp["norm2"], cfg.norm_eps)
            if cfg.moe is not None:
                z, _ = moe_mod.moe_forward(lp["moe"], h2, cfg)
            else:
                z = mlp(lp["mlp"], h2, cfg.act)
            return y + z, nc
        x, new_layer_cache = jax.lax.scan(body, x, (params["layers"], cache["layers"]))
        new_cache = {"layers": new_layer_cache}

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return _logits(params, x, cfg), new_cache


def _hybrid_decode(params, x, cache, pos, cfg):
    k = max(cfg.hybrid_attn_every, 1)
    n_groups = cfg.num_layers // k
    rem = cfg.num_layers - n_groups * k

    def body(carry, inp):
        lp, lc = inp
        h = rmsnorm(carry, lp["norm1"], cfg.norm_eps)
        y, nc = ssm_mod.mamba2_decode(lp["mamba"], h, cfg, lc)
        return carry + y, nc

    new_mamba = []
    new_attn = []
    for g in range(n_groups):
        sl = lambda a, g=g, n=k: a[g * n : (g + 1) * n]
        x, nm = jax.lax.scan(body, x, (jax.tree.map(sl, params["layers"]),
                                       jax.tree.map(sl, cache["layers"])))
        new_mamba.append(nm)
        ac = jax.tree.map(lambda a, g=g: a[g], cache["shared_attn"])
        h = rmsnorm(x, params["shared_attn"]["norm1"], cfg.norm_eps)
        a, nac = attn.gqa_decode(params["shared_attn"]["attn"], h, cfg, ac, pos)
        x = x + a
        x = x + mlp(params["shared_attn"]["mlp"],
                    rmsnorm(x, params["shared_attn"]["norm2"], cfg.norm_eps), cfg.act)
        new_attn.append(nac)
    if rem:
        sl = lambda a: a[n_groups * k :]
        x, nm = jax.lax.scan(body, x, (jax.tree.map(sl, params["layers"]),
                                       jax.tree.map(sl, cache["layers"])))
        new_mamba.append(nm)
    new_cache = {
        "layers": jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *new_mamba),
        "shared_attn": jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *new_attn),
    }
    return x, new_cache
