"""Per-round record sinks — watch a consensus run while it executes.

The PS runtime emits one record each time a round *completes* (every
lock domain has published the round's version), at the configured
``metrics_every`` cadence. A record is a plain JSON-able dict:

  {"round": r, "version": r+1, "sim_time": ..., "loss": ...,
   "stationarity": {"P": ..., "primal_residual": ...,
                    "prox_residual": ..., "grad_norm": ...,
                    "per_block": {"primal": [...], "prox": [...],
                                  "grad": [...], "P": [...]}} | null,
   "queue_depth": [...per domain...], "commits": ..., "pushes": ...,
   "stall_count": ..., "stall_time": ...,
   "transport": {...} | null}

``stationarity`` is null when the runtime cannot compute it without
perturbing the run (timing-only mode, ``track_x=False`` sessions,
streamed ``batches=`` data, or a block server currently down);
``transport`` is null on reliable runs. Records are computed from
committed state and monotone counters only — no rng, no scheduled
events — so streaming on/off cannot change the run (the determinism
contract of ``repro.obs``).

Sinks are pluggable: :class:`JsonlSink` (one JSON object per line),
:class:`StdoutSink` (live mode for a terminal), :class:`CallbackSink`
(in-process consumer via ``run_ps(telemetry=callable)``).
:func:`make_sink` coerces what users pass; :func:`validate_record`
pins the schema (CI validates every streamed line against it).
"""
from __future__ import annotations

import json
import sys
from typing import Any, Callable, Dict, IO, Optional


class Sink:
    """A per-round record consumer. ``emit`` must not raise on
    well-formed records; ``close`` flushes/releases resources."""

    def emit(self, record: Dict[str, Any]) -> None:  # pragma: no cover
        raise NotImplementedError

    def close(self) -> None:
        pass


class JsonlSink(Sink):
    """Append records to ``path``, one JSON object per line."""

    def __init__(self, path: str):
        self.path = str(path)
        self._f: Optional[IO[str]] = open(self.path, "w")

    def emit(self, record: Dict[str, Any]) -> None:
        assert self._f is not None, "sink already closed"
        self._f.write(json.dumps(record) + "\n")

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


class StdoutSink(Sink):
    """Live mode: one JSON line per record to a stream (stdout)."""

    def __init__(self, stream: Optional[IO[str]] = None):
        self._stream = stream

    def emit(self, record: Dict[str, Any]) -> None:
        stream = self._stream or sys.stdout
        stream.write(json.dumps(record) + "\n")
        stream.flush()


class CallbackSink(Sink):
    """Hand each record to an in-process callable."""

    def __init__(self, fn: Callable[[Dict[str, Any]], None]):
        self._fn = fn

    def emit(self, record: Dict[str, Any]) -> None:
        self._fn(record)


def make_sink(spec: Any) -> Optional[Sink]:
    """Coerce a user-facing sink spec: None -> None, a Sink ->
    itself, a callable -> CallbackSink, "stdout"/"-" -> StdoutSink,
    any other string/path -> JsonlSink."""
    if spec is None:
        return None
    if isinstance(spec, Sink):
        return spec
    if callable(spec):
        return CallbackSink(spec)
    if isinstance(spec, (str, bytes)) or hasattr(spec, "__fspath__"):
        path = str(spec)
        if path in ("stdout", "-"):
            return StdoutSink()
        return JsonlSink(path)
    raise TypeError(
        f"cannot make a telemetry sink from {type(spec).__name__}: pass "
        f"None, a repro.obs.Sink, a callable, 'stdout', or a file path")


# ---------------------------------------------------------------------------
# record schema (CI validates the emitted JSONL against this)
# ---------------------------------------------------------------------------

#: required top-level keys -> allowed types (None encodes "nullable").
ROUND_RECORD_SCHEMA: Dict[str, tuple] = {
    "round":        (int,),
    "version":      (int,),
    "sim_time":     (float, int),
    "loss":         (float, int, type(None)),
    "stationarity": (dict, type(None)),
    "queue_depth":  (list,),
    "commits":      (int,),
    "pushes":       (int,),
    "stall_count":  (int,),
    "stall_time":   (float, int),
    "transport":    (dict, type(None)),
}

_STATIONARITY_KEYS = ("P", "primal_residual", "prox_residual",
                      "grad_norm", "per_block")
_PER_BLOCK_KEYS = ("primal", "prox", "grad", "P")


def validate_record(record: Dict[str, Any]) -> Dict[str, Any]:
    """Check one streamed record against the schema; raises
    ``ValueError`` naming the offending key. Returns the record."""
    for key, types in ROUND_RECORD_SCHEMA.items():
        if key not in record:
            raise ValueError(f"round record missing key {key!r}; "
                             f"got keys {sorted(record)}")
        if not isinstance(record[key], types):
            raise ValueError(
                f"round record key {key!r} has type "
                f"{type(record[key]).__name__}, expected one of "
                f"{[t.__name__ for t in types]}")
    st = record["stationarity"]
    if st is not None:
        missing = [k for k in _STATIONARITY_KEYS if k not in st]
        if missing:
            raise ValueError(f"stationarity block missing {missing}")
        pb = st["per_block"]
        bad = [k for k in _PER_BLOCK_KEYS
               if not isinstance(pb.get(k), list)]
        if bad:
            raise ValueError(f"stationarity per_block keys {bad} must "
                             f"be lists of per-block floats")
    return record
