"""Metrics registry — lazy instruments over the PS runtime's counters.

The determinism contract (see ``repro.obs``) forbids telemetry from
touching the schedule, so the registry inverts the usual push model:
components do NOT increment instruments on the hot path (their plain
attribute counters stay exactly as they were); instead they *register*
an instrument whose value is a zero-argument callback reading those
attributes. ``collect()`` runs the callbacks once, at the end of the
run, in registration order — which is how ``ps/runtime.py`` assembles
``PSRunResult.metrics`` with the exact key order and values the
pre-telemetry dict had (byte-compatible by construction: the callbacks
evaluate the same expressions the inline dict used to).

Instrument names validate against :data:`repro.obs.names.METRICS`
(the stable public spellings); ``register(..., check=False)`` opts a
scratch instrument out (benchmarks register ad-hoc series).

``hist`` is the shared histogram summarizer (promoted from
``ps/runtime.py::_hist``), with the degenerate cases fixed: an empty
input yields all-zero counts over a unit range instead of a phantom
observation at 0, and an all-equal input gets a non-zero-width range
centered on the value.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from .names import METRICS

INSTRUMENT_KINDS = ("counter", "gauge", "histogram", "series")


def hist(values, bins: int = 8) -> Dict[str, list]:
    """Summarize ``values`` into ``{"counts": [...], "edges": [...]}``
    with ``bins`` buckets. Degenerate inputs stay well-formed: empty
    input -> all-zero counts over [0, 1] (no phantom observation);
    all-equal values -> a unit-width range centered on the value
    (numpy would otherwise produce zero-width bins for an explicit
    degenerate range)."""
    if bins < 1:
        raise ValueError(f"hist needs bins >= 1; got {bins}")
    vals = np.asarray(list(values), np.float64)
    if vals.size == 0:
        edges = np.linspace(0.0, 1.0, bins + 1)
        return {"counts": [0] * bins, "edges": [float(e) for e in edges]}
    lo, hi = float(vals.min()), float(vals.max())
    rng = (lo - 0.5, hi + 0.5) if lo == hi else (lo, hi)
    counts, edges = np.histogram(vals, bins=bins, range=rng)
    return {"counts": counts.tolist(), "edges": [float(e) for e in edges]}


class Instrument:
    """One named metric: a kind, a unit, and a value callback."""

    __slots__ = ("name", "kind", "unit", "help", "_fn")

    def __init__(self, name: str, kind: str, unit: str, help_: str,
                 fn: Callable[[], Any]):
        self.name = name
        self.kind = kind
        self.unit = unit
        self.help = help_
        self._fn = fn

    def value(self) -> Any:
        return self._fn()


class TimeSeries:
    """An append-only (sim_time, value) series — the time-bucketed
    instrument kind. Appends are O(1) list pushes (no rng, no events:
    safe on the recording path); ``buckets(width)`` aggregates
    post-hoc."""

    __slots__ = ("points",)

    def __init__(self):
        self.points: List[Tuple[float, float]] = []

    def append(self, t: float, value: float) -> None:
        self.points.append((float(t), float(value)))

    def buckets(self, width: float) -> Dict[str, list]:
        """Aggregate into fixed-width time buckets: per-bucket count,
        sum, and last value. Empty series -> empty buckets."""
        if width <= 0:
            raise ValueError(f"bucket width must be > 0; got {width}")
        out: Dict[int, list] = {}
        for (t, v) in self.points:
            b = int(t // width)
            slot = out.setdefault(b, [0, 0.0, v])
            slot[0] += 1
            slot[1] += v
            slot[2] = v
        return {"width": width,
                "buckets": [{"t0": b * width, "count": c, "sum": s,
                             "last": last}
                            for b, (c, s, last) in sorted(out.items())]}

    def value(self) -> List[Tuple[float, float]]:
        return list(self.points)


class MetricsRegistry:
    """Named instruments, collected once in registration order."""

    def __init__(self):
        self._instruments: Dict[str, Instrument] = {}
        self._series: Dict[str, TimeSeries] = {}

    # ------------------------------------------------------------------
    def register(self, name: str, kind: str, fn: Callable[[], Any], *,
                 unit: str = "", help: str = "",
                 check: bool = True) -> Instrument:
        """Register instrument ``name`` with value callback ``fn``.
        Registered names must appear in ``repro.obs.names.METRICS``
        with a matching kind (``check=False`` skips — scratch/benchmark
        instruments); duplicate registration is an error (the runtime
        assembles its metrics dict from these, and a silent overwrite
        would reorder or clobber a public key)."""
        if kind not in INSTRUMENT_KINDS:
            raise ValueError(f"unknown instrument kind {kind!r}; "
                             f"expected one of {INSTRUMENT_KINDS}")
        if name in self._instruments:
            raise ValueError(f"instrument {name!r} already registered")
        if check:
            decl = METRICS.get(name)
            if decl is None:
                raise ValueError(
                    f"metric name {name!r} is not declared in "
                    f"repro.obs.names.METRICS; declare it there (the "
                    f"stable-name contract) or register with "
                    f"check=False for a scratch instrument")
            if decl[0] != kind:
                raise ValueError(
                    f"metric {name!r} is declared as a {decl[0]} in "
                    f"repro.obs.names.METRICS but registered as a "
                    f"{kind}")
            if not unit:
                unit = decl[1]
            if not help:
                help = decl[2]
        inst = Instrument(name, kind, unit, help, fn)
        self._instruments[name] = inst
        return inst

    def counter(self, name: str, fn: Callable[[], Any],
                **kw) -> Instrument:
        return self.register(name, "counter", fn, **kw)

    def gauge(self, name: str, fn: Callable[[], Any], **kw) -> Instrument:
        return self.register(name, "gauge", fn, **kw)

    def histogram(self, name: str, fn: Callable[[], Any],
                  **kw) -> Instrument:
        """A histogram instrument: ``fn`` returns the raw observations;
        ``collect`` summarizes them via :func:`hist`."""
        return self.register(name, "histogram", lambda: fn(), **kw)

    def series(self, name: str, *, check: bool = False) -> TimeSeries:
        """Create (or fetch) a named append-only time series. Series
        are scratch by default (``check=False``): they are recording
        surfaces, not ``PSRunResult.metrics`` keys."""
        ts = self._series.get(name)
        if ts is None:
            ts = self._series[name] = TimeSeries()
            self.register(name, "series", ts.value, check=check)
        return ts

    # ------------------------------------------------------------------
    def __contains__(self, name: str) -> bool:
        return name in self._instruments

    def get(self, name: str) -> Optional[Instrument]:
        return self._instruments.get(name)

    def describe(self) -> List[Dict[str, str]]:
        """The instrument table (name/kind/unit/help) in registration
        order — what API.md's metric table documents."""
        return [{"name": i.name, "kind": i.kind, "unit": i.unit,
                 "help": i.help} for i in self._instruments.values()]

    def collect(self, names: Optional[List[str]] = None) -> Dict[str, Any]:
        """Evaluate instruments (all, or the ``names`` subset) in
        registration order and return the name -> value dict."""
        insts = self._instruments.values() if names is None else \
            [self._instruments[n] for n in names]
        return {i.name: i.value() for i in insts}
