"""Telemetry — the one handle the PS runtime threads everywhere.

A :class:`Telemetry` bundles the three observability layers (span
tracer, metrics registry, per-round stream sink) behind a single
object the runtime stores as ``rt.obs``. Every instrumentation site in
``repro.ps`` is guarded by ``rt.obs is not None`` — telemetry off
means *no object*, zero calls, zero state: the telemetry-off run is
the pre-telemetry runtime, byte for byte.

The determinism contract, concretely:

* recording uses **virtual sim-time only** (the DES clock) — no
  wall-clock reads;
* recording **consumes no rng** — every instrumented site records
  values the schedule already produced;
* recording **schedules nothing and reorders nothing** — appends to
  Python lists and dict counters only.

So a telemetry-on run commits the identical z trajectory (bitwise on
pallas), fold logs and makespan as the telemetry-off run — pinned by
``tests/test_obs.py`` and gated in ``scripts/ci.sh``.

Construction: ``Telemetry(...)`` directly for full control, or
:func:`as_telemetry` to coerce what ``run_ps(telemetry=)`` accepts
(True, a path, "stdout", a callable, a Sink, or a Telemetry).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

from .spans import SpanTracer
from .stream import Sink, make_sink


class Telemetry:
    """Span tracer + stream sink + round-completion bookkeeping."""

    def __init__(self, *, spans: bool = True, sink: Any = None,
                 metrics_every: int = 1,
                 trace_path: Optional[str] = None):
        if metrics_every < 1:
            raise ValueError(f"metrics_every must be >= 1; "
                             f"got {metrics_every}")
        self.spans: Optional[SpanTracer] = SpanTracer() if spans else None
        self.sink: Optional[Sink] = make_sink(sink)
        self.metrics_every = int(metrics_every)
        self.trace_path = trace_path
        self.records_emitted = 0
        self.events_seen = 0
        self._commit_counts: Dict[int, int] = {}
        # open "down" windows: track name -> sim time the entity died
        # (closed at rejoin/recovery, or at makespan by finalize)
        self._down_since: Dict[str, float] = {}
        self._num_domains = 0
        self._num_rounds = 0
        self._record_fn: Optional[Callable] = None

    # ------------------------------------------------------------------
    # runtime wiring
    # ------------------------------------------------------------------
    def bind(self, *, num_domains: int, num_rounds: int,
             record_fn: Callable[[int, float], Dict[str, Any]]) -> None:
        """Called by ``PSRuntime.run`` before launch: how many lock
        domains make a round complete, and the callback that assembles
        one round record from committed state (read-only)."""
        self._num_domains = int(num_domains)
        self._num_rounds = int(num_rounds)
        self._record_fn = record_fn
        self._commit_counts = {}

    def on_event(self, now: float, tag: Optional[str]) -> None:
        """The scheduler's observer hook (``events.py``): count every
        processed event. Pure accounting — never touches the queue."""
        self.events_seen += 1

    def note_commit(self, sid: int, version: int, now: float) -> None:
        """A lock domain published ``version``. When the last domain
        reaches it, round ``version - 1`` is complete — emit its record
        at the configured cadence. WAL-replay rebuilds do NOT re-enter
        here (those versions were counted at their live commit)."""
        n = self._commit_counts.get(version, 0) + 1
        self._commit_counts[version] = n
        if n != self._num_domains or self.sink is None \
                or self._record_fn is None:
            return
        r = version - 1
        if r % self.metrics_every == 0 or r == self._num_rounds - 1:
            self.sink.emit(self._record_fn(version, now))
            self.records_emitted += 1

    # ------------------------------------------------------------------
    # span conveniences (all no-ops when spans are disabled)
    # ------------------------------------------------------------------
    @staticmethod
    def worker_track(i: int) -> str:
        return f"worker {i}"

    @staticmethod
    def server_track(sid: int) -> str:
        return f"server {sid}"

    RUNTIME_TRACK = "runtime"

    def entity_down(self, track: str, t: float) -> None:
        """Open a "down" window on ``track`` (idempotent while open —
        overlapping fault windows merge, as the runtime's do)."""
        if self.spans is not None:
            self._down_since.setdefault(track, float(t))

    def entity_up(self, track: str, t: float) -> None:
        """Close ``track``'s open "down" window, if any."""
        start = self._down_since.pop(track, None)
        if self.spans is not None and start is not None:
            self.spans.complete(track, "down", start, float(t))

    def transport_recorder(self, inner: Callable) -> Callable:
        """Wrap the DelayTrace transport recorder so every delivery
        decision also lands as an instant on the worker's track."""
        if self.spans is None:
            return inner

        def record(kind: str, **fields: Any) -> None:
            inner(kind, **fields)
            self.spans.instant(
                self.worker_track(fields.get("worker", -1)), kind,
                fields.get("time", 0.0),
                **{k: v for k, v in fields.items()
                   if k not in ("worker", "time")})
        return record

    # ------------------------------------------------------------------
    def finalize(self, meta: Optional[Dict[str, Any]] = None) -> None:
        """End of run: flush/close the sink and save the Chrome trace
        when a ``trace_path`` was configured."""
        if self.sink is not None:
            self.sink.close()
        if self.spans is not None:
            end = (meta or {}).get("makespan")
            if end is not None:
                # entities still dead at the end of the run: close
                # their windows at the makespan (sorted for a stable
                # event order)
                for track in sorted(self._down_since):
                    self.spans.complete(track, "down",
                                        self._down_since[track],
                                        float(end))
                self._down_since.clear()
            if self.trace_path:
                self.spans.save(self.trace_path, meta)


def as_telemetry(spec: Any) -> Optional[Telemetry]:
    """Coerce ``run_ps(telemetry=)``: None/False -> None (inert),
    True -> spans only, a Telemetry -> itself, anything else -> a
    Telemetry streaming to ``make_sink(spec)``."""
    if spec is None or spec is False:
        return None
    if isinstance(spec, Telemetry):
        return spec
    if spec is True:
        return Telemetry(spans=True)
    return Telemetry(spans=True, sink=spec)
