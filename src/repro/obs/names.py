"""The observability name registries — one place, no silent drift.

Every name the telemetry subsystem emits is declared here:

* ``TRACE_EVENT_KINDS`` — the chaos-timeline kinds a
  :class:`~repro.ps.trace.DelayTrace` may carry (``add_event``
  validates against this set, so a new fault type cannot invent a
  trace-event spelling the telemetry layer does not know);
* ``TRANSPORT_EVENT_KINDS`` — the per-link delivery-decision kinds
  (``add_transport`` validates the same way);
* ``SPAN_NAMES`` — the span/instant vocabulary of the Chrome-trace
  export (``obs/spans.py`` refuses unknown names);
* ``METRICS`` — the stable metric names of the registry
  (``obs/metrics.py`` refuses unregistered spellings), with units and
  one-line descriptions. These names ARE the public contract
  (API.md's metric table is generated from this dict's entries), so a
  rename is an API change, not a refactor.

Keeping the registries next to each other is the point: the PS
runtime's trace events, the span tracer's tracks and the metrics
registry all describe the same underlying schedule, and the names
must agree for a Perfetto trace, a JSONL stream and a saved
``DelayTrace`` to be cross-referenced.
"""
from __future__ import annotations

from typing import Mapping

# ---------------------------------------------------------------------------
# DelayTrace event kinds (ps/trace.py validates against these)
# ---------------------------------------------------------------------------

#: Chaos-timeline kinds recorded via ``DelayTrace.add_event`` — the
#: fault transitions (ps/runtime.py) plus the queried factor windows
#: the injector logs up front (ps/chaos.py).
TRACE_EVENT_KINDS = frozenset({
    "crash",           # worker lost mid-cycle (transient)
    "leave",           # worker left permanently
    "join",            # cold worker joined mid-run
    "rejoin",          # crashed worker resumed
    "slowdown",        # transient worker compute multiplier window
    "server_spike",    # transient server commit-latency window
    "link_loss",       # burst packet-loss window on matching links
    "server_crash",    # block server lost its volatile state
    "server_recover",  # block server rebuilt from its WAL
})

#: Per-link delivery decisions recorded via ``DelayTrace.add_transport``
#: (ps/transport.py's ``LinkChannel`` is the only writer).
TRANSPORT_EVENT_KINDS = frozenset({
    "drop",            # message lost on the link
    "dup",             # message delivered twice
    "reorder",         # delivery held back past later traffic
    "retransmit",      # sender's timeout fired, message resent
    "pull_timeout",    # pull degraded to the cached version
})


def validate_kind(kind: str, registry: frozenset, what: str) -> str:
    """Raise an actionable ``ValueError`` when ``kind`` is not a
    registered ``what`` name; returns ``kind`` unchanged otherwise."""
    if kind not in registry:
        raise ValueError(
            f"unknown {what} kind {kind!r}; registered kinds: "
            f"{sorted(registry)}. Register new kinds in "
            f"repro.obs.names so telemetry spans, trace events and "
            f"the metrics registry cannot silently diverge.")
    return kind


# ---------------------------------------------------------------------------
# span vocabulary (obs/spans.py validates against these)
# ---------------------------------------------------------------------------

#: name -> (event type, description). ``complete`` spans have duration
#: (Chrome "X"); ``instant`` marks a point (Chrome "i"); ``counter`` is
#: a sampled value track (Chrome "C"). Times are virtual sim-seconds.
SPAN_NAMES: Mapping[str, tuple] = {
    # worker tracks
    "pull":          ("complete", "pull issue -> version resolved (RTT "
                                  "incl. stall/retransmission)"),
    "stall":         ("complete", "bounded-staleness stall: pull parked "
                                  "-> commit that satisfied it"),
    "compute":       ("complete", "worker service time for one round"),
    "down":          ("complete", "entity dead: crash/leave -> "
                                  "rejoin/recovery (or run end)"),
    # server tracks
    "queue_wait":    ("complete", "time an item sat behind earlier work "
                                  "in the lock domain's serial queue"),
    "push_service":  ("complete", "push processing occupancy (+ eager "
                                  "commit draw under per_push)"),
    "commit_service": ("complete", "round-boundary commit occupancy"),
    "commit":        ("instant",  "version published (args: version, "
                                  "folds)"),
    "wal_replay":    ("instant",  "WAL replay rebuilt the domain (args: "
                                  "replayed versions)"),
    "snapshot":      ("complete", "quiescent barrier: first worker "
                                  "parked -> snapshot written"),
    # chaos / transport instants (same spellings as the trace logs)
    "crash":         ("instant",  "worker crash"),
    "leave":         ("instant",  "worker permanent leave"),
    "join":          ("instant",  "cold worker joined"),
    "rejoin":        ("instant",  "worker resumed"),
    "server_crash":  ("instant",  "block server lost volatile state"),
    "server_recover": ("instant", "block server recovered"),
    "drop":          ("instant",  "link dropped a message"),
    "dup":           ("instant",  "link duplicated a message"),
    "reorder":       ("instant",  "link held a message back"),
    "retransmit":    ("instant",  "sender retransmitted"),
    "pull_timeout":  ("instant",  "pull fell back to the cached z"),
    # counter tracks
    "queue_depth":   ("counter",  "unprocessed pushes per lock domain"),
    "events":        ("counter",  "scheduler events processed"),
}


# ---------------------------------------------------------------------------
# stable metric names (obs/metrics.py validates against these)
# ---------------------------------------------------------------------------

#: name -> (kind, unit, description). ``kind`` is the instrument type
#: the registry will accept for the name. The spellings match
#: ``PSRunResult.metrics`` keys exactly — the registry IS how
#: ``ps/runtime.py`` assembles that dict, so this table is the
#: authoritative metric contract (mirrored in API.md).
METRICS: Mapping[str, tuple] = {
    # staleness enforcement (ps/staleness.py)
    "bound":                  ("gauge",   "versions", "Assumption-3 T"),
    "pulls_served":           ("counter", "pulls",    "pulls served"),
    "max_served_tau":         ("gauge",   "versions", "max staleness served"),
    "stall_count":            ("counter", "stalls",   "pulls that parked"),
    "stall_time":             ("counter", "sim_s",    "total stall time"),
    "dropped_pulls":          ("counter", "pulls",    "parked pulls dropped "
                                                      "by crashes"),
    "version_resets":         ("counter", "events",   "rejoin version resets"),
    "timeout_fallbacks":      ("counter", "pulls",    "cached-z fallbacks"),
    # scheduler / servers (ps/events.py, ps/server.py)
    "makespan":               ("gauge",   "sim_s",    "final simulated time"),
    "events":                 ("counter", "events",   "scheduler events "
                                                      "processed"),
    "commits":                ("counter", "commits",  "versions published"),
    "pushes":                 ("counter", "pushes",   "w pushes received"),
    "server_busy_time":       ("gauge",   "sim_s",    "per-domain occupancy"),
    "server_busy_frac":       ("gauge",   "fraction", "per-domain occupancy "
                                                      "/ makespan"),
    "server_wait_time":       ("gauge",   "sim_s",    "per-domain queueing "
                                                      "delay"),
    # workers / membership (ps/worker.py, ps/membership.py)
    "stall_time_per_worker":  ("gauge",   "sim_s",    "per-worker stall time"),
    "stall_count_per_worker": ("gauge",   "stalls",   "per-worker stalls"),
    "participated_rounds":    ("gauge",   "rounds",   "per-worker rounds "
                                                      "participated"),
    "worker_iterations":      ("counter", "rounds",   "total worker-rounds"),
    "crashes":                ("counter", "events",   "worker crashes"),
    "rejoins":                ("counter", "events",   "worker rejoins/joins"),
    "histograms":             ("histogram", "mixed",  "worker_stall_time + "
                                                      "server_occupancy"),
    # durability (ps/recovery.py) — present only when armed
    "server_recoveries":      ("counter", "events",   "WAL-replay rebuilds"),
    "wal":                    ("gauge",   "records",  "WAL record totals"),
    "snapshots":              ("gauge",   "paths",    "snapshot prefixes "
                                                      "written"),
    # transport (ps/transport.py) — present only on lossy runs
    "transport":              ("gauge",   "messages", "fleet-wide delivery "
                                                      "totals"),
}
