"""Span tracer — the PS schedule as a Chrome trace, in virtual time.

Spans live on entity *tracks* ("worker 3", "server 0", "runtime"),
mapped onto Chrome trace-event pid/tid pairs so Perfetto
(https://ui.perfetto.dev) renders each entity as its own swimlane.
All timestamps are the DES's *simulated* seconds scaled to
microseconds (the trace-event unit) — wall-clock never appears, which
is what makes the export deterministic: two runs of the same seed
produce byte-identical span lists.

Recording is append-only list pushes (no rng, no scheduling, no
reading of volatile numeric state) — the determinism contract's
"never perturb the schedule" in practice. Span/instant/counter names
validate against :data:`repro.obs.names.SPAN_NAMES`, so the span
vocabulary cannot drift from the documented schema.

Export: :meth:`to_chrome` returns the ``{"traceEvents": [...]}`` JSON
object (complete "X" spans, instant "i" events, counter "C" samples,
plus thread-name metadata), :meth:`save` writes it. Load it in
Perfetto or ``chrome://tracing`` directly.
"""
from __future__ import annotations

import json
from typing import Any, Dict, List

from .names import SPAN_NAMES, validate_kind

_SCALE = 1e6          # sim seconds -> trace-event microseconds


class SpanTracer:
    """Deterministic virtual-time span recorder for one run."""

    def __init__(self):
        self._events: List[Dict[str, Any]] = []
        self._tids: Dict[str, int] = {}

    # ------------------------------------------------------------------
    def _tid(self, track: str) -> int:
        tid = self._tids.get(track)
        if tid is None:
            tid = self._tids[track] = len(self._tids) + 1
        return tid

    def _check(self, name: str, expected: str) -> None:
        kind = validate_kind(name, frozenset(SPAN_NAMES), "span")
        actual = SPAN_NAMES[name][0]
        if actual != expected:
            raise ValueError(
                f"span name {name!r} is declared as {actual!r} in "
                f"repro.obs.names.SPAN_NAMES but emitted as "
                f"{expected!r}")

    # ------------------------------------------------------------------
    def complete(self, track: str, name: str, start: float, end: float,
                 **args: Any) -> None:
        """A duration span [start, end] (sim seconds) on ``track``."""
        self._check(name, "complete")
        if end < start:
            raise ValueError(f"span {name!r} ends before it starts "
                             f"({end} < {start})")
        self._events.append({
            "name": name, "ph": "X", "pid": 1, "tid": self._tid(track),
            "ts": start * _SCALE, "dur": (end - start) * _SCALE,
            "args": args})

    def instant(self, track: str, name: str, t: float,
                **args: Any) -> None:
        """A point event at sim time ``t`` on ``track``."""
        self._check(name, "instant")
        self._events.append({
            "name": name, "ph": "i", "s": "t", "pid": 1,
            "tid": self._tid(track), "ts": t * _SCALE, "args": args})

    def counter(self, track: str, name: str, t: float,
                **values: float) -> None:
        """A sampled counter value at sim time ``t``."""
        self._check(name, "counter")
        self._events.append({
            "name": name, "ph": "C", "pid": 1,
            "tid": self._tid(track), "ts": t * _SCALE, "args": values})

    # ------------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._events)

    def to_chrome(self, meta: Dict[str, Any] | None = None) -> Dict:
        """The Chrome trace-event JSON object: thread-name metadata
        (one per track, in first-use order) + recorded events."""
        header = [{
            "name": "thread_name", "ph": "M", "pid": 1, "tid": tid,
            "args": {"name": track}}
            for track, tid in self._tids.items()]
        out = {"traceEvents": header + self._events,
               "displayTimeUnit": "ms"}
        if meta:
            out["otherData"] = dict(meta)
        return out

    def save(self, path: str, meta: Dict[str, Any] | None = None) -> str:
        """Write the Perfetto-loadable trace JSON to ``path``."""
        with open(path, "w") as f:
            json.dump(self.to_chrome(meta), f)
        return path
