"""repro.obs — deterministic telemetry for the PS runtime.

Three layers, one hard contract:

* :mod:`repro.obs.spans` — a virtual-time span tracer (Chrome
  trace-event JSON, loadable in Perfetto) the runtime hangs pull RTTs,
  stalls, commit queues, retransmit ladders, crash/recovery windows
  and snapshot barriers onto;
* :mod:`repro.obs.metrics` — a registry of lazily-evaluated
  counters/gauges/histograms/series the runtime components register
  instruments into, from which ``PSRunResult.metrics`` is assembled;
* :mod:`repro.obs.stream` — pluggable per-round record sinks (JSONL,
  stdout live mode, in-process callback) carrying loss, per-block
  stationarity/residuals, queue depths, stall and transport totals.

**The contract: telemetry is inert by default and never perturbs the
schedule.** Recording uses the DES's virtual clock only, consumes no
rng, and schedules no events — a telemetry-on run is bitwise
identical (pallas) to a telemetry-off run, with equal fold logs and
makespan. ``scripts/ci.sh`` gates this on a chaos scenario.

Metric, span and trace-event names all validate against
:mod:`repro.obs.names` — the single registry that keeps the
vocabularies from drifting apart.
"""
from .metrics import MetricsRegistry, TimeSeries, hist
from .names import (METRICS, SPAN_NAMES, TRACE_EVENT_KINDS,
                    TRANSPORT_EVENT_KINDS, validate_kind)
from .spans import SpanTracer
from .stream import (CallbackSink, JsonlSink, ROUND_RECORD_SCHEMA, Sink,
                     StdoutSink, make_sink, validate_record)
from .telemetry import Telemetry, as_telemetry

__all__ = [
    "MetricsRegistry", "TimeSeries", "hist",
    "METRICS", "SPAN_NAMES", "TRACE_EVENT_KINDS", "TRANSPORT_EVENT_KINDS",
    "validate_kind", "SpanTracer",
    "CallbackSink", "JsonlSink", "ROUND_RECORD_SCHEMA", "Sink",
    "StdoutSink", "make_sink", "validate_record",
    "Telemetry", "as_telemetry",
]
