"""Pytree checkpointing: .npz payload + JSON treedef manifest.

Path-keyed (not order-keyed) so checkpoints survive adding/removing
state fields; supports partial restore and dtype/shape validation.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def save(path: str, tree, step: Optional[int] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(path + ".npz", **arrays)
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                   for k, a in arrays.items()},
    }
    with open(path + ".json", "w") as f:
        json.dump(manifest, f, indent=1)


def restore(path: str, like_tree) -> Any:
    """Restore into the structure of ``like_tree`` (path-matched)."""
    data = np.load(path + ".npz")
    flat_like = _flatten_with_paths(like_tree)
    missing = [k for k in flat_like if k not in data.files]
    if missing:
        raise KeyError(f"checkpoint missing keys: {missing[:5]}...")
    leaves_like, treedef = jax.tree_util.tree_flatten(like_tree)
    paths = list(_flatten_with_paths(like_tree).keys())
    out = []
    for key, ref in zip(paths, leaves_like):
        arr = data[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {ref.shape}")
        out.append(jnp.asarray(arr, ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def load_step(path: str) -> Optional[int]:
    with open(path + ".json") as f:
        return json.load(f).get("step")
