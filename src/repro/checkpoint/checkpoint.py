"""Pytree checkpointing: .npz payload + JSON treedef manifest.

Path-keyed (not order-keyed) so checkpoints survive adding/removing
state fields; supports partial restore and dtype/shape validation.

Writes are **atomic**: both files land via temp-file + ``os.replace``
in the target directory, so a crash mid-save can never leave a torn
checkpoint — the previous one survives intact (this is what makes the
PS runtime's crash-consistent snapshots in ``repro.ps.recovery``
safe). ``restore`` cross-validates the JSON manifest against the npz
payload before touching any leaf and fails with errors that name the
file and the offending leaf.

The manifest can carry an arbitrary JSON-serializable ``extra``
payload next to the leaves (``save(..., extra=...)`` /
``load_extra``) — the runtime snapshot layer stores all its
non-array state (rng states, clock, membership intervals, ...) there.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def _atomic_replace(target: str, write_fn) -> None:
    """Write via a temp file in the target's directory + os.replace —
    the only crash-safe publish on POSIX (rename within a filesystem
    is atomic; a crash leaves either the old file or the new one)."""
    d = os.path.dirname(target) or "."
    fd, tmp = tempfile.mkstemp(dir=d, prefix=os.path.basename(target) + ".",
                               suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            write_fn(f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, target)
    except BaseException:
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def save(path: str, tree, step: Optional[int] = None,
         extra: Optional[Dict] = None) -> None:
    """Atomically write ``path + ".npz"`` (arrays) and ``path + ".json"``
    (manifest). The npz lands first, the manifest second — a reader
    that sees the manifest is guaranteed a complete matching payload
    (restore cross-validates anyway). ``extra`` is an arbitrary
    JSON-serializable blob stored in the manifest."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(tree)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    _atomic_replace(path + ".npz", lambda f: np.savez(f, **arrays))
    manifest = {
        "step": step,
        "leaves": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                   for k, a in arrays.items()},
    }
    if extra is not None:
        manifest["extra"] = extra
    _atomic_replace(
        path + ".json",
        lambda f: f.write(json.dumps(manifest, indent=1).encode()))


def _load_manifest(path: str) -> Dict:
    mpath = path + ".json"
    try:
        with open(mpath) as f:
            text = f.read()
    except FileNotFoundError:
        raise FileNotFoundError(
            f"checkpoint manifest {mpath!r} not found — was this "
            f"checkpoint written by repro.checkpoint.save?") from None
    try:
        manifest = json.loads(text)
    except json.JSONDecodeError as e:
        raise ValueError(f"checkpoint manifest {mpath!r} is corrupt JSON "
                         f"({e}) — torn write or wrong file") from e
    if not isinstance(manifest, dict) or "leaves" not in manifest \
            or not isinstance(manifest["leaves"], dict):
        raise ValueError(f"checkpoint manifest {mpath!r} has no 'leaves' "
                         f"table — not a repro.checkpoint manifest")
    return manifest


def _load_validated(path: str):
    """Load the npz and cross-validate it against the manifest: every
    manifest leaf must exist in the npz with the recorded shape, and
    vice versa. Catches torn/mismatched checkpoint halves before any
    caller reads a leaf."""
    manifest = _load_manifest(path)
    npath = path + ".npz"
    try:
        data = np.load(npath, allow_pickle=False)
    except FileNotFoundError:
        raise FileNotFoundError(
            f"checkpoint payload {npath!r} not found (manifest "
            f"{path + '.json'!r} exists) — torn checkpoint") from None
    except Exception as e:
        raise ValueError(f"checkpoint payload {npath!r} is unreadable "
                         f"({e}) — truncated or corrupt npz") from e
    leaves = manifest["leaves"]
    for key, meta in leaves.items():
        if key not in data.files:
            raise ValueError(
                f"checkpoint {path!r}: manifest lists leaf {key!r} but the "
                f"npz payload does not contain it — torn or mixed-up "
                f"checkpoint halves")
        shape = tuple(data[key].shape)
        want = tuple(meta.get("shape", ()))
        if shape != want:
            raise ValueError(
                f"checkpoint {path!r}: leaf {key!r} has npz shape {shape} "
                f"but the manifest recorded {want} — torn or mixed-up "
                f"checkpoint halves")
    for key in data.files:
        if key not in leaves:
            raise ValueError(
                f"checkpoint {path!r}: npz contains leaf {key!r} absent "
                f"from the manifest — torn or mixed-up checkpoint halves")
    return data, manifest


def restore(path: str, like_tree) -> Any:
    """Restore into the structure of ``like_tree`` (path-matched)."""
    data, _ = _load_validated(path)
    flat_like = _flatten_with_paths(like_tree)
    missing = [k for k in flat_like if k not in data.files]
    if missing:
        raise KeyError(
            f"checkpoint {path!r} is missing leaves required by the "
            f"restore target: {missing[:5]}{'...' if len(missing) > 5 else ''}")
    leaves_like, treedef = jax.tree_util.tree_flatten(like_tree)
    paths = list(flat_like.keys())
    out = []
    for key, ref in zip(paths, leaves_like):
        arr = data[key]
        if tuple(arr.shape) != tuple(ref.shape):
            raise ValueError(
                f"checkpoint {path!r}: leaf {key!r} has shape "
                f"{tuple(arr.shape)} but the restore target expects "
                f"{tuple(ref.shape)}")
        out.append(jnp.asarray(arr, ref.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


def load_arrays(path: str) -> Dict[str, np.ndarray]:
    """All leaves of a checkpoint as a flat {path: array} dict,
    manifest-validated (no ``like_tree`` needed — used by the PS
    snapshot layer whose leaf set is data-dependent)."""
    data, _ = _load_validated(path)
    return {k: data[k] for k in data.files}


def load_extra(path: str) -> Optional[Dict]:
    """The manifest's ``extra`` payload (None when absent)."""
    return _load_manifest(path).get("extra")


def load_step(path: str) -> Optional[int]:
    return _load_manifest(path).get("step")
