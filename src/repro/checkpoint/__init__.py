from .checkpoint import load_step, restore, save
