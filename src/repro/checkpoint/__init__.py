from .checkpoint import (load_arrays, load_extra, load_step, restore,
                         save)
