from . import ops, ref
from .ops import admm_worker_update, logreg_grad, matmul, prox_consensus
