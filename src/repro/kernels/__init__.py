from . import ops, ref
from .ops import (admm_worker_select_update, admm_worker_update, logreg_grad,
                  matmul, prox_consensus, server_prox_update)
