"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Tests sweep shapes/dtypes and assert the kernels (interpret mode on CPU,
compiled on TPU) match these to numerical tolerance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def admm_worker_update_ref(g, y, z_tilde, rho):
    """Fused eqs. (11)+(12)+(9): returns (x, y_new, w). ``rho`` is a
    scalar or any array broadcastable against the buffers."""
    x = z_tilde - (g + y) / rho
    y_new = y + rho * (x - z_tilde)      # == -g
    w = rho * x + y_new
    return x, y_new, w


def admm_worker_select_update_ref(g, y, z_tilde, w_old, sel, rho_vec,
                                  x_old=None):
    """Worker update + Alg. 1 sel-masked merges in one op.

    g, y, z_tilde, w_old [, x_old]: (N, M, dblk); sel: (N, M) bool;
    rho_vec: (N,). Returns (y', w'[, x'])."""
    rho = rho_vec.reshape(-1, 1, 1)
    x, y_new, w = admm_worker_update_ref(g, y, z_tilde, rho)
    keep = sel[..., None]
    y_out = jnp.where(keep, y_new, y)
    w_out = jnp.where(keep, w, w_old)
    if x_old is None:
        return y_out, w_out
    return y_out, w_out, jnp.where(keep, x, x_old)


def prox_consensus_ref(z_tilde, w_sum, rho_sum, gamma: float,
                       l1: float, clip: float):
    """Fused eq. (13) with h = l1*|.|_1 + box(clip).
    z_tilde, w_sum: (M, d); rho_sum: (M, 1)."""
    mu = gamma + rho_sum
    v = (gamma * z_tilde + w_sum) / mu
    u = jnp.sign(v) * jnp.maximum(jnp.abs(v) - l1 / mu, 0.0) if l1 > 0 else v
    if clip > 0:
        u = jnp.clip(u, -clip, clip)
    return u


def server_prox_update_ref(z_cur, w_cache, edge, rho_sum, gamma: float,
                           l1: float, clip: float):
    """Edge-masked worker reduction + eq. (13) in one op.

    z_cur: (M, d); w_cache: (N, M, d); edge: (N, M) bool; rho_sum: (M,)."""
    w_sum = jnp.sum(jnp.where(edge[..., None], w_cache, 0.0), axis=0)
    return prox_consensus_ref(z_cur, w_sum, rho_sum.reshape(-1, 1),
                              gamma, l1, clip)


def logreg_margin_ref(X, y, w):
    """v = -y * sigmoid(-y * (X @ w)) — per-sample dloss/dmargin."""
    s = X @ w
    return -y * jax.nn.sigmoid(-y * s)


def logreg_grad_ref(X, y, w):
    """grad of mean_i log(1+exp(-y_i x_i.w)) wrt w (eq. 22 smooth part)."""
    m = X.shape[0]
    v = logreg_margin_ref(X, y, w)
    return (X.T @ v) / m
