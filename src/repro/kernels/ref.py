"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Tests sweep shapes/dtypes and assert the kernels (interpret mode on CPU,
compiled on TPU) match these to numerical tolerance.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def admm_worker_update_ref(g, y, z_tilde, rho: float):
    """Fused eqs. (11)+(12)+(9): returns (x, y_new, w)."""
    x = z_tilde - (g + y) / rho
    y_new = y + rho * (x - z_tilde)      # == -g
    w = rho * x + y_new
    return x, y_new, w


def prox_consensus_ref(z_tilde, w_sum, rho_sum, gamma: float,
                       l1: float, clip: float):
    """Fused eq. (13) with h = l1*|.|_1 + box(clip).
    z_tilde, w_sum: (M, d); rho_sum: (M, 1)."""
    mu = gamma + rho_sum
    v = (gamma * z_tilde + w_sum) / mu
    u = jnp.sign(v) * jnp.maximum(jnp.abs(v) - l1 / mu, 0.0) if l1 > 0 else v
    if clip > 0:
        u = jnp.clip(u, -clip, clip)
    return u


def logreg_margin_ref(X, y, w):
    """v = -y * sigmoid(-y * (X @ w)) — per-sample dloss/dmargin."""
    s = X @ w
    return -y * jax.nn.sigmoid(-y * s)


def logreg_grad_ref(X, y, w):
    """grad of mean_i log(1+exp(-y_i x_i.w)) wrt w (eq. 22 smooth part)."""
    m = X.shape[0]
    v = logreg_margin_ref(X, y, w)
    return (X.T @ v) / m
