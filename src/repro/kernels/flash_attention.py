"""Pallas TPU kernel: flash attention (tile-resident online softmax).

Motivated by the §Perf hillclimb on chameleon-34b x prefill_32k: pure-XLA
attention — naive, kv-chunked, or q-chunked — always round-trips the
(S x T) score tiles through HBM, because XLA cannot fuse
matmul -> softmax -> matmul into one kernel. At S = T = 32768 that is
the dominant memory-roofline term. This kernel keeps the score tile, the
online-softmax statistics (m, l) and the output accumulator in VMEM
scratch across the K-tile loop; HBM sees only Q/K/V reads and one output
write — the O(S^2) term disappears from the roofline.

Grid: (batch*heads, S/BQ, T/BK), K innermost. Tiles default to
(128, head_dim) — MXU-aligned (128 lanes, head_dim multiple of 128 for
the assigned archs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK = 128
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            k_steps: int, scale: float, causal: bool, bq: int, bk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)                  # (bq, hd)
    k = k_ref[0].astype(jnp.float32)                  # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        qi = pl.program_id(1)
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + jnp.sum(p, axis=-1, keepdims=True)
    m_scr[...] = m_new
    v = v_ref[0].astype(jnp.float32)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

    @pl.when(ki == k_steps - 1)
    def _():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)
                    ).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         block_q: int = DEFAULT_BLOCK,
                         block_k: int = DEFAULT_BLOCK,
                         scale: float = None,
                         interpret: bool = True):
    """q: (BH, S, hd); k, v: (BH, T, hd); S % block_q == T % block_k == 0."""
    BH, S, hd = q.shape
    T = k.shape[1]
    bq, bk = min(block_q, S), min(block_k, T)
    assert S % bq == 0 and T % bk == 0, (S, T, bq, bk)
    grid = (BH, S // bq, T // bk)
    if scale is None:
        scale = 1.0 / (hd ** 0.5)
    return pl.pallas_call(
        functools.partial(_kernel, k_steps=grid[2], scale=scale,
                          causal=causal, bq=bq, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),     # running max m
            pltpu.VMEM((bq, 1), jnp.float32),     # running denom l
            pltpu.VMEM((bq, hd), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
