"""Pallas TPU kernels for the paper's workload hot-spot: the sparse
logistic-regression gradient (eq. 22 smooth part),

    g = X^T ( -y * sigmoid(-y * (X @ w)) ) / m.

Built from two MXU-aligned tiled primitives:

* ``matmul`` — 128x128x128 blocked matmul with an f32 VMEM accumulator
  scratch, K innermost in the grid so each (i, j) output tile is
  revisited across K steps (zero-init at k==0, flush at k==K-1).
  ``transpose_a`` contracts over the *row* axis of A without ever
  materializing X^T in HBM — that is the X^T v pass.
* ``margin`` — elementwise v = -y*sigmoid(-y*s) on (8,128) vreg tiles.

Note on matvecs: w and v are carried as (d, 128)/(m, 128) single-column
panels. On the MXU this is free — the systolic array processes 128
lanes per pass regardless — so the "padded matvec" IS the TPU-native
formulation, not a workaround.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

BLK = 128


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, k_steps: int,
                   transpose_a: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]
    if transpose_a:
        a = a.T
    acc_ref[...] += jnp.dot(a, b_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(k == k_steps - 1)
    def _():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(a, b, *, transpose_a: bool = False, interpret: bool = True,
           blk_m: int = BLK, blk_n: int = BLK, blk_k: int = BLK):
    """C = A^T B if transpose_a else A B.  All dims must be tile-aligned
    (ops.py pads)."""
    if transpose_a:
        K, M = a.shape
    else:
        M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    blk_m, blk_n, blk_k = min(blk_m, M), min(blk_n, N), min(blk_k, K)
    assert M % blk_m == 0 and N % blk_n == 0 and K % blk_k == 0
    grid = (M // blk_m, N // blk_n, K // blk_k)
    if transpose_a:
        a_spec = pl.BlockSpec((blk_k, blk_m), lambda i, j, k: (k, i))
    else:
        a_spec = pl.BlockSpec((blk_m, blk_k), lambda i, j, k: (i, k))
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=grid[2],
                          transpose_a=transpose_a),
        grid=grid,
        in_specs=[a_spec,
                  pl.BlockSpec((blk_k, blk_n), lambda i, j, k: (k, j))],
        out_specs=pl.BlockSpec((blk_m, blk_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((blk_m, blk_n), jnp.float32)],
        interpret=interpret,
    )(a, b)


def _margin_kernel(s_ref, y_ref, v_ref):
    s = s_ref[...]
    y = y_ref[...]
    v_ref[...] = (-y * jax.nn.sigmoid(-y * s)).astype(v_ref.dtype)


def margin(s, y, *, interpret: bool = True):
    """s, y: (m, C) tile-aligned. v = -y*sigmoid(-y*s)."""
    M, C = s.shape
    blk_m = min(256, M)
    assert M % blk_m == 0
    spec = pl.BlockSpec((blk_m, C), lambda i: (i, 0))
    return pl.pallas_call(
        _margin_kernel,
        grid=(M // blk_m,),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(s.shape, s.dtype),
        interpret=interpret,
    )(s, y)
