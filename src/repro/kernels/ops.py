"""Public jit'd wrappers around the Pallas kernels.

Handle arbitrary shapes/dtypes: flatten to 2D, pad to (8,128) vreg /
(128,128) MXU alignment (skipping the pad-copy entirely when the buffer
is already aligned), dispatch, slice back. ``interpret`` defaults to
True off-TPU (this container is CPU-only: interpret mode executes the
kernel body in Python for validation; on TPU the same code compiles to
Mosaic).

``rho`` enters every ADMM op as a *traced array operand* — never a jit
static — so rho sweeps and heterogeneous per-worker rho_vec share one
compilation.

The two epoch-native fused ops (``admm_worker_select_update`` /
``server_prox_update``) also accept ``boundary_stub=True``, which lowers
the op as a single opaque callback custom-call instead of a Pallas
kernel. The stub is never executed for real work — it exists so
``analysis/hlo_cost.py`` can charge the fused op exactly its
operand+result HBM traffic (the same boundary model it applies to XLA
fusions) when the benchmark costs the kernel-backed epoch.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import admm_update as _admm
from . import logreg_grad as _lg
from . import prox_update as _prox
from . import ref as _ref

LANE = 128
SUBLANE = 8


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _to_2d(v, lane=LANE, sublane=SUBLANE):
    """Flatten to (R, lane) with R % sublane == 0; returns (arr2d, orig).

    When the element count is already (sublane*lane)-aligned this is a
    pure reshape — no zero-fill + scatter copy."""
    flat = v.reshape(-1)
    n = flat.shape[0]
    rows = _round_up(-(-n // lane), sublane)
    total = rows * lane
    if total == n:
        return flat.reshape(rows, lane), (v.shape, n)
    return jnp.pad(flat, (0, total - n)).reshape(rows, lane), (v.shape, n)


def _from_2d(a2d, orig):
    shape, n = orig
    return a2d.reshape(-1)[:n].reshape(shape)


def _rho_operand(rho):
    """Scalar or () / (1,) array rho -> (1, 1) f32 traced operand."""
    return jnp.asarray(rho, jnp.float32).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def admm_worker_update(g, y, z_tilde, rho,
                       interpret: Optional[bool] = None):
    """Fused eqs. (11)+(12)+(9) on arbitrarily-shaped buffers. ``rho`` is
    a traced operand (python float or 0-d array) — distinct rho values
    share one compilation."""
    interpret = _default_interpret() if interpret is None else interpret
    g2, orig = _to_2d(g)
    y2, _ = _to_2d(y)
    z2, _ = _to_2d(z_tilde)
    x2, yn2, w2 = _admm.admm_worker_update_2d(g2, y2, z2, _rho_operand(rho),
                                              interpret=interpret)
    return (_from_2d(x2, orig), _from_2d(yn2, orig), _from_2d(w2, orig))


def _prox_stub(zt, ws, rs, gamma, l1, clip):
    return np.asarray(_ref.prox_consensus_ref(
        jnp.asarray(zt), jnp.asarray(ws), jnp.asarray(rs), gamma, l1, clip))


@functools.partial(jax.jit,
                   static_argnames=("gamma", "l1", "clip", "interpret",
                                    "boundary_stub"))
def prox_consensus(z_tilde, w_sum, rho_sum, gamma: float, l1: float = 0.0,
                   clip: float = 0.0, interpret: Optional[bool] = None, *,
                   boundary_stub: bool = False):
    """Fused eq. (13). z_tilde, w_sum: (M, d); rho_sum: (M,) or (M, 1)."""
    interpret = _default_interpret() if interpret is None else interpret
    M, d = z_tilde.shape
    rho_sum = rho_sum.reshape(M, 1).astype(z_tilde.dtype)
    if boundary_stub:
        return jax.pure_callback(
            functools.partial(_prox_stub, gamma=gamma, l1=l1, clip=clip),
            jax.ShapeDtypeStruct(z_tilde.shape, z_tilde.dtype),
            z_tilde, w_sum, rho_sum)
    dp = _round_up(d, LANE)
    Mp = _round_up(M, _prox.BLK_M)
    if (Mp, dp) == (M, d):                 # aligned: no pad copies
        zt, ws, rs = z_tilde, w_sum, rho_sum
    else:
        zt = jnp.pad(z_tilde, ((0, Mp - M), (0, dp - d)))
        ws = jnp.pad(w_sum, ((0, Mp - M), (0, dp - d)))
        rs = jnp.ones((Mp, 1), z_tilde.dtype).at[:M].set(rho_sum)
    out = _prox.prox_consensus_2d(zt, ws, rs, gamma, l1, clip,
                                  interpret=interpret)
    return out[:M, :d] if (Mp, dp) != (M, d) else out


# ---------------------------------------------------------------------------
# epoch-native fused ops (the VariableSpace pallas backend)
# ---------------------------------------------------------------------------

def _blk_m(M: int) -> int:
    return M if M <= _admm.BLK_M else _admm.BLK_M


def _pad3(a, Mp: int, dp: int):
    N, M, d = a.shape
    if (Mp, dp) == (M, d):
        return a
    return jnp.pad(a, ((0, 0), (0, Mp - M), (0, dp - d)))


def _worker_stub(g, y, zt, w_old, smask, rho2, x_old):
    out = _ref.admm_worker_select_update_ref(
        jnp.asarray(g), jnp.asarray(y), jnp.asarray(zt), jnp.asarray(w_old),
        jnp.asarray(smask)[..., 0] > 0, jnp.asarray(rho2).reshape(-1),
        None if x_old is None else jnp.asarray(x_old))
    return tuple(np.asarray(o) for o in out)


@functools.partial(jax.jit,
                   static_argnames=("interpret", "boundary_stub"))
def admm_worker_select_update(g, y, z_tilde, w_old, sel, rho_vec,
                              x_old=None, *,
                              interpret: Optional[bool] = None,
                              boundary_stub: bool = False):
    """Worker side of one epoch of Algorithm 1, fused: eqs. (11)+(12)+(9)
    plus the sel-masked merge of y / w_cache [/ x] in one HBM pass.

    g, y, z_tilde, w_old [, x_old] : (N, M, dblk);
    sel     : (N, M) bool — the selected (worker, block) pairs;
    rho_vec : (N,) per-worker penalties (traced — heterogeneous rho_i).

    Returns (y', w'[, x']).
    """
    interpret = _default_interpret() if interpret is None else interpret
    N, M, d = g.shape
    smask = sel.astype(g.dtype)[..., None]
    rho2 = rho_vec.astype(jnp.float32).reshape(N, 1)
    if boundary_stub:
        shapes = [jax.ShapeDtypeStruct(g.shape, g.dtype)] * (
            2 if x_old is None else 3)
        args = (g, y, z_tilde, w_old, smask, rho2)
        if x_old is None:
            cb = lambda *a: _worker_stub(*a, x_old=None)
        else:
            cb = lambda *a: _worker_stub(*a[:-1], x_old=a[-1])
            args = args + (x_old,)
        return jax.pure_callback(cb, tuple(shapes), *args)
    bm = _blk_m(M)
    Mp, dp = _round_up(M, bm), _round_up(d, LANE)
    pads = (Mp, dp) != (M, d)
    gp, yp, zp, wp = (_pad3(a, Mp, dp) for a in (g, y, z_tilde, w_old))
    xp = None if x_old is None else _pad3(x_old, Mp, dp)
    # padded blocks carry mask 0 -> they keep the (zero) old values
    mp = _pad3(smask, Mp, 1)
    out = _admm.admm_worker_select_update_3d(gp, yp, zp, wp, mp, rho2, xp,
                                             interpret=interpret)
    if pads:
        out = tuple(o[:, :M, :d] for o in out)
    return tuple(out)


def _server_stub(z_cur, w_cache, emask, rs, gamma, l1, clip):
    return np.asarray(_ref.server_prox_update_ref(
        jnp.asarray(z_cur), jnp.asarray(w_cache),
        jnp.asarray(emask)[..., 0] > 0, jnp.asarray(rs).reshape(-1),
        gamma, l1, clip))


@functools.partial(jax.jit, static_argnames=("gamma", "l1", "clip",
                                             "interpret", "boundary_stub"))
def server_prox_update(z_cur, w_cache, edge, rho_sum, gamma: float,
                       l1: float = 0.0, clip: float = 0.0, *,
                       interpret: Optional[bool] = None,
                       boundary_stub: bool = False):
    """Server side of one epoch of Algorithm 1, fused: the edge-masked
    reduction of the stale-w cache over workers AND the prox step (13)
    in one kernel — the (M, d) w_sum intermediate never touches HBM.

    z_cur: (M, d); w_cache: (N, M, d); edge: (N, M) bool;
    rho_sum: (M,) traced per-block penalty sums. Returns z_new (M, d).
    """
    interpret = _default_interpret() if interpret is None else interpret
    N, M, d = w_cache.shape
    emask = edge.astype(z_cur.dtype)[..., None]
    rs = rho_sum.astype(jnp.float32).reshape(M, 1)
    if boundary_stub:
        return jax.pure_callback(
            functools.partial(_server_stub, gamma=gamma, l1=l1, clip=clip),
            jax.ShapeDtypeStruct(z_cur.shape, z_cur.dtype),
            z_cur, w_cache, emask, rs)
    bm = _blk_m(M)
    Mp, dp = _round_up(M, bm), _round_up(d, LANE)
    pads = (Mp, dp) != (M, d)
    if pads:
        z_cur = jnp.pad(z_cur, ((0, Mp - M), (0, dp - d)))
        # padded rho_sum rows are 1.0 so mu stays nonzero off the slice
        rs = jnp.ones((Mp, 1), jnp.float32).at[:M].set(rs)
    out = _prox.server_prox_fused_2d(
        z_cur, _pad3(w_cache, Mp, dp), _pad3(emask, Mp, 1), rs,
        gamma, l1, clip, interpret=interpret)
    return out[:M, :d] if pads else out


# ---------------------------------------------------------------------------
# matmul / logistic-regression gradient
# ---------------------------------------------------------------------------

def _pad2(a, rm, cm):
    r, c = a.shape
    rp, cp = _round_up(r, rm), _round_up(c, cm)
    if (rp, cp) == (r, c):
        return a
    return jnp.pad(a, ((0, rp - r), (0, cp - c)))


@functools.partial(jax.jit, static_argnames=("transpose_a", "interpret"))
def matmul(a, b, transpose_a: bool = False,
           interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    if transpose_a:
        K, M = a.shape
    else:
        M, K = a.shape
    N = b.shape[1]
    ap = _pad2(a, _lg.BLK, _lg.BLK)
    bp = _pad2(b, _lg.BLK, _lg.BLK)
    out = _lg.matmul(ap, bp, transpose_a=transpose_a, interpret=interpret)
    return out[:M, :N]


@functools.partial(jax.jit, static_argnames=("interpret",))
def logreg_grad(X, y, w, interpret: Optional[bool] = None):
    """Gradient of mean logistic loss: X (m, d), y (m,) in {-1,+1},
    w (d,). Composition of three kernels; X^T never materialized."""
    interpret = _default_interpret() if interpret is None else interpret
    m, d = X.shape
    Xp = _pad2(X, _lg.BLK, _lg.BLK)
    mp, dp = Xp.shape
    wp = jnp.zeros((dp, LANE), X.dtype).at[:d, 0].set(w)
    s = _lg.matmul(Xp, wp, interpret=interpret)            # (mp, 128)
    yp = jnp.zeros((mp, LANE), X.dtype).at[:m, 0].set(y)
    mask = jnp.zeros((mp, LANE), X.dtype).at[:m, 0].set(1.0)
    v = _lg.margin(s, yp, interpret=interpret) * mask      # zero padded rows
    g = _lg.matmul(Xp, v, transpose_a=True, interpret=interpret)  # (dp, 128)
    return g[:d, 0] / m
