"""Public jit'd wrappers around the Pallas kernels.

Handle arbitrary shapes/dtypes: flatten to 2D, pad to (8,128) vreg /
(128,128) MXU alignment, dispatch, slice back. ``interpret`` defaults to
True off-TPU (this container is CPU-only: interpret mode executes the
kernel body in Python for validation; on TPU the same code compiles to
Mosaic).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import admm_update as _admm
from . import logreg_grad as _lg
from . import prox_update as _prox

LANE = 128


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _to_2d(v, lane=LANE, sublane=8):
    """Flatten to (R, lane) with R % sublane == 0; returns (arr2d, orig)."""
    flat = v.reshape(-1)
    n = flat.shape[0]
    row = lane
    rows = -(-n // row)
    rows = -(-rows // sublane) * sublane
    padded = jnp.zeros((rows * row,), v.dtype).at[:n].set(flat)
    return padded.reshape(rows, row), (v.shape, n)


def _from_2d(a2d, orig):
    shape, n = orig
    return a2d.reshape(-1)[:n].reshape(shape)


@functools.partial(jax.jit, static_argnames=("rho", "interpret"))
def admm_worker_update(g, y, z_tilde, rho: float,
                       interpret: Optional[bool] = None):
    """Fused eqs. (11)+(12)+(9) on arbitrarily-shaped buffers."""
    interpret = _default_interpret() if interpret is None else interpret
    g2, orig = _to_2d(g)
    y2, _ = _to_2d(y)
    z2, _ = _to_2d(z_tilde)
    x2, yn2, w2 = _admm.admm_worker_update_2d(g2, y2, z2, rho,
                                              interpret=interpret)
    return (_from_2d(x2, orig), _from_2d(yn2, orig), _from_2d(w2, orig))


@functools.partial(jax.jit,
                   static_argnames=("gamma", "l1", "clip", "interpret"))
def prox_consensus(z_tilde, w_sum, rho_sum, gamma: float, l1: float = 0.0,
                   clip: float = 0.0, interpret: Optional[bool] = None):
    """Fused eq. (13). z_tilde, w_sum: (M, d); rho_sum: (M,) or (M, 1)."""
    interpret = _default_interpret() if interpret is None else interpret
    M, d = z_tilde.shape
    rho_sum = rho_sum.reshape(M, 1).astype(z_tilde.dtype)
    dp = -(-d // LANE) * LANE
    Mp = -(-M // _prox.BLK_M) * _prox.BLK_M
    zt = jnp.zeros((Mp, dp), z_tilde.dtype).at[:M, :d].set(z_tilde)
    ws = jnp.zeros((Mp, dp), w_sum.dtype).at[:M, :d].set(w_sum)
    rs = jnp.ones((Mp, 1), z_tilde.dtype).at[:M].set(rho_sum)
    out = _prox.prox_consensus_2d(zt, ws, rs, gamma, l1, clip,
                                  interpret=interpret)
    return out[:M, :d]


def _pad2(a, rm, cm):
    r, c = a.shape
    rp, cp = -(-r // rm) * rm, -(-c // cm) * cm
    if (rp, cp) == (r, c):
        return a
    return jnp.zeros((rp, cp), a.dtype).at[:r, :c].set(a)


@functools.partial(jax.jit, static_argnames=("transpose_a", "interpret"))
def matmul(a, b, transpose_a: bool = False,
           interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    if transpose_a:
        K, M = a.shape
    else:
        M, K = a.shape
    N = b.shape[1]
    ap = _pad2(a, _lg.BLK, _lg.BLK)
    bp = _pad2(b, _lg.BLK, _lg.BLK)
    out = _lg.matmul(ap, bp, transpose_a=transpose_a, interpret=interpret)
    return out[:M, :N]


@functools.partial(jax.jit, static_argnames=("interpret",))
def logreg_grad(X, y, w, interpret: Optional[bool] = None):
    """Gradient of mean logistic loss: X (m, d), y (m,) in {-1,+1},
    w (d,). Composition of three kernels; X^T never materialized."""
    interpret = _default_interpret() if interpret is None else interpret
    m, d = X.shape
    Xp = _pad2(X, _lg.BLK, _lg.BLK)
    mp, dp = Xp.shape
    wp = jnp.zeros((dp, LANE), X.dtype).at[:d, 0].set(w)
    s = _lg.matmul(Xp, wp, interpret=interpret)            # (mp, 128)
    yp = jnp.zeros((mp, LANE), X.dtype).at[:m, 0].set(y)
    mask = jnp.zeros((mp, LANE), X.dtype).at[:m, 0].set(1.0)
    v = _lg.margin(s, yp, interpret=interpret) * mask      # zero padded rows
    g = _lg.matmul(Xp, v, transpose_a=True, interpret=interpret)  # (dp, 128)
    return g[:d, 0] / m
