"""Public jit'd wrappers around the Pallas kernels.

Lane alignment is a property of the layout, not a per-call pad: since
the lane-aligned packed refactor, ``core/blocks.py`` rounds every block
row up to the 128-lane boundary at layout-build time, so these wrappers
*require* aligned inputs and always take the no-copy fast path (the old
pad-copy branches burned an extra HBM round trip per epoch on ragged
layouts). Unaligned buffers raise an actionable error pointing at the
layout constructors. The MXU ops (``matmul`` / ``logreg_grad``) still
pad internally — data matrices are not layout-controlled. ``interpret``
defaults to True off-TPU (this container is CPU-only: interpret mode
executes the kernel body in Python for validation; on TPU the same code
compiles to Mosaic).

Tile shapes (``blk_m``, ``blk_d``) default to the static heuristics in
``admm_update.py`` / ``prox_update.py``; the fused epoch ops accept a
static ``tile=(blk_m, blk_d)`` override, which ``core/space.py`` feeds
from the per-device autotuner table (``kernels/autotune.py``) when
``ADMMConfig(autotune=)`` is "cached" or "sweep".

``rho`` enters every ADMM op as a *traced array operand* — never a jit
static — so rho sweeps and heterogeneous per-worker rho_vec share one
compilation.

The two epoch-native fused ops (``admm_worker_select_update`` /
``server_prox_update``) also accept ``boundary_stub=True``, which lowers
the op as a single opaque callback custom-call instead of a Pallas
kernel. The stub is never executed for real work — it exists so
``analysis/hlo_cost.py`` can charge the fused op exactly its
operand+result HBM traffic (the same boundary model it applies to XLA
fusions) when the benchmark costs the kernel-backed epoch.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import admm_update as _admm
from . import logreg_grad as _lg
from . import prox_update as _prox
from . import ref as _ref

LANE = 128
SUBLANE = 8


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _round_up(n: int, m: int) -> int:
    return -(-n // m) * m


def _to_2d(v, lane=LANE, sublane=SUBLANE):
    """Flatten an (sublane*lane)-aligned buffer to (R, lane), R % sublane
    == 0 — a pure reshape, never a zero-fill + scatter copy. Raises for
    unaligned element counts: lane alignment is the layout's job."""
    flat = v.reshape(-1)
    n = flat.shape[0]
    if n % (sublane * lane) != 0:
        raise ValueError(
            f"buffer of {n} elements (shape {v.shape}) is not "
            f"({sublane}x{lane})-vreg aligned; kernel ops require "
            f"lane-aligned buffers. Pack through a lane-aligned layout "
            f"(core.blocks.make_flat_blocks / make_block_layout round "
            f"block_dim up to {lane}) instead of passing raw leaves.")
    return flat.reshape(n // lane, lane), (v.shape, n)


def _from_2d(a2d, orig):
    shape, n = orig
    return a2d.reshape(-1)[:n].reshape(shape)


def _rho_operand(rho):
    """Scalar or () / (1,) array rho -> (1, 1) f32 traced operand."""
    return jnp.asarray(rho, jnp.float32).reshape(1, 1)


@functools.partial(jax.jit, static_argnames=("interpret",))
def admm_worker_update(g, y, z_tilde, rho,
                       interpret: Optional[bool] = None):
    """Fused eqs. (11)+(12)+(9) on arbitrarily-shaped buffers. ``rho`` is
    a traced operand (python float or 0-d array) — distinct rho values
    share one compilation."""
    interpret = _default_interpret() if interpret is None else interpret
    g2, orig = _to_2d(g)
    y2, _ = _to_2d(y)
    z2, _ = _to_2d(z_tilde)
    x2, yn2, w2 = _admm.admm_worker_update_2d(g2, y2, z2, _rho_operand(rho),
                                              interpret=interpret)
    return (_from_2d(x2, orig), _from_2d(yn2, orig), _from_2d(w2, orig))


def _prox_stub(zt, ws, rs, gamma, l1, clip):
    return np.asarray(_ref.prox_consensus_ref(
        jnp.asarray(zt), jnp.asarray(ws), jnp.asarray(rs), gamma, l1, clip))


@functools.partial(jax.jit,
                   static_argnames=("gamma", "l1", "clip", "interpret",
                                    "boundary_stub", "tile"))
def prox_consensus(z_tilde, w_sum, rho_sum, gamma: float, l1: float = 0.0,
                   clip: float = 0.0, interpret: Optional[bool] = None, *,
                   boundary_stub: bool = False,
                   tile: Optional[Tuple[int, int]] = None):
    """Fused eq. (13). z_tilde, w_sum: (M, d) lane-aligned; rho_sum: (M,)
    or (M, 1). ``tile=(blk_m, blk_d)`` statically overrides the grid."""
    interpret = _default_interpret() if interpret is None else interpret
    M, d = z_tilde.shape
    rho_sum = rho_sum.reshape(M, 1).astype(z_tilde.dtype)
    if boundary_stub:
        return jax.pure_callback(
            functools.partial(_prox_stub, gamma=gamma, l1=l1, clip=clip),
            jax.ShapeDtypeStruct(z_tilde.shape, z_tilde.dtype),
            z_tilde, w_sum, rho_sum)
    _require_lane_aligned(d, "prox_consensus")
    bm, bd = tile if tile is not None else (None, None)
    return _prox.prox_consensus_2d(z_tilde, w_sum, rho_sum, gamma, l1, clip,
                                   interpret=interpret, blk_m=bm, blk_d=bd)


# ---------------------------------------------------------------------------
# epoch-native fused ops (the VariableSpace pallas backend)
# ---------------------------------------------------------------------------

def _require_lane_aligned(d: int, op: str) -> None:
    if d % LANE != 0:
        raise ValueError(
            f"{op}: block row width d={d} is not a multiple of {LANE}; "
            f"lane alignment is a property of the layout — build blocks "
            f"via core.blocks.make_flat_blocks / make_block_layout (which "
            f"round block_dim up to {LANE}) rather than padding per call.")


def _worker_stub(g, y, zt, w_old, smask, rho2, x_old):
    out = _ref.admm_worker_select_update_ref(
        jnp.asarray(g), jnp.asarray(y), jnp.asarray(zt), jnp.asarray(w_old),
        jnp.asarray(smask)[..., 0] > 0, jnp.asarray(rho2).reshape(-1),
        None if x_old is None else jnp.asarray(x_old))
    return tuple(np.asarray(o) for o in out)


@functools.partial(jax.jit,
                   static_argnames=("interpret", "boundary_stub", "tile"))
def admm_worker_select_update(g, y, z_tilde, w_old, sel, rho_vec,
                              x_old=None, *,
                              interpret: Optional[bool] = None,
                              boundary_stub: bool = False,
                              tile: Optional[Tuple[int, int]] = None):
    """Worker side of one epoch of Algorithm 1, fused: eqs. (11)+(12)+(9)
    plus the sel-masked merge of y / w_cache [/ x] in one HBM pass.

    g, y, z_tilde, w_old [, x_old] : (N, M, dblk) with dblk lane-aligned;
    sel     : (N, M) bool — the selected (worker, block) pairs;
    rho_vec : (N,) per-worker penalties (traced — heterogeneous rho_i);
    tile    : static (blk_m, blk_d) grid override (autotuner winners).

    Returns (y', w'[, x']).
    """
    interpret = _default_interpret() if interpret is None else interpret
    N, M, d = g.shape
    smask = sel.astype(g.dtype)[..., None]
    rho2 = rho_vec.astype(jnp.float32).reshape(N, 1)
    if boundary_stub:
        shapes = [jax.ShapeDtypeStruct(g.shape, g.dtype)] * (
            2 if x_old is None else 3)
        args = (g, y, z_tilde, w_old, smask, rho2)
        if x_old is None:
            cb = lambda *a: _worker_stub(*a, x_old=None)
        else:
            cb = lambda *a: _worker_stub(*a[:-1], x_old=a[-1])
            args = args + (x_old,)
        return jax.pure_callback(cb, tuple(shapes), *args)
    _require_lane_aligned(d, "admm_worker_select_update")
    bm, bd = tile if tile is not None else (None, None)
    out = _admm.admm_worker_select_update_3d(g, y, z_tilde, w_old, smask,
                                             rho2, x_old,
                                             interpret=interpret,
                                             blk_m=bm, blk_d=bd)
    return tuple(out)


def _server_stub(z_cur, w_cache, emask, rs, gamma, l1, clip):
    return np.asarray(_ref.server_prox_update_ref(
        jnp.asarray(z_cur), jnp.asarray(w_cache),
        jnp.asarray(emask)[..., 0] > 0, jnp.asarray(rs).reshape(-1),
        gamma, l1, clip))


@functools.partial(jax.jit, static_argnames=("gamma", "l1", "clip",
                                             "interpret", "boundary_stub",
                                             "tile"))
def server_prox_update(z_cur, w_cache, edge, rho_sum, gamma: float,
                       l1: float = 0.0, clip: float = 0.0, *,
                       interpret: Optional[bool] = None,
                       boundary_stub: bool = False,
                       tile: Optional[Tuple[int, int]] = None):
    """Server side of one epoch of Algorithm 1, fused: the edge-masked
    reduction of the stale-w cache over workers AND the prox step (13)
    in one kernel — the (M, d) w_sum intermediate never touches HBM.

    z_cur: (M, d) lane-aligned; w_cache: (N, M, d); edge: (N, M) bool;
    rho_sum: (M,) traced per-block penalty sums; ``tile=(blk_m, blk_d)``
    statically overrides the grid. Returns z_new (M, d).
    """
    interpret = _default_interpret() if interpret is None else interpret
    N, M, d = w_cache.shape
    emask = edge.astype(z_cur.dtype)[..., None]
    rs = rho_sum.astype(jnp.float32).reshape(M, 1)
    if boundary_stub:
        return jax.pure_callback(
            functools.partial(_server_stub, gamma=gamma, l1=l1, clip=clip),
            jax.ShapeDtypeStruct(z_cur.shape, z_cur.dtype),
            z_cur, w_cache, emask, rs)
    _require_lane_aligned(d, "server_prox_update")
    bm, bd = tile if tile is not None else (None, None)
    return _prox.server_prox_fused_2d(z_cur, w_cache, emask, rs,
                                      gamma, l1, clip, interpret=interpret,
                                      blk_m=bm, blk_d=bd)


# ---------------------------------------------------------------------------
# matmul / logistic-regression gradient
# ---------------------------------------------------------------------------

def _pad2(a, rm, cm):
    r, c = a.shape
    rp, cp = _round_up(r, rm), _round_up(c, cm)
    if (rp, cp) == (r, c):
        return a
    return jnp.pad(a, ((0, rp - r), (0, cp - c)))


@functools.partial(jax.jit, static_argnames=("transpose_a", "interpret"))
def matmul(a, b, transpose_a: bool = False,
           interpret: Optional[bool] = None):
    interpret = _default_interpret() if interpret is None else interpret
    if transpose_a:
        K, M = a.shape
    else:
        M, K = a.shape
    N = b.shape[1]
    ap = _pad2(a, _lg.BLK, _lg.BLK)
    bp = _pad2(b, _lg.BLK, _lg.BLK)
    out = _lg.matmul(ap, bp, transpose_a=transpose_a, interpret=interpret)
    return out[:M, :N]


@functools.partial(jax.jit, static_argnames=("interpret",))
def logreg_grad(X, y, w, interpret: Optional[bool] = None):
    """Gradient of mean logistic loss: X (m, d), y (m,) in {-1,+1},
    w (d,). Composition of three kernels; X^T never materialized."""
    interpret = _default_interpret() if interpret is None else interpret
    m, d = X.shape
    Xp = _pad2(X, _lg.BLK, _lg.BLK)
    mp, dp = Xp.shape
    wp = jnp.zeros((dp, LANE), X.dtype).at[:d, 0].set(w)
    s = _lg.matmul(Xp, wp, interpret=interpret)            # (mp, 128)
    yp = jnp.zeros((mp, LANE), X.dtype).at[:m, 0].set(y)
    mask = jnp.zeros((mp, LANE), X.dtype).at[:m, 0].set(1.0)
    v = _lg.margin(s, yp, interpret=interpret) * mask      # zero padded rows
    g = _lg.matmul(Xp, v, transpose_a=True, interpret=interpret)  # (dp, 128)
    return g[:d, 0] / m
