"""Deterministic per-device tile autotuner for the fused ADMM kernels.

Stop hand-picking tile shapes: sweep the (blk_m, blk_d) grid/VMEM-
accumulator candidates for the two epoch-native fused ops —
``admm_worker_select_update_3d`` (op key ``worker_select_update``) and
``server_prox_fused_2d`` (op key ``server_prox_fused``) — score each
candidate, and persist the winner keyed by
``(device_kind, op, N, M, dblk, dtype)``.

Scoring is measured, not claimed, in both regimes:

* **real devices** (``jax.default_backend() == "tpu"``): median
  wall-clock of the jitted kernel with that tile (seeded inputs, warmup
  excluded);
* **interpret / CI** (CPU containers): a deterministic proxy built on
  the same accounting ``analysis/hlo_cost.py`` established — HBM
  operand+result bytes at the kernel boundary (tile-invariant) plus a
  per-grid-step overhead term, with a VMEM-residency feasibility cap.
  The proxy is pure arithmetic on static shapes: same inputs, same
  winner, on every machine.

Winners are persisted to ``benchmarks/kernels_tuned.json`` (an in-repo
default table, generated under the proxy for the benchmark shapes,
ships with the repo; ``REPRO_KERNELS_TUNED`` overrides the path).
Tile choice never reorders accumulation — the fused prox reduces over
the worker grid axis in the same order for every (blk_m, blk_d) — so
tuned tiles are bitwise-equivalent to the heuristics; the ``--smoke``
CLI pins that plus table validity, and ``scripts/ci.sh`` runs it.

The knob: ``ADMMConfig(autotune="off" | "cached" | "sweep")``, threaded
through ``make_spec`` / ``ConsensusSession`` / ``launch.train
--autotune``. "off" uses the static heuristics in ``admm_update.py`` /
``prox_update.py``; "cached" consults this table (heuristic fallback on
a miss); "sweep" measures the session's shapes up front, persists the
winners, then behaves like "cached".
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import pathlib
import time
from typing import Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import admm_update as _admm
from . import prox_update as _prox

LANE = 128
OPS = ("worker_select_update", "server_prox_fused")
MODES = ("off", "cached", "sweep")

#: VMEM residency budget per grid step (bytes). Cores have ~16 MiB; the
#: sweep keeps double-buffered operand+result tiles under half of it.
VMEM_BUDGET = 8 * 1024 * 1024
#: f32 tiles resident per grid step (operands + results), per op.
_TILES_PER_STEP = {"worker_select_update": 8, "server_prox_fused": 4}
#: proxy constants: HBM bandwidth and per-grid-step launch overhead.
_HBM_BYTES_PER_US = 1.2e6
_STEP_OVERHEAD_US = 1.0

_TABLE_ENV = "REPRO_KERNELS_TUNED"
_SCHEMA = ("entries: {device_kind|op|N<N>|M<M>|d<dblk>|<dtype>: "
           "{blk_m, blk_d, score_us, method}}")


@dataclasses.dataclass(frozen=True)
class TileConfig:
    blk_m: int
    blk_d: int
    score_us: float
    method: str                     # "wallclock" | "proxy"


def default_table_path() -> pathlib.Path:
    env = os.environ.get(_TABLE_ENV)
    if env:
        return pathlib.Path(env)
    return (pathlib.Path(__file__).resolve().parents[3]
            / "benchmarks" / "kernels_tuned.json")


def device_kind() -> str:
    """Normalized device kind of the default backend ("cpu" in interpret
    containers, e.g. "TPU_v4" on hardware)."""
    try:
        kind = jax.devices()[0].device_kind
    except Exception:
        kind = "cpu"
    return str(kind).strip().replace(" ", "_")


def table_key(dev: str, op: str, N: int, M: int, d: int,
              dtype: str = "float32") -> str:
    return f"{dev}|{op}|N{N}|M{M}|d{d}|{dtype}"


# ---------------------------------------------------------------------------
# table persistence (module-level cache; session sweeps merge into it)
# ---------------------------------------------------------------------------

_table_cache: Optional[Dict[str, dict]] = None


def load_table(path: Optional[pathlib.Path] = None,
               refresh: bool = False) -> Dict[str, dict]:
    global _table_cache
    if _table_cache is not None and not refresh and path is None:
        return _table_cache
    p = path or default_table_path()
    entries: Dict[str, dict] = {}
    try:
        with open(p) as f:
            entries = dict(json.load(f).get("entries", {}))
    except (OSError, ValueError):
        entries = {}
    if path is None:
        _table_cache = entries
    return entries


def save_table(entries: Dict[str, dict],
               path: Optional[pathlib.Path] = None) -> bool:
    """Merge ``entries`` into the persisted table (best effort — a
    read-only checkout degrades to the in-memory cache)."""
    global _table_cache
    merged = dict(load_table(path))
    merged.update(entries)
    if path is None:
        _table_cache = merged
    p = path or default_table_path()
    try:
        p.parent.mkdir(parents=True, exist_ok=True)
        with open(p, "w") as f:
            json.dump({"_schema": _SCHEMA,
                       "entries": {k: merged[k] for k in sorted(merged)}},
                      f, indent=2, sort_keys=False)
            f.write("\n")
        return True
    except OSError:
        return False


def lookup(op: str, N: int, M: int, d: int, dtype: str = "float32",
           dev: Optional[str] = None) -> Optional[TileConfig]:
    """Cached winner for this exact (device, op, shape) key, or None."""
    entry = load_table().get(
        table_key(dev or device_kind(), op, N, M, d, dtype))
    if not entry:
        return None
    return TileConfig(blk_m=int(entry["blk_m"]), blk_d=int(entry["blk_d"]),
                      score_us=float(entry.get("score_us", 0.0)),
                      method=str(entry.get("method", "proxy")))


def lookup_tile(op: str, N: int, M: int, d: int,
                dtype: str = "float32") -> Optional[Tuple[int, int]]:
    """(blk_m, blk_d) for kernel dispatch, validated against the
    divisibility rules; None on a miss (heuristics apply)."""
    cfg = lookup(op, N, M, d, dtype)
    if cfg is None:
        return None
    if M % cfg.blk_m or d % cfg.blk_d or cfg.blk_d % LANE:
        return None                       # stale entry for another shape
    return cfg.blk_m, cfg.blk_d


# ---------------------------------------------------------------------------
# candidate enumeration + scoring
# ---------------------------------------------------------------------------

def tile_candidates(op: str, N: int, M: int, d: int) -> List[Tuple[int, int]]:
    """Feasible (blk_m, blk_d) grid tiles: blk_m a divisor of M (the M
    grid is never padded — block-id contract), blk_d a lane multiple
    dividing d, double-buffered VMEM residency under budget."""
    if d % LANE != 0:
        raise ValueError(f"autotune sweep requires lane-aligned d "
                         f"(d % {LANE} == 0), got d={d}")
    blk_ms = [bm for bm in (1, 2, 4, 8, 16) if bm <= M and M % bm == 0]
    blk_ds = [bd for bd in (LANE, 256, 512, 1024, 2048, 4096, 8192)
              if bd <= d and d % bd == 0]
    if d <= 8192 and d not in blk_ds:
        blk_ds.append(d)
    tiles_per_step = _TILES_PER_STEP[op]
    out = []
    for bm in blk_ms:
        for bd in blk_ds:
            resident = 2 * tiles_per_step * bm * bd * 4   # double-buffered f32
            if resident <= VMEM_BUDGET:
                out.append((bm, bd))
    if not out:
        raise ValueError(f"no feasible tile for {op} at N={N} M={M} d={d}")
    return out


def _op_bytes(op: str, N: int, M: int, d: int) -> int:
    """HBM boundary bytes of the fused op (f32), tile-invariant — the
    same operand+result accounting analysis/hlo_cost.py charges."""
    if op == "worker_select_update":
        # in: rho, sel, g, y, z~, w_old; out: y', w'
        return (4 * N * M * d + 2 * N * M * d + N * M + N) * 4
    # server_prox_fused — in: z, rho_sum, edge, w_cache; out: z'
    return (N * M * d + 2 * M * d + N * M + M) * 4


def _grid_steps(op: str, N: int, M: int, d: int, bm: int, bd: int) -> int:
    return N * (M // bm) * (d // bd)


def proxy_score_us(op: str, N: int, M: int, d: int,
                   bm: int, bd: int) -> float:
    """Deterministic off-device score: bandwidth floor + grid overhead."""
    return (_op_bytes(op, N, M, d) / _HBM_BYTES_PER_US
            + _grid_steps(op, N, M, d, bm, bd) * _STEP_OVERHEAD_US)


def _op_inputs(op: str, N: int, M: int, d: int):
    key = jax.random.PRNGKey(0)
    t = lambda i: jax.random.normal(jax.random.fold_in(key, i), (N, M, d),
                                    jnp.float32)
    if op == "worker_select_update":
        return (t(0), t(1), t(2), t(3),
                jnp.ones((N, M, 1), jnp.float32),
                jnp.full((N, 1), 2.0, jnp.float32))
    return (t(0)[0], t(1), jnp.ones((N, M, 1), jnp.float32),
            jnp.full((M, 1), 6.0, jnp.float32))


def run_op(op: str, args, bm: int, bd: int, *, interpret: bool):
    if op == "worker_select_update":
        g, y, zt, w, sel, rho = args
        return _admm.admm_worker_select_update_3d(
            g, y, zt, w, sel, rho, interpret=interpret, blk_m=bm, blk_d=bd)
    z, w, e, rs = args
    return _prox.server_prox_fused_2d(z, w, e, rs, 0.01, 0.001, 1.0,
                                      interpret=interpret, blk_m=bm, blk_d=bd)


def wallclock_score_us(op: str, N: int, M: int, d: int,
                       bm: int, bd: int, reps: int = 5) -> float:
    """Median wall-clock of the jitted kernel on the real device."""
    args = _op_inputs(op, N, M, d)
    fn = jax.jit(lambda *a: run_op(op, a, bm, bd, interpret=False))
    jax.block_until_ready(fn(*args))                      # compile + warmup
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def sweep_op(op: str, N: int, M: int, d: int, dtype: str = "float32",
             measure: Optional[str] = None) -> TileConfig:
    """Sweep all feasible tiles for one op/shape; deterministic winner
    (score, then larger blk_d, then larger blk_m breaks ties)."""
    if measure is None:
        measure = ("wallclock" if jax.default_backend() == "tpu"
                   else "proxy")
    best = None
    for bm, bd in tile_candidates(op, N, M, d):
        if measure == "wallclock":
            score = wallclock_score_us(op, N, M, d, bm, bd)
        else:
            score = proxy_score_us(op, N, M, d, bm, bd)
        cand = (score, -bd, -bm, TileConfig(bm, bd, score, measure))
        if best is None or cand[:3] < best[:3]:
            best = cand
    return best[3]


def sweep_shapes(shapes: Iterable[Tuple[int, int, int]],
                 dtype: str = "float32", measure: Optional[str] = None,
                 persist: bool = True) -> Dict[str, dict]:
    """Sweep both fused ops over (N, M, dblk) shapes; merge winners into
    the cached table (and the JSON file when ``persist``)."""
    dev = device_kind()
    entries: Dict[str, dict] = {}
    for (N, M, d) in shapes:
        for op in OPS:
            cfg = sweep_op(op, N, M, d, dtype, measure=measure)
            entries[table_key(dev, op, N, M, d, dtype)] = {
                "blk_m": cfg.blk_m, "blk_d": cfg.blk_d,
                "score_us": round(cfg.score_us, 3), "method": cfg.method}
    if persist:
        save_table(entries)
    else:
        load_table().update(entries)
    return entries


def sweep_for_space(N: int, M: int, d: int, mesh=None,
                    dtype: str = "float32", persist: bool = True) -> None:
    """Eager sweep at spec-build time (never during a trace): the full
    epoch shape plus, under a mesh, the local (N/data, M/model) shard
    shape the kernels actually see."""
    shapes = [(N, M, d)]
    if mesh is not None:
        dsz = int(mesh.shape.get("data", 1))
        msz = int(mesh.shape.get("model", 1))
        if N % max(dsz, 1) == 0 and M % max(msz, 1) == 0:
            local = (max(N // max(dsz, 1), 1), max(M // max(msz, 1), 1), d)
            if local != shapes[0]:
                shapes.append(local)
    sweep_shapes(shapes, dtype=dtype, persist=persist)


def resolve_autotune(mode: Optional[str]) -> str:
    mode = "off" if mode in (None, "") else str(mode)
    if mode not in MODES:
        raise ValueError(f"unknown autotune mode {mode!r}; "
                         f"expected one of {MODES}")
    return mode


# ---------------------------------------------------------------------------
# CLI: --smoke validates the cached table; --sweep regenerates entries
# ---------------------------------------------------------------------------

def _smoke(shapes: List[Tuple[int, int, int]]) -> int:
    """Cached-mode smoke for CI (interpret backend): every cached entry
    is shape-valid and VMEM-feasible, the proxy sweep reproduces the
    committed winners for this device kind, and tuned tiles are
    bitwise-identical to the heuristic tiles on a small case."""
    dev = device_kind()
    entries = load_table(refresh=True)
    checked = 0
    for key, e in entries.items():
        parts = key.split("|")
        if len(parts) != 6:
            raise SystemExit(f"[autotune] malformed key {key!r}")
        kdev, op = parts[0], parts[1]
        N, M, d = (int(parts[i][1:]) for i in (2, 3, 4))
        bm, bd = int(e["blk_m"]), int(e["blk_d"])
        if op not in OPS:
            raise SystemExit(f"[autotune] unknown op in key {key!r}")
        if M % bm or d % bd or bd % LANE:
            raise SystemExit(f"[autotune] invalid tile {bm}x{bd} for {key}")
        if 2 * _TILES_PER_STEP[op] * bm * bd * 4 > VMEM_BUDGET:
            raise SystemExit(f"[autotune] tile {bm}x{bd} over VMEM budget "
                             f"for {key}")
        if kdev == dev and e.get("method") == "proxy":
            want = sweep_op(op, N, M, d, measure="proxy")
            if (want.blk_m, want.blk_d) != (bm, bd):
                raise SystemExit(
                    f"[autotune] stale winner for {key}: table {bm}x{bd} "
                    f"vs proxy sweep {want.blk_m}x{want.blk_d} — rerun "
                    f"--sweep")
        checked += 1
    # tuned-vs-heuristic bitwise parity on a small interpret case
    N, M, d = 2, 3, 256
    for op in OPS:
        args = _op_inputs(op, N, M, d)
        base = run_op(op, args, None, None, interpret=True)
        for bm, bd in tile_candidates(op, N, M, d):
            out = run_op(op, args, bm, bd, interpret=True)
            for a, b in zip(jax.tree.leaves(base), jax.tree.leaves(out)):
                if not bool(jnp.all(a == b)):
                    raise SystemExit(f"[autotune] tile {bm}x{bd} changed "
                                     f"{op} output — tiling must be inert")
    # cached lookups for the benchmark shapes resolve (the in-repo table)
    misses = [s for s in shapes
              if lookup_tile("worker_select_update", *s) is None]
    if misses and dev == "cpu":
        raise SystemExit(f"[autotune] in-repo default table misses cpu "
                         f"entries for {misses} — rerun --sweep")
    print(f"[autotune] smoke ok: {checked} cached entries valid, tiling "
          f"bitwise-inert, defaults cover {len(shapes) - len(misses)}/"
          f"{len(shapes)} bench shapes on {dev}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="validate the cached table (CI, interpret mode)")
    ap.add_argument("--sweep", action="store_true",
                    help="sweep the benchmark shapes and persist winners")
    ap.add_argument("--shape", action="append", default=[],
                    metavar="N,M,DBLK",
                    help="extra shape(s) to sweep/validate")
    args = ap.parse_args(argv)
    # the kernels_bench.py case shapes — the in-repo defaults cover these
    shapes = [(4, 8, 256), (8, 64, 315904)]
    for s in args.shape:
        N, M, d = (int(x) for x in s.split(","))
        shapes.append((N, M, d))
    if args.sweep:
        entries = sweep_shapes(shapes)
        for k in sorted(entries):
            e = entries[k]
            print(f"[autotune] {k}: blk_m={e['blk_m']} blk_d={e['blk_d']} "
                  f"({e['method']} {e['score_us']}us)")
        return 0
    return _smoke(shapes)


if __name__ == "__main__":
    raise SystemExit(main())
