"""Pallas TPU kernels: fused AsyBADMM worker update — eqs. (11)+(12)+(9).

The worker update is the per-step hot loop of the paper: three
elementwise expressions over gradient-sized buffers. Unfused, XLA
materializes x and y' between HBM round-trips; fused, each (g, y, z~)
tile is read once from HBM into VMEM and all three outputs (x, y', w)
are produced in-register — the op becomes strictly HBM-bandwidth-bound
at its arithmetic-intensity floor.

Two entry points:

* ``admm_worker_update_2d`` — the original (R, 128) 2D form used by the
  per-leaf wrappers. ``rho`` is a (1, 1) *traced operand* (not a static
  jit argument), so sweeping rho never recompiles.
* ``admm_worker_select_update_3d`` — the epoch-native batched form: a
  (N, M, dblk) grid that additionally fuses Algorithm 1's sel-masked
  select writes for y / w_cache / x. One pass over the worker bundles
  instead of four (update + three ``jnp.where`` merges), with a
  per-worker rho column (N, 1) so heterogeneous rho_i (the paper's
  general form) is native.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK_R = 256
BLK_M = 8
LANE = 128


def pick_blk_m(M: int, tuned: Optional[int] = None) -> int:
    """Sublane grid tile: the largest divisor of M that is <= BLK_M (the
    M grid is never padded — block j is row j everywhere, the block-id
    contract — so M=1 PS commits and odd model-shard sizes tile at a
    smaller divisor). A cached autotuner winner ``tuned`` is used
    verbatim when it divides M."""
    if tuned is not None and 0 < tuned <= M and M % tuned == 0:
        return tuned
    bm = min(M, BLK_M)
    while M % bm:
        bm -= 1
    return bm


# ---------------------------------------------------------------------------
# 2D form (per-leaf wrappers)
# ---------------------------------------------------------------------------

def _kernel_2d(rho_ref, g_ref, y_ref, zt_ref, x_ref, ynew_ref, w_ref):
    g = g_ref[...]
    y = y_ref[...]
    zt = zt_ref[...]
    rho = rho_ref[0, 0]
    x = zt - (g + y) / rho
    y_new = -g                      # identity (25): y' = y + rho(x - z~) = -g
    w = rho * x + y_new
    x_ref[...] = x.astype(x_ref.dtype)
    ynew_ref[...] = y_new.astype(ynew_ref.dtype)
    w_ref[...] = w.astype(w_ref.dtype)


def admm_worker_update_2d(g, y, z_tilde, rho, *, interpret: bool = True):
    """g, y, z_tilde: (R, 128)-aligned 2D arrays; rho: (1, 1) array —
    a traced operand, NOT a compile-time constant. Returns (x, y_new, w)."""
    R, C = g.shape
    assert C % LANE == 0 and R % 8 == 0, (R, C)
    blk_r = min(BLK_R, R)
    grid = (R // blk_r,)
    spec = pl.BlockSpec((blk_r, C), lambda i: (i, 0))
    rho_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    out_shape = [jax.ShapeDtypeStruct(g.shape, g.dtype)] * 3
    return pl.pallas_call(
        _kernel_2d,
        grid=grid,
        in_specs=[rho_spec, spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=out_shape,
        interpret=interpret,
    )(rho, g, y, z_tilde)


# ---------------------------------------------------------------------------
# batched (N, M, dblk) form with fused select writes
# ---------------------------------------------------------------------------

def _kernel_3d(rho_ref, m_ref, g_ref, y_ref, zt_ref, w_ref, *refs,
               with_x: bool):
    if with_x:
        x_ref, yo_ref, wo_ref, xo_ref = refs
    else:
        yo_ref, wo_ref = refs
    rho = rho_ref[0, 0]
    keep = m_ref[0] > 0.0                     # (blk_m, 1) — broadcasts
    g = g_ref[0]
    y = y_ref[0]
    zt = zt_ref[0]
    x = zt - (g + y) / rho
    y_new = -g
    w = rho * x + y_new
    yo_ref[0] = jnp.where(keep, y_new, y).astype(yo_ref.dtype)
    wo_ref[0] = jnp.where(keep, w, w_ref[0]).astype(wo_ref.dtype)
    if with_x:
        xo_ref[0] = jnp.where(keep, x, x_ref[0]).astype(xo_ref.dtype)


def _pick_lane_tile(d: int, tuned: Optional[int] = None) -> int:
    """Lane grid tile: the largest lane-multiple <= 2048 dividing d.

    Precondition: ``d % 128 == 0``. Lane-aligned layouts
    (core.blocks.make_flat_blocks / make_block_layout) guarantee it;
    raw ragged widths raise an actionable error instead of the old
    silent non-termination of the decrement loop. A cached autotuner
    winner ``tuned`` (kernels/autotune.py) is used verbatim when it is
    a lane multiple dividing d.
    """
    if d % LANE != 0:
        raise ValueError(
            f"lane tile requires d % {LANE} == 0, got d={d}; build the "
            f"block table through a lane-aligned layout "
            f"(core.blocks.make_flat_blocks / make_block_layout round "
            f"block_dim up to {LANE}) instead of passing ragged rows.")
    if tuned is not None and tuned % LANE == 0 and 0 < tuned <= d \
            and d % tuned == 0:
        return tuned
    blk_d = min(d, 2048)
    while d % blk_d:
        blk_d -= LANE
    return blk_d


def admm_worker_select_update_3d(g, y, z_tilde, w_old, sel_mask, rho,
                                 x_old=None, *, interpret: bool = True,
                                 blk_m: Optional[int] = None,
                                 blk_d: Optional[int] = None):
    """Fused worker update + Alg. 1 select writes, epoch-native.

    g, y, z_tilde, w_old [, x_old] : (N, M, d) with d % 128 == 0
        (lane-aligned layout rows); the M grid tiles at the largest
        divisor of M <= 8 — never padded;
    sel_mask : (N, M, 1) float — 1.0 where the (worker, block) pair was
        selected this epoch, 0.0 otherwise;
    rho      : (N, 1) per-worker penalties (traced operand);
    blk_m, blk_d : optional tile overrides (autotuner winners; validated
        against the divisibility rules, heuristic fallback otherwise).

    Returns (y', w'[, x']): selected entries take the fresh update,
    unselected keep the old value — one pass over HBM instead of four.
    """
    N, M, d = g.shape
    blk_m = pick_blk_m(M, tuned=blk_m)
    blk_d = _pick_lane_tile(d, tuned=blk_d)
    grid = (N, M // blk_m, d // blk_d)
    tspec = pl.BlockSpec((1, blk_m, blk_d), lambda n, i, j: (n, i, j))
    mspec = pl.BlockSpec((1, blk_m, 1), lambda n, i, j: (n, i, 0))
    rspec = pl.BlockSpec((1, 1), lambda n, i, j: (n, 0))
    with_x = x_old is not None
    n_out = 3 if with_x else 2
    operands = [rho, sel_mask, g, y, z_tilde, w_old]
    in_specs = [rspec, mspec, tspec, tspec, tspec, tspec]
    if with_x:
        operands.append(x_old)
        in_specs.append(tspec)
    return pl.pallas_call(
        functools.partial(_kernel_3d, with_x=with_x),
        grid=grid,
        in_specs=in_specs,
        out_specs=[tspec] * n_out,
        out_shape=[jax.ShapeDtypeStruct(g.shape, g.dtype)] * n_out,
        interpret=interpret,
    )(*operands)
