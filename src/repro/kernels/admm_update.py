"""Pallas TPU kernel: fused AsyBADMM worker update — eqs. (11)+(12)+(9).

The worker update is the per-step hot loop of the paper: three
elementwise expressions over gradient-sized buffers. Unfused, XLA
materializes x and y' between HBM round-trips; fused, each (g, y, z~)
tile is read once from HBM into VMEM and all three outputs (x, y', w)
are produced in-register — the op becomes strictly HBM-bandwidth-bound
at its arithmetic-intensity floor (3 reads + 3 writes per element,
~5 flops/element).

Tiling: inputs are reshaped to (R, 128) 2D form by ops.py; the grid
walks (R/BLK_R) row-tiles of shape (BLK_R, 128) — second-minor multiple
of 8 and minor 128 to match the VPU (8, 128) vregs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK_R = 256
LANE = 128


def _kernel(g_ref, y_ref, zt_ref, x_ref, ynew_ref, w_ref, *, rho: float):
    g = g_ref[...]
    y = y_ref[...]
    zt = zt_ref[...]
    inv_rho = 1.0 / rho
    x = zt - (g + y) * inv_rho
    y_new = -g                      # identity (25): y' = y + rho(x - z~) = -g
    w = rho * x + y_new
    x_ref[...] = x.astype(x_ref.dtype)
    ynew_ref[...] = y_new.astype(ynew_ref.dtype)
    w_ref[...] = w.astype(w_ref.dtype)


def admm_worker_update_2d(g, y, z_tilde, rho: float, *, interpret: bool = True):
    """g, y, z_tilde: (R, 128)-aligned 2D arrays. Returns (x, y_new, w)."""
    R, C = g.shape
    assert C % LANE == 0 and R % 8 == 0, (R, C)
    blk_r = min(BLK_R, R)
    grid = (R // blk_r,)
    spec = pl.BlockSpec((blk_r, C), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct(g.shape, g.dtype)] * 3
    return pl.pallas_call(
        functools.partial(_kernel, rho=float(rho)),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=[spec, spec, spec],
        out_shape=out_shape,
        interpret=interpret,
    )(g, y, z_tilde)
