"""Pallas TPU kernel: fused AsyBADMM server update — eq. (13).

Combines the gamma-stabilized weighted average with the proximal map of
h = l1*||.||_1 + box(clip) in a single VMEM pass: one read of (z~, w_sum),
one write of z'. The per-block rho_sum = sum_{i in N(j)} rho_i enters as
a (M, 1) column so heterogeneous neighborhoods N(j) (the general-form
sparse case) are supported without a gather.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLK_M = 8
LANE = 128


def _kernel(zt_ref, ws_ref, rs_ref, z_ref, *, gamma: float, l1: float,
            clip: float):
    zt = zt_ref[...]
    ws = ws_ref[...]
    rs = rs_ref[...]                      # (blk_m, 1) broadcast column
    mu = gamma + rs
    v = (gamma * zt + ws) / mu
    if l1 > 0.0:
        thr = l1 / mu
        v = jnp.sign(v) * jnp.maximum(jnp.abs(v) - thr, 0.0)
    if clip > 0.0:
        v = jnp.clip(v, -clip, clip)
    z_ref[...] = v.astype(z_ref.dtype)


def prox_consensus_2d(z_tilde, w_sum, rho_sum, gamma: float, l1: float,
                      clip: float, *, interpret: bool = True):
    """z_tilde, w_sum: (M, d) with d % 128 == 0, M % 8 == 0;
    rho_sum: (M, 1). Returns z_new (M, d)."""
    M, d = z_tilde.shape
    assert d % LANE == 0 and M % BLK_M == 0, (M, d)
    blk_m = BLK_M
    blk_d = min(d, 512)
    while d % blk_d:
        blk_d //= 2
    grid = (M // blk_m, d // blk_d)
    spec = pl.BlockSpec((blk_m, blk_d), lambda i, j: (i, j))
    rs_spec = pl.BlockSpec((blk_m, 1), lambda i, j: (i, 0))
    return pl.pallas_call(
        functools.partial(_kernel, gamma=float(gamma), l1=float(l1),
                          clip=float(clip)),
        grid=grid,
        in_specs=[spec, spec, rs_spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(z_tilde.shape, z_tilde.dtype),
        interpret=interpret,
    )(z_tilde, w_sum, rho_sum)
