"""Pallas TPU kernels: fused AsyBADMM server update — eq. (13).

Two entry points:

* ``prox_consensus_2d`` — gamma-stabilized weighted average + prox of
  h = l1*||.||_1 + box(clip) in one VMEM pass over a pre-reduced
  (M, d) w_sum. The per-block rho_sum = sum_{i in N(j)} rho_i enters as
  a (M, 1) column so heterogeneous neighborhoods N(j) (the general-form
  sparse case) are supported without a gather.
* ``server_prox_fused_2d`` — the epoch-native deeper fusion: the
  edge-masked reduction over the worker axis N runs *inside* the grid
  (innermost grid dimension, accumulating into a VMEM scratch tile), so
  the (M, d) ``w_sum`` intermediate is never materialized in HBM. One
  read of w_cache + z, one write of z'.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .admm_update import pick_blk_m

BLK_M = 8
LANE = 128


def _prox_tail(v, mu, l1: float, clip: float):
    if l1 > 0.0:
        thr = l1 / mu
        v = jnp.sign(v) * jnp.maximum(jnp.abs(v) - thr, 0.0)
    if clip > 0.0:
        v = jnp.clip(v, -clip, clip)
    return v


def _kernel(zt_ref, ws_ref, rs_ref, z_ref, *, gamma: float, l1: float,
            clip: float):
    zt = zt_ref[...]
    ws = ws_ref[...]
    rs = rs_ref[...]                      # (blk_m, 1) broadcast column
    mu = gamma + rs
    v = _prox_tail((gamma * zt + ws) / mu, mu, l1, clip)
    z_ref[...] = v.astype(z_ref.dtype)


def _pick_blk_d(d: int, tuned: Optional[int] = None) -> int:
    """Lane tile for the prox grids (d % 128 == 0 — lane-aligned layout
    rows; raises otherwise). A cached autotuner winner ``tuned`` is used
    verbatim when it is a lane multiple dividing d."""
    if d % LANE != 0:
        raise ValueError(
            f"prox lane tile requires d % {LANE} == 0, got d={d}; build "
            f"the block table through a lane-aligned layout "
            f"(core.blocks.make_flat_blocks / make_block_layout).")
    if tuned is not None and tuned % LANE == 0 and 0 < tuned <= d \
            and d % tuned == 0:
        return tuned
    blk_d = min(d, 512)
    while d % blk_d:
        blk_d //= 2
    return blk_d


def prox_consensus_2d(z_tilde, w_sum, rho_sum, gamma: float, l1: float,
                      clip: float, *, interpret: bool = True,
                      blk_m: Optional[int] = None,
                      blk_d: Optional[int] = None):
    """z_tilde, w_sum: (M, d) with d % 128 == 0 (lane-aligned rows; the
    M grid tiles at the largest divisor of M <= 8, never padded);
    rho_sum: (M, 1); blk_m/blk_d optionally override the grid tiles
    (autotuner winners). Returns z_new (M, d)."""
    M, d = z_tilde.shape
    blk_m = pick_blk_m(M, tuned=blk_m)
    blk_d = _pick_blk_d(d, tuned=blk_d)
    grid = (M // blk_m, d // blk_d)
    spec = pl.BlockSpec((blk_m, blk_d), lambda i, j: (i, j))
    rs_spec = pl.BlockSpec((blk_m, 1), lambda i, j: (i, 0))
    return pl.pallas_call(
        functools.partial(_kernel, gamma=float(gamma), l1=float(l1),
                          clip=float(clip)),
        grid=grid,
        in_specs=[spec, spec, rs_spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(z_tilde.shape, z_tilde.dtype),
        interpret=interpret,
    )(z_tilde, w_sum, rho_sum)


# ---------------------------------------------------------------------------
# fused edge-masked worker reduction + prox (w_sum never hits HBM)
# ---------------------------------------------------------------------------

def _fused_kernel(z_ref, rs_ref, e_ref, w_ref, out_ref, acc_ref, *,
                  gamma: float, l1: float, clip: float, n_workers: int):
    n = pl.program_id(2)

    @pl.when(n == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    keep = e_ref[0] > 0.0                          # (blk_m, 1)
    acc_ref[...] += jnp.where(keep, w_ref[0].astype(jnp.float32), 0.0)

    @pl.when(n == n_workers - 1)
    def _():
        rs = rs_ref[...]
        mu = gamma + rs
        v = (gamma * z_ref[...].astype(jnp.float32) + acc_ref[...]) / mu
        out_ref[...] = _prox_tail(v, mu, l1, clip).astype(out_ref.dtype)


def server_prox_fused_2d(z_cur, w_cache, edge_mask, rho_sum, gamma: float,
                         l1: float, clip: float, *, interpret: bool = True,
                         blk_m: Optional[int] = None,
                         blk_d: Optional[int] = None):
    """Eq. (13) with the worker reduction fused into the grid.

    z_cur   : (M, d), d % 128 == 0 (lane-aligned rows; the M grid tiles
        at the largest divisor of M <= 8 — M=1 PS commits included);
    w_cache : (N, M, d) stale-w cache across all workers;
    edge_mask: (N, M, 1) float — 1.0 where (i, j) in E, else 0.0;
    rho_sum : (M, 1) per-block sum of rho_i over the neighborhood;
    blk_m, blk_d : optional tile overrides (autotuner winners).

    The grid is (M/blk_m, d/blk_d, N) with the worker axis innermost:
    each (block, d) tile accumulates its edge-masked w contribution in a
    VMEM scratch across the N sweeps, and the prox fires on the last
    worker — the reduced w_sum never exists as an HBM buffer. The tile
    choice never reorders the N accumulation, so tuned tiles are
    bitwise-equivalent to the heuristic.
    """
    N, M, d = w_cache.shape
    assert z_cur.shape == (M, d), (N, M, d)
    blk_m = pick_blk_m(M, tuned=blk_m)
    blk_d = _pick_blk_d(d, tuned=blk_d)
    grid = (M // blk_m, d // blk_d, N)
    spec = pl.BlockSpec((blk_m, blk_d), lambda i, j, n: (i, j))
    rs_spec = pl.BlockSpec((blk_m, 1), lambda i, j, n: (i, 0))
    e_spec = pl.BlockSpec((1, blk_m, 1), lambda i, j, n: (n, i, 0))
    w_spec = pl.BlockSpec((1, blk_m, blk_d), lambda i, j, n: (n, i, j))
    return pl.pallas_call(
        functools.partial(_fused_kernel, gamma=float(gamma), l1=float(l1),
                          clip=float(clip), n_workers=N),
        grid=grid,
        in_specs=[spec, rs_spec, e_spec, w_spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(z_cur.shape, z_cur.dtype),
        scratch_shapes=[pltpu.VMEM((blk_m, blk_d), jnp.float32)],
        interpret=interpret,
    )(z_cur, rho_sum, edge_mask, w_cache)
