"""Event-driven Parameter Server runtime for AsyBADMM.

The subsystem that turns the paper's systems claims — lock-free block
servers, bounded delay (Assumption 3), near-linear speedup (Table 1) —
into executable, measurable, replayable scenarios:

* :class:`EventScheduler` — deterministic discrete-event clock;
* :class:`BlockServerProc` + ``DISCIPLINES`` — per-block ``lockfree``
  servers vs the ``locked`` full-vector baseline (paper §1), plus the
  eager ``per_push`` commit discipline;
* :class:`WorkerProc` — workers running the REAL jitted
  ``VariableSpace`` hot path (jnp and pallas);
* :class:`StalenessEnforcer` — stalls pulls that would violate
  ``tau <= T`` instead of silently clipping;
* :class:`FaultPlan` / :class:`FaultInjector` +
  :class:`MembershipManager` — deterministic chaos (crash / rejoin /
  join / leave / slowdown / server spikes) over an elastic fleet;
* :class:`Transport` + :class:`TransportFabric` — unreliable
  worker<->server links (drop / duplicate / reorder, seeded per link)
  with ack/retry/backoff reliability, exactly-once commit folds, and
  graceful pull-timeout degradation within Assumption 3's bound;
* :class:`DomainWAL` + :class:`SnapshotCoordinator`
  (``ps/recovery.py``) — durability: per-domain write-ahead commit
  logs that rebuild a crashed block server exactly (``server_crash``
  faults, zero committed folds lost), and crash-consistent runtime
  snapshots with deterministic mid-run resume
  (``run_ps(checkpoint_every=, resume_from=)``);
* :class:`DelayTrace` — records what happened (staleness + partial
  participation + chaos events + transport delivery log); replays
  through the fast ``asybadmm_epoch`` via ``core.space.TraceDelay``
  exactly;
* :class:`PSRuntime` / :class:`PSRunResult` — the front door, also
  reachable as ``ConsensusSession.run_ps(...)`` and
  ``repro.launch.train --runtime ps``.

See API.md's "PS runtime" section for the scheduler model, the trace
format, and the runtime-vs-epoch decision guide.
"""
from .chaos import FaultEvent, FaultInjector, FaultPlan
from .engine import SpaceEngine
from .events import EventScheduler
from .membership import MembershipManager
from .recovery import (DomainWAL, SnapshotCoordinator, latest_snapshot,
                       list_snapshots, load_snapshot)
from .runtime import PSRunResult, PSRuntime
from .server import (BlockServerProc, Discipline, DISCIPLINES,
                     register_discipline, resolve_discipline)
from .staleness import StalenessEnforcer
from .timing import (SERVICE_MODELS, ConstantService, CostProfile,
                     LognormalService, NetworkModel, ParetoService,
                     ServiceModel, Transport, as_network, as_service,
                     measure_costs)
from .trace import DelayTrace
from .transport import LinkChannel, TransportFabric
from .worker import WorkerProc

__all__ = [
    "SpaceEngine", "EventScheduler", "PSRunResult", "PSRuntime",
    "BlockServerProc", "Discipline", "DISCIPLINES", "register_discipline",
    "resolve_discipline", "StalenessEnforcer", "SERVICE_MODELS",
    "ConstantService", "CostProfile", "LognormalService", "NetworkModel",
    "ParetoService", "ServiceModel", "Transport", "as_network",
    "as_service", "measure_costs", "DelayTrace", "LinkChannel",
    "TransportFabric", "WorkerProc",
    "FaultEvent", "FaultInjector", "FaultPlan", "MembershipManager",
    "DomainWAL", "SnapshotCoordinator", "latest_snapshot",
    "list_snapshots", "load_snapshot",
]
