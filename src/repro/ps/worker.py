"""Worker processes — pull, compute, declare/push, repeat.

Each worker runs Algorithm 1's lines 3-9 as an event-driven cycle:

  1. **pull**  — request every lock domain's freshest committed version
     (capped at its own round t, as the epoch model reads versions
     <= t). Pulls route through the :class:`StalenessEnforcer`: a
     domain lagging more than T versions stalls the worker until the
     commit that restores Assumption 3. With a
     :class:`~repro.ps.timing.NetworkModel` on the cost profile, each
     served pull's *response* additionally travels ``net.sample()``
     simulated seconds before the worker sees it (the version is fixed
     at serve time), and each round's declaration/push bundle travels
     the same way back — latency shifts what the trace records, never
     whether it replays.
  2. **compute** — once every pull resolves, the observed staleness row
     is recorded into the :class:`DelayTrace` and the worker's service
     time elapses (the scheduler's clock; stragglers come from the
     timing model, transient chaos slowdowns multiply the draw). The
     numerics — the REAL jitted ``worker_grads`` +
     ``worker_select_update`` at the epoch's full shape with this
     worker's row live — run at completion.
  3. **declare/push** — the selection row (the epoch's selector on the
     epoch's key chain) decides which blocks get fresh w pushes; every
     edge domain gets a declaration either way.

In ``timing_only`` mode step 2 skips the numerics (selection still
runs — it shapes server load) so coordination scalability can be
simulated at sizes where real gradients would dominate wall-clock.

Elasticity: a worker can die mid-cycle (:meth:`kill`) and resume later
(:meth:`revive`) at the round the membership manager hands it. Death
bumps an **incarnation counter**; every event the worker schedules
(compute completions, delayed pull responses, declaration deliveries
already in flight are fine — they belong to completed rounds) is
guarded on the incarnation it was scheduled under, so a dead
incarnation's events no-op instead of corrupting the resumed cycle.
The worker's y row and its w~ rows on the servers stay stale across
the outage until its first post-resume declare — exactly the frozen
rows the epoch's partial-participation mask reproduces on replay.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


class WorkerProc:
    def __init__(self, i: int, runtime, *, cold: bool = False):
        self.i = i
        self.rt = runtime
        self.rng = np.random.default_rng([runtime.seed, 1000 + i])
        self.t = 0
        self.rounds_done = 0
        self.alive = not cold
        self.gen = 0                   # incarnation counter
        self._pulled = {}
        # block id -> content grabbed when its pull resolved: the pull
        # response carries the payload (as a real protocol's would), so
        # a block server crashing between the response and this round's
        # compute cannot take the read back with it
        self._vals = {}
        self._pending = 0
        self._issued = False
        # unreliable-transport state: last committed version observed per
        # domain (the graceful-degradation fallback read), and the
        # (domain, round) declare bundles the server has acked. Both
        # survive kill/revive: the cache is still a legally committed
        # version, and completed rounds' declares must keep deduping.
        self._cache = {}
        self._acked = set()
        # telemetry anchors (records of times the schedule already
        # chose — never inputs to it): round pull-issue time, compute
        # start time
        self._issue_time = 0.0
        self._compute_start = 0.0

    # ---- elasticity -------------------------------------------------------
    def kill(self) -> None:
        """Crash/leave: invalidate every in-flight event of this
        incarnation (the enforcer separately drops parked pulls)."""
        self.alive = False
        self.gen += 1
        self._pulled = {}
        self._vals = {}
        self._pending = 0
        self._issued = False

    def revive(self, t: int) -> None:
        """Resume the cycle at round ``t`` (the membership manager's
        service frontier). Fresh z comes from the first pulls; y/w~
        stay whatever the last completed round left."""
        self.alive = True
        self.gen += 1
        self._begin_round(t)

    def _guarded(self, fn):
        gen = self.gen

        def run(*args):
            if self.alive and self.gen == gen:
                fn(*args)
        return run

    # ---- the cycle --------------------------------------------------------
    def start(self) -> None:
        self._begin_round(0)

    def _begin_round(self, t: int) -> None:
        self.t = t                     # finished workers report t == R
        if t >= self.rt.num_rounds:
            return
        ckpt = self.rt.ckpt
        if ckpt is not None and ckpt.park(self, t):
            return                     # snapshot barrier; resumes on release
        self._pulled = {}
        self._vals = {}
        self._issued = False
        self._pending = len(self.rt.domains)
        self._issue_time = self.rt.sched.now
        net = self.rt.net
        for dom in self.rt.domains:
            if self.rt.transport is not None:
                # lossy link: request/response with ack-by-response,
                # timeout + backoff retransmission, cache fallback
                self._pull_attempt(dom, t, 0)
                continue
            if net is None:
                resolve = (lambda version, dom=dom:
                           self._on_pull(dom, version))
            else:
                # the enforcer fixes the served version NOW; the response
                # then spends a network-latency sample in flight (guarded:
                # a response landing on a dead incarnation is dropped)
                def resolve(version, dom=dom):
                    self.rt.sched.after(
                        net.sample(self.rng),
                        self._guarded(lambda: self._on_pull(dom, version)))
            self.rt.enforcer.request(dom, t, self.rt.sched.now, resolve,
                                     worker=self.i)
        self._issued = True
        if self._pending == 0:
            self._start_compute()

    def _on_pull(self, dom, version: int, payload=None) -> None:
        self._pulled[dom.sid] = version
        obs = self.rt.obs
        if obs is not None and obs.spans is not None:
            # pull RTT: issue -> version in hand (stalls, network
            # latency and retransmission ladders all inside the span)
            obs.spans.complete(obs.worker_track(self.i), "pull",
                               self._issue_time, self.rt.sched.now,
                               round=self.t, domain=dom.sid,
                               version=version, tau=self.t - version)
        if not self.rt.timing_only:
            # grab the payload NOW (transport responses deliver it;
            # direct serves read the committed store, which is immutable
            # per version) — see the _vals contract above
            if payload is None:
                payload = [dom.content_at(j, version)
                           for j in dom.block_ids]
            for j, val in zip(dom.block_ids, payload):
                self._vals[j] = val
        if self.rt.transport is not None:
            self._cache[dom.sid] = max(self._cache.get(dom.sid, 0), version)
        self._pending -= 1
        if self._issued and self._pending == 0:
            self._start_compute()

    # ---- unreliable-transport pull cycle ----------------------------------
    def _pull_attempt(self, dom, t: int, retry: int) -> None:
        ch = self.rt.fabric.link(self.i, dom)
        if retry > 0:
            ch.note_retransmit("pull_req", t, retry)
        ch.send(lambda: dom.on_pull_request(self.i, t),
                msg="pull_req", t=t)
        self.rt.sched.after(
            self.rt.transport.timeout(retry),
            self._guarded(lambda: self._pull_retry(dom, t, retry)))

    def _pull_retry(self, dom, t: int, retry: int) -> None:
        """Retransmission timer fired: resend unless the pull resolved
        meanwhile. After ``max_retries`` the worker degrades gracefully
        to its cached version — IF that read still satisfies
        Assumption 3's tau <= bound; a cache too stale to be legal keeps
        retransmitting (the server must catch up eventually, and the
        bounded-staleness stall is exactly what the theory expects)."""
        if self.t != t or self._pending == 0 or dom.sid in self._pulled:
            return
        tr = self.rt.transport
        cached = self._cache.get(dom.sid, 0)
        # a DOWN domain cannot serve the cached read's payload — keep
        # retransmitting; its recovery delay is finite by plan contract
        if retry >= tr.max_retries and not dom.down \
                and t - cached <= self.rt.enforcer.bound:
            ch = self.rt.fabric.link(self.i, dom)
            ch.note_timeout("pull_req", t, cached)
            self.rt.enforcer.fallback(t, cached, worker=self.i)
            self._on_pull(dom, cached)
            return
        self._pull_attempt(dom, t, retry + 1)

    def on_pull_response(self, dom, t: int, version: int,
                         payload=None) -> None:
        """A pull response landed off the link (possibly late, possibly
        a duplicate, possibly for a round this incarnation already left
        behind) — only the first response for the CURRENT round's
        outstanding pull resolves it. ``payload`` is the block contents
        the response carried (None in timing-only mode)."""
        if (not self.alive or self.t != t or self._pending == 0
                or dom.sid in self._pulled):
            return
        self._on_pull(dom, version, payload)

    # ---- unreliable-transport declare cycle -------------------------------
    def _declare_reliably(self, dom, t: int, pushes: list,
                          retry: int = 0) -> None:
        """Send the round-t declaration bundle until the server acks it.
        Deliberately NOT incarnation-guarded and NOT retry-capped: the
        round already completed, so its declaration must eventually
        reach the commit gate (required gates would deadlock otherwise)
        even if this worker dies in the meantime; the gate's
        (worker, round) dedup makes every retransmit fold zero times
        after the first arrival."""
        if (dom.sid, t) in self._acked:
            return
        ch = self.rt.fabric.link(self.i, dom)
        if retry > 0:
            ch.note_retransmit("declare", t, retry)
        ch.send(lambda: dom.on_declare_msg(self.i, t, pushes),
                msg="declare", t=t)
        self.rt.sched.after(
            self.rt.transport.timeout(retry),
            lambda: self._declare_reliably(dom, t, pushes, retry + 1))

    def on_declare_ack(self, dom, t: int) -> None:
        self._acked.add((dom.sid, t))

    def _start_compute(self) -> None:
        t = self.t
        rt = self.rt
        # observed staleness row -> the trace (replayable via TraceDelay)
        row = np.empty(rt.engine.M, np.int32)
        for j in range(rt.engine.M):
            row[j] = t - self._pulled[rt.domain_of_block[j].sid]
        rt.trace.record(t, self.i, row)
        contents: Optional[list] = None
        if not rt.timing_only:
            # the payloads grabbed as each pull resolved (_vals): the
            # versions pinned in self._pulled, immune to a block server
            # crashing between its response and this compute start
            contents = [self._vals[j] for j in range(rt.engine.M)]
        dur = rt.worker_service.sample(self.rng)
        dur *= rt.injector.worker_factor(self.i, rt.sched.now)
        self._compute_start = rt.sched.now
        rt.sched.after(dur, self._guarded(
            lambda: self._finish_round(t, contents)))

    def _finish_round(self, t: int, contents) -> None:
        rt, i = self.rt, self.i
        eng = rt.engine
        obs = rt.obs
        if obs is not None and obs.spans is not None:
            # emitted at completion so a mid-compute crash leaves no
            # phantom span (the guarded event never fires)
            obs.spans.complete(obs.worker_track(i), "compute",
                               self._compute_start, rt.sched.now,
                               round=t)
        if rt.timing_only:
            sel_row = eng.select(t, i, None)
        else:
            z_buf = eng.z_tilde_buffer(i, contents)
            data = rt.data_for(t)
            losses, g_buf, gnorm = eng.grads(z_buf, data)
            rt.record_loss(t, i, losses[i])
            sel_row = eng.select(
                t, i, gnorm[i] if eng.needs_grads_for_select() else None)
            rt.y, rt.w, rt.x = eng.update(
                i, g_buf, z_buf, rt.y, rt.w, rt.x, sel_row)
        # declare to every edge domain; push fresh w where selected (the
        # declaration + its pushes travel as ONE message, so a round's
        # pushes never overtake their own declaration under latency;
        # deliveries stay valid even if the worker dies after sending —
        # the round completed, so they are NOT incarnation-guarded)
        sel_row = np.asarray(sel_row, bool) & eng.edge[i]
        for dom in rt.domains_of_worker[i]:
            pushes = [(j, None if rt.timing_only
                       else eng.push_value(rt.w, i, j))
                      for j in dom.block_ids if sel_row[j]]
            if rt.transport is not None:
                self._declare_reliably(dom, t, pushes)
            elif rt.net is None:
                dom.on_declare(i, t, pushes)
            else:
                rt.sched.after(rt.net.sample(self.rng),
                               lambda dom=dom, pushes=pushes:
                               dom.on_declare(i, t, pushes))
        self.rounds_done += 1
        rt.data_done(t)
        self._begin_round(t + 1)
        rt.on_worker_progress()

    # ---- telemetry --------------------------------------------------------
    @staticmethod
    def register_metrics(reg, rt) -> None:
        """Register the worker/membership instruments into the run's
        :class:`~repro.obs.MetricsRegistry`."""
        enforcer, membership, N = rt.enforcer, rt.membership, rt.engine.N
        reg.gauge("stall_time_per_worker",
                  lambda: [enforcer.stall_time_by_worker.get(i, 0.0)
                           for i in range(N)])
        reg.gauge("stall_count_per_worker",
                  lambda: [enforcer.stall_count_by_worker.get(i, 0)
                           for i in range(N)])
        reg.gauge("participated_rounds",
                  lambda: [membership.participated_rounds(i)
                           for i in range(N)])
        reg.counter("worker_iterations",
                    lambda: sum(membership.participated_rounds(i)
                                for i in range(N)))
        reg.counter("crashes", lambda: membership.crashes)
        reg.counter("rejoins", lambda: membership.rejoins)
