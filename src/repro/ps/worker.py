"""Worker processes — pull, compute, declare/push, repeat.

Each worker runs Algorithm 1's lines 3-9 as an event-driven cycle:

  1. **pull**  — request every lock domain's freshest committed version
     (capped at its own round t, as the epoch model reads versions
     <= t). Pulls route through the :class:`StalenessEnforcer`: a
     domain lagging more than T versions stalls the worker until the
     commit that restores Assumption 3. With a
     :class:`~repro.ps.timing.NetworkModel` on the cost profile, each
     served pull's *response* additionally travels ``net.sample()``
     simulated seconds before the worker sees it (the version is fixed
     at serve time), and each round's declaration/push bundle travels
     the same way back — latency shifts what the trace records, never
     whether it replays.
  2. **compute** — once every pull resolves, the observed staleness row
     is recorded into the :class:`DelayTrace` and the worker's service
     time elapses (the scheduler's clock; stragglers come from the
     timing model, transient chaos slowdowns multiply the draw). The
     numerics — the REAL jitted ``worker_grads`` +
     ``worker_select_update`` at the epoch's full shape with this
     worker's row live — run at completion.
  3. **declare/push** — the selection row (the epoch's selector on the
     epoch's key chain) decides which blocks get fresh w pushes; every
     edge domain gets a declaration either way.

In ``timing_only`` mode step 2 skips the numerics (selection still
runs — it shapes server load) so coordination scalability can be
simulated at sizes where real gradients would dominate wall-clock.

Elasticity: a worker can die mid-cycle (:meth:`kill`) and resume later
(:meth:`revive`) at the round the membership manager hands it. Death
bumps an **incarnation counter**; every event the worker schedules
(compute completions, delayed pull responses, declaration deliveries
already in flight are fine — they belong to completed rounds) is
guarded on the incarnation it was scheduled under, so a dead
incarnation's events no-op instead of corrupting the resumed cycle.
The worker's y row and its w~ rows on the servers stay stale across
the outage until its first post-resume declare — exactly the frozen
rows the epoch's partial-participation mask reproduces on replay.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


class WorkerProc:
    def __init__(self, i: int, runtime, *, cold: bool = False):
        self.i = i
        self.rt = runtime
        self.rng = np.random.default_rng([runtime.seed, 1000 + i])
        self.t = 0
        self.rounds_done = 0
        self.alive = not cold
        self.gen = 0                   # incarnation counter
        self._pulled = {}
        self._pending = 0
        self._issued = False

    # ---- elasticity -------------------------------------------------------
    def kill(self) -> None:
        """Crash/leave: invalidate every in-flight event of this
        incarnation (the enforcer separately drops parked pulls)."""
        self.alive = False
        self.gen += 1
        self._pulled = {}
        self._pending = 0
        self._issued = False

    def revive(self, t: int) -> None:
        """Resume the cycle at round ``t`` (the membership manager's
        service frontier). Fresh z comes from the first pulls; y/w~
        stay whatever the last completed round left."""
        self.alive = True
        self.gen += 1
        self._begin_round(t)

    def _guarded(self, fn):
        gen = self.gen

        def run(*args):
            if self.alive and self.gen == gen:
                fn(*args)
        return run

    # ---- the cycle --------------------------------------------------------
    def start(self) -> None:
        self._begin_round(0)

    def _begin_round(self, t: int) -> None:
        self.t = t                     # finished workers report t == R
        if t >= self.rt.num_rounds:
            return
        self._pulled = {}
        self._issued = False
        self._pending = len(self.rt.domains)
        net = self.rt.net
        for dom in self.rt.domains:
            if net is None:
                resolve = (lambda version, dom=dom:
                           self._on_pull(dom, version))
            else:
                # the enforcer fixes the served version NOW; the response
                # then spends a network-latency sample in flight (guarded:
                # a response landing on a dead incarnation is dropped)
                def resolve(version, dom=dom):
                    self.rt.sched.after(
                        net.sample(self.rng),
                        self._guarded(lambda: self._on_pull(dom, version)))
            self.rt.enforcer.request(dom, t, self.rt.sched.now, resolve,
                                     worker=self.i)
        self._issued = True
        if self._pending == 0:
            self._start_compute()

    def _on_pull(self, dom, version: int) -> None:
        self._pulled[dom.sid] = version
        self._pending -= 1
        if self._issued and self._pending == 0:
            self._start_compute()

    def _start_compute(self) -> None:
        t = self.t
        rt = self.rt
        # observed staleness row -> the trace (replayable via TraceDelay)
        row = np.empty(rt.engine.M, np.int32)
        for j in range(rt.engine.M):
            row[j] = t - self._pulled[rt.domain_of_block[j].sid]
        rt.trace.record(t, self.i, row)
        contents: Optional[list] = None
        if not rt.timing_only:
            contents = [rt.domain_of_block[j].content_at(
                j, self._pulled[rt.domain_of_block[j].sid])
                for j in range(rt.engine.M)]
        dur = rt.worker_service.sample(self.rng)
        dur *= rt.injector.worker_factor(self.i, rt.sched.now)
        rt.sched.after(dur, self._guarded(
            lambda: self._finish_round(t, contents)))

    def _finish_round(self, t: int, contents) -> None:
        rt, i = self.rt, self.i
        eng = rt.engine
        if rt.timing_only:
            sel_row = eng.select(t, i, None)
        else:
            z_buf = eng.z_tilde_buffer(i, contents)
            data = rt.data_for(t)
            losses, g_buf, gnorm = eng.grads(z_buf, data)
            rt.record_loss(t, i, losses[i])
            sel_row = eng.select(
                t, i, gnorm[i] if eng.needs_grads_for_select() else None)
            rt.y, rt.w, rt.x = eng.update(
                i, g_buf, z_buf, rt.y, rt.w, rt.x, sel_row)
        # declare to every edge domain; push fresh w where selected (the
        # declaration + its pushes travel as ONE message, so a round's
        # pushes never overtake their own declaration under latency;
        # deliveries stay valid even if the worker dies after sending —
        # the round completed, so they are NOT incarnation-guarded)
        sel_row = np.asarray(sel_row, bool) & eng.edge[i]
        for dom in rt.domains_of_worker[i]:
            pushes = [(j, None if rt.timing_only
                       else eng.push_value(rt.w, i, j))
                      for j in dom.block_ids if sel_row[j]]
            if rt.net is None:
                dom.on_declare(i, t, pushes)
            else:
                rt.sched.after(rt.net.sample(self.rng),
                               lambda dom=dom, pushes=pushes:
                               dom.on_declare(i, t, pushes))
        self.rounds_done += 1
        rt.data_done(t)
        self._begin_round(t + 1)
        rt.on_worker_progress()
