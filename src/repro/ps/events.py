"""Deterministic discrete-event scheduler — the PS runtime's clock.

A single priority queue of ``(time, seq, callback, tag)`` entries
drives the whole runtime: worker compute completions, push arrivals,
server commits, and stalled-pull resolutions are all events.
Determinism is a hard requirement (traces must replay, CI gates must
not flake), and it comes from two rules:

* ties in ``time`` break by insertion order (``seq`` is a monotonically
  increasing counter), so zero-cost events (e.g. ``t_push == 0``)
  process in the order they were scheduled;
* no entity draws randomness from a shared stream — every worker and
  server owns its own seeded ``numpy`` generator, so service-time draws
  are independent of event interleaving.

Simulated time is unitless; callers decide whether a unit is a second
(measured kernel costs) or an abstract service slot.

Two small extensions exist for the durability layer
(``ps/recovery.py``): events can carry a ``tag`` (the fault injector
tags its chaos timeline "fault", so a checkpoint barrier can tell
pending chaos apart from in-flight work), and an optional
``after_event`` hook runs after every callback (the snapshot
coordinator's quiescence check). ``restore_clock`` fast-forwards the
clock when a run resumes from a snapshot; it refuses to run with
events already queued — restored time must never travel backwards
past scheduled work.

A third hook serves telemetry (``repro.obs``): ``observer``, called as
``observer(now, tag)`` after every callback (and after ``after_event``
— the snapshot barrier's own work is observable too). The observer is
read-only by contract: it must not schedule events, consume rng, or
mutate runtime state — the determinism guarantee that a telemetry-on
run is bitwise identical to a telemetry-off one rests on it.
"""
from __future__ import annotations

import heapq
from typing import Callable, List, Optional, Tuple


class EventScheduler:
    """Run callbacks at simulated times; ``run`` drains the queue."""

    def __init__(self):
        self._q: List[Tuple[float, int, Callable[[], None],
                            Optional[str]]] = []
        self._seq = 0
        self.now = 0.0
        self.events_processed = 0
        self.after_event: Optional[Callable[[], None]] = None
        # telemetry observer: observer(now, tag) after every callback;
        # must never schedule, draw rng, or mutate (see module doc)
        self.observer: Optional[Callable[[float, Optional[str]],
                                         None]] = None

    def at(self, time: float, fn: Callable[[], None],
           tag: Optional[str] = None) -> None:
        """Schedule ``fn`` at absolute simulated ``time``."""
        if time < self.now:
            raise ValueError(f"cannot schedule at {time} < now={self.now}")
        heapq.heappush(self._q, (float(time), self._seq, fn, tag))
        self._seq += 1

    def after(self, delay: float, fn: Callable[[], None],
              tag: Optional[str] = None) -> None:
        """Schedule ``fn`` ``delay`` time units from now."""
        if delay < 0:
            raise ValueError(f"negative delay {delay}")
        self.at(self.now + delay, fn, tag)

    def only_tagged(self, tag: str) -> bool:
        """True when every queued event carries ``tag`` (or the queue
        is empty) — the snapshot coordinator's quiescence test: all
        in-flight work has drained and only future chaos remains."""
        return all(entry[3] == tag for entry in self._q)

    def restore_clock(self, time: float) -> None:
        """Fast-forward the clock to a snapshot's saved time. Only
        legal before anything is queued or processed — resume restores
        the clock first, then re-arms events at/after it."""
        if self._q or self.events_processed:
            raise RuntimeError(
                "restore_clock on a scheduler that already has queued or "
                "processed events — restore before arming anything")
        if time < 0.0:
            raise ValueError(f"cannot restore clock to {time} < 0")
        self.now = float(time)

    def run(self, max_events: int = 10_000_000) -> float:
        """Process events until the queue drains; returns the final
        simulated time (the makespan). ``max_events`` is a runaway
        guard — a healthy run is O(rounds * (workers + servers))."""
        while self._q:
            if self.events_processed >= max_events:
                raise RuntimeError(
                    f"event budget {max_events} exhausted at t={self.now} "
                    f"— likely a runaway commit loop (check num_rounds "
                    f"caps and staleness bounds)")
            time, _, fn, _tag = heapq.heappop(self._q)
            self.now = time
            self.events_processed += 1
            fn()
            if self.after_event is not None:
                self.after_event()
            if self.observer is not None:
                self.observer(self.now, _tag)
        return self.now
