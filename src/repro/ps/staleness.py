"""Bounded-staleness enforcement (Assumption 3) for the PS runtime.

The theory requires every read z~_j = z_j^{t - tau} to satisfy
``tau <= T``. The vectorized epoch gets this for free (the delay model
draws within the ring depth); a real parameter server does NOT — a
straggling block server can fall arbitrarily far behind a fast worker.
The enforcer is the runtime's gatekeeper: a pull whose freshest
available version would violate the bound **stalls** (the worker
blocks, simulated time passes) until the server commits version
``t - T``, instead of silently clipping the staleness the way a
sampled delay model would.

Serving discipline, for determinism: waiters resolve in FIFO order
inside the commit event that satisfies them. Every served pull is
asserted ``0 <= tau <= T`` — the property tests/test_ps_runtime.py
sweeps disciplines and straggler models against.

Elasticity (chaos runs): a crashed worker's parked pulls are dropped
(:meth:`drop_worker` — they will never be consumed), and a rejoin is
accounted as a **version reset**, not a tau violation: the membership
manager resumes the worker at the current service frontier (one past
the newest committed version), so its first pulls are ordinary
requests whose staleness is within the bound by construction. The
enforcer never compares a resumed round index against the worker's
pre-crash pull history — it only ever validates the (t, version) pair
it serves.

Server crashes (``server_crash`` faults): a crashed block server's
parked pulls die with its volatile state (:meth:`drop_server`, counted
as dropped pulls); the workers' retransmission timers re-request after
WAL recovery and the fresh request is validated like any other — the
bounded-staleness contract survives recovery because the rebuilt
version history is exactly the committed one.

Unreliable transport: a pull whose response keeps getting lost degrades
gracefully — after the retransmission budget the worker proceeds on its
cached z (:meth:`fallback`), which the enforcer validates against the
SAME tau <= T bound and accounts as ``timeout_fallbacks`` (extra
staleness steps, not violations). A cache too stale to satisfy the
bound is not a legal fallback; the worker keeps retransmitting.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Callable, Dict, List, Tuple


class StalenessEnforcer:
    """Gate pulls on the Assumption-3 bound; account stalls."""

    def __init__(self, bound: int):
        if bound < 0:
            raise ValueError(f"staleness bound must be >= 0; got {bound}")
        self.bound = int(bound)
        self.pulls_served = 0
        self.max_served_tau = 0
        self.stall_count = 0
        self.stall_time = 0.0
        self.dropped_pulls = 0
        self.version_resets = 0
        self.timeout_fallbacks = 0
        self.stall_time_by_worker: Dict[int, float] = defaultdict(float)
        self.stall_count_by_worker: Dict[int, int] = defaultdict(int)
        # server sid -> FIFO [(worker id, round t, issue time, resolve)]
        self._waiting: Dict[int, List[Tuple[int, int, float, Callable]]] = {}
        # telemetry (repro.obs.Telemetry) — None keeps every
        # instrumentation site inert; set by PSRuntime.run
        self.obs = None

    def request(self, server, t: int, now: float,
                resolve: Callable[[int], None], *, worker: int = -1) -> bool:
        """Worker round-t pull against ``server``. Resolves immediately
        (returning True) with version ``min(newest, t)`` when that
        read's staleness is within the bound; otherwise parks the pull
        until the server catches up to version ``t - bound``."""
        if server.version >= t - self.bound:
            self._serve(t, min(server.version, t), resolve)
            return True
        self.stall_count += 1
        self.stall_count_by_worker[worker] += 1
        self._waiting.setdefault(server.sid, []).append(
            (worker, t, now, resolve))
        return False

    def notify(self, server, now: float) -> None:
        """``server`` committed a new version — flush satisfiable
        waiters in FIFO order (within the commit event, so resolution
        order is deterministic)."""
        waiters = self._waiting.get(server.sid)
        if not waiters:
            return
        keep = []
        spans = self.obs.spans if self.obs is not None else None
        for (worker, t, issued, resolve) in waiters:
            if server.version >= t - self.bound:
                self.stall_time += now - issued
                self.stall_time_by_worker[worker] += now - issued
                if spans is not None:
                    # the stall window is only known at resolution —
                    # emit the complete span on the worker's track
                    spans.complete(self.obs.worker_track(worker), "stall",
                                   issued, now, round=t, server=server.sid)
                self._serve(t, min(server.version, t), resolve)
            else:
                keep.append((worker, t, issued, resolve))
        if keep:
            self._waiting[server.sid] = keep
        else:
            del self._waiting[server.sid]

    def drop_worker(self, worker: int) -> None:
        """A worker crashed: discard its parked pulls (the resolutions
        would land on a dead incarnation). The stall that ends in a
        crash is counted in ``dropped_pulls``, not ``stall_time``."""
        for sid in list(self._waiting):
            keep = [e for e in self._waiting[sid] if e[0] != worker]
            self.dropped_pulls += len(self._waiting[sid]) - len(keep)
            if keep:
                self._waiting[sid] = keep
            else:
                del self._waiting[sid]

    def drop_server(self, sid: int) -> None:
        """Block server ``sid`` crashed: the pulls parked on it died
        with its volatile state (the server-side dedup entries that
        would route the resolutions are gone). Counted as
        ``dropped_pulls``; the workers' transport retransmission timers
        re-request after WAL recovery, and the fresh request parks or
        serves against the rebuilt state."""
        waiters = self._waiting.pop(sid, None)
        if waiters:
            self.dropped_pulls += len(waiters)

    def fallback(self, t: int, version: int, *, worker: int = -1) -> None:
        """A worker's round-t pull timed out through every retry on an
        unreliable transport, and it is proceeding on its CACHED version
        instead of deadlocking (graceful degradation). The read must
        still satisfy Assumption 3 — the extra staleness steps count
        against the same tau <= T bound every served pull is held to
        (validated here; the caller checks eligibility before falling
        back) — so the recorded trace stays within its declared bound
        and replays unchanged."""
        tau = t - version
        if not 0 <= tau <= self.bound:
            raise AssertionError(
                f"timeout fallback for worker {worker} would read "
                f"tau={tau} outside [0, {self.bound}] — the worker must "
                f"keep retransmitting instead")
        self.timeout_fallbacks += 1
        self.max_served_tau = max(self.max_served_tau, tau)

    def note_rejoin(self) -> None:
        """Membership resumed a worker at the service frontier — count
        the version reset (tau accounting restarts from the resumed
        round; no violation is recorded)."""
        self.version_resets += 1

    def _serve(self, t: int, version: int, resolve) -> None:
        tau = t - version
        if not 0 <= tau <= self.bound:
            raise AssertionError(
                f"staleness enforcer served tau={tau} outside [0, "
                f"{self.bound}] — runtime invariant broken")
        self.pulls_served += 1
        self.max_served_tau = max(self.max_served_tau, tau)
        resolve(version)

    @property
    def idle(self) -> bool:
        return not self._waiting

    def stats(self) -> Dict[str, float]:
        return {"bound": self.bound,
                "pulls_served": self.pulls_served,
                "max_served_tau": self.max_served_tau,
                "stall_count": self.stall_count,
                "stall_time": self.stall_time,
                "dropped_pulls": self.dropped_pulls,
                "version_resets": self.version_resets,
                "timeout_fallbacks": self.timeout_fallbacks}

    def register_metrics(self, reg) -> None:
        """Register the enforcer's instruments (same keys/order as
        :meth:`stats` — the head of ``PSRunResult.metrics``)."""
        reg.gauge("bound", lambda: self.bound)
        reg.counter("pulls_served", lambda: self.pulls_served)
        reg.gauge("max_served_tau", lambda: self.max_served_tau)
        reg.counter("stall_count", lambda: self.stall_count)
        reg.counter("stall_time", lambda: self.stall_time)
        reg.counter("dropped_pulls", lambda: self.dropped_pulls)
        reg.counter("version_resets", lambda: self.version_resets)
        reg.counter("timeout_fallbacks", lambda: self.timeout_fallbacks)
