"""Bounded-staleness enforcement (Assumption 3) for the PS runtime.

The theory requires every read z~_j = z_j^{t - tau} to satisfy
``tau <= T``. The vectorized epoch gets this for free (the delay model
draws within the ring depth); a real parameter server does NOT — a
straggling block server can fall arbitrarily far behind a fast worker.
The enforcer is the runtime's gatekeeper: a pull whose freshest
available version would violate the bound **stalls** (the worker
blocks, simulated time passes) until the server commits version
``t - T``, instead of silently clipping the staleness the way a
sampled delay model would.

Serving discipline, for determinism: waiters resolve in FIFO order
inside the commit event that satisfies them. Every served pull is
asserted ``0 <= tau <= T`` — the property tests/test_ps_runtime.py
sweeps disciplines and straggler models against.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple


class StalenessEnforcer:
    """Gate pulls on the Assumption-3 bound; account stalls."""

    def __init__(self, bound: int):
        if bound < 0:
            raise ValueError(f"staleness bound must be >= 0; got {bound}")
        self.bound = int(bound)
        self.pulls_served = 0
        self.max_served_tau = 0
        self.stall_count = 0
        self.stall_time = 0.0
        # server sid -> FIFO [(worker round t, issue time, resolve)]
        self._waiting: Dict[int, List[Tuple[int, float, Callable]]] = {}

    def request(self, server, t: int, now: float,
                resolve: Callable[[int], None]) -> bool:
        """Worker round-t pull against ``server``. Resolves immediately
        (returning True) with version ``min(newest, t)`` when that
        read's staleness is within the bound; otherwise parks the pull
        until the server catches up to version ``t - bound``."""
        if server.version >= t - self.bound:
            self._serve(t, min(server.version, t), resolve)
            return True
        self.stall_count += 1
        self._waiting.setdefault(server.sid, []).append((t, now, resolve))
        return False

    def notify(self, server, now: float) -> None:
        """``server`` committed a new version — flush satisfiable
        waiters in FIFO order (within the commit event, so resolution
        order is deterministic)."""
        waiters = self._waiting.get(server.sid)
        if not waiters:
            return
        keep = []
        for (t, issued, resolve) in waiters:
            if server.version >= t - self.bound:
                self.stall_time += now - issued
                self._serve(t, min(server.version, t), resolve)
            else:
                keep.append((t, issued, resolve))
        if keep:
            self._waiting[server.sid] = keep
        else:
            del self._waiting[server.sid]

    def _serve(self, t: int, version: int, resolve) -> None:
        tau = t - version
        if not 0 <= tau <= self.bound:
            raise AssertionError(
                f"staleness enforcer served tau={tau} outside [0, "
                f"{self.bound}] — runtime invariant broken")
        self.pulls_served += 1
        self.max_served_tau = max(self.max_served_tau, tau)
        resolve(version)

    @property
    def idle(self) -> bool:
        return not self._waiting

    def stats(self) -> Dict[str, float]:
        return {"bound": self.bound,
                "pulls_served": self.pulls_served,
                "max_served_tau": self.max_served_tau,
                "stall_count": self.stall_count,
                "stall_time": self.stall_time}
