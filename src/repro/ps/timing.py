"""Service-time models for the PS runtime + measured kernel costs.

The discrete-event scheduler charges every worker compute and every
server commit a service time drawn from a :class:`ServiceModel`:

* ``constant``  — deterministic (CI gates, analytical checks);
* ``lognormal`` — the seed benchmark's EC2-style jitter;
* ``pareto``    — heavy-tailed stragglers (the cluster profile behind
  the paper's Table-1 story and our ``ParetoDelay`` staleness model).

:class:`NetworkModel` (``CostProfile(net=...)``) additionally charges a
constant + jitter latency on every worker<->server message — pull
responses and declaration/push bundles — so coordination studies can
separate compute stragglers from network lag (``--net-latency`` /
``--net-jitter`` on ``launch.train``). Observed staleness still lands
in the ``DelayTrace``, so replay parity holds under any network model.

:func:`measure_costs` grounds the simulation in reality: it times the
REAL jitted ``VariableSpace`` hot-path ops (the same ``worker_grads`` /
``worker_select_update`` / ``server_consensus_update`` the epoch runs)
on this host and returns a :class:`CostProfile` — this replaces the
hand-rolled ``loss_fn``/``server_update`` measurement the old
``benchmarks/speedup.py`` carried.
"""
from __future__ import annotations

import dataclasses
import time as _time
from typing import Any, Dict, Optional, Protocol

import numpy as np


class ServiceModel(Protocol):
    """Draws one service duration from an entity-owned generator."""

    def sample(self, rng: np.random.Generator) -> float: ...


@dataclasses.dataclass(frozen=True)
class ConstantService:
    """Deterministic service time (the CI-gate workhorse)."""
    mean: float

    def sample(self, rng: np.random.Generator) -> float:
        return self.mean


@dataclasses.dataclass(frozen=True)
class LognormalService:
    """``mean * LogNormal(0, sigma)`` — the seed benchmark's jitter."""
    mean: float
    sigma: float = 0.3

    def sample(self, rng: np.random.Generator) -> float:
        return self.mean * float(rng.lognormal(0.0, self.sigma))


@dataclasses.dataclass(frozen=True)
class ParetoService:
    """Heavy-tailed straggler service: ``mean * X`` with X ~ Pareto
    (x_m = 1, tail ``alpha``), mean-normalized when ``alpha > 1`` and
    capped at ``cap`` multiples of the mean so a single draw cannot
    dominate the makespan unboundedly."""
    mean: float
    alpha: float = 1.2
    cap: float = 50.0

    def sample(self, rng: np.random.Generator) -> float:
        x = (1.0 - float(rng.random())) ** (-1.0 / self.alpha)
        if self.alpha > 1.0:
            x *= (self.alpha - 1.0) / self.alpha
        return self.mean * min(x, self.cap)


SERVICE_MODELS = {"constant": ConstantService, "lognormal": LognormalService,
                  "pareto": ParetoService}


def as_service(v) -> ServiceModel:
    """Coerce a float to ConstantService; pass ServiceModels through.

    Constants are validated eagerly: a negative or non-finite service
    time would silently run the scheduler's clock backwards (``after``
    rejects negative delays only at event time, deep inside a run), so
    it fails here with an actionable message instead."""
    if hasattr(v, "sample"):
        return v
    t = float(v)
    if not np.isfinite(t) or t < 0.0:
        raise ValueError(
            f"as_service: constant service time must be finite and >= 0 "
            f"(simulated seconds per event); got {v!r} — fix the "
            f"CostProfile field (t_worker / t_server_block), or pass a "
            f"ServiceModel for stochastic draws")
    return ConstantService(t)


@dataclasses.dataclass(frozen=True)
class NetworkModel:
    """Per-message network latency between workers and block servers:
    ``latency`` + U(-jitter, +jitter), floored at 0.

    Charged once per worker<->server message — each pull *response*
    (server -> worker, after the enforcer serves it) and each
    declaration/push bundle (worker -> server). Latency shifts WHEN
    messages land (and therefore which versions later pulls observe and
    how long commits wait on declarations), but every observed
    staleness row is still recorded into the ``DelayTrace`` at compute
    time, so trace replay through ``asybadmm_epoch`` stays exact — the
    network model changes the trace, never the replay contract."""
    latency: float
    jitter: float = 0.0

    def __post_init__(self):
        if self.latency < 0.0 or self.jitter < 0.0:
            raise ValueError(f"network latency/jitter must be >= 0; got "
                             f"latency={self.latency} jitter={self.jitter}")

    def sample(self, rng: np.random.Generator) -> float:
        if self.jitter <= 0.0:
            return self.latency
        return max(0.0, self.latency
                   + self.jitter * (2.0 * float(rng.random()) - 1.0))


@dataclasses.dataclass(frozen=True)
class Transport(NetworkModel):
    """An *unreliable* network between workers and block servers.

    Extends :class:`NetworkModel` (constant + jitter latency per
    message) with per-link delivery faults, drawn from seeded per-link
    rngs so lossy runs stay exactly as deterministic and replayable as
    reliable ones:

    drop_rate    : probability a sent message is lost;
    dup_rate     : probability a delivered message arrives twice;
    reorder_rate : probability a delivered copy is held back an extra
                   U(0, reorder window) — enough to land after later
                   traffic on the same link;
    ack_timeout  : how long the sender waits for the response/ack
                   before retransmitting;
    max_retries  : pull retransmissions before the worker degrades
                   gracefully to its cached z (when that read still
                   satisfies Assumption 3's tau <= T); declarations
                   retransmit without bound — a round's pushes must
                   eventually commit;
    backoff      : exponential retransmission backoff multiplier,
                   capped at ``max_backoff`` timeouts;
    reorder_window : extra-delay window for reordered copies
                   (0.0 = one ack_timeout).

    With every fault knob at zero the transport is INERT: the runtime
    routes messages through the plain :class:`NetworkModel` path (or no
    network model at all), byte-identical to pre-transport behavior.
    The reliability machinery — sequence numbers, acks, retransmits,
    commit-gate dedup — engages only when a knob is on (or a
    ``link_loss`` fault window makes a link lossy mid-run).
    """
    drop_rate: float = 0.0
    dup_rate: float = 0.0
    reorder_rate: float = 0.0
    ack_timeout: float = 1.0
    max_retries: int = 3
    backoff: float = 2.0
    max_backoff: float = 8.0
    reorder_window: float = 0.0

    def __post_init__(self):
        super().__post_init__()
        for name in ("drop_rate", "dup_rate", "reorder_rate"):
            p = getattr(self, name)
            if not (0.0 <= p < 1.0) or not np.isfinite(p):
                raise ValueError(
                    f"transport {name} must be a probability in [0, 1) "
                    f"(1.0 would never deliver); got {p}")
        if not np.isfinite(self.ack_timeout) or self.ack_timeout <= 0.0:
            raise ValueError(f"transport ack_timeout must be finite and "
                             f"> 0; got {self.ack_timeout}")
        if self.max_retries < 0:
            raise ValueError(f"transport max_retries must be >= 0; got "
                             f"{self.max_retries}")
        if not np.isfinite(self.backoff) or self.backoff < 1.0:
            raise ValueError(f"transport backoff multiplier must be >= 1; "
                             f"got {self.backoff}")
        if self.max_backoff < 1.0:
            raise ValueError(f"transport max_backoff must be >= 1 "
                             f"ack_timeout; got {self.max_backoff}")
        if self.reorder_window < 0.0:
            raise ValueError(f"transport reorder_window must be >= 0; got "
                             f"{self.reorder_window}")

    @property
    def unreliable(self) -> bool:
        """Whether any fault knob is on — the switch between the plain
        NetworkModel path and the ack/retry reliability sublayer."""
        return (self.drop_rate > 0.0 or self.dup_rate > 0.0
                or self.reorder_rate > 0.0)

    def timeout(self, retry: int) -> float:
        """Retransmission timeout for attempt ``retry`` (0-based):
        capped exponential backoff."""
        return self.ack_timeout * min(self.backoff ** retry,
                                      self.max_backoff)

    def reorder_extra(self, rng: np.random.Generator) -> float:
        window = self.reorder_window if self.reorder_window > 0.0 \
            else self.ack_timeout
        return window * float(rng.random())


def as_network(v) -> Optional[NetworkModel]:
    """None / 0.0 -> no network model; float -> constant latency;
    NetworkModel passes through (degenerate zero models drop to None so
    the zero-latency scheduler path stays byte-identical). An
    *unreliable* :class:`Transport` always passes through — loss alone
    engages the messaging layer even at zero latency."""
    if v is None:
        return None
    net = v if isinstance(v, NetworkModel) else NetworkModel(float(v))
    if isinstance(net, Transport) and net.unreliable:
        return net
    return net if (net.latency > 0.0 or net.jitter > 0.0) else None


@dataclasses.dataclass(frozen=True)
class CostProfile:
    """Per-event costs fed to the scheduler.

    t_worker       : one worker iteration (stale pull -> grad -> update);
    t_server_block : one block server commit (eq. 13 on one block); the
                     locked full-vector discipline pays it once per
                     block it holds under the lock;
    t_push         : server-side processing of one incoming w push
                     (queueing delay on the lock domain) — a plain
                     float, charged deterministically per push;
    net            : worker<->server network latency per message —
                     None (ideal network), a float (constant), a
                     :class:`NetworkModel` (constant + jitter), or a
                     :class:`Transport` (unreliable: drop / duplicate /
                     reorder with ack+retransmit reliability).
    ``t_worker`` / ``t_server_block`` floats coerce to
    ConstantService; pass a ServiceModel for jitter.
    """
    t_worker: Any = 1.0
    t_server_block: Any = 0.25
    t_push: float = 0.0
    net: Any = None

    def __post_init__(self):
        if hasattr(self.t_push, "sample"):
            raise TypeError("t_push is a deterministic float cost, not a "
                            "ServiceModel (push processing is charged per "
                            "event on the lock domain's queue)")

    def worker_service(self) -> ServiceModel:
        return as_service(self.t_worker)

    def server_service(self) -> ServiceModel:
        return as_service(self.t_server_block)

    def network(self) -> Optional[NetworkModel]:
        return as_network(self.net)


def measure_costs(spec, data, z0=None, *, repeats: int = 20
                  ) -> Dict[str, float]:
    """Time the real jitted unified-path ops for one worker iteration
    and one block-server commit on this host.

    Returns ``{"t_worker": s, "t_server_block": s}`` — seconds per
    event. The worker op executes at the epoch's full (N, ...) shape
    (that IS the jitted hot path), so the per-worker cost is the
    measured call divided by N.
    """
    import jax

    from .engine import SpaceEngine

    eng = SpaceEngine(spec)
    z0r, y, w, x = eng.init(z0)
    contents = eng.split_blocks(z0r)
    data0 = eng.round_data(0, data)
    zbuf = eng.z_tilde_buffer(0, contents)
    gnorm0 = (np.zeros(eng.M, np.float32) if eng.needs_grads_for_select()
              else None)
    sel_row = eng.select(0, 0, gnorm0)

    def _timeit(fn, n):
        jax.block_until_ready(fn())            # compile + warm
        t0 = _time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(fn())
        return (_time.perf_counter() - t0) / n

    def worker_once():
        losses, g_buf, _ = eng.grads(zbuf, data0)
        return eng.update(0, g_buf, zbuf, y, w, x, sel_row)

    t_worker = _timeit(worker_once, repeats) / eng.N

    cache0 = eng.block_cache(w, 0)
    t_server = _timeit(lambda: eng.commit_block(0, contents[0], cache0),
                       max(repeats, 50))
    return {"t_worker": t_worker, "t_server_block": t_server}
