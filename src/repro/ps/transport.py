"""Unreliable worker<->server links + the delivery bookkeeping.

One :class:`LinkChannel` per (worker, lock domain) pair simulates the
link both directions travel: every ``send`` draws the link's fate —
drop, duplicate, reorder hold-back, latency — from the link's OWN
seeded rng (``default_rng([seed, 3000 + worker, sid])``), so delivery
schedules are deterministic and independent of event interleaving,
exactly like every other draw in the DES runtime.

The reliability protocol built on top (in ``worker.py``/``server.py``)
is end-to-end:

* **pulls** — the request travels, the server fixes the served version
  (through the StalenessEnforcer) once per (worker, round) and replies;
  the *response is the ack*. The worker retransmits on timeout with
  capped exponential backoff; after ``max_retries`` it degrades
  gracefully to its cached z when that read still satisfies
  Assumption 3 (accounted by the enforcer as a timeout fallback — an
  extra staleness step, never a tau violation), else keeps retrying.
* **declarations/pushes** — the round bundle retransmits WITHOUT bound
  until the server acks it (a required round must eventually commit);
  the commit gate dedups by (worker, round), so retransmits and
  transport duplicates fold exactly once.

Every non-clean delivery decision (drop, duplicate, reorder slot,
retransmit, pull timeout) is recorded into the run's
:class:`~repro.ps.trace.DelayTrace` transport log — the *effective
committed schedule* is what the trace's staleness + participation
matrices pin, so lossy runs replay through ``asybadmm_epoch`` exactly
like reliable ones; the log is for debugging the loss itself.

``link_loss`` fault windows (``ps/chaos.py``) add burst loss on top of
the base ``drop_rate``: at send time the channel asks the injector for
the window's drop probability and composes it with the base rate.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional

import numpy as np

from .timing import Transport


class LinkChannel:
    """One worker<->domain link: seeded fate draws + delivery stats."""

    def __init__(self, transport: Transport, sched, rng: np.random.Generator,
                 worker: int, sid: int, block_ids,
                 recorder: Optional[Callable] = None,
                 burst_drop: Optional[Callable] = None):
        self.transport = transport
        self.sched = sched
        self.rng = rng
        self.worker = worker
        self.sid = sid
        self.block_ids = tuple(block_ids)
        self._record = recorder
        self._burst_drop = burst_drop
        self._seq = 0
        self.sent = 0
        self.delivered = 0
        self.drops = 0
        self.dups = 0
        self.reorders = 0
        self.retransmits = 0

    # ------------------------------------------------------------------
    def _note(self, kind: str, msg: str, t: int, **extra) -> None:
        if self._record is not None:
            self._record(kind, msg=msg, worker=self.worker, domain=self.sid,
                         round=t, time=self.sched.now, **extra)

    def _drop_rate(self) -> float:
        """Base drop rate composed with any active link_loss burst."""
        p = self.transport.drop_rate
        if self._burst_drop is not None:
            q = self._burst_drop(self.worker, self.block_ids, self.sched.now)
            if q > 0.0:
                p = 1.0 - (1.0 - p) * (1.0 - q)
        return p

    def send(self, deliver: Callable[[], None], *, msg: str, t: int) -> int:
        """Put one message on the link; returns its sequence number.
        Draws (in order): drop -> duplicate -> per-copy latency +
        reorder hold-back. A dropped message schedules nothing — the
        sender's retransmission timer is the only way it recovers."""
        tr = self.transport
        rng = self.rng
        seq = self._seq
        self._seq += 1
        self.sent += 1
        p_drop = self._drop_rate()
        if p_drop > 0.0 and float(rng.random()) < p_drop:
            self.drops += 1
            self._note("drop", msg, t, seq=seq)
            return seq
        copies = 1
        if tr.dup_rate > 0.0 and float(rng.random()) < tr.dup_rate:
            copies = 2
            self.dups += 1
            self._note("dup", msg, t, seq=seq)
        self.delivered += 1
        for c in range(copies):
            delay = tr.sample(rng)
            if tr.reorder_rate > 0.0 \
                    and float(rng.random()) < tr.reorder_rate:
                extra = tr.reorder_extra(rng)
                delay += extra
                self.reorders += 1
                self._note("reorder", msg, t, seq=seq, copy=c,
                           held=round(extra, 6))
            self.sched.after(delay, deliver)
        return seq

    def note_retransmit(self, msg: str, t: int, retry: int) -> None:
        self.retransmits += 1
        self._note("retransmit", msg, t, retry=retry)

    def note_timeout(self, msg: str, t: int, version: int) -> None:
        self._note("pull_timeout", msg, t, served_version=version)

    @property
    def delivery_rate(self) -> float:
        return self.delivered / self.sent if self.sent else 1.0


class TransportFabric:
    """All links of one run: lazy per-link channels + fleet-wide stats."""

    def __init__(self, transport: Transport, sched, seed: int,
                 recorder: Optional[Callable] = None,
                 burst_drop: Optional[Callable] = None):
        self.transport = transport
        self.sched = sched
        self.seed = seed
        self._recorder = recorder
        self._burst_drop = burst_drop
        self._links: Dict[tuple, LinkChannel] = {}

    def link(self, worker: int, dom) -> LinkChannel:
        key = (worker, dom.sid)
        ch = self._links.get(key)
        if ch is None:
            ch = self._links[key] = LinkChannel(
                self.transport, self.sched,
                np.random.default_rng([self.seed, 3000 + worker, dom.sid]),
                worker, dom.sid, dom.block_ids,
                recorder=self._recorder, burst_drop=self._burst_drop)
        return ch

    def stats(self) -> Dict:
        links = self._links.values()
        total = {k: sum(getattr(ch, k) for ch in links)
                 for k in ("sent", "delivered", "drops", "dups", "reorders",
                           "retransmits")}
        total["delivery_rate"] = (total["delivered"] / total["sent"]
                                  if total["sent"] else 1.0)
        total["per_link_delivery_rate"] = {
            f"w{w}->s{s}": round(ch.delivery_rate, 4)
            for (w, s), ch in sorted(self._links.items())}
        return total

    def register_metrics(self, reg, rt) -> None:
        """Register the fleet-wide transport instrument (delivery
        totals + the server/enforcer dedup and fallback counters that
        belong to the transport story)."""
        def value():
            s = self.stats()
            s["dups_dropped"] = sum(d.dups_dropped for d in rt.domains)
            s["timeout_fallbacks"] = rt.enforcer.timeout_fallbacks
            return s
        reg.gauge("transport", value)
