"""DelayTrace — what the PS runtime actually observed, replayable.

Every pull the runtime serves is a (round t, worker i, block j) read of
some committed version u <= t; the trace records the full staleness
matrix ``delays[t, i, j] = t - u``. Because the runtime realizes
Algorithm 1's logical dataflow exactly (round-r pushes commit block
version r+1), replaying a recorded trace through the fast vectorized
``asybadmm_epoch`` via :class:`repro.core.space.TraceDelay` reproduces
the runtime's z trajectory — structurally exact, bitwise on the pallas
backend, fp32-ulp (cross-program XLA fusion) on jnp — the bridge that
lets every scheduling/straggler scenario discovered under the
event-driven runtime re-run at SPMD speed (pinned by
tests/test_ps_runtime.py).

Elastic runs add **partial participation**: ``participation[t, i]`` is
False for rounds worker i missed (crashed, left, or not yet joined) —
its delay row stays -1 (nothing was pulled) and replay contributes no
edge updates for that (round, worker), via the selection mask in
:class:`~repro.core.space.TraceDelay`. Chaos timeline entries
(``events``: crash / rejoin / join / leave / slowdown / server_spike /
server_crash / server_recover dicts) ride along for analysis and are
round-trip persisted. Server recovery gaps need no special replay
handling: WAL replay rebuilds exactly the committed versions, so the
staleness matrix the workers observed is already the effective
schedule (the gap shows up as stalls/retransmits in sim time, not as
extra staleness beyond the recorded taus).

File format (``.npz``): ``delays`` (rounds, N, M) int32, ``bound`` (the
Assumption-3 T the enforcer guaranteed), ``discipline``, a JSON
``meta`` blob (timing config, seeds, makespan), and — only when the run
was elastic — ``participation`` (rounds, N) bool and a JSON ``events``
list, and — only when the run went over an unreliable transport — a
JSON ``transport`` delivery log. Older files simply lack the newer
keys; ``load`` defaults them (full participation, no events, no
transport log), so old traces keep loading — pinned by
tests/test_ps_chaos.py. ``load`` validates the archive eagerly and
raises an actionable ``ValueError`` (file, offending key, shape) on
truncated/corrupt files.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class DelayTrace:
    delays: np.ndarray                 # (rounds, N, M) int32; -1 = unrecorded
    bound: int                         # Assumption 3's T enforced at record time
    discipline: str = "lockfree"
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # (rounds, N) bool; None = full participation (pre-chaos traces)
    participation: Optional[np.ndarray] = None
    # chaos timeline: [{"kind": "crash"|"rejoin"|"join"|"leave"|
    #                   "slowdown"|"server_spike"|"link_loss"|
    #                   "server_crash"|"server_recover", ...}]
    events: List[Dict[str, Any]] = dataclasses.field(default_factory=list)
    # unreliable-transport delivery log: every drop / dup / reorder /
    # retransmit / pull-timeout decision, in decision order. Debugging
    # detail only — the staleness matrix + participation mask (the
    # EFFECTIVE committed schedule) are what replay consumes, so lossy
    # traces replay through ``asybadmm_epoch`` exactly like reliable
    # ones. Empty (and unsaved) on reliable runs.
    transport: List[Dict[str, Any]] = dataclasses.field(default_factory=list)

    @classmethod
    def empty(cls, num_rounds: int, n_workers: int, n_blocks: int,
              bound: int, discipline: str = "lockfree") -> "DelayTrace":
        return cls(delays=np.full((num_rounds, n_workers, n_blocks), -1,
                                  np.int32),
                   bound=int(bound), discipline=discipline)

    # ---- recording -------------------------------------------------------
    def record(self, t: int, i: int, row) -> None:
        """Record worker i's round-t staleness row (M,)."""
        self.delays[t, i, :] = np.asarray(row, np.int32)

    def set_participation(self, part) -> None:
        """Install the (rounds, N) participation matrix from an elastic
        run and erase any partially-recorded rows of absent (t, i)
        pairs (a worker that crashed mid-compute recorded its staleness
        row but never declared — the round did not happen for it)."""
        p = np.asarray(part, bool)
        if p.shape != self.delays.shape[:2]:
            raise ValueError(
                f"participation must be (rounds, N) = "
                f"{self.delays.shape[:2]}; got shape {p.shape}")
        self.delays[~p] = -1
        self.participation = None if p.all() else p

    def add_event(self, kind: str, **fields) -> None:
        """Append one chaos-timeline entry. ``kind`` must be declared
        in :data:`repro.obs.names.TRACE_EVENT_KINDS` — the shared
        registry that keeps trace spellings and telemetry span names
        from silently diverging."""
        from ..obs.names import TRACE_EVENT_KINDS, validate_kind
        validate_kind(kind, TRACE_EVENT_KINDS, "trace event")
        self.events.append({"kind": kind, **fields})

    def add_transport(self, kind: str, **fields) -> None:
        """Log one delivery decision (drop/dup/reorder/retransmit/
        pull_timeout) from a lossy link — the TransportFabric's
        recorder hook. ``kind`` validates against
        :data:`repro.obs.names.TRANSPORT_EVENT_KINDS`."""
        from ..obs.names import TRANSPORT_EVENT_KINDS, validate_kind
        validate_kind(kind, TRANSPORT_EVENT_KINDS, "transport event")
        self.transport.append({"kind": kind, **fields})

    @property
    def num_rounds(self) -> int:
        return self.delays.shape[0]

    def _participation_full(self) -> np.ndarray:
        if self.participation is None:
            return np.ones(self.delays.shape[:2], bool)
        return self.participation

    @property
    def complete(self) -> bool:
        """All participating (round, worker) pulls recorded — and no
        phantom rows recorded for absent pairs."""
        p = self._participation_full()[:, :, None]
        return bool(((self.delays >= 0) == p).all())

    def validate(self) -> "DelayTrace":
        if not self.complete:
            raise ValueError("trace has unrecorded (round, worker) pulls "
                             "(or recorded rows for absent workers)")
        mx = int(self.delays.max())
        if mx > self.bound:
            raise ValueError(f"trace violates its own staleness bound: "
                             f"max tau {mx} > T={self.bound}")
        return self

    # ---- replay ----------------------------------------------------------
    def to_delay_model(self):
        """The :class:`~repro.core.space.TraceDelay` that replays this
        trace through ``asybadmm_epoch`` (any space/backend/mesh) —
        carrying the partial-participation mask when the run was
        elastic."""
        from ..core.space import TraceDelay
        self.validate()
        return TraceDelay(self.delays, participation=self.participation)

    # ---- persistence -----------------------------------------------------
    def save(self, path: str) -> str:
        if not str(path).endswith(".npz"):
            path = f"{path}.npz"
        extra = {}
        if self.participation is not None:
            extra["participation"] = self.participation
        if self.events:
            extra["events"] = np.str_(json.dumps(self.events))
        if self.transport:
            extra["transport"] = np.str_(json.dumps(self.transport))
        np.savez(path, delays=self.delays, bound=np.int32(self.bound),
                 discipline=np.str_(self.discipline),
                 meta=np.str_(json.dumps(self.meta)), **extra)
        return path

    # keys every trace file must carry / may carry (optional ones are
    # absent on pre-chaos / reliable-transport files — load defaults
    # them, so old traces keep loading)
    _REQUIRED_KEYS = ("delays", "bound", "discipline")
    _OPTIONAL_KEYS = ("meta", "participation", "events", "transport")

    @staticmethod
    def load(path: str) -> "DelayTrace":
        """Load a saved trace, failing with an ACTIONABLE error — the
        file, the missing/extra key, or the shape that is wrong — on a
        truncated or corrupt npz instead of leaking a raw numpy
        exception from deep inside the zip reader."""
        def bad(problem: str) -> ValueError:
            return ValueError(
                f"DelayTrace.load: {path!r} is not a valid trace file — "
                f"{problem}. Expected an .npz written by DelayTrace.save "
                f"with keys {list(DelayTrace._REQUIRED_KEYS)} (+ optional "
                f"{list(DelayTrace._OPTIONAL_KEYS)}); re-record the trace "
                f"or check the file was fully written.")
        try:
            f = np.load(path, allow_pickle=False)
        except FileNotFoundError:
            raise
        except Exception as e:
            raise bad(f"unreadable as npz ({type(e).__name__}: {e}); the "
                      f"file is likely truncated or not an npz archive") \
                from e
        with f:
            keys = set(f.files)
            missing = [k for k in DelayTrace._REQUIRED_KEYS
                       if k not in keys]
            if missing:
                raise bad(f"missing required key(s) {missing}; "
                          f"found {sorted(keys)}")
            extra = sorted(keys - set(DelayTrace._REQUIRED_KEYS)
                           - set(DelayTrace._OPTIONAL_KEYS))
            if extra:
                raise bad(f"unrecognized key(s) {extra}; this file was "
                          f"not written by DelayTrace.save (or by a "
                          f"newer incompatible version)")
            try:
                delays = np.asarray(f["delays"], np.int32)
                bound = int(f["bound"])
                discipline = str(f["discipline"])
                meta = json.loads(str(f["meta"])) if "meta" in f else {}
                participation = (np.asarray(f["participation"], bool)
                                 if "participation" in f else None)
                events = (json.loads(str(f["events"]))
                          if "events" in f else [])
                transport = (json.loads(str(f["transport"]))
                             if "transport" in f else [])
            except Exception as e:
                raise bad(f"corrupt array/JSON payload "
                          f"({type(e).__name__}: {e})") from e
        if delays.ndim != 3:
            raise bad(f"'delays' must be (rounds, N, M) 3-d; got shape "
                      f"{delays.shape}")
        if participation is not None \
                and participation.shape != delays.shape[:2]:
            raise bad(f"'participation' shape {participation.shape} does "
                      f"not match delays' (rounds, N) = {delays.shape[:2]}")
        return DelayTrace(delays=delays, bound=bound,
                          discipline=discipline, meta=meta,
                          participation=participation, events=events,
                          transport=transport)
