"""DelayTrace — what the PS runtime actually observed, replayable.

Every pull the runtime serves is a (round t, worker i, block j) read of
some committed version u <= t; the trace records the full staleness
matrix ``delays[t, i, j] = t - u``. Because the runtime realizes
Algorithm 1's logical dataflow exactly (round-r pushes commit block
version r+1), replaying a recorded trace through the fast vectorized
``asybadmm_epoch`` via :class:`repro.core.space.TraceDelay` reproduces
the runtime's z trajectory — structurally exact, bitwise on the pallas
backend, fp32-ulp (cross-program XLA fusion) on jnp — the bridge that
lets every scheduling/straggler scenario discovered under the
event-driven runtime re-run at SPMD speed (pinned by
tests/test_ps_runtime.py).

File format (``.npz``): ``delays`` (rounds, N, M) int32, ``bound`` (the
Assumption-3 T the enforcer guaranteed), ``discipline``, and a JSON
``meta`` blob (timing config, seeds, makespan).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict

import numpy as np


@dataclasses.dataclass
class DelayTrace:
    delays: np.ndarray                 # (rounds, N, M) int32; -1 = unrecorded
    bound: int                         # Assumption 3's T enforced at record time
    discipline: str = "lockfree"
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @classmethod
    def empty(cls, num_rounds: int, n_workers: int, n_blocks: int,
              bound: int, discipline: str = "lockfree") -> "DelayTrace":
        return cls(delays=np.full((num_rounds, n_workers, n_blocks), -1,
                                  np.int32),
                   bound=int(bound), discipline=discipline)

    # ---- recording -------------------------------------------------------
    def record(self, t: int, i: int, row) -> None:
        """Record worker i's round-t staleness row (M,)."""
        self.delays[t, i, :] = np.asarray(row, np.int32)

    @property
    def num_rounds(self) -> int:
        return self.delays.shape[0]

    @property
    def complete(self) -> bool:
        return bool((self.delays >= 0).all())

    def validate(self) -> "DelayTrace":
        if not self.complete:
            raise ValueError("trace has unrecorded (round, worker) pulls")
        mx = int(self.delays.max())
        if mx > self.bound:
            raise ValueError(f"trace violates its own staleness bound: "
                             f"max tau {mx} > T={self.bound}")
        return self

    # ---- replay ----------------------------------------------------------
    def to_delay_model(self):
        """The :class:`~repro.core.space.TraceDelay` that replays this
        trace through ``asybadmm_epoch`` (any space/backend/mesh)."""
        from ..core.space import TraceDelay
        return TraceDelay(self.validate().delays)

    # ---- persistence -----------------------------------------------------
    def save(self, path: str) -> str:
        if not str(path).endswith(".npz"):
            path = f"{path}.npz"
        np.savez(path, delays=self.delays, bound=np.int32(self.bound),
                 discipline=np.str_(self.discipline),
                 meta=np.str_(json.dumps(self.meta)))
        return path

    @staticmethod
    def load(path: str) -> "DelayTrace":
        with np.load(path, allow_pickle=False) as f:
            return DelayTrace(
                delays=np.asarray(f["delays"], np.int32),
                bound=int(f["bound"]),
                discipline=str(f["discipline"]),
                meta=json.loads(str(f["meta"])) if "meta" in f else {})
