"""Deterministic fault injection for the PS runtime.

A :class:`FaultPlan` is a declarative, JSON-serializable timeline of
chaos events; the :class:`FaultInjector` turns it into scheduler events
and service-time multipliers inside one ``PSRuntime.run``. Everything
is deterministic: event times are fixed by the plan, stochastic plan
*generation* (:meth:`FaultPlan.churn`) uses the runtime's seeded
per-entity rng convention (``np.random.default_rng([seed, tag])``), and
multipliers scale the draws the per-entity generators were already
making — so a chaos run is exactly as replayable as a fault-free one,
and its recorded :class:`~repro.ps.trace.DelayTrace` (staleness +
participation) reproduces the z trajectory through ``asybadmm_epoch``.

Event kinds
-----------
``crash``        worker ``worker`` dies at sim time ``at``; with
                 ``duration`` it restarts after that much downtime
                 (membership resumes it at the service frontier),
                 without it stays down for good.
``leave``        permanent departure (sugar for a crash without
                 restart, recorded distinctly in the trace events).
``join``         ``worker`` is NOT in the initial fleet; it boots cold
                 at ``at`` and joins at the frontier. Join workers must
                 still be counted in the spec's N — they own edge rows;
                 membership just keeps them absent until activation.
``slowdown``     worker's compute service draws are multiplied by
                 ``factor`` during [at, at+duration) — a transient
                 straggler.
``server_spike`` commit-service draws of the lock domain holding block
                 ``block`` are multiplied by ``factor`` during
                 [at, at+duration) — a slow/hot server.
``link_loss``    a windowed loss burst: every message sent during
                 [at, at+duration) is dropped with probability
                 ``factor`` (composed with the Transport's base
                 drop_rate as ``1-(1-p)(1-q)``), scoped to worker
                 ``worker`` and/or the lock domain holding block
                 ``block`` when given, fleet-wide otherwise. A plan
                 with link_loss events engages the ack/retry transport
                 layer even when the base network is reliable.
``server_crash`` the lock domain holding block ``block`` LOSES its
                 volatile state at ``at`` (in-memory z versions,
                 caches, pending declarations/pushes, queued pulls)
                 and comes back after ``duration`` by replaying its
                 write-ahead commit log (``ps/recovery.py``) — zero
                 committed folds lost. ``duration`` is required: a
                 server that never recovers would deadlock its commit
                 gates. Messages sent to a down server are dropped, so
                 a plan with server_crash events engages the ack/retry
                 transport layer like ``link_loss`` does.
"""
from __future__ import annotations

import dataclasses
import json
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import numpy as np

FAULT_KINDS = ("crash", "leave", "join", "slowdown", "server_spike",
               "link_loss", "server_crash")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    kind: str
    at: float
    worker: Optional[int] = None
    block: Optional[int] = None
    duration: Optional[float] = None
    factor: Optional[float] = None

    def validate(self, num_workers: Optional[int] = None,
                 num_blocks: Optional[int] = None) -> "FaultEvent":
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; expected "
                             f"one of {FAULT_KINDS}")
        if not np.isfinite(self.at) or self.at < 0.0:
            raise ValueError(f"fault time must be finite and >= 0; got "
                             f"at={self.at} for {self.kind}")
        needs_worker = self.kind in ("crash", "leave", "join", "slowdown")
        if needs_worker:
            if self.worker is None:
                raise ValueError(f"{self.kind} event needs a worker id")
            if num_workers is not None and not 0 <= self.worker < num_workers:
                raise ValueError(f"{self.kind} worker {self.worker} outside "
                                 f"[0, {num_workers})")
        if self.kind == "server_spike":
            if self.block is None:
                raise ValueError("server_spike event needs a block id")
            if num_blocks is not None and not 0 <= self.block < num_blocks:
                raise ValueError(f"server_spike block {self.block} outside "
                                 f"[0, {num_blocks})")
        if self.kind in ("slowdown", "server_spike"):
            if self.duration is None or self.duration <= 0.0:
                raise ValueError(f"{self.kind} needs duration > 0; got "
                                 f"{self.duration}")
            if self.factor is None or not np.isfinite(self.factor) \
                    or self.factor <= 0.0:
                raise ValueError(f"{self.kind} needs a finite factor > 0; "
                                 f"got {self.factor}")
        if self.kind == "link_loss":
            if self.duration is None or self.duration <= 0.0:
                raise ValueError(f"link_loss needs duration > 0; got "
                                 f"{self.duration}")
            if self.factor is None or not np.isfinite(self.factor) \
                    or not 0.0 < self.factor <= 1.0:
                raise ValueError(
                    f"link_loss factor is the window's drop probability "
                    f"and must be in (0, 1]; got {self.factor}")
            if self.worker is not None and num_workers is not None \
                    and not 0 <= self.worker < num_workers:
                raise ValueError(f"link_loss worker {self.worker} outside "
                                 f"[0, {num_workers})")
            if self.block is not None and num_blocks is not None \
                    and not 0 <= self.block < num_blocks:
                raise ValueError(f"link_loss block {self.block} outside "
                                 f"[0, {num_blocks})")
        if self.kind == "server_crash":
            if self.block is None:
                raise ValueError("server_crash event needs a block id (it "
                                 "scopes the lock domain holding that block)")
            if num_blocks is not None and not 0 <= self.block < num_blocks:
                raise ValueError(f"server_crash block {self.block} outside "
                                 f"[0, {num_blocks})")
            if self.duration is None or self.duration <= 0.0:
                raise ValueError(
                    f"server_crash needs duration > 0 (the recovery delay; "
                    f"a server that never recovers would deadlock its "
                    f"commit gates); got {self.duration}")
        if self.kind == "crash" and self.duration is not None \
                and self.duration <= 0.0:
            raise ValueError(f"crash downtime must be > 0 (or omitted for "
                             f"a permanent crash); got {self.duration}")
        return self

    def to_dict(self) -> Dict:
        return {k: v for k, v in dataclasses.asdict(self).items()
                if v is not None}


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    events: Tuple[FaultEvent, ...] = ()

    def __post_init__(self):
        evs = tuple(e if isinstance(e, FaultEvent) else FaultEvent(**e)
                    for e in self.events)
        object.__setattr__(self, "events", evs)

    def validate(self, num_workers: Optional[int] = None,
                 num_blocks: Optional[int] = None) -> "FaultPlan":
        for e in self.events:
            e.validate(num_workers, num_blocks)
        # one membership timeline per worker: a worker is either in the
        # initial fleet or a cold joiner, never both
        joiners = self.cold_workers
        for e in self.events:
            if e.kind == "join" and sum(
                    1 for x in self.events
                    if x.kind == "join" and x.worker == e.worker) > 1:
                raise ValueError(f"worker {e.worker} has multiple join "
                                 f"events; use crash+duration for churn")
        for e in self.events:
            if e.kind in ("crash", "leave") and e.worker in joiners \
                    and e.at <= min(x.at for x in self.events
                                    if x.kind == "join"
                                    and x.worker == e.worker):
                raise ValueError(f"worker {e.worker} crashes/leaves before "
                                 f"its join event")
        return self

    @property
    def has_link_loss(self) -> bool:
        """Whether any event is a link_loss burst — the runtime engages
        the unreliable-transport layer when so."""
        return any(e.kind == "link_loss" for e in self.events)

    @property
    def has_server_crash(self) -> bool:
        """Whether any event crashes a block server — the runtime then
        arms the per-domain write-ahead commit log (``ps/recovery.py``)
        and engages the ack/retry transport layer (messages to a down
        server are dropped and must retransmit)."""
        return any(e.kind == "server_crash" for e in self.events)

    @property
    def cold_workers(self) -> frozenset:
        """Workers that boot cold (join events) — excluded from the
        initial fleet by the runtime."""
        return frozenset(e.worker for e in self.events if e.kind == "join")

    # ---- construction helpers ---------------------------------------------
    @classmethod
    def of(cls, *events: FaultEvent) -> "FaultPlan":
        return cls(tuple(events)).validate()

    @staticmethod
    def crash(worker: int, at: float, down: Optional[float] = None
              ) -> FaultEvent:
        return FaultEvent("crash", at, worker=worker, duration=down)

    @staticmethod
    def leave(worker: int, at: float) -> FaultEvent:
        return FaultEvent("leave", at, worker=worker)

    @staticmethod
    def join(worker: int, at: float) -> FaultEvent:
        return FaultEvent("join", at, worker=worker)

    @staticmethod
    def slowdown(worker: int, at: float, duration: float, factor: float
                 ) -> FaultEvent:
        return FaultEvent("slowdown", at, worker=worker, duration=duration,
                          factor=factor)

    @staticmethod
    def server_spike(block: int, at: float, duration: float, factor: float
                     ) -> FaultEvent:
        return FaultEvent("server_spike", at, block=block, duration=duration,
                          factor=factor)

    @staticmethod
    def link_loss(at: float, duration: float, drop: float, *,
                  worker: Optional[int] = None,
                  block: Optional[int] = None) -> FaultEvent:
        """A loss burst: messages during [at, at+duration) drop with
        probability ``drop``, scoped to ``worker``'s links and/or the
        lock domain holding ``block`` when given."""
        return FaultEvent("link_loss", at, worker=worker, block=block,
                          duration=duration, factor=drop)

    @staticmethod
    def server_crash(block: int, at: float, down: float) -> FaultEvent:
        """The lock domain holding ``block`` loses its volatile state at
        ``at`` and recovers by WAL replay after ``down`` sim seconds."""
        return FaultEvent("server_crash", at, block=block, duration=down)

    @classmethod
    def churn(cls, num_workers: int, *, seed: int = 0, crashes: int = 2,
              window: Tuple[float, float] = (2.0, 10.0),
              down: Tuple[float, float] = (2.0, 6.0)) -> "FaultPlan":
        """A deterministic random crash+rejoin plan: ``crashes`` distinct
        workers crash at times ~ U(window) and restart after downtime
        ~ U(down). Draws come from the runtime's per-entity rng
        convention (``default_rng([seed, 77])``), so the same seed
        yields the same plan everywhere."""
        if crashes > num_workers:
            raise ValueError(f"cannot crash {crashes} of {num_workers} "
                             f"workers")
        rng = np.random.default_rng([seed, 77])
        victims = rng.choice(num_workers, size=crashes, replace=False)
        evs = []
        for i in victims:
            at = float(rng.uniform(*window))
            dt = float(rng.uniform(*down))
            evs.append(cls.crash(int(i), at, dt))
        return cls(tuple(evs)).validate(num_workers)

    # ---- persistence ------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({"events": [e.to_dict() for e in self.events]},
                          indent=2)

    @classmethod
    def from_json(cls, text: str, *,
                  source: str = "<fault plan>") -> "FaultPlan":
        """Parse a fault-plan JSON document. Errors are actionable —
        they name the source (``FaultPlan.load`` passes the file path)
        and the offending event index instead of leaking a bare
        ``JSONDecodeError`` / ``KeyError`` / ``TypeError``."""
        def bad(problem, idx=None):
            where = f"event {idx}: " if idx is not None else ""
            return ValueError(
                f"FaultPlan: {source} is not a valid fault plan — "
                f"{where}{problem}. Expected "
                f'{{"events": [{{"kind": ..., "at": <sim time>, ...}}]}} '
                f"with kinds {FAULT_KINDS} (schema in API.md's elastic-PS "
                f"section).")

        try:
            obj = json.loads(text)
        except json.JSONDecodeError as e:
            raise bad(f"corrupt JSON ({e})") from e
        if not isinstance(obj, dict) \
                or not isinstance(obj.get("events", []), list):
            raise bad("top level must be an object with an 'events' list")
        events = []
        for idx, spec in enumerate(obj.get("events", [])):
            if not isinstance(spec, dict):
                raise bad(f"must be an object, got {type(spec).__name__}",
                          idx)
            try:
                ev = FaultEvent(**spec)
            except TypeError as e:
                raise bad(f"{e}; the only fields are kind, at, worker, "
                          f"block, duration, factor", idx) from e
            try:
                ev.validate()
            except (ValueError, TypeError) as e:
                raise bad(str(e), idx) from e
            events.append(ev)
        try:
            return cls(tuple(events)).validate()
        except ValueError as e:
            raise bad(str(e)) from e

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json())
        return path

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        with open(path) as f:          # FileNotFoundError names the path
            text = f.read()
        return cls.from_json(text, source=repr(path))


class FaultInjector:
    """Drives one runtime's chaos: schedules the plan's membership
    transitions and answers multiplier queries for service draws.

    The injector never touches numerics — it only moves membership
    state (through ``PSRuntime._crash_worker`` / ``_rejoin_worker``)
    and scales the durations the per-entity rngs already drew, so the
    recorded trace stays the single source of replay truth."""

    def __init__(self, plan: Optional[FaultPlan], runtime):
        self.plan = plan if plan is not None else FaultPlan()
        self.rt = runtime
        self.fired = set()                         # action keys already run
        self._worker_windows = defaultdict(list)   # i -> [(s, e, factor)]
        self._block_windows = defaultdict(list)    # j -> [(s, e, factor)]
        # [(s, e, drop_p, worker|None, block|None)] — queried per send
        self._link_windows = []
        for e in self.plan.events:
            if e.kind == "slowdown":
                self._worker_windows[e.worker].append(
                    (e.at, e.at + e.duration, e.factor))
            elif e.kind == "server_spike":
                self._block_windows[e.block].append(
                    (e.at, e.at + e.duration, e.factor))
            elif e.kind == "link_loss":
                self._link_windows.append(
                    (e.at, e.at + e.duration, e.factor, e.worker, e.block))

    def install(self, *, fired=(), floor: float = 0.0,
                log_windows: bool = True) -> None:
        """Schedule the plan's membership/server transitions (before
        t=0 worker starts, so same-time ties resolve plan-first —
        deterministically either way, by insertion seq). Every action
        is keyed ("<event idx>:<action>") and marks ``self.fired`` when
        it runs; a mid-run resume re-installs only the not-yet-fired
        actions (``fired=`` from the snapshot) at ``max(at, floor)``
        with ``floor`` = the restored clock. All actions carry the
        scheduler tag "fault" so the snapshot coordinator can tell
        pending chaos apart from in-flight work when it checks for
        quiescence."""
        sched = self.rt.sched
        self.fired = set(fired)

        def arm(key, at, fn):
            if key in self.fired:
                return

            def run():
                self.fired.add(key)
                fn()
            sched.at(max(at, floor), run, tag="fault")

        for idx, e in enumerate(self.plan.events):
            if e.kind in ("slowdown", "server_spike", "link_loss") \
                    and log_windows:
                # factor windows are queried, not scheduled — log them
                # into the trace timeline up front (a resumed run
                # restores the trace events instead of re-logging)
                self.rt.trace.add_event(e.kind, **{
                    k: v for k, v in e.to_dict().items() if k != "kind"})
            if e.kind == "crash":
                arm(f"{idx}:crash", e.at,
                    lambda i=e.worker: self.rt._crash_worker(i))
                if e.duration is not None:
                    arm(f"{idx}:rejoin", e.at + e.duration,
                        lambda i=e.worker: self.rt._rejoin_worker(i))
            elif e.kind == "leave":
                arm(f"{idx}:leave", e.at,
                    lambda i=e.worker: self.rt._crash_worker(
                        i, permanent=True))
            elif e.kind == "join":
                arm(f"{idx}:join", e.at,
                    lambda i=e.worker: self.rt._rejoin_worker(i, cold=True))
            elif e.kind == "server_crash":
                arm(f"{idx}:server_crash", e.at,
                    lambda j=e.block: self.rt._crash_server(j))
                arm(f"{idx}:server_recover", e.at + e.duration,
                    lambda j=e.block: self.rt._recover_server(j))

    # ---- multiplier queries -----------------------------------------------
    @staticmethod
    def _factor(windows, now: float) -> float:
        f = 1.0
        for (s, e, fac) in windows:
            if s <= now < e:
                f *= fac
        return f

    def worker_factor(self, i: int, now: float) -> float:
        """Compute-service multiplier for worker i at sim time ``now``."""
        w = self._worker_windows.get(i)
        return self._factor(w, now) if w else 1.0

    def server_factor(self, block_ids, now: float) -> float:
        """Commit-service multiplier for a lock domain holding
        ``block_ids`` at sim time ``now`` (spikes compose across the
        held blocks — a locked full-vector domain feels every spike)."""
        f = 1.0
        for j in block_ids:
            w = self._block_windows.get(j)
            if w:
                f *= self._factor(w, now)
        return f

    def link_drop(self, worker: int, block_ids, now: float) -> float:
        """Burst drop probability for a (worker, lock domain) link at
        sim time ``now``: overlapping windows compose as independent
        loss processes, ``1 - prod(1 - p_k)``. A window scoped to a
        worker/block applies only to links touching it; unscoped
        windows apply fleet-wide."""
        keep = 1.0
        for (s, e, p, w, b) in self._link_windows:
            if not s <= now < e:
                continue
            if w is not None and w != worker:
                continue
            if b is not None and b not in block_ids:
                continue
            keep *= 1.0 - p
        return 1.0 - keep

    @property
    def empty(self) -> bool:
        return not self.plan.events
