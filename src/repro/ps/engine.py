"""SpaceEngine — the PS runtime's bridge to the real `VariableSpace`.

The runtime's workers and servers execute the SAME jitted hot-path ops
the vectorized epoch runs — ``worker_grads`` + ``worker_select_update``
on the worker side, ``server_consensus_update`` on the server side —
so the jnp and pallas backends both execute under the event-driven
runtime, and a recorded trace replays through ``asybadmm_epoch``
(structurally exact; bitwise on pallas, fp32-ulp cross-program XLA
fusion on jnp). Exactness rests on two verified properties of those
ops:

* **row locality** — every worker-side op is row-independent over the
  leading worker axis, so calling it at the epoch's FULL (N, ...)
  shape with only worker i's row live (zeros elsewhere) yields worker
  i's row bit-identical to the epoch's batched call (a per-worker
  N=1 vmap would NOT: XLA batched-matmul accumulation differs across
  batch sizes);
* **column locality** — the server reduce+prox on a single block's
  (N, 1, dblk) column equals that block's column of the full-grid
  call, so lock-free per-block commits are exact.

The engine also owns the epoch's per-round rng chain (delay key burned,
selection/minibatch keys consumed), the block split/join of the
consensus representation, and per-block caches — everything numeric;
the runtime modules own only *time*.

Both spaces arrive here in the canonical packed block representation
(z is an (M, dblk) table, worker bundles (N, M, dblk) — TreeSpace
lowers its leaves onto it via ``core.blocks.BlockLayout``), so block j
of EVERY space is row j: the lock domains' block ids, the per-block
caches and the column-local commits are one code path, and pytree
models run under ``lockfree``/``locked`` identically to flat ones.
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.space import (BLOCK_SELECTORS, ConsensusSpec, SelectorContext,
                          epoch_keys)
from ..core.async_sim import subsample_worker_data


class SpaceEngine:
    """Numeric services for one :class:`ConsensusSpec`."""

    def __init__(self, spec: ConsensusSpec):
        space = spec.space
        if getattr(space, "mesh", None) is not None:
            # the runtime IS the distribution model; numerics run local
            space = dataclasses.replace(space, mesh=None)
        self.spec = spec
        self.space = space
        self.N = space.num_workers
        self.M = space.num_blocks
        self.edge = np.asarray(spec.edge, bool)
        self.rho_sum = jnp.sum(
            jnp.where(spec.edge, spec.rho_vec[:, None], 0.0), axis=0)
        # epoch rng chain: (r_delay, r_sel, r_batch) per round — the
        # delay key is burned unused (the runtime's delays are OBSERVED,
        # not drawn), which keeps the chain identical to a TraceDelay
        # replay, where sample() ignores the same key
        self._rng = jax.random.PRNGKey(spec.seed)
        self._keys: List[Tuple] = []
        self._sel_cache = {}               # t -> (N, M) bool, grad-free only
        self._jit_cache = {}

    # ------------------------------------------------------------------
    # rng chain + selection + minibatch
    # ------------------------------------------------------------------
    def keys(self, t: int) -> Tuple:
        while len(self._keys) <= t:
            nxt, r_delay, r_sel, r_batch = epoch_keys(
                self._rng, self.spec.minibatch)
            self._rng = nxt
            self._keys.append((r_delay, r_sel, r_batch))
        return self._keys[t]

    def needs_grads_for_select(self) -> bool:
        """Whether the selector must see real gradient norms. The
        built-in ``random``/``cyclic`` policies are known gradient-free,
        as is any selector carrying a truthy ``gradient_free`` attribute
        (the ``zipf`` family sets it — ``make_zipf_selector`` returns
        fresh closures, so identity against the registry can't cover
        them); everything else (gauss_southwell, custom registrations)
        is conservatively fed worker i's true grad_sqnorm row — the
        runtime evaluates the selector at full (N, M) shape with only
        that row live, so any selector whose row i depends only on row
        i of grad_sqnorm replays exactly."""
        sel = self.spec.selector
        if getattr(sel, "gradient_free", False):
            return False
        return sel not in (BLOCK_SELECTORS.get("random"),
                           BLOCK_SELECTORS.get("cyclic"))

    def select(self, t: int, i: int, gnorm_row) -> np.ndarray:
        """Worker i's round-t block selection — the epoch's selector
        evaluated on the epoch's r_sel key; returns a bool (M,) row.
        Gradient-free selectors depend only on (key, t), so their full
        (N, M) matrix is computed once per round and served row-wise."""
        if gnorm_row is None:
            cached = self._sel_cache.get(t)
            if cached is None:
                cached = self._sel_cache[t] = self._select_full(t, None, 0)
            return cached[i]
        return self._select_full(t, gnorm_row, i)[i]

    def _select_full(self, t: int, gnorm_row, i: int) -> np.ndarray:
        fn = self._jit("sel", self._build_sel)
        buf = jnp.zeros((self.N, self.M), jnp.float32)
        if gnorm_row is not None:
            buf = buf.at[i].set(jnp.asarray(gnorm_row, jnp.float32))
        return np.asarray(fn(self.keys(t)[1], jnp.asarray(t, jnp.int32),
                             buf))

    def _build_sel(self):
        spec = self.spec

        def sel_fn(key, t, gnorm_buf):
            ctx = SelectorContext(rng=key, edge=spec.edge, t=t,
                                  block_fraction=spec.block_fraction,
                                  grad_sqnorm=lambda: gnorm_buf)
            return spec.selector(ctx)
        return jax.jit(sel_fn)

    def round_data(self, t: int, data):
        """The round-t (possibly minibatched) full-N data — the same
        subsample the epoch's ``worker_grads(minibatch=, rng=)`` draws."""
        if self.spec.minibatch is None:
            return data
        return subsample_worker_data(self.keys(t)[2], data,
                                     self.spec.minibatch)

    # ------------------------------------------------------------------
    # state construction
    # ------------------------------------------------------------------
    def init(self, z0=None):
        """(z0_repr, y, w_cache, x) — Algorithm 1 lines 1-2, the same
        init as ``init_consensus_state`` minus the ring buffer (the
        servers' version lists play that role)."""
        space, spec = self.space, self.spec
        z0r = space.init_repr(z0)
        y = space.zeros_workers(z0r)
        w = space.workers_scaled(z0r, spec.rho_vec)
        x = space.broadcast_workers(z0r) if spec.track_x else ()
        return z0r, y, w, x

    # ------------------------------------------------------------------
    # block split / join of the packed consensus representation
    # ------------------------------------------------------------------
    def split_blocks(self, z) -> list:
        """Packed z (M, dblk) -> per-block contents ((dblk,) rows —
        block j of either space IS row j of the packed table)."""
        return [z[j] for j in range(self.M)]

    def join_blocks(self, contents: list):
        """Per-block (dblk,) rows -> packed z (M, dblk)."""
        return jnp.stack(contents)

    # ------------------------------------------------------------------
    # worker side — epoch-shaped calls with one live row
    # ------------------------------------------------------------------
    def z_tilde_buffer(self, i: int, contents: list):
        """Embed worker i's mixed-version pull (per-block contents) as
        row i of an otherwise-zero full (N, ...) z~ bundle."""
        z_row = self.join_blocks(contents)
        fn = self._jit("embed", self._build_embed)
        return fn(z_row, jnp.asarray(i, jnp.int32))

    def _build_embed(self):
        N = self.N

        def embed(z_row, i):
            return jax.tree.map(
                lambda zl: jnp.zeros((N,) + zl.shape, zl.dtype).at[i].set(zl),
                z_row)
        return jax.jit(embed)

    def grads(self, z_buf, data):
        """THE epoch gradient call (full-N ``space.worker_grads``) plus
        per-block sq-norms; rows other than the live one are garbage."""
        fn = self._jit("grads", self._build_grads)
        return fn(z_buf, data)

    def _build_grads(self):
        spec, space = self.spec, self.space

        def g(z_buf, data):
            losses, grad = space.worker_grads(spec.loss_fn, z_buf, data)
            return losses, grad, space.grad_sqnorm(grad)
        return jax.jit(g)

    def update(self, i: int, g_buf, zt_buf, y, w, x, sel_row):
        """THE epoch worker update (full-N ``worker_select_update``)
        with only row i's selection live; merges row i of the outputs
        back into the (y, w, x) stores and returns the new stores."""
        fn = self._jit("update", self._build_update)
        sel_buf = jnp.zeros((self.N, self.M), bool).at[i].set(
            jnp.asarray(sel_row, bool))
        return fn(g_buf, zt_buf, y, w, x, sel_buf, jnp.asarray(i, jnp.int32))

    def _build_update(self):
        spec, space = self.spec, self.space

        def upd(g_buf, zt_buf, y, w, x, sel_buf, i):
            y2, w2, x2 = space.worker_select_update(
                g_buf, y, zt_buf, w, x, sel_buf, spec.rho_vec, spec.track_x)
            merge = lambda store, out: jax.tree.map(
                lambda s, o: s.at[i].set(o[i]), store, out)
            return merge(y, y2), merge(w, w2), (
                merge(x, x2) if spec.track_x else x)
        return jax.jit(upd)

    # ------------------------------------------------------------------
    # server side — per-block caches + commits
    # ------------------------------------------------------------------
    def block_cache(self, w_store, j: int):
        """Block j's server-side stale-w~ cache: column j of the packed
        (N, M, dblk) bundle, an (N, dblk) slab."""
        return w_store[:, j]

    def push_value(self, w_store, i: int, j: int):
        """Worker i's fresh w for block j (what a push carries)."""
        return w_store[i, j]

    def apply_push(self, cache, i: int, value):
        """Overwrite worker i's row of a block cache with a pushed w."""
        return cache.at[i].set(value)

    def commit_block(self, j: int, z_content, cache):
        """Block j's server update (13) — the REAL jitted
        ``server_consensus_update`` on the block's column (exact vs the
        full-grid epoch call; see module docstring). ONE compilation
        serves every block of either space — all columns share the
        packed (N, dblk) shape."""
        fn = self._jit("commit", self._build_commit)
        return fn(z_content, cache, jnp.asarray(self.edge[:, j:j + 1]),
                  self.rho_sum[j:j + 1])

    def _build_commit(self):
        spec, space = self.spec, self.space

        def commit(z_col, w_col, e_col, rs):
            out = space.server_consensus_update(
                z_col[None], w_col[:, None, :], e_col, rs,
                spec.gamma, spec.reg)
            return out[0]
        return jax.jit(commit)

    # ------------------------------------------------------------------
    def _jit(self, key, builder):
        fn = self._jit_cache.get(key)
        if fn is None:
            fn = self._jit_cache[key] = builder()
        return fn
