"""Durability + recovery for the PS runtime — the restartable service.

Two failure models, two mechanisms, one determinism contract:

**Block-server crash** (`server_crash` fault, :mod:`repro.ps.chaos`).
Each lock domain armed for crashes owns a :class:`DomainWAL` — a
write-ahead commit log on simulated stable storage. Every declaration
(round intent + push payloads) is logged *before* any queue or commit
processing, and every committed version logs its fold order *before*
the version publish. A crash drops the server's volatile state — the
in-memory z version history, w~ caches, pending declarations/pushes,
queued pulls — and recovery rebuilds it exactly by replaying the log
through the same ``engine.apply_push`` / ``engine.commit_block`` fold
path the live server uses (the jitted ``_PackedOps`` kernels), so the
rebuilt contents are **bitwise** what the crash-free fold produced:
zero committed folds lost. Uncommitted-but-logged declarations are
re-installed through the service queue in arrival order (the queue
itself was volatile, so its processing cost is re-paid — recovery
changes *timing*, never committed numerics). Messages sent to a down
server drop at the server, and the ack/retry transport layer's
retransmission recovers them — which is why a plan with
``server_crash`` events engages the transport layer like ``link_loss``
does.

**Whole-process kill** (``run_ps(checkpoint_every=, checkpoint_dir=,
resume_from=)``). The :class:`SnapshotCoordinator` takes a
crash-consistent snapshot of the *entire* runtime every
``checkpoint_every`` rounds using a quiescent barrier: workers park at
the top of each barrier round, and once every in-flight event has
drained (only the fault injector's future timeline remains queued —
the scheduler's ``only_tagged("fault")`` test) and no pull is parked
at the staleness enforcer, the full state — server version histories
and caches per domain, worker y/w/x, staleness counters, membership
intervals, every per-entity rng state, the DES clock, the partial
:class:`~repro.ps.trace.DelayTrace`, per-round losses, and the fault
timeline's fired-set — is written atomically via
:mod:`repro.checkpoint` (temp file + rename; a kill mid-save leaves
the previous snapshot intact). Parked workers are then released in
worker-id order at the barrier time.

Resume (``resume_from=``) rebuilds the runtime normally, restores the
clock and every piece of saved state, re-arms only the *not-yet-fired*
fault events (at ``max(at, clock)``), and schedules the parked
workers' releases exactly as the straight run's barrier did. Because
the barrier is part of the run's schedule, the contract is:

* a run with ``checkpoint_every=E`` killed after any snapshot and
  resumed from it produces a final z, z history, trace, fold log,
  losses and makespan **identical** (bitwise on pallas, same arrays on
  jnp — restore feeds back the exact saved bytes) to the same run left
  uninterrupted;
* ``checkpoint_every=None`` is byte-identical to the pre-durability
  runtime (no barrier, no hook, no WAL unless ``server_crash`` faults
  arm it).

What is restored vs recomputed: engine key chains, selector caches and
per-round data derive purely from the seed and round index, so they
are recomputed, not stored; everything stateful (rngs, clocks,
counters, intervals, arrays) is restored. Snapshots require a reliable
network (in-flight retransmission timers are not snapshotable) and
real compute; ``server_crash`` faults therefore do not compose with
``checkpoint_every`` — WAL recovery covers the server side, snapshots
cover the process side.
"""
from __future__ import annotations

import dataclasses
import os
from collections import defaultdict
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from ..checkpoint import load_arrays, load_extra, save

SNAPSHOT_FORMAT = "ps-snapshot-v1"
_PREFIX = "snap-"


# ---------------------------------------------------------------------------
# write-ahead commit log (per lock domain)
# ---------------------------------------------------------------------------

class DomainWAL:
    """Simulated stable storage for one lock domain.

    Two record streams, both append-only and idempotent:

    * **declare records** — keyed ``(worker, round)`` (the same dedup
      key the transport commit gate uses), holding the round's push
      payloads ``[(block, value)]`` in arrival order. Logged before the
      server touches its queue: write-ahead.
    * **commit records** — ``commits[v]`` is version v's fold order
      ``[(worker, block)]``, logged before the version publish. Replay
      walks them in order, looking each fold's payload up in the
      declare records, through the same engine fold path — bitwise.
    """

    def __init__(self, sid: int):
        self.sid = sid
        # (worker, round) -> [(block, value)], insertion = arrival order
        self._decls: Dict[Tuple[int, int], list] = {}
        self.commits: List[Tuple[Tuple[int, int], ...]] = []
        self.dedup_skips = 0
        self.replays = 0

    def record_declare(self, i: int, t: int, pushes: list) -> bool:
        """Append worker i's round-t declaration; a duplicate key is a
        no-op (the log is idempotent under retransmission)."""
        key = (i, t)
        if key in self._decls:
            self.dedup_skips += 1
            return False
        self._decls[key] = list(pushes)
        return True

    def record_commit(self, v: int, folds: list) -> None:
        """Append version v's fold order. Versions commit in sequence,
        so a redone commit (the in-flight one a crash stranded) lands
        exactly where the lost attempt would have."""
        if v != len(self.commits):
            raise RuntimeError(
                f"WAL commit record out of sequence: version {v} logged "
                f"with {len(self.commits)} commits on record")
        self.commits.append(tuple((i, j) for (i, j) in folds))

    def value(self, i: int, t: int, j: int):
        """The logged push payload for (worker i, round t, block j)."""
        for (jj, value) in self._decls[(i, t)]:
            if jj == j:
                return value
        raise KeyError(f"WAL has no push for worker {i} round {t} "
                       f"block {j}")

    def pending(self, version: int):
        """Declarations for rounds >= ``version`` (not yet folded into
        a committed version), in arrival order — what recovery
        re-installs through the service queue."""
        return [(i, t, list(pushes))
                for (i, t), pushes in self._decls.items() if t >= version]

    @property
    def declares(self) -> int:
        return len(self._decls)


def register_wal_metrics(reg, domains) -> None:
    """Register the durability instruments (WAL record totals +
    recovery count) over the run's armed lock domains."""
    reg.counter("server_recoveries",
                lambda: sum(d.recoveries for d in domains))
    reg.gauge("wal", lambda: {
        "commits": sum(len(d.wal.commits) for d in domains),
        "declares": sum(d.wal.declares for d in domains),
        "dedup_skips": sum(d.wal.dedup_skips for d in domains),
        "replays": sum(d.wal.replays for d in domains)})


# ---------------------------------------------------------------------------
# crash-consistent snapshots (quiescent barrier)
# ---------------------------------------------------------------------------

class SnapshotCoordinator:
    """Parks workers at rounds E, 2E, ... and writes one atomic
    snapshot per barrier once the runtime is quiescent.

    Quiescence = every alive, unfinished worker is parked AND the
    scheduler's queue holds only the fault injector's future timeline
    AND no pull is parked at the staleness enforcer — i.e. nothing is
    in flight, so the state on the heap IS the state of the run. The
    check runs from the scheduler's ``after_event`` hook; parked
    workers are released in worker-id order at the barrier time, which
    makes the barrier a deterministic part of the run's schedule (a
    resumed run re-creates the identical releases)."""

    def __init__(self, runtime, every: int, directory: str):
        self.rt = runtime
        self.every = int(every)
        self.dir = str(directory)
        self.next_round = self.every
        self.parked: Dict[int, int] = {}     # worker id -> parked round
        self.written: List[str] = []
        # telemetry anchor: sim time the first worker parked at the
        # pending barrier (the "snapshot" span's start)
        self._barrier_start: Optional[float] = None

    @property
    def active(self) -> bool:
        """Barriers land strictly inside the horizon — a final-round
        snapshot would duplicate the run's own result."""
        return self.next_round < self.rt.num_rounds

    def park(self, wk, t: int) -> bool:
        """Worker ``wk`` is entering round t; park it when the round is
        at/past the next barrier. Returns True when parked (the worker
        resumes via the barrier's release)."""
        if not self.active or t < self.next_round:
            return False
        if self._barrier_start is None:
            self._barrier_start = self.rt.sched.now
        self.parked[wk.i] = t
        return True

    def unpark(self, i: int) -> None:
        """Worker i crashed while parked — it no longer blocks (or
        rides) the barrier; membership already marked it absent."""
        self.parked.pop(i, None)

    def check(self) -> None:
        """The scheduler's after-event hook: fire the barrier once the
        runtime is quiescent."""
        if not self.active:
            return
        rt = self.rt
        for wk in rt._workers:
            if wk.alive and wk.t < rt.num_rounds and wk.i not in self.parked:
                return
        if not rt.sched.only_tagged("fault"):
            return
        if not rt.enforcer.idle:
            return
        self._fire()

    def _fire(self) -> None:
        rt = self.rt
        self.written.append(
            write_snapshot(rt, self.dir, self.next_round, self.parked))
        obs = rt.obs
        if obs is not None and obs.spans is not None:
            start = self._barrier_start if self._barrier_start is not None \
                else rt.sched.now
            obs.spans.complete(obs.RUNTIME_TRACK, "snapshot",
                               start, rt.sched.now,
                               round=self.next_round,
                               path=self.written[-1],
                               parked=len(self.parked))
        self._barrier_start = None
        self.next_round += self.every
        parked, self.parked = self.parked, {}
        for i in sorted(parked):
            wk = rt._workers[i]
            rt.sched.at(rt.sched.now, wk._guarded(
                lambda wk=wk, t=parked[i]: wk._begin_round(t)))

    def register_metrics(self, reg) -> None:
        reg.gauge("snapshots", lambda: list(self.written))


# ---------------------------------------------------------------------------
# snapshot serialization
# ---------------------------------------------------------------------------

def snapshot_path(directory: str, round_: int) -> str:
    return os.path.join(directory, f"{_PREFIX}{int(round_):06d}")


def list_snapshots(directory: str) -> List[str]:
    """Snapshot path prefixes in ``directory``, oldest round first."""
    out = []
    for name in sorted(os.listdir(directory)):
        if name.startswith(_PREFIX) and name.endswith(".json"):
            out.append(os.path.join(directory, name[:-len(".json")]))
    return out


def latest_snapshot(directory: str) -> Optional[str]:
    snaps = list_snapshots(directory)
    return snaps[-1] if snaps else None


def _fingerprint(rt) -> Dict[str, Any]:
    """The run-shape identity a snapshot is only valid against."""
    eng = rt.engine
    return {
        "space": type(eng.space).__name__,
        "workers": int(eng.N),
        "blocks": int(eng.M),
        "num_rounds": int(rt.num_rounds),
        "discipline": rt.discipline,
        "seed": int(rt.seed),
        "bound": int(rt.bound),
        "record_z": bool(rt.record_z),
        "minibatch": rt.spec.minibatch,
        "checkpoint_every": rt.ckpt.every if rt.ckpt is not None else None,
    }


def write_snapshot(rt, directory: str, round_: int,
                   parked: Dict[int, int]) -> str:
    """Serialize the quiescent runtime. Arrays go into the npz half,
    everything else (rng states, clocks, counters, intervals, the fault
    timeline's fired-set) into the manifest's ``extra`` blob; both land
    atomically via :func:`repro.checkpoint.save`."""
    arrays: Dict[str, Any] = {"trace/delays": np.array(rt.trace.delays)}
    if not rt.timing_only:
        arrays["state/y"] = np.asarray(rt.y)
        arrays["state/w"] = np.asarray(rt.w)
        if not isinstance(rt.x, tuple):
            arrays["state/x"] = np.asarray(rt.x)
    domains_meta = []
    for dom in rt.domains:
        versions = {}
        for j in dom.block_ids:
            store = dom.contents.get(j, {})
            for v, arr in store.items():
                arrays[f"dom{dom.sid}/content/{j}/{v}"] = np.asarray(arr)
            versions[str(j)] = sorted(store)
            if j in dom.caches:
                arrays[f"dom{dom.sid}/cache/{j}"] = np.asarray(dom.caches[j])
        domains_meta.append({
            "sid": dom.sid, "version": dom.version,
            "busy_until": dom.busy_until, "busy_time": dom.busy_time,
            "wait_time": dom.wait_time, "wait_count": dom.wait_count,
            "commits": dom.commits, "pushes": dom.pushes,
            "content_versions": versions,
            "fold_log": [list(e) for e in dom.fold_log],
            "rng": dom.rng.bit_generator.state,
        })
    workers_meta = [{
        "i": wk.i, "t": wk.t, "alive": wk.alive, "gen": wk.gen,
        "rounds_done": wk.rounds_done, "parked": wk.i in parked,
        "rng": wk.rng.bit_generator.state,
    } for wk in rt._workers]
    enf = rt.enforcer
    meta = {
        "format": SNAPSHOT_FORMAT,
        "round": int(round_),
        "clock": float(rt.sched.now),
        "fingerprint": _fingerprint(rt),
        "workers": workers_meta,
        "domains": domains_meta,
        "enforcer": {
            "pulls_served": enf.pulls_served,
            "max_served_tau": enf.max_served_tau,
            "stall_count": enf.stall_count,
            "stall_time": enf.stall_time,
            "dropped_pulls": enf.dropped_pulls,
            "version_resets": enf.version_resets,
            "timeout_fallbacks": enf.timeout_fallbacks,
            "stall_time_by_worker": dict(enf.stall_time_by_worker),
            "stall_count_by_worker": dict(enf.stall_count_by_worker),
        },
        "membership": rt.membership.state_dict(),
        "losses": rt._losses,
        "trace_events": rt.trace.events,
        "injector_fired": sorted(rt.injector.fired),
    }
    prefix = snapshot_path(directory, round_)
    save(prefix, arrays, step=int(round_), extra=meta)
    return prefix


@dataclasses.dataclass
class SnapshotState:
    """A loaded, format-validated snapshot ready for :func:`resume`."""
    path: str
    meta: Dict[str, Any]
    arrays: Dict[str, np.ndarray]


def load_snapshot(path: str) -> SnapshotState:
    """Load a snapshot by path prefix, ``.json``/``.npz`` half, or the
    checkpoint directory (resolves to the latest snapshot)."""
    path = os.fspath(path)
    if os.path.isdir(path):
        latest = latest_snapshot(path)
        if latest is None:
            raise FileNotFoundError(
                f"no PS snapshots ({_PREFIX}NNNNNN.json) in directory "
                f"{path!r} — nothing to resume from")
        path = latest
    if path.endswith(".json") or path.endswith(".npz"):
        path = path[:path.rfind(".")]
    meta = load_extra(path)
    fmt = meta.get("format") if isinstance(meta, dict) else None
    if fmt != SNAPSHOT_FORMAT:
        raise ValueError(
            f"{path!r} is not a PS runtime snapshot (manifest extra "
            f"format={fmt!r}, expected {SNAPSHOT_FORMAT!r}) — point "
            f"resume_from at a snapshot written by "
            f"run_ps(checkpoint_every=...)")
    return SnapshotState(path=path, meta=meta, arrays=load_arrays(path))


def resume(rt, snap: SnapshotState) -> None:
    """Restore a constructed-but-unlaunched runtime to the snapshot's
    quiescent barrier and arm it: clock, every entity's state and rng,
    the not-yet-fired fault timeline, and the parked workers' releases.
    The caller (``PSRuntime.run``) skips its normal t=0 launch."""
    import jax.numpy as jnp

    meta, arrays = snap.meta, snap.arrays
    current = _fingerprint(rt)
    saved = meta.get("fingerprint", {})
    diffs = [f"{k}: snapshot={saved.get(k)!r} vs run={current[k]!r}"
             for k in current if saved.get(k) != current[k]]
    if diffs:
        raise ValueError(
            f"snapshot {snap.path!r} was taken from a different run "
            f"configuration — resume requires the identical session "
            f"and run_ps arguments. Mismatched: {'; '.join(diffs)}")
    sched = rt.sched
    sched.restore_clock(meta["clock"])
    # chaos timeline first (smaller seqs), so same-time ties against
    # the releases pop in the straight run's order
    rt.injector.install(fired=meta["injector_fired"], floor=sched.now,
                        log_windows=False)
    for wmeta in meta["workers"]:
        wk = rt._workers[wmeta["i"]]
        wk.t = wmeta["t"]
        wk.alive = wmeta["alive"]
        wk.gen = wmeta["gen"]
        wk.rounds_done = wmeta["rounds_done"]
        wk.rng.bit_generator.state = wmeta["rng"]
    for dmeta in meta["domains"]:
        dom = rt.domains[dmeta["sid"]]
        dom.version = dmeta["version"]
        dom.busy_until = dmeta["busy_until"]
        dom.busy_time = dmeta["busy_time"]
        dom.wait_time = dmeta["wait_time"]
        dom.wait_count = dmeta["wait_count"]
        dom.commits = dmeta["commits"]
        dom.pushes = dmeta["pushes"]
        dom.fold_log = [tuple(e) for e in dmeta["fold_log"]]
        dom.rng.bit_generator.state = dmeta["rng"]
        if not rt.timing_only:
            dom.contents = {j: {} for j in dom.block_ids}
            dom.caches = {}
            for j in dom.block_ids:
                for v in dmeta["content_versions"][str(j)]:
                    dom.contents[j][int(v)] = jnp.asarray(
                        arrays[f"dom{dom.sid}/content/{j}/{v}"])
                dom.caches[j] = jnp.asarray(
                    arrays[f"dom{dom.sid}/cache/{j}"])
    if not rt.timing_only:
        rt.y = jnp.asarray(arrays["state/y"])
        rt.w = jnp.asarray(arrays["state/w"])
        if "state/x" in arrays:
            rt.x = jnp.asarray(arrays["state/x"])
    e = meta["enforcer"]
    enf = rt.enforcer
    enf.pulls_served = e["pulls_served"]
    enf.max_served_tau = e["max_served_tau"]
    enf.stall_count = e["stall_count"]
    enf.stall_time = e["stall_time"]
    enf.dropped_pulls = e["dropped_pulls"]
    enf.version_resets = e["version_resets"]
    enf.timeout_fallbacks = e["timeout_fallbacks"]
    enf.stall_time_by_worker = defaultdict(
        float, {int(k): v for k, v in e["stall_time_by_worker"].items()})
    enf.stall_count_by_worker = defaultdict(
        int, {int(k): v for k, v in e["stall_count_by_worker"].items()})
    rt.membership.restore_state(meta["membership"])
    rt.trace.delays = np.asarray(arrays["trace/delays"], np.int32)
    rt.trace.events = list(meta["trace_events"])
    if rt._losses is not None:
        rt._losses = [list(l) for l in meta["losses"]]
    if rt.ckpt is not None:
        rt.ckpt.next_round = meta["round"] + rt.ckpt.every
    # the straight run's barrier released parked workers in worker-id
    # order at the barrier time; re-create exactly those events
    for wmeta in meta["workers"]:
        if wmeta["parked"] and wmeta["alive"]:
            wk = rt._workers[wmeta["i"]]
            sched.at(sched.now, wk._guarded(
                lambda wk=wk, t=wmeta["t"]: wk._begin_round(t)))
