"""PSRuntime — wire scheduler + servers + workers + enforcer together.

One call, two products:

* **what happened** — makespan, per-domain queue occupancy, stall
  statistics (the coordination-scalability quantities Table 1
  measures), per-round losses when numerics run;
* **what to replay** — a validated :class:`DelayTrace` whose
  ``TraceDelay`` reproduces the runtime's z trajectory through the
  fast vectorized ``asybadmm_epoch`` (flat/tree, jnp/pallas,
  single-device/SPMD): structurally exact, bitwise on pallas,
  fp32-ulp (cross-program XLA fusion) on jnp.

Numerics run through :class:`~repro.ps.engine.SpaceEngine` (the real
jitted ``VariableSpace`` ops); ``compute="timing"`` skips them for
pure coordination studies (``benchmarks/speedup.py``).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import numpy as np

from ..core.space import ConsensusSpec
from .engine import SpaceEngine
from .events import EventScheduler
from .server import BlockServerProc, resolve_discipline
from .staleness import StalenessEnforcer
from .timing import CostProfile
from .trace import DelayTrace
from .worker import WorkerProc


@dataclasses.dataclass
class PSRunResult:
    """What a PS-runtime run produced. ``z_final`` / ``z_versions`` are
    in USER representation (flat vector / params pytree) like every
    other ``ConsensusSession`` surface; both are None for timing-only
    runs, and ``z_versions`` additionally needs ``record_z=True``."""
    makespan: float
    num_rounds: int
    discipline: str
    trace: DelayTrace
    z_final: Optional[Any]               # final consensus value (real mode)
    z_versions: Optional[List[Any]]      # z per version 0..R (record_z)
    losses: Optional[List[float]]        # mean worker loss per round
    metrics: Dict[str, Any]

    def to_delay_model(self):
        return self.trace.to_delay_model()


class PSRuntime:
    """Event-driven Parameter Server over one :class:`ConsensusSpec`."""

    def __init__(self, spec: ConsensusSpec, data=None, batches=None, *,
                 discipline: str = "lockfree",
                 timing: Optional[CostProfile] = None,
                 compute: str = "real",
                 seed: Optional[int] = None,
                 staleness_bound: Optional[int] = None,
                 record_z: bool = True):
        if compute not in ("real", "timing"):
            raise ValueError(f"compute must be 'real' or 'timing'; "
                             f"got {compute!r}")
        self.spec = spec
        self.engine = SpaceEngine(spec)
        self.discipline = discipline
        self.groups = resolve_discipline(discipline)(self.engine.M)
        covered = sorted(j for g in self.groups for j in g)
        if covered != list(range(self.engine.M)):
            raise ValueError(f"discipline {discipline!r} does not "
                             f"partition the {self.engine.M} blocks")
        self.timing_profile = timing if timing is not None else CostProfile()
        self.timing_only = compute == "timing"
        # record_z=False keeps only the O(T) live version window per
        # block server (plus the final z) — the long-training mode;
        # record_z=True retains the full per-version trajectory for
        # replay-parity pins and analysis
        self.record_z = record_z and not self.timing_only
        self.seed = spec.seed if seed is None else seed
        # Assumption 3's T: the session's delay model already carries it
        # (ring depth D+1) — the enforcer guarantees the runtime never
        # serves staler, so its trace replays within the same depth
        self.bound = (spec.delay_model.depth - 1 if staleness_bound is None
                      else int(staleness_bound))
        self._fixed_data = data
        self._batches = batches
        if not self.timing_only and data is None and batches is None:
            raise ValueError("compute='real' needs fixed per-worker data "
                             "or a batches(t) callable")
        if self.timing_only and self.engine.needs_grads_for_select():
            raise ValueError(
                "this block selector may read gradient norms "
                "(gauss_southwell / custom policies); run the PS runtime "
                "with compute='real', or pick the gradient-free random/"
                "cyclic selectors for timing studies)")

    # ------------------------------------------------------------------
    def run(self, num_rounds: int, z0=None) -> PSRunResult:
        if num_rounds < 1:
            raise ValueError("num_rounds must be >= 1")
        eng = self.engine
        self.num_rounds = num_rounds
        self.sched = EventScheduler()
        self.enforcer = StalenessEnforcer(self.bound)
        self.trace = DelayTrace.empty(num_rounds, eng.N, eng.M, self.bound,
                                      self.discipline)
        self.worker_service = self.timing_profile.worker_service()
        self.net = self.timing_profile.network()
        self._losses = [[] for _ in range(num_rounds)] \
            if not self.timing_only else None
        self._data_cache: Dict[int, Any] = {}
        self._data_refs: Dict[int, int] = {}

        # --- numeric state (Algorithm 1 lines 1-2) ---
        if self.timing_only:
            self.y = self.w = self.x = None
            contents0 = {j: None for j in range(eng.M)}
            caches0 = {}
        else:
            z0r, self.y, self.w, self.x = eng.init(z0)
            contents0 = dict(enumerate(eng.split_blocks(z0r)))
            caches0 = {j: eng.block_cache(self.w, j) for j in range(eng.M)}

        # --- lock domains per the coordination discipline ---
        commit_service = self.timing_profile.server_service()
        self.domains: List[BlockServerProc] = []
        for sid, block_ids in enumerate(self.groups):
            edge_workers = frozenset(
                i for i in range(eng.N)
                if any(eng.edge[i, j] for j in block_ids))
            self.domains.append(BlockServerProc(
                sid, block_ids, engine=eng, sched=self.sched,
                enforcer=self.enforcer, commit_service=commit_service,
                push_cost=self.timing_profile.t_push,
                rng=np.random.default_rng([self.seed, sid]),
                num_rounds=num_rounds, edge_workers=edge_workers,
                contents0={j: contents0[j] for j in block_ids},
                caches0={j: caches0[j] for j in block_ids}
                if not self.timing_only else {},
                timing_only=self.timing_only))
        self.domain_of_block = [None] * eng.M
        for dom in self.domains:
            for j in dom.block_ids:
                self.domain_of_block[j] = dom
        self.domains_of_worker = [
            [dom for dom in self.domains if i in dom.edge_workers]
            for i in range(eng.N)]

        # --- launch ---
        workers = self._workers = [WorkerProc(i, self)
                                   for i in range(eng.N)]
        for wk in workers:
            self.sched.at(0.0, wk.start)
        for dom in self.domains:
            # blocks with an empty edge neighborhood still commit every
            # round (prox-only decay, as the epoch does)
            self.sched.at(0.0, dom._maybe_commit)
        makespan = self.sched.run()

        # --- invariants ---
        for wk in workers:
            if wk.rounds_done != num_rounds:
                raise RuntimeError(f"worker {wk.i} finished "
                                   f"{wk.rounds_done}/{num_rounds} rounds "
                                   f"— runtime deadlock?")
        for dom in self.domains:
            if dom.version != num_rounds:
                raise RuntimeError(f"lock domain {dom.sid} committed "
                                   f"{dom.version}/{num_rounds} versions")
        self.trace.validate()
        assert self.enforcer.idle

        z_final = None
        z_versions = None
        losses = None
        if not self.timing_only:
            to_user = eng.space.to_user

            def z_at(v):
                return to_user(eng.join_blocks(
                    [self.domain_of_block[j].content_at(j, v)
                     for j in range(eng.M)]))
            if self.record_z:
                z_versions = [z_at(v) for v in range(num_rounds + 1)]
            z_final = z_versions[-1] if z_versions else z_at(num_rounds)
            losses = [float(np.mean(l)) for l in self._losses]

        metrics = dict(self.enforcer.stats())
        metrics.update(
            makespan=makespan,
            events=self.sched.events_processed,
            commits=sum(d.commits for d in self.domains),
            pushes=sum(d.pushes for d in self.domains),
            server_busy_time=[d.busy_time for d in self.domains],
            worker_iterations=eng.N * num_rounds)
        self.trace.meta.update(
            seed=self.seed, makespan=makespan,
            discipline=self.discipline,
            minibatch=self.spec.minibatch,
            net_latency=self.net.latency if self.net else 0.0,
            net_jitter=self.net.jitter if self.net else 0.0,
            stall_count=metrics["stall_count"],
            max_served_tau=metrics["max_served_tau"])
        return PSRunResult(makespan=makespan, num_rounds=num_rounds,
                           discipline=self.discipline, trace=self.trace,
                           z_final=z_final, z_versions=z_versions,
                           losses=losses, metrics=metrics)

    # ------------------------------------------------------------------
    # per-round data (minibatched through the epoch's key chain)
    # ------------------------------------------------------------------
    def data_for(self, t: int):
        if t not in self._data_cache:
            base = self._batches(t) if self._batches is not None \
                else self._fixed_data
            self._data_cache[t] = self.engine.round_data(t, base)
            self._data_refs[t] = 0
        return self._data_cache[t]

    def data_done(self, t: int) -> None:
        if t in self._data_refs:
            self._data_refs[t] += 1
            if self._data_refs[t] >= self.engine.N:
                del self._data_cache[t]
                del self._data_refs[t]

    def record_loss(self, t: int, i: int, loss) -> None:
        self._losses[t].append(float(loss))

    def on_worker_progress(self) -> None:
        """A worker advanced a round: without full-trajectory recording,
        drop block versions no worker can legally read anymore
        (< min worker round - T)."""
        if self.record_z or self.timing_only:
            return
        thr = min(wk.t for wk in self._workers) - self.bound
        if thr > 0:
            for dom in self.domains:
                dom.prune(thr)
