"""PSRuntime — wire scheduler + servers + workers + enforcer together.

One call, two products:

* **what happened** — makespan, per-domain queue occupancy, stall
  statistics (the coordination-scalability quantities Table 1
  measures), per-round losses when numerics run;
* **what to replay** — a validated :class:`DelayTrace` whose
  ``TraceDelay`` reproduces the runtime's z trajectory through the
  fast vectorized ``asybadmm_epoch`` (flat/tree, jnp/pallas,
  single-device/SPMD): structurally exact, bitwise on pallas,
  fp32-ulp (cross-program XLA fusion) on jnp.

Numerics run through :class:`~repro.ps.engine.SpaceEngine` (the real
jitted ``VariableSpace`` ops); ``compute="timing"`` skips them for
pure coordination studies (``benchmarks/speedup.py``).

Chaos/elasticity (``faults=``): a :class:`~repro.ps.chaos.FaultPlan`
injects worker crash/restart, permanent leaves, cold joins, transient
compute slowdowns and server commit-latency spikes into the run. The
:class:`~repro.ps.membership.MembershipManager` keeps commit gates and
participation straight (rounds a worker missed contribute no edge
updates), the StalenessEnforcer treats rejoin as a version reset, and
the recorded trace carries the participation matrix + the chaos event
timeline — replay parity holds for chaos runs exactly as for
fault-free ones.

Durability (``ps/recovery.py``): ``server_crash`` fault events arm a
per-domain write-ahead commit log so a block server can lose its
volatile state and rebuild it exactly by replay (zero committed folds
lost), and ``run(checkpoint_every=, checkpoint_dir=, resume_from=)``
takes periodic crash-consistent snapshots of the whole runtime so a
killed run resumes mid-stream with results identical to the
uninterrupted one.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, List, Optional

import numpy as np

from ..core.space import ConsensusSpec
from ..obs import MetricsRegistry, as_telemetry, hist
from . import recovery as _recovery
from .chaos import FaultInjector, FaultPlan
from .engine import SpaceEngine
from .events import EventScheduler
from .membership import MembershipManager
from .server import BlockServerProc, resolve_discipline
from .staleness import StalenessEnforcer
from .timing import CostProfile, Transport
from .trace import DelayTrace
from .transport import TransportFabric
from .worker import WorkerProc


@dataclasses.dataclass
class PSRunResult:
    """What a PS-runtime run produced. ``z_final`` / ``z_versions`` are
    in USER representation (flat vector / params pytree) like every
    other ``ConsensusSession`` surface; both are None for timing-only
    runs, and ``z_versions`` additionally needs ``record_z=True``."""
    makespan: float
    num_rounds: int
    discipline: str
    trace: DelayTrace
    z_final: Optional[Any]               # final consensus value (real mode)
    z_versions: Optional[List[Any]]      # z per version 0..R (record_z)
    losses: Optional[List[float]]        # mean participant loss per round
    metrics: Dict[str, Any]
    # the run's Telemetry (None when telemetry was off): spans carry
    # the Chrome trace (telemetry.spans.save(path)), the sink already
    # received every per-round record
    telemetry: Optional[Any] = None

    def to_delay_model(self):
        return self.trace.to_delay_model()


class PSRuntime:
    """Event-driven Parameter Server over one :class:`ConsensusSpec`."""

    def __init__(self, spec: ConsensusSpec, data=None, batches=None, *,
                 discipline: str = "lockfree",
                 timing: Optional[CostProfile] = None,
                 compute: str = "real",
                 seed: Optional[int] = None,
                 staleness_bound: Optional[int] = None,
                 record_z: bool = True,
                 faults: Optional[FaultPlan] = None,
                 check_finite: bool = False,
                 telemetry: Any = None,
                 metrics_every: Optional[int] = None):
        if compute not in ("real", "timing"):
            raise ValueError(f"compute must be 'real' or 'timing'; "
                             f"got {compute!r}")
        self.spec = spec
        self.engine = SpaceEngine(spec)
        self.discipline = discipline
        disc = resolve_discipline(discipline)
        self.groups = disc.groups(self.engine.M)
        self.per_push = disc.per_push
        covered = sorted(j for g in self.groups for j in g)
        if covered != list(range(self.engine.M)):
            raise ValueError(f"discipline {discipline!r} does not "
                             f"partition the {self.engine.M} blocks")
        self.timing_profile = timing if timing is not None else CostProfile()
        self.timing_only = compute == "timing"
        # record_z=False keeps only the O(T) live version window per
        # block server (plus the final z) — the long-training mode;
        # record_z=True retains the full per-version trajectory for
        # replay-parity pins and analysis
        self.record_z = record_z and not self.timing_only
        self.seed = spec.seed if seed is None else seed
        # Assumption 3's T: the session's delay model already carries it
        # (ring depth D+1) — the enforcer guarantees the runtime never
        # serves staler, so its trace replays within the same depth
        self.bound = (spec.delay_model.depth - 1 if staleness_bound is None
                      else int(staleness_bound))
        self.faults = faults.validate(self.engine.N, self.engine.M) \
            if faults is not None else None
        # divergence watchdog: halt the run (FloatingPointError naming
        # the round/block) the moment a committed z goes NaN/Inf
        self.check_finite = bool(check_finite) and not self.timing_only
        # telemetry (repro.obs): None = inert — rt.obs is None and no
        # instrumentation site does anything; on = spans/stream record
        # in virtual time only, never perturbing the schedule
        self.obs = as_telemetry(telemetry)
        if metrics_every is not None:
            if self.obs is None:
                raise ValueError("metrics_every= needs telemetry= "
                                 "(the per-round stream cadence)")
            if metrics_every < 1:
                raise ValueError(f"metrics_every must be >= 1; "
                                 f"got {metrics_every}")
            self.obs.metrics_every = int(metrics_every)
        self._fixed_data = data
        self._batches = batches
        if not self.timing_only and data is None and batches is None:
            raise ValueError("compute='real' needs fixed per-worker data "
                             "or a batches(t) callable")
        if self.timing_only and self.engine.needs_grads_for_select():
            raise ValueError(
                "this block selector may read gradient norms "
                "(gauss_southwell / custom policies); run the PS runtime "
                "with compute='real', or pick a gradient-free selector "
                "(random/cyclic/zipf) for timing studies)")

    # ------------------------------------------------------------------
    def run(self, num_rounds: int, z0=None, *,
            checkpoint_every: Optional[int] = None,
            checkpoint_dir: Optional[str] = None,
            resume_from: Optional[str] = None) -> PSRunResult:
        """Drive ``num_rounds`` rounds. Durability knobs
        (``ps/recovery.py``): ``checkpoint_every=E`` writes an atomic,
        crash-consistent snapshot of the whole runtime to
        ``checkpoint_dir`` at rounds E, 2E, ... (a quiescent barrier —
        part of the run's schedule); ``resume_from=`` (a snapshot
        prefix, file, or the checkpoint directory for its latest)
        restores one and continues mid-stream, producing results
        identical to the uninterrupted run. ``checkpoint_every=None``
        (default) is byte-identical to the pre-durability runtime."""
        if num_rounds < 1:
            raise ValueError("num_rounds must be >= 1")
        eng = self.engine
        self.num_rounds = num_rounds
        self.sched = EventScheduler()
        self.enforcer = StalenessEnforcer(self.bound)
        if self.obs is not None:
            self.sched.observer = self.obs.on_event
            self.enforcer.obs = self.obs
        self.trace = DelayTrace.empty(num_rounds, eng.N, eng.M, self.bound,
                                      self.discipline)
        self.worker_service = self.timing_profile.worker_service()
        self.net = self.timing_profile.network()
        self._losses = [[] for _ in range(num_rounds)] \
            if not self.timing_only else None
        self._data_cache: Dict[int, Any] = {}
        self._data_refs: Dict[int, int] = {}

        # --- chaos + elastic membership ---
        self.injector = FaultInjector(self.faults, self)
        cold = self.faults.cold_workers if self.faults is not None \
            else frozenset()
        self.membership = MembershipManager(eng.N, num_rounds, cold=cold)
        elastic = self.faults is not None and bool(self.faults.events)

        # --- unreliable transport (inert unless a knob or fault turns
        # loss on: reliable runs keep the exact pre-transport paths) ---
        raw_net = self.timing_profile.net
        base_tr = raw_net if isinstance(raw_net, Transport) else None
        lossy_faults = self.faults is not None and (
            self.faults.has_link_loss or self.faults.has_server_crash)
        if base_tr is not None and (base_tr.unreliable or lossy_faults):
            self.transport = base_tr
        elif lossy_faults:
            # link_loss bursts / server_crash outages need the ack/retry
            # layer even when the base network is reliable (messages to
            # a down server drop and must retransmit) — synthesize a
            # zero-knob Transport carrying the base latency model
            self.transport = Transport(
                latency=self.net.latency if self.net else 0.0,
                jitter=self.net.jitter if self.net else 0.0)
        else:
            self.transport = None
        self.fabric = None
        if self.transport is not None:
            recorder = self.trace.add_transport
            if self.obs is not None:
                # every delivery decision also lands as a span instant
                # (same kind spellings — obs.names is one registry)
                recorder = self.obs.transport_recorder(recorder)
            self.fabric = TransportFabric(
                self.transport, self.sched, self.seed,
                recorder=recorder,
                burst_drop=self.injector.link_drop
                if not self.injector.empty else None)

        # --- durability: periodic snapshots + mid-run resume ---
        self.ckpt = None
        resume_state = None
        if resume_from is not None:
            resume_state = _recovery.load_snapshot(resume_from)
            saved_every = resume_state.meta["fingerprint"].get(
                "checkpoint_every")
            if checkpoint_every is None:
                # the barrier cadence is part of the run's schedule —
                # resume inherits it so the continuation matches the
                # uninterrupted run exactly
                checkpoint_every = saved_every
            elif saved_every is not None \
                    and int(checkpoint_every) != int(saved_every):
                raise ValueError(
                    f"resume_from snapshot was written with "
                    f"checkpoint_every={saved_every} but this run asks "
                    f"for {checkpoint_every} — the barrier cadence is "
                    f"part of the run's schedule and cannot change "
                    f"mid-stream")
            if checkpoint_dir is None:
                checkpoint_dir = os.path.dirname(resume_state.path) or "."
        if checkpoint_every is not None:
            every = int(checkpoint_every)
            if every < 1:
                raise ValueError(f"checkpoint_every must be >= 1; "
                                 f"got {checkpoint_every}")
            if checkpoint_dir is None:
                raise ValueError("checkpoint_every= needs checkpoint_dir= "
                                 "(where the snapshots land)")
            if self.transport is not None:
                raise ValueError(
                    "checkpoint_every is incompatible with an unreliable "
                    "transport (in-flight retransmission timers are not "
                    "snapshotable): drop the Transport knobs and any "
                    "link_loss/server_crash fault events, or run without "
                    "checkpointing — server_crash durability comes from "
                    "the per-domain WAL instead")
            if self.timing_only:
                raise ValueError(
                    "checkpoint_every needs compute='real' (timing-only "
                    "runs hold no numeric state worth snapshotting)")
            self.ckpt = _recovery.SnapshotCoordinator(
                self, every, checkpoint_dir)
            self.sched.after_event = self.ckpt.check

        # --- numeric state (Algorithm 1 lines 1-2) ---
        if self.timing_only:
            self.y = self.w = self.x = None
            contents0 = {j: None for j in range(eng.M)}
            caches0 = {}
        else:
            z0r, self.y, self.w, self.x = eng.init(z0)
            contents0 = dict(enumerate(eng.split_blocks(z0r)))
            caches0 = {j: eng.block_cache(self.w, j) for j in range(eng.M)}

        # --- lock domains per the coordination discipline ---
        # server_crash faults arm each domain's write-ahead commit log
        # (recovery replays it through the same fold path — zero
        # committed folds lost); without them the WAL does not exist
        wal_armed = self.faults is not None and self.faults.has_server_crash
        commit_service = self.timing_profile.server_service()
        self.domains: List[BlockServerProc] = []
        for sid, block_ids in enumerate(self.groups):
            edge_workers = frozenset(
                i for i in range(eng.N)
                if any(eng.edge[i, j] for j in block_ids))
            self.domains.append(BlockServerProc(
                sid, block_ids, engine=eng, sched=self.sched,
                enforcer=self.enforcer, commit_service=commit_service,
                push_cost=self.timing_profile.t_push,
                rng=np.random.default_rng([self.seed, sid]),
                num_rounds=num_rounds, edge_workers=edge_workers,
                contents0={j: contents0[j] for j in block_ids},
                caches0={j: caches0[j] for j in block_ids}
                if not self.timing_only else {},
                timing_only=self.timing_only, per_push=self.per_push,
                membership=self.membership if elastic else None,
                fault_factor=self.injector.server_factor
                if not self.injector.empty else None,
                runtime=self,
                wal=_recovery.DomainWAL(sid) if wal_armed else None))
        self.domain_of_block = [None] * eng.M
        for dom in self.domains:
            for j in dom.block_ids:
                self.domain_of_block[j] = dom
        self.domains_of_worker = [
            [dom for dom in self.domains if i in dom.edge_workers]
            for i in range(eng.N)]

        # --- launch ---
        workers = self._workers = [WorkerProc(i, self, cold=i in cold)
                                   for i in range(eng.N)]
        if self.obs is not None:
            self.obs.bind(num_domains=len(self.domains),
                          num_rounds=num_rounds,
                          record_fn=self._round_record)
        self._register_metrics()
        if resume_state is not None:
            # restore the quiescent barrier state and arm it: clock,
            # entity state + rngs, the not-yet-fired fault timeline,
            # and the parked workers' releases. The t=0 launch below is
            # skipped — at a quiescent barrier no commit gate is
            # satisfiable until a released worker declares
            _recovery.resume(self, resume_state)
        else:
            self.injector.install()
            for wk in workers:
                if wk.alive:
                    self.sched.at(0.0, wk.start)
            for dom in self.domains:
                # blocks with an empty edge neighborhood still commit
                # every round (prox-only decay, as the epoch does)
                self.sched.at(0.0, dom._maybe_commit)
        makespan = self.sched.run()

        # --- invariants ---
        for wk in workers:
            expect = self.membership.participated_rounds(wk.i)
            if wk.rounds_done != expect:
                raise RuntimeError(f"worker {wk.i} finished "
                                   f"{wk.rounds_done}/{expect} participated "
                                   f"rounds — runtime deadlock?")
        for dom in self.domains:
            if dom.version != num_rounds:
                raise RuntimeError(f"lock domain {dom.sid} committed "
                                   f"{dom.version}/{num_rounds} versions")
        self.trace.set_participation(self.membership.participation_matrix())
        self.trace.validate()
        assert self.enforcer.idle

        z_final = None
        z_versions = None
        losses = None
        if not self.timing_only:
            to_user = eng.space.to_user

            def z_at(v):
                return to_user(eng.join_blocks(
                    [self.domain_of_block[j].content_at(j, v)
                     for j in range(eng.M)]))
            if self.record_z:
                z_versions = [z_at(v) for v in range(num_rounds + 1)]
            z_final = z_versions[-1] if z_versions else z_at(num_rounds)
            # mean over the round's PARTICIPANTS (all workers when
            # fault-free); a round everyone missed reports nan
            losses = [float(np.mean(l)) if l else float("nan")
                      for l in self._losses]

        # assemble the final metrics dict from the registry — the
        # instruments every component registered in _register_metrics
        # evaluate lazily here, in registration order, reproducing the
        # pre-telemetry dict byte for byte
        metrics = self.registry.collect()
        self.trace.meta.update(
            seed=self.seed, makespan=makespan,
            discipline=self.discipline,
            minibatch=self.spec.minibatch,
            net_latency=self.net.latency if self.net else 0.0,
            net_jitter=self.net.jitter if self.net else 0.0,
            stall_count=metrics["stall_count"],
            max_served_tau=metrics["max_served_tau"])
        if elastic:
            self.trace.meta.update(
                fault_events=len(self.faults.events),
                crashes=self.membership.crashes,
                rejoins=self.membership.rejoins)
        if self.transport is not None:
            tstats = metrics["transport"]
            self.trace.meta.update(transport={
                "drop_rate": self.transport.drop_rate,
                "dup_rate": self.transport.dup_rate,
                "reorder_rate": self.transport.reorder_rate,
                "ack_timeout": self.transport.ack_timeout,
                **{k: tstats[k] for k in
                   ("sent", "delivered", "drops", "dups", "reorders",
                    "retransmits", "dups_dropped", "timeout_fallbacks",
                    "delivery_rate")}})
        if self.obs is not None:
            self.obs.finalize({"seed": self.seed, "makespan": makespan,
                               "discipline": self.discipline,
                               "num_rounds": num_rounds})
        return PSRunResult(makespan=makespan, num_rounds=num_rounds,
                           discipline=self.discipline, trace=self.trace,
                           z_final=z_final, z_versions=z_versions,
                           losses=losses, metrics=metrics,
                           telemetry=self.obs)

    # ------------------------------------------------------------------
    # observability (repro.obs)
    # ------------------------------------------------------------------
    def _register_metrics(self) -> None:
        """Build the run's :class:`~repro.obs.MetricsRegistry`: every
        component registers lazy instruments over its own counters (no
        hot-path writes), and registration order IS the key order of
        the final ``PSRunResult.metrics`` dict — kept identical to the
        pre-registry inline assembly (byte-compatible)."""
        reg = self.registry = MetricsRegistry()
        self.enforcer.register_metrics(reg)
        reg.gauge("makespan", lambda: self.sched.now)
        reg.counter("events", lambda: self.sched.events_processed)
        BlockServerProc.register_metrics(reg, self.domains, self.sched)
        WorkerProc.register_metrics(reg, self)
        reg.histogram("histograms", lambda: {
            "worker_stall_time": hist(
                [self.enforcer.stall_time_by_worker.get(i, 0.0)
                 for i in range(self.engine.N)]),
            "server_occupancy": hist(
                [d.busy_time / self.sched.now if self.sched.now > 0
                 else 0.0 for d in self.domains])})
        if any(d.wal is not None for d in self.domains):
            _recovery.register_wal_metrics(reg, self.domains)
        if self.ckpt is not None:
            self.ckpt.register_metrics(reg)
        if self.fabric is not None:
            self.fabric.register_metrics(reg, self)

    def _round_record(self, version: int, now: float) -> Dict[str, Any]:
        """One per-round stream record (obs/stream.py schema), built
        the moment the LAST lock domain published ``version`` — pure
        reads of committed state and monotone counters (no rng, no
        events: telemetry-on stays bitwise-identical)."""
        r = version - 1
        loss = None
        if self._losses is not None and self._losses[r]:
            loss = float(np.mean(self._losses[r]))
        depth = [int(sum(d._unprocessed.values())) for d in self.domains]
        record = {
            "round": r, "version": version, "sim_time": float(now),
            "loss": loss,
            "stationarity": self._round_stationarity(version),
            "queue_depth": depth,
            "commits": int(sum(d.commits for d in self.domains)),
            "pushes": int(sum(d.pushes for d in self.domains)),
            "stall_count": int(self.enforcer.stall_count),
            "stall_time": float(self.enforcer.stall_time),
            "transport": None}
        if self.fabric is not None:
            s = self.fabric.stats()
            record["transport"] = {
                k: int(s[k]) for k in ("sent", "delivered", "drops",
                                       "dups", "reorders", "retransmits")}
        spans = self.obs.spans if self.obs is not None else None
        if spans is not None:
            for dom, q in zip(self.domains, depth):
                spans.counter(self.obs.server_track(dom.sid),
                              "queue_depth", now, depth=q)
        return record

    def _round_stationarity(self, version: int) -> Optional[Dict]:
        """Per-block stationarity/residuals at a committed version
        (``core.metrics.block_residuals`` over the packed state), or
        None when not computable without perturbing the run: timing
        mode, ``track_x=False`` sessions, or a block server currently
        down (its committed contents are dark until WAL recovery). The
        gradient term needs fixed full-batch data (``batches=`` streams
        and minibatch draws are round-scoped); without it the streamed
        P carries the primal + prox terms only. Only the packed flat
        representation streams (pytree sessions default ``track_x=False``
        and their bundles are not packed tables)."""
        if self.timing_only or getattr(self.x, "ndim", 0) != 3 \
                or any(d.down for d in self.domains):
            return None
        from ..core.metrics import block_residuals
        eng = self.engine
        try:
            z = eng.join_blocks([
                self.domain_of_block[j].content_at(j, version)
                for j in range(eng.M)])
        except KeyError:
            return None                # version pruned / lost to a crash
        grads = None
        if self._fixed_data is not None and self.spec.minibatch is None:
            _, grads, _ = eng.grads(self.x, self._fixed_data)
        res = block_residuals(z, self.y, self.x, eng.edge,
                              self.spec.rho_vec, reg=self.spec.reg,
                              grads=grads)
        primal = [float(v) for v in np.asarray(res["primal"])]
        prox = [float(v) for v in np.asarray(res["prox"])]
        grad = [] if res["grad"] is None else \
            [float(v) for v in np.asarray(res["grad"])]
        p_blocks = [float(v) for v in np.asarray(res["P"])]
        return {
            "P": float(sum(p_blocks)),
            "primal_residual": float(np.sqrt(sum(v * v for v in primal))),
            "prox_residual": float(np.sqrt(sum(v * v for v in prox))),
            "grad_norm": (float(np.sqrt(sum(v * v for v in grad)))
                          if grad else None),
            "per_block": {"primal": primal, "prox": prox, "grad": grad,
                          "P": p_blocks}}

    # ------------------------------------------------------------------
    def worker_proc(self, i: int) -> WorkerProc:
        """Routing handle for server->worker messages (transport mode)."""
        return self._workers[i]

    # ------------------------------------------------------------------
    # chaos transitions (driven by the FaultInjector's scheduled events)
    # ------------------------------------------------------------------
    def _crash_worker(self, i: int, permanent: bool = False) -> None:
        wk = self._workers[i]
        if not wk.alive or wk.t >= self.num_rounds:
            return                     # already down / already finished
        r = wk.t                       # the round it never declared
        wk.kill()
        if self.ckpt is not None:
            # a worker parked at a snapshot barrier no longer blocks
            # (or rides) it — membership marks it absent below
            self.ckpt.unpark(i)
        self.membership.deactivate(i, r)
        self.enforcer.drop_worker(i)
        if self.transport is not None:
            # pending pull requests died with the incarnation; clearing
            # the servers' dedup state lets a revived worker's
            # re-request for the same round be served as new
            for dom in self.domains:
                dom.forget_pending_pulls(i)
        kind = "leave" if permanent else "crash"
        self.trace.add_event(kind, worker=i, round=r, time=self.sched.now)
        if self.obs is not None:
            track = self.obs.worker_track(i)
            if self.obs.spans is not None:
                self.obs.spans.instant(track, kind, self.sched.now,
                                       round=r)
            self.obs.entity_down(track, self.sched.now)
        # gates waiting on this worker's declaration must re-check
        for dom in self.domains_of_worker[i]:
            dom._maybe_commit()
        self._maybe_evict_data(r)

    def _rejoin_worker(self, i: int, cold: bool = False) -> None:
        wk = self._workers[i]
        if wk.alive:
            return                     # crash was a no-op (already done)
        doms = self.domains_of_worker[i]
        # service frontier: one past the newest version any edge domain
        # has committed OR is committing — strictly-future gates only,
        # so resumption never races an in-flight commit whose gate
        # already passed without this worker
        frontier = max((d.version + (1 if d._committing else 0)
                        for d in doms), default=0)
        r = max(wk.t, frontier + 1)
        kind = "join" if cold else "rejoin"
        if r >= self.num_rounds:
            # nothing left to participate in — stays absent to the end
            self.trace.add_event(kind, worker=i, round=None,
                                 time=self.sched.now, effective=False)
            return
        self.membership.activate(i, r)
        self.enforcer.note_rejoin()
        self.trace.add_event(kind, worker=i, round=r, time=self.sched.now)
        if self.obs is not None:
            track = self.obs.worker_track(i)
            if self.obs.spans is not None:
                self.obs.spans.instant(track, kind, self.sched.now,
                                       round=r)
            self.obs.entity_up(track, self.sched.now)
        wk.revive(r)

    def _crash_server(self, block: int) -> None:
        """A ``server_crash`` fault fired: the lock domain holding
        ``block`` loses its volatile state (version history, caches,
        queue, pending declarations, parked pulls). Its WAL survives;
        messages to it drop at the server until recovery."""
        dom = self.domain_of_block[block]
        if dom.down:
            return                     # overlapping windows merge
        self.trace.add_event("server_crash", block=block, sid=dom.sid,
                             version=dom.version, time=self.sched.now)
        if self.obs is not None:
            track = self.obs.server_track(dom.sid)
            if self.obs.spans is not None:
                self.obs.spans.instant(track, "server_crash",
                                       self.sched.now,
                                       version=dom.version)
            self.obs.entity_down(track, self.sched.now)
        dom.crash()
        self.enforcer.drop_server(dom.sid)

    def _recover_server(self, block: int) -> None:
        """The recovery delay elapsed: rebuild the domain exactly by
        WAL replay (committed folds bitwise, pending declarations
        re-queued) and resume its commit chain."""
        dom = self.domain_of_block[block]
        if not dom.down:
            return
        dom.recover()
        self.trace.add_event("server_recover", block=block, sid=dom.sid,
                             version=dom.version, time=self.sched.now,
                             replayed=len(dom.wal.commits))
        if self.obs is not None:
            track = self.obs.server_track(dom.sid)
            if self.obs.spans is not None:
                self.obs.spans.instant(track, "server_recover",
                                       self.sched.now,
                                       version=dom.version)
            self.obs.entity_up(track, self.sched.now)

    # ------------------------------------------------------------------
    # per-round data (minibatched through the epoch's key chain)
    # ------------------------------------------------------------------
    def data_for(self, t: int):
        if t not in self._data_cache:
            base = self._batches(t) if self._batches is not None \
                else self._fixed_data
            self._data_cache[t] = self.engine.round_data(t, base)
            self._data_refs[t] = 0
        return self._data_cache[t]

    def data_done(self, t: int) -> None:
        if t in self._data_refs:
            self._data_refs[t] += 1
            self._maybe_evict_data(t)

    def _expected_consumers(self, t: int) -> int:
        return sum(1 for i in range(self.engine.N)
                   if self.membership.required(i, t))

    def _maybe_evict_data(self, t: int) -> None:
        if t in self._data_refs \
                and self._data_refs[t] >= self._expected_consumers(t):
            del self._data_cache[t]
            del self._data_refs[t]

    def record_loss(self, t: int, i: int, loss) -> None:
        self._losses[t].append(float(loss))

    def on_worker_progress(self) -> None:
        """A worker advanced a round: without full-trajectory recording,
        drop block versions no worker can legally read anymore
        (< min worker round - T). Absent workers resume at one past the
        newest committed version, so counting ``1 + max version`` for
        them keeps every version a future rejoiner could read."""
        if self.record_z or self.timing_only:
            return
        live = [wk.t for wk in self._workers if wk.alive]
        if len(live) < len(self._workers):
            live.append(1 + max(d.version for d in self.domains))
        thr = min(live) - self.bound
        if thr > 0:
            for dom in self.domains:
                dom.prune(thr)
