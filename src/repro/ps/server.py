"""Block servers — lock-free per-block vs locked full-vector (paper §1).

The paper's headline architectural claim is that block-wise servers
need NO global lock: a push to block j occupies only server j, so
different blocks commit concurrently, while all prior async consensus
ADMM (Chang et al. 2015; Zhang & Kwok 2014) serializes every update
through one full-vector lock. All disciplines here are the SAME server
implementation grouped (and commit-scheduled) differently:

* ``lockfree`` — M lock domains, one block each; round-buffered: the
  round's pushes apply and the block proxes once, at the round-v
  commit, paying one commit service time;
* ``locked``   — ONE lock domain holding every block; all pushes queue
  on it and each commit pays the per-block service time M times, under
  the lock;
* ``per_push`` — M per-block domains with **per-push commits**: the
  server does its fold/prox work eagerly as each push is processed
  through the queue (each push pays ``push_cost`` + one commit-service
  draw), so the round-boundary version *publish* is a pointer bump —
  free when the round folded at least one push, one commit-service
  draw for push-less (prox-only) rounds. The commit *fold* is the same
  round-ordered application lockfree does (given the same pushes, the
  published version is bit-identical), but the commit latency moves
  off the round boundary into the push stream — versions publish at
  different sim times, workers observe different staleness, and the
  run explores a different (still deterministic, still
  replay-exact) trajectory than lockfree. That timing shift is the
  point: fewer round-boundary stalls when declarations arrive spread
  out, longer queues on hot blocks under skew.

A lock domain commits version v+1 of its blocks once (a) it has heard
a round-v declaration (push or skip) from every worker in its edge
neighborhood that is ACTIVE for round v (elastic membership: crashed /
departed / not-yet-joined workers are excluded, so churn never
deadlocks a gate), (b) all round-v pushes have been processed through
its queue, and (c) version v is committed. Pushes that arrive EARLY (a
worker running up to T rounds ahead under bounded staleness) buffer
per round and apply to the stale-w~ cache only at their round's commit
— that round-ordering is what makes a recorded trace replay through
the vectorized epoch exactly. Commits cap at ``num_rounds``: versions
beyond the horizon would never be read.

``DISCIPLINES`` maps names to :class:`Discipline` entries (a block ->
lock-domain grouping plus the commit mode); register custom groupings
(e.g. shard-pair servers) with :func:`register_discipline`. Block ids
follow the packed block layout's contract
(``core.blocks.BlockLayout``): block j is row j of the canonical
(M, dblk) table for BOTH spaces — a pytree model's lock domains are
the same objects as a flat vector's, so every discipline behaves
identically in pytree mode.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# coordination disciplines = block -> lock-domain groupings + commit mode
# ---------------------------------------------------------------------------

DisciplineFn = Callable[[int], List[Tuple[int, ...]]]


@dataclasses.dataclass(frozen=True)
class Discipline:
    """A named coordination discipline: how blocks group into lock
    domains (``groups(num_blocks)``) and whether commit work is paid
    per push (eager) or per round (buffered)."""
    groups: DisciplineFn
    per_push: bool = False


DISCIPLINES: Dict[str, Discipline] = {}


def register_discipline(name: str, *, per_push: bool = False):
    """Register a grouping fn under ``name``. The decorated callable
    keeps its plain ``fn(num_blocks) -> groups`` signature (existing
    custom registrations stay valid); ``per_push=True`` marks the
    discipline's commit work as paid eagerly per push."""
    def deco(fn: DisciplineFn) -> DisciplineFn:
        DISCIPLINES[name] = Discipline(fn, per_push)
        return fn
    return deco


@register_discipline("lockfree")
def lockfree_domains(num_blocks: int) -> List[Tuple[int, ...]]:
    """AsyBADMM: one lock domain per block server."""
    return [(j,) for j in range(num_blocks)]


@register_discipline("locked")
def locked_domains(num_blocks: int) -> List[Tuple[int, ...]]:
    """The baseline the paper beats: one global full-vector lock."""
    return [tuple(range(num_blocks))]


@register_discipline("per_push", per_push=True)
def per_push_domains(num_blocks: int) -> List[Tuple[int, ...]]:
    """Per-block servers with eager (per-push) commit work."""
    return [(j,) for j in range(num_blocks)]


def resolve_discipline(name: str) -> Discipline:
    try:
        return DISCIPLINES[name]
    except KeyError:
        raise ValueError(f"unknown discipline {name!r}; registered: "
                         f"{sorted(DISCIPLINES)}") from None


# ---------------------------------------------------------------------------
# the server process
# ---------------------------------------------------------------------------

class BlockServerProc:
    """One lock domain: a set of blocks sharing a serial service queue.

    Owns the blocks' committed-version contents, their stale-w~ caches,
    per-round push buffers and declarations; numeric commits delegate
    to ``engine.commit_block`` (the real jitted server update)."""

    def __init__(self, sid: int, block_ids: Sequence[int], *, engine, sched,
                 enforcer, commit_service, push_cost: float,
                 rng: np.random.Generator, num_rounds: int,
                 edge_workers: frozenset, contents0: dict, caches0: dict,
                 timing_only: bool, per_push: bool = False,
                 membership=None, fault_factor=None, runtime=None,
                 wal=None):
        self.sid = sid
        self.block_ids = tuple(block_ids)
        self.engine = engine
        self.sched = sched
        self.enforcer = enforcer
        self.commit_service = commit_service
        self.push_cost = float(push_cost)
        self.rng = rng
        self.num_rounds = num_rounds
        self.edge_workers = edge_workers
        self.timing_only = timing_only
        self.per_push = per_push
        self.membership = membership
        # chaos hook: commit-latency multiplier at a sim time
        self._fault_factor = fault_factor
        # unreliable-transport state (None/unused on reliable runs):
        # the owning runtime (for routing responses/acks back through
        # its fabric), per-(worker, round) pull dedup and dup counter
        self.rt = runtime
        self._pull_state: Dict[Tuple[int, int], Optional[int]] = {}
        self.dups_dropped = 0
        # the exactly-once fold log ((version, worker, block) in fold
        # order) the transport/recovery property tests pin
        self.fold_log: list = []
        # durability (ps/recovery.py): the write-ahead commit log this
        # domain replays after a server_crash fault, the incarnation
        # counter that strands a dead incarnation's queue/commit
        # events, and the version-0 base state replay rebuilds from
        self.wal = wal
        self.down = False
        self.gen = 0
        self.recoveries = 0
        self._contents0 = dict(contents0) \
            if wal is not None and not timing_only else None
        self._caches0 = dict(caches0) \
            if wal is not None and not timing_only else None

        self.version = 0
        # contents[j][v] = block j's committed content at version v
        # (a dict keyed by version: old versions are prunable once no
        # worker can legally read them — see ``prune``)
        self.contents = {j: {0: contents0[j]} for j in self.block_ids} \
            if not timing_only else {}
        self.caches = dict(caches0) if not timing_only else {}
        self._decl: Dict[int, set] = defaultdict(set)
        self._push_buf: Dict[int, list] = defaultdict(list)
        self._unprocessed: Dict[int, int] = defaultdict(int)
        self._committing = False
        self.busy_until = 0.0
        self.busy_time = 0.0
        self.wait_time = 0.0
        self.wait_count = 0
        self.commits = 0
        self.pushes = 0

    # ---- queue occupancy --------------------------------------------------
    def _occupy(self, duration: float, label: Optional[str] = None) -> float:
        """Serialize ``duration`` of work through this lock domain's
        queue; returns the completion time. Accounts the queueing delay
        of the newly enqueued item (time it sat behind earlier work).
        ``label`` names the service span ("push_service" /
        "commit_service") on the telemetry track — purely a recording
        of the times computed here, never an input to them."""
        start = max(self.sched.now, self.busy_until)
        done = start + duration
        self.wait_time += start - self.sched.now
        self.wait_count += 1
        self.busy_until = done
        self.busy_time += duration
        obs = self.rt.obs if self.rt is not None else None
        if obs is not None and obs.spans is not None and label is not None:
            track = obs.server_track(self.sid)
            if start > self.sched.now:
                obs.spans.complete(track, "queue_wait",
                                   self.sched.now, start)
            if duration > 0:
                obs.spans.complete(track, label, start, done)
        return done

    def _commit_sample(self) -> float:
        dur = self.commit_service.sample(self.rng)
        if self._fault_factor is not None:
            dur *= self._fault_factor(self.block_ids, self.sched.now)
        return dur

    # ---- worker-facing API ------------------------------------------------
    def on_declare(self, i: int, t: int, pushes: list) -> None:
        """Worker i's round-t declaration: ``pushes`` is the
        [(block_id, w_value)] it commits this round (w_value is None in
        timing-only mode); an empty list is a skip. Either way the
        server now knows worker i's round-t intent — the runtime
        analogue of the bounded-delay assumption that lets a real
        lock-free server stop waiting on non-pushers."""
        if self.wal is not None:
            # write-ahead: the declaration (intent + push payloads) is
            # durable BEFORE any queue/commit processing — a crash
            # between here and the round's publish replays it
            self.wal.record_declare(i, t, pushes)
        self._decl[t].add(i)
        for (j, value) in pushes:
            self.pushes += 1
            self._unprocessed[t] += 1
            # per-push discipline: the server folds/proxes eagerly as it
            # processes the push, so the commit-service draw is paid
            # HERE instead of at the round-boundary publish
            cost = self.push_cost
            if self.per_push:
                cost += self._commit_sample()
            done = self._occupy(cost, label="push_service")
            self.sched.at(done, self._guard(
                lambda t=t, i=i, j=j, v=value:
                self._push_processed(t, i, j, v)))
        self._maybe_commit()

    def _push_processed(self, t: int, i: int, j: int, value) -> None:
        self._push_buf[t].append((i, j, value))
        self._unprocessed[t] -= 1
        self._maybe_commit()

    # ---- unreliable-transport endpoints -----------------------------------
    # Only reachable when the runtime routes messages through a lossy
    # Transport; reliable runs never enter these paths.

    def on_pull_request(self, i: int, t: int) -> None:
        """Worker i's round-t pull REQUEST arrived over the lossy link.
        The served version is fixed exactly once per (worker, round) —
        a retransmitted request whose original is still pending is
        dropped (the pending resolution will answer both), and one
        whose response was already sent gets the SAME version resent
        (the response, not the request, must have been lost)."""
        if self.down:
            return                 # dark server: retransmission recovers
        key = (i, t)
        if key in self._pull_state:
            self.dups_dropped += 1
            v = self._pull_state[key]
            if v is not None:
                self._send_pull_response(i, t, v)
            return
        self._pull_state[key] = None       # pending at the enforcer
        self.enforcer.request(
            self, t, self.sched.now,
            lambda version, i=i, t=t: self._pull_served(i, t, version),
            worker=i)

    def _pull_served(self, i: int, t: int, version: int) -> None:
        self._pull_state[(i, t)] = version
        self._send_pull_response(i, t, version)

    def _send_pull_response(self, i: int, t: int, version: int) -> None:
        # the response carries the block payloads (as a real protocol
        # does) — a server that crashes while this message is in flight
        # must not take the read back with it
        wk = self.rt.worker_proc(i)
        payload = None if self.timing_only else \
            [self.content_at(j, version) for j in self.block_ids]
        self.rt.fabric.link(i, self).send(
            lambda: wk.on_pull_response(self, t, version, payload),
            msg="pull_resp", t=t)

    def forget_pending_pulls(self, i: int) -> None:
        """Worker i crashed: its pending pull requests died with it (the
        enforcer already dropped the parked resolutions). Clearing the
        dedup state lets the revived incarnation's re-request for the
        same round be treated as NEW instead of an eternal duplicate."""
        for key in [k for k, v in self._pull_state.items()
                    if k[0] == i and v is None]:
            del self._pull_state[key]

    def on_declare_msg(self, i: int, t: int, pushes: list) -> None:
        """Worker i's round-t declaration bundle arrived over the lossy
        link. The commit gate dedups by (worker, round): a bundle for an
        already-committed round (t < version) or one already declared
        this round folds ZERO more times — but is re-acked either way,
        because a duplicate here usually means the original ack was
        lost and the worker is still retransmitting."""
        if self.down:
            return                 # dark server: retransmission recovers
        if t < self.version or i in self._decl[t]:
            self.dups_dropped += 1
        else:
            self.on_declare(i, t, pushes)
        self._send_ack(i, t)

    def _send_ack(self, i: int, t: int) -> None:
        wk = self.rt.worker_proc(i)
        self.rt.fabric.link(i, self).send(
            lambda: wk.on_declare_ack(self, t), msg="ack", t=t)

    # ---- commit machinery -------------------------------------------------
    def _required_declarations(self, v: int) -> frozenset:
        """Who round v's gate waits on: the edge neighborhood, minus
        workers elastic membership marks absent for round v."""
        if self.membership is None:
            return self.edge_workers
        return frozenset(i for i in self.edge_workers
                         if self.membership.required(i, v))

    def _maybe_commit(self) -> None:
        if self.down:
            return                 # recovery restarts the commit chain
        v = self.version
        if self._committing or v >= self.num_rounds:
            return
        if not self._decl[v] >= self._required_declarations(v):
            return
        if self._unprocessed[v] > 0:
            return
        self._committing = True
        if self.per_push:
            # commit work was paid per push; the version publish is a
            # pointer bump — unless the round folded nothing (prox-only
            # decay still runs the server update once)
            dur = 0.0 if self._push_buf.get(v) else self._commit_sample()
        else:
            dur = sum(self._commit_sample() for _ in self.block_ids)
        self.sched.at(self._occupy(dur, label="commit_service"),
                      self._guard(self._finish_commit))

    def _finish_commit(self) -> None:
        v = self.version
        # apply round-v pushes to the stale-w~ caches in processed order
        # (round-buffered: early pushes from workers running ahead under
        # bounded staleness must not leak into this commit; per_push
        # pays its commit latency eagerly but folds at the SAME point,
        # so the published version is bit-identical across disciplines)
        pushes = self._push_buf.pop(v, [])
        if self.wal is not None:
            # write-ahead: the fold order is durable before the publish
            self.wal.record_commit(v, [(i, j) for (i, j, _) in pushes])
        self.fold_log.extend((v, i, j) for (i, j, _) in pushes)
        if not self.timing_only:
            for (i, j, value) in pushes:
                self.caches[j] = self.engine.apply_push(self.caches[j], i,
                                                        value)
            for j in self.block_ids:
                self.contents[j][v + 1] = self.engine.commit_block(
                    j, self.contents[j][v], self.caches[j])
            if self.rt is not None and self.rt.check_finite:
                for j in self.block_ids:
                    if not np.all(np.isfinite(
                            np.asarray(self.contents[j][v + 1]))):
                        raise FloatingPointError(
                            f"divergence watchdog: committed z for block "
                            f"{j} at round {v} (version {v + 1}) contains "
                            f"NaN/Inf — the run is training on garbage. "
                            f"Check rho / step sizes; rerun with "
                            f"check_finite=False to disable this halt.")
        self.version = v + 1
        self.commits += 1
        self._decl.pop(v, None)
        self._unprocessed.pop(v, None)
        self._committing = False
        obs = self.rt.obs if self.rt is not None else None
        if obs is not None:
            if obs.spans is not None:
                obs.spans.instant(obs.server_track(self.sid), "commit",
                                  self.sched.now, version=self.version,
                                  folds=len(pushes))
            # round-completion detection: the stream record for round
            # v emits the moment the LAST domain publishes version v+1
            obs.note_commit(self.sid, self.version, self.sched.now)
        self.enforcer.notify(self, self.sched.now)
        self._maybe_commit()

    # ---- durability: crash / WAL-replay recovery --------------------------
    # (driven by the runtime's _crash_server/_recover_server transitions;
    #  only reachable when a FaultPlan carries server_crash events, which
    #  also arms self.wal and the ack/retry transport layer)

    def _guard(self, fn):
        """Bind ``fn`` to this server incarnation: a crash strands the
        dead incarnation's queue/commit completions (the volatile queue
        died with it) instead of letting them corrupt the rebuild."""
        gen = self.gen

        def run(*args):
            if self.gen == gen:
                fn(*args)
        return run

    def crash(self) -> None:
        """Lose all volatile state: the in-memory version history and
        caches, pending declarations/pushes, the service queue, pull
        dedup state, any in-flight commit. The WAL (stable storage) and
        the historical perf counters survive."""
        self.down = True
        self.gen += 1
        self._decl = defaultdict(set)
        self._push_buf = defaultdict(list)
        self._unprocessed = defaultdict(int)
        self._pull_state = {}
        self._committing = False
        self.busy_until = self.sched.now
        self.version = 0
        if not self.timing_only:
            self.contents = {}
            self.caches = {}

    def recover(self) -> None:
        """Rebuild from the WAL: replay every committed version's fold
        order through the same ``apply_push``/``commit_block`` path the
        live server uses (bitwise — zero committed folds lost), then
        re-install the logged-but-uncommitted declarations through the
        service queue in arrival order. The queue work is re-paid (it
        was volatile), so recovery shifts timing, never numerics."""
        assert self.wal is not None and self.down
        self.down = False
        self.busy_until = self.sched.now
        if not self.timing_only:
            self.contents = {j: {0: self._contents0[j]}
                             for j in self.block_ids}
            self.caches = dict(self._caches0)
        for v, folds in enumerate(self.wal.commits):
            if not self.timing_only:
                for (i, j) in folds:
                    self.caches[j] = self.engine.apply_push(
                        self.caches[j], i, self.wal.value(i, v, j))
                for j in self.block_ids:
                    self.contents[j][v + 1] = self.engine.commit_block(
                        j, self.contents[j][v], self.caches[j])
        self.version = len(self.wal.commits)
        self.wal.replays += 1
        self.recoveries += 1
        obs = self.rt.obs if self.rt is not None else None
        if obs is not None and obs.spans is not None:
            obs.spans.instant(obs.server_track(self.sid), "wal_replay",
                              self.sched.now,
                              replayed=len(self.wal.commits))
        for (i, t, pushes) in self.wal.pending(self.version):
            self._decl[t].add(i)
            for (j, value) in pushes:
                self._unprocessed[t] += 1
                cost = self.push_cost
                if self.per_push:
                    cost += self._commit_sample()
                done = self._occupy(cost, label="push_service")
                self.sched.at(done, self._guard(
                    lambda t=t, i=i, j=j, v=value:
                    self._push_processed(t, i, j, v)))
        self._maybe_commit()

    # ---- reads ------------------------------------------------------------
    def content_at(self, j: int, version: int):
        return self.contents[j][version]

    def prune(self, min_version: int) -> None:
        """Drop committed versions below ``min_version`` (the oldest any
        worker can still legally read: min worker round - T). The
        newest version always stays. Keeps a real-compute run's memory
        at O(T) versions instead of O(num_rounds) when the caller does
        not want the full z trajectory."""
        if self.down:
            return                 # nothing in memory to prune
        for j in self.block_ids:
            store = self.contents[j]
            for v in [v for v in store if v < min_version
                      and v != self.version]:
                del store[v]

    # ---- telemetry --------------------------------------------------------
    @staticmethod
    def register_metrics(reg, domains: list, sched) -> None:
        """Register the server-side instruments over ``domains``
        (fleet totals + per-domain occupancy lists) into the run's
        :class:`~repro.obs.MetricsRegistry`."""
        reg.counter("commits", lambda: sum(d.commits for d in domains))
        reg.counter("pushes", lambda: sum(d.pushes for d in domains))
        reg.gauge("server_busy_time",
                  lambda: [d.busy_time for d in domains])
        reg.gauge("server_busy_frac",
                  lambda: [d.busy_time / sched.now if sched.now > 0
                           else 0.0 for d in domains])
        reg.gauge("server_wait_time",
                  lambda: [d.wait_time for d in domains])
