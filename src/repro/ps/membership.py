"""Elastic membership for the PS runtime — who participates in which round.

A parameter-server *service* does not run against a fixed fleet:
workers crash and restart, new workers join mid-run, old ones leave for
good. Algorithm 1 tolerates all of it — the partial-participation
analysis of Chang et al. (arXiv:1509.02597) only needs every round's
commit to fold the updates of the workers that actually pushed, with
everyone else's server-side w~ cache left stale — but the *runtime*
must keep three books straight:

* **gates** — a lock domain's round-v commit waits on declarations from
  the workers ACTIVE for round v, not the static edge neighborhood
  (otherwise one crash deadlocks every server);
* **participation** — every (round, worker) pair is either participated
  (declared) or absent; the matrix goes into the
  :class:`~repro.ps.trace.DelayTrace` so replay masks the absent pairs
  out of the epoch's block selection;
* **resumption** — a rejoining worker cannot re-enter at its crashed
  round: domains may have committed past it (their gates stopped
  waiting on it), so it resumes one past the current *service frontier*
  (the newest version any of its edge domains has committed or is
  committing — strictly future gates, never racing an in-flight
  commit). It pulls fresh z there, while its w~ rows on the servers —
  and its local y — stay stale until its next declare: the
  **version-reset** semantics the StalenessEnforcer accounts (a reset,
  not a tau violation).

This module is pure round-space bookkeeping (intervals of activity per
worker); the sim-time side — when crashes fire, how factors apply — is
:mod:`repro.ps.chaos`, and the wiring is ``PSRuntime``.
"""
from __future__ import annotations

from typing import Iterable, List, Optional

import numpy as np


class MembershipManager:
    """Per-worker activity intervals over the round horizon.

    Worker i's history is a list of half-open round intervals
    ``[start, end)`` (``end=None`` while active). Warm workers open
    ``[0, ·)`` at construction; cold workers (a ``join`` fault event)
    start with no interval and open their first at activation.
    Deactivation closes the open interval at the worker's current
    (uncompleted) round — rounds it fully declared stay participated.
    """

    def __init__(self, num_workers: int, num_rounds: int,
                 cold: Iterable[int] = ()):
        self.N = int(num_workers)
        self.R = int(num_rounds)
        cold = set(cold)
        bad = [i for i in cold if not 0 <= i < self.N]
        if bad:
            raise ValueError(f"cold (join) worker ids {bad} outside "
                             f"[0, {self.N})")
        self._intervals: List[List[List[Optional[int]]]] = [
            [] if i in cold else [[0, None]] for i in range(self.N)]
        self.crashes = 0
        self.rejoins = 0

    # ---- transitions ------------------------------------------------------
    def is_active(self, i: int) -> bool:
        iv = self._intervals[i]
        return bool(iv) and iv[-1][1] is None

    def deactivate(self, i: int, round_from: int) -> None:
        """Worker i went down while working on ``round_from`` (it never
        declared it): absent from that round until (re)activation."""
        if not self.is_active(i):
            raise RuntimeError(f"worker {i} deactivated while not active")
        iv = self._intervals[i]
        if iv[-1][0] >= round_from:       # interval never covered a round
            iv.pop()
        else:
            iv[-1][1] = round_from
        self.crashes += 1

    def activate(self, i: int, round_from: int) -> None:
        """Worker i resumes participation at ``round_from`` (computed by
        the runtime as one past its edge domains' service frontier)."""
        if self.is_active(i):
            raise RuntimeError(f"worker {i} activated while already active")
        last_end = self._intervals[i][-1][1] if self._intervals[i] else 0
        if round_from < last_end:
            raise RuntimeError(
                f"worker {i} resumed at round {round_from} inside its "
                f"absence window (absent from {last_end}) — resumption "
                f"must be at the service frontier")
        if round_from < self.R:
            self._intervals[i].append([round_from, None])
        self.rejoins += 1

    # ---- durability (ps/recovery.py snapshots) ----------------------------
    def state_dict(self) -> dict:
        """JSON-serializable membership state for a runtime snapshot."""
        return {"intervals": [[list(iv) for iv in worker]
                              for worker in self._intervals],
                "crashes": self.crashes, "rejoins": self.rejoins}

    def restore_state(self, state: dict) -> None:
        """Restore from :meth:`state_dict` (JSON round-tripped: open
        intervals' ``None`` ends survive as nulls)."""
        if len(state["intervals"]) != self.N:
            raise ValueError(
                f"membership snapshot covers {len(state['intervals'])} "
                f"workers; this runtime has {self.N}")
        self._intervals = [[list(iv) for iv in worker]
                           for worker in state["intervals"]]
        self.crashes = state["crashes"]
        self.rejoins = state["rejoins"]

    # ---- queries ----------------------------------------------------------
    def required(self, i: int, v: int) -> bool:
        """Does round v's commit gate wait on worker i's declaration?"""
        for (s, e) in self._intervals[i]:
            if s <= v < (self.R if e is None else e):
                return True
        return False

    def participated_rounds(self, i: int) -> int:
        return sum((self.R if e is None else e) - s
                   for (s, e) in self._intervals[i])

    def participation_matrix(self) -> np.ndarray:
        """(rounds, N) bool — True where worker i declared round t."""
        P = np.zeros((self.R, self.N), bool)
        for i in range(self.N):
            for (s, e) in self._intervals[i]:
                P[s:(self.R if e is None else e), i] = True
        return P

    @property
    def elastic(self) -> bool:
        """Whether any worker was ever absent for any round."""
        return any(iv != [[0, None]] for iv in self._intervals)
