"""Roofline model for TPU v5e from dry-run compiled artifacts.

Hardware constants (per chip):
  peak bf16 compute : 197 TFLOP/s
  HBM bandwidth     : 819 GB/s
  ICI               : ~50 GB/s per link

All inputs are per-device quantities (cost_analysis and as_text both
describe the partitioned per-device module), so each term is simply
per-device work / per-chip rate:

  compute    = flops_per_device / peak
  memory     = hbm_bytes_per_device / hbm_bw
  collective = sum_k protocol_factor_k * bytes_k / ici_bw

Protocol factors approximate ring implementations on the 2D torus:
all-reduce 2x (reduce-scatter + all-gather), others 1x on their
result-byte conventions (see hlo.py).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

_PROTOCOL_FACTOR = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_per_device: float
    hbm_bytes_per_device: float
    collective_bytes: Dict[str, int]
    chips: int
    model_flops_total: float = 0.0      # 6*N*D (active) across the step
    bytes_accessed_peak: float = 0.0    # memory_analysis peak, if available

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        t = 0.0
        for kind, b in self.collective_bytes.items():
            if kind == "total":
                continue
            t += _PROTOCOL_FACTOR.get(kind, 1.0) * b / ICI_BW
        return t

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        """Perfect-overlap lower bound: max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> Optional[float]:
        """MODEL_FLOPS / total HLO flops — remat/redundancy waste probe."""
        if not self.model_flops_total:
            return None
        return self.model_flops_total / (self.flops_per_device * self.chips)

    @property
    def mfu_bound(self) -> Optional[float]:
        """Upper bound on MFU implied by the roofline (useful flops over
        peak at the bound step time)."""
        if not self.model_flops_total or self.step_time == 0:
            return None
        return (self.model_flops_total / self.chips) / (
            self.step_time * PEAK_FLOPS)

    def row(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "step_time_bound_s": self.step_time,
            "useful_flops_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu_bound,
            "flops_per_device": self.flops_per_device,
            "hbm_bytes_per_device": self.hbm_bytes_per_device,
            "collective_bytes_per_device": self.collective_bytes.get("total", 0),
        }


def model_flops(cfg, shape) -> float:
    """6 * N_active * tokens for training; 2 * N_active * tokens for a
    forward-only step (prefill); decode processes one token per request."""
    n_active = cfg.param_count(active_only=True)
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape.global_batch      # decode: 1 tok/request
