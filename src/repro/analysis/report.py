"""Render EXPERIMENTS.md tables from experiments/dryrun.jsonl.

  PYTHONPATH=src python -m repro.analysis.report [--variant baseline]
"""
import argparse
import json
import os
from collections import defaultdict


def load(path, variant=None):
    rows = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            key = (r["arch"], r["shape"], r["mesh"], r.get("variant", "baseline"))
            rows[key] = r
    if variant is not None:
        rows = {k: v for k, v in rows.items() if k[3] == variant}
    return rows


def fmt_bytes(b):
    if b >= 1 << 30:
        return f"{b / (1 << 30):.1f}G"
    if b >= 1 << 20:
        return f"{b / (1 << 20):.1f}M"
    return f"{b / 1024:.0f}K"


def roofline_table(rows, mesh="pod"):
    out = ["| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bottleneck "
           "| useful_flops | MFU bound | coll bytes/dev |",
           "|---|---|---|---|---|---|---|---|---|"]
    for (arch, shape, m, _v), r in sorted(rows.items()):
        if m != mesh:
            continue
        if r["status"] == "skipped":
            out.append(f"| {arch} | {shape} | — | — | — | skipped "
                       f"(full-attn @500k) | — | — | — |")
            continue
        uf = r.get("useful_flops_ratio")
        mfu = r.get("mfu_bound")
        uf_s = f"{uf:.3f}" if uf is not None else "—"
        mfu_s = f"{mfu:.3f}" if mfu is not None else "—"
        out.append(
            f"| {arch} | {shape} | {r['t_compute_s']:.2e} | "
            f"{r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} | "
            f"**{r['bottleneck']}** | {uf_s} | {mfu_s} | "
            f"{fmt_bytes(r['collective_bytes_per_device'])} |")
    return "\n".join(out)


def dryrun_table(rows, mesh):
    out = [f"| arch | shape | status | flops/dev | HBM bytes/dev | "
           f"coll bytes/dev | arg bytes/dev | compile (s) |",
           "|---|---|---|---|---|---|---|---|"]
    for (arch, shape, m, _v), r in sorted(rows.items()):
        if m != mesh:
            continue
        if r["status"] != "ok":
            out.append(f"| {arch} | {shape} | {r['status']} | — | — | — | — | — |")
            continue
        mem = r.get("memory_analysis", {})
        args = mem.get("argument_size_in_bytes", 0)
        out.append(
            f"| {arch} | {shape} | ok | {r['flops_per_device']:.2e} | "
            f"{fmt_bytes(r['hbm_bytes_per_device'])} | "
            f"{fmt_bytes(r['collective_bytes_per_device'])} | "
            f"{fmt_bytes(args)} | "
            f"{r['compile_s']['compile']:.1f} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--path", default="experiments/dryrun.jsonl")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--table", default="roofline",
                    choices=["roofline", "dryrun"])
    ap.add_argument("--mesh", default="pod")
    args = ap.parse_args()
    rows = load(args.path, args.variant)
    if args.table == "roofline":
        print(roofline_table(rows, args.mesh))
    else:
        print(dryrun_table(rows, args.mesh))


if __name__ == "__main__":
    main()
