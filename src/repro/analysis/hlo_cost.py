"""Trip-count-aware HLO cost analysis.

``compiled.cost_analysis()`` on the CPU backend counts each while-loop
*body once*, so any scanned layer stack (all our models) is undercounted
by ~num_layers x. XLA annotates every while with
``backend_config={"known_trip_count":{"n":...}}`` — this module walks
the computation call graph from ENTRY and scales costs correctly.

Cost model (per device — the module is the partitioned SPMD program):
  flops      : dot = 2 * prod(result dims) * prod(contracting dims);
               elementwise/reduce = prod(result dims) (1 flop/elem).
  hbm bytes  : per *top-level* instruction: operand bytes + result bytes
               (fusion = boundary only — internals live in
               registers/VMEM, which is exactly the fused-kernel HBM
               model; tuple/GTE/parameter/constant/bitcast are free).
  collective : result-shape bytes per kind, scaled by loop trips.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "iota", "partition-id", "replica-id"}
_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shapes(text: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _bytes_of(text: str) -> int:
    total = 0
    for dt, dims in _shapes(text):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _elems_of(text: str) -> int:
    total = 0
    for _dt, dims in _shapes(text):
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    type_text: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.flops += mult * other.flops
        self.hbm_bytes += mult * other.hbm_bytes
        for k, v in other.coll.items():
            self.coll[k] += mult * v


_INSTR_RE = re.compile(r"^\s+(?:ROOT )?%?([^\s=]+) = ")
_COMP_RE = re.compile(r"^(ENTRY )?%?([^\s(]+)[^{]*\{\s*$")
_TRIP_RE = re.compile(r'known_trip_count":\{"n":"(\d+)"')


def _operand_names(text: str) -> List[str]:
    """Operand instruction names, with or without ``%`` sigils.

    Optimized HLO writes ``fusion(%a, %b)``; the pre-optimization dump
    (``lower().compiler_ir(dialect="hlo")``) writes ``add(a.1, b.2)``,
    optionally with leading shape tokens. Commas inside ``[]``/``{}``/
    ``()`` (shape dims, layouts, nested tuples) are not separators."""
    names: List[str] = []
    depth = 0
    tok: List[str] = []
    for ch in text + ",":
        if ch == "," and depth == 0:
            t = "".join(tok).strip()
            tok = []
            if t:
                names.append(t.split()[-1].lstrip("%"))
            continue
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        tok.append(ch)
    return names


def _balanced(text: str, start: int) -> int:
    depth = 0
    for i in range(start, len(text)):
        if text[i] == "(":
            depth += 1
        elif text[i] == ")":
            depth -= 1
            if depth == 0:
                return i
    return len(text) - 1


def parse_module(hlo: str):
    comps: Dict[str, List[Instr]] = {}
    entry: Optional[str] = None
    shapes: Dict[str, str] = {}          # instr name -> type text
    cur: Optional[str] = None
    for line in hlo.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            # optimized headers carry a `(params) -> result` signature;
            # the pre-optimization dump is just `name {`
            if m and not line.startswith("HloModule"):
                cur = m.group(2)
                comps[cur] = []
                if m.group(1):
                    entry = cur
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if not m:
            continue
        name = m.group(1)
        rest = line[m.end():]
        # type: tuple or simple
        if rest.startswith("("):
            close = _balanced(rest, 0)
            type_text = rest[: close + 1]
            rest2 = rest[close + 1:].lstrip()
        else:
            sp = rest.index(" ")
            type_text = rest[:sp]
            rest2 = rest[sp + 1:]
        par = rest2.find("(")
        if par < 0:
            continue
        op = rest2[:par].strip()
        aclose = _balanced(rest2, par)
        operand_text = rest2[par + 1 : aclose]
        attrs = rest2[aclose + 1:]
        operands = _operand_names(operand_text)
        comps[cur].append(Instr(name, op, type_text, operands, attrs))
        shapes[name] = type_text
    return comps, entry, shapes


def _dot_flops(instr: Instr, shapes: Dict[str, str]) -> float:
    res_elems = _elems_of(instr.type_text)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.attrs)
    k = 1
    if m and instr.operands:
        lhs_type = shapes.get(instr.operands[0], "")
        sh = _shapes(lhs_type)
        if sh:
            dims = sh[0][1]
            for idx in (int(x) for x in m.group(1).split(",") if x):
                if idx < len(dims):
                    k *= dims[idx]
    return 2.0 * res_elems * k


def analyze_hlo(hlo: str) -> Cost:
    comps, entry, shapes = parse_module(hlo)
    memo: Dict[str, Cost] = {}

    def comp_cost(name: str) -> Cost:
        if name in memo:
            return memo[name]
        memo[name] = Cost()                      # guard (no real recursion)
        total = Cost()
        for ins in comps.get(name, []):
            op = ins.op
            if op in _FREE_OPS:
                continue
            if op == "fusion":
                called = re.search(r"calls=%?([^\s,]+)", ins.attrs)
                if called:
                    sub = comp_cost(called.group(1))
                    total.flops += sub.flops     # flops only; bytes at boundary
                total.hbm_bytes += _boundary_bytes(ins, shapes, comps)
                continue
            if op == "while":
                body = re.search(r"body=%?([^\s,]+)", ins.attrs)
                cond = re.search(r"condition=%?([^\s,]+)", ins.attrs)
                trip = 1
                tm = _TRIP_RE.search(ins.attrs)
                if tm:
                    trip = int(tm.group(1))
                if body:
                    total.add(comp_cost(body.group(1)), trip)
                if cond:
                    total.add(comp_cost(cond.group(1)), trip + 1)
                continue
            if op in ("call", "async-start"):
                called = re.search(r"(?:to_apply|calls)=%?([^\s,]+)", ins.attrs)
                if called:
                    total.add(comp_cost(called.group(1)))
                continue
            if op == "conditional":
                for c in re.findall(r"(?:branch_computations=\{([^}]*)\}|"
                                    r"true_computation=%?([^\s,]+)|"
                                    r"false_computation=%?([^\s,]+))", ins.attrs):
                    for g in c:
                        for nm in re.findall(r"%?([\w\.\-]+)", g or ""):
                            if nm in comps:
                                total.add(comp_cost(nm))
                total.hbm_bytes += _boundary_bytes(ins, shapes, comps)
                continue
            kind = next((k for k in _COLL_KINDS if op.startswith(k)), None)
            if kind is not None:
                if op.endswith("-done"):
                    continue
                b = _bytes_of(ins.type_text)
                total.coll[kind] += b
                total.hbm_bytes += _boundary_bytes(ins, shapes, comps)
                continue
            # generic compute op
            total.hbm_bytes += _boundary_bytes(ins, shapes, comps)
            if op == "dot":
                total.flops += _dot_flops(ins, shapes)
            elif op == "convolution":
                # approx: 2 * result elems * kernel elems / out_channels
                total.flops += 2.0 * _elems_of(ins.type_text)
            elif op in ("reduce", "reduce-window"):
                total.flops += sum(_elems_of(shapes.get(o, ""))
                                   for o in ins.operands[:1])
            else:
                total.flops += _elems_of(ins.type_text)
            if op in ("reduce", "map", "sort", "scatter", "select-and-scatter"):
                called = re.search(r"to_apply=%?([^\s,]+)", ins.attrs)
                # tiny scalar computations — ignore
        memo[name] = total
        return total

    if entry is None:
        raise ValueError("no ENTRY computation found")
    return comp_cost(entry)


def _boundary_bytes(ins: Instr, shapes: Dict[str, str],
                    comps: Optional[Dict[str, List["Instr"]]] = None) -> int:
    """HBM traffic of one top-level instruction.

    Windowed patterns are special-cased: (dynamic-)slice and
    dynamic-update-slice on a big buffer touch only the window (XLA
    aliases the buffer / reads only the sliced region), so counting the
    full operand would overcharge scan carries by ~num_layers x and
    per-leaf unpacks of a packed table by ~num_leaves x.
    """
    op = ins.op
    result = _bytes_of(ins.type_text)
    if op in ("slice", "dynamic-slice"):
        # Either slice kind reads only the window it produces, never the
        # full operand — charging operand+result would bill a per-leaf
        # unpack of a packed block table at num_leaves x the table.
        return 2 * result
    if op == "dynamic-update-slice":
        upd = _bytes_of(shapes.get(ins.operands[1], "")) if len(ins.operands) > 1 else 0
        return 2 * upd
    if op == "gather":
        idx = _bytes_of(shapes.get(ins.operands[1], "")) if len(ins.operands) > 1 else 0
        return 2 * result + idx
    if op == "scatter":
        upd = _bytes_of(shapes.get(ins.operands[2], "")) if len(ins.operands) > 2 else result
        return 2 * upd
    if op == "fusion" and comps is not None:
        called = re.search(r"calls=%?([^\s,]+)", ins.attrs)
        root = None
        if called and called.group(1) in comps:
            body = comps[called.group(1)]
            if body:
                root = body[-1]
        if root is not None and root.op == "dynamic-slice":
            return 2 * result + sum(
                _bytes_of(shapes.get(o, "")) for o in ins.operands
                if _bytes_of(shapes.get(o, "")) <= result)
        if root is not None and root.op in ("dynamic-update-slice", "scatter"):
            # in-place rooted fusion: charge small operands twice (read
            # update / write slice), skip the big aliased buffer.
            small = sum(_bytes_of(shapes.get(o, "")) for o in ins.operands
                        if _bytes_of(shapes.get(o, "")) * 2 <= result)
            return 2 * small
    b = result
    for o in ins.operands:
        t = shapes.get(o)
        if t:
            b += _bytes_of(t)
    return b


def collective_bytes_scaled(hlo: str) -> Dict[str, int]:
    cost = analyze_hlo(hlo)
    out = {k: int(v) for k, v in cost.coll.items()}
    out["total"] = int(sum(cost.coll.values()))
    return out
