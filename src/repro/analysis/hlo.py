"""Parse collective traffic out of post-SPMD HLO text.

``compiled.as_text()`` is the *per-device* partitioned module; we sum
the result-shape bytes of every collective op, bucketed by kind. For
all-gather the result is the gathered (larger) buffer — a reasonable
proxy for link bytes in a ring implementation; for reduce-scatter /
all-reduce the result is the reduced buffer (ring moves ~2x that; we
report raw bytes and apply protocol factors in roofline.py).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# result part of an HLO instruction: "%name = <shape-or-tuple> opname("
_INSTR_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:[a-z0-9]+\[[0-9,]*\][^\s]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes by collective kind (result-shape convention).
    ``-done`` ops are skipped so async start/done pairs count once."""
    out: Dict[str, int] = defaultdict(int)
    for m in _INSTR_RE.finditer(hlo_text):
        shape_txt, kind = m.group(1), m.group(2)
        if "-done(" in m.group(0):
            continue
        out[kind] += _shape_bytes(shape_txt)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)


def count_ops(hlo_text: str) -> Dict[str, int]:
    counts: Dict[str, int] = defaultdict(int)
    for kind in _COLLECTIVES:
        counts[kind] = len(re.findall(rf"\s{kind}(?:-start)?\(", hlo_text))
    counts["fusion"] = hlo_text.count(" fusion(")
    counts["while"] = hlo_text.count(" while(")
    return dict(counts)
