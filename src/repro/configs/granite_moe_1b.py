"""granite-moe-1b-a400m [moe] — 32 experts top-8.

24L d_model=1024 16H (GQA kv=8) d_ff=512 (per expert) vocab=49155.
[hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from .base import ModelConfig, MoEConfig

ARCH_ID = "granite-moe-1b-a400m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="moe",
        num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
        d_ff=512, vocab_size=49155, head_dim=64,
        moe=MoEConfig(num_experts=32, top_k=8, expert_ff=512),
        citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", arch_type="moe",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=64, vocab_size=512, head_dim=32,
        moe=MoEConfig(num_experts=4, top_k=2, expert_ff=64, capacity_factor=4.0),
        citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
    )
