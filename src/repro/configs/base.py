"""Model / run configuration dataclasses.

Every assigned architecture gets one ``ModelConfig`` (full size, exercised
only via the dry-run) plus a ``smoke()`` reduced variant (2 layers,
d_model <= 512, <= 4 experts) used in CPU tests.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_ff: int                  # per-expert hidden dim
    router_aux_coef: float = 0.01   # load-balance loss coefficient
    capacity_factor: float = 1.25   # GShard capacity; >= num_experts/top_k => dropless


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int                  # d_state (N in Mamba2)
    head_dim: int = 64              # P in Mamba2 (channels per SSD head)
    expand: int = 2                 # d_inner = expand * d_model
    chunk_size: int = 256           # SSD chunk length
    conv_width: int = 4             # depthwise causal conv window


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3 style)."""
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int                  # query heads (0 for attn-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None  # default d_model // num_heads
    # --- attention flavour flags ---
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_fraction: float = 1.0      # <1.0 => partial ("2d") RoPE (ChatGLM)
    rope_theta: float = 10000.0
    sliding_window: Optional[int] = None   # SWA width (Mixtral)
    mla: Optional[MLAConfig] = None
    # --- mixture / ssm / hybrid ---
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid_attn_every: int = 0      # hybrid: 1 shared attn block every k SSM layers
    # --- enc-dec (audio) ---
    encoder_layers: int = 0         # >0 => encoder-decoder
    encoder_seq_len: int = 1500     # stub frontend frame count (Whisper 30s)
    # --- misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    act: str = "swiglu"             # swiglu | gelu
    dtype: str = "float32"          # compute dtype for CPU tests
    param_dtype: str = "float32"
    remat: bool = False             # activation checkpointing in the layer scan
    attn_impl: str = "naive"        # naive (materialized S^2) | chunked (flash-style)
    attn_chunk: int = 1024          # query/key block for chunked attention
    moe_impl: str = "onehot"        # onehot (GShard einsum) | scatter (index dispatch)
    citation: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def is_enc_dec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.arch_type == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch serve 500k-token contexts (per-step cost not O(L^2),
        decode KV memory bounded)?"""
        return (
            self.arch_type in ("ssm", "hybrid")
            or self.sliding_window is not None
        )

    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (for roofline MODEL_FLOPS = 6 N D) ----
    def param_count(self, active_only: bool = False) -> int:
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        per_layer = 0
        if self.arch_type == "ssm" or (self.arch_type == "hybrid"):
            if self.ssm is None:
                raise ValueError("ssm config required")
            di = self.ssm.expand * d
            nh = di // self.ssm.head_dim
            # in_proj (z,x,B,C,dt) + out_proj + conv + norms (B,C per group, G=1)
            per_layer_ssm = d * (2 * di + 2 * self.ssm.state_dim + nh) + di * d
            per_layer_ssm += self.ssm.conv_width * (di + 2 * self.ssm.state_dim)
            per_layer_ssm += 2 * d + di
        if self.arch_type == "ssm":
            per_layer = per_layer_ssm
        else:
            attn = d * nq * hd + 2 * d * nkv * hd + nq * hd * d
            if self.mla is not None:
                m = self.mla
                attn = (
                    d * m.q_lora_rank
                    + m.q_lora_rank * nq * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * nq * (m.qk_nope_head_dim + m.v_head_dim)
                    + nq * m.v_head_dim * d
                )
            if self.moe is not None:
                n_mlp_experts = self.moe.top_k if active_only else self.moe.num_experts
                mlp = n_mlp_experts * 3 * d * self.moe.expert_ff + d * self.moe.num_experts
            elif self.act == "swiglu":
                mlp = 3 * d * ff
            else:
                mlp = 2 * d * ff
            per_layer = attn + mlp + 2 * d
        total = 0
        if self.arch_type == "hybrid":
            n_attn = self.num_layers // max(self.hybrid_attn_every, 1)
            total += (self.num_layers) * per_layer_ssm + n_attn * per_layer
        else:
            total += self.num_layers * per_layer
        if self.encoder_layers:
            # encoder layers: self-attn + mlp; decoder adds cross-attn
            total += self.encoder_layers * per_layer
            total += self.num_layers * (d * nq * hd + 2 * d * nkv * hd + nq * hd * d)
        total += V * d  # embeddings
        if not self.tie_embeddings:
            total += V * d
        return int(total)


@dataclasses.dataclass(frozen=True)
class ADMMConfig:
    """Hyper-parameters of AsyBADMM (paper §3, Theorem 1)."""
    rho: float = 100.0          # penalty ρ_i (paper uses 100)
    gamma: float = 0.01         # server prox regularizer γ (paper uses 0.01)
    max_delay: int = 0          # bounded-delay D (Assumption 3); 0 == synchronous
    block_fraction: float = 1.0 # fraction of blocks each worker updates per round
    l1_coef: float = 0.0        # λ for h(z) = λ||z||_1
    clip: Optional[float] = None  # box constraint ||z||_inf <= C
    num_blocks: int = 16        # M logical blocks (== model-axis size on pod)
    block_selection: str = "random"  # random | cyclic | gauss_southwell | zipf
    zipf_a: float = 1.1         # skew exponent for block_selection="zipf"
                                # (block j sampled with weight (j+1)^-a)
    # incremental/stochastic workers (Hong 2014): fraction of each
    # worker's samples drawn fresh per epoch; None/1.0 = full batch
    minibatch: Optional[float] = None
    # compute backend for the epoch's fused worker/server hot path:
    # jnp | pallas | auto (auto = pallas on TPU, jnp elsewhere)
    backend: str = "auto"
    # SPMD mesh for the sharded epoch: None/"none" (single device), a jax
    # Mesh, or a preset name resolved by repro.launch.mesh.resolve_mesh
    # ("test" | "pod" | "multipod"). Workers shard over the data axes,
    # FlatSpace block servers over the model axis (core/sharded.py).
    mesh: Any = None
    # per-device kernel tile autotuning (kernels/autotune.py):
    # "off" = static heuristics; "cached" = use winners persisted in
    # benchmarks/kernels_tuned.json (heuristic fallback on a miss);
    # "sweep" = measure this session's shapes up front, persist the
    # winners, then run cached
    autotune: str = "off"
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
