"""whisper-medium [audio] — encoder-decoder; conv frontend stubbed.

24L d_model=1024 16H (GQA kv=16) d_ff=4096 vocab=51865.
input_specs() supplies precomputed mel/conv frame embeddings
(B, 1500, d_model); the transformer backbone is what we implement.
[arXiv:2212.04356]
"""
from .base import ModelConfig

ARCH_ID = "whisper-medium"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="audio",
        num_layers=24, encoder_layers=24, encoder_seq_len=1500,
        d_model=1024, num_heads=16, num_kv_heads=16,
        d_ff=4096, vocab_size=51865, act="gelu",
        citation="arXiv:2212.04356",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", arch_type="audio",
        num_layers=2, encoder_layers=2, encoder_seq_len=16,
        d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=512, act="gelu",
        citation="arXiv:2212.04356",
    )
