"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention block.

38L d_model=2048 32H (GQA kv=32) d_ff=8192 vocab=32000, ssm_state=64.
[arXiv:2411.15242]
"""
from .base import ModelConfig, SSMConfig

ARCH_ID = "zamba2-1.2b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="hybrid",
        num_layers=38, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=8192, vocab_size=32000, head_dim=64,
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2),
        hybrid_attn_every=6,
        citation="arXiv:2411.15242",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", arch_type="hybrid",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=512, head_dim=32,
        ssm=SSMConfig(state_dim=16, head_dim=32, expand=2, chunk_size=16),
        hybrid_attn_every=1,
        citation="arXiv:2411.15242",
    )
