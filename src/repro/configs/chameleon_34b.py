"""chameleon-34b [vlm] — early-fusion; VQ image tokens share the vocab.

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536 (text + 8192 VQ
image codes), qk-norm. The VQ-VAE image tokenizer is the stubbed
frontend: input_specs() supplies interleaved text+image token ids.
[arXiv:2405.09818]
"""
from .base import ModelConfig

ARCH_ID = "chameleon-34b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="vlm",
        num_layers=48, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=22016, vocab_size=65536, head_dim=128, qk_norm=True,
        citation="arXiv:2405.09818",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", arch_type="vlm",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=512, head_dim=32, qk_norm=True,
        citation="arXiv:2405.09818",
    )
