"""qwen1.5-32b [dense] — QKV bias.

64L d_model=5120 40H (GQA kv=40) d_ff=27392 vocab=152064.
[hf:Qwen/Qwen1.5-0.5B]
"""
from .base import ModelConfig

ARCH_ID = "qwen1.5-32b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="dense",
        num_layers=64, d_model=5120, num_heads=40, num_kv_heads=40,
        d_ff=27392, vocab_size=152064, qkv_bias=True,
        citation="hf:Qwen/Qwen1.5-0.5B",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", arch_type="dense",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=512, qkv_bias=True,
        citation="hf:Qwen/Qwen1.5-0.5B",
    )
