"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.

48L d_model=1024 d_ff=0 vocab=50280, ssm_state=128.
[arXiv:2405.21060]
"""
from .base import ModelConfig, SSMConfig

ARCH_ID = "mamba2-370m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="ssm",
        num_layers=48, d_model=1024, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=50280,
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2),
        tie_embeddings=True,
        citation="arXiv:2405.21060",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", arch_type="ssm",
        num_layers=2, d_model=128, num_heads=0, num_kv_heads=0,
        d_ff=0, vocab_size=512,
        ssm=SSMConfig(state_dim=16, head_dim=32, expand=2, chunk_size=16),
        tie_embeddings=True,
        citation="arXiv:2405.21060",
    )
