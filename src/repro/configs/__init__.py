"""Architecture config registry: ``get_config(arch_id)`` / ``get_smoke``.

Every assigned architecture is a selectable ``--arch <id>`` config; each
module cites its source paper / model card.
"""
from . import (chameleon_34b, chatglm3_6b, granite_moe_1b, mamba2_370m,
               minicpm3_4b, mixtral_8x7b, qwen1p5_32b, qwen3_1p7b,
               whisper_medium, zamba2_1p2b)
from .base import (ADMMConfig, INPUT_SHAPES, InputShape, MLAConfig,
                   ModelConfig, MoEConfig, SSMConfig)

_MODULES = [
    zamba2_1p2b, minicpm3_4b, qwen1p5_32b, whisper_medium, qwen3_1p7b,
    mixtral_8x7b, granite_moe_1b, mamba2_370m, chameleon_34b, chatglm3_6b,
]

REGISTRY = {m.ARCH_ID: m for m in _MODULES}


def list_archs():
    return list(REGISTRY)


def get_config(arch_id: str):
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list_archs()}")
    return REGISTRY[arch_id].config()


def get_smoke(arch_id: str):
    if arch_id not in REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {list_archs()}")
    return REGISTRY[arch_id].smoke()
