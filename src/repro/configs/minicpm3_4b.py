"""minicpm3-4b [dense] — Multi-head Latent Attention (MLA).

62L d_model=2560 40H (GQA kv=40) d_ff=6400 vocab=73448.
[hf:openbmb/MiniCPM3-4B]
"""
from .base import MLAConfig, ModelConfig

ARCH_ID = "minicpm3-4b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="dense",
        num_layers=62, d_model=2560, num_heads=40, num_kv_heads=40,
        d_ff=6400, vocab_size=73448,
        mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256,
                      qk_nope_head_dim=64, qk_rope_head_dim=32, v_head_dim=64),
        citation="hf:openbmb/MiniCPM3-4B",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", arch_type="dense",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=4,
        d_ff=256, vocab_size=512,
        mla=MLAConfig(q_lora_rank=48, kv_lora_rank=32,
                      qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16),
        citation="hf:openbmb/MiniCPM3-4B",
    )
