"""qwen3-1.7b [dense] — qk-norm, GQA.

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936.
[hf:Qwen/Qwen3-8B]
"""
from .base import ModelConfig

ARCH_ID = "qwen3-1.7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="dense",
        num_layers=28, d_model=2048, num_heads=16, num_kv_heads=8,
        d_ff=6144, vocab_size=151936, head_dim=128,
        qk_norm=True, tie_embeddings=True, rope_theta=1_000_000.0,
        citation="hf:Qwen/Qwen3-8B",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", arch_type="dense",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=512, head_dim=32,
        qk_norm=True, tie_embeddings=True,
        citation="hf:Qwen/Qwen3-8B",
    )
