"""chatglm3-6b [dense] — partial ("2d") RoPE, extreme GQA (kv=2).

28L d_model=4096 32H (GQA kv=2) d_ff=13696 vocab=65024.
[arXiv:2406.12793]
"""
from .base import ModelConfig

ARCH_ID = "chatglm3-6b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="dense",
        num_layers=28, d_model=4096, num_heads=32, num_kv_heads=2,
        d_ff=13696, vocab_size=65024, head_dim=128,
        qkv_bias=True, rope_fraction=0.5,
        citation="arXiv:2406.12793",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", arch_type="dense",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=512, head_dim=32,
        qkv_bias=True, rope_fraction=0.5,
        citation="arXiv:2406.12793",
    )
