"""mixtral-8x7b [moe] — 8 experts top-2, sliding-window attention.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
SWA(4096) makes this the dense-attention arch eligible for long_500k.
[arXiv:2401.04088]
"""
from .base import ModelConfig, MoEConfig

ARCH_ID = "mixtral-8x7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID, arch_type="moe",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=32000, head_dim=128,
        sliding_window=4096,
        moe=MoEConfig(num_experts=8, top_k=2, expert_ff=14336),
        citation="arXiv:2401.04088",
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID + "-smoke", arch_type="moe",
        num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
        d_ff=256, vocab_size=512, head_dim=32, sliding_window=32,
        moe=MoEConfig(num_experts=4, top_k=2, expert_ff=256, capacity_factor=4.0),
        citation="arXiv:2401.04088",
    )
