from .train_state import ADMMTrainState, SGDTrainState
from .trainer import ADMMTrainer, SGDTrainer
