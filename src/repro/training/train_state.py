"""Train-state containers for both trainers."""
from __future__ import annotations

from typing import Any, NamedTuple

import jax


class SGDTrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array


class ADMMTrainState(NamedTuple):
    """State of the block-wise consensus trainer (pytree mode).

    z_hist : pytree; every leaf has leading axis (D+1,) — the bounded-
             staleness ring buffer (index 0 = newest consensus params).
    y      : pytree; leaves have leading worker axis (N, ...) — duals.
             By eq. (25) these are exactly -(last gradient) per worker.
    w_cache: pytree; leaves (N, ...) — server-side stale w~ cache.
    """
    z_hist: Any
    y: Any
    w_cache: Any
    step: jax.Array
    rng: jax.Array

    @property
    def params(self):
        return jax.tree.map(lambda a: a[0], self.z_hist)
