"""Trainers.

``ADMMTrainer`` — AsyBADMM as a distributed-training feature (pytree
mode). The mapping from the paper's parameter-server picture to the
SPMD pod is in DESIGN.md §3:

  worker i      = data-parallel slice i (leading worker axis N, sharded
                  over the ``data``/``pod`` mesh axes)
  server j      = logical parameter block j (leaves assigned by
                  core.blocks.make_tree_blocks; on the pod each block
                  lives on its ``model``-axis shard)
  push w_ij     = the sum over the worker axis inside jit — under pjit
                  this lowers to exactly one reduce-scatter/all-reduce
                  per selected block, the collective analogue of the
                  paper's lock-free per-block push
  bounded delay = ring buffer z_hist + per-(worker, block) sampled
                  delays (Assumption 3)

``SGDTrainer`` — the conventional synchronous data-parallel baseline
(mean gradient + Adam/SGD), for the convergence/efficiency comparisons.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ADMMConfig
from ..core.admm import worker_update
from ..core.blocks import TreeBlocks, make_tree_blocks
from ..core.prox import make_prox
from ..optim.optimizers import Optimizer, apply_updates
from .train_state import ADMMTrainState, SGDTrainState


# ===========================================================================
# baseline: synchronous data-parallel SGD/Adam
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class SGDTrainer:
    loss_fn: Callable            # loss_fn(params, batch) -> scalar
    optimizer: Optimizer

    def init(self, params) -> SGDTrainState:
        return SGDTrainState(params=params,
                             opt_state=self.optimizer.init(params),
                             step=jnp.zeros((), jnp.int32))

    def train_step(self, state: SGDTrainState, batch) -> Tuple[SGDTrainState, Dict]:
        loss, grads = jax.value_and_grad(self.loss_fn)(state.params, batch)
        updates, opt_state = self.optimizer.update(grads, state.opt_state,
                                                   state.params)
        params = apply_updates(state.params, updates)
        return (SGDTrainState(params, opt_state, state.step + 1),
                {"loss": loss})


# ===========================================================================
# AsyBADMM consensus trainer
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class ADMMTrainer:
    """Block-wise asynchronous consensus training over a params pytree.

    loss_fn(params, worker_batch) -> scalar — per-worker loss; batches
    carry a leading worker axis N.
    """
    loss_fn: Callable
    admm: ADMMConfig
    num_workers: int
    blocks: Optional[TreeBlocks] = None

    def _blocks(self, params) -> TreeBlocks:
        if self.blocks is not None:
            return self.blocks
        return make_tree_blocks(params, self.admm.num_blocks)

    def init(self, params, *, cyclic: bool = False) -> ADMMTrainState:
        D = self.admm.max_delay
        N = self.num_workers
        z_hist = jax.tree.map(
            lambda p: jnp.broadcast_to(p, (D + 1,) + p.shape).copy(), params)
        y = jax.tree.map(
            lambda p: jnp.zeros((N,) + p.shape, p.dtype), params)
        if cyclic:
            # Gauss-Seidel rounds never read the stale-w cache (every
            # worker pushes the active block fresh) — don't carry it.
            w_cache = ()
        else:
            # w_cache init: w = rho*x + y with x = z0, y = 0  ->  rho * z0
            w_cache = jax.tree.map(
                lambda p: jnp.broadcast_to(self.admm.rho * p, (N,) + p.shape)
                .astype(p.dtype).copy(), params)
        return ADMMTrainState(z_hist=z_hist, y=y, w_cache=w_cache,
                              step=jnp.zeros((), jnp.int32),
                              rng=jax.random.PRNGKey(self.admm.seed))

    # -----------------------------------------------------------------
    def train_step(self, state: ADMMTrainState, batch
                   ) -> Tuple[ADMMTrainState, Dict]:
        """One AsyBADMM epoch across all N workers (Alg. 1, both roles).

        batch: pytree with leading axes (N, per_worker_batch, ...).
        """
        cfg = self.admm
        N, M = self.num_workers, cfg.num_blocks
        params0 = jax.tree.map(lambda a: a[0], state.z_hist)
        blocks = self._blocks(params0)
        rng, r_delay, r_sel = jax.random.split(state.rng, 3)

        # --- bounded-staleness pull: per-(worker, block) delays ---
        if cfg.max_delay > 0:
            delays = jax.random.randint(r_delay, (N, M), 0, cfg.max_delay + 1)
        else:
            delays = jnp.zeros((N, M), jnp.int32)
        bid_tree = blocks.block_id_tree()
        z_tilde = jax.tree.map(
            lambda zh, bid: zh[delays[:, bid]], state.z_hist, bid_tree)

        # --- per-worker gradients at z~ (eq. 5 linearization) ---
        def per_worker_loss(p, b):
            return self.loss_fn(p, b)
        losses, grads = jax.vmap(jax.value_and_grad(per_worker_loss))(
            z_tilde, batch)                                   # leaves (N, ...)

        # --- block selection (Alg. 1 line 4) ---
        if cfg.block_fraction >= 1.0:
            sel = jnp.ones((N, M), bool)
        else:
            k = max(1, int(round(cfg.block_fraction * M)))
            gumbel = jax.random.gumbel(r_sel, (N, M))
            thresh = jax.lax.top_k(gumbel, k)[0][:, -1:]
            sel = gumbel >= thresh

        def mask_leaf(leaf_val, bid):
            m = sel[:, bid].astype(leaf_val.dtype)
            return m.reshape((N,) + (1,) * (leaf_val.ndim - 1))

        # --- worker update (11)(12)(9), masked to selected blocks ---
        def upd(g, y, zt, w_old, bid):
            g32 = g.astype(jnp.float32)
            y32 = y.astype(jnp.float32)
            zt32 = zt.astype(jnp.float32)
            _, y_new, w_new = worker_update(g32, y32, zt32, cfg.rho)
            m = mask_leaf(g, bid).astype(jnp.float32)
            y_out = (m * y_new + (1 - m) * y32).astype(y.dtype)
            w_out = (m * w_new + (1 - m) * w_old.astype(jnp.float32)).astype(w_old.dtype)
            return y_out, w_out

        yw = jax.tree.map(upd, grads, state.y, z_tilde, state.w_cache,
                          bid_tree)
        # unzip the (y, w) tuples
        y_new = jax.tree.map(lambda t: t[0], yw,
                             is_leaf=lambda t: isinstance(t, tuple))
        w_new = jax.tree.map(lambda t: t[1], yw,
                             is_leaf=lambda t: isinstance(t, tuple))

        # --- server update (13): one collective reduction per block ---
        prox = make_prox(cfg.l1_coef, cfg.clip).prox
        mu = cfg.gamma + cfg.rho * N

        def server(zh, w):
            z_cur = zh[0].astype(jnp.float32)
            w_sum = jnp.sum(w.astype(jnp.float32), axis=0)    # over workers
            z_new = prox((cfg.gamma * z_cur + w_sum) / mu, mu).astype(zh.dtype)
            if zh.shape[0] == 1:
                return z_new[None]
            return jnp.concatenate([z_new[None], zh[:-1]], axis=0)

        z_hist = jax.tree.map(server, state.z_hist, w_new)

        # --- diagnostics ---
        info = {
            "loss": jnp.mean(losses),
            "selected_fraction": jnp.mean(sel.astype(jnp.float32)),
        }
        return (ADMMTrainState(z_hist=z_hist, y=y_new, w_cache=w_new,
                               step=state.step + 1, rng=rng), info)

    # -----------------------------------------------------------------
    def train_step_block(self, state: ADMMTrainState, batch, block_id: int
                         ) -> Tuple[ADMMTrainState, Dict]:
        """Cyclic (Gauss-Seidel) block round: ALL workers update block
        ``block_id`` this step (the paper's §3.2 alternative block
        selection, the TPU-natural one — see EXPERIMENTS.md §Perf).

        ``block_id`` must be static (jit with static_argnums=2); drive it
        with ``step % num_blocks``. Because the block set is known at
        trace time:
          * gradients are taken w.r.t. the active leaves only — the
            parameter-gradient matmuls of frozen leaves are never built;
          * the cross-worker reduction (the paper's w push) covers only
            the active block — collective volume drops by ~1/M;
          * the server-side stale-w cache is never read (every worker
            pushes the active block fresh), so it is not carried at all.
        """
        cfg = self.admm
        N = self.num_workers
        params0 = jax.tree.map(lambda a: a[0], state.z_hist)
        blocks = self._blocks(params0)
        rng, r_delay = jax.random.split(state.rng)

        leaves_ids = blocks.leaf_block_ids
        active_idx = [i for i, b in enumerate(leaves_ids) if b == block_id]
        treedef = blocks.treedef

        # --- bounded-staleness pull (全 leaves — forward needs them all)
        M = cfg.num_blocks
        if cfg.max_delay > 0:
            delays = jax.random.randint(r_delay, (N, M), 0, cfg.max_delay + 1)
        else:
            delays = jnp.zeros((N, M), jnp.int32)
        bid_tree = blocks.block_id_tree()
        z_tilde = jax.tree.map(
            lambda zh, bid: zh[delays[:, bid]], state.z_hist, bid_tree)

        zt_leaves = jax.tree.leaves(z_tilde)
        active_zt = [zt_leaves[i] for i in active_idx]

        def loss_from_active(active_leaves, all_leaves, b):
            merged = list(all_leaves)
            for i, al in zip(active_idx, active_leaves):
                merged[i] = al
            return self.loss_fn(jax.tree.unflatten(treedef, merged), b)

        losses, g_active = jax.vmap(
            jax.value_and_grad(loss_from_active))(active_zt, zt_leaves, batch)

        # --- worker + server update on the active leaves only ---
        y_leaves = list(jax.tree.leaves(state.y))
        w_sum_active = []
        y_new_leaves = list(y_leaves)
        for j, (i, g) in enumerate(zip(active_idx, g_active)):
            g32 = g.astype(jnp.float32)
            zt32 = zt_leaves[i].astype(jnp.float32)
            y32 = y_leaves[i].astype(jnp.float32)
            _, y_new, w_new = worker_update(g32, y32, zt32, cfg.rho)
            y_new_leaves[i] = y_new.astype(y_leaves[i].dtype)
            w_sum_active.append(jnp.sum(w_new, axis=0))   # reduce over N

        prox = make_prox(cfg.l1_coef, cfg.clip).prox
        mu = cfg.gamma + cfg.rho * N
        zh_leaves = list(jax.tree.leaves(state.z_hist))
        for i, w_sum in zip(active_idx, w_sum_active):
            zh = zh_leaves[i]
            z_cur = zh[0].astype(jnp.float32)
            z_new = prox((cfg.gamma * z_cur + w_sum) / mu, mu).astype(zh.dtype)
            if zh.shape[0] == 1:
                zh_leaves[i] = z_new[None]
            else:
                zh_leaves[i] = jnp.concatenate([z_new[None], zh[:-1]], axis=0)

        y_def = jax.tree.structure(state.y)
        zh_def = jax.tree.structure(state.z_hist)
        info = {"loss": jnp.mean(losses),
                "selected_fraction": jnp.asarray(len(active_idx)
                                                 / max(len(leaves_ids), 1))}
        return (ADMMTrainState(
            z_hist=jax.tree.unflatten(zh_def, zh_leaves),
            y=jax.tree.unflatten(y_def, y_new_leaves),
            w_cache=state.w_cache,        # untouched (never read in cyclic)
            step=state.step + 1, rng=rng), info)

    # -----------------------------------------------------------------
    def consensus_residual(self, state: ADMMTrainState) -> jax.Array:
        """||x_i - z||/||z|| proxy: since x = z~-(g+y')/rho and y' = -g at
        update time, the dual drift ||y_i + g_i|| collapses; we report the
        w-cache dispersion across workers instead (0 at consensus)."""
        def disp(w):
            w32 = w.astype(jnp.float32)
            mean = jnp.mean(w32, axis=0, keepdims=True)
            return jnp.sum(jnp.square(w32 - mean)), jnp.sum(jnp.square(mean)) * w.shape[0]
        num, den = 0.0, 0.0
        for leaf in jax.tree.leaves(state.w_cache):
            n, d = disp(leaf)
            num, den = num + n, den + d
        return jnp.sqrt(num / jnp.maximum(den, 1e-12))
