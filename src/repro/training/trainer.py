"""Trainers.

``ADMMTrainer`` — AsyBADMM as a distributed-training feature (pytree
mode). Since the `VariableSpace` refactor the trainer is a thin adapter:
delay gather, block selection, worker update, and server prox all route
through ``core.space.TreeSpace`` + the shared generic
``core.space.asybadmm_epoch`` — the same implementation the flat driver
uses — so the pytree path honors every ``ADMMConfig`` policy
(``block_selection`` random/cyclic/gauss_southwell), heterogeneous
per-worker ``rho_scale``, and an optional general-form ``edge`` set.

The mapping from the paper's parameter-server picture to the SPMD pod
is in DESIGN.md §3:

  worker i      = data-parallel slice i (leading worker axis N, sharded
                  over the ``data``/``pod`` mesh axes)
  server j      = logical parameter block j (leaves assigned by
                  core.blocks.make_tree_blocks; on the pod each block
                  lives on its ``model``-axis shard)
  push w_ij     = the sum over the worker axis inside jit — under pjit
                  this lowers to exactly one reduce-scatter/all-reduce
                  per selected block, the collective analogue of the
                  paper's lock-free per-block push
  bounded delay = ring buffer z_hist + per-(worker, block) sampled
                  delays (Assumption 3)

``SGDTrainer`` — the conventional synchronous data-parallel baseline
(mean gradient + Adam/SGD), for the convergence/efficiency comparisons.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ADMMConfig
from ..core.admm import server_update, worker_update
from ..core.async_sim import push_history, subsample_worker_data
from ..core.blocks import TreeBlocks, make_block_layout, make_tree_blocks
from ..core.space import (ConsensusSpec, ConsensusState, TreeSpace,
                          asybadmm_epoch, consensus_residual,
                          init_consensus_state, make_spec,
                          sample_delay_model)
from ..optim.optimizers import Optimizer, apply_updates
from .train_state import ADMMTrainState, SGDTrainState


# ===========================================================================
# baseline: synchronous data-parallel SGD/Adam
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class SGDTrainer:
    loss_fn: Callable            # loss_fn(params, batch) -> scalar
    optimizer: Optimizer

    def init(self, params) -> SGDTrainState:
        return SGDTrainState(params=params,
                             opt_state=self.optimizer.init(params),
                             step=jnp.zeros((), jnp.int32))

    def train_step(self, state: SGDTrainState, batch) -> Tuple[SGDTrainState, Dict]:
        loss, grads = jax.value_and_grad(self.loss_fn)(state.params, batch)
        updates, opt_state = self.optimizer.update(grads, state.opt_state,
                                                   state.params)
        params = apply_updates(state.params, updates)
        return (SGDTrainState(params, opt_state, state.step + 1),
                {"loss": loss})


# ===========================================================================
# AsyBADMM consensus trainer — thin adapter over core.space
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class ADMMTrainer:
    """Block-wise asynchronous consensus training over a params pytree.

    loss_fn(params, worker_batch) -> scalar — per-worker loss; batches
    carry a leading worker axis N.

    edge      : optional (N, M) bool — the paper's general-form edge set
                E; worker i only touches blocks j with edge[i, j].
    rho_scale : optional (N,) — heterogeneous per-worker penalties,
                effective rho_i = admm.rho * rho_scale[i].
    mesh      : optional jax Mesh (or ``launch.mesh.resolve_mesh``
                preset) overriding ``admm.mesh`` — ``train_step`` then
                runs the SPMD-sharded epoch with the worker axis of
                every state/batch leaf sharded over the data axes
                (``train_step_block``'s static Gauss-Seidel round stays
                GSPMD-partitioned via launch/shardings.py instead).
    """
    loss_fn: Callable
    admm: ADMMConfig
    num_workers: int
    blocks: Optional[TreeBlocks] = None
    edge: Optional[Any] = None
    rho_scale: Optional[Any] = None
    mesh: Optional[Any] = None

    def _blocks(self, params) -> TreeBlocks:
        if self.blocks is not None:
            return self.blocks
        return make_tree_blocks(params, self.admm.num_blocks)

    def _space(self, params) -> TreeSpace:
        blocks = self._blocks(params)
        return TreeSpace(blocks=blocks, num_workers=self.num_workers,
                         layout=make_block_layout(params, blocks))

    def _spec(self, params) -> ConsensusSpec:
        return make_spec(self._space(params), self.admm, self.loss_fn,
                         edge=self.edge, rho_scale=self.rho_scale,
                         track_x=False, mesh=self.mesh)

    def init(self, params, *, cyclic: bool = False) -> ADMMTrainState:
        spec = self._spec(params)
        g = init_consensus_state(spec, params)
        # the trainer's user-facing state stays in PARAMS representation
        # (leaf dtypes, launch/shardings.py TP overlays, checkpoints);
        # train_step lowers it onto the packed block table per epoch
        unpack = spec.space.layout.from_blocks
        z_hist, y, w_cache = (unpack(g.z_hist), unpack(g.y),
                              unpack(g.w_cache))
        if cyclic:
            # Static Gauss-Seidel rounds (train_step_block) never read the
            # stale-w cache (every worker pushes the active block fresh) —
            # don't carry it.
            w_cache = ()
        return ADMMTrainState(z_hist=z_hist, y=y, w_cache=w_cache,
                              step=g.t, rng=g.rng)

    # -----------------------------------------------------------------
    def train_step(self, state: ADMMTrainState, batch
                   ) -> Tuple[ADMMTrainState, Dict]:
        """One AsyBADMM epoch across all N workers (Alg. 1, both roles),
        delegated to the shared generic step.

        batch: pytree with leading axes (N, per_worker_batch, ...).
        """
        if isinstance(state.w_cache, tuple) and state.w_cache == ():
            raise ValueError(
                "state was built with init(cyclic=True), which drops the "
                "w cache and only supports train_step_block; for the "
                "dynamic block_selection='cyclic' policy use a plain "
                "init(params)")
        params0 = jax.tree.map(lambda a: a[0], state.z_hist)
        spec = self._spec(params0)
        # lower the params-shaped state onto the packed block table (a
        # reshape/concat boundary — the epoch's hot path, kernels and
        # SPMD sharding all run on the packed (N, M, dblk) layout), then
        # lift the result back to params representation
        pack = spec.space.layout.to_blocks
        unpack = spec.space.layout.from_blocks
        g = ConsensusState(z_hist=pack(state.z_hist), y=pack(state.y),
                           w_cache=pack(state.w_cache), x=(), t=state.step,
                           rng=state.rng)
        g, info = asybadmm_epoch(spec, g, batch)
        return (ADMMTrainState(z_hist=unpack(g.z_hist), y=unpack(g.y),
                               w_cache=unpack(g.w_cache), step=g.t,
                               rng=g.rng), info)

    # -----------------------------------------------------------------
    def train_step_block(self, state: ADMMTrainState, batch, block_id: int
                         ) -> Tuple[ADMMTrainState, Dict]:
        """Cyclic (Gauss-Seidel) block round: ALL workers update block
        ``block_id`` this step (the paper's §3.2 alternative block
        selection, the TPU-natural one — see EXPERIMENTS.md §Perf).

        This is the statically-specialized sibling of
        ``block_selection="cyclic"``: because ``block_id`` is known at
        trace time (jit with static_argnums=2; drive it with
        ``step % num_blocks``):
          * gradients are taken w.r.t. the active leaves only — the
            parameter-gradient matmuls of frozen leaves are never built;
          * the cross-worker reduction (the paper's w push) covers only
            the active block — collective volume drops by ~1/M;
          * the server-side stale-w cache is never read (every worker
            pushes the active block fresh), so it is not carried at all.
        The delay gather, update equations, and server prox are the
        shared core.space / core.admm primitives.
        """
        cfg = self.admm
        N, M = self.num_workers, cfg.num_blocks
        params0 = jax.tree.map(lambda a: a[0], state.z_hist)
        spec = self._spec(params0)
        space = spec.space
        blocks = space.blocks
        if spec.minibatch is not None:
            # incremental workers: same semantics as the generic epoch
            # (this specialized path has its own rng chain, so the draw
            # widens it rather than matching the epoch's keys)
            rng, r_delay, r_batch = jax.random.split(state.rng, 3)
            batch = subsample_worker_data(r_batch, batch, spec.minibatch)
        else:
            rng, r_delay = jax.random.split(state.rng)

        leaves_ids = blocks.leaf_block_ids
        active_idx = [i for i, b in enumerate(leaves_ids) if b == block_id]
        treedef = blocks.treedef

        # --- bounded-staleness pull (all leaves — forward needs them);
        #     per-leaf gather: this path keeps the params-shaped state,
        #     it never lowers onto the packed block table ---
        delays = sample_delay_model(spec.delay_model, r_delay, N, M,
                                    state.step)
        z_tilde = jax.tree.map(lambda zh, bid: zh[delays[:, bid]],
                               state.z_hist, blocks.block_id_tree())

        zt_leaves = jax.tree.leaves(z_tilde)
        active_zt = [zt_leaves[i] for i in active_idx]

        def loss_from_active(active_leaves, all_leaves, b):
            merged = list(all_leaves)
            for i, al in zip(active_idx, active_leaves):
                merged[i] = al
            return self.loss_fn(jax.tree.unflatten(treedef, merged), b)

        losses, g_active = jax.vmap(
            jax.value_and_grad(loss_from_active))(active_zt, zt_leaves, batch)

        # --- worker + server update on the active leaves only ---
        rho32 = spec.rho_vec.astype(jnp.float32)
        e_blk = spec.edge[:, block_id]                       # (N,) bool
        y_leaves = list(jax.tree.leaves(state.y))
        w_sum_active = []
        y_new_leaves = list(y_leaves)
        for i, g in zip(active_idx, g_active):
            g32 = g.astype(jnp.float32)
            zt32 = zt_leaves[i].astype(jnp.float32)
            y32 = y_leaves[i].astype(jnp.float32)
            wshape = (N,) + (1,) * (g32.ndim - 1)
            _, y_new, w_new = worker_update(g32, y32, zt32,
                                            rho32.reshape(wshape))
            em = e_blk.reshape(wshape)
            y_new_leaves[i] = jnp.where(em, y_new, y32).astype(
                y_leaves[i].dtype)
            w_sum_active.append(
                jnp.sum(jnp.where(em, w_new, 0.0), axis=0))  # reduce over N

        rho_sum = jnp.sum(jnp.where(e_blk, rho32, 0.0))
        prox = spec.reg.prox
        zh_leaves = list(jax.tree.leaves(state.z_hist))
        for i, w_sum in zip(active_idx, w_sum_active):
            zh = zh_leaves[i]
            z_new = server_update(zh[0].astype(jnp.float32), w_sum, rho_sum,
                                  spec.gamma, prox).astype(zh.dtype)
            zh_leaves[i] = push_history(zh, z_new)

        y_def = jax.tree.structure(state.y)
        zh_def = jax.tree.structure(state.z_hist)
        info = {"loss": jnp.mean(losses),
                "selected_fraction": jnp.asarray(len(active_idx)
                                                 / max(len(leaves_ids), 1))}
        return (ADMMTrainState(
            z_hist=jax.tree.unflatten(zh_def, zh_leaves),
            y=jax.tree.unflatten(y_def, y_new_leaves),
            w_cache=state.w_cache,        # untouched (never read in cyclic)
            step=state.step + 1, rng=rng), info)

    # -----------------------------------------------------------------
    def consensus_residual(self, state: ADMMTrainState) -> jax.Array:
        """||x_i - z||/||z|| proxy: since x = z~-(g+y')/rho and y' = -g at
        update time, the dual drift ||y_i + g_i|| collapses; we report the
        w-cache dispersion across workers instead (0 at consensus)."""
        if isinstance(state.w_cache, tuple) and state.w_cache == ():
            raise ValueError(
                "state was built with init(cyclic=True), which drops the "
                "w cache the consensus residual is computed from; use a "
                "plain init(params) to track it")
        params0 = jax.tree.map(lambda a: a[0], state.z_hist)
        spec = self._spec(params0)
        pack = spec.space.layout.to_blocks
        g = ConsensusState(z_hist=pack(state.z_hist), y=pack(state.y),
                           w_cache=pack(state.w_cache), x=(), t=state.step,
                           rng=state.rng)
        return consensus_residual(spec, g)
