#!/usr/bin/env bash
# One-shot verify entry point: install the test extra (best effort — the
# suite degrades hypothesis-based modules to skips when it is absent,
# e.g. in offline containers) and run the tier-1 test command.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! python -c "import hypothesis" >/dev/null 2>&1; then
    echo "[ci] hypothesis missing — trying to install the test extra"
    pip install -e ".[test]" \
        || echo "[ci] install failed (offline?); continuing — hypothesis modules will skip"
fi

# kernel benchmark smoke: numeric pallas<->jnp parity + NaN check and
# fused-epoch HBM-byte regression gate vs benchmarks/kernels_baseline.json
echo "[ci] kernels bench (smoke)"
env PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/kernels_bench.py --smoke

exec env PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q "$@"
