#!/usr/bin/env bash
# One-shot verify entry point: install the test extra (best effort — the
# suite degrades hypothesis-based modules to skips when it is absent,
# e.g. in offline containers) and run the tier-1 test command.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! python -c "import hypothesis" >/dev/null 2>&1; then
    echo "[ci] hypothesis missing — trying to install the test extra"
    pip install -e ".[test]" \
        || echo "[ci] install failed (offline?); continuing — hypothesis modules will skip"
fi

# kernel benchmark smoke: numeric pallas<->jnp parity + NaN check,
# fused-epoch HBM-byte regression gate, and the per-shard byte-shrink
# gates of the SPMD epoch — flat (max_shard_bytes_frac) AND tree
# (max_tree_shard_bytes_frac: the packed BlockLayout lowering must keep
# TreeSpace block servers sharding over model) — all vs
# benchmarks/kernels_baseline.json (the bench forces 8 host devices
# itself for the sharded wall-clock)
echo "[ci] kernels bench (smoke)"
env PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/kernels_bench.py --smoke

# kernel autotuner smoke: the deterministic tile sweep for the two
# fused epoch kernels must run end to end on this device kind and
# produce a winner for every (op, case) cell — regressions here would
# silently fall back to the default tile heuristic at session build
echo "[ci] kernel autotuner (smoke sweep)"
env PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.kernels.autotune --smoke

# PS-runtime coordination gate: a deterministic locked-vs-lockfree
# comparison at 8 workers (benchmarks/speedup.py --smoke, service times
# measured from the real jitted hot path) must show the paper's block-
# wise lock-free servers beating the full-vector lock by at least
# min_lockfree_speedup_x8 from benchmarks/kernels_baseline.json
echo "[ci] PS-runtime speedup gate (smoke)"
env PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/speedup.py --smoke

# Elastic-PS chaos smoke: crash+rejoin at 8 real-compute workers under
# per-push commits. The deterministic chaos trace must replay its z
# trajectory through the vectorized epoch — single-device AND the SPMD
# (data=4, model=2) mesh (hence the forced 8 host devices) — and the
# run must reach the fault-free tolerance within max_churn_rounds_ratio
# x the fault-free round count (benchmarks/kernels_baseline.json)
echo "[ci] elastic-PS churn gate (smoke, 8 host devices)"
env PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python benchmarks/speedup.py --scenario churn --smoke

# Lossy-transport gate: 8 real-compute workers over an unreliable
# network (5% drop / 2% dup / 10% reorder with ack/retry/backoff
# reliability). The lossy trace must replay through the vectorized
# epoch (single-device AND the SPMD mesh — hence the forced 8 host
# devices) and reach the reliable run's tolerance within
# max_lossy_rounds_ratio x its round count (kernels_baseline.json)
echo "[ci] lossy-transport gate (smoke, 8 host devices)"
env PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python benchmarks/speedup.py --scenario lossy --smoke

# Durability gate: block-server crash + WAL-replay recovery at 8
# real-compute workers. Hard-fails on any lost/duplicated committed
# fold (per-domain fold multisets vs the crash-free run), a wrong
# recovery count, rounds-to-tolerance above
# max_server_crash_rounds_ratio (kernels_baseline.json), or a crash
# trace that does not replay through the vectorized epoch within 1e-5
# (single-device AND SPMD — hence the forced 8 host devices)
echo "[ci] server-crash durability gate (smoke, 8 host devices)"
env PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python benchmarks/speedup.py --scenario server_crash --smoke

# Telemetry-inertness gate: FULL telemetry (span tracer + JSONL stream
# + Chrome trace export + per-round stationarity) on a server-crash +
# worker-churn chaos run must change NOTHING the runtime computes —
# bitwise-identical z, identical fold logs, metrics dict (keys, order,
# values) and makespan vs the telemetry-off run — and every streamed
# record / exported trace event must validate against the repro.obs
# schemas. 8 forced host devices so the gate covers the multi-device
# build of the jitted ops.
echo "[ci] telemetry inertness + schema gate (8 host devices)"
env PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python scripts/check_telemetry_inert.py

# Checkpoint/resume determinism: a run killed at a snapshot barrier and
# resumed must finish with bitwise-identical z (pallas cells), trace,
# losses and makespan vs the uninterrupted run — including composed
# with worker-crash chaos. Runs in its own process with 8 host devices
# so the SPMD resume cell exercises the sharded epoch replay too.
echo "[ci] checkpoint/resume determinism (8 host devices)"
env PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m pytest -x -q tests/test_ps_recovery.py

# Selection-skew and straggler-tail scenario gates (timing-only,
# deterministic seeded draws): zipf selection must pile occupancy onto
# the head lock domains (min_skew_occupancy_ratio) and the Pareto
# compute tail must trigger bounded-staleness stalls without ever
# serving past the bound (min_heavy_tail_stall)
echo "[ci] skew + heavy-tail scenario gates (smoke)"
env PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/speedup.py --scenario skew --smoke
env PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python benchmarks/speedup.py --scenario heavy_tail --smoke

# SPMD parity smoke: the sharded epoch needs an 8-host-device mesh, so
# the parity suite runs in its own process with the device count forced
# (inside the main tier-1 run below it skips) — single-device-only
# regressions of the mesh path cannot land. This includes the TREE
# cells (test_tree_spmd_parity): pytree z_hist/prox natively sharded
# over model via the packed BlockLayout, no replicated-z fallback.
echo "[ci] SPMD parity, flat + tree cells (8 host devices, data=4 x model=2)"
env PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m pytest -x -q tests/test_spmd_parity.py
echo "[ci] PS-trace -> SPMD-epoch replay parity, flat + tree (8 host devices)"
env PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m pytest -x -q tests/test_ps_runtime.py -k spmd

# Lossy-transport replay-parity cells: the drop/dup/reorder trace must
# replay bitwise on pallas / fp32-ulp on jnp for BOTH spaces, plus the
# SPMD cell (needs the forced 8 host devices; it skips inside the main
# tier-1 run below)
echo "[ci] lossy-transport replay parity, flat + tree x jnp + pallas + SPMD (8 host devices)"
env PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m pytest -x -q tests/test_ps_transport.py -k "replay or spmd"

exec env PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q "$@"
