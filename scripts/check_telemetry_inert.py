"""CI gate: telemetry must be inert and its exports must validate.

Runs the same server-crash + worker-churn chaos scenario twice — once
bare, once with FULL telemetry (span tracer, JSONL sink, Chrome trace
export, per-round stationarity) — and hard-fails unless:

* the final z is BITWISE identical across the two runs;
* makespan, the metrics dict (keys, order, values) and every lock
  domain's committed fold log are identical;
* every streamed JSONL record validates against
  ``repro.obs.stream.ROUND_RECORD_SCHEMA``;
* the exported Chrome trace is well-formed trace-event JSON whose
  span names all come from ``repro.obs.names.SPAN_NAMES``.

ci.sh runs this under 8 forced host devices so the gate also covers
the multi-device build of the jitted space ops.
"""
import json
import sys
import tempfile
from pathlib import Path

import jax.numpy as jnp
import numpy as np

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.api import ConsensusSession                      # noqa: E402
from repro.configs.base import ADMMConfig                   # noqa: E402
from repro.obs import (SPAN_NAMES, Telemetry,               # noqa: E402
                       validate_record)
from repro.ps import (CostProfile, FaultPlan,               # noqa: E402
                      LognormalService, ParetoService, PSRuntime)

N, M, DBLK = 8, 4, 5
DIM = M * DBLK
ROUNDS = 10

CHAOS = FaultPlan.of(FaultPlan.server_crash(1, at=2.0, down=3.0),
                     FaultPlan.crash(0, at=1.0, down=1.0))
STRAGGLER = CostProfile(t_worker=ParetoService(1.0, alpha=1.2),
                        t_server_block=LognormalService(0.3, 0.4))


def _loss(z, c):
    return 0.5 * jnp.sum(jnp.square(z - c))


def _runtime(telemetry=None):
    rng = np.random.RandomState(7)
    centers = jnp.asarray(rng.randn(N, DIM).astype(np.float32))
    cfg = ADMMConfig(rho=2.0, gamma=0.1, max_delay=2, block_fraction=0.5,
                     num_blocks=M, block_selection="random", l1_coef=1e-3,
                     clip=0.8, seed=0)
    # pallas backend: interpret-mode kernels are fusion-stable, so the
    # bitwise-z assertion pins the kernel path, not an XLA accident
    sess = ConsensusSession.flat(_loss, centers, dim=DIM, cfg=cfg,
                                 backend="pallas")
    return PSRuntime(sess.spec, data=sess.data, timing=STRAGGLER,
                     faults=CHAOS, telemetry=telemetry)


def _fold_logs(rt):
    return {dom.sid: list(dom.fold_log) for dom in rt.domains}


def main() -> int:
    rt_off = _runtime()
    off = rt_off.run(ROUNDS)

    out = Path(tempfile.mkdtemp(prefix="telemetry_gate_"))
    jsonl = out / "rounds.jsonl"
    trace = out / "run.trace.json"
    tel = Telemetry(spans=True, sink=str(jsonl), trace_path=str(trace))
    rt_on = _runtime(telemetry=tel)
    on = rt_on.run(ROUNDS)

    # --- inertness -----------------------------------------------------
    assert on.makespan == off.makespan, \
        f"makespan drift: {on.makespan} != {off.makespan}"
    np.testing.assert_array_equal(
        np.asarray(on.z_final), np.asarray(off.z_final),
        err_msg="telemetry changed the committed z (not bitwise)")
    assert list(on.metrics) == list(off.metrics), "metrics key order drift"
    assert on.metrics == off.metrics, "metrics value drift"
    assert _fold_logs(rt_on) == _fold_logs(rt_off), "fold log drift"
    np.testing.assert_array_equal(on.trace.delays, off.trace.delays,
                                  err_msg="staleness trace drift")

    # --- streamed JSONL schema ----------------------------------------
    records = [json.loads(line)
               for line in jsonl.read_text().splitlines()]
    assert len(records) == ROUNDS, \
        f"expected {ROUNDS} round records, got {len(records)}"
    for rec in records:
        validate_record(rec)
    assert [r["round"] for r in records] == list(range(ROUNDS))
    assert [r["loss"] for r in records] == on.losses, \
        "streamed losses are not the full-precision run losses"

    # --- Chrome trace schema ------------------------------------------
    doc = json.loads(trace.read_text())
    events = doc["traceEvents"]
    assert events, "empty Chrome trace"
    named_tids = {e["tid"] for e in events if e["name"] == "thread_name"}
    for e in events:
        assert e["ph"] in ("X", "i", "C", "M"), f"bad phase {e['ph']!r}"
        assert e["tid"] in named_tids, f"unnamed track tid {e['tid']}"
        if e["ph"] == "M":
            continue
        assert e["name"] in SPAN_NAMES, f"undeclared span {e['name']!r}"
        if e["ph"] == "X":
            assert e["dur"] >= 0.0, f"negative span {e['name']!r}"
    names = {e["name"] for e in events}
    for required in ("pull", "compute", "commit", "server_crash",
                     "wal_replay", "down"):
        assert required in names, f"span family {required!r} missing"

    print(f"[telemetry gate] ok: bitwise z + identical metrics/fold "
          f"logs/makespan ({on.makespan:.4f}) with telemetry on; "
          f"{len(records)} records and {len(events)} trace events "
          f"validated ({out})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
