"""Paper Table 1 analogue: speedup of p workers performing k iterations.

We cannot rent 36 EC2 cores, so we reproduce the quantity Table 1
actually measures — the scalability of the *coordination scheme* — with
a discrete-event simulation driven by measured per-iteration costs:

* worker compute time  : measured from the real jitted AsyBADMM worker
  gradient update on this host, with lognormal jitter (the EC2
  stragglers the paper's bounded-delay assumption exists for);
* server service time  : measured from the real jitted prox z-update.

Two coordination disciplines:
  locked    — full-vector consensus: one global lock serializes every
              worker's z-update (all prior async ADMM, per paper §1);
  lockfree  — AsyBADMM: M block servers; a push occupies only its own
              block's server; different blocks commit in parallel.

T_k(p) = makespan until k total iterations commit, work-shared by p
workers; Speedup_p = T_k(1)/T_k(p) (the paper's metric).

CSV columns: name, us_per_call (simulated makespan), derived (speedup).
"""
import heapq
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import make_sparse_logreg

K_ITERS = 320
WORKERS = [1, 4, 8, 16, 32]
M_BLOCKS = 16


def measure_costs(dim=2048, samples=64):
    """Real measured costs of one worker iteration and one z-block update."""
    data = make_sparse_logreg(num_workers=1, samples_per_worker=samples,
                              dim=dim, density=0.1, seed=0)

    def loss_fn(z, d):
        X, y = d
        return jnp.mean(jnp.log1p(jnp.exp(-y * (X @ z))))

    X = jnp.asarray(data.X[0])
    yv = jnp.asarray(data.y[0])
    z = jnp.zeros(dim)
    gfn = jax.jit(jax.grad(lambda w: loss_fn(w, (X, yv))))
    gfn(z).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        gfn(z).block_until_ready()
    t_comp = (time.perf_counter() - t0) / 20

    from repro.core.admm import server_update
    from repro.core.prox import make_prox
    reg = make_prox(l1_coef=1e-3, clip=1e4)
    blk = jnp.zeros(dim // M_BLOCKS)
    sfn = jax.jit(lambda zt, ws: server_update(zt, ws, 8.0, 0.1, reg.prox))
    sfn(blk, blk).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(50):
        sfn(blk, blk).block_until_ready()
    t_serve_block = (time.perf_counter() - t0) / 50
    return t_comp, t_serve_block


def simulate(p, k_total, t_comp, t_serve_block, discipline,
             m_blocks=M_BLOCKS, seed=0, jitter=0.3):
    """Event-driven makespan until k_total iterations commit."""
    rng = np.random.RandomState(seed + p)
    t_serve = t_serve_block * (m_blocks if discipline == "locked" else 1.0)
    n_servers = 1 if discipline == "locked" else m_blocks
    server_free = np.zeros(n_servers)
    committed = 0
    q = [(t_comp * rng.lognormal(0, jitter), i) for i in range(p)]
    heapq.heapify(q)
    t_end = 0.0
    while committed < k_total and q:
        t, i = heapq.heappop(q)
        j = rng.randint(n_servers)          # block j_t ~ U (Alg. 1 line 4)
        start = max(t, server_free[j])
        finish = start + t_serve * rng.lognormal(0, jitter / 2)
        server_free[j] = finish
        t_end = max(t_end, finish)
        committed += 1
        if committed + len(q) < k_total:
            heapq.heappush(q, (finish + t_comp * rng.lognormal(0, jitter), i))
    return t_end


def main(emit=print):
    t_comp, t_serve_block = measure_costs()
    emit(f"speedup_measured_costs,{t_comp*1e6:.1f},"
         f"t_serve_block_us={t_serve_block*1e6:.1f}")
    for discipline in ("lockfree", "locked"):
        base = simulate(1, K_ITERS, t_comp, t_serve_block, discipline)
        for p in WORKERS:
            tk = simulate(p, K_ITERS, t_comp, t_serve_block, discipline)
            emit(f"table1_{discipline}_p{p},{tk*1e6:.0f},"
                 f"speedup={base / tk:.2f}")


if __name__ == "__main__":
    main()
