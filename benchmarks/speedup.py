"""Paper Table 1 analogue: speedup of p workers performing k iterations.

We cannot rent 36 EC2 cores, so we reproduce the quantity Table 1
actually measures — the scalability of the *coordination scheme* —
with the event-driven Parameter Server runtime (``repro.ps``). This
module is now a thin client of that subsystem: the lock domains, push
queues, bounded-staleness stalls and makespan accounting all live in
``repro.ps``; here we only

* measure the real per-event costs — one worker iteration and one
  block-server commit of the REAL jitted ``VariableSpace`` hot path
  (``repro.ps.timing.measure_costs``; the hand-rolled loss_fn /
  server_update measurement this file used to carry is gone);
* feed them to the scheduler as service times (lognormal jitter, the
  EC2 stragglers Assumption 3 exists for) and sweep workers x
  {lockfree, locked} through ONE code path (``PSRuntime`` in
  timing-only mode);
* report ``T_k(p)`` = makespan until k total iterations commit,
  work-shared by p workers, and ``Speedup_p = T_k(1) / T_k(p)``.

``--smoke`` (CI, via scripts/ci.sh) additionally runs a DETERMINISTIC
locked-vs-lockfree comparison at 8 workers — constant service times in
a coordination-bound regime (worker compute pinned to 4 block-serve
units, M=16, so the full-vector lock's M-serial commit dominates) —
and gates the lockfree/locked makespan ratio against
``min_lockfree_speedup_x8`` in benchmarks/kernels_baseline.json.

``--scenario`` runs the elastic-PS chaos studies instead of Table 1:

* ``churn``      — 8-worker REAL-compute run under per-push commits
  with a deterministic crash+rejoin plan (``FaultPlan.churn``):
  replays the chaos trace through the vectorized epoch (single device,
  and the SPMD (data=4, model=2) mesh when 8 devices are up) and gates
  rounds-to-tolerance chaos/fault-free vs ``max_churn_rounds_ratio``;
* ``lossy``      — 8-worker REAL-compute run over an unreliable
  transport (5% drop / 2% dup / 10% reorder, ack+retry reliability):
  gates rounds-to-tolerance lossy/reliable vs
  ``max_lossy_rounds_ratio`` and replay parity of the lossy trace;
* ``skew``       — timing-only zipf vs uniform block selection: hot
  head blocks pile onto few lock domains (queue-occupancy spread,
  gated vs ``min_skew_occupancy_ratio``);
* ``heavy_tail`` — Pareto worker compute (the EC2 straggler tail):
  stall-time concentration under lockfree vs per_push commits (gated
  vs ``min_heavy_tail_stall``).

All scenarios print the per-worker stall-time and per-domain queue
occupancy histograms from ``PSRunResult.metrics["histograms"]``.

CSV columns: name, us_per_call (simulated makespan), derived (speedup).
"""
import argparse
import json
import pathlib

import numpy as np

from repro.api import ConsensusSession
from repro.configs.base import ADMMConfig
from repro.data import make_sparse_logreg
from repro.ps import (ConstantService, CostProfile, FaultPlan,
                      LognormalService, ParetoService, PSRuntime,
                      measure_costs)

K_ITERS = 320
WORKERS = [1, 4, 8, 16, 32]
M_BLOCKS = 16
GATE_WORKERS = 8
GATE_ROUNDS = 12
BASELINE = pathlib.Path(__file__).parent / "kernels_baseline.json"
CHURN_DIM = M_BLOCKS * 16


def build_session(num_workers: int, dim: int = 2048, samples: int = 64,
                  seed: int = 0, *, block_selection: str = "random",
                  zipf_a: float = 1.1, delay_model=None,
                  mesh=None) -> ConsensusSession:
    """The paper's sparse-logreg workload (eq. 22) on the unified API."""
    import jax.numpy as jnp

    data = make_sparse_logreg(num_workers=num_workers,
                              samples_per_worker=samples, dim=dim,
                              density=0.1, seed=seed)

    def loss_fn(z, d):
        X, y = d
        return jnp.mean(jnp.log1p(jnp.exp(-y * (X @ z))))

    cfg = ADMMConfig(rho=2.0, gamma=0.1, max_delay=2, block_fraction=0.5,
                     num_blocks=M_BLOCKS, l1_coef=1e-3, clip=1e4, seed=seed,
                     block_selection=block_selection, zipf_a=zipf_a)
    return ConsensusSession.flat(
        loss_fn, (jnp.asarray(data.X), jnp.asarray(data.y)), dim=dim,
        cfg=cfg, delay_model=delay_model, mesh=mesh)


def measured_costs(dim: int = 2048, samples: int = 64) -> dict:
    """Real measured costs of one worker iteration and one z-block
    commit, timed on the unified jitted hot path."""
    sess = build_session(1, dim=dim, samples=samples)
    return measure_costs(sess.spec, sess.data)


def makespan(p: int, k_total: int, timing: CostProfile,
             discipline: str) -> float:
    """Event-driven makespan until k_total iterations commit, the work
    shared by p workers (ceil-split like the paper's fixed-k runs)."""
    rounds = -(-k_total // p)
    sess = build_session(p, dim=M_BLOCKS * 16, samples=4)
    rt = PSRuntime(sess.spec, discipline=discipline, timing=timing,
                   compute="timing")
    return rt.run(rounds).makespan


def table1(emit, costs: dict, workers=WORKERS, k_iters=K_ITERS,
           jitter: float = 0.3) -> None:
    for discipline in ("lockfree", "locked"):
        timing = CostProfile(
            t_worker=LognormalService(costs["t_worker"], jitter),
            t_server_block=LognormalService(costs["t_server_block"],
                                            jitter / 2))
        base = makespan(1, k_iters, timing, discipline)
        for p in workers:
            tk = base if p == 1 else makespan(p, k_iters, timing, discipline)
            emit(f"table1_{discipline}_p{p},{tk*1e6:.0f},"
                 f"speedup={base / tk:.2f}")


def smoke_gate(emit, costs: dict) -> bool:
    """Deterministic coordination-bound comparison at 8 workers:
    constant service, worker compute = 4 block-serve units. The only
    difference between the two runs is the lock discipline, so the
    makespan ratio isolates exactly the paper's §1 claim (block-wise
    servers beat the full-vector lock). Gated vs the baseline."""
    ts = costs["t_server_block"]
    timing = CostProfile(t_worker=ConstantService(4.0 * ts),
                         t_server_block=ConstantService(ts))
    spans = {d: makespan(GATE_WORKERS, GATE_WORKERS * GATE_ROUNDS, timing, d)
             for d in ("lockfree", "locked")}
    ratio = spans["locked"] / spans["lockfree"]
    min_ratio = json.loads(BASELINE.read_text())["min_lockfree_speedup_x8"]
    ok = ratio >= min_ratio
    emit(f"speedup_gate_lockfree_x{GATE_WORKERS},"
         f"{spans['lockfree']*1e6:.0f},ratio={ratio:.2f}")
    emit(f"speedup_gate_locked_x{GATE_WORKERS},"
         f"{spans['locked']*1e6:.0f},min_ratio={min_ratio}")
    if not ok:
        emit(f"speedup_gate_FAILED,0,locked/lockfree ratio {ratio:.2f} < "
             f"{min_ratio}")
    return ok


# ---------------------------------------------------------------------------
# elastic-PS chaos scenarios (--scenario churn | skew | heavy_tail)
# ---------------------------------------------------------------------------

def _emit_hist(emit, name: str, hist: dict) -> None:
    """One histogram as a CSV row: total count, then edge:count bins."""
    bins = "|".join(f"{hist['edges'][i]:.3g}:{c}"
                    for i, c in enumerate(hist["counts"]))
    emit(f"{name},{sum(hist['counts'])},bins={bins}")


def _rounds_to_tolerance(losses, tol: float):
    for t, loss in enumerate(losses):
        if np.isfinite(loss) and loss <= tol:
            return t + 1
    return None


def _replay_max_err(res, sess) -> float:
    """Max |z_replay - z_runtime| over all rounds, replaying ``res``'s
    trace through ``sess``'s vectorized epoch."""
    state = sess.init()
    step = sess.step_fn()
    err = 0.0
    for t in range(res.num_rounds):
        state, _ = step(state, sess.data)
        err = max(err, float(np.max(np.abs(
            np.asarray(res.z_versions[t + 1]) - np.asarray(sess.z(state))))))
    return err


def churn_scenario(emit, smoke: bool = False) -> bool:
    """Crash+rejoin at 8 workers, per-push commits, REAL numerics:
    deterministic plan, replay-parity through the epoch (single device
    + SPMD when 8 devices are up), and a rounds-to-tolerance gate —
    chaos must converge within ``max_churn_rounds_ratio`` x the
    fault-free round count (benchmarks/kernels_baseline.json)."""
    import jax

    R = 16 if smoke else 24
    timing = CostProfile(t_worker=ConstantService(1.0),
                         t_server_block=ConstantService(0.25))
    plan = FaultPlan.churn(GATE_WORKERS, seed=0, crashes=2,
                           window=(2.0, 8.0), down=(2.0, 5.0))
    sess = build_session(GATE_WORKERS, dim=CHURN_DIM, samples=4)
    ff = sess.run_ps(R, discipline="per_push", timing=timing)
    ch = sess.run_ps(R, discipline="per_push", timing=timing, faults=plan)

    # rounds-to-tolerance: the loss level the fault-free run reaches at
    # 60% of its rounds; chaos must get there within max_ratio x as many
    tol = ff.losses[int(0.6 * R) - 1]
    r_ff = _rounds_to_tolerance(ff.losses, tol)
    r_ch = _rounds_to_tolerance(ch.losses, tol)
    ratio = float("inf") if r_ch is None else r_ch / r_ff
    max_ratio = json.loads(BASELINE.read_text())["max_churn_rounds_ratio"]

    emit(f"churn_faultfree_makespan,{ff.makespan*1e6:.0f},"
         f"rounds_to_tol={r_ff}")
    emit(f"churn_chaos_makespan,{ch.makespan*1e6:.0f},"
         f"rounds_to_tol={r_ch}")
    emit(f"churn_rounds_ratio,{ratio:.3f},max={max_ratio}"
         f"|crashes={ch.metrics['crashes']}|rejoins={ch.metrics['rejoins']}")
    _emit_hist(emit, "churn_worker_stall_hist",
               ch.metrics["histograms"]["worker_stall_time"])
    _emit_hist(emit, "churn_server_occupancy_hist",
               ch.metrics["histograms"]["server_occupancy"])

    # replay parity: the chaos trace (staleness + participation) must
    # reproduce the runtime's z trajectory through the fast epoch
    dm = ch.to_delay_model()
    err1 = _replay_max_err(ch, build_session(GATE_WORKERS, dim=CHURN_DIM,
                                             samples=4, delay_model=dm))
    emit(f"churn_replay_err_1dev,{err1:.2e},tol=1e-05")
    ok = err1 <= 1e-5
    if jax.device_count() >= 8:
        from repro.launch.mesh import make_test_mesh
        err8 = _replay_max_err(
            ch, build_session(GATE_WORKERS, dim=CHURN_DIM, samples=4,
                              delay_model=dm, mesh=make_test_mesh(8)))
        emit(f"churn_replay_err_spmd,{err8:.2e},mesh=data4xmodel2")
        ok = ok and err8 <= 1e-5
    else:
        emit("churn_replay_err_spmd,skipped,need 8 devices "
             "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    if ratio > max_ratio:
        emit(f"churn_gate_FAILED,0,rounds ratio {ratio:.3f} > {max_ratio}")
    if not ok:
        emit("churn_gate_FAILED,0,replay parity error above 1e-5")
    return ok and ratio <= max_ratio


def lossy_scenario(emit, smoke: bool = False) -> bool:
    """Unreliable transport at 8 workers, REAL numerics: 5% drop, 2%
    duplication, 10% reorder on every worker<->server link, with the
    runtime's ack/retry/backoff reliability layer on. Gates
    rounds-to-tolerance lossy/reliable vs ``max_lossy_rounds_ratio``
    (benchmarks/kernels_baseline.json) and replay parity of the lossy
    trace through the vectorized epoch (single device + SPMD when 8
    devices are up)."""
    import jax

    from repro.ps import Transport

    R = 16 if smoke else 24
    tw, ts = ConstantService(1.0), ConstantService(0.25)
    tr = Transport(0.0, 0.0, drop_rate=0.05, dup_rate=0.02,
                   reorder_rate=0.1, ack_timeout=0.5)
    sess = build_session(GATE_WORKERS, dim=CHURN_DIM, samples=4)
    rel = sess.run_ps(R, timing=CostProfile(t_worker=tw, t_server_block=ts))
    lo = sess.run_ps(R, timing=CostProfile(t_worker=tw, t_server_block=ts,
                                           net=tr))

    tol = rel.losses[int(0.6 * R) - 1]
    r_rel = _rounds_to_tolerance(rel.losses, tol)
    r_lo = _rounds_to_tolerance(lo.losses, tol)
    ratio = float("inf") if r_lo is None else r_lo / r_rel
    max_ratio = json.loads(BASELINE.read_text())["max_lossy_rounds_ratio"]

    t = lo.metrics["transport"]
    emit(f"lossy_reliable_makespan,{rel.makespan*1e6:.0f},"
         f"rounds_to_tol={r_rel}")
    emit(f"lossy_transport_makespan,{lo.makespan*1e6:.0f},"
         f"rounds_to_tol={r_lo}")
    emit(f"lossy_rounds_ratio,{ratio:.3f},max={max_ratio}"
         f"|delivery_rate={t['delivery_rate']:.3f}"
         f"|drops={t['drops']}|dups={t['dups']}|reorders={t['reorders']}"
         f"|retransmits={t['retransmits']}|dups_dropped={t['dups_dropped']}"
         f"|timeout_fallbacks={t['timeout_fallbacks']}")

    dm = lo.to_delay_model()
    err1 = _replay_max_err(lo, build_session(GATE_WORKERS, dim=CHURN_DIM,
                                             samples=4, delay_model=dm))
    emit(f"lossy_replay_err_1dev,{err1:.2e},tol=1e-05")
    ok = err1 <= 1e-5
    if jax.device_count() >= 8:
        from repro.launch.mesh import make_test_mesh
        err8 = _replay_max_err(
            lo, build_session(GATE_WORKERS, dim=CHURN_DIM, samples=4,
                              delay_model=dm, mesh=make_test_mesh(8)))
        emit(f"lossy_replay_err_spmd,{err8:.2e},mesh=data4xmodel2")
        ok = ok and err8 <= 1e-5
    else:
        emit("lossy_replay_err_spmd,skipped,need 8 devices "
             "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    if ratio > max_ratio:
        emit(f"lossy_gate_FAILED,0,rounds ratio {ratio:.3f} > {max_ratio}")
    if not ok:
        emit("lossy_gate_FAILED,0,replay parity error above 1e-5")
    return ok and ratio <= max_ratio


def server_crash_scenario(emit, smoke: bool = False) -> bool:
    """Block-server crash + WAL-replay recovery at 8 real-compute
    workers: a deterministic ``server_crash`` plan drops two lock
    domains' in-memory state mid-run; each rebuilds from its write-ahead
    commit log. Gates (benchmarks/kernels_baseline.json):

    * **zero lost folds** — every domain's committed fold log matches
      the crash-free run's per-round multiset exactly (hard-fail);
    * **rounds-to-tolerance** — the crash run must reach the crash-free
      tolerance within ``max_server_crash_rounds_ratio`` x its rounds
      (recovery costs sim time, never committed progress);
    * **replay parity** — the crash run's trace replays through the
      vectorized epoch within 1e-5 (single-device + SPMD when 8
      devices are up)."""
    import jax

    R = 16 if smoke else 24
    timing = CostProfile(t_worker=ConstantService(1.0),
                         t_server_block=ConstantService(0.25))
    plan = FaultPlan.of(FaultPlan.server_crash(2, at=3.0, down=2.5),
                        FaultPlan.server_crash(9, at=6.0, down=3.0))
    sess = build_session(GATE_WORKERS, dim=CHURN_DIM, samples=4)
    rt_ff = PSRuntime(sess.spec, data=sess.data, timing=timing)
    ff = rt_ff.run(R)
    rt_cr = PSRuntime(sess.spec, data=sess.data, timing=timing,
                      faults=plan)
    cr = rt_cr.run(R)

    # zero lost folds: per-domain, per-round fold MULTISETS must match
    # the crash-free run (in-round order may differ across a recovery)
    lost = 0
    for d_ff, d_cr in zip(rt_ff.domains, rt_cr.domains):
        per_round_ff = {}
        for (t, i, j) in d_ff.fold_log:
            per_round_ff.setdefault(t, []).append((i, j))
        per_round_cr = {}
        for (t, i, j) in d_cr.fold_log:
            per_round_cr.setdefault(t, []).append((i, j))
        for t in set(per_round_ff) | set(per_round_cr):
            if sorted(per_round_ff.get(t, [])) \
                    != sorted(per_round_cr.get(t, [])):
                lost += 1
    # read the durability instruments straight off the run's metrics
    # registry (the same instruments PSRunResult.metrics is built from)
    m = rt_cr.registry.collect(["server_recoveries", "wal"])
    emit(f"server_crash_folds,{sum(len(d.fold_log) for d in rt_cr.domains)},"
         f"mismatched_rounds={lost}"
         f"|recoveries={m['server_recoveries']}"
         f"|wal_commits={m['wal']['commits']}"
         f"|wal_replays={m['wal']['replays']}")
    ok = lost == 0 and m["server_recoveries"] == 2 \
        and ff.metrics.get("server_recoveries", 0) == 0

    tol = ff.losses[int(0.6 * R) - 1]
    r_ff = _rounds_to_tolerance(ff.losses, tol)
    r_cr = _rounds_to_tolerance(cr.losses, tol)
    ratio = float("inf") if r_cr is None else r_cr / r_ff
    max_ratio = json.loads(BASELINE.read_text())[
        "max_server_crash_rounds_ratio"]
    emit(f"server_crash_faultfree_makespan,{ff.makespan*1e6:.0f},"
         f"rounds_to_tol={r_ff}")
    emit(f"server_crash_chaos_makespan,{cr.makespan*1e6:.0f},"
         f"rounds_to_tol={r_cr}")
    emit(f"server_crash_rounds_ratio,{ratio:.3f},max={max_ratio}")

    dm = cr.to_delay_model()
    err1 = _replay_max_err(cr, build_session(GATE_WORKERS, dim=CHURN_DIM,
                                             samples=4, delay_model=dm))
    emit(f"server_crash_replay_err_1dev,{err1:.2e},tol=1e-05")
    ok = ok and err1 <= 1e-5
    if jax.device_count() >= 8:
        from repro.launch.mesh import make_test_mesh
        err8 = _replay_max_err(
            cr, build_session(GATE_WORKERS, dim=CHURN_DIM, samples=4,
                              delay_model=dm, mesh=make_test_mesh(8)))
        emit(f"server_crash_replay_err_spmd,{err8:.2e},mesh=data4xmodel2")
        ok = ok and err8 <= 1e-5
    else:
        emit("server_crash_replay_err_spmd,skipped,need 8 devices "
             "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")
    if lost:
        emit(f"server_crash_gate_FAILED,0,{lost} rounds lost/extra folds "
             f"after WAL replay")
    if ratio > max_ratio:
        emit(f"server_crash_gate_FAILED,0,rounds ratio {ratio:.3f} > "
             f"{max_ratio}")
    if not ok:
        emit("server_crash_gate_FAILED,0,replay parity or recovery "
             "count off")
    return ok and ratio <= max_ratio


def skew_scenario(emit, smoke: bool = False) -> bool:
    """Timing-only: zipf(a=1.5) vs uniform block selection at 8 workers
    under per-push commits (commit work paid per push, so a domain's
    busy time follows its push count). Skewed selection piles pushes
    onto the head blocks' lock domains — visible as queue-occupancy
    spread across the 16 per-block servers. Gated: the zipf run's
    occupancy spread (busiest/mean domain busy fraction) must exceed
    the uniform run's by ``min_skew_occupancy_ratio``."""
    R = 12 if smoke else 40
    timing = CostProfile(t_worker=ConstantService(1.0),
                         t_server_block=ConstantService(0.25),
                         t_push=0.05)
    spread = {}
    for selection in ("random", "zipf"):
        sess = build_session(GATE_WORKERS, dim=CHURN_DIM, samples=4,
                             block_selection=selection, zipf_a=1.5)
        rt = PSRuntime(sess.spec, discipline="per_push", timing=timing,
                       compute="timing")
        res = rt.run(R)
        # named-subset read off the run's metrics registry
        m = rt.registry.collect(["server_busy_frac", "histograms"])
        bf = m["server_busy_frac"]
        spread[selection] = max(bf) / (sum(bf) / len(bf))
        emit(f"skew_{selection}_makespan,{res.makespan*1e6:.0f},"
             f"busy_max={max(bf):.3f}|busy_min={min(bf):.3f}"
             f"|spread={spread[selection]:.3f}")
        _emit_hist(emit, f"skew_{selection}_occupancy_hist",
                   m["histograms"]["server_occupancy"])
    min_ratio = json.loads(BASELINE.read_text())["min_skew_occupancy_ratio"]
    ratio = spread["zipf"] / spread["random"]
    emit(f"skew_spread_ratio,{ratio:.3f},min={min_ratio}")
    if ratio < min_ratio:
        emit(f"skew_gate_FAILED,0,zipf/random occupancy spread "
             f"{ratio:.3f} < {min_ratio}")
        return False
    return True


def heavy_tail_scenario(emit, smoke: bool = False) -> bool:
    """Timing-only: Pareto(alpha=1.1) worker compute — Assumption 3's
    straggler tail — under round-buffered vs per-push commits. Stall
    time concentrates on the workers behind the straggler. Gated: the
    straggler tail must actually bite (lockfree stall time >=
    ``min_heavy_tail_stall``) while every served read stays within the
    enforced staleness bound."""
    R = 12 if smoke else 40
    timing = CostProfile(t_worker=ParetoService(1.0, alpha=1.1),
                         t_server_block=ConstantService(0.25))
    stalls = {}
    ok = True
    for disc in ("lockfree", "per_push"):
        sess = build_session(GATE_WORKERS, dim=CHURN_DIM, samples=4)
        rt = PSRuntime(sess.spec, discipline=disc, timing=timing,
                       compute="timing")
        res = rt.run(R)
        m = rt.registry.collect(["stall_time", "max_served_tau", "bound",
                                 "histograms"])
        stalls[disc] = m["stall_time"]
        ok = ok and m["max_served_tau"] <= m["bound"]
        emit(f"heavy_tail_{disc}_makespan,{res.makespan*1e6:.0f},"
             f"stall_time={m['stall_time']:.2f}"
             f"|max_served_tau={m['max_served_tau']}")
        _emit_hist(emit, f"heavy_tail_{disc}_stall_hist",
                   m["histograms"]["worker_stall_time"])
    min_stall = json.loads(BASELINE.read_text())["min_heavy_tail_stall"]
    emit(f"heavy_tail_lockfree_stall,{stalls['lockfree']:.2f},"
         f"min={min_stall}")
    if not ok:
        emit("heavy_tail_gate_FAILED,0,served tau above the bound")
        return False
    if stalls["lockfree"] < min_stall:
        emit(f"heavy_tail_gate_FAILED,0,lockfree stall time "
             f"{stalls['lockfree']:.2f} < {min_stall} — straggler tail "
             f"not biting; timing model regressed?")
        return False
    return True


SCENARIOS = {"churn": churn_scenario, "lossy": lossy_scenario,
             "server_crash": server_crash_scenario,
             "skew": skew_scenario, "heavy_tail": heavy_tail_scenario}


def main(emit=print, smoke: bool = False) -> None:
    costs = measured_costs()
    emit(f"speedup_measured_costs,{costs['t_worker']*1e6:.1f},"
         f"t_serve_block_us={costs['t_server_block']*1e6:.1f}")
    if smoke:
        if not smoke_gate(emit, costs):
            raise SystemExit(1)
        table1(emit, costs, workers=[1, GATE_WORKERS], k_iters=64)
    else:
        table1(emit, costs)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: deterministic locked-vs-lockfree gate "
                         "at 8 workers + a reduced Table-1 sweep (or a "
                         "reduced chaos scenario with --scenario)")
    ap.add_argument("--scenario", default=None, choices=sorted(SCENARIOS),
                    help="elastic-PS chaos study instead of Table 1: "
                         "churn (crash+rejoin, replay parity + "
                         "rounds-to-tolerance gate), lossy (unreliable "
                         "transport: drop/dup/reorder + ack/retry, "
                         "rounds-to-tolerance + replay gates), "
                         "server_crash (block-server crash + WAL-replay "
                         "recovery: zero-lost-folds, rounds-to-tolerance "
                         "+ replay gates), skew "
                         "(zipf block selection), heavy_tail (Pareto "
                         "stragglers)")
    args = ap.parse_args()
    if args.scenario is not None:
        if not SCENARIOS[args.scenario](print, smoke=args.smoke):
            raise SystemExit(1)
    else:
        main(smoke=args.smoke)
