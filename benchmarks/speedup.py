"""Paper Table 1 analogue: speedup of p workers performing k iterations.

We cannot rent 36 EC2 cores, so we reproduce the quantity Table 1
actually measures — the scalability of the *coordination scheme* —
with the event-driven Parameter Server runtime (``repro.ps``). This
module is now a thin client of that subsystem: the lock domains, push
queues, bounded-staleness stalls and makespan accounting all live in
``repro.ps``; here we only

* measure the real per-event costs — one worker iteration and one
  block-server commit of the REAL jitted ``VariableSpace`` hot path
  (``repro.ps.timing.measure_costs``; the hand-rolled loss_fn /
  server_update measurement this file used to carry is gone);
* feed them to the scheduler as service times (lognormal jitter, the
  EC2 stragglers Assumption 3 exists for) and sweep workers x
  {lockfree, locked} through ONE code path (``PSRuntime`` in
  timing-only mode);
* report ``T_k(p)`` = makespan until k total iterations commit,
  work-shared by p workers, and ``Speedup_p = T_k(1) / T_k(p)``.

``--smoke`` (CI, via scripts/ci.sh) additionally runs a DETERMINISTIC
locked-vs-lockfree comparison at 8 workers — constant service times in
a coordination-bound regime (worker compute pinned to 4 block-serve
units, M=16, so the full-vector lock's M-serial commit dominates) —
and gates the lockfree/locked makespan ratio against
``min_lockfree_speedup_x8`` in benchmarks/kernels_baseline.json.

CSV columns: name, us_per_call (simulated makespan), derived (speedup).
"""
import argparse
import json
import pathlib

from repro.api import ConsensusSession
from repro.configs.base import ADMMConfig
from repro.data import make_sparse_logreg
from repro.ps import (ConstantService, CostProfile, LognormalService,
                      PSRuntime, measure_costs)

K_ITERS = 320
WORKERS = [1, 4, 8, 16, 32]
M_BLOCKS = 16
GATE_WORKERS = 8
GATE_ROUNDS = 12
BASELINE = pathlib.Path(__file__).parent / "kernels_baseline.json"


def build_session(num_workers: int, dim: int = 2048, samples: int = 64,
                  seed: int = 0) -> ConsensusSession:
    """The paper's sparse-logreg workload (eq. 22) on the unified API."""
    import jax.numpy as jnp

    data = make_sparse_logreg(num_workers=num_workers,
                              samples_per_worker=samples, dim=dim,
                              density=0.1, seed=seed)

    def loss_fn(z, d):
        X, y = d
        return jnp.mean(jnp.log1p(jnp.exp(-y * (X @ z))))

    cfg = ADMMConfig(rho=2.0, gamma=0.1, max_delay=2, block_fraction=0.5,
                     num_blocks=M_BLOCKS, l1_coef=1e-3, clip=1e4, seed=seed)
    return ConsensusSession.flat(
        loss_fn, (jnp.asarray(data.X), jnp.asarray(data.y)), dim=dim,
        cfg=cfg)


def measured_costs(dim: int = 2048, samples: int = 64) -> dict:
    """Real measured costs of one worker iteration and one z-block
    commit, timed on the unified jitted hot path."""
    sess = build_session(1, dim=dim, samples=samples)
    return measure_costs(sess.spec, sess.data)


def makespan(p: int, k_total: int, timing: CostProfile,
             discipline: str) -> float:
    """Event-driven makespan until k_total iterations commit, the work
    shared by p workers (ceil-split like the paper's fixed-k runs)."""
    rounds = -(-k_total // p)
    sess = build_session(p, dim=M_BLOCKS * 16, samples=4)
    rt = PSRuntime(sess.spec, discipline=discipline, timing=timing,
                   compute="timing")
    return rt.run(rounds).makespan


def table1(emit, costs: dict, workers=WORKERS, k_iters=K_ITERS,
           jitter: float = 0.3) -> None:
    for discipline in ("lockfree", "locked"):
        timing = CostProfile(
            t_worker=LognormalService(costs["t_worker"], jitter),
            t_server_block=LognormalService(costs["t_server_block"],
                                            jitter / 2))
        base = makespan(1, k_iters, timing, discipline)
        for p in workers:
            tk = base if p == 1 else makespan(p, k_iters, timing, discipline)
            emit(f"table1_{discipline}_p{p},{tk*1e6:.0f},"
                 f"speedup={base / tk:.2f}")


def smoke_gate(emit, costs: dict) -> bool:
    """Deterministic coordination-bound comparison at 8 workers:
    constant service, worker compute = 4 block-serve units. The only
    difference between the two runs is the lock discipline, so the
    makespan ratio isolates exactly the paper's §1 claim (block-wise
    servers beat the full-vector lock). Gated vs the baseline."""
    ts = costs["t_server_block"]
    timing = CostProfile(t_worker=ConstantService(4.0 * ts),
                         t_server_block=ConstantService(ts))
    spans = {d: makespan(GATE_WORKERS, GATE_WORKERS * GATE_ROUNDS, timing, d)
             for d in ("lockfree", "locked")}
    ratio = spans["locked"] / spans["lockfree"]
    min_ratio = json.loads(BASELINE.read_text())["min_lockfree_speedup_x8"]
    ok = ratio >= min_ratio
    emit(f"speedup_gate_lockfree_x{GATE_WORKERS},"
         f"{spans['lockfree']*1e6:.0f},ratio={ratio:.2f}")
    emit(f"speedup_gate_locked_x{GATE_WORKERS},"
         f"{spans['locked']*1e6:.0f},min_ratio={min_ratio}")
    if not ok:
        emit(f"speedup_gate_FAILED,0,locked/lockfree ratio {ratio:.2f} < "
             f"{min_ratio}")
    return ok


def main(emit=print, smoke: bool = False) -> None:
    costs = measured_costs()
    emit(f"speedup_measured_costs,{costs['t_worker']*1e6:.1f},"
         f"t_serve_block_us={costs['t_server_block']*1e6:.1f}")
    if smoke:
        if not smoke_gate(emit, costs):
            raise SystemExit(1)
        table1(emit, costs, workers=[1, GATE_WORKERS], k_iters=64)
    else:
        table1(emit, costs)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: deterministic locked-vs-lockfree gate "
                         "at 8 workers + a reduced Table-1 sweep")
    main(smoke=ap.parse_args().smoke)
