"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only NAME]

Prints ``name,us_per_call,derived`` CSV rows:
  convergence    — paper Fig. 2 (objective vs epoch, sync + delays)
  speedup        — paper Table 1 (event-driven coordination scalability)
  kernels        — fused-kernel HBM-traffic roofline projections
  roofline       — §Roofline table from the dry-run artifacts
"""
import argparse
import sys
import traceback

from . import convergence, kernels_bench, roofline_bench, speedup

SUITES = {
    "convergence": convergence.main,
    "speedup": speedup.main,
    "kernels": kernels_bench.main,
    "roofline": roofline_bench.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=list(SUITES))
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = []
    for name, fn in SUITES.items():
        if args.only and name != args.only:
            continue
        try:
            fn(emit=print)
        except Exception as e:
            failed.append(name)
            print(f"{name}_FAILED,0,{e!r}", flush=True)
            traceback.print_exc(file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
