"""Roofline report: aggregates experiments/dryrun.jsonl into the
EXPERIMENTS.md §Roofline table.

CSV columns: name, us_per_call (roofline step-time bound, us), derived
(bottleneck + the three terms).
"""
import json
import os

DEFAULT_PATH = os.path.join(os.path.dirname(__file__), "..",
                            "experiments", "dryrun.jsonl")


def load(path=DEFAULT_PATH, variant="baseline"):
    rows = {}
    if not os.path.exists(path):
        return rows
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if r.get("status") != "ok" or r.get("variant") != variant:
                continue
            rows[(r["arch"], r["shape"], r["mesh"])] = r   # last write wins
    return rows


def main(emit=print, path=DEFAULT_PATH):
    rows = load(path)
    if not rows:
        emit("roofline_missing,0,run `python -m repro.launch.dryrun` first")
        return
    for (arch, shape, mesh), r in sorted(rows.items()):
        name = f"roofline_{arch}_{shape}_{mesh}"
        us = r["step_time_bound_s"] * 1e6
        der = (f"bottleneck={r['bottleneck']};"
               f"tc={r['t_compute_s']:.2e};tm={r['t_memory_s']:.2e};"
               f"tx={r['t_collective_s']:.2e};"
               f"useful={r.get('useful_flops_ratio') or 0:.3f};"
               f"mfu_bound={r.get('mfu_bound') or 0:.3f}")
        emit(f"{name},{us:.1f},{der}")


if __name__ == "__main__":
    main()
