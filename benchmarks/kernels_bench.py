"""Kernel-level benchmark: bytes-moved roofline projection for the fused
Pallas ops vs. their unfused jnp reference.

On this CPU container, interpret-mode wall time is meaningless; what is
meaningful and machine-independent is the HBM traffic each formulation
implies. We count bytes (inputs read + outputs written, assuming perfect
fusion for the Pallas kernel and materialized intermediates for the
unfused reference) and project v5e time at 819 GB/s.

CSV columns: name, us_per_call (projected TPU v5e us), derived.
"""
import numpy as np

HBM_BW = 819e9
BYTES = 4  # f32


def admm_update_traffic(n):
    fused = (3 + 3) * n * BYTES          # read g,y,z~ ; write x,y',w
    # unfused: x = z-(g+y)/rho (r3,w1); y' = -g (r1,w1); w = rho*x+y' (r2,w1)
    unfused = (3 + 1 + 1 + 1 + 2 + 1) * n * BYTES
    return fused, unfused


def prox_traffic(n):
    fused = (2 + 1) * n * BYTES          # read z~,w_sum ; write z'
    # unfused: v=(g z+w)/mu (r2,w1); soft-thresh (r1,w1); clip (r1,w1)
    unfused = (3 + 2 + 2) * n * BYTES
    return fused, unfused


def main(emit=print):
    for n in (1 << 20, 1 << 24, 1 << 27):
        f, u = admm_update_traffic(n)
        emit(f"kern_admm_update_n{n},{f/HBM_BW*1e6:.1f},"
             f"unfused_us={u/HBM_BW*1e6:.1f};saving={1-f/u:.2%}")
        f, u = prox_traffic(n)
        emit(f"kern_prox_update_n{n},{f/HBM_BW*1e6:.1f},"
             f"unfused_us={u/HBM_BW*1e6:.1f};saving={1-f/u:.2%}")
    # logreg grad: arithmetic intensity of the two matmul passes
    m, d = 1 << 20, 1 << 14
    flops = 2 * 2 * m * d                 # Xw and X^T v
    bytes_ = (2 * m * d + 2 * (m + d)) * BYTES
    emit(f"kern_logreg_grad_m{m}_d{d},{flops/197e12*1e6:.1f},"
         f"ai={flops/bytes_:.2f}flops/B;"
         f"mem_us={bytes_/HBM_BW*1e6:.1f}")


if __name__ == "__main__":
    main()
