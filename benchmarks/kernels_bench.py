"""Kernel-level benchmark: measured HBM bytes for the kernel-backed
(pallas) epoch vs the unfused jnp epoch, plus the seed's analytic
roofline projections.

On this CPU container, interpret-mode wall time is meaningless; what is
meaningful and machine-independent is the HBM traffic each formulation
implies. We measure it from real lowered programs:

* both epochs are lowered through ``asybadmm_epoch`` (the single
  Algorithm 1 implementation) and costed by
  ``analysis/hlo_cost.analyze_hlo`` on the op-level (pre-optimization)
  HLO — every jnp op charged its operand+result traffic, i.e. the
  *unfused* execution the fusion claim is measured against;
* the pallas epoch is lowered with ``backend="pallas_stub"``: each
  fused kernel appears as a single opaque boundary op charged exactly
  its operand+result bytes — the same boundary model ``hlo_cost``
  applies to XLA fusions, and exactly the kernels' VMEM DMA contract.

Sizes follow the paper's kddA workload (~20.2M features; here split
into M=64 lane-aligned blocks over N=8 workers) plus a small smoke
case. Results land in ``BENCH_kernels.json`` at the repo root.

``--smoke`` additionally runs a numeric jnp<->pallas(interpret) parity
+ NaN check and compares everything against
``benchmarks/kernels_baseline.json``, exiting nonzero on regression —
wired into ``scripts/ci.sh``.

CSV columns: name, us_per_call (projected TPU v5e us), derived.
"""
import argparse
import json
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo_cost import analyze_hlo
from repro.api import ConsensusSession
from repro.configs.base import ADMMConfig
from repro.core.space import asybadmm_epoch, init_consensus_state

REPO = Path(__file__).resolve().parent.parent
OUT_JSON = REPO / "BENCH_kernels.json"
BASELINE_JSON = REPO / "benchmarks" / "kernels_baseline.json"

HBM_BW = 819e9
BYTES = 4  # f32

# (name, N workers, M blocks, per-block dim) — kdda_like ~= the paper's
# kddA sparse logistic regression scale (20.2M coords, lane-aligned)
CASES = [
    ("smoke", 4, 8, 256),
    ("kdda_like", 8, 64, 315904),
]


# ---------------------------------------------------------------------------
# analytic single-op roofline rows (the seed bench, kept for reference)
# ---------------------------------------------------------------------------

def admm_update_traffic(n):
    fused = (3 + 3) * n * BYTES          # read g,y,z~ ; write x,y',w
    # unfused: x = z-(g+y)/rho (r3,w1); y' = -g (r1,w1); w = rho*x+y' (r2,w1)
    unfused = (3 + 1 + 1 + 1 + 2 + 1) * n * BYTES
    return fused, unfused


def prox_traffic(n):
    fused = (2 + 1) * n * BYTES          # read z~,w_sum ; write z'
    # unfused: v=(g z+w)/mu (r2,w1); soft-thresh (r1,w1); clip (r1,w1)
    unfused = (3 + 2 + 2) * n * BYTES
    return fused, unfused


def _analytic_rows(emit):
    for n in (1 << 20, 1 << 24, 1 << 27):
        f, u = admm_update_traffic(n)
        emit(f"kern_admm_update_n{n},{f/HBM_BW*1e6:.1f},"
             f"unfused_us={u/HBM_BW*1e6:.1f};saving={1-f/u:.2%}")
        f, u = prox_traffic(n)
        emit(f"kern_prox_update_n{n},{f/HBM_BW*1e6:.1f},"
             f"unfused_us={u/HBM_BW*1e6:.1f};saving={1-f/u:.2%}")
    # logreg grad: arithmetic intensity of the two matmul passes
    m, d = 1 << 20, 1 << 14
    flops = 2 * 2 * m * d                 # Xw and X^T v
    bytes_ = (2 * m * d + 2 * (m + d)) * BYTES
    emit(f"kern_logreg_grad_m{m}_d{d},{flops/197e12*1e6:.1f},"
         f"ai={flops/bytes_:.2f}flops/B;"
         f"mem_us={bytes_/HBM_BW*1e6:.1f}")


# ---------------------------------------------------------------------------
# measured epoch cost (op-level HLO, kernels at their DMA boundary)
# ---------------------------------------------------------------------------

def _quad_loss(z, c):
    return 0.5 * jnp.sum(jnp.square(z - c))


def _session(backend, N, M, dblk):
    dim = M * dblk
    cfg = ADMMConfig(rho=2.0, gamma=0.1, max_delay=1, block_fraction=0.5,
                     num_blocks=M, l1_coef=1e-3, clip=1.0, backend=backend)
    data = jax.ShapeDtypeStruct((N, dim), jnp.float32)
    return ConsensusSession.flat(_quad_loss, data, dim=dim, cfg=cfg)


def _epoch_cost(backend, N, M, dblk):
    """HLO cost of one asybadmm_epoch, lowered abstractly (no real
    arrays — works at full kddA scale)."""
    sess = _session(backend, N, M, dblk)
    spec = sess.spec
    state = jax.eval_shape(lambda: init_consensus_state(spec, None))
    hlo = (jax.jit(lambda s, b: asybadmm_epoch(spec, s, b))
           .lower(state, sess.data)
           .compiler_ir(dialect="hlo").as_hlo_text())
    return analyze_hlo(hlo)


def measure_cases(emit):
    out = []
    for name, N, M, dblk in CASES:
        jnp_cost = _epoch_cost("jnp", N, M, dblk)
        pl_cost = _epoch_cost("pallas_stub", N, M, dblk)
        saving = 1.0 - pl_cost.hbm_bytes / jnp_cost.hbm_bytes
        rec = {
            "name": name, "N": N, "M": M, "dblk": dblk, "dim": M * dblk,
            "jnp": {"hbm_bytes": int(jnp_cost.hbm_bytes),
                    "flops": int(jnp_cost.flops),
                    "v5e_us": jnp_cost.hbm_bytes / HBM_BW * 1e6},
            "pallas": {"hbm_bytes": int(pl_cost.hbm_bytes),
                       "flops": int(pl_cost.flops),
                       "v5e_us": pl_cost.hbm_bytes / HBM_BW * 1e6},
            "bytes_saving_frac": saving,
        }
        out.append(rec)
        emit(f"epoch_{name}_N{N}_M{M},{rec['pallas']['v5e_us']:.1f},"
             f"jnp_us={rec['jnp']['v5e_us']:.1f};"
             f"bytes_saving={saving:.2%}")
    return out


def parity_check(epochs=5):
    """Numeric jnp vs pallas(interpret) agreement on a real small run."""
    N, M, dblk = 3, 8, 32
    dim = M * dblk
    rng = np.random.RandomState(0)
    centers = jnp.asarray(rng.randn(N, dim), jnp.float32)
    cfg = ADMMConfig(rho=2.0, gamma=0.1, max_delay=1, block_fraction=0.5,
                     num_blocks=M, l1_coef=1e-3, clip=1.0)
    zs = {}
    for backend in ("jnp", "pallas"):
        sess = ConsensusSession.flat(_quad_loss, centers, dim=dim, cfg=cfg,
                                     backend=backend)
        state = sess.init()
        step = sess.step_fn()
        for _ in range(epochs):
            state, _ = step(state, centers)
        zs[backend] = np.asarray(sess.z(state))
    err = float(np.max(np.abs(zs["jnp"] - zs["pallas"])))
    finite = bool(np.isfinite(zs["jnp"]).all()
                  and np.isfinite(zs["pallas"]).all())
    return err, finite


def main(emit=print, smoke: bool = False) -> None:
    _analytic_rows(emit)
    cases = measure_cases(emit)
    report = {
        "hbm_bw_gbps": HBM_BW / 1e9,
        "method": ("op-level (pre-optimization) HLO costed by "
                   "analysis.hlo_cost; pallas kernels charged at their "
                   "operand+result DMA boundary via backend=pallas_stub"),
        "cases": cases,
    }
    failures = []
    if smoke:
        err, finite = parity_check()
        report["parity"] = {"max_err": err, "finite": finite}
        emit(f"epoch_backend_parity,0,max_err={err:.2e};finite={finite}")
        baseline = json.loads(BASELINE_JSON.read_text())
        min_saving = baseline["min_bytes_saving_frac"]
        if not finite:
            failures.append("NaN/Inf in epoch outputs")
        if err > baseline["max_parity_err"]:
            failures.append(f"parity err {err:.2e} > "
                            f"{baseline['max_parity_err']:.0e}")
        for rec in cases:
            if rec["bytes_saving_frac"] < min_saving:
                failures.append(
                    f"{rec['name']}: bytes saving "
                    f"{rec['bytes_saving_frac']:.2%} < {min_saving:.0%}")
    OUT_JSON.write_text(json.dumps(report, indent=2) + "\n")
    emit(f"bench_json,0,written={OUT_JSON.name}")
    if failures:
        for f in failures:
            emit(f"kernels_bench_REGRESSION,0,{f}")
        raise SystemExit(1)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="also run numeric parity/NaN checks and fail on "
                         "regression vs benchmarks/kernels_baseline.json")
    args = ap.parse_args()
    main(smoke=args.smoke)
