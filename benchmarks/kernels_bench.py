"""Kernel-level benchmark: measured HBM bytes for the kernel-backed
(pallas) epoch vs the unfused jnp epoch vs the SPMD-sharded per-shard
program, measured wall-clock per epoch, plus the seed's analytic
roofline projections.

On this CPU container, interpret-mode wall time is not TPU-predictive;
what is meaningful and machine-independent is the HBM traffic each
formulation implies. We measure it from real lowered programs:

* both epochs are lowered through ``asybadmm_epoch`` (the single
  Algorithm 1 implementation) and costed by
  ``analysis/hlo_cost.analyze_hlo`` on the op-level (pre-optimization)
  HLO — every jnp op charged its operand+result traffic, i.e. the
  *unfused* execution the fusion claim is measured against;
* the pallas epoch is lowered with ``backend="pallas_stub"``: each
  fused kernel appears as a single opaque boundary op charged exactly
  its operand+result bytes — the same boundary model ``hlo_cost``
  applies to XLA fusions, and exactly the kernels' VMEM DMA contract;
* the SPMD epoch is costed *per shard*: ``core.sharded``'s
  ``per_shard_cost_program`` lowers one (data=4, model=2) shard of the
  sharded epoch (collectives replaced by shape-faithful single-device
  stand-ins, state shrunk to its local tile) — the gate checks the
  per-shard bytes shrink toward 1/(data*model) of the fused epoch.

Wall-clock is additionally *executed* at the smoke shape (jit + warmup,
then median of 5 ``block_until_ready`` epochs) for jnp vs
pallas(interpret) vs sharded-pallas on an 8-host-device mesh, so
BENCH_kernels.json carries a real measured trajectory next to the cost
model (CPU-relative numbers; the byte counts are the portable claim).

Sizes follow the paper's kddA workload (~20.2M features; here split
into M=64 lane-aligned blocks over N=8 workers) plus a small smoke
case. Results land in ``BENCH_kernels.json`` at the repo root.

``--smoke`` additionally runs a numeric jnp<->pallas(interpret) parity
+ NaN check and compares everything against
``benchmarks/kernels_baseline.json``, exiting nonzero on regression —
wired into ``scripts/ci.sh``.

CSV columns: name, us_per_call (projected TPU v5e us), derived.
"""
import argparse
import json
import os
import sys
import time
from pathlib import Path

# The sharded wall-clock run needs a (data=4, model=2) host-device mesh,
# and the device count must be pinned before jax first initializes.
# No-op when jax is already imported (this module imported from
# elsewhere) — the sharded timing then degrades to a skip note.
if "jax" not in sys.modules:
    _flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _flags:
        os.environ["XLA_FLAGS"] = (
            _flags + " --xla_force_host_platform_device_count=8").strip()

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.hlo_cost import analyze_hlo
from repro.api import ConsensusSession
from repro.configs.base import ADMMConfig
from repro.core.sharded import per_shard_cost_program
from repro.core.space import asybadmm_epoch, init_consensus_state

REPO = Path(__file__).resolve().parent.parent
OUT_JSON = REPO / "BENCH_kernels.json"
BASELINE_JSON = REPO / "benchmarks" / "kernels_baseline.json"

HBM_BW = 819e9
BYTES = 4  # f32

# (name, N workers, M blocks, per-block dim) — kdda_like ~= the paper's
# kddA sparse logistic regression scale (20.2M coords, lane-aligned)
CASES = [
    ("smoke", 4, 8, 256),
    ("kdda_like", 8, 64, 315904),
]

# (data, model) shards for the per-shard / sharded-wall-clock rows
MESH_SHAPE = (4, 2)


# ---------------------------------------------------------------------------
# analytic single-op roofline rows (the seed bench, kept for reference)
# ---------------------------------------------------------------------------

def admm_update_traffic(n):
    fused = (3 + 3) * n * BYTES          # read g,y,z~ ; write x,y',w
    # unfused: x = z-(g+y)/rho (r3,w1); y' = -g (r1,w1); w = rho*x+y' (r2,w1)
    unfused = (3 + 1 + 1 + 1 + 2 + 1) * n * BYTES
    return fused, unfused


def prox_traffic(n):
    fused = (2 + 1) * n * BYTES          # read z~,w_sum ; write z'
    # unfused: v=(g z+w)/mu (r2,w1); soft-thresh (r1,w1); clip (r1,w1)
    unfused = (3 + 2 + 2) * n * BYTES
    return fused, unfused


def _analytic_rows(emit):
    for n in (1 << 20, 1 << 24, 1 << 27):
        f, u = admm_update_traffic(n)
        emit(f"kern_admm_update_n{n},{f/HBM_BW*1e6:.1f},"
             f"unfused_us={u/HBM_BW*1e6:.1f};saving={1-f/u:.2%}")
        f, u = prox_traffic(n)
        emit(f"kern_prox_update_n{n},{f/HBM_BW*1e6:.1f},"
             f"unfused_us={u/HBM_BW*1e6:.1f};saving={1-f/u:.2%}")
    # logreg grad: arithmetic intensity of the two matmul passes
    m, d = 1 << 20, 1 << 14
    flops = 2 * 2 * m * d                 # Xw and X^T v
    bytes_ = (2 * m * d + 2 * (m + d)) * BYTES
    emit(f"kern_logreg_grad_m{m}_d{d},{flops/197e12*1e6:.1f},"
         f"ai={flops/bytes_:.2f}flops/B;"
         f"mem_us={bytes_/HBM_BW*1e6:.1f}")


# ---------------------------------------------------------------------------
# measured epoch cost (op-level HLO, kernels at their DMA boundary)
# ---------------------------------------------------------------------------

def _quad_loss(z, c):
    return 0.5 * jnp.sum(jnp.square(z - c))


def _session(backend, N, M, dblk, mesh=None, data=None):
    dim = M * dblk
    cfg = ADMMConfig(rho=2.0, gamma=0.1, max_delay=1, block_fraction=0.5,
                     num_blocks=M, l1_coef=1e-3, clip=1.0, backend=backend,
                     autotune="cached")
    if data is None:
        data = jax.ShapeDtypeStruct((N, dim), jnp.float32)
    return ConsensusSession.flat(_quad_loss, data, dim=dim, cfg=cfg,
                                 mesh=mesh)


def _abstract_mesh():
    """Shape-only (data, model) mesh — per-shard costing needs no devices."""
    from jax.sharding import AbstractMesh
    return AbstractMesh((("data", MESH_SHAPE[0]), ("model", MESH_SHAPE[1])))


def _tree_spec(backend, N, M, dblk, mesh=None, concrete=False):
    """A ragged pytree spec at the same packed scale as the flat case:
    block j packs two leaves (dblk-128, 128), the last block only one —
    a genuinely ragged BlockLayout exercised end to end. The per-worker
    data (and loss) are per-leaf, matching how a params-pytree workload
    actually feeds batches — the loss never concatenates the pytree into
    one flat vector (that concat's transpose alone used to cost ~28 GB
    per kddA epoch). ``concrete=False`` builds ShapeDtypeStructs only
    (costing at full kddA scale); ``concrete=True`` allocates seeded
    arrays for the wall-clock runs."""
    from repro.core.blocks import TreeBlocks, make_block_layout
    from repro.core.space import TreeSpace, make_spec

    shapes = {f"w{j:03d}a": (dblk - 128,) for j in range(M)}
    shapes.update({f"w{j:03d}b": (128,) for j in range(M - 1)})
    names = sorted(shapes)                    # == jax dict flatten order
    if concrete:
        rng = np.random.RandomState(0)
        params = {n: jnp.asarray(rng.randn(*shapes[n]), jnp.float32)
                  for n in names}
        data = {n: jnp.asarray(rng.randn(N, *shapes[n]), jnp.float32)
                for n in names}
    else:
        params = {n: jax.ShapeDtypeStruct(shapes[n], jnp.float32)
                  for n in names}
        data = {n: jax.ShapeDtypeStruct((N,) + shapes[n], jnp.float32)
                for n in names}
    tblocks = TreeBlocks(num_blocks=M,
                         leaf_block_ids=tuple(int(n[1:4]) for n in names),
                         treedef=jax.tree.structure(params))
    space = TreeSpace(blocks=tblocks, num_workers=N,
                      layout=make_block_layout(params, tblocks))
    cfg = ADMMConfig(rho=2.0, gamma=0.1, max_delay=1, block_fraction=0.5,
                     num_blocks=M, l1_coef=1e-3, clip=1.0, backend=backend,
                     autotune="cached")

    def tree_loss(p, c):
        return 0.5 * sum(jnp.sum(jnp.square(p[n] - c[n])) for n in names)

    spec = make_spec(space, cfg, tree_loss, backend=backend, mesh=mesh)
    return spec, params, data


def _tree_session(backend, N, M, dblk, mesh=None):
    """Concrete TreeSpace session for the wall-clock rows."""
    spec, params, data = _tree_spec(backend, N, M, dblk, mesh=mesh,
                                    concrete=True)
    cfg = ADMMConfig(num_blocks=M, backend=backend, autotune="cached")
    return ConsensusSession(spec=spec, cfg=cfg, z0=params, data=data)


def _tree_epoch_cost(backend, N, M, dblk):
    """HLO cost of one TreeSpace asybadmm_epoch (packed layout)."""
    spec, params, data = _tree_spec(backend, N, M, dblk)
    state = jax.eval_shape(lambda p: init_consensus_state(spec, p), params)
    hlo = (jax.jit(lambda s, b: asybadmm_epoch(spec, s, b))
           .lower(state, data)
           .compiler_ir(dialect="hlo").as_hlo_text())
    return analyze_hlo(hlo)


def _tree_shard_epoch_cost(N, M, dblk):
    """HLO cost of ONE shard of the TreeSpace SPMD epoch — native block
    servers over `model` since the packed-layout lowering."""
    spec, params, data = _tree_spec("pallas_stub", N, M, dblk,
                                    mesh=_abstract_mesh())
    fn, args = per_shard_cost_program(spec, data, z0=params)
    hlo = (jax.jit(fn).lower(*args)
           .compiler_ir(dialect="hlo").as_hlo_text())
    return analyze_hlo(hlo)


def _epoch_cost(backend, N, M, dblk):
    """HLO cost of one asybadmm_epoch, lowered abstractly (no real
    arrays — works at full kddA scale)."""
    sess = _session(backend, N, M, dblk)
    spec = sess.spec
    state = jax.eval_shape(lambda: init_consensus_state(spec, None))
    hlo = (jax.jit(lambda s, b: asybadmm_epoch(spec, s, b))
           .lower(state, sess.data)
           .compiler_ir(dialect="hlo").as_hlo_text())
    return analyze_hlo(hlo)


def _shard_epoch_cost(N, M, dblk):
    """HLO cost of ONE shard of the SPMD epoch (kernels at their DMA
    boundary, collectives as shape-faithful stand-ins)."""
    sess = _session("pallas_stub", N, M, dblk, mesh=_abstract_mesh())
    fn, args = per_shard_cost_program(sess.spec, sess.data)
    hlo = (jax.jit(fn).lower(*args)
           .compiler_ir(dialect="hlo").as_hlo_text())
    return analyze_hlo(hlo)


def measure_cases(emit):
    from repro.kernels.autotune import device_kind, lookup_tile
    out = []
    shards = MESH_SHAPE[0] * MESH_SHAPE[1]
    for name, N, M, dblk in CASES:
        jnp_cost = _epoch_cost("jnp", N, M, dblk)
        pl_cost = _epoch_cost("pallas_stub", N, M, dblk)
        sh_cost = _shard_epoch_cost(N, M, dblk)
        tr_cost = _tree_epoch_cost("pallas_stub", N, M, dblk)
        tr_sh_cost = _tree_shard_epoch_cost(N, M, dblk)
        saving = 1.0 - pl_cost.hbm_bytes / jnp_cost.hbm_bytes
        shard_frac = sh_cost.hbm_bytes / pl_cost.hbm_bytes
        tree_shard_frac = tr_sh_cost.hbm_bytes / tr_cost.hbm_bytes
        tree_flat_ratio = tr_cost.hbm_bytes / pl_cost.hbm_bytes
        tiles = {op: lookup_tile(op, N, M, dblk)
                 for op in ("worker_select_update", "server_prox_fused")}
        rec = {
            "name": name, "N": N, "M": M, "dblk": dblk, "dim": M * dblk,
            "jnp": {"hbm_bytes": int(jnp_cost.hbm_bytes),
                    "flops": int(jnp_cost.flops),
                    "v5e_us": jnp_cost.hbm_bytes / HBM_BW * 1e6},
            "pallas": {"hbm_bytes": int(pl_cost.hbm_bytes),
                       "flops": int(pl_cost.flops),
                       "v5e_us": pl_cost.hbm_bytes / HBM_BW * 1e6},
            "pallas_sharded": {
                "hbm_bytes_per_shard": int(sh_cost.hbm_bytes),
                "flops_per_shard": int(sh_cost.flops),
                "v5e_us": sh_cost.hbm_bytes / HBM_BW * 1e6,
                "mesh": f"data={MESH_SHAPE[0]},model={MESH_SHAPE[1]}",
                "shard_bytes_frac": shard_frac,
                "ideal_frac": 1.0 / shards,
            },
            # tree space, packed-layout lowering: the ragged pytree's
            # epoch + ONE shard of its SPMD epoch (native block servers
            # over model — flipped from the old replicated-z fallback)
            "tree_pallas": {"hbm_bytes": int(tr_cost.hbm_bytes),
                            "flops": int(tr_cost.flops),
                            "v5e_us": tr_cost.hbm_bytes / HBM_BW * 1e6,
                            "flat_bytes_ratio": tree_flat_ratio},
            "tree_pallas_sharded": {
                "hbm_bytes_per_shard": int(tr_sh_cost.hbm_bytes),
                "flops_per_shard": int(tr_sh_cost.flops),
                "v5e_us": tr_sh_cost.hbm_bytes / HBM_BW * 1e6,
                "mesh": f"data={MESH_SHAPE[0]},model={MESH_SHAPE[1]}",
                "shard_bytes_frac": tree_shard_frac,
                "ideal_frac": 1.0 / shards,
            },
            "bytes_saving_frac": saving,
            # tuned tiles the pallas dispatch uses at this shape (cached
            # winners from benchmarks/kernels_tuned.json; null = miss,
            # heuristic tiles apply)
            "autotune": {"device_kind": device_kind(),
                         "tiles": {op: (list(t) if t else None)
                                   for op, t in tiles.items()}},
        }
        out.append(rec)
        emit(f"epoch_{name}_N{N}_M{M},{rec['pallas']['v5e_us']:.1f},"
             f"jnp_us={rec['jnp']['v5e_us']:.1f};"
             f"bytes_saving={saving:.2%}")
        emit(f"epoch_{name}_tree_vs_flat,{rec['tree_pallas']['v5e_us']:.1f},"
             f"tree_flat_bytes_ratio={tree_flat_ratio:.2f}")
        emit(f"epoch_{name}_shard_d{MESH_SHAPE[0]}m{MESH_SHAPE[1]},"
             f"{rec['pallas_sharded']['v5e_us']:.1f},"
             f"shard_bytes_frac={shard_frac:.3f};ideal={1.0/shards:.3f}")
        emit(f"epoch_{name}_tree_shard_d{MESH_SHAPE[0]}m{MESH_SHAPE[1]},"
             f"{rec['tree_pallas_sharded']['v5e_us']:.1f},"
             f"tree_shard_bytes_frac={tree_shard_frac:.3f};"
             f"ideal={1.0/shards:.3f}")
    return out


# ---------------------------------------------------------------------------
# measured wall-clock per epoch (real execution, smoke shape)
# ---------------------------------------------------------------------------

def _median_epoch_ms(sess, data, epochs=5):
    state = sess.init()
    step = sess.step_fn()
    state, _ = step(state, data)                # compile + warm the caches
    jax.block_until_ready(state)
    times = []
    for _ in range(epochs):
        t0 = time.perf_counter()
        state, _ = step(state, data)
        jax.block_until_ready(state)
        times.append((time.perf_counter() - t0) * 1e3)
    return float(np.median(times)), len(times)


def measure_walltime(emit):
    """jit + block_until_ready, median of 5 — jnp vs pallas(interpret)
    vs sharded-pallas, plus the TreeSpace lowering (tree_pallas /
    tree_pallas_sharded), at the smoke shape. CPU-relative numbers
    (pallas runs in interpret mode here); recorded so the perf
    trajectory of the epoch is measured, not only modeled. The pallas
    variants dispatch with autotune="cached", so the tuned tiles in use
    are part of the measurement (recorded per case in the cost rows)."""
    name, N, M, dblk = CASES[0]
    dim = M * dblk
    rng = np.random.RandomState(0)
    data = jnp.asarray(rng.randn(N, dim), jnp.float32)
    need = MESH_SHAPE[0] * MESH_SHAPE[1]
    mesh = None
    if jax.device_count() >= need:
        from repro.launch.mesh import make_test_mesh
        mesh = make_test_mesh(need, model=MESH_SHAPE[1])
    variants = [("jnp", "jnp", None, False),
                ("pallas", "pallas", None, False),
                ("pallas_sharded", "pallas", mesh, False),
                ("tree_pallas", "pallas", None, True),
                ("tree_pallas_sharded", "pallas", mesh, True)]
    entries = []
    for label, backend, m, tree in variants:
        if label.endswith("sharded") and m is None:
            emit(f"wallclock_{name}_{label},0,skipped;"
                 f"need_{need}_devices_have_{jax.device_count()}")
            continue
        if tree:
            sess = _tree_session(backend, N, M, dblk, mesh=m)
            ms, n = _median_epoch_ms(sess, sess.data)
        else:
            ms, n = _median_epoch_ms(_session(backend, N, M, dblk, mesh=m,
                                              data=data), data)
        entries.append({"variant": label, "median_ms": ms, "n": n})
        emit(f"wallclock_{name}_{label},{ms * 1e3:.0f},median_of_{n};ms={ms:.3f}")
    return {"case": name, "shape": {"N": N, "M": M, "dblk": dblk},
            "device_count": jax.device_count(),
            "method": "jit + block_until_ready, median of 5 epochs "
                      "(pallas in interpret mode on CPU; pallas variants "
                      "use autotune=cached tiles)",
            "entries": entries}


def parity_check(epochs=5):
    """Numeric jnp vs pallas(interpret) agreement on a real small run."""
    N, M, dblk = 3, 8, 32
    dim = M * dblk
    rng = np.random.RandomState(0)
    centers = jnp.asarray(rng.randn(N, dim), jnp.float32)
    cfg = ADMMConfig(rho=2.0, gamma=0.1, max_delay=1, block_fraction=0.5,
                     num_blocks=M, l1_coef=1e-3, clip=1.0)
    zs = {}
    for backend in ("jnp", "pallas"):
        sess = ConsensusSession.flat(_quad_loss, centers, dim=dim, cfg=cfg,
                                     backend=backend)
        state = sess.init()
        step = sess.step_fn()
        for _ in range(epochs):
            state, _ = step(state, centers)
        zs[backend] = np.asarray(sess.z(state))
    err = float(np.max(np.abs(zs["jnp"] - zs["pallas"])))
    finite = bool(np.isfinite(zs["jnp"]).all()
                  and np.isfinite(zs["pallas"]).all())
    return err, finite


def main(emit=print, smoke: bool = False) -> None:
    _analytic_rows(emit)
    cases = measure_cases(emit)
    report = {
        "hbm_bw_gbps": HBM_BW / 1e9,
        "method": ("op-level (pre-optimization) HLO costed by "
                   "analysis.hlo_cost; pallas kernels charged at their "
                   "operand+result DMA boundary via backend=pallas_stub; "
                   "pallas_sharded = ONE (data=4, model=2) shard of the "
                   "SPMD epoch (core.sharded.per_shard_cost_program)"),
        "cases": cases,
        "walltime": measure_walltime(emit),
    }
    failures = []
    if smoke:
        err, finite = parity_check()
        report["parity"] = {"max_err": err, "finite": finite}
        emit(f"epoch_backend_parity,0,max_err={err:.2e};finite={finite}")
        baseline = json.loads(BASELINE_JSON.read_text())
        min_saving = baseline["min_bytes_saving_frac"]
        max_shard_frac = baseline["max_shard_bytes_frac"]
        if not finite:
            failures.append("NaN/Inf in epoch outputs")
        if err > baseline["max_parity_err"]:
            failures.append(f"parity err {err:.2e} > "
                            f"{baseline['max_parity_err']:.0e}")
        for rec in cases:
            if rec["bytes_saving_frac"] < min_saving:
                failures.append(
                    f"{rec['name']}: bytes saving "
                    f"{rec['bytes_saving_frac']:.2%} < {min_saving:.0%}")
        # sharding gate: per-shard bytes of the SPMD epoch must shrink
        # toward 1/(data*model) of the fused single-device epoch at the
        # paper-scale shape (the small smoke case is padding-dominated)
        kdda = next(r for r in cases if r["name"] == "kdda_like")
        frac = kdda["pallas_sharded"]["shard_bytes_frac"]
        if frac > max_shard_frac:
            failures.append(
                f"kdda_like: per-shard bytes frac {frac:.3f} > "
                f"{max_shard_frac} (ideal 1/{MESH_SHAPE[0] * MESH_SHAPE[1]}"
                f" = {1.0 / (MESH_SHAPE[0] * MESH_SHAPE[1]):.3f})")
        # tree gate: the packed-layout lowering must keep TreeSpace's
        # per-shard bytes shrinking like the flat block servers (no
        # regression back toward the old replicated-z fallback, whose
        # state path would not shrink over model at all)
        max_tree_frac = baseline["max_tree_shard_bytes_frac"]
        tfrac = kdda["tree_pallas_sharded"]["shard_bytes_frac"]
        if tfrac > max_tree_frac:
            failures.append(
                f"kdda_like: TREE per-shard bytes frac {tfrac:.3f} > "
                f"{max_tree_frac} (ideal "
                f"1/{MESH_SHAPE[0] * MESH_SHAPE[1]} = "
                f"{1.0 / (MESH_SHAPE[0] * MESH_SHAPE[1]):.3f})")
        # tree/flat gate: the lane-aligned layout + dynamic-slice unpack
        # must keep the ragged pytree epoch's HBM traffic within a small
        # multiple of the flat epoch (it was ~8.3x before the layout
        # refactor — per-leaf row slices charged the full table per leaf)
        max_ratio = baseline["max_tree_flat_bytes_ratio"]
        ratio = kdda["tree_pallas"]["flat_bytes_ratio"]
        if ratio > max_ratio:
            failures.append(
                f"kdda_like: tree/flat epoch HBM ratio {ratio:.2f} > "
                f"{max_ratio}")
    OUT_JSON.write_text(json.dumps(report, indent=2) + "\n")
    emit(f"bench_json,0,written={OUT_JSON.name}")
    if failures:
        for f in failures:
            emit(f"kernels_bench_REGRESSION,0,{f}")
        raise SystemExit(1)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="also run numeric parity/NaN checks and fail on "
                         "regression vs benchmarks/kernels_baseline.json")
    args = ap.parse_args()
    main(smoke=args.smoke)
