"""Paper Fig. 2 analogue: AsyBADMM convergence on sparse logistic
regression (synthetic KDDa-like data), sync vs async at several delay
bounds, plus the stationarity metric P (Theorem 1.3).

CSV columns: name, us_per_call (per-epoch wall time), derived
(final objective | final P).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ADMMConfig
from repro.core import init_state, make_problem, make_step_fn, stationarity
from repro.data import make_sparse_logreg

EPOCHS = 600
EVAL_EVERY = 100


def build_problem(num_workers=8, dim=512, samples=64, num_blocks=16, seed=0):
    data = make_sparse_logreg(num_workers=num_workers,
                              samples_per_worker=samples, dim=dim,
                              density=0.1, seed=seed)

    def loss_fn(z, d):
        X, y = d
        return jnp.mean(jnp.log1p(jnp.exp(-y * (X @ z))))

    return make_problem(loss_fn, (jnp.asarray(data.X), jnp.asarray(data.y)),
                        dim=dim, num_blocks=num_blocks, support=data.support,
                        l1_coef=1e-3, clip=1e4)


def run_one(prob, cfg, epochs=EPOCHS):
    state = init_state(prob, cfg)
    step = make_step_fn(prob, cfg)
    state = step(state)                      # compile
    jax.block_until_ready(state.z_hist)
    t0 = time.perf_counter()
    trace = []
    for t in range(epochs):
        state = step(state)
        if (t + 1) % EVAL_EVERY == 0:
            z = prob.blocks.from_blocks(state.z_hist[0])
            trace.append(float(prob.objective(z)))
    jax.block_until_ready(state.z_hist)
    dt = (time.perf_counter() - t0) / epochs
    P = float(stationarity(prob, state, cfg.rho)["P"])
    return dt * 1e6, trace, P


def main(emit=print):
    prob = build_problem()
    variants = [
        ("fig2_sync_D0", ADMMConfig(rho=2.0, gamma=0.0, max_delay=0,
                                    block_fraction=1.0, num_blocks=16)),
        ("fig2_async_D2", ADMMConfig(rho=2.0, gamma=0.1, max_delay=2,
                                     block_fraction=0.5, num_blocks=16, seed=1)),
        ("fig2_async_D4", ADMMConfig(rho=2.0, gamma=0.1, max_delay=4,
                                     block_fraction=0.5, num_blocks=16, seed=2)),
        ("fig2_async_D8", ADMMConfig(rho=2.0, gamma=0.2, max_delay=8,
                                     block_fraction=0.5, num_blocks=16, seed=3)),
        ("fig2_fullvec_async", ADMMConfig(rho=2.0, gamma=0.1, max_delay=2,
                                          block_fraction=1.0, num_blocks=1,
                                          seed=4)),
    ]
    for name, cfg in variants:
        us, trace, P = run_one(prob, cfg)
        emit(f"{name},{us:.1f},obj={trace[-1]:.4f};P={P:.3e};"
             f"trace={'|'.join(f'{x:.3f}' for x in trace)}")


if __name__ == "__main__":
    main()
