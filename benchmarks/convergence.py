"""Paper Fig. 2 analogue: AsyBADMM convergence on sparse logistic
regression (synthetic KDDa-like data), sync vs async at several delay
bounds, plus the stationarity metric P (Theorem 1.3).

CSV columns: name, us_per_call (per-epoch wall time), derived
(final objective | final P).
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ConsensusSession
from repro.configs.base import ADMMConfig
from repro.data import make_sparse_logreg

EPOCHS = 600
EVAL_EVERY = 100


def build_session(cfg, num_workers=8, dim=512, samples=64, seed=0):
    data = make_sparse_logreg(num_workers=num_workers,
                              samples_per_worker=samples, dim=dim,
                              density=0.1, seed=seed)

    def loss_fn(z, d):
        X, y = d
        return jnp.mean(jnp.log1p(jnp.exp(-y * (X @ z))))

    return ConsensusSession.flat(
        loss_fn, (jnp.asarray(data.X), jnp.asarray(data.y)), dim=dim,
        cfg=cfg, support=data.support, l1_coef=1e-3, clip=1e4)


def run_one(sess, epochs=EPOCHS):
    state = sess.init()
    step = sess.step_fn()
    state, _ = step(state, sess.data)        # compile
    jax.block_until_ready(state.z_hist)
    t0 = time.perf_counter()
    trace = []
    for t in range(epochs):
        state, _ = step(state, sess.data)
        if (t + 1) % EVAL_EVERY == 0:
            trace.append(sess.objective(state))
    jax.block_until_ready(state.z_hist)
    dt = (time.perf_counter() - t0) / epochs
    P = float(sess.stationarity(state)["P"])
    return dt * 1e6, trace, P


def main(emit=print):
    variants = [
        ("fig2_sync_D0", ADMMConfig(rho=2.0, gamma=0.0, max_delay=0,
                                    block_fraction=1.0, num_blocks=16)),
        ("fig2_async_D2", ADMMConfig(rho=2.0, gamma=0.1, max_delay=2,
                                     block_fraction=0.5, num_blocks=16, seed=1)),
        ("fig2_async_D4", ADMMConfig(rho=2.0, gamma=0.1, max_delay=4,
                                     block_fraction=0.5, num_blocks=16, seed=2)),
        ("fig2_async_D8", ADMMConfig(rho=2.0, gamma=0.2, max_delay=8,
                                     block_fraction=0.5, num_blocks=16, seed=3)),
        ("fig2_fullvec_async", ADMMConfig(rho=2.0, gamma=0.1, max_delay=2,
                                          block_fraction=1.0, num_blocks=1,
                                          seed=4)),
    ]
    for name, cfg in variants:
        us, trace, P = run_one(build_session(cfg))
        emit(f"{name},{us:.1f},obj={trace[-1]:.4f};P={P:.3e};"
             f"trace={'|'.join(f'{x:.3f}' for x in trace)}")


if __name__ == "__main__":
    main()
