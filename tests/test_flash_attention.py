"""Pallas flash-attention kernel vs naive softmax oracle (interpret
mode on CPU; compiles to Mosaic on TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_bhsd


def _ref(q, k, v, causal):
    hd = q.shape[-1]
    s = (q @ jnp.swapaxes(k, 1, 2)).astype(jnp.float32) / np.sqrt(hd)
    if causal:
        S, T = s.shape[-2:]
        mask = jnp.tril(jnp.ones((S, T), bool))
        s = jnp.where(mask, s, -1e30)
    return (jax.nn.softmax(s, axis=-1) @ v.astype(jnp.float32)).astype(q.dtype)


@pytest.mark.parametrize("BH,S,hd", [(2, 128, 128), (4, 256, 128),
                                     (1, 512, 256), (3, 384, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_matches_naive(BH, S, hd, causal):
    rng = np.random.RandomState(BH + S)
    q = jnp.asarray(rng.randn(BH, S, hd), jnp.float32)
    k = jnp.asarray(rng.randn(BH, S, hd), jnp.float32)
    v = jnp.asarray(rng.randn(BH, S, hd), jnp.float32)
    out = flash_attention_bhsd(q, k, v, causal=causal, interpret=True)
    np.testing.assert_allclose(out, _ref(q, k, v, causal),
                               rtol=1e-4, atol=1e-4)


def test_flash_bf16():
    rng = np.random.RandomState(0)
    q, k, v = [jnp.asarray(rng.randn(2, 256, 128), jnp.bfloat16)
               for _ in range(3)]
    out = flash_attention_bhsd(q, k, v, causal=True, interpret=True)
    ref = _ref(q.astype(jnp.float32), k.astype(jnp.float32),
               v.astype(jnp.float32), True)
    np.testing.assert_allclose(np.asarray(out, np.float32), ref,
                               rtol=5e-2, atol=5e-2)


def test_flash_block_sizes():
    rng = np.random.RandomState(1)
    q, k, v = [jnp.asarray(rng.randn(1, 512, 128), jnp.float32)
               for _ in range(3)]
    ref = _ref(q, k, v, True)
    for bq, bk in [(128, 128), (256, 128), (128, 256), (512, 512)]:
        out = flash_attention_bhsd(q, k, v, causal=True, block_q=bq,
                                   block_k=bk, interpret=True)
        np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "chameleon-34b"])
def test_flash_integrated_in_model(arch):
    """attn_impl='flash' routes model attention through the Pallas
    kernel and matches the naive path end to end."""
    from repro.configs import get_smoke
    from repro.models import build_model
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 40), 0,
                             cfg.vocab_size)
    ref = model.prefill(params, tok)
    out = build_model(cfg.with_(attn_impl="flash")).prefill(params, tok)
    assert float(jnp.max(jnp.abs(ref - out))) < 2e-3
