"""Unit tests for the trip-count-aware HLO cost analyzer — this module
is load-bearing for the §Roofline tables, so its numbers are checked
against programs with analytically known costs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import collective_bytes
from repro.analysis.hlo_cost import analyze_hlo, parse_module


def _hlo_of(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_matmul_flops_exact():
    M, K, N = 256, 512, 128
    a = jax.ShapeDtypeStruct((M, K), jnp.float32)
    b = jax.ShapeDtypeStruct((K, N), jnp.float32)
    hlo = _hlo_of(lambda x, y: x @ y, a, b)
    cost = analyze_hlo(hlo)
    expect = 2 * M * K * N
    assert abs(cost.flops - expect) / expect < 0.05, (cost.flops, expect)


def test_scan_scales_by_trip_count():
    """A scanned matmul must cost ~trips x the single matmul."""
    D, TRIPS = 128, 17
    x = jax.ShapeDtypeStruct((D, D), jnp.float32)
    w = jax.ShapeDtypeStruct((TRIPS, D, D), jnp.float32)

    def scanned(x0, ws):
        def body(c, w_):
            return c @ w_, None
        out, _ = jax.lax.scan(body, x0, ws)
        return out

    hlo_1 = _hlo_of(lambda a, b: a @ b, x, x)
    hlo_n = _hlo_of(scanned, x, w)
    f1 = analyze_hlo(hlo_1).flops
    fn = analyze_hlo(hlo_n).flops
    ratio = fn / f1
    assert TRIPS * 0.9 < ratio < TRIPS * 1.3, ratio


def test_nested_scan_multiplies():
    D, INNER, OUTER = 128, 5, 7
    x = jax.ShapeDtypeStruct((D, D), jnp.float32)

    def nested(x0):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ c2, None
            c, _ = jax.lax.scan(inner, c, None, length=INNER)
            return c, None
        out, _ = jax.lax.scan(outer, x0, None, length=OUTER)
        return out

    hlo = _hlo_of(nested, x)
    f = analyze_hlo(hlo).flops
    expect = 2 * D ** 3 * INNER * OUTER
    assert 0.8 * expect < f < 1.5 * expect, (f, expect)


def test_hbm_bytes_elementwise():
    """y = a + b reads 2 arrays, writes 1: ~3x array bytes."""
    n = 1 << 16
    a = jax.ShapeDtypeStruct((n,), jnp.float32)
    hlo = _hlo_of(lambda x, y: x + y, a, a)
    c = analyze_hlo(hlo)
    expect = 3 * n * 4
    assert 0.5 * expect <= c.hbm_bytes <= 2.0 * expect, (c.hbm_bytes, expect)


def test_parse_module_structure():
    hlo = _hlo_of(lambda x: jnp.sin(x) @ x, jax.ShapeDtypeStruct((64, 64), jnp.float32))
    comps, entry, shapes = parse_module(hlo)
    assert entry is not None and entry in comps
    assert len(shapes) > 0


def test_dus_aliasing_not_overcharged():
    """A scan stacking outputs must not charge the whole stack per step."""
    D, TRIPS = 256, 32
    x = jax.ShapeDtypeStruct((D,), jnp.float32)

    def stacking(x0):
        def body(c, _):
            c = c * 2.0
            return c, c
        _, ys = jax.lax.scan(body, x0, None, length=TRIPS)
        return ys

    hlo = _hlo_of(stacking, x)
    c = analyze_hlo(hlo)
    # naive (full-stack per step) would be ~TRIPS^2 * D * 4 = 8.4 MB;
    # correct is O(TRIPS * D): well under 1 MB
    assert c.hbm_bytes < TRIPS * D * 4 * 20, c.hbm_bytes


def test_static_slice_charged_per_window():
    """Slicing K small leaves out of one big buffer must cost O(leaf
    bytes), not O(K x buffer bytes) — the packed-layout unpack
    (BlockLayout.from_blocks) is exactly this pattern."""
    n, K, w = 1 << 20, 16, 256
    x = jax.ShapeDtypeStruct((n,), jnp.float32)

    def unpack(buf):
        return [jax.lax.slice_in_dim(buf, k * w, (k + 1) * w) for k in range(K)]

    hlo = (jax.jit(unpack).lower(x)
           .compiler_ir(dialect="hlo").as_hlo_text())
    c = analyze_hlo(hlo)
    # 2x window per leaf; naive operand+result charging would be ~K * n * 4
    assert c.hbm_bytes <= 4 * K * w * 4, c.hbm_bytes
    assert c.hbm_bytes < 0.01 * K * n * 4, c.hbm_bytes


def test_preopt_call_bodies_counted():
    """The pre-optimization dump writes ``to_apply=inner.3`` without the
    ``%`` sigil — the analyzer must still recurse into the callee, or
    every kernel custom-call boundary vanishes from bench numbers."""
    n = 1 << 16
    x = jax.ShapeDtypeStruct((n,), jnp.float32)

    def f(v):
        def inner(y):
            return y * 2.0 + 1.0
        return jax.jit(inner)(v) + v

    hlo = (jax.jit(f).lower(x)
           .compiler_ir(dialect="hlo").as_hlo_text())
    assert "to_apply=" in hlo
    c = analyze_hlo(hlo)
    # inner body alone moves >= 2 array-loads + 1 store
    assert c.hbm_bytes > 3 * n * 4, c.hbm_bytes


def test_collective_bytes_unscaled_parser_on_known_text():
    hlo = "  %ar = bf16[256,128]{1,0} all-reduce(%x)\n"
    cb = collective_bytes(hlo)
    assert cb["all-reduce"] == 256 * 128 * 2
