"""Data pipeline tests."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # collection degrades to skip without the test extra
from hypothesis import given, settings, strategies as st

from repro.data import TokenPipeline, make_sparse_logreg


def test_pipeline_deterministic_and_resumable():
    p = TokenPipeline(vocab_size=100, seq_len=17, global_batch=4, seed=1)
    a = p.batch(5)
    b = p.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = p.batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_pipeline_labels_shifted():
    p = TokenPipeline(vocab_size=100, seq_len=17, global_batch=2, seed=0)
    b = p.batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    assert b["tokens"].shape == (2, 16)


def test_pipeline_worker_split():
    p = TokenPipeline(vocab_size=50, seq_len=9, global_batch=8, seed=0)
    flat = p.batch(3)
    split = p.batch(3, num_workers=4)
    assert split["tokens"].shape == (4, 2, 8)
    np.testing.assert_array_equal(split["tokens"].reshape(8, 8),
                                  flat["tokens"])


def test_pipeline_learnable():
    """With small branching, bigram entropy << log(vocab): a model can
    learn it, and tokens are in range."""
    p = TokenPipeline(vocab_size=64, seq_len=65, global_batch=4, seed=0,
                      branch=2)
    b = p.batch(0)
    assert int(b["tokens"].min()) >= 0 and int(b["tokens"].max()) < 64


def test_sparse_dataset_properties():
    d = make_sparse_logreg(num_workers=4, samples_per_worker=32, dim=80,
                           density=0.1, seed=0)
    assert d.X.shape == (4, 32, 80)
    assert set(np.unique(d.y)) <= {-1.0, 1.0}
    # sparsity: most entries zero
    assert (d.X != 0).mean() < 0.2
    # locality: every worker's support is partial
    assert d.support.shape == (4, 80)
    assert d.support.sum(axis=1).max() < 80
    # support consistent with X
    np.testing.assert_array_equal(d.support, (np.abs(d.X).sum(axis=1) > 0))


@given(st.integers(2, 5), st.integers(8, 32), st.integers(20, 60))
@settings(max_examples=10, deadline=None)
def test_sparse_dataset_shapes(n, m, d):
    data = make_sparse_logreg(n, m, d, seed=1)
    assert data.X.shape == (n, m, d)
    assert data.y.shape == (n, m)
    assert np.isfinite(data.X).all()
