"""Serving engine tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.models import build_model
from repro.serving import Engine


@pytest.mark.parametrize("arch", ["qwen3-1.7b", "mamba2-370m", "zamba2-1.2b"])
def test_greedy_matches_prefill_argmax(arch):
    """First generated token must equal argmax of the prefill logits at
    the last prompt position."""
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.random.RandomState(0).randint(0, cfg.vocab_size, (2, 6))
    res = Engine(model, params, max_len=32).generate(prompts, max_new=4)
    ref = model.prefill(params, jnp.asarray(prompts))
    expect = np.asarray(jnp.argmax(ref[:, -1, :], axis=-1))
    np.testing.assert_array_equal(res.tokens[:, 0], expect)
    assert res.tokens.shape == (2, 4)


def test_generation_deterministic():
    cfg = get_smoke("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.random.RandomState(1).randint(0, cfg.vocab_size, (3, 5))
    e = Engine(model, params, max_len=24)
    a = e.generate(prompts, max_new=6).tokens
    b = e.generate(prompts, max_new=6).tokens
    np.testing.assert_array_equal(a, b)


def test_temperature_sampling_runs():
    cfg = get_smoke("granite-moe-1b-a400m")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompts = np.zeros((2, 4), np.int64)
    res = Engine(model, params, max_len=16).generate(
        prompts, max_new=4, temperature=0.8, seed=3)
    assert res.tokens.shape == (2, 4)
    assert res.tokens.max() < cfg.vocab_size


def test_enc_dec_serving():
    cfg = get_smoke("whisper-medium")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    enc = jnp.asarray(
        np.random.RandomState(0).randn(2, cfg.encoder_seq_len, cfg.d_model),
        jnp.float32) * 0.1
    prompts = np.random.RandomState(2).randint(0, cfg.vocab_size, (2, 4))
    res = Engine(model, params, max_len=16).generate(prompts, max_new=4,
                                                     enc_frames=enc)
    assert res.tokens.shape == (2, 4)
