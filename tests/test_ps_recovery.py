"""Durability layer (repro.ps.recovery): block-server crash + WAL-replay
recovery, crash-consistent snapshots, and deterministic mid-run resume.

The headline pins:

* **zero lost folds** — a ``server_crash`` fault drops a lock domain's
  entire in-memory state mid-run; WAL replay rebuilds it exactly, so
  every domain's committed fold log matches the crash-free run's
  per-round multiset, and at staleness bound 0 the final z is BITWISE
  identical to the crash-free run;
* **resume determinism** — a run killed at any snapshot barrier and
  resumed finishes with exactly the uninterrupted run's z (bitwise on
  pallas), staleness trace, fold logs, losses, and makespan — composed
  with worker-crash chaos too;
* **inertness** — with ``checkpoint_every=None`` and no server_crash
  events the layer adds nothing: no metrics keys, byte-identical runs;
* torn checkpoints and malformed fault plans fail with actionable
  errors naming the file / leaf / event index.
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ConsensusSession
from repro.checkpoint import load_arrays, load_extra, restore, save
from repro.configs.base import ADMMConfig
from repro.ps import (ConstantService, CostProfile, DomainWAL, FaultPlan,
                      PSRuntime, Transport, latest_snapshot, list_snapshots,
                      load_snapshot)

N, M, DBLK = 3, 4, 5
DIM = M * DBLK
ROUNDS = 8

_r = np.random.RandomState(7)
CENTERS = jnp.asarray(_r.randn(N, DIM).astype(np.float32))
EDGE = np.array([[1, 1, 0, 1],
                 [1, 0, 1, 0],
                 [1, 1, 1, 1]], bool)
RHO_SCALE = np.array([0.5, 1.0, 2.0], np.float32)

TIMING = CostProfile(t_worker=ConstantService(1.0),
                     t_server_block=ConstantService(0.25))
CRASH_PLAN = FaultPlan.of(FaultPlan.server_crash(1, at=2.0, down=3.0))


def _cfg(**kw):
    kw.setdefault("max_delay", 2)
    return ADMMConfig(rho=2.0, gamma=0.1, block_fraction=0.5,
                      num_blocks=M, block_selection="random", l1_coef=1e-3,
                      clip=0.8, seed=0, **kw)


def _flat_loss(z, c):
    return 0.5 * jnp.sum(jnp.square(z - c))


def _session(backend="jnp", cfg=None, delay_model=None):
    return ConsensusSession.flat(
        _flat_loss, CENTERS, dim=DIM, cfg=cfg or _cfg(), edge=EDGE,
        rho_scale=RHO_SCALE, backend=backend, delay_model=delay_model)


def _runtime(faults=None, cfg=None, backend="jnp", **kw):
    sess = _session(backend=backend, cfg=cfg)
    return PSRuntime(sess.spec, data=sess.data, timing=TIMING,
                     faults=faults, **kw)


def _per_round_folds(rt):
    """{sid: {round: sorted [(worker, block)]}} from the fold logs."""
    out = {}
    for dom in rt.domains:
        rounds = {}
        for (v, i, j) in dom.fold_log:
            rounds.setdefault(v, []).append((i, j))
        out[dom.sid] = {v: sorted(fs) for v, fs in rounds.items()}
    return out


# ---------------------------------------------------------------------------
# server_crash: WAL replay loses zero committed folds
# ---------------------------------------------------------------------------

def test_server_crash_zero_lost_folds():
    """The crashed domain rebuilds from its WAL: every domain's
    committed per-round fold multiset matches the crash-free run's
    exactly, and the recovery is visible in metrics + trace events."""
    rt_ff = _runtime()
    ff = rt_ff.run(ROUNDS)
    rt_cr = _runtime(faults=CRASH_PLAN)
    cr = rt_cr.run(ROUNDS)

    assert _per_round_folds(rt_cr) == _per_round_folds(rt_ff)
    assert cr.metrics["server_recoveries"] == 1
    wal = cr.metrics["wal"]
    assert wal["replays"] == 1
    assert wal["commits"] == sum(d.version for d in rt_cr.domains)
    kinds = [e["kind"] for e in cr.trace.events]
    assert kinds.count("server_crash") == 1
    assert kinds.count("server_recover") == 1
    down = [e for e in cr.trace.events if e["kind"] == "server_crash"][0]
    up = [e for e in cr.trace.events if e["kind"] == "server_recover"][0]
    assert up["time"] - down["time"] == pytest.approx(3.0)
    assert down["sid"] == up["sid"] == 1
    assert up["replayed"] == down["version"]    # committed before crash
    # the outage costs sim time, never committed progress
    assert cr.makespan > ff.makespan
    # fault-free runs never arm the durability layer
    assert "server_recoveries" not in ff.metrics
    assert "wal" not in ff.metrics


def test_server_crash_bitwise_z_at_bound0():
    """At staleness bound 0 every read is fresh, so the effective
    schedule is crash-invariant — the crash run's final z must be
    BITWISE the crash-free run's (WAL replay goes through the same
    jitted fold path; per-round folds commute)."""
    cfg = _cfg(max_delay=0)
    ff = _runtime(cfg=cfg).run(ROUNDS)
    cr = _runtime(cfg=cfg, faults=CRASH_PLAN).run(ROUNDS)
    np.testing.assert_array_equal(np.asarray(ff.z_final),
                                  np.asarray(cr.z_final))
    np.testing.assert_array_equal(ff.trace.delays, cr.trace.delays)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_server_crash_trace_replays(backend):
    """The crash run's trace replays through the vectorized epoch —
    bitwise on pallas, fp32-ulp on jnp (recovery gaps shift sim time,
    not the committed version schedule)."""
    sess = _session(backend=backend)
    res = sess.run_ps(ROUNDS, timing=TIMING, faults=CRASH_PLAN)
    sess2 = _session(backend=backend, delay_model=res.to_delay_model())
    state = sess2.init()
    step = sess2.step_fn()
    for t in range(res.num_rounds):
        state, _ = step(state, CENTERS)
        replay = np.asarray(sess2.z(state)).ravel()
        runtime = np.asarray(res.z_versions[t + 1]).ravel()
        if backend == "pallas":
            np.testing.assert_array_equal(
                replay, runtime, err_msg=f"diverged at round {t}")
        else:
            np.testing.assert_allclose(
                replay, runtime, rtol=1e-5, atol=1e-6,
                err_msg=f"diverged at round {t}")


def test_server_crash_deterministic():
    """The same plan twice produces identical runs (seeded link fates,
    deterministic recovery)."""
    a = _runtime(faults=CRASH_PLAN).run(ROUNDS)
    b = _runtime(faults=CRASH_PLAN).run(ROUNDS)
    np.testing.assert_array_equal(np.asarray(a.z_final),
                                  np.asarray(b.z_final))
    np.testing.assert_array_equal(a.trace.delays, b.trace.delays)
    assert a.makespan == b.makespan


def test_server_crash_timing_only():
    """Timing-only runs crash/recover too (WAL replay skips the absent
    numerics but restores the version counter + pending queue)."""
    sess = _session()
    rt = PSRuntime(sess.spec, timing=TIMING, compute="timing",
                   faults=CRASH_PLAN)
    res = rt.run(ROUNDS)
    assert res.metrics["server_recoveries"] == 1
    assert res.trace.complete


def test_overlapping_server_crash_windows_merge():
    """A second crash landing while the domain is already down merges
    into the outage instead of double-crashing."""
    plan = FaultPlan.of(FaultPlan.server_crash(1, at=2.0, down=4.0),
                        FaultPlan.server_crash(1, at=3.0, down=1.0))
    rt = _runtime(faults=plan)
    res = rt.run(ROUNDS)
    rt_ff = _runtime()
    rt_ff.run(ROUNDS)
    assert _per_round_folds(rt) == _per_round_folds(rt_ff)
    assert res.metrics["server_recoveries"] >= 1


def test_wal_unit_dedup_and_sequencing():
    wal = DomainWAL(0)
    assert wal.record_declare(0, 0, [(1, "v")]) is True
    assert wal.record_declare(0, 0, [(1, "v")]) is False     # retransmit
    assert wal.dedup_skips == 1
    wal.record_commit(0, [(0, 1)])
    with pytest.raises(RuntimeError, match="out of sequence"):
        wal.record_commit(2, [(0, 1)])
    assert wal.value(0, 0, 1) == "v"
    assert wal.pending(1) == []


# ---------------------------------------------------------------------------
# crash-consistent snapshots + deterministic mid-run resume
# ---------------------------------------------------------------------------

def _assert_same_run(a, b):
    np.testing.assert_array_equal(np.asarray(a.z_final),
                                  np.asarray(b.z_final))
    np.testing.assert_array_equal(a.trace.delays, b.trace.delays)
    assert a.losses == b.losses
    assert a.makespan == b.makespan


def test_resume_parity_every_snapshot(tmp_path):
    """Resuming from EVERY snapshot of a checkpointed run reproduces
    the uninterrupted run exactly — z, trace, fold logs, losses,
    makespan."""
    rt_full = _runtime()
    full = rt_full.run(ROUNDS, checkpoint_every=2,
                       checkpoint_dir=str(tmp_path))
    snaps = full.metrics["snapshots"]
    assert [os.path.basename(s) for s in snaps] \
        == ["snap-000002", "snap-000004", "snap-000006"]
    assert list_snapshots(str(tmp_path)) == snaps
    assert latest_snapshot(str(tmp_path)) == snaps[-1]
    for snap in snaps:
        rt_res = _runtime()
        res = rt_res.run(ROUNDS, resume_from=snap)
        _assert_same_run(full, res)
        for d_full, d_res in zip(rt_full.domains, rt_res.domains):
            assert d_full.fold_log == d_res.fold_log
    # resume_from a DIRECTORY takes the latest snapshot
    res = _runtime().run(ROUNDS, resume_from=str(tmp_path))
    _assert_same_run(full, res)


def test_resume_parity_pallas_bitwise(tmp_path):
    """The pallas backend pins the resume bitwise: kernels are
    fusion-stable, so z_final must be byte-identical."""
    full = _runtime(backend="pallas").run(ROUNDS, checkpoint_every=3,
                                          checkpoint_dir=str(tmp_path))
    res = _runtime(backend="pallas").run(
        ROUNDS, resume_from=full.metrics["snapshots"][0])
    assert np.asarray(full.z_final).tobytes() \
        == np.asarray(res.z_final).tobytes()


def test_resume_composes_with_worker_chaos(tmp_path):
    """Snapshots taken while worker-crash chaos is active restore the
    membership timeline and pending fault events exactly."""
    plan = FaultPlan.of(FaultPlan.crash(1, 2.5, 2.0),
                        FaultPlan.crash(2, 6.0, 1.0))
    full = _runtime(faults=plan).run(ROUNDS, checkpoint_every=2,
                                     checkpoint_dir=str(tmp_path))
    assert full.metrics["crashes"] == 2
    for snap in full.metrics["snapshots"]:
        res = _runtime(faults=plan).run(ROUNDS, resume_from=snap)
        _assert_same_run(full, res)
        assert res.metrics["crashes"] + res.metrics["rejoins"] > 0 \
            or snap == full.metrics["snapshots"][0]


def test_checkpoint_layer_inert_when_off(tmp_path):
    """checkpoint_every=None is the default run, byte-identical."""
    plain = _runtime().run(ROUNDS)
    again = _runtime().run(ROUNDS)
    _assert_same_run(plain, again)
    assert "snapshots" not in plain.metrics
    assert np.asarray(plain.z_final).tobytes() \
        == np.asarray(again.z_final).tobytes()


def test_resume_validation_errors(tmp_path):
    with pytest.raises(ValueError, match="checkpoint_dir"):
        _runtime().run(ROUNDS, checkpoint_every=2)
    with pytest.raises(ValueError, match=">= 1"):
        _runtime().run(ROUNDS, checkpoint_every=0,
                       checkpoint_dir=str(tmp_path))
    with pytest.raises(FileNotFoundError):
        _runtime().run(ROUNDS, resume_from=str(tmp_path / "nope"))
    # no snapshots in an empty directory
    with pytest.raises(FileNotFoundError):
        _runtime().run(ROUNDS, resume_from=str(tmp_path))


def test_checkpoint_transport_incompatible(tmp_path):
    """In-flight retransmission timers are not snapshotable — the
    combination is refused up front (server_crash durability comes
    from the WAL instead)."""
    sess = _session()
    tr = Transport(0.0, 0.0, drop_rate=0.1)
    rt = PSRuntime(sess.spec, data=sess.data,
                   timing=CostProfile(t_worker=ConstantService(1.0),
                                      t_server_block=ConstantService(0.25),
                                      net=tr))
    with pytest.raises(ValueError, match="transport"):
        rt.run(ROUNDS, checkpoint_every=2, checkpoint_dir=str(tmp_path))
    rt2 = PSRuntime(sess.spec, timing=TIMING, compute="timing")
    with pytest.raises(ValueError, match="timing"):
        rt2.run(ROUNDS, checkpoint_every=2, checkpoint_dir=str(tmp_path))


def test_resume_fingerprint_mismatch(tmp_path):
    """A snapshot resumed into a differently-configured run fails
    naming the mismatched fields, not silently diverging."""
    full = _runtime().run(ROUNDS, checkpoint_every=2,
                          checkpoint_dir=str(tmp_path))
    snap = full.metrics["snapshots"][0]
    with pytest.raises(ValueError, match="num_rounds"):
        _runtime().run(ROUNDS + 2, resume_from=snap)
    with pytest.raises(ValueError, match="discipline"):
        sess = _session()
        PSRuntime(sess.spec, data=sess.data, timing=TIMING,
                  discipline="locked").run(ROUNDS, resume_from=snap)
    with pytest.raises(ValueError, match="cadence"):
        _runtime().run(ROUNDS, resume_from=snap, checkpoint_every=3)


def test_snapshot_format_validation(tmp_path):
    """A checkpoint that is not a runtime snapshot is refused by
    format tag."""
    save(str(tmp_path / "notsnap"), {"z": np.zeros(3)}, step=1)
    with pytest.raises(ValueError, match="format"):
        load_snapshot(str(tmp_path / "notsnap"))


# ---------------------------------------------------------------------------
# checkpoint file layer: atomicity + manifest cross-validation
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_with_extra(tmp_path):
    path = str(tmp_path / "ck")
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones(4)}}
    save(path, tree, step=7, extra={"clock": 1.25, "rng": {"s": [1, 2]}})
    back = restore(path, tree)
    np.testing.assert_array_equal(np.asarray(back["a"]),
                                  np.asarray(tree["a"]))
    assert load_extra(path) == {"clock": 1.25, "rng": {"s": [1, 2]}}
    flat = load_arrays(path)
    assert set(flat) == {"a", "b/c"}


def test_checkpoint_torn_halves_detected(tmp_path):
    """Mixed-up npz/manifest halves fail naming the file and leaf."""
    p1, p2 = str(tmp_path / "one"), str(tmp_path / "two")
    save(p1, {"a": np.zeros(3)})
    save(p2, {"b": np.zeros(3)})
    os.replace(p2 + ".npz", p1 + ".npz")       # mix the halves
    with pytest.raises(ValueError, match="'a'.*torn or mixed-up"):
        load_arrays(p1)
    save(p1, {"a": np.zeros(3)})
    save(p2, {"a": np.zeros(5)})
    os.replace(p2 + ".npz", p1 + ".npz")       # right key, wrong shape
    with pytest.raises(ValueError, match="shape"):
        load_arrays(p1)


def test_checkpoint_missing_payload(tmp_path):
    path = str(tmp_path / "ck")
    save(path, {"a": np.zeros(3)})
    os.unlink(path + ".npz")
    with pytest.raises(FileNotFoundError, match="torn checkpoint"):
        load_arrays(path)


def test_checkpoint_corrupt_manifest(tmp_path):
    path = str(tmp_path / "ck")
    save(path, {"a": np.zeros(3)})
    with open(path + ".json", "w") as f:
        f.write("{not json")
    with pytest.raises(ValueError, match="corrupt JSON"):
        load_arrays(path)


def test_checkpoint_atomic_no_tmp_residue(tmp_path):
    """Atomic writes leave no temp files behind, and re-saving over an
    existing checkpoint replaces it in one step."""
    path = str(tmp_path / "ck")
    save(path, {"a": np.zeros(3)})
    save(path, {"a": np.ones(3)})
    files = sorted(os.listdir(tmp_path))
    assert files == ["ck.json", "ck.npz"]
    np.testing.assert_array_equal(load_arrays(path)["a"], np.ones(3))


# ---------------------------------------------------------------------------
# FaultPlan JSON diagnostics: file + event index in every error
# ---------------------------------------------------------------------------

def test_fault_plan_from_json_actionable_errors():
    with pytest.raises(ValueError, match="corrupt JSON"):
        FaultPlan.from_json("{nope")
    with pytest.raises(ValueError, match="event 1"):
        FaultPlan.from_json(json.dumps(
            {"events": [{"kind": "crash", "at": 1.0, "worker": 0,
                         "duration": 2.0},
                        {"kind": "wibble", "at": 1.0}]}))
    with pytest.raises(ValueError, match="event 0"):
        FaultPlan.from_json(json.dumps(
            {"events": [{"kind": "server_crash", "at": 1.0}]}))


def test_fault_plan_load_names_file(tmp_path):
    p = tmp_path / "plan.json"
    p.write_text(json.dumps({"events": [{"kind": "server_crash",
                                         "at": 2.0, "block": 1}]}))
    with pytest.raises(ValueError) as ei:
        FaultPlan.load(str(p))
    assert "plan.json" in str(ei.value)
    assert "event 0" in str(ei.value)
    p.write_text(json.dumps(
        {"events": [{"kind": "server_crash", "at": 2.0, "block": 1,
                     "duration": 3.0}]}))
    assert FaultPlan.load(str(p)).has_server_crash


def test_server_crash_event_validation():
    with pytest.raises(ValueError, match="block id"):
        FaultPlan.of(FaultPlan.server_crash(None, at=1.0, down=1.0))
    with pytest.raises(ValueError, match="duration"):
        FaultPlan.of(FaultPlan.server_crash(0, at=1.0, down=0.0))
    plan = FaultPlan.of(FaultPlan.server_crash(2, at=1.0, down=1.0))
    with pytest.raises(ValueError, match="outside"):
        plan.validate(num_workers=N, num_blocks=2)
    # JSON round-trip keeps the crash
    assert FaultPlan.from_json(plan.to_json()).has_server_crash
