"""Unit + property tests for proximal operators."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # collection degrades to skip without the test extra
from hypothesis import given, settings, strategies as st

from repro.core.prox import (make_prox, prox_box, prox_group_lasso, prox_l1,
                             prox_l2, soft_threshold)

finite_floats = st.floats(-1e3, 1e3, allow_nan=False, allow_infinity=False,
                          width=32)


def test_soft_threshold_values():
    v = jnp.array([-2.0, -0.5, 0.0, 0.5, 2.0])
    out = soft_threshold(v, 1.0)
    np.testing.assert_allclose(out, [-1.0, 0.0, 0.0, 0.0, 1.0])


def test_prox_l1_matches_argmin():
    # brute-force check: prox solves argmin lam|u| + mu/2 (v-u)^2
    rng = np.random.RandomState(0)
    v = rng.randn(16).astype(np.float32)
    lam, mu = 0.3, 2.0
    u = np.asarray(prox_l1(jnp.asarray(v), lam, mu))
    grid = np.linspace(-3, 3, 20001)
    for i in range(16):
        obj = lam * np.abs(grid) + mu / 2 * (v[i] - grid) ** 2
        assert abs(grid[obj.argmin()] - u[i]) < 1e-3


def test_prox_l2_shrinks():
    v = jnp.ones(4) * 2.0
    out = prox_l2(v, lam=1.0, mu=1.0)
    np.testing.assert_allclose(out, 1.0)


def test_group_lasso_zeroes_small_groups():
    v = jnp.array([0.1, 0.1, 5.0, 5.0])
    out = prox_group_lasso(v, lam=1.0, mu=1.0, group_size=2)
    np.testing.assert_allclose(out[:2], 0.0)
    assert float(jnp.linalg.norm(out[2:])) > 0


@given(st.lists(finite_floats, min_size=1, max_size=64),
       st.floats(0.0, 10.0), st.floats(0.1, 10.0))
@settings(max_examples=50, deadline=None)
def test_prox_l1_nonexpansive_and_shrinking(vals, lam, mu):
    v = jnp.asarray(vals, jnp.float32)
    u = prox_l1(v, lam, mu)
    # shrinkage: |u| <= |v| elementwise; sign preserved
    assert bool(jnp.all(jnp.abs(u) <= jnp.abs(v) + 1e-6))
    assert bool(jnp.all(u * v >= -1e-6))


@given(st.lists(finite_floats, min_size=1, max_size=64),
       st.floats(0.01, 100.0))
@settings(max_examples=50, deadline=None)
def test_box_bounds(vals, clip):
    v = jnp.asarray(vals, jnp.float32)
    u = prox_box(v, clip)
    assert bool(jnp.all(jnp.abs(u) <= clip + 1e-6))


@given(st.lists(finite_floats, min_size=2, max_size=32),
       st.lists(finite_floats, min_size=2, max_size=32),
       st.floats(0.0, 5.0), st.floats(0.5, 5.0))
@settings(max_examples=40, deadline=None)
def test_prox_firm_nonexpansiveness(a, b, lam, mu):
    """||prox(x)-prox(y)|| <= ||x-y|| — used in the Thm 1 proof (eq. 47)."""
    n = min(len(a), len(b))
    x = jnp.asarray(a[:n], jnp.float32)
    y = jnp.asarray(b[:n], jnp.float32)
    reg = make_prox(l1_coef=lam, clip=50.0)
    d_out = float(jnp.linalg.norm(reg.prox(x, mu) - reg.prox(y, mu)))
    d_in = float(jnp.linalg.norm(x - y))
    assert d_out <= d_in + 1e-4


def test_regularizer_value():
    reg = make_prox(l1_coef=0.5, clip=10.0, l2_coef=2.0)
    z = jnp.array([1.0, -2.0])
    expected = 0.5 * 3.0 + 0.5 * 2.0 * 5.0
    np.testing.assert_allclose(float(reg.value(z)), expected, rtol=1e-6)
