"""Trainer integration tests: losses decrease, consensus forms,
checkpoints roundtrip, ADMM == manual math on a tiny model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore, save
from repro.configs import get_smoke
from repro.configs.base import ADMMConfig
from repro.data import TokenPipeline
from repro.models import build_model
from repro.optim import adamw, apply_updates, sgd
from repro.training import ADMMTrainer, SGDTrainer


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=33,
                         global_batch=8, seed=0, branch=2)
    return cfg, model, params, pipe


def test_admm_loss_decreases(setup):
    cfg, model, params, pipe = setup
    acfg = ADMMConfig(rho=5.0, gamma=0.01, max_delay=0, block_fraction=1.0,
                      num_blocks=4)
    tr = ADMMTrainer(loss_fn=model.loss, admm=acfg, num_workers=4)
    state = tr.init(params)
    step = jax.jit(tr.train_step)
    losses = []
    for i in range(30):
        state, info = step(state, pipe.batch(i, num_workers=4))
        losses.append(float(info["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_admm_async_loss_decreases(setup):
    cfg, model, params, pipe = setup
    acfg = ADMMConfig(rho=5.0, gamma=0.1, max_delay=2, block_fraction=0.5,
                      num_blocks=4, seed=3)
    tr = ADMMTrainer(loss_fn=model.loss, admm=acfg, num_workers=4)
    state = tr.init(params)
    step = jax.jit(tr.train_step)
    losses = []
    for i in range(40):
        state, info = step(state, pipe.batch(i, num_workers=4))
        losses.append(float(info["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.05, losses


def test_sgd_baseline_decreases(setup):
    cfg, model, params, pipe = setup
    tr = SGDTrainer(loss_fn=model.loss, optimizer=adamw(3e-3))
    state = tr.init(params)
    step = jax.jit(tr.train_step)
    losses = []
    for i in range(30):
        state, info = step(state, pipe.batch(i))
        losses.append(float(info["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


def test_consensus_residual_decreases(setup):
    cfg, model, params, pipe = setup
    acfg = ADMMConfig(rho=5.0, gamma=0.01, max_delay=1, block_fraction=1.0,
                      num_blocks=4)
    tr = ADMMTrainer(loss_fn=model.loss, admm=acfg, num_workers=4)
    state = tr.init(params)
    step = jax.jit(tr.train_step)
    state, _ = step(state, pipe.batch(0, num_workers=4))
    early = float(tr.consensus_residual(state))
    for i in range(1, 25):
        state, _ = step(state, pipe.batch(i, num_workers=4))
    late = float(tr.consensus_residual(state))
    assert np.isfinite(early) and np.isfinite(late)
    assert late < max(early, 1.0)   # dispersion does not blow up


def test_admm_trainer_matches_flat_math():
    """The pytree trainer must agree with hand-rolled ADMM on a convex
    quadratic (single block, sync): f_i(p) = ||p - c_i||^2 / 2."""
    centers = jnp.array([[1.0, 2.0], [3.0, -1.0]])

    def loss_fn(p, batch):
        return 0.5 * jnp.sum(jnp.square(p["w"] - batch))

    acfg = ADMMConfig(rho=4.0, gamma=0.0, max_delay=0, block_fraction=1.0,
                      num_blocks=1)
    tr = ADMMTrainer(loss_fn=loss_fn, admm=acfg, num_workers=2)
    params = {"w": jnp.zeros(2)}
    state = tr.init(params)
    step = jax.jit(tr.train_step)
    z = jnp.zeros(2)
    y = jnp.zeros((2, 2))
    for i in range(20):
        state, _ = step(state, centers)
        g = z[None] - centers            # grad at z per worker
        x = z[None] - (g + y) / 4.0
        y = y + 4.0 * (x - z[None])
        w = 4.0 * x + y
        z = w.sum(0) / 8.0
    np.testing.assert_allclose(np.asarray(state.params["w"]),
                               np.asarray(z), rtol=1e-5, atol=1e-6)
    # consensus optimum of sum ||p-c_i||^2/2 is the centroid
    np.testing.assert_allclose(np.asarray(state.params["w"]),
                               np.asarray(centers.mean(0)), atol=0.05)


def test_checkpoint_roundtrip(tmp_path, setup):
    cfg, model, params, pipe = setup
    path = str(tmp_path / "ckpt")
    save(path, params, step=7)
    restored = restore(path, jax.tree.map(lambda a: a, params))
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    from repro.checkpoint import load_step
    assert load_step(path) == 7


def test_optimizers_quadratic():
    def loss(p):
        return jnp.sum(jnp.square(p["x"] - 3.0))
    for opt in (sgd(0.05, momentum=0.8), adamw(0.3)):
        params = {"x": jnp.zeros(4)}
        state = opt.init(params)
        for _ in range(120):
            g = jax.grad(loss)(params)
            upd, state = opt.update(g, state, params)
            params = apply_updates(params, upd)
        assert float(loss(params)) < 1e-2


def test_block_step_honors_minibatch(setup):
    """The statically-specialized Gauss-Seidel path subsamples worker
    batches when ADMMConfig.minibatch is set (like the generic epoch),
    and stays deterministic per seed."""
    cfg, model, params, pipe = setup
    def make(minibatch):
        acfg = ADMMConfig(rho=5.0, gamma=0.01, max_delay=0,
                          block_fraction=1.0, num_blocks=4,
                          minibatch=minibatch)
        tr = ADMMTrainer(loss_fn=model.loss, admm=acfg, num_workers=4)
        state = tr.init(params)
        step = jax.jit(tr.train_step_block, static_argnums=2)
        out = []
        for i in range(3):
            state, info = step(state, pipe.batch(i, num_workers=4), i % 4)
            out.append(float(info["loss"]))
        return out
    full, mini = make(None), make(0.5)
    assert all(np.isfinite(mini))
    assert mini != full                 # subsampling actually engaged
    assert mini == make(0.5)            # seeded draw, reproducible
