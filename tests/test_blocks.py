"""Block partitioning tests (flat + pytree modes)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # collection degrades to skip without the test extra
from hypothesis import given, settings, strategies as st

from repro.core.blocks import (edge_set_from_support, make_flat_blocks,
                               make_tree_blocks)


@given(st.integers(1, 300), st.integers(1, 16))
@settings(max_examples=50, deadline=None)
def test_flat_roundtrip(dim, m):
    blocks = make_flat_blocks(dim, m)
    v = jnp.arange(dim, dtype=jnp.float32)
    b = blocks.to_blocks(v)
    assert b.shape == (m, blocks.block_dim)
    np.testing.assert_array_equal(blocks.from_blocks(b), v)


def test_flat_batched_roundtrip():
    blocks = make_flat_blocks(10, 4)
    v = jnp.arange(30, dtype=jnp.float32).reshape(3, 10)
    np.testing.assert_array_equal(blocks.from_blocks(blocks.to_blocks(v)), v)


def test_edge_set_from_support():
    blocks = make_flat_blocks(8, 4)          # block_dim 2
    support = np.zeros((2, 8), bool)
    support[0, 0] = True                     # worker 0 -> block 0
    support[1, 5] = True                     # worker 1 -> block 2
    E = edge_set_from_support(support, blocks)
    assert E.shape == (2, 4)
    assert E[0].tolist() == [True, False, False, False]
    assert E[1].tolist() == [False, False, True, False]


def test_tree_blocks_cover_and_balance():
    tree = {"a": jnp.zeros((100, 100)), "b": jnp.zeros((100, 100)),
            "c": jnp.zeros((10,)), "d": {"e": jnp.zeros((100, 100))}}
    tb = make_tree_blocks(tree, 3)
    sizes = tb.block_sizes(tree)
    assert sizes.sum() == 30010
    # LPT: the three big leaves land on distinct blocks
    assert (sizes >= 10000).all()


def test_tree_mask():
    tree = {"a": jnp.zeros((4,)), "b": jnp.zeros((4,))}
    tb = make_tree_blocks(tree, 2)
    sel = jnp.array([1.0, 0.0])
    mask = tb.mask_tree(sel)
    vals = sorted(float(v) for v in jax.tree.leaves(mask))
    assert vals == [0.0, 1.0]   # one leaf per block


@given(st.integers(1, 8), st.integers(1, 6))
@settings(max_examples=30, deadline=None)
def test_tree_blocks_assignment_valid(n_leaves, m):
    tree = {f"l{i}": jnp.zeros((i + 1, 3)) for i in range(n_leaves)}
    tb = make_tree_blocks(tree, m)
    assert len(tb.leaf_block_ids) == n_leaves
    assert all(0 <= b < m for b in tb.leaf_block_ids)
