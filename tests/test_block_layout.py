"""BlockLayout (core/blocks.py): the canonical packed block layout that
lowers pytree consensus onto the flat (M, dblk) block table.

Pins the two properties every layer above relies on:

* **bitwise round-trip** — ``to_blocks`` -> ``from_blocks`` reproduces
  every leaf exactly, for ragged/odd-shaped pytrees, mixed float
  dtypes (f32/bf16/f16 all embed losslessly in the f32 compute dtype),
  leading batch axes (worker N, ring depth), and blocks left empty by
  the assignment;
* **inert padding** — pad lanes are zero after packing and stay
  exactly zero through real epochs (worker update, w reduction, prox),
  so they never leak into w_sum, the prox step, or gradient norms.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ConsensusSession
from repro.configs.base import ADMMConfig
from repro.core.blocks import (LANE, BlockLayout, make_block_layout,
                               make_flat_blocks, make_tree_blocks,
                               round_up_to_lane)


def _ragged_tree():
    """Odd shapes on purpose: scalars, vectors, matrices, 3-d leaves."""
    r = np.random.RandomState(0)
    return {
        "bias": jnp.asarray(r.randn(), jnp.float32),
        "w1": jnp.asarray(r.randn(7), jnp.float32),
        "w2": jnp.asarray(r.randn(3, 5), jnp.float32),
        "deep": {"w3": jnp.asarray(r.randn(2, 2, 3), jnp.float32),
                 "w4": jnp.asarray(r.randn(11), jnp.float32)},
    }


def test_roundtrip_ragged_tree():
    tree = _ragged_tree()
    for m in (1, 2, 3, 7):                     # 7 > num leaves: empty blocks
        layout = make_block_layout(tree, num_blocks=m)
        packed = layout.to_blocks(tree)
        assert packed.shape == (m, layout.block_dim)
        assert max(layout.block_sizes) <= layout.block_dim
        back = layout.from_blocks(packed)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # padding is zero and exactly where the mask says
        mask = layout.padding_mask()
        np.testing.assert_array_equal(np.asarray(packed)[~mask], 0.0)


def test_roundtrip_leading_batch_axes():
    """Worker bundles (N, ...) and ring buffers (D+1, ...) pack through
    the same layout — leading axes pass straight through."""
    tree = _ragged_tree()
    layout = make_block_layout(tree, num_blocks=3)
    for lead in ((4,), (2, 4)):
        batched = jax.tree.map(
            lambda a: jnp.broadcast_to(a, lead + a.shape).copy(), tree)
        packed = layout.to_blocks(batched)
        assert packed.shape == lead + (3, layout.block_dim)
        back = layout.from_blocks(packed)
        for a, b in zip(jax.tree.leaves(batched), jax.tree.leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_roundtrip_mixed_dtypes_bitwise():
    """bf16/f16 leaves embed losslessly in the f32 compute dtype — the
    round-trip is bit-exact, not merely close."""
    r = np.random.RandomState(1)
    tree = {
        "f32": jnp.asarray(r.randn(9), jnp.float32),
        "bf16": jnp.asarray(r.randn(4, 3), jnp.float32).astype(jnp.bfloat16),
        "f16": jnp.asarray(r.randn(5), jnp.float32).astype(jnp.float16),
    }
    layout = make_block_layout(tree, num_blocks=2)
    back = layout.from_blocks(layout.to_blocks(tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a).view(np.uint8), np.asarray(b).view(np.uint8))


def test_layout_validates_structure():
    tree = _ragged_tree()
    layout = make_block_layout(tree, num_blocks=2)
    with pytest.raises(ValueError, match="structure"):
        layout.to_blocks({"other": jnp.zeros(3)})
    with pytest.raises(ValueError, match="shape"):
        bad = dict(tree, w1=jnp.zeros((8,)))   # w1 is (7,) in the layout
        layout.to_blocks(bad)
    with pytest.raises(ValueError, match="empty"):
        make_block_layout({}, num_blocks=2)
    blocks = make_tree_blocks(tree, 2)
    with pytest.raises(ValueError, match="structure"):
        make_block_layout({"other": jnp.zeros(3)}, blocks)


def test_block_id_contract():
    """Block ids follow TreeBlocks' assignment and rows pack the
    block's leaves in leaf order at the recorded offsets."""
    tree = {"a": jnp.arange(3.0), "b": jnp.arange(3.0, 7.0),
            "c": jnp.arange(7.0, 9.0)}
    blocks = make_tree_blocks(tree, 2)
    layout = make_block_layout(tree, blocks)
    assert layout.block_ids == blocks.leaf_block_ids
    assert isinstance(layout, BlockLayout)
    packed = np.asarray(layout.to_blocks(tree))
    leaves = jax.tree.leaves(tree)
    for k, leaf in enumerate(leaves):
        j, off = layout.block_ids[k], layout.leaf_offsets[k]
        np.testing.assert_array_equal(packed[j, off:off + leaf.size],
                                      np.asarray(leaf).ravel())


def test_block_dim_is_lane_rounded():
    """Lane alignment is a property of the LAYOUT: block_dim is the max
    block payload rounded up to the 128-lane boundary, never the raw
    payload — so every kernel below sees vreg-aligned rows without a
    per-call pad copy."""
    tree = _ragged_tree()
    for m in (1, 2, 3):
        layout = make_block_layout(tree, num_blocks=m)
        assert layout.block_dim % LANE == 0
        assert layout.block_dim == round_up_to_lane(max(layout.block_sizes))
    # flat layouts too, including dims already on the boundary
    for dim, m in ((256, 2), (315, 3), (129, 1)):
        fb = make_flat_blocks(dim, m)
        assert fb.block_dim % LANE == 0
        assert fb.block_dim == round_up_to_lane(fb.used_dim)
        assert fb.used_dim * m >= dim


def test_roundtrip_at_lane_boundary_bitwise():
    """Leaf sizes straddling the 128 boundary (127/128/129) round-trip
    bit-exactly in every stored dtype — the rounded row never bleeds
    pad lanes into payload."""
    r = np.random.RandomState(5)
    for size in (127, 128, 129):
        tree = {
            "f32": jnp.asarray(r.randn(size), jnp.float32),
            "bf16": jnp.asarray(r.randn(size), jnp.float32).astype(jnp.bfloat16),
            "f16": jnp.asarray(r.randn(size), jnp.float32).astype(jnp.float16),
        }
        layout = make_block_layout(tree, num_blocks=3)
        packed = layout.to_blocks(tree)
        assert packed.shape[-1] % LANE == 0
        back = layout.from_blocks(packed)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(
                np.asarray(a).view(np.uint8), np.asarray(b).view(np.uint8))
        np.testing.assert_array_equal(
            np.asarray(packed)[~layout.padding_mask()], 0.0)


def test_padding_inert_through_prox_and_edge_mask():
    """Zero pad lanes stay exactly zero through the fused server op
    (edge-masked w reduction + prox) and the plain prox: soft-threshold
    of 0 with w_sum 0 is 0, so padding can never contaminate z."""
    from repro.kernels import ops

    N, M = 3, 2
    tree = {"a": jnp.ones((100,), jnp.float32),
            "b": jnp.ones((130,), jnp.float32)}
    layout = make_block_layout(tree, num_blocks=M)
    pad = ~layout.padding_mask()
    assert pad.any()
    r = np.random.RandomState(7)
    z = layout.to_blocks(jax.tree.map(
        lambda a: jnp.asarray(r.randn(*a.shape), a.dtype), tree))
    w_cache = jnp.stack([z * (k + 1) for k in range(N)])
    edge = jnp.asarray(r.rand(N, M) < 0.7)
    rho_sum = jnp.full((M,), 2.0, jnp.float32)
    z_new = ops.server_prox_update(z, w_cache, edge, rho_sum,
                                   gamma=0.1, l1=1e-3, clip=0.5)
    np.testing.assert_array_equal(np.asarray(z_new)[pad], 0.0)
    assert float(np.max(np.abs(np.asarray(z_new)))) > 0.0
    z_prox = ops.prox_consensus(z, z * 0.5, rho_sum, gamma=0.1, l1=1e-3,
                                clip=0.5)
    np.testing.assert_array_equal(np.asarray(z_prox)[pad], 0.0)


def test_sharded_divisibility_of_lane_rounded_layout():
    """Model-axis sharding splits the BLOCK axis, never the lane axis:
    the per-shard state keeps full lane-aligned rows, and indivisible
    block counts still fail eagerly with the num_blocks message."""
    from jax.sharding import AbstractMesh

    mesh = AbstractMesh((("data", 4), ("model", 2)))
    params = {"w": jnp.zeros((300,), jnp.float32)}
    cfg = ADMMConfig(rho=1.0, gamma=0.1, num_blocks=4, seed=0)

    def loss(p, c):
        return 0.5 * jnp.sum(jnp.square(p["w"] - c))

    sess = ConsensusSession.pytree(loss, params, cfg, num_workers=4,
                                   mesh=mesh)
    from repro.core.sharded import consensus_state_specs
    state = jax.eval_shape(sess.init)
    specs = consensus_state_specs(sess.spec, state)
    yspec = specs.y
    assert yspec[1] == "model" and yspec[2] is None   # blocks split, lanes whole
    assert state.y.shape[2] % LANE == 0
    assert state.y.shape[1] % 2 == 0                  # M divides the model axis
    with pytest.raises(ValueError, match="num_blocks"):
        ConsensusSession.pytree(loss, params,
                                ADMMConfig(rho=1.0, gamma=0.1, num_blocks=3,
                                           seed=0),
                                num_workers=4, mesh=mesh)


def _ragged_session(max_delay=1, clip=0.8):
    """A pytree session whose LPT assignment leaves real padding in
    some rows (block sizes 13, 12, 4 -> dblk 13)."""
    params = {"w2": jnp.zeros((3, 4), jnp.float32),    # 12 -> own block
              "w1": jnp.zeros((13,), jnp.float32),     # 13 -> own block
              "w0": jnp.zeros((4,), jnp.float32)}      # 4  -> padded block
    cfg = ADMMConfig(rho=2.0, gamma=0.1, max_delay=max_delay,
                     block_fraction=0.5, num_blocks=3, l1_coef=1e-3,
                     clip=clip, seed=0)

    def loss(p, c):
        z = jnp.concatenate([p["w0"].ravel(), p["w1"].ravel(),
                             p["w2"].ravel()])
        return 0.5 * jnp.sum(jnp.square(z - c))
    return ConsensusSession.pytree(loss, params, cfg, num_workers=3)


def test_padding_never_leaks_into_epoch():
    """Pad lanes stay exactly 0 through real epochs: z ring, duals,
    w cache, and the edge-masked w_sum reduction all keep zero padding,
    so the prox never sees (or emits) garbage lanes."""
    sess = _ragged_session()
    layout = sess.spec.space.layout
    pad = ~layout.padding_mask()
    assert pad.any()                          # the case really is ragged
    centers = jnp.asarray(
        np.random.RandomState(3).randn(3, sum(layout.block_sizes)),
        jnp.float32)
    state = sess.init()
    step = sess.step_fn()
    for _ in range(6):
        state, _ = step(state, centers)
        for name, buf in (("z_hist", state.z_hist), ("y", state.y),
                          ("w_cache", state.w_cache)):
            vals = np.asarray(buf)[..., pad]
            np.testing.assert_array_equal(
                vals, 0.0, err_msg=f"padding leaked into {name}")
        w_sum = np.asarray(sess.spec.space.reduce_workers(
            state.w_cache, sess.spec.edge))
        np.testing.assert_array_equal(w_sum[pad], 0.0)
    assert float(np.max(np.abs(np.asarray(state.z_hist)))) > 0.0


try:
    import hypothesis  # noqa: F401
    from hypothesis import given, settings, strategies as st

    _dtypes = st.sampled_from(["float32", "bfloat16", "float16"])
    _shapes = st.lists(st.integers(1, 4), min_size=0, max_size=3)

    @given(leaves=st.lists(st.tuples(_shapes, _dtypes),
                           min_size=1, max_size=6),
           m=st.integers(1, 5), lead=st.integers(0, 2),
           data=st.data())
    @settings(max_examples=40, deadline=None)
    def test_roundtrip_property(leaves, m, lead, data):
        """pack -> unpack is a bitwise round-trip for arbitrary ragged
        pytrees, block counts, and leading batch axes."""
        r = np.random.RandomState(data.draw(st.integers(0, 2**31 - 1)))
        prefix = tuple(data.draw(st.integers(1, 3)) for _ in range(lead))
        tree = {}
        for k, (shape, dt) in enumerate(leaves):
            vals = r.randn(*(prefix + tuple(shape))).astype(np.float32)
            tree[f"l{k}"] = jnp.asarray(vals).astype(dt)
        template = {k: jax.ShapeDtypeStruct(v.shape[lead:], v.dtype)
                    for k, v in tree.items()}
        layout = make_block_layout(template, num_blocks=m)
        assert layout.block_dim % LANE == 0
        packed = layout.to_blocks(tree)
        assert packed.shape == prefix + (m, layout.block_dim)
        back = layout.from_blocks(packed)
        for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(
                np.asarray(a).view(np.uint8), np.asarray(b).view(np.uint8))
        # padding is exactly zero at every batch index
        mask = layout.padding_mask()
        np.testing.assert_array_equal(np.asarray(packed)[..., ~mask], 0.0)
except ImportError:                     # pragma: no cover - optional extra
    pass
