"""Convergence tests for AsyBADMM — the paper's Theorem 1 claims.

Validated against the paper:
  * objective decreases and stabilizes (Fig. 2 behaviour);
  * asynchronous runs (bounded delays 1..4) reach the same objective
    neighborhood as the synchronous run (the paper's headline claim);
  * KKT conditions (20a-c) approximately hold at the limit;
  * the y = -grad f identity (appendix eq. 25);
  * stationarity metric P decays like O(1/t) in min-so-far terms (21).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ADMMConfig
from repro.core import (init_state, kkt_violations, make_problem,
                        make_step_fn, run, stationarity)
from repro.data import make_sparse_logreg


def _logreg_problem(num_blocks=8, l1=1e-3, seed=0):
    data = make_sparse_logreg(num_workers=4, samples_per_worker=48, dim=64,
                              density=0.25, seed=seed)

    def loss_fn(z, d):
        X, y = d
        return jnp.mean(jnp.log1p(jnp.exp(-y * (X @ z))))

    prob = make_problem(loss_fn, (jnp.asarray(data.X), jnp.asarray(data.y)),
                        dim=64, num_blocks=num_blocks, support=data.support,
                        l1_coef=l1, clip=1e4)
    return prob


def test_sync_objective_decreases():
    prob = _logreg_problem()
    cfg = ADMMConfig(rho=2.0, gamma=0.0, max_delay=0, block_fraction=1.0,
                     num_blocks=8)
    _, hist = run(prob, cfg, 200, eval_every=50)
    objs = [h["objective"] for h in hist]
    assert objs[-1] < objs[0]
    assert objs[-1] < 3.0


@pytest.mark.parametrize("delay", [1, 2, 4])
def test_async_matches_sync_neighborhood(delay):
    """Paper Fig. 2: asynchrony with tolerable delay still converges."""
    prob = _logreg_problem()
    sync = ADMMConfig(rho=2.0, gamma=0.0, max_delay=0, block_fraction=1.0,
                      num_blocks=8)
    _, hist_s = run(prob, sync, 300, eval_every=300)
    async_cfg = ADMMConfig(rho=2.0, gamma=0.1, max_delay=delay,
                           block_fraction=0.5, num_blocks=8, seed=1)
    _, hist_a = run(prob, async_cfg, 900, eval_every=900)
    obj_s = hist_s[-1]["objective"]
    obj_a = hist_a[-1]["objective"]
    assert obj_a < obj_s * 1.15 + 0.1, (obj_a, obj_s)


def test_kkt_at_limit():
    prob = _logreg_problem()
    cfg = ADMMConfig(rho=2.0, gamma=0.0, max_delay=0, block_fraction=1.0,
                     num_blocks=8)
    state, _ = run(prob, cfg, 1200)
    k = kkt_violations(prob, state, cfg.rho)
    assert float(k["kkt_grad"]) < 1e-3          # (20a) grad f + y = 0
    assert float(k["kkt_consensus"]) < 1e-2     # (20c) x = z
    assert float(k["kkt_subgrad"]) < 2e-2       # (20b) sum y in subdiff h


def test_dual_equals_negative_gradient():
    """Appendix eq. 25: after updating (i,j), y_ij = -grad_j f_i(z~)."""
    prob = _logreg_problem()
    cfg = ADMMConfig(rho=2.0, gamma=0.0, max_delay=0, block_fraction=1.0,
                     num_blocks=8)
    state = init_state(prob, cfg)
    step = make_step_fn(prob, cfg)
    state = step(state)
    # recompute gradients at the z~ the step used (delay 0 -> z_hist[0]
    # of the *previous* state == initial z = 0)
    z0 = jnp.zeros(prob.dim)

    def g(d):
        return jax.grad(prob.loss_fn)(z0, d)
    grads = jax.vmap(g)(prob.data)
    gb = prob.blocks.to_blocks(grads)
    edge = prob.edge[..., None]
    np.testing.assert_allclose(
        np.where(edge, state.y, 0), np.where(edge, -gb, 0), atol=1e-5)


def test_stationarity_decays():
    """Theorem 1.3: T(eps) <= C/eps  =>  min_t<=T P ~ O(1/T)."""
    prob = _logreg_problem()
    cfg = ADMMConfig(rho=2.0, gamma=0.1, max_delay=1, block_fraction=0.5,
                     num_blocks=8)
    state = init_state(prob, cfg)
    step = make_step_fn(prob, cfg)
    ps = []
    for t in range(400):
        state = step(state)
        if (t + 1) % 40 == 0:
            ps.append(float(stationarity(prob, state, cfg.rho)["P"]))
    min_so_far = np.minimum.accumulate(ps)
    assert min_so_far[-1] < min_so_far[0]
    assert min_so_far[-1] < 0.5                 # reaches small stationarity


def test_full_vector_baseline_equivalence():
    """num_blocks=1 degenerates to full-vector consensus ADMM (the
    Zhang-Kwok-style baseline): still converges on a dense problem."""
    prob = _logreg_problem(num_blocks=1)
    cfg = ADMMConfig(rho=2.0, gamma=0.1, max_delay=2, block_fraction=1.0,
                     num_blocks=1)
    _, hist = run(prob, cfg, 300, eval_every=100)
    objs = [h["objective"] for h in hist]
    assert objs[-1] < objs[0]


def test_box_constraint_respected():
    prob = _logreg_problem(l1=0.0)
    prob = jax.tree_util.tree_map(lambda x: x, prob)  # no-op copy
    from repro.core import make_prox
    object.__setattr__(prob, "reg", make_prox(l1_coef=0.0, clip=0.05))
    cfg = ADMMConfig(rho=2.0, gamma=0.0, max_delay=0, block_fraction=1.0,
                     num_blocks=8)
    state, _ = run(prob, cfg, 50)
    z = prob.blocks.from_blocks(state.z_blocks)
    assert float(jnp.max(jnp.abs(z))) <= 0.05 + 1e-6


def test_minibatch_workers_converge():
    """Incremental/stochastic workers (Hong 2014): subsampling half of
    each worker's data per epoch still drives the objective into the
    full-batch neighborhood, and the minibatch draw is seeded
    (bit-reproducible across runs)."""
    prob = _logreg_problem()
    full = ADMMConfig(rho=2.0, gamma=0.1, max_delay=1, block_fraction=0.5,
                      num_blocks=8, seed=1)
    mini = ADMMConfig(rho=2.0, gamma=0.1, max_delay=1, block_fraction=0.5,
                      num_blocks=8, seed=1, minibatch=0.5)
    _, hist_f = run(prob, full, 400, eval_every=400)
    states = []
    for _ in range(2):
        state, hist_m = run(prob, mini, 400, eval_every=400)
        states.append(prob.blocks.from_blocks(state.z_blocks))
    obj_f = hist_f[-1]["objective"]
    obj_m = hist_m[-1]["objective"]
    assert obj_m < obj_f * 1.2 + 0.1, (obj_m, obj_f)
    np.testing.assert_array_equal(np.asarray(states[0]),
                                  np.asarray(states[1]))


def test_minibatch_fraction_validated():
    prob = _logreg_problem()
    with pytest.raises(ValueError):
        init_state(prob, ADMMConfig(num_blocks=8, minibatch=0.0))
    with pytest.raises(ValueError):
        init_state(prob, ADMMConfig(num_blocks=8, minibatch=1.5))
    # 1.0 is the full-batch no-op: identical trajectory to minibatch=None
    base = ADMMConfig(rho=2.0, gamma=0.1, max_delay=1, num_blocks=8)
    one = ADMMConfig(rho=2.0, gamma=0.1, max_delay=1, num_blocks=8,
                     minibatch=1.0)
    s_base, _ = run(prob, base, 20)
    s_one, _ = run(prob, one, 20)
    np.testing.assert_array_equal(np.asarray(s_base.z_hist[0]),
                                  np.asarray(s_one.z_hist[0]))
