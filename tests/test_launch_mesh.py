"""launch/mesh.py helpers — shape math, presets, and the eager
validation that replaced the silent ``devices // model`` reshape.

Helper functions only need ``axis_names`` / ``shape``, so they are
exercised against ``AbstractMesh`` (no forced host devices); the
device-count error paths are exercised against this container's real
single CPU device.
"""
import jax
import pytest
from jax.sharding import AbstractMesh

from repro.launch.mesh import (MESH_PRESETS, data_axes, make_production_mesh,
                               make_test_mesh, model_axis_size, num_workers,
                               resolve_mesh)


def _amesh(*shape_tuple):
    return AbstractMesh(tuple(shape_tuple))


def test_helpers_single_pod():
    m = _amesh(("data", 16), ("model", 16))
    assert data_axes(m) == ("data",)
    assert num_workers(m) == 16
    assert model_axis_size(m) == 16


def test_helpers_multi_pod():
    m = _amesh(("pod", 2), ("data", 16), ("model", 16))
    assert data_axes(m) == ("pod", "data")
    assert num_workers(m) == 32             # workers span pod x data
    assert model_axis_size(m) == 16


def test_helpers_no_model_axis():
    m = _amesh(("data", 8),)
    assert data_axes(m) == ("data",)
    assert num_workers(m) == 8
    assert model_axis_size(m) == 1          # missing axis = unsharded blocks


def test_test_mesh_shape():
    m = _amesh(("data", 4), ("model", 2))   # what make_test_mesh(8) builds
    assert num_workers(m) * model_axis_size(m) == 8


def test_make_test_mesh_rejects_non_divisible():
    with pytest.raises(ValueError, match="devices=6 does not divide"):
        make_test_mesh(6, model=4)
    with pytest.raises(ValueError, match="does not divide"):
        make_test_mesh(7)                   # default model=2
    with pytest.raises(ValueError, match="must be >= 1"):
        make_test_mesh(8, model=0)


def test_make_test_mesh_reports_missing_devices():
    """With too few host devices the error must name the XLA_FLAGS fix,
    not die in jax.make_mesh."""
    if jax.device_count() >= 512:
        pytest.skip("container already forces many host devices")
    with pytest.raises(RuntimeError, match="xla_force_host_platform"):
        make_test_mesh(512)


def test_make_production_mesh_reports_missing_devices():
    if jax.device_count() >= 256:
        pytest.skip("container already forces many host devices")
    with pytest.raises(RuntimeError, match="need 256 devices"):
        make_production_mesh()
    with pytest.raises(RuntimeError, match="need 512 devices"):
        make_production_mesh(multi_pod=True)


def test_resolve_mesh():
    assert resolve_mesh(None) is None
    assert resolve_mesh("none") is None
    m = _amesh(("data", 4), ("model", 2))
    assert resolve_mesh(m) is m             # pass-through for built meshes
    with pytest.raises(ValueError, match="unknown mesh"):
        resolve_mesh("v5e")
    assert set(MESH_PRESETS) == {"none", "test", "pod", "multipod"}
