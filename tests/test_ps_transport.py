"""Unreliable transport (repro.ps.transport): lossy-run replay parity,
zero-loss inertness, exactly-once commit folds, graceful pull-timeout
degradation, link_loss chaos, trace-load diagnostics, and the
divergence watchdogs.

The headline pins:

* a run under drop/dup/reorder + ack/retry/backoff records a
  ``DelayTrace`` that replays through the vectorized ``asybadmm_epoch``
  exactly like a reliable run — bitwise on pallas, fp32-ulp on jnp,
  1e-5 on the SPMD mesh (the effective committed schedule is what the
  staleness + participation matrices pin; delivery chaos only shifts
  WHEN messages land);
* with every reliability knob at zero the transport layer is INERT:
  trace and z trajectory are byte-identical to the pre-transport
  runtime (same rng draw sequences, no transport metrics/log);
* the commit gate folds each (worker, block, round) push exactly once
  under ANY loss schedule — retransmits and duplicates never
  double-fold (property-tested under hypothesis).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ConsensusSession
from repro.configs.base import ADMMConfig
from repro.core.blocks import TreeBlocks
from repro.core.space import (asybadmm_epoch, set_epoch_check_finite)
from repro.ps import (ConstantService, CostProfile, DelayTrace, FaultPlan,
                      LognormalService, NetworkModel, ParetoService,
                      Transport, as_network)

N, M, DBLK = 3, 4, 5
DIM = M * DBLK
ROUNDS = 6

_r = np.random.RandomState(7)
CENTERS = jnp.asarray(_r.randn(N, DIM).astype(np.float32))
EDGE = np.array([[1, 1, 0, 1],
                 [1, 0, 1, 0],
                 [1, 1, 1, 1]], bool)
RHO_SCALE = np.array([0.5, 1.0, 2.0], np.float32)

STRAGGLER = CostProfile(t_worker=ParetoService(1.0, alpha=1.2),
                        t_server_block=LognormalService(0.3, 0.4))
LOSSY = Transport(0.0, 0.0, drop_rate=0.1, dup_rate=0.05,
                  reorder_rate=0.2, ack_timeout=0.5)


def _cfg(**kw):
    return ADMMConfig(rho=2.0, gamma=0.1, max_delay=2, block_fraction=0.5,
                      num_blocks=M, block_selection="random", l1_coef=1e-3,
                      clip=0.8, seed=0, **kw)


def _flat_loss(z, c):
    return 0.5 * jnp.sum(jnp.square(z - c))


def _flat_session(backend="jnp", delay_model=None, cfg=None, mesh=None):
    return ConsensusSession.flat(
        _flat_loss, CENTERS, dim=DIM, cfg=cfg or _cfg(), edge=EDGE,
        rho_scale=RHO_SCALE, backend=backend, delay_model=delay_model,
        mesh=mesh)


def _tree_loss(p, c):
    z = jnp.concatenate([p[f"w{j}"] for j in range(M)])
    return 0.5 * jnp.sum(jnp.square(z - c))


def _tree_session(backend="jnp", delay_model=None):
    params = {f"w{j}": jnp.zeros((DBLK,), jnp.float32) for j in range(M)}
    tblocks = TreeBlocks(num_blocks=M, leaf_block_ids=tuple(range(M)),
                         treedef=jax.tree.structure(params))
    return ConsensusSession.pytree(
        _tree_loss, params, _cfg(), num_workers=N, blocks=tblocks,
        edge=EDGE, rho_scale=RHO_SCALE, backend=backend,
        delay_model=delay_model)


def _tree_vec(zt):
    return np.concatenate([np.asarray(zt[f"w{j}"]).ravel()
                           for j in range(M)])


def _assert_replay(res, sess2, data, to_vec, bitwise):
    state = sess2.init()
    step = sess2.step_fn()
    for t in range(res.num_rounds):
        state, _ = step(state, data)
        replay = to_vec(sess2.z(state))
        runtime = to_vec(res.z_versions[t + 1])
        if bitwise:
            np.testing.assert_array_equal(
                replay, runtime, err_msg=f"replay diverged at round {t}")
        else:
            np.testing.assert_allclose(
                replay, runtime, rtol=1e-5, atol=1e-6,
                err_msg=f"replay diverged at round {t}")


# ---------------------------------------------------------------------------
# lossy-run replay parity (the acceptance pin): flat + tree x jnp + pallas
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_flat_lossy_replay_parity(backend):
    sess = _flat_session(backend)
    res = sess.run_ps(ROUNDS, transport=LOSSY)
    t = res.metrics["transport"]
    assert t["drops"] > 0 and t["retransmits"] > 0
    assert res.trace.transport, "delivery decisions must be logged"
    sess2 = _flat_session(backend, delay_model=res.to_delay_model())
    _assert_replay(res, sess2, CENTERS,
                   lambda z: np.asarray(z).ravel(),
                   bitwise=backend == "pallas")


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_tree_lossy_replay_parity(backend):
    sess = _tree_session(backend)
    res = sess.run_ps(ROUNDS, transport=LOSSY, batches=lambda t: CENTERS)
    assert res.metrics["transport"]["drops"] > 0
    sess2 = _tree_session(backend, delay_model=res.to_delay_model())
    _assert_replay(res, sess2, CENTERS, _tree_vec,
                   bitwise=backend == "pallas")


def test_lossy_with_latency_and_straggler_replay_parity():
    """Loss composes with real latency/jitter and straggler service:
    the recorded effective schedule still replays."""
    tr = Transport(0.2, 0.1, drop_rate=0.08, dup_rate=0.04,
                   reorder_rate=0.15, ack_timeout=0.8)
    timing = dataclasses.replace(STRAGGLER, net=tr)
    sess = _flat_session()
    res = sess.run_ps(ROUNDS, timing=timing)
    sess2 = _flat_session(delay_model=res.to_delay_model())
    _assert_replay(res, sess2, CENTERS, lambda z: np.asarray(z).ravel(),
                   bitwise=False)


def test_lossy_deterministic():
    """Same seed + same transport -> identical trace, z, and delivery
    log (per-link seeded rngs, not event-interleaving-dependent)."""
    r1 = _flat_session().run_ps(ROUNDS, transport=LOSSY)
    r2 = _flat_session().run_ps(ROUNDS, transport=LOSSY)
    np.testing.assert_array_equal(r1.trace.delays, r2.trace.delays)
    np.testing.assert_array_equal(np.asarray(r1.z_final),
                                  np.asarray(r2.z_final))
    assert r1.trace.transport == r2.trace.transport
    assert r1.makespan == r2.makespan


# ---------------------------------------------------------------------------
# zero-loss inertness (acceptance criterion): knobs off == pre-transport
# ---------------------------------------------------------------------------

def test_zero_loss_transport_is_inert():
    """A Transport with every fault knob at zero routes through the
    plain NetworkModel/no-network paths: byte-identical trace, z
    trajectory and makespan; no transport metrics or delivery log."""
    base = _flat_session().run_ps(ROUNDS, timing=STRAGGLER)
    inert = _flat_session().run_ps(
        ROUNDS, timing=dataclasses.replace(STRAGGLER,
                                           net=Transport(0.0, 0.0)))
    np.testing.assert_array_equal(base.trace.delays, inert.trace.delays)
    for a, b in zip(base.z_versions, inert.z_versions):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert base.makespan == inert.makespan
    assert "transport" not in inert.metrics
    assert "transport" not in inert.trace.meta
    assert not inert.trace.transport


def test_zero_loss_transport_with_latency_is_plain_network():
    """Zero-knob Transport WITH latency == the plain NetworkModel of
    the same latency, byte for byte (same rng draw sequence)."""
    net = _flat_session().run_ps(
        ROUNDS, timing=CostProfile(net=NetworkModel(0.3, 0.1)))
    tr = _flat_session().run_ps(
        ROUNDS, timing=CostProfile(net=Transport(0.3, 0.1)))
    np.testing.assert_array_equal(net.trace.delays, tr.trace.delays)
    np.testing.assert_array_equal(np.asarray(net.z_final),
                                  np.asarray(tr.z_final))
    assert net.makespan == tr.makespan


def test_as_network_transport_passthrough():
    """Degenerate zero models drop to None as before, but an unreliable
    Transport always engages — loss alone needs the message layer."""
    assert as_network(None) is None
    assert as_network(0.0) is None
    assert as_network(Transport(0.0, 0.0)) is None
    lossy = Transport(0.0, 0.0, drop_rate=0.01)
    assert as_network(lossy) is lossy


def test_transport_validation():
    with pytest.raises(ValueError, match="drop_rate"):
        Transport(0.0, 0.0, drop_rate=1.0)
    with pytest.raises(ValueError, match="dup_rate"):
        Transport(0.0, 0.0, dup_rate=-0.1)
    with pytest.raises(ValueError, match="ack_timeout"):
        Transport(0.0, 0.0, ack_timeout=0.0)
    with pytest.raises(ValueError, match="max_retries"):
        Transport(0.0, 0.0, max_retries=-1)
    with pytest.raises(ValueError, match="backoff"):
        Transport(0.0, 0.0, backoff=0.5)
    assert Transport(0.0, 0.0, drop_rate=0.1).timeout(10) == \
        pytest.approx(8.0)              # capped exponential backoff


# ---------------------------------------------------------------------------
# capped exponential backoff: formula pin + observed retransmit schedule
# ---------------------------------------------------------------------------

def test_backoff_formula_pin():
    """``timeout(k) == ack_timeout * min(backoff**k, max_backoff)`` —
    pinned exactly so a silent change to the retransmission schedule
    (which shifts every lossy run's timing, trace, and replay) cannot
    slip through."""
    tr = Transport(0.0, 0.0, drop_rate=0.1, ack_timeout=0.3)
    for k in range(7):                      # defaults: backoff=2, cap=8
        assert tr.timeout(k) == pytest.approx(0.3 * min(2.0 ** k, 8.0))
    custom = Transport(0.0, 0.0, drop_rate=0.1, ack_timeout=0.5,
                       backoff=3.0, max_backoff=5.0)
    for k in range(6):
        assert custom.timeout(k) == pytest.approx(
            0.5 * min(3.0 ** k, 5.0))
    # the cap is reached and then HELD — timeouts never keep growing
    assert tr.timeout(3) == tr.timeout(4) == tr.timeout(50) \
        == pytest.approx(0.3 * 8.0)


def test_backoff_schedule_observed_under_loss_burst():
    """Under a total-loss ``link_loss`` burst the declare stream's
    logged retransmit times follow the capped exponential ladder:
    consecutive gaps are exactly ``timeout(k)`` and the gap saturates
    at ``ack_timeout * max_backoff`` for the rest of the burst."""
    plan = FaultPlan.of(FaultPlan.link_loss(2.0, 28.0, 1.0))
    sess = _flat_session()
    res = sess.run_ps(ROUNDS, faults=plan)
    tr = Transport(0.0, 0.0)                # the synthesized zero-knob
    streams = {}                            # transport's defaults
    for e in res.trace.transport:
        if e["kind"] == "retransmit" and e["msg"] == "declare":
            key = (e["worker"], e["domain"], e["round"])
            streams.setdefault(key, []).append(e)
    assert streams, "a 28s total-loss burst must force retransmissions"
    deep = max(streams.values(), key=len)
    assert len(deep) >= 5, "burst long enough to reach the backoff cap"
    deep.sort(key=lambda e: e["retry"])
    assert [e["retry"] for e in deep] == list(range(1, len(deep) + 1))
    for prev, nxt in zip(deep, deep[1:]):
        # retransmit k's timer was armed with timeout(k)
        assert nxt["time"] - prev["time"] == pytest.approx(
            tr.timeout(prev["retry"]))
    cap = tr.ack_timeout * tr.max_backoff
    tail = [nxt["time"] - prev["time"] for prev, nxt in
            zip(deep, deep[1:])][-2:]
    assert all(g == pytest.approx(cap) for g in tail), \
        f"backoff must saturate at ack_timeout*max_backoff={cap}; " \
        f"tail gaps {tail}"
    # and once the burst lifts, the stalled rounds complete
    assert res.trace.complete


# ---------------------------------------------------------------------------
# graceful degradation: pull timeout -> cached read within the tau bound
# ---------------------------------------------------------------------------

def test_pull_timeout_falls_back_within_bound():
    """Heavy drop with a zero-retry budget forces cache fallbacks; the
    extra staleness stays within Assumption 3's bound and the trace
    still replays."""
    tr = Transport(0.0, 0.0, drop_rate=0.45, ack_timeout=0.4,
                   max_retries=0)
    sess = _flat_session()
    res = sess.run_ps(ROUNDS, transport=tr)
    assert res.metrics["transport"]["timeout_fallbacks"] > 0
    assert res.metrics["max_served_tau"] <= res.metrics["bound"]
    assert int(res.trace.delays.max()) <= res.trace.bound
    sess2 = _flat_session(delay_model=res.to_delay_model())
    _assert_replay(res, sess2, CENTERS, lambda z: np.asarray(z).ravel(),
                   bitwise=False)


# ---------------------------------------------------------------------------
# link_loss chaos + crash interplay
# ---------------------------------------------------------------------------

def test_link_loss_fault_engages_transport_and_replays():
    """A link_loss burst over a RELIABLE base network engages the
    ack/retry layer for the whole run; drops concentrate in the window
    and the trace replays (with the burst logged in the timeline)."""
    plan = FaultPlan.of(FaultPlan.link_loss(1.0, 4.0, 0.5))
    sess = _flat_session()
    res = sess.run_ps(ROUNDS, timing=STRAGGLER, faults=plan)
    t = res.metrics["transport"]
    assert t["drops"] > 0
    assert any(e["kind"] == "link_loss" for e in res.trace.events)
    sess2 = _flat_session(delay_model=res.to_delay_model())
    _assert_replay(res, sess2, CENTERS, lambda z: np.asarray(z).ravel(),
                   bitwise=False)


def test_link_loss_with_churn_replays():
    """Loss + worker crash/rejoin in the same run: pending pull dedup
    state is cleared on crash (a revived worker's re-request is served
    as new) and the combined trace still replays."""
    plan = FaultPlan.of(FaultPlan.link_loss(0.5, 5.0, 0.4),
                        FaultPlan.crash(1, 3.0, 4.0))
    sess = _flat_session()
    res = sess.run_ps(ROUNDS + 2, timing=STRAGGLER, faults=plan)
    assert res.metrics["crashes"] == 1 and res.metrics["rejoins"] == 1
    sess2 = _flat_session(delay_model=res.to_delay_model())
    _assert_replay(res, sess2, CENTERS, lambda z: np.asarray(z).ravel(),
                   bitwise=False)


def test_link_loss_validation():
    with pytest.raises(ValueError, match="duration"):
        FaultPlan.of(FaultPlan.link_loss(1.0, 0.0, 0.5))
    with pytest.raises(ValueError, match="drop probability"):
        FaultPlan.of(FaultPlan.link_loss(1.0, 2.0, 1.5))
    with pytest.raises(ValueError, match="outside"):
        FaultPlan.of(FaultPlan.link_loss(1.0, 2.0, 0.5, worker=9)
                     ).validate(num_workers=3)
    # JSON round-trip keeps the burst
    plan = FaultPlan.of(FaultPlan.link_loss(1.0, 2.0, 0.5, block=2))
    assert FaultPlan.from_json(plan.to_json()).has_link_loss


# ---------------------------------------------------------------------------
# exactly-once commit folds (hypothesis property, satellite 4)
# ---------------------------------------------------------------------------

def _fold_exactly_once_run(drop, dup, reorder, ack_timeout, retries):
    tr = Transport(0.0, 0.0, drop_rate=drop, dup_rate=dup,
                   reorder_rate=reorder, ack_timeout=ack_timeout,
                   max_retries=retries)
    sess = _flat_session()
    rt_timing = CostProfile(t_worker=ConstantService(1.0),
                            t_server_block=ConstantService(0.25), net=tr)
    from repro.ps import PSRuntime
    rt = PSRuntime(sess.spec, data=sess.data, timing=rt_timing)
    res = rt.run(ROUNDS)
    folds = [f for dom in rt.domains for f in dom.fold_log]
    return res, folds


try:
    import hypothesis  # noqa: F401
    from hypothesis import given, settings, strategies as st

    @given(drop=st.floats(0.0, 0.5), dup=st.floats(0.0, 0.4),
           reorder=st.floats(0.0, 0.6), ack_timeout=st.floats(0.2, 2.0),
           retries=st.integers(0, 4))
    @settings(max_examples=10, deadline=None)
    def test_exactly_once_fold_property(drop, dup, reorder, ack_timeout,
                                        retries):
        """Under ARBITRARY drop/dup/reorder schedules the commit layer
        folds each (round, worker, block) push exactly once, and the
        final z matches the reliable-transport execution of the same
        effective schedule (the vectorized epoch replay of the recorded
        trace)."""
        res, folds = _fold_exactly_once_run(drop, dup, reorder,
                                            ack_timeout, retries)
        assert len(folds) == len(set(folds)), \
            "a (round, worker, block) push folded more than once"
        assert len(folds) == res.metrics["pushes"]
        # reliable execution of the same effective schedule == epoch
        # replay of the recorded trace; final z must match
        sess2 = _flat_session(delay_model=res.to_delay_model())
        state = sess2.init()
        step = sess2.step_fn()
        for _ in range(res.num_rounds):
            state, _ = step(state, CENTERS)
        np.testing.assert_allclose(
            np.asarray(sess2.z(state)), np.asarray(res.z_final),
            rtol=1e-5, atol=1e-6)
except ImportError:                     # pragma: no cover - optional extra
    pass


def test_exactly_once_fold_fixed_schedule():
    """Non-hypothesis pin of the exactly-once property (runs even
    without the test extra installed)."""
    res, folds = _fold_exactly_once_run(0.3, 0.2, 0.3, 0.5, 2)
    assert len(folds) == len(set(folds))
    assert len(folds) == res.metrics["pushes"]
    assert res.metrics["transport"]["dups_dropped"] > 0


# ---------------------------------------------------------------------------
# DelayTrace persistence: transport log round-trip + actionable load errors
# ---------------------------------------------------------------------------

def test_trace_transport_log_roundtrip(tmp_path):
    res = _flat_session().run_ps(ROUNDS, transport=LOSSY)
    path = res.trace.save(str(tmp_path / "lossy"))
    back = DelayTrace.load(path)
    assert back.transport == res.trace.transport
    assert back.meta["transport"]["drop_rate"] == LOSSY.drop_rate
    np.testing.assert_array_equal(back.delays, res.trace.delays)


def test_trace_load_missing_file():
    with pytest.raises(FileNotFoundError):
        DelayTrace.load("/nonexistent/trace.npz")


def test_trace_load_truncated(tmp_path):
    res = _flat_session().run_ps(2, timing=STRAGGLER)
    path = res.trace.save(str(tmp_path / "t"))
    data = open(path, "rb").read()
    trunc = tmp_path / "trunc.npz"
    trunc.write_bytes(data[:len(data) // 2])
    with pytest.raises(ValueError) as ei:
        DelayTrace.load(str(trunc))
    msg = str(ei.value)
    assert "trunc.npz" in msg and "truncated" in msg


def test_trace_load_missing_key(tmp_path):
    path = tmp_path / "missing.npz"
    np.savez(path, delays=np.zeros((2, N, M), np.int32))   # no bound
    with pytest.raises(ValueError) as ei:
        DelayTrace.load(str(path))
    msg = str(ei.value)
    assert "missing.npz" in msg and "bound" in msg and "discipline" in msg


def test_trace_load_extra_key(tmp_path):
    path = tmp_path / "extra.npz"
    np.savez(path, delays=np.zeros((2, N, M), np.int32),
             bound=np.int32(2), discipline=np.str_("lockfree"),
             meta=np.str_("{}"), bogus=np.zeros(3))
    with pytest.raises(ValueError, match="bogus"):
        DelayTrace.load(str(path))


def test_trace_load_shape_mismatch(tmp_path):
    path = tmp_path / "shape.npz"
    np.savez(path, delays=np.zeros((2, N), np.int32),     # 2-d, not 3-d
             bound=np.int32(2), discipline=np.str_("lockfree"))
    with pytest.raises(ValueError, match=r"\(rounds, N, M\)"):
        DelayTrace.load(str(path))
    path2 = tmp_path / "part.npz"
    np.savez(path2, delays=np.zeros((2, N, M), np.int32),
             bound=np.int32(2), discipline=np.str_("lockfree"),
             participation=np.ones((5, N), bool))
    with pytest.raises(ValueError, match="participation"):
        DelayTrace.load(str(path2))


def test_trace_load_corrupt_json(tmp_path):
    path = tmp_path / "badmeta.npz"
    np.savez(path, delays=np.zeros((2, N, M), np.int32),
             bound=np.int32(2), discipline=np.str_("lockfree"),
             meta=np.str_("{not json"))
    with pytest.raises(ValueError, match="corrupt"):
        DelayTrace.load(str(path))


def test_trace_load_not_an_npz(tmp_path):
    path = tmp_path / "noise.npz"
    path.write_bytes(b"this is not a zip archive")
    with pytest.raises(ValueError, match="noise.npz"):
        DelayTrace.load(str(path))


def test_old_trace_without_new_keys_loads(tmp_path):
    """Pre-transport (and pre-chaos) files lack the newer keys; load
    defaults them."""
    path = tmp_path / "old.npz"
    np.savez(path, delays=np.zeros((2, N, M), np.int32),
             bound=np.int32(2), discipline=np.str_("lockfree"))
    tr = DelayTrace.load(str(path))
    assert tr.meta == {} and tr.events == [] and tr.transport == []
    assert tr.participation is None


# ---------------------------------------------------------------------------
# divergence watchdogs (satellite 3)
# ---------------------------------------------------------------------------

def _exploding_session():
    # rho ~ 1e-38: x = z - (g+y)/rho overflows fp32 at the first worker
    # update, so the first committed z is non-finite
    cfg = ADMMConfig(rho=1e-38, gamma=1e-30, max_delay=2,
                     block_fraction=1.0, num_blocks=M,
                     block_selection="random", seed=0)
    return ConsensusSession.flat(_flat_loss, CENTERS, dim=DIM, cfg=cfg,
                                 edge=EDGE, rho_scale=RHO_SCALE)


def test_runtime_divergence_watchdog():
    sess = _exploding_session()
    with pytest.raises(FloatingPointError) as ei:
        sess.run_ps(ROUNDS, check_finite=True)
    msg = str(ei.value)
    assert "block" in msg and "round" in msg
    # off by default: the same run completes (silently non-finite)
    res = sess.run_ps(ROUNDS)
    assert not np.all(np.isfinite(np.asarray(res.z_final)))


def test_epoch_divergence_watchdog():
    sess = _exploding_session()
    prev = set_epoch_check_finite(True)
    try:
        with pytest.raises(FloatingPointError) as ei:
            state = sess.init()
            for _ in range(ROUNDS):
                state, _ = asybadmm_epoch(sess.spec, state, sess.data)
        assert "round" in str(ei.value) and "block" in str(ei.value)
    finally:
        set_epoch_check_finite(prev)
    # flag restored: the same loop runs unchecked
    state = sess.init()
    state, _ = asybadmm_epoch(sess.spec, state, sess.data)


def test_healthy_run_passes_watchdog():
    res = _flat_session().run_ps(ROUNDS, timing=STRAGGLER,
                                 check_finite=True)
    assert np.all(np.isfinite(np.asarray(res.z_final)))


# ---------------------------------------------------------------------------
# SPMD cell (runs under scripts/ci.sh's forced-8-device step)
# ---------------------------------------------------------------------------

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(scripts/ci.sh runs this file's spmd tests under it)")


@needs8
def test_spmd_lossy_trace_replay():
    """The acceptance-criterion rates (drop 5% / dup 2% / reorder 10%)
    at 8 workers: the lossy trace replays through the SPMD-sharded
    epoch within the SPMD parity tolerance."""
    from repro.launch.mesh import make_test_mesh

    N8, M8 = 8, 8
    dim = M8 * DBLK
    centers = jnp.asarray(
        np.random.RandomState(5).randn(N8, dim).astype(np.float32))
    cfg = ADMMConfig(rho=2.0, gamma=0.1, max_delay=2, block_fraction=0.5,
                     num_blocks=M8, l1_coef=1e-3, clip=0.8, seed=0)

    def make(dm=None, mesh=None):
        return ConsensusSession.flat(_flat_loss, centers, dim=dim, cfg=cfg,
                                     delay_model=dm, mesh=mesh)
    tr = Transport(0.0, 0.0, drop_rate=0.05, dup_rate=0.02,
                   reorder_rate=0.1, ack_timeout=0.5)
    res = make().run_ps(ROUNDS, transport=tr)
    assert res.metrics["transport"]["drops"] > 0
    sess = make(dm=res.to_delay_model(), mesh=make_test_mesh(8))
    state = sess.init()
    step = sess.step_fn()
    for t in range(ROUNDS):
        state, _ = step(state, centers)
        np.testing.assert_allclose(
            np.asarray(jax.device_get(sess.z(state))),
            np.asarray(res.z_versions[t + 1]), rtol=1e-5, atol=1e-5,
            err_msg=f"SPMD lossy replay diverged at round {t}")
