"""Deterministic telemetry (repro.obs): the inertness contract, the
stream/trace schemas, and the registry/vocabulary validation.

The headline pin: a chaos run (server crash + WAL recovery + worker
crash/rejoin) with full telemetry — spans, a JSONL sink, a Chrome
trace export — commits the BITWISE-identical z, the identical metrics
dict (same keys, same order, same values), identical fold logs and the
identical makespan as the telemetry-off run. Telemetry records the
schedule; it never becomes part of it.

Secondary pins: every streamed record validates against
``ROUND_RECORD_SCHEMA``; the Chrome export is well-formed trace-event
JSON whose span names all come from ``SPAN_NAMES``; ``hist`` handles
the degenerate inputs (empty, all-equal) without phantom observations;
the metrics registry refuses undeclared names, kind mismatches and
duplicates; ``DelayTrace.add_event``/``add_transport`` refuse kinds
missing from the ``repro.obs.names`` registries.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ConsensusSession
from repro.configs.base import ADMMConfig
from repro.obs import (METRICS, ROUND_RECORD_SCHEMA, SPAN_NAMES,
                       TRACE_EVENT_KINDS, TRANSPORT_EVENT_KINDS,
                       CallbackSink, JsonlSink, MetricsRegistry, SpanTracer,
                       Telemetry, TimeSeries, as_telemetry, hist, make_sink,
                       validate_record)
from repro.ps import (ConstantService, CostProfile, DelayTrace, FaultPlan,
                      LognormalService, ParetoService, PSRuntime)

N, M, DBLK = 3, 4, 5
DIM = M * DBLK
ROUNDS = 8

_r = np.random.RandomState(7)
CENTERS = jnp.asarray(_r.randn(N, DIM).astype(np.float32))
EDGE = np.array([[1, 1, 0, 1],
                 [1, 0, 1, 0],
                 [1, 1, 1, 1]], bool)
RHO_SCALE = np.array([0.5, 1.0, 2.0], np.float32)

TIMING = CostProfile(t_worker=ConstantService(1.0),
                     t_server_block=ConstantService(0.25))
#: heavy-tailed service times: creates real queue backlogs (queue_wait
#: spans) and lets a round complete while the crashed server is still
#: down (the null-stationarity path)
STRAGGLER = CostProfile(t_worker=ParetoService(1.0, alpha=1.2),
                        t_server_block=LognormalService(0.3, 0.4))
#: a run that exercises every span family: server crash -> WAL replay
#: (down window + wal_replay instant on the server track) and a worker
#: crash at 1.0 whose rejoin at 2.0 still has rounds left to join
#: (down window + crash/rejoin instants on the worker track).
CHAOS = FaultPlan.of(FaultPlan.server_crash(1, at=2.0, down=3.0),
                     FaultPlan.crash(0, at=1.0, down=1.0))


def _cfg(**kw):
    kw.setdefault("max_delay", 2)
    return ADMMConfig(rho=2.0, gamma=0.1, block_fraction=0.5,
                      num_blocks=M, block_selection="random", l1_coef=1e-3,
                      clip=0.8, seed=0, **kw)


def _flat_loss(z, c):
    return 0.5 * jnp.sum(jnp.square(z - c))


def _session(cfg=None, backend="jnp"):
    return ConsensusSession.flat(
        _flat_loss, CENTERS, dim=DIM, cfg=cfg or _cfg(), edge=EDGE,
        rho_scale=RHO_SCALE, backend=backend)


def _runtime(timing=TIMING, backend="jnp", **kw):
    sess = _session(backend=backend)
    return PSRuntime(sess.spec, data=sess.data, timing=timing, **kw)


def _per_round_folds(rt):
    """{sid: {round: sorted [(worker, block)]}} from the fold logs."""
    out = {}
    for dom in rt.domains:
        rounds = {}
        for (v, i, j) in dom.fold_log:
            rounds.setdefault(v, []).append((i, j))
        out[dom.sid] = {v: sorted(fs) for v, fs in rounds.items()}
    return out


# ---------------------------------------------------------------------------
# the determinism contract (the headline pin; scripts/ci.sh re-gates it
# under forced multi-device XLA)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_telemetry_is_inert_under_chaos(tmp_path, backend):
    """Full telemetry on a chaos run changes NOTHING the runtime
    computes: bitwise z (both backends — the pallas cell is the
    fusion-stable bitwise pin), equal metrics (keys, order, values),
    equal fold logs, equal makespan and staleness trace."""
    rt_off = _runtime(faults=CHAOS, backend=backend)
    off = rt_off.run(ROUNDS)

    tel = Telemetry(spans=True,
                    sink=str(tmp_path / "rounds.jsonl"),
                    trace_path=str(tmp_path / "run.trace.json"))
    rt_on = _runtime(faults=CHAOS, telemetry=tel, backend=backend)
    on = rt_on.run(ROUNDS)

    assert on.makespan == off.makespan
    np.testing.assert_array_equal(np.asarray(on.z_final),
                                  np.asarray(off.z_final))
    assert list(on.metrics) == list(off.metrics)    # exact key order
    assert on.metrics == off.metrics
    assert _per_round_folds(rt_on) == _per_round_folds(rt_off)
    np.testing.assert_array_equal(on.trace.delays, off.trace.delays)
    assert on.trace.events == off.trace.events
    assert on.telemetry is tel and off.telemetry is None


def test_streamed_records_validate(tmp_path):
    """Every JSONL line passes the schema; losses stream at full
    precision and match ``PSRunResult.losses``; stationarity goes null
    exactly while a block server is down (never silently wrong)."""
    path = tmp_path / "rounds.jsonl"
    tel = Telemetry(spans=False, sink=str(path))
    rt = _runtime(timing=STRAGGLER, faults=CHAOS, telemetry=tel)
    res = rt.run(ROUNDS)

    records = [json.loads(line) for line in path.read_text().splitlines()]
    assert len(records) == ROUNDS == tel.records_emitted
    for rec in records:
        validate_record(rec)
        assert set(ROUND_RECORD_SCHEMA) <= set(rec)
    assert [r["round"] for r in records] == list(range(ROUNDS))
    assert [r["version"] for r in records] == list(range(1, ROUNDS + 1))
    # full-precision loss passthrough (no display rounding in the
    # machine stream)
    assert [r["loss"] for r in records] == res.losses
    times = [r["sim_time"] for r in records]
    assert times == sorted(times) and times[-1] <= res.makespan
    # stationarity goes null exactly for rounds completing inside the
    # server-down window [2.0, 5.0) — a crashed *worker* never nulls it
    null_rounds = [r["round"] for r in records if r["stationarity"] is None]
    assert null_rounds
    for rec in records:
        in_outage = 2.0 <= rec["sim_time"] < 5.0
        assert (rec["stationarity"] is None) == in_outage, rec["round"]
        if rec["stationarity"] is not None:
            pb = rec["stationarity"]["per_block"]
            assert all(len(pb[k]) == M for k in ("primal", "prox",
                                                 "grad", "P"))
        assert len(rec["queue_depth"]) == len(rt.domains)


def test_chrome_trace_schema(tmp_path):
    """The export is loadable trace-event JSON: declared span names
    only, sane phases, non-negative durations, a thread-name record for
    every track, and the chaos/durability spans present."""
    trace_path = tmp_path / "run.trace.json"
    tel = Telemetry(spans=True, trace_path=str(trace_path))
    rt = _runtime(timing=STRAGGLER, faults=CHAOS, telemetry=tel)
    res = rt.run(ROUNDS)

    doc = json.loads(trace_path.read_text())
    events = doc["traceEvents"]
    assert events and doc["displayTimeUnit"] == "ms"
    assert doc["otherData"]["makespan"] == res.makespan
    named_tids = {e["tid"] for e in events if e["name"] == "thread_name"}
    tracks = {e["args"]["name"] for e in events if e["name"] == "thread_name"}
    assert {f"worker {i}" for i in range(N)} <= tracks
    assert {f"server {s}" for s in range(len(rt.domains))} <= tracks
    for e in events:
        assert e["ph"] in ("X", "i", "C", "M")
        assert e["tid"] in named_tids
        if e["ph"] == "M":
            continue
        assert e["name"] in SPAN_NAMES
        assert e["ts"] >= 0.0
        if e["ph"] == "X":
            assert e["dur"] >= 0.0
    names = {e["name"] for e in events}
    assert {"pull", "compute", "commit", "queue_wait"} <= names
    # chaos + durability visible as spans, same spellings as the trace
    assert {"server_crash", "server_recover", "wal_replay", "crash",
            "rejoin", "down"} <= names
    # the two outage windows (server 1, worker 0) appear as closed
    # "down" spans of the planned length
    downs = [e for e in events if e["name"] == "down"]
    assert sorted(e["dur"] for e in downs) == [1.0e6, 3.0e6]


def test_metrics_every_cadence():
    """Records land at the configured cadence, final round always
    included; ``metrics_every`` without telemetry is an error."""
    records = []
    tel = Telemetry(spans=False, sink=records.append, metrics_every=3)
    _runtime(telemetry=tel).run(ROUNDS)
    assert [r["round"] for r in records] == [0, 3, 6, ROUNDS - 1]

    with pytest.raises(ValueError, match="metrics_every"):
        _runtime(metrics_every=2)
    with pytest.raises(ValueError, match="metrics_every"):
        Telemetry(metrics_every=0)


def test_session_level_telemetry_coercion():
    """``run_ps(telemetry=...)`` coerces callables/True like
    ``as_telemetry`` documents, and hands the Telemetry back on the
    result."""
    records = []
    res = _session().run_ps(ROUNDS, timing=TIMING,
                            telemetry=records.append)
    assert len(records) == ROUNDS
    for rec in records:
        validate_record(rec)
    assert res.telemetry is not None
    assert res.telemetry.spans is not None and len(res.telemetry.spans) > 0
    assert res.telemetry.events_seen == res.metrics["events"]

    assert as_telemetry(None) is None and as_telemetry(False) is None
    tel = Telemetry(spans=False)
    assert as_telemetry(tel) is tel
    assert as_telemetry(True).sink is None
    assert isinstance(as_telemetry("stdout"), Telemetry)


def test_snapshot_barrier_span(tmp_path):
    """Checkpointed runs put the quiescent barrier on the runtime
    track: first worker parked -> snapshot written."""
    tel = Telemetry(spans=True)
    rt = _runtime(telemetry=tel)
    res = rt.run(ROUNDS, checkpoint_every=4,
                 checkpoint_dir=str(tmp_path / "snaps"))
    snaps = [e for e in tel.spans._events if e["name"] == "snapshot"]
    assert len(snaps) == len(res.metrics["snapshots"]) > 0
    for e in snaps:
        assert e["ph"] == "X" and e["dur"] >= 0.0
        assert e["args"]["path"] in res.metrics["snapshots"]


# ---------------------------------------------------------------------------
# hist degenerate cases (promoted from ps/runtime.py::_hist)
# ---------------------------------------------------------------------------

def test_hist_matches_numpy_on_generic_input():
    vals = [0.0, 1.0, 2.5, 2.5, 7.0]
    h = hist(vals, bins=4)
    counts, edges = np.histogram(vals, bins=4)
    assert h["counts"] == counts.tolist()
    np.testing.assert_allclose(h["edges"], edges)


def test_hist_empty_input_no_phantom_observation():
    h = hist([], bins=8)
    assert h["counts"] == [0] * 8
    assert h["edges"][0] == 0.0 and h["edges"][-1] == 1.0
    assert sum(h["counts"]) == 0


def test_hist_all_equal_values_centered_unit_range():
    h = hist([3.0, 3.0, 3.0], bins=8)
    assert sum(h["counts"]) == 3
    assert h["edges"][0] == pytest.approx(2.5)
    assert h["edges"][-1] == pytest.approx(3.5)
    widths = np.diff(h["edges"])
    assert (widths > 0).all()


def test_hist_rejects_bad_bins():
    with pytest.raises(ValueError, match="bins"):
        hist([1.0], bins=0)


# ---------------------------------------------------------------------------
# metrics registry: stable-name validation + collection order
# ---------------------------------------------------------------------------

def test_registry_rejects_undeclared_name():
    reg = MetricsRegistry()
    with pytest.raises(ValueError, match="not declared"):
        reg.counter("totally_new_metric", lambda: 0)
    reg.counter("totally_new_metric", lambda: 0, check=False)  # scratch ok


def test_registry_rejects_kind_mismatch_and_duplicates():
    reg = MetricsRegistry()
    with pytest.raises(ValueError, match="declared as a gauge"):
        reg.counter("makespan", lambda: 0.0)    # makespan is a gauge
    reg.gauge("makespan", lambda: 7.0)
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("makespan", lambda: 8.0)
    with pytest.raises(ValueError, match="unknown instrument kind"):
        reg.register("events", "dial", lambda: 0)


def test_registry_collects_in_registration_order():
    reg = MetricsRegistry()
    reg.gauge("makespan", lambda: 1.0)
    reg.counter("events", lambda: 2)
    reg.counter("commits", lambda: 3)
    assert list(reg.collect()) == ["makespan", "events", "commits"]
    assert reg.collect(["commits"]) == {"commits": 3}
    assert "events" in reg and reg.get("events").unit == METRICS["events"][1]
    table = reg.describe()
    assert [row["name"] for row in table] == ["makespan", "events",
                                              "commits"]
    assert all(row["help"] for row in table)


def test_timeseries_buckets():
    ts = TimeSeries()
    for t, v in [(0.1, 1.0), (0.4, 2.0), (1.2, 5.0)]:
        ts.append(t, v)
    out = ts.buckets(1.0)
    assert out["width"] == 1.0
    assert out["buckets"] == [
        {"t0": 0.0, "count": 2, "sum": 3.0, "last": 2.0},
        {"t0": 1.0, "count": 1, "sum": 5.0, "last": 5.0}]
    assert TimeSeries().buckets(0.5)["buckets"] == []
    with pytest.raises(ValueError, match="width"):
        ts.buckets(0.0)

    reg = MetricsRegistry()
    series = reg.series("scratch_series")
    series.append(1.0, 2.0)
    assert reg.series("scratch_series") is series       # fetch, not new
    assert reg.collect()["scratch_series"] == [(1.0, 2.0)]


# ---------------------------------------------------------------------------
# span-name and trace-kind vocabularies (one registry, no drift)
# ---------------------------------------------------------------------------

def test_span_tracer_rejects_unknown_and_mistyped_names():
    tr = SpanTracer()
    with pytest.raises(ValueError, match="unknown span kind"):
        tr.complete("worker 0", "made_up_span", 0.0, 1.0)
    with pytest.raises(ValueError, match="declared as"):
        tr.complete("worker 0", "commit", 0.0, 1.0)   # commit is instant
    with pytest.raises(ValueError, match="ends before"):
        tr.complete("worker 0", "pull", 2.0, 1.0)
    tr.complete("worker 0", "pull", 1.0, 2.0, round=0)
    tr.instant("server 0", "commit", 2.0, version=1)
    tr.counter("server 0", "queue_depth", 2.0, depth=3)
    assert len(tr) == 3
    doc = tr.to_chrome({"seed": 0})
    # thread-name metadata precedes events; tids are stable per track
    assert [e["ph"] for e in doc["traceEvents"][:2]] == ["M", "M"]
    assert doc["otherData"] == {"seed": 0}


def test_trace_event_kinds_validated():
    tr = DelayTrace.empty(2, N, M, bound=2)
    tr.add_event("crash", time=1.0, worker=0)
    with pytest.raises(ValueError, match="unknown trace event kind"):
        tr.add_event("meteor_strike", time=1.0)
    tr.add_transport("drop", time=1.0, worker=0)
    with pytest.raises(ValueError, match="unknown transport event kind"):
        tr.add_transport("wormhole", time=1.0)
    # every runtime spelling stays declared
    assert {"crash", "rejoin", "server_crash",
            "server_recover"} <= TRACE_EVENT_KINDS
    assert {"drop", "dup", "reorder", "retransmit",
            "pull_timeout"} <= TRANSPORT_EVENT_KINDS
    # chaos/transport kinds double as span instants (cross-referencable
    # between a saved DelayTrace and a Perfetto trace)
    for kind in TRACE_EVENT_KINDS - {"leave", "join", "slowdown",
                                     "server_spike", "link_loss"}:
        assert SPAN_NAMES[kind][0] == "instant"
    for kind in TRANSPORT_EVENT_KINDS:
        assert SPAN_NAMES[kind][0] == "instant"


def test_make_sink_coercion(tmp_path, capsys):
    assert make_sink(None) is None
    sink = make_sink(str(tmp_path / "out.jsonl"))
    assert isinstance(sink, JsonlSink)
    sink.emit({"round": 0})
    sink.close()
    assert json.loads((tmp_path / "out.jsonl").read_text()) == {"round": 0}
    got = []
    cb = make_sink(got.append)
    assert isinstance(cb, CallbackSink)
    cb.emit({"round": 1})
    assert got == [{"round": 1}]
    make_sink("stdout").emit({"round": 2})
    assert json.loads(capsys.readouterr().out) == {"round": 2}
    with pytest.raises(TypeError, match="sink"):
        make_sink(42)


def test_validate_record_names_offending_key():
    good = {"round": 0, "version": 1, "sim_time": 1.0, "loss": 0.5,
            "stationarity": None, "queue_depth": [0], "commits": 1,
            "pushes": 2, "stall_count": 0, "stall_time": 0.0,
            "transport": None}
    assert validate_record(dict(good)) == good
    with pytest.raises(ValueError, match="'commits'"):
        validate_record({**good, "commits": "three"})
    missing = dict(good)
    del missing["loss"]
    with pytest.raises(ValueError, match="'loss'"):
        validate_record(missing)
    with pytest.raises(ValueError, match="per_block"):
        validate_record({**good, "stationarity": {"P": 1.0}})
