"""Decode path == prefill path, token by token, for every architecture.

This is the deepest correctness check of the KV-cache / SSM-state /
latent-cache machinery: any off-by-one in positions, masks, RoPE or
state carry shows up here.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke, list_archs
from repro.models import build_model
from repro.serving.engine import Engine


@pytest.mark.parametrize("arch", list_archs())
def test_decode_matches_prefill(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    enc = None
    if cfg.is_enc_dec:
        enc = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.encoder_seq_len, cfg.d_model)) * 0.1
    ref = model.prefill(params, tok, enc_frames=enc)

    engine = Engine(model, params, max_len=S + 4)
    cache = model.init_cache(B, S + 4)
    if cfg.is_enc_dec:
        cache = engine._fill_cross_attn(cache, enc)
    decode = jax.jit(model.decode_step)
    errs = []
    for t in range(S):
        lg, cache = decode(params, tok[:, t : t + 1], cache, jnp.int32(t))
        errs.append(float(jnp.max(jnp.abs(lg[:, 0] - ref[:, t]))))
    assert max(errs) < 5e-4, f"{arch}: max err {max(errs)}"
