"""Elastic PS service: fault injection, worker churn, and
partial-participation replay (repro.ps.chaos / membership).

The headline pin: a chaos run — crashes, rejoins, cold joins,
permanent leaves, transient slowdowns and server commit spikes — is
exactly as deterministic and replayable as a fault-free one. The
recorded :class:`DelayTrace` carries the staleness matrix AND the
(rounds, N) participation matrix; replaying it through the vectorized
``asybadmm_epoch`` masks the absent (round, worker) pairs out of block
selection (their y / w~ rows stay frozen, exactly what an absent
worker leaves behind on the servers), reproducing the runtime's z
trajectory — bitwise on pallas, fp32-ulp on jnp, and through the SPMD
mesh under ci.sh's forced-8-device step.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ConsensusSession
from repro.configs.base import ADMMConfig
from repro.core.blocks import TreeBlocks
from repro.ps import (ConstantService, CostProfile, DelayTrace, FaultEvent,
                      FaultPlan, MembershipManager, PSRuntime, as_service)
from repro.ps.chaos import FaultInjector

N, M, DBLK = 4, 8, 5
DIM = M * DBLK
ROUNDS = 10

_r = np.random.RandomState(11)
CENTERS = jnp.asarray(_r.randn(N, DIM).astype(np.float32))

TIMING = CostProfile(t_worker=ConstantService(1.0),
                     t_server_block=ConstantService(0.25))

# crash+rejoin, a cold join, a transient straggler and a hot server —
# every event kind in one deterministic plan
PLAN = FaultPlan.of(FaultPlan.crash(1, 3.5, 3.0),
                    FaultPlan.join(3, 2.5),
                    FaultPlan.slowdown(0, 1.0, 4.0, 3.0),
                    FaultPlan.server_spike(2, 2.0, 5.0, 4.0))


def _cfg(max_delay=2, **kw):
    return ADMMConfig(rho=2.0, gamma=0.1, max_delay=max_delay,
                      block_fraction=0.5, num_blocks=M, l1_coef=1e-3,
                      clip=0.8, seed=0, **kw)


def _flat_loss(z, c):
    return 0.5 * jnp.sum(jnp.square(z - c))


def _flat_session(backend="jnp", delay_model=None, cfg=None, mesh=None):
    return ConsensusSession.flat(
        _flat_loss, CENTERS, dim=DIM, cfg=cfg or _cfg(), backend=backend,
        delay_model=delay_model, mesh=mesh)


def _assert_replay(res, sess2, data, bitwise, to_vec=None):
    to_vec = to_vec or (lambda z: np.asarray(z).ravel())
    state = sess2.init()
    step = sess2.step_fn()
    for t in range(res.num_rounds):
        state, _ = step(state, data)
        replay, runtime = to_vec(sess2.z(state)), to_vec(res.z_versions[t + 1])
        if bitwise:
            np.testing.assert_array_equal(
                replay, runtime, err_msg=f"chaos replay diverged at round {t}")
        else:
            np.testing.assert_allclose(
                replay, runtime, rtol=1e-5, atol=1e-6,
                err_msg=f"chaos replay diverged at round {t}")


# ---------------------------------------------------------------------------
# the acceptance pin: chaos runs replay through the epoch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("discipline", ["lockfree", "per_push"])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_chaos_replay_parity(backend, discipline):
    sess = _flat_session(backend)
    res = sess.run_ps(ROUNDS, discipline=discipline, timing=TIMING,
                      faults=PLAN)
    # the chaos actually happened and was recorded
    assert res.metrics["crashes"] >= 1 and res.metrics["rejoins"] >= 1
    assert res.trace.participation is not None
    assert not res.trace.participation.all()
    kinds = {e["kind"] for e in res.trace.events}
    assert {"crash", "rejoin", "join", "slowdown", "server_spike"} <= kinds
    # staleness stays within Assumption 3's T through the churn
    assert res.metrics["max_served_tau"] <= 2
    assert res.trace.complete
    sess2 = _flat_session(backend, delay_model=res.to_delay_model())
    _assert_replay(res, sess2, CENTERS, bitwise=backend == "pallas")


def test_tree_chaos_replay_parity():
    params = {f"w{j}": jnp.zeros((DBLK,), jnp.float32) for j in range(M)}
    tblocks = TreeBlocks(num_blocks=M, leaf_block_ids=tuple(range(M)),
                         treedef=jax.tree.structure(params))

    def tree_loss(p, c):
        z = jnp.concatenate([p[f"w{j}"] for j in range(M)])
        return 0.5 * jnp.sum(jnp.square(z - c))

    def make(dm=None):
        return ConsensusSession.pytree(tree_loss, params, _cfg(),
                                       num_workers=N, blocks=tblocks,
                                       delay_model=dm)
    res = make().run_ps(ROUNDS, discipline="per_push", timing=TIMING,
                        faults=PLAN, batches=lambda t: CENTERS)
    assert res.metrics["crashes"] >= 1

    def to_vec(zt):
        return np.concatenate([np.asarray(zt[f"w{j}"]).ravel()
                               for j in range(M)])
    _assert_replay(res, make(res.to_delay_model()), CENTERS, bitwise=False,
                   to_vec=to_vec)


def test_chaos_run_deterministic():
    """Same session + same plan -> identical makespan, staleness,
    participation, event timeline and z trajectory."""
    runs = [_flat_session().run_ps(ROUNDS, timing=TIMING, faults=PLAN)
            for _ in range(2)]
    assert runs[0].makespan == runs[1].makespan
    np.testing.assert_array_equal(runs[0].trace.delays, runs[1].trace.delays)
    np.testing.assert_array_equal(runs[0].trace.participation,
                                  runs[1].trace.participation)
    assert runs[0].trace.events == runs[1].trace.events
    np.testing.assert_array_equal(np.asarray(runs[0].z_final),
                                  np.asarray(runs[1].z_final))


def test_run_ps_accepts_fault_plan_path(tmp_path):
    path = PLAN.save(str(tmp_path / "plan.json"))
    res = _flat_session().run_ps(ROUNDS, timing=TIMING, faults=path)
    ref = _flat_session().run_ps(ROUNDS, timing=TIMING, faults=PLAN)
    assert res.makespan == ref.makespan
    np.testing.assert_array_equal(res.trace.delays, ref.trace.delays)


# ---------------------------------------------------------------------------
# membership semantics
# ---------------------------------------------------------------------------

def test_membership_intervals_and_queries():
    mm = MembershipManager(3, 10, cold=(2,))
    assert mm.is_active(0) and not mm.is_active(2)
    mm.deactivate(0, 4)                      # crashed while working round 4
    assert not mm.is_active(0)
    mm.activate(0, 7)                        # resumed at the frontier
    assert mm.required(0, 3) and not mm.required(0, 5) and mm.required(0, 8)
    mm.activate(2, 6)                        # cold join
    assert not mm.required(2, 5) and mm.required(2, 6)
    P = mm.participation_matrix()
    assert P.shape == (10, 3)
    assert P[:, 1].all()                     # untouched worker: everywhere
    assert list(np.nonzero(~P[:, 0])[0]) == [4, 5, 6]
    assert mm.participated_rounds(0) == 7
    assert mm.participated_rounds(2) == 4
    assert mm.crashes == 1 and mm.rejoins == 2 and mm.elastic
    mm.deactivate(0, 8)
    with pytest.raises(RuntimeError):        # double-deactivate
        mm.deactivate(0, 9)
    m2 = MembershipManager(1, 10)
    m2.deactivate(0, 4)
    with pytest.raises(RuntimeError):        # resume inside absence window
        m2.activate(0, 2)
    with pytest.raises(ValueError):          # cold id out of range
        MembershipManager(2, 10, cold=(5,))


def test_membership_empty_interval_popped():
    """Crash + rejoin while the frontier is still at/behind the crashed
    round: the absence interval is empty and the worker misses nothing."""
    mm = MembershipManager(2, 10)
    mm.deactivate(0, 3)
    mm.activate(0, 3)                        # resumed at the same round
    assert mm.participation_matrix()[:, 0].all()
    assert mm.participated_rounds(0) == 10


def test_leave_is_permanent():
    plan = FaultPlan.of(FaultPlan.leave(2, 4.0))
    res = _flat_session().run_ps(ROUNDS, timing=TIMING, faults=plan)
    P = res.trace.participation
    gone = np.nonzero(~P[:, 2])[0]
    assert gone.size > 0 and list(gone) == list(range(gone[0], ROUNDS))
    assert res.metrics["rejoins"] == 0
    assert any(e["kind"] == "leave" for e in res.trace.events)
    # absent rounds average the loss over the remaining participants
    assert np.isfinite(res.losses).all()
    _assert_replay(res, _flat_session(delay_model=res.to_delay_model()),
                   CENTERS, bitwise=False)


def test_ineffective_rejoin_stays_absent():
    """A rejoin landing past the round horizon records an ineffective
    event and the worker stays absent to the end — no deadlock, no
    partial interval."""
    plan = FaultPlan.of(FaultPlan.crash(1, 2.0, 1000.0))
    res = _flat_session().run_ps(ROUNDS, timing=TIMING, faults=plan)
    ev = [e for e in res.trace.events if e["kind"] == "rejoin"]
    assert ev and ev[0].get("effective") is False
    assert not res.trace.participation[-1, 1]
    assert res.metrics["rejoins"] == 0


def test_rejoin_is_version_reset_not_tau_violation():
    """The enforcer books a rejoin as a version reset; parked pulls of
    a crashed worker are dropped, and served staleness never exceeds T
    (the rejoiner re-enters at the service frontier, so its first pull
    is fresh by construction)."""
    res = _flat_session().run_ps(ROUNDS, timing=TIMING, faults=PLAN)
    assert res.metrics["version_resets"] == res.metrics["rejoins"]
    assert res.metrics["max_served_tau"] <= 2
    assert int(res.trace.delays.max()) <= 2


# ---------------------------------------------------------------------------
# slowdown / server-spike timing faults
# ---------------------------------------------------------------------------

def test_slowdown_and_spike_stretch_makespan():
    base = _flat_session().run_ps(ROUNDS, timing=TIMING)
    slow = _flat_session().run_ps(
        ROUNDS, timing=TIMING,
        faults=FaultPlan.of(FaultPlan.slowdown(0, 0.0, 8.0, 5.0)))
    spike = _flat_session().run_ps(
        ROUNDS, timing=TIMING,
        faults=FaultPlan.of(FaultPlan.server_spike(0, 0.0, 8.0, 20.0)))
    assert slow.makespan > base.makespan
    assert spike.makespan > base.makespan
    # pure timing faults: full participation, so the numerics match the
    # fault-free run version-for-version only if staleness agrees —
    # participation must NOT be marked elastic
    assert slow.trace.participation is None
    assert spike.trace.participation is None


def test_injector_factor_windows():
    plan = FaultPlan.of(FaultPlan.slowdown(0, 1.0, 2.0, 3.0),
                        FaultPlan.slowdown(0, 2.0, 2.0, 2.0),
                        FaultPlan.server_spike(1, 1.0, 1.0, 4.0))
    inj = FaultInjector(plan, None)
    assert inj.worker_factor(0, 0.5) == 1.0
    assert inj.worker_factor(0, 1.5) == 3.0
    assert inj.worker_factor(0, 2.5) == 6.0      # overlapping windows compose
    assert inj.worker_factor(0, 3.5) == 2.0
    assert inj.worker_factor(1, 1.5) == 1.0
    assert inj.server_factor((1,), 1.5) == 4.0
    assert inj.server_factor((0, 1), 1.5) == 4.0  # locked domain feels it
    assert inj.server_factor((0,), 1.5) == 1.0
    assert not inj.empty and FaultInjector(None, None).empty


# ---------------------------------------------------------------------------
# per-push commit discipline
# ---------------------------------------------------------------------------

def test_per_push_faultfree_replays_and_times_differently():
    """per_push pays commit work eagerly in the push stream: same fold
    numerics as lockfree given the same pushes, but versions publish at
    different sim times — a different (still replay-exact) trajectory
    and a different makespan."""
    pp = _flat_session().run_ps(ROUNDS, discipline="per_push", timing=TIMING)
    lf = _flat_session().run_ps(ROUNDS, discipline="lockfree", timing=TIMING)
    assert pp.makespan != lf.makespan
    assert pp.trace.discipline == "per_push"
    _assert_replay(pp, _flat_session(delay_model=pp.to_delay_model()),
                   CENTERS, bitwise=False)


# ---------------------------------------------------------------------------
# FaultPlan construction / validation / persistence
# ---------------------------------------------------------------------------

def test_fault_plan_json_roundtrip(tmp_path):
    text = PLAN.to_json()
    again = FaultPlan.from_json(text)
    assert again == PLAN
    path = PLAN.save(str(tmp_path / "plan.json"))
    assert FaultPlan.load(path) == PLAN
    # dicts coerce (the schema API.md documents)
    assert FaultPlan(({"kind": "crash", "at": 1.0, "worker": 0},)) == \
        FaultPlan.of(FaultPlan.crash(0, 1.0))


def test_fault_plan_validation_errors():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent("meteor", 1.0).validate()
    with pytest.raises(ValueError, match="finite and >= 0"):
        FaultEvent("crash", -1.0, worker=0).validate()
    with pytest.raises(ValueError, match="needs a worker id"):
        FaultEvent("crash", 1.0).validate()
    with pytest.raises(ValueError, match="outside"):
        FaultEvent("crash", 1.0, worker=9).validate(num_workers=4)
    with pytest.raises(ValueError, match="outside"):
        FaultEvent("server_spike", 1.0, block=9, duration=1.0,
                   factor=2.0).validate(num_blocks=4)
    with pytest.raises(ValueError, match="duration"):
        FaultEvent("slowdown", 1.0, worker=0, factor=2.0).validate()
    with pytest.raises(ValueError, match="factor"):
        FaultEvent("server_spike", 1.0, block=0, duration=1.0,
                   factor=-2.0).validate()
    with pytest.raises(ValueError, match="downtime"):
        FaultEvent("crash", 1.0, worker=0, duration=-3.0).validate()
    with pytest.raises(ValueError, match="multiple join"):
        FaultPlan.of(FaultPlan.join(0, 1.0), FaultPlan.join(0, 2.0))
    with pytest.raises(ValueError, match="before"):
        FaultPlan.of(FaultPlan.join(0, 5.0), FaultPlan.crash(0, 2.0))
    # the runtime validates the plan against the spec's N and M
    with pytest.raises(ValueError, match="outside"):
        _flat_session().run_ps(
            ROUNDS, timing=TIMING,
            faults=FaultPlan.of(FaultPlan.crash(N + 3, 1.0)))


def test_fault_plan_churn_deterministic():
    a = FaultPlan.churn(8, seed=3, crashes=3)
    b = FaultPlan.churn(8, seed=3, crashes=3)
    assert a == b
    assert len({e.worker for e in a.events}) == 3    # distinct victims
    assert all(e.kind == "crash" and e.duration > 0 for e in a.events)
    assert FaultPlan.churn(8, seed=4, crashes=3) != a
    with pytest.raises(ValueError):
        FaultPlan.churn(2, crashes=3)


# ---------------------------------------------------------------------------
# trace persistence: new keys + forward compatibility
# ---------------------------------------------------------------------------

def test_chaos_trace_npz_roundtrip(tmp_path):
    res = _flat_session().run_ps(ROUNDS, timing=TIMING, faults=PLAN)
    path = res.trace.save(str(tmp_path / "chaos_trace"))
    loaded = DelayTrace.load(path)
    np.testing.assert_array_equal(loaded.delays, res.trace.delays)
    np.testing.assert_array_equal(loaded.participation,
                                  res.trace.participation)
    assert loaded.events == res.trace.events
    assert loaded.meta["crashes"] == res.metrics["crashes"]
    assert loaded.complete
    # the loaded trace replays identically to the in-memory one
    _assert_replay(res, _flat_session(delay_model=loaded.to_delay_model()),
                   CENTERS, bitwise=False)


def test_pre_chaos_trace_loads_with_defaults(tmp_path):
    """Forward compatibility pin: an npz written before the elastic-PS
    keys existed (delays/bound/discipline/meta only) still loads — full
    participation, empty event list, same replay."""
    res = _flat_session().run_ps(ROUNDS, timing=TIMING)
    path = str(tmp_path / "old_trace.npz")
    np.savez(path, delays=res.trace.delays,
             bound=np.int32(res.trace.bound),
             discipline=np.str_(res.trace.discipline),
             meta=np.str_(json.dumps(res.trace.meta)))
    loaded = DelayTrace.load(path)
    assert loaded.participation is None and loaded.events == []
    assert loaded.complete
    _assert_replay(res, _flat_session(delay_model=loaded.to_delay_model()),
                   CENTERS, bitwise=False)


def test_faultfree_trace_omits_chaos_keys(tmp_path):
    """Fault-free saves stay byte-compatible with pre-chaos readers:
    no participation/events keys are written."""
    res = _flat_session().run_ps(ROUNDS, timing=TIMING)
    path = res.trace.save(str(tmp_path / "ff_trace"))
    with np.load(path, allow_pickle=False) as f:
        assert "participation" not in f and "events" not in f


def test_set_participation_validates_and_erases():
    tr = DelayTrace.empty(3, 2, M, bound=2)
    tr.delays[:] = 1
    with pytest.raises(ValueError, match="rounds, N"):
        tr.set_participation(np.ones((3, 5), bool))
    part = np.ones((3, 2), bool)
    part[1, 0] = False
    tr.set_participation(part)
    assert (tr.delays[1, 0] == -1).all()     # absent row erased
    assert tr.complete
    # full participation normalizes to None (fault-free fast path)
    tr2 = DelayTrace.empty(3, 2, M, bound=2)
    tr2.delays[:] = 0
    tr2.set_participation(np.ones((3, 2), bool))
    assert tr2.participation is None


# ---------------------------------------------------------------------------
# satellite: as_service rejects negative / non-finite constants
# ---------------------------------------------------------------------------

def test_as_service_rejects_bad_constants():
    with pytest.raises(ValueError, match="finite and >= 0"):
        as_service(-1.0)
    with pytest.raises(ValueError, match="finite and >= 0"):
        as_service(float("nan"))
    with pytest.raises(ValueError, match="finite and >= 0"):
        as_service(float("inf"))
    assert as_service(0.0).sample(np.random.default_rng(0)) == 0.0
    # the CostProfile accessors surface the same actionable message
    with pytest.raises(ValueError, match="t_worker"):
        CostProfile(t_worker=-2.0).worker_service()


# ---------------------------------------------------------------------------
# SPMD chaos replay (runs under scripts/ci.sh's forced-8-device step)
# ---------------------------------------------------------------------------

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(scripts/ci.sh runs this file's spmd tests under it)")


@needs8
def test_spmd_chaos_trace_replay():
    """Crash+rejoin participation masks apply identically inside the
    SPMD-sharded epoch: the chaos trace replays over the (data=4,
    model=2) mesh at the SPMD parity tolerance."""
    from repro.launch.mesh import make_test_mesh

    def make(dm=None, mesh=None):
        return _flat_session("pallas", delay_model=dm, mesh=mesh)
    res = make().run_ps(ROUNDS, discipline="per_push", timing=TIMING,
                        faults=PLAN)
    assert res.metrics["crashes"] >= 1
    sess = make(dm=res.to_delay_model(), mesh=make_test_mesh(8))
    state = sess.init()
    step = sess.step_fn()
    for t in range(ROUNDS):
        state, _ = step(state, CENTERS)
        np.testing.assert_allclose(
            np.asarray(jax.device_get(sess.z(state))),
            np.asarray(res.z_versions[t + 1]), rtol=1e-5, atol=1e-5,
            err_msg=f"SPMD chaos replay diverged at round {t}")
