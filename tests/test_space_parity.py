"""Flat <-> pytree parity: the same quadratic consensus problem driven
through ``FlatSpace`` and ``TreeSpace`` must produce the SAME z
trajectory (same seed, same config) — for all three block-selection
policies, under bounded delay, heterogeneous rho_i, and a sparse
general-form edge set.

Construction: dim = M * DBLK coordinates; flat block j is the
coordinate slice [j*DBLK, (j+1)*DBLK); the pytree has one leaf per
block ("w0".."w{M-1}", each (DBLK,)) pinned to block j via an explicit
TreeBlocks assignment. Both spaces then draw identical (N, M) delay and
selection randomness from the same key, so every update is elementwise
identical.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ConsensusSession
from repro.configs.base import ADMMConfig
from repro.core.blocks import TreeBlocks

N, M, DBLK = 3, 4, 5
DIM = M * DBLK

# every worker keeps >= 1 block; block 0 is shared by all
EDGE = np.array([[1, 1, 0, 1],
                 [1, 0, 1, 0],
                 [1, 1, 1, 1]], bool)
RHO_SCALE = np.array([0.5, 1.0, 2.0], np.float32)


def _centers():
    rng = np.random.RandomState(7)
    return jnp.asarray(rng.randn(N, DIM).astype(np.float32))


def _flat_loss(z, c):
    return 0.5 * jnp.sum(jnp.square(z - c))


def _tree_params():
    return {f"w{j}": jnp.zeros((DBLK,), jnp.float32) for j in range(M)}


def _tree_loss(p, c):
    z = jnp.concatenate([p[f"w{j}"] for j in range(M)])
    return 0.5 * jnp.sum(jnp.square(z - c))


def _tree_z(sess, state):
    zt = sess.z(state)
    return jnp.concatenate([zt[f"w{j}"] for j in range(M)])


@pytest.mark.parametrize("scheme", ["random", "cyclic", "gauss_southwell"])
def test_flat_tree_same_z_trajectory(scheme):
    cfg = ADMMConfig(rho=2.0, gamma=0.1, max_delay=1, block_fraction=0.5,
                     num_blocks=M, block_selection=scheme, l1_coef=1e-3,
                     seed=0)
    centers = _centers()

    flat = ConsensusSession.flat(_flat_loss, centers, dim=DIM, cfg=cfg,
                                 edge=EDGE, rho_scale=RHO_SCALE)

    params = _tree_params()
    # leaf k of the sorted dict IS flat block k
    tblocks = TreeBlocks(num_blocks=M, leaf_block_ids=tuple(range(M)),
                         treedef=jax.tree.structure(params))
    tree = ConsensusSession.pytree(_tree_loss, params, cfg, num_workers=N,
                                   blocks=tblocks, edge=EDGE,
                                   rho_scale=RHO_SCALE)

    sf = flat.init()
    st = tree.init()
    step_f = flat.step_fn()
    step_t = tree.step_fn()
    traj_err = []
    for t in range(25):
        sf, info_f = step_f(sf, centers)
        st, info_t = step_t(st, centers)
        zf = np.asarray(flat.z(sf))
        zt = np.asarray(_tree_z(tree, st))
        traj_err.append(float(np.max(np.abs(zf - zt))))
        np.testing.assert_allclose(zf, zt, rtol=1e-6, atol=1e-6,
                                   err_msg=f"{scheme} diverged at epoch {t}")
        np.testing.assert_allclose(float(info_f["selected_fraction"]),
                                   float(info_t["selected_fraction"]),
                                   atol=1e-7)
    # and the run actually moved somewhere
    assert float(np.max(np.abs(zf))) > 0.0, traj_err


def test_pytree_edge_set_respected():
    """Workers never touch blocks outside their edge neighborhood: the
    duals y of a (worker, block) pair outside E stay exactly zero."""
    cfg = ADMMConfig(rho=2.0, gamma=0.1, max_delay=1, block_fraction=1.0,
                     num_blocks=M, seed=1)
    params = _tree_params()
    tblocks = TreeBlocks(num_blocks=M, leaf_block_ids=tuple(range(M)),
                         treedef=jax.tree.structure(params))
    sess = ConsensusSession.pytree(_tree_loss, params, cfg, num_workers=N,
                                   blocks=tblocks, edge=EDGE)
    state = sess.init()
    step = sess.step_fn()
    centers = _centers()
    for _ in range(5):
        state, _ = step(state, centers)
    y = np.asarray(state.y)          # packed (N, M, dblk) worker bundle
    for j in range(M):
        outside = ~EDGE[:, j]
        assert np.all(y[outside, j] == 0.0), (j, y)
        inside = EDGE[:, j]
        assert np.any(y[inside, j] != 0.0), (j, y)


def test_pytree_heterogeneous_rho_changes_trajectory():
    """rho_scale is actually honored in pytree mode (not silently
    ignored as before the VariableSpace refactor)."""
    cfg = ADMMConfig(rho=2.0, gamma=0.1, max_delay=0, block_fraction=1.0,
                     num_blocks=M, seed=0)
    params = _tree_params()
    tblocks = TreeBlocks(num_blocks=M, leaf_block_ids=tuple(range(M)),
                         treedef=jax.tree.structure(params))
    centers = _centers()

    def final_z(rho_scale):
        sess = ConsensusSession.pytree(_tree_loss, params, cfg,
                                       num_workers=N, blocks=tblocks,
                                       rho_scale=rho_scale)
        state = sess.init()
        step = sess.step_fn()
        for _ in range(10):
            state, _ = step(state, centers)
        return np.asarray(_tree_z(sess, state))

    z_homog = final_z(None)
    z_heterog = final_z(RHO_SCALE)
    assert np.isfinite(z_homog).all() and np.isfinite(z_heterog).all()
    assert float(np.max(np.abs(z_homog - z_heterog))) > 1e-4
