"""End-to-end system behaviour tests: the paper's full workflow.

1. Solve the paper's sparse logistic regression with AsyBADMM (async,
   block-wise, delayed) and verify it reaches a stationary point whose
   objective matches the synchronous reference.
2. Train a reduced transformer with the ADMM consensus trainer and
   verify the loss drops and the consensus params serve correctly.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.configs.base import ADMMConfig
from repro.core import make_problem, run, stationarity
from repro.data import TokenPipeline, make_sparse_logreg
from repro.models import build_model
from repro.serving import Engine
from repro.training import ADMMTrainer


def test_paper_workflow_sparse_logreg():
    data = make_sparse_logreg(num_workers=8, samples_per_worker=40, dim=256,
                              density=0.02, locality=0.8, seed=7)

    def loss_fn(z, d):
        X, y = d
        return jnp.mean(jnp.log1p(jnp.exp(-y * (X @ z))))

    prob = make_problem(loss_fn, (jnp.asarray(data.X), jnp.asarray(data.y)),
                        dim=256, num_blocks=32, support=data.support,
                        l1_coef=1e-3, clip=1e4)
    # the edge set is genuinely sparse (each worker touches few blocks)
    assert float(jnp.mean(prob.edge)) < 1.0

    sync = ADMMConfig(rho=2.0, gamma=0.0, max_delay=0, block_fraction=1.0,
                      num_blocks=32)
    st_sync, hist_sync = run(prob, sync, 300, eval_every=300)

    asyn = ADMMConfig(rho=2.0, gamma=0.1, max_delay=3, block_fraction=0.3,
                      num_blocks=32, seed=5)
    st_async, hist_async = run(prob, asyn, 1200, eval_every=1200)

    obj_sync = hist_sync[-1]["objective"]
    obj_async = hist_async[-1]["objective"]
    assert obj_async < obj_sync * 1.2 + 0.1
    P = float(stationarity(prob, st_async, asyn.rho)["P"])
    assert np.isfinite(P) and P < 5.0


def test_transformer_admm_train_and_serve():
    cfg = get_smoke("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=33,
                         global_batch=8, seed=0, branch=2)
    tr = ADMMTrainer(
        loss_fn=model.loss,
        admm=ADMMConfig(rho=5.0, gamma=0.01, max_delay=1,
                        block_fraction=1.0, num_blocks=4),
        num_workers=4)
    state = tr.init(params)
    step = jax.jit(tr.train_step)
    losses = []
    for i in range(25):
        state, info = step(state, pipe.batch(i, num_workers=4))
        losses.append(float(info["loss"]))
    assert losses[-1] < losses[0]

    # consensus params serve
    engine = Engine(model, state.params, max_len=16)
    prompts = np.random.RandomState(0).randint(0, cfg.vocab_size, (2, 4))
    res = engine.generate(prompts, max_new=4)
    assert res.tokens.shape == (2, 4)
    assert np.isfinite(losses).all()
