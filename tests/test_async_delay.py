"""Bounded-delay simulation semantics (Assumption 3) + sync/async parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # collection degrades to skip without the test extra
from hypothesis import given, settings, strategies as st

from repro.configs.base import ADMMConfig
from repro.core import init_state, make_problem, make_step_fn
from repro.core.async_sim import (gather_delayed, push_history,
                                  sample_delays, select_blocks)
from repro.core.space import ParetoDelay


def test_push_history_ring():
    h = jnp.zeros((3, 2, 4))
    h1 = push_history(h, jnp.ones((2, 4)))
    assert float(h1[0].sum()) == 8.0 and float(h1[1].sum()) == 0.0
    h2 = push_history(h1, 2 * jnp.ones((2, 4)))
    assert float(h2[0, 0, 0]) == 2.0 and float(h2[1, 0, 0]) == 1.0


def test_gather_delayed_indices():
    D, M, dblk = 3, 4, 2
    h = jnp.arange(D * M * dblk, dtype=jnp.float32).reshape(D, M, dblk)
    delays = jnp.array([[0, 1, 2, 0], [2, 2, 0, 1]])
    out = gather_delayed(h, delays)
    assert out.shape == (2, M, dblk)
    np.testing.assert_array_equal(out[0, 1], h[1, 1])
    np.testing.assert_array_equal(out[1, 0], h[2, 0])


@given(st.integers(0, 5), st.integers(1, 6), st.integers(1, 6))
@settings(max_examples=30, deadline=None)
def test_delays_bounded(max_delay, n, m):
    d = sample_delays(jax.random.PRNGKey(0), n, m, max_delay)
    assert d.shape == (n, m)
    assert int(d.min()) >= 0 and int(d.max()) <= max_delay


def test_select_blocks_respects_edge():
    edge = jnp.array([[True, True, False, False],
                      [False, False, True, True]])
    for frac in (0.25, 0.5):
        sel = select_blocks(jax.random.PRNGKey(1), edge, frac)
        assert not bool(jnp.any(sel & ~edge))
        assert bool(jnp.all(sel.sum(axis=1) >= 1))


def test_select_blocks_full_fraction_is_edge():
    edge = jnp.asarray(np.random.RandomState(0).rand(3, 5) < 0.6)
    sel = select_blocks(jax.random.PRNGKey(0), edge, 1.0)
    np.testing.assert_array_equal(sel, edge)


@given(st.integers(0, 5), st.floats(0.6, 3.0))
@settings(max_examples=20, deadline=None)
def test_pareto_delay_bounded(max_delay, alpha):
    """Clipped heavy-tail stays inside the ring depth for any alpha.
    (The distribution-shape tests live in test_spmd_parity.py, which
    runs without the hypothesis extra.)"""
    dm = ParetoDelay(max_delay, alpha=alpha)
    assert dm.depth == max_delay + 1
    d = dm.sample(jax.random.PRNGKey(0), 7, 5)
    assert d.shape == (7, 5) and d.dtype == jnp.int32
    assert int(d.min()) >= 0 and int(d.max()) <= max_delay


def test_sync_equals_zero_delay():
    """max_delay=0 with depth-1 history must equal the synchronous
    algorithm: z~ == z for every worker, every step."""
    rng = np.random.RandomState(0)
    X = rng.randn(3, 20, 12).astype(np.float32)
    y = np.sign(rng.randn(3, 20)).astype(np.float32)

    def loss_fn(z, d):
        Xi, yi = d
        return jnp.mean(jnp.log1p(jnp.exp(-yi * (Xi @ z))))

    prob = make_problem(loss_fn, (jnp.asarray(X), jnp.asarray(y)), 12,
                        num_blocks=3, l1_coef=1e-3)
    cfg = ADMMConfig(rho=2.0, gamma=0.0, max_delay=0, block_fraction=1.0,
                     num_blocks=3)
    state = init_state(prob, cfg)
    step = make_step_fn(prob, cfg)
    for _ in range(5):
        state = step(state)
    # reference manual synchronous iteration
    z = jnp.zeros(12)
    yv = jnp.zeros((3, 12))
    rho, gamma = 2.0, 0.0
    for _ in range(5):
        g = jax.vmap(lambda d: jax.grad(loss_fn)(z, d))(prob.data)
        x = z[None] - (g + yv) / rho
        yv = yv + rho * (x - z[None])
        w = rho * x + yv
        mu = gamma + rho * 3
        v = (gamma * z + w.sum(0)) / mu
        z = jnp.sign(v) * jnp.maximum(jnp.abs(v) - 1e-3 / mu, 0.0)
    z_state = prob.blocks.from_blocks(state.z_blocks)
    np.testing.assert_allclose(np.asarray(z_state), np.asarray(z),
                               rtol=1e-5, atol=1e-6)
