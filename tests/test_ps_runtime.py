"""PS runtime (repro.ps): runtime <-> replay parity, bounded-staleness
enforcement, trace IO, and discipline behavior.

The headline pin: a ``DelayTrace`` recorded by the event-driven
Parameter Server runtime, replayed via ``TraceDelay`` through the
vectorized ``asybadmm_epoch``, reproduces the runtime's z trajectory —
for both spaces (flat / tree), both backends (jnp / pallas), both
coordination disciplines (lockfree / locked), and the SPMD epoch.
The replay is structurally exact (delays, selection, push/commit
round-ordering are integers) and float-exact up to cross-program XLA
fusion: the pallas backend pins BITWISE equality (interpret-mode
kernels are fusion-stable), jnp pins at the same fp32 ulp tolerance
class as the repo's other same-math-different-program parity suites
(backend/SPMD parity).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ConsensusSession
from repro.configs.base import ADMMConfig
from repro.core.blocks import TreeBlocks
from repro.core.space import DELAY_MODELS, TraceDelay
from repro.ps import (ConstantService, CostProfile, DelayTrace,
                      EventScheduler, LognormalService, ParetoService,
                      PSRuntime)

N, M, DBLK = 3, 4, 5
DIM = M * DBLK
ROUNDS = 6

_r = np.random.RandomState(7)
CENTERS = jnp.asarray(_r.randn(N, DIM).astype(np.float32))
EDGE = np.array([[1, 1, 0, 1],
                 [1, 0, 1, 0],
                 [1, 1, 1, 1]], bool)
RHO_SCALE = np.array([0.5, 1.0, 2.0], np.float32)

STRAGGLER = CostProfile(t_worker=ParetoService(1.0, alpha=1.2),
                        t_server_block=LognormalService(0.3, 0.4))


def _cfg(scheme="random", max_delay=2, **kw):
    return ADMMConfig(rho=2.0, gamma=0.1, max_delay=max_delay,
                      block_fraction=0.5, num_blocks=M,
                      block_selection=scheme, l1_coef=1e-3, clip=0.8,
                      seed=0, **kw)


def _flat_loss(z, c):
    return 0.5 * jnp.sum(jnp.square(z - c))


def _flat_session(backend="jnp", delay_model=None, cfg=None, mesh=None):
    return ConsensusSession.flat(
        _flat_loss, CENTERS, dim=DIM, cfg=cfg or _cfg(), edge=EDGE,
        rho_scale=RHO_SCALE, backend=backend, delay_model=delay_model,
        mesh=mesh)


def _tree_params():
    return {f"w{j}": jnp.zeros((DBLK,), jnp.float32) for j in range(M)}


def _tree_loss(p, c):
    z = jnp.concatenate([p[f"w{j}"] for j in range(M)])
    return 0.5 * jnp.sum(jnp.square(z - c))


def _tree_session(backend="jnp", delay_model=None, cfg=None):
    params = _tree_params()
    tblocks = TreeBlocks(num_blocks=M, leaf_block_ids=tuple(range(M)),
                         treedef=jax.tree.structure(params))
    return ConsensusSession.pytree(
        _tree_loss, params, cfg or _cfg(), num_workers=N, blocks=tblocks,
        edge=EDGE, rho_scale=RHO_SCALE, backend=backend,
        delay_model=delay_model)


def _tree_vec(zt):
    return np.concatenate([np.asarray(zt[f"w{j}"]).ravel()
                           for j in range(M)])


def _assert_replay(res, sess2, data, to_vec, bitwise):
    state = sess2.init()
    step = sess2.step_fn()
    for t in range(res.num_rounds):
        state, _ = step(state, data)
        replay = to_vec(sess2.z(state))
        runtime = to_vec(res.z_versions[t + 1])      # user representation
        if bitwise:
            np.testing.assert_array_equal(
                replay, runtime, err_msg=f"replay diverged at round {t}")
        else:
            np.testing.assert_allclose(
                replay, runtime, rtol=1e-5, atol=1e-6,
                err_msg=f"replay diverged at round {t}")


# ---------------------------------------------------------------------------
# runtime <-> replay parity (the acceptance pin)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("discipline", ["lockfree", "locked"])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_flat_runtime_replay_parity(backend, discipline):
    sess = _flat_session(backend)
    res = sess.run_ps(ROUNDS, discipline=discipline, timing=STRAGGLER)
    assert res.trace.complete and res.trace.delays.max() <= 2
    sess2 = _flat_session(backend, delay_model=res.to_delay_model())
    _assert_replay(res, sess2, CENTERS,
                   lambda z: np.asarray(z).ravel(),
                   bitwise=backend == "pallas")


@pytest.mark.parametrize("discipline", ["lockfree", "locked"])
@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_tree_runtime_replay_parity(backend, discipline):
    sess = _tree_session(backend)
    res = sess.run_ps(ROUNDS, discipline=discipline, timing=STRAGGLER,
                      batches=lambda t: CENTERS)
    sess2 = _tree_session(backend, delay_model=res.to_delay_model())
    _assert_replay(res, sess2, CENTERS, _tree_vec,
                   bitwise=backend == "pallas")


@pytest.mark.parametrize("scheme", ["cyclic", "gauss_southwell"])
def test_selector_runtime_replay_parity(scheme):
    """Selection runs on the epoch's key chain inside the runtime, so
    non-default selectors replay too (Gauss-Southwell additionally
    exercises the per-row gradient-norm path)."""
    sess = _flat_session(cfg=_cfg(scheme))
    res = sess.run_ps(ROUNDS, timing=STRAGGLER)
    sess2 = _flat_session(cfg=_cfg(scheme), delay_model=res.to_delay_model())
    _assert_replay(res, sess2, CENTERS, lambda z: np.asarray(z).ravel(),
                   bitwise=False)


def test_custom_selector_runtime_replay_parity():
    """A user-registered selector is conservatively fed real gradient
    norms under the runtime (only the built-in random/cyclic are known
    gradient-free), so custom policies replay too — and timing-only
    mode refuses them rather than silently zeroing the norms."""
    def top1_by_gnorm(ctx):
        g = jnp.where(ctx.edge, ctx.grad_sqnorm(), -jnp.inf)
        best = jnp.argmax(g, axis=1)
        sel = jax.nn.one_hot(best, ctx.edge.shape[1], dtype=bool)
        return sel & ctx.edge

    def make(dm=None):
        return ConsensusSession.flat(
            _flat_loss, CENTERS, dim=DIM, cfg=_cfg(), edge=EDGE,
            rho_scale=RHO_SCALE, selector=top1_by_gnorm, delay_model=dm)
    sess = make()
    res = sess.run_ps(ROUNDS, timing=STRAGGLER)
    _assert_replay(res, make(res.to_delay_model()), CENTERS,
                   lambda z: np.asarray(z).ravel(), bitwise=False)
    with pytest.raises(ValueError):
        PSRuntime(make().spec, compute="timing")


def test_minibatch_runtime_replay_parity():
    """Incremental workers: the runtime's per-round minibatch draw is
    the epoch's (same key chain), so stochastic-gradient runs replay."""
    cfg = _cfg(minibatch=0.5)
    rng = np.random.RandomState(3)
    X = jnp.asarray(rng.randn(N, 24, DIM).astype(np.float32))
    y = jnp.asarray(np.sign(rng.randn(N, 24)).astype(np.float32))

    def loss(z, d):
        Xi, yi = d
        return jnp.mean(jnp.log1p(jnp.exp(-yi * (Xi @ z))))

    def make(dm=None):
        return ConsensusSession.flat(loss, (X, y), dim=DIM, cfg=cfg,
                                     delay_model=dm)
    sess = make()
    res = sess.run_ps(ROUNDS, timing=STRAGGLER)
    _assert_replay(res, make(res.to_delay_model()), sess.data,
                   lambda z: np.asarray(z).ravel(), bitwise=False)


def test_runtime_loss_matches_replay_info():
    """The runtime's per-round mean worker loss equals the epoch
    info['loss'] under replay (same grads at the same stale reads)."""
    sess = _flat_session()
    res = sess.run_ps(ROUNDS, timing=STRAGGLER)
    sess2 = _flat_session(delay_model=res.to_delay_model())
    state = sess2.init()
    step = sess2.step_fn()
    for t in range(ROUNDS):
        state, info = step(state, CENTERS)
        np.testing.assert_allclose(res.losses[t], float(info["loss"]),
                                   rtol=1e-5)


# ---------------------------------------------------------------------------
# bounded-staleness enforcement (Assumption 3)
# ---------------------------------------------------------------------------

def _staleness_run(discipline, bound, timing, rounds=10, scheme="random"):
    sess = _flat_session(cfg=_cfg(scheme, max_delay=bound))
    rt = PSRuntime(sess.spec, discipline=discipline, timing=timing,
                   compute="timing")
    return rt.run(rounds)


@pytest.mark.parametrize("discipline", ["lockfree", "locked"])
@pytest.mark.parametrize("bound", [0, 1, 3])
def test_no_pull_ever_exceeds_bound(discipline, bound):
    """Deterministic sweep of the property the enforcer guarantees: no
    served pull observes a version older than T, across disciplines and
    straggler models — even when servers straggle so hard that pulls
    must stall."""
    slow_servers = CostProfile(t_worker=ConstantService(0.1),
                               t_server_block=ParetoService(1.0, alpha=1.1))
    res = _staleness_run(discipline, bound, slow_servers)
    assert res.metrics["max_served_tau"] <= bound
    assert int(res.trace.delays.max()) <= bound
    assert int(res.trace.delays.min()) >= 0
    if bound <= 1:
        # fast workers + straggling servers must actually stall (the
        # enforcer is enforcing, not vacuously passing)
        assert res.metrics["stall_count"] > 0


def test_stalls_account_simulated_time():
    res = _staleness_run("locked", 0, CostProfile(
        t_worker=ConstantService(0.1), t_server_block=ConstantService(1.0)))
    assert res.metrics["stall_count"] > 0
    assert res.metrics["stall_time"] > 0.0
    assert res.metrics["makespan"] > 0.0


try:
    import hypothesis  # noqa: F401
    from hypothesis import given, settings, strategies as st

    @given(bound=st.integers(0, 3),
           discipline=st.sampled_from(["lockfree", "locked"]),
           worker_alpha=st.floats(1.05, 2.5),
           server_mean=st.floats(0.05, 3.0))
    @settings(max_examples=15, deadline=None)
    def test_staleness_bound_property(bound, discipline, worker_alpha,
                                      server_mean):
        """Property form of the Assumption-3 guarantee under arbitrary
        straggler profiles."""
        timing = CostProfile(
            t_worker=ParetoService(1.0, alpha=worker_alpha),
            t_server_block=LognormalService(server_mean, 0.5))
        res = _staleness_run(discipline, bound, timing, rounds=6)
        assert res.metrics["max_served_tau"] <= bound
        assert int(res.trace.delays.max()) <= bound
except ImportError:                     # pragma: no cover - optional extra
    pass


# ---------------------------------------------------------------------------
# network latency (constant + jitter on every worker<->server message)
# ---------------------------------------------------------------------------

def test_net_latency_slows_makespan_but_replays():
    """A lagged network stretches the makespan (pull responses and
    declaration/push bundles spend time in flight) and reshapes the
    observed staleness — but the trace still records exactly what each
    worker saw, so epoch replay parity is untouched."""
    from repro.ps import NetworkModel
    base = CostProfile(t_worker=ConstantService(1.0),
                       t_server_block=ConstantService(0.25))
    lag = CostProfile(t_worker=ConstantService(1.0),
                      t_server_block=ConstantService(0.25),
                      net=NetworkModel(0.5, 0.2))
    res0 = _flat_session().run_ps(ROUNDS, timing=base)
    res = _flat_session().run_ps(ROUNDS, timing=lag)
    # each round's critical path pays >= one pull response + one declare
    assert res.makespan >= res0.makespan + ROUNDS * 0.5
    assert res.trace.meta["net_latency"] == 0.5
    assert res.trace.meta["net_jitter"] == 0.2
    assert res.trace.complete and res.metrics["max_served_tau"] <= 2
    sess2 = _flat_session(delay_model=res.to_delay_model())
    _assert_replay(res, sess2, CENTERS, lambda z: np.asarray(z).ravel(),
                   bitwise=False)


def test_net_latency_deterministic_and_coerced():
    from repro.ps import NetworkModel, as_network
    timing = CostProfile(net=0.25)               # float -> constant model
    assert timing.network() == NetworkModel(0.25)
    assert as_network(None) is None
    assert as_network(0.0) is None               # ideal network: no model
    assert as_network(NetworkModel(0.0, 0.0)) is None
    with pytest.raises(ValueError):
        NetworkModel(-1.0)
    runs = [_flat_session().run_ps(
        ROUNDS, timing=CostProfile(net=NetworkModel(0.3, 0.1)))
        for _ in range(2)]
    np.testing.assert_array_equal(runs[0].trace.delays,
                                  runs[1].trace.delays)
    assert runs[0].makespan == runs[1].makespan


# ---------------------------------------------------------------------------
# trace recording / persistence / TraceDelay
# ---------------------------------------------------------------------------

def test_trace_save_load_roundtrip(tmp_path):
    sess = _flat_session()
    res = sess.run_ps(ROUNDS, timing=STRAGGLER)
    path = res.trace.save(str(tmp_path / "trace"))
    loaded = DelayTrace.load(path)
    np.testing.assert_array_equal(loaded.delays, res.trace.delays)
    assert loaded.bound == res.trace.bound
    assert loaded.discipline == res.trace.discipline
    assert loaded.meta["makespan"] == pytest.approx(res.makespan)
    # TraceDelay.load reads the same file
    dm = TraceDelay.load(path)
    np.testing.assert_array_equal(dm.delays, res.trace.delays)


def test_trace_delay_registered_and_samples():
    assert DELAY_MODELS["trace"] is TraceDelay
    delays = np.random.RandomState(0).randint(0, 3, (4, N, M))
    dm = TraceDelay(delays)
    assert dm.depth == int(delays.max()) + 1
    for t in [0, 2, 3, 7]:                       # 7 clamps to final round
        out = np.asarray(dm.sample(jax.random.PRNGKey(0), N, M, t=t))
        np.testing.assert_array_equal(out, delays[min(t, 3)])
    with pytest.raises(ValueError):
        dm.sample(jax.random.PRNGKey(0), N, M)   # epoch counter required
    with pytest.raises(ValueError):
        dm.sample(jax.random.PRNGKey(0), N + 1, M, t=0)  # shape mismatch
    with pytest.raises(ValueError):
        TraceDelay(np.array([[1, 2], [3, 4]]))   # not (rounds, N, M)


def test_incomplete_trace_rejected():
    tr = DelayTrace.empty(3, N, M, bound=2)
    with pytest.raises(ValueError):
        tr.validate()
    with pytest.raises(ValueError):
        tr.to_delay_model()


# ---------------------------------------------------------------------------
# disciplines + scheduler + runtime surface
# ---------------------------------------------------------------------------

def test_locked_serializes_lockfree_does_not():
    """Same deterministic coordination-bound config, only the lock
    discipline differs: the full-vector lock's M-serial commit must
    cost strictly more wall-clock (the paper's §1 claim, and what the
    CI speedup gate measures at benchmark scale)."""
    timing = CostProfile(t_worker=ConstantService(1.0),
                         t_server_block=ConstantService(1.0))
    spans = {}
    for d in ("lockfree", "locked"):
        sess = _flat_session()
        rt = PSRuntime(sess.spec, discipline=d, timing=timing,
                       compute="timing")
        spans[d] = rt.run(8).makespan
    assert spans["locked"] > spans["lockfree"] * 1.2


def test_locked_pull_sees_uniform_version():
    """Under the full-vector lock every block is the same version, so
    each recorded delay row is constant across blocks."""
    sess = _flat_session()
    res = sess.run_ps(ROUNDS, discipline="locked", timing=STRAGGLER)
    assert (res.trace.delays == res.trace.delays[:, :, :1]).all()


def test_event_scheduler_deterministic_ties():
    order = []
    s = EventScheduler()
    s.at(1.0, lambda: order.append("a"))
    s.at(0.5, lambda: order.append("b"))
    s.at(1.0, lambda: order.append("c"))
    assert s.run() == 1.0
    assert order == ["b", "a", "c"]
    with pytest.raises(ValueError):
        s.at(0.1, lambda: None)                  # scheduling in the past


def test_runtime_rejects_bad_config():
    sess = _flat_session()
    with pytest.raises(ValueError):
        PSRuntime(sess.spec, data=sess.data, discipline="quantum")
    with pytest.raises(ValueError):
        PSRuntime(sess.spec, data=sess.data, compute="psychic")
    with pytest.raises(ValueError):              # real mode needs data
        PSRuntime(_flat_session().spec)
    with pytest.raises(ValueError):              # GS needs gradients
        PSRuntime(_flat_session(cfg=_cfg("gauss_southwell")).spec,
                  compute="timing")
    rt = PSRuntime(sess.spec, data=sess.data)
    with pytest.raises(ValueError):
        rt.run(0)


def test_timing_only_records_no_z():
    sess = _flat_session()
    rt = PSRuntime(sess.spec, compute="timing",
                   timing=CostProfile(t_worker=ConstantService(1.0)))
    res = rt.run(4)
    assert res.z_versions is None and res.losses is None
    assert res.z_final is None
    assert res.trace.complete


def test_record_z_false_prunes_but_matches():
    """Long-training memory mode: record_z=False keeps only the live
    staleness window of committed versions per block server, yet
    z_final (user representation) matches the full-recording run."""
    full = _flat_session().run_ps(ROUNDS, timing=STRAGGLER)
    sess = _flat_session()
    rt = PSRuntime(sess.spec, data=sess.data, timing=STRAGGLER,
                   record_z=False)
    res = rt.run(ROUNDS)
    assert res.z_versions is None
    np.testing.assert_array_equal(np.asarray(res.z_final),
                                  np.asarray(full.z_final))
    np.testing.assert_array_equal(res.trace.delays, full.trace.delays)
    bound = sess.spec.delay_model.depth - 1
    for dom in rt.domains:
        for j in dom.block_ids:
            assert len(dom.contents[j]) <= bound + 2


def test_run_ps_deterministic():
    """Same session, same timing -> identical trace and makespan."""
    runs = [
        _flat_session().run_ps(ROUNDS, timing=STRAGGLER) for _ in range(2)]
    np.testing.assert_array_equal(runs[0].trace.delays,
                                  runs[1].trace.delays)
    assert runs[0].makespan == runs[1].makespan
    np.testing.assert_array_equal(np.asarray(runs[0].z_final),
                                  np.asarray(runs[1].z_final))


# ---------------------------------------------------------------------------
# SPMD replay (runs under scripts/ci.sh's forced-8-device step)
# ---------------------------------------------------------------------------

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(scripts/ci.sh runs this file's spmd tests under it)")


@needs8
def test_spmd_trace_replay():
    """A runtime-recorded trace replays through the SPMD-sharded epoch:
    the mesh run's z trajectory matches the runtime's at the SPMD
    parity suite's tolerance (the worker reduction's psum changes float
    order — same contract as tests/test_spmd_parity.py)."""
    from repro.launch.mesh import make_test_mesh

    N8, M8 = 4, 8
    dim = M8 * DBLK
    centers = jnp.asarray(
        np.random.RandomState(5).randn(N8, dim).astype(np.float32))
    cfg = ADMMConfig(rho=2.0, gamma=0.1, max_delay=2, block_fraction=0.5,
                     num_blocks=M8, l1_coef=1e-3, clip=0.8, seed=0)

    def make(dm=None, mesh=None):
        return ConsensusSession.flat(_flat_loss, centers, dim=dim, cfg=cfg,
                                     delay_model=dm, mesh=mesh,
                                     backend="pallas")
    res = make().run_ps(ROUNDS, timing=STRAGGLER)
    sess = make(dm=res.to_delay_model(), mesh=make_test_mesh(8))
    state = sess.init()
    step = sess.step_fn()
    for t in range(ROUNDS):
        state, _ = step(state, centers)
        np.testing.assert_allclose(
            np.asarray(jax.device_get(sess.z(state))),
            np.asarray(res.z_versions[t + 1]), rtol=1e-5, atol=1e-5,
            err_msg=f"SPMD replay diverged at round {t}")


@needs8
def test_tree_spmd_trace_replay():
    """Pytree models close the loop too since the packed-layout
    lowering: a PS-runtime trace recorded for a pytree session replays
    through the SPMD epoch with the z ring sharded over ``model`` —
    the tree x SPMD cell of the support matrix, now native."""
    from repro.launch.mesh import make_test_mesh

    N8, M8 = 4, 8
    dim = M8 * DBLK
    centers = jnp.asarray(
        np.random.RandomState(6).randn(N8, dim).astype(np.float32))
    params = {f"w{j}": jnp.zeros((DBLK,), jnp.float32) for j in range(M8)}
    tblocks = TreeBlocks(num_blocks=M8, leaf_block_ids=tuple(range(M8)),
                         treedef=jax.tree.structure(params))
    cfg = ADMMConfig(rho=2.0, gamma=0.1, max_delay=2, block_fraction=0.5,
                     num_blocks=M8, l1_coef=1e-3, clip=0.8, seed=0)

    def tree_loss(p, c):
        z = jnp.concatenate([p[f"w{j}"] for j in range(M8)])
        return 0.5 * jnp.sum(jnp.square(z - c))

    def make(dm=None, mesh=None):
        return ConsensusSession.pytree(
            tree_loss, params, cfg, num_workers=N8, blocks=tblocks,
            delay_model=dm, mesh=mesh, backend="pallas")

    res = make().run_ps(ROUNDS, timing=STRAGGLER,
                        batches=lambda t: centers)
    sess = make(dm=res.to_delay_model(), mesh=make_test_mesh(8))
    assert sess.init().z_hist.sharding.spec[1] == "model"
    state = sess.init()
    step = sess.step_fn()

    def to_vec(zt):
        return np.concatenate([np.asarray(jax.device_get(zt[f"w{j}"]))
                               for j in range(M8)])
    for t in range(ROUNDS):
        state, _ = step(state, centers)
        np.testing.assert_allclose(
            to_vec(sess.z(state)), to_vec(res.z_versions[t + 1]),
            rtol=1e-5, atol=1e-5,
            err_msg=f"tree SPMD replay diverged at round {t}")
