"""pallas <-> jnp backend parity: the kernel-backed epoch (interpret
mode on CPU; the same code compiles to Mosaic on TPU) must produce the
SAME z trajectory as the pure-jnp composition — for both spaces
(``FlatSpace`` / ``TreeSpace``), all three block-selection policies,
and both delay models. Mirrors ``test_space_parity.py``: selection /
delay randomness is drawn identically, so the only difference between
the two runs is WHO executes the elementwise hot path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ConsensusSession
from repro.configs.base import ADMMConfig
from repro.core.blocks import TreeBlocks
from repro.core.space import ConstantDelay, UniformDelay, resolve_backend

N, M, DBLK = 3, 4, 5
DIM = M * DBLK
EPOCHS = 8
TOL = 1e-5

EDGE = np.array([[1, 1, 0, 1],
                 [1, 0, 1, 0],
                 [1, 1, 1, 1]], bool)
RHO_SCALE = np.array([0.5, 1.0, 2.0], np.float32)

DELAY_MODELS = {"uniform": UniformDelay(1), "constant": ConstantDelay(1)}


def _centers():
    rng = np.random.RandomState(7)
    return jnp.asarray(rng.randn(N, DIM).astype(np.float32))


def _flat_loss(z, c):
    return 0.5 * jnp.sum(jnp.square(z - c))


def _tree_params():
    return {f"w{j}": jnp.zeros((DBLK,), jnp.float32) for j in range(M)}


def _tree_loss(p, c):
    z = jnp.concatenate([p[f"w{j}"] for j in range(M)])
    return 0.5 * jnp.sum(jnp.square(z - c))


def _cfg(scheme):
    # l1 + clip: the exact prox family the fused server kernel owns
    return ADMMConfig(rho=2.0, gamma=0.1, max_delay=1, block_fraction=0.5,
                      num_blocks=M, block_selection=scheme, l1_coef=1e-3,
                      clip=0.8, seed=0)


def _run_pair(make_session, to_vec):
    sessions = {b: make_session(b) for b in ("jnp", "pallas")}
    states = {b: s.init() for b, s in sessions.items()}
    steps = {b: s.step_fn() for b, s in sessions.items()}
    centers = _centers()
    for t in range(EPOCHS):
        zs = {}
        for b in sessions:
            states[b], _ = steps[b](states[b], centers)
            zs[b] = np.asarray(to_vec(sessions[b], states[b]))
        np.testing.assert_allclose(
            zs["pallas"], zs["jnp"], rtol=TOL, atol=TOL,
            err_msg=f"backends diverged at epoch {t}")
    assert np.max(np.abs(zs["jnp"])) > 0.0      # the run actually moved


@pytest.mark.parametrize("delay", sorted(DELAY_MODELS))
@pytest.mark.parametrize("scheme", ["random", "cyclic", "gauss_southwell"])
def test_flat_backend_parity(scheme, delay):
    def make(backend):
        return ConsensusSession.flat(
            _flat_loss, _centers(), dim=DIM, cfg=_cfg(scheme), edge=EDGE,
            rho_scale=RHO_SCALE, delay_model=DELAY_MODELS[delay],
            backend=backend)
    _run_pair(make, lambda s, st: s.z(st))


@pytest.mark.parametrize("delay", sorted(DELAY_MODELS))
@pytest.mark.parametrize("scheme", ["random", "cyclic", "gauss_southwell"])
def test_tree_backend_parity(scheme, delay):
    params = _tree_params()
    tblocks = TreeBlocks(num_blocks=M, leaf_block_ids=tuple(range(M)),
                         treedef=jax.tree.structure(params))

    def make(backend):
        return ConsensusSession.pytree(
            _tree_loss, params, _cfg(scheme), num_workers=N, blocks=tblocks,
            edge=EDGE, rho_scale=RHO_SCALE,
            delay_model=DELAY_MODELS[delay], backend=backend)

    def to_vec(sess, state):
        zt = sess.z(state)
        return jnp.concatenate([zt[f"w{j}"] for j in range(M)])

    _run_pair(make, to_vec)


@pytest.mark.parametrize("kwargs", [dict(l2_coef=0.5), dict(clip=0.0)])
def test_non_fusable_prox_falls_back(kwargs):
    """An l2 term pushes the prox outside the kernel family, and
    clip=0.0 means the degenerate box {0} (the kernel encodes 0.0 as
    "no box"); in both cases the pallas backend must fall back to the
    jnp server path, not silently change the prox."""
    centers = _centers()

    def final_z(backend):
        sess = ConsensusSession.flat(
            _flat_loss, centers, dim=DIM, cfg=_cfg("random"),
            backend=backend, **kwargs)
        state = sess.init()
        step = sess.step_fn()
        for _ in range(5):
            state, _ = step(state, centers)
        return np.asarray(sess.z(state))

    np.testing.assert_allclose(final_z("pallas"), final_z("jnp"),
                               rtol=TOL, atol=TOL)


def test_resolve_backend():
    assert resolve_backend(None) in ("jnp", "pallas")
    assert resolve_backend("auto") == resolve_backend(None)
    assert resolve_backend("jnp") == "jnp"
    assert resolve_backend("pallas") == "pallas"
    with pytest.raises(ValueError):
        resolve_backend("tpu")
