"""Hypothesis property tests on kernel/algorithm invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # collection degrades to skip without the test extra
from hypothesis import given, settings, strategies as st

from repro.core.admm import server_update, worker_update
from repro.core.prox import make_prox
from repro.kernels import ops, ref

small = st.floats(-50.0, 50.0, allow_nan=False, width=32)
arrays = st.lists(small, min_size=1, max_size=200)


def _lane_pad(vals):
    """Embed arbitrary-length draws in the (8*128)-aligned buffer the
    kernel ops require since the layout refactor (zero fill is inert:
    the ops are elementwise)."""
    out = np.zeros(1024, np.float32)
    out[: len(vals)] = vals
    return jnp.asarray(out)


@given(arrays, arrays, arrays, st.floats(0.1, 200.0))
@settings(max_examples=40, deadline=None)
def test_kernel_matches_core_update(gs, ys, zs, rho):
    n = min(len(gs), len(ys), len(zs))
    g = _lane_pad(gs[:n])
    y = _lane_pad(ys[:n])
    z = _lane_pad(zs[:n])
    kx, ky, kw = ops.admm_worker_update(g, y, z, rho, interpret=True)
    cx, cy, cw = worker_update(g, y, z, rho)
    # kernel emits the algebraic identity y' = -g exactly; the unfused
    # core rounds through y + rho*(x - z~), so compare at fp32 tolerance
    # scaled by rho (the (g+y)/rho -> *rho round-trip loses ~rho*eps).
    atol = 1e-4 * max(1.0, rho)
    np.testing.assert_allclose(kx, cx, rtol=1e-4, atol=atol)
    np.testing.assert_allclose(ky, cy, rtol=1e-4, atol=atol)
    np.testing.assert_allclose(kw, cw, rtol=1e-4, atol=atol)


@given(arrays, st.floats(0.1, 200.0))
@settings(max_examples=30, deadline=None)
def test_w_identity(gs, rho):
    """w = rho*z~ - 2g - y (the fused identity used everywhere)."""
    g = jnp.asarray(gs, jnp.float32)
    y = jnp.sin(g)
    z = jnp.cos(g)
    _, _, w = worker_update(g, y, z, rho)
    np.testing.assert_allclose(w, rho * z - 2 * g - y, rtol=1e-4, atol=1e-4)


@given(arrays, st.floats(0.0, 2.0), st.floats(0.5, 10.0),
       st.floats(0.0, 1.0))
@settings(max_examples=30, deadline=None)
def test_server_update_fixed_point(vals, gamma, rho_sum, l1):
    """If w_sum/rho_sum == z~ and prox is identity-compatible (l1=0),
    the server update is a fixed point: z' == z~."""
    z = jnp.asarray(vals, jnp.float32)
    reg = make_prox(l1_coef=0.0)
    out = server_update(z, rho_sum * z, rho_sum, gamma, reg.prox)
    np.testing.assert_allclose(out, z, rtol=1e-5, atol=1e-5)


@given(st.integers(1, 4), st.integers(1, 5), st.integers(1, 30))
@settings(max_examples=20, deadline=None)
def test_block_roundtrip_consistency(n, m, d):
    """to_blocks/from_blocks consistency under worker batching."""
    from repro.core.blocks import make_flat_blocks
    blocks = make_flat_blocks(d, m)
    v = jnp.arange(n * d, dtype=jnp.float32).reshape(n, d)
    np.testing.assert_array_equal(blocks.from_blocks(blocks.to_blocks(v)), v)


@given(st.integers(0, 3), st.integers(2, 5))
@settings(max_examples=10, deadline=None)
def test_delay_zero_history_identity(depth_extra, m):
    """Reading delay 0 always returns the newest z regardless of depth."""
    from repro.core.async_sim import gather_delayed, push_history
    D = depth_extra
    h = jnp.zeros((D + 1, m, 4))
    h = push_history(h, jnp.ones((m, 4)) * 7)
    delays = jnp.zeros((3, m), jnp.int32)
    out = gather_delayed(h, delays)
    np.testing.assert_allclose(out, 7.0)
