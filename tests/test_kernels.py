"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode
executes the kernel body on CPU; on TPU the same code compiles).

Since the lane-aligned layout refactor the kernel ops CONSUME alignment
instead of producing it: buffers must be (8x128)-vreg aligned (flat
ops) / have d % 128 == 0 (batched ops) — the layouts in core/blocks.py
guarantee this, and raw ragged buffers raise actionable errors, pinned
below.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

# every shape is (8*128)-element aligned — the layout's output contract
SHAPES = [(1024,), (2048,), (8, 128), (2, 8, 128), (4, 2, 128)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("rho", [0.5, 100.0])
def test_admm_worker_update(shape, dtype, rho):
    rng = np.random.RandomState(hash((shape, rho)) % 2**31)
    g, y, z = [jnp.asarray(rng.randn(*shape), dtype) for _ in range(3)]
    x, yn, w = ops.admm_worker_update(g, y, z, rho, interpret=True)
    # oracle in f32 (bf16 kernel vs bf16 ref would compare two rounding
    # orders; the contract is closeness to the exact math)
    xe, yne, we = ref.admm_worker_update_ref(*(a.astype(jnp.float32)
                                               for a in (g, y, z)), rho)
    if dtype == jnp.float32:
        rtol, atol = 1e-5, 1e-4
    else:
        # bf16 has ~8 mantissa bits; outputs scale with rho*|z|
        rtol, atol = 4e-2, 4e-2 * max(1.0, rho)
    for o, e in zip((x, yn, w), (xe, yne, we)):
        assert o.shape == shape and o.dtype == dtype
        np.testing.assert_allclose(np.asarray(o, np.float32),
                                   np.asarray(e, np.float32),
                                   rtol=rtol, atol=atol)


@pytest.mark.parametrize("shape", [(64,), (7, 33), (3, 5, 17), (513,)])
def test_worker_update_rejects_unaligned(shape):
    """Ragged buffers no longer get a silent pad copy — the error names
    the layout builders that produce aligned tables."""
    a = jnp.ones(shape, jnp.float32)
    with pytest.raises(ValueError, match="make_flat_blocks"):
        ops.admm_worker_update(a, a, a, 1.0, interpret=True)


def test_admm_worker_y_identity():
    """Eq. 25: kernel's y' must equal -g exactly."""
    g = jnp.asarray(np.random.randn(1024), jnp.float32)
    o = jnp.ones(1024)
    _, yn, _ = ops.admm_worker_update(g, o, o, 3.0, interpret=True)
    np.testing.assert_array_equal(np.asarray(yn), -np.asarray(g))


@pytest.mark.parametrize("M,d", [(1, 128), (5, 256), (16, 1024), (3, 384)])
@pytest.mark.parametrize("l1,clip", [(0.0, 0.0), (0.05, 0.0), (0.05, 0.4)])
def test_prox_consensus(M, d, l1, clip):
    rng = np.random.RandomState(0)
    zt = jnp.asarray(rng.randn(M, d), jnp.float32)
    ws = jnp.asarray(rng.randn(M, d) * 3, jnp.float32)
    rs = jnp.asarray(rng.rand(M) * 5 + 0.5, jnp.float32)
    out = ops.prox_consensus(zt, ws, rs, gamma=0.1, l1=l1, clip=clip,
                             interpret=True)
    exp = ref.prox_consensus_ref(zt, ws, rs[:, None], 0.1, l1, clip)
    assert out.shape == (M, d)
    np.testing.assert_allclose(out, exp, rtol=1e-5, atol=1e-6)
    if clip > 0:
        assert float(jnp.max(jnp.abs(out))) <= clip + 1e-6


def test_prox_consensus_rejects_ragged_rows():
    zt = jnp.ones((3, 129), jnp.float32)
    with pytest.raises(ValueError, match="prox_consensus.*129"):
        ops.prox_consensus(zt, zt, jnp.ones(3), gamma=0.1, interpret=True)


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 128),
                                   (100, 50, 30), (129, 257, 65)])
@pytest.mark.parametrize("transpose_a", [False, True])
def test_matmul(m, k, n, transpose_a):
    rng = np.random.RandomState(1)
    a_shape = (k, m) if transpose_a else (m, k)
    A = jnp.asarray(rng.randn(*a_shape), jnp.float32)
    B = jnp.asarray(rng.randn(k, n), jnp.float32)
    C = ops.matmul(A, B, transpose_a=transpose_a, interpret=True)
    Ce = (A.T if transpose_a else A) @ B
    assert C.shape == (m, n)
    np.testing.assert_allclose(C, Ce, rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("m,d", [(64, 32), (200, 300), (129, 257)])
def test_logreg_grad(m, d):
    rng = np.random.RandomState(2)
    X = jnp.asarray(rng.randn(m, d), jnp.float32)
    y = jnp.asarray(rng.choice([-1.0, 1.0], m), jnp.float32)
    w = jnp.asarray(rng.randn(d) * 0.2, jnp.float32)
    g = ops.logreg_grad(X, y, w, interpret=True)
    ge = ref.logreg_grad_ref(X, y, w)
    assert g.shape == (d,)
    np.testing.assert_allclose(g, ge, rtol=1e-4, atol=1e-5)


def test_logreg_grad_matches_autodiff():
    rng = np.random.RandomState(3)
    X = jnp.asarray(rng.randn(50, 20), jnp.float32)
    y = jnp.asarray(rng.choice([-1.0, 1.0], 50), jnp.float32)
    w = jnp.asarray(rng.randn(20) * 0.3, jnp.float32)

    def loss(w_):
        return jnp.mean(jnp.log1p(jnp.exp(-y * (X @ w_))))
    np.testing.assert_allclose(ops.logreg_grad(X, y, w, interpret=True),
                               jax.grad(loss)(w), rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("N,M,d", [(3, 4, 128), (2, 8, 128), (4, 12, 256),
                                   (1, 1, 128)])
@pytest.mark.parametrize("with_x", [False, True])
def test_admm_worker_select_update(N, M, d, with_x):
    """Batched worker kernel: update (11)(12)(9) + sel-masked merges in
    one pass, per-worker heterogeneous rho as a traced operand."""
    rng = np.random.RandomState(N * 100 + M)
    g, y, zt, w, x = [jnp.asarray(rng.randn(N, M, d), jnp.float32)
                      for _ in range(5)]
    sel = jnp.asarray(rng.rand(N, M) < 0.5)
    rho = jnp.asarray(rng.rand(N) * 3 + 0.5, jnp.float32)
    x_old = x if with_x else None
    out = ops.admm_worker_select_update(g, y, zt, w, sel, rho, x_old,
                                        interpret=True)
    exp = ref.admm_worker_select_update_ref(g, y, zt, w, sel, rho, x_old)
    assert len(out) == (3 if with_x else 2)
    for o, e in zip(out, exp):
        assert o.shape == (N, M, d)
        np.testing.assert_allclose(np.asarray(o), np.asarray(e),
                                   rtol=1e-6, atol=1e-6)
    # unselected (worker, block) pairs keep their old values exactly
    keep = ~np.asarray(sel)
    np.testing.assert_array_equal(np.asarray(out[0])[keep],
                                  np.asarray(y)[keep])


@pytest.mark.parametrize("N,M,d", [(3, 4, 128), (2, 8, 128), (4, 12, 256)])
@pytest.mark.parametrize("l1,clip", [(0.0, 0.0), (0.05, 0.4)])
def test_server_prox_update(N, M, d, l1, clip):
    """Fused server kernel: edge-masked worker reduction + prox (13)
    with the reduction running inside the grid (w_sum never in HBM)."""
    rng = np.random.RandomState(M * 10 + d)
    zc = jnp.asarray(rng.randn(M, d), jnp.float32)
    w = jnp.asarray(rng.randn(N, M, d), jnp.float32)
    edge = jnp.asarray(rng.rand(N, M) < 0.7)
    rs = jnp.asarray(rng.rand(M) * 4 + 0.5, jnp.float32)
    out = ops.server_prox_update(zc, w, edge, rs, gamma=0.1, l1=l1,
                                 clip=clip, interpret=True)
    exp = ref.server_prox_update_ref(zc, w, edge, rs, 0.1, l1, clip)
    assert out.shape == (M, d)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-6)
    if clip > 0:
        assert float(jnp.max(jnp.abs(out))) <= clip + 1e-6


@pytest.mark.parametrize("op,args", [
    ("admm_worker_select_update",
     lambda a3, sel, rho: ops.admm_worker_select_update(
         a3, a3, a3, a3, sel, rho, interpret=True)),
    ("server_prox_update",
     lambda a3, sel, rho: ops.server_prox_update(
         a3[0], a3, sel, rho[0] * jnp.ones(a3.shape[1]), gamma=0.1,
         interpret=True)),
])
def test_batched_ops_reject_ragged_rows(op, args):
    """d % 128 != 0 raises the layout-pointing error instead of the old
    silent non-termination of the tile-decrement loop."""
    a3 = jnp.ones((2, 4, 129), jnp.float32)
    sel = jnp.ones((2, 4), bool)
    rho = jnp.ones(2, jnp.float32)
    with pytest.raises(ValueError, match=f"{op}.*129"):
        args(a3, sel, rho)


def test_pick_lane_tile_contract():
    """The lane-tile picker: actionable error off the lane grid, tuned
    winners consulted verbatim only when they are lane multiples
    dividing d, heuristic fallback otherwise."""
    from repro.kernels.admm_update import _pick_lane_tile, pick_blk_m

    with pytest.raises(ValueError, match="d % 128 == 0, got d=136"):
        _pick_lane_tile(136)
    assert _pick_lane_tile(4096) == 2048          # heuristic: cap at 2048
    assert _pick_lane_tile(3 * 128) == 384        # largest lane divisor
    assert _pick_lane_tile(4096, tuned=512) == 512    # tuned divides -> used
    assert _pick_lane_tile(4096, tuned=384) == 2048   # tuned !divides -> fallback
    assert _pick_lane_tile(4096, tuned=100) == 2048   # tuned !lane-mult -> fallback
    assert pick_blk_m(12, tuned=6) == 6
    assert pick_blk_m(12, tuned=5) == pick_blk_m(12)  # non-divisor ignored


def test_admm_worker_update_rho_is_traced():
    """Sweeping rho must not recompile: rho is an array operand, not a
    jit-static argument (each distinct value used to trigger a fresh
    Mosaic compile)."""
    ops.admm_worker_update._clear_cache()
    g = jnp.asarray(np.random.randn(1024), jnp.float32)
    o = jnp.ones(1024)
    for rho in (0.5, 2.0, 100.0, 3.7):
        x, yn, w = ops.admm_worker_update(g, o, o, rho, interpret=True)
        xe, yne, we = ref.admm_worker_update_ref(g, o, o, rho)
        np.testing.assert_allclose(np.asarray(x), np.asarray(xe),
                                   rtol=1e-5, atol=1e-5)
    assert ops.admm_worker_update._cache_size() == 1


def test_to_2d_aligned_is_reshape_only():
    """(8*128)-aligned buffers must pass through _to_2d without a
    zero-fill + scatter copy (no `pad` / `scatter` in the jaxpr), and
    unaligned buffers are a layout bug — they raise, never pad."""
    from repro.kernels.ops import _from_2d, _to_2d

    def roundtrip(v):
        a2d, orig = _to_2d(v)
        return _from_2d(a2d, orig)

    aligned = jnp.ones((8, 128))
    jaxpr = str(jax.make_jaxpr(roundtrip)(aligned))
    assert "pad" not in jaxpr and "scatter" not in jaxpr, jaxpr
    np.testing.assert_array_equal(np.asarray(roundtrip(aligned)),
                                  np.ones((8, 128)))
    with pytest.raises(ValueError, match="vreg aligned"):
        _to_2d(jnp.ones((3, 5, 17)))
