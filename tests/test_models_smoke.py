"""Per-architecture smoke tests (deliverable f): instantiate the reduced
variant of each assigned family, run one forward + one ADMM train step on
CPU, assert output shapes and absence of NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke, list_archs
from repro.configs.base import ADMMConfig
from repro.data import TokenPipeline
from repro.models import build_model
from repro.training import ADMMTrainer

ARCHS = list_archs()


def _batch(cfg, B=4, S=16, workers=None, seed=0):
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=S + 1,
                         global_batch=B, seed=seed)
    kw = {}
    if cfg.is_enc_dec:
        kw = dict(enc_frames_dim=cfg.d_model, enc_seq_len=cfg.encoder_seq_len)
    if workers:
        return pipe.batch(0, num_workers=workers, **kw)
    return pipe.batch(0, **kw)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(arch):
    cfg = get_smoke(arch)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits = model.prefill(params, batch["tokens"],
                           enc_frames=batch.get("enc_frames"))
    B, S = batch["tokens"].shape
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_admm_train_step(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    acfg = ADMMConfig(rho=50.0, gamma=0.01, max_delay=1, block_fraction=0.5,
                      num_blocks=4)
    tr = ADMMTrainer(loss_fn=model.loss, admm=acfg, num_workers=2)
    state = tr.init(params)
    batch = _batch(cfg, workers=2)
    state, info = jax.jit(tr.train_step)(state, batch)
    assert np.isfinite(float(info["loss"]))
    for leaf in jax.tree.leaves(state.params):
        assert bool(jnp.all(jnp.isfinite(leaf))), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B = 2
    cache = model.init_cache(B, 32)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, new_cache = jax.jit(model.decode_step)(params, tok, cache,
                                                   jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache structure preserved
    assert (jax.tree_util.tree_structure(cache)
            == jax.tree_util.tree_structure(new_cache))
