"""Block-selection schemes (random / cyclic / Gauss-Southwell) and
heterogeneous per-worker rho_i — paper §3.2 remarks + general form."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ADMMConfig
from repro.core import init_state, make_problem, make_step_fn, run
from repro.core.space import (BLOCK_SELECTORS, SelectorContext,
                              make_zipf_selector)


def _problem(rho_scale=None, seed=0):
    rng = np.random.RandomState(seed)
    N, m, d = 4, 32, 48
    X = rng.randn(N, m, d).astype(np.float32) * (rng.rand(N, 1, d) < 0.5)
    w = (rng.rand(d) < 0.3) * rng.randn(d)
    yv = np.sign(np.einsum("nmd,d->nm", X, w) + 0.1 * rng.randn(N, m))

    def loss_fn(z, dat):
        Xi, yi = dat
        return jnp.mean(jnp.log1p(jnp.exp(-yi * (Xi @ z))))

    return make_problem(loss_fn, (jnp.asarray(X), jnp.asarray(yv.astype(np.float32))),
                        dim=d, num_blocks=8, l1_coef=1e-3,
                        rho_scale=rho_scale)


@pytest.mark.parametrize("scheme", ["random", "cyclic", "gauss_southwell",
                                    "zipf"])
def test_all_selection_schemes_converge(scheme):
    prob = _problem()
    obj0 = float(prob.objective(jnp.zeros(prob.dim)))
    cfg = ADMMConfig(rho=2.0, gamma=0.1, max_delay=1, block_fraction=0.25,
                     num_blocks=8, block_selection=scheme)
    _, hist = run(prob, cfg, 400, eval_every=100)
    objs = [h["objective"] for h in hist]
    assert objs[-1] < obj0 - 0.1, (objs, obj0)
    assert np.isfinite(objs).all()


def test_gauss_southwell_selects_max_gradient_block():
    """Semantics check: the first GS round updates exactly the block(s)
    with the largest gradient norm per worker. (No performance claim:
    greedy k=1 selection can cycle when the dual y couples blocks —
    observed on adversarial seeds; the paper only cites GS as an
    alternative scheme, and our implementation reproduces both its
    behavior and its fragility.)"""
    prob = _problem(seed=1)
    cfg = ADMMConfig(rho=2.0, gamma=0.0, max_delay=0, block_fraction=0.125,
                     num_blocks=8, block_selection="gauss_southwell")
    state = init_state(prob, cfg)
    # expected: block with max ||grad_j f_i(0)||^2 per worker
    g = jax.vmap(lambda d: jax.grad(prob.loss_fn)(jnp.zeros(prob.dim), d))(
        prob.data)
    gb = prob.blocks.to_blocks(g)
    expect = np.asarray(jnp.argmax(jnp.sum(jnp.square(gb), axis=-1), axis=1))
    step = make_step_fn(prob, cfg)
    new = step(state)
    # the updated y rows are exactly -grad at the selected block
    moved = np.asarray(jnp.any(new.y != 0, axis=-1))        # (N, M)
    assert (moved.argmax(axis=1) == expect).all()
    assert (moved.sum(axis=1) == 1).all()


def test_gauss_southwell_exact_count_under_ties():
    """Tied gradient norms must not over-select: GS picks EXACTLY
    min(k, |edge row|) blocks per worker, ties broken deterministically
    toward the lower block index."""
    N, M, k = 3, 8, 2
    edge = jnp.ones((N, M), bool).at[2, 4:].set(False)   # worker 2: 4 blocks
    # all-equal gradient norms — the worst tie case (old `gnorm >= thresh`
    # selected the whole edge neighborhood here)
    gnorm = jnp.ones((N, M), jnp.float32)
    ctx = SelectorContext(rng=jax.random.PRNGKey(0), edge=edge,
                          t=jnp.zeros((), jnp.int32),
                          block_fraction=k / M, grad_sqnorm=lambda: gnorm)
    sel = np.asarray(BLOCK_SELECTORS["gauss_southwell"](ctx))
    assert (sel.sum(axis=1) == k).all(), sel
    # deterministic: lowest-index blocks win the tie, inside the edge set
    assert sel[0, :k].all() and not sel[0, k:].any()
    assert (sel & ~np.asarray(edge)).sum() == 0
    # and the draw is reproducible
    sel2 = np.asarray(BLOCK_SELECTORS["gauss_southwell"](ctx))
    assert (sel == sel2).all()


def _zipf_ctx(key, edge, frac):
    return SelectorContext(rng=jax.random.PRNGKey(key), edge=edge,
                           t=jnp.zeros((), jnp.int32), block_fraction=frac,
                           grad_sqnorm=lambda: None)


def test_zipf_deterministic_exact_count_respects_edge():
    """Satellite pin: zipf is a registered, gradient-free selector;
    same key -> same selection; exactly min(k, |edge row|) blocks per
    worker; never outside the edge set."""
    sel_fn = BLOCK_SELECTORS["zipf"]
    assert getattr(sel_fn, "gradient_free", False)
    N, M, k = 3, 8, 2
    edge = jnp.ones((N, M), bool).at[2, 4:].set(False)   # worker 2: 4 blocks
    ctx = _zipf_ctx(0, edge, k / M)
    sel = np.asarray(sel_fn(ctx))
    assert (sel.sum(axis=1) == k).all(), sel
    assert (sel & ~np.asarray(edge)).sum() == 0
    np.testing.assert_array_equal(sel, np.asarray(sel_fn(ctx)))
    # a different key draws a different selection (it IS sampling)
    assert (sel != np.asarray(sel_fn(_zipf_ctx(1, edge, k / M)))).any()
    # an edge row smaller than k selects the whole row, no more
    tiny = jnp.zeros((1, M), bool).at[0, 3].set(True)
    assert np.asarray(sel_fn(_zipf_ctx(0, tiny, k / M))).sum() == 1


def test_zipf_skews_toward_head_blocks():
    """The point of the scheme: under weight (j+1)^-a the head blocks
    are selected far more often than the tail — the hot-block workload
    benchmarks/speedup.py --scenario skew stresses the servers with."""
    sel_fn = make_zipf_selector(3.0)
    N, M = 4, 8
    edge = jnp.ones((N, M), bool)
    counts = np.zeros(M)
    for s in range(40):
        counts += np.asarray(sel_fn(_zipf_ctx(s, edge, 0.25))).sum(axis=0)
    assert counts[0] > 4 * counts[-1]
    assert counts[0] > counts[M // 2]
    with pytest.raises(ValueError):
        make_zipf_selector(-1.0)
    with pytest.raises(ValueError):
        make_zipf_selector(float("nan"))


def test_heterogeneous_rho_converges():
    scale = np.array([0.5, 1.0, 2.0, 4.0], np.float32)
    prob = _problem(rho_scale=scale)
    cfg = ADMMConfig(rho=2.0, gamma=0.1, max_delay=1, block_fraction=0.5,
                     num_blocks=8)
    state, hist = run(prob, cfg, 400, eval_every=200)
    objs = [h["objective"] for h in hist]
    assert objs[-1] < objs[0] and np.isfinite(objs[-1])


def test_cyclic_visits_every_block():
    prob = _problem()
    cfg = ADMMConfig(rho=2.0, gamma=0.0, max_delay=0, block_fraction=0.125,
                     num_blocks=8, block_selection="cyclic")
    state = init_state(prob, cfg)
    step = make_step_fn(prob, cfg)
    z_prev = state.z_hist[0]
    changed = np.zeros(8, bool)
    for t in range(8):
        state = step(state)
        diff = np.asarray(jnp.sum(jnp.abs(state.z_hist[0] - z_prev), axis=-1))
        changed |= diff > 0
        z_prev = state.z_hist[0]
    assert changed.all()          # one full Gauss-Seidel sweep hits all M
