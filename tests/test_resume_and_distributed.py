"""Checkpoint-resume of ADMM training and SPMD execution of the flat
AsyBADMM driver on an 8-host-device mesh (subprocess — device count must
be forced before jax init)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore, save
from repro.configs import get_smoke
from repro.configs.base import ADMMConfig
from repro.data import TokenPipeline
from repro.models import build_model
from repro.training import ADMMTrainer

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_admm_state_checkpoint_resume(tmp_path):
    """Training 10 steps straight == training 5, checkpointing the FULL
    ADMM state (z ring, duals, w cache, rng), restoring, training 5."""
    cfg = get_smoke("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=17,
                         global_batch=8, seed=0)
    tr = ADMMTrainer(loss_fn=model.loss,
                     admm=ADMMConfig(rho=5.0, gamma=0.05, max_delay=1,
                                     block_fraction=0.5, num_blocks=4),
                     num_workers=4)
    step = jax.jit(tr.train_step)

    straight = tr.init(params)
    for i in range(10):
        straight, _ = step(straight, pipe.batch(i, num_workers=4))

    half = tr.init(params)
    for i in range(5):
        half, _ = step(half, pipe.batch(i, num_workers=4))
    path = str(tmp_path / "admm_ckpt")
    save(path, half._asdict(), step=5)
    resumed_dict = restore(path, half._asdict())
    resumed = type(half)(**resumed_dict)
    for i in range(5, 10):
        resumed, _ = step(resumed, pipe.batch(i, num_workers=4))

    for a, b in zip(jax.tree.leaves(straight.params),
                    jax.tree.leaves(resumed.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_flat_driver_runs_spmd():
    """The paper's Algorithm 1 driver executes under jit on a 4-device
    (2 data x 2 model) host mesh with the worker axis sharded — the
    result matches the single-device run bit-for-bit semantics."""
    code = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import ADMMConfig
from repro.core import init_state, make_problem, make_step_fn, run
from repro.data import make_sparse_logreg

data = make_sparse_logreg(num_workers=4, samples_per_worker=32, dim=64,
                          density=0.2, seed=0)
def loss_fn(z, d):
    X, y = d
    return jnp.mean(jnp.log1p(jnp.exp(-y * (X @ z))))
prob = make_problem(loss_fn, (jnp.asarray(data.X), jnp.asarray(data.y)),
                    dim=64, num_blocks=8, support=data.support, l1_coef=1e-3)
cfg = ADMMConfig(rho=2.0, gamma=0.1, max_delay=1, block_fraction=0.5,
                 num_blocks=8)

# single device reference
state_ref, hist_ref = run(prob, cfg, 30, eval_every=30)

# SPMD: worker axis over 'data', blocks over 'model'
mesh = jax.make_mesh((2, 2), ('data', 'model'))
with mesh:
    state = init_state(prob, cfg)
    shard = lambda a, spec: jax.device_put(a, NamedSharding(mesh, spec))
    state = state._replace(
        y=shard(state.y, P('data', 'model', None)),
        w_cache=shard(state.w_cache, P('data', 'model', None)),
        x=shard(state.x, P('data', 'model', None)),
        z_hist=shard(state.z_hist, P(None, 'model', None)))
    step = make_step_fn(prob, cfg)
    for _ in range(30):
        state = step(state)
    z = prob.blocks.from_blocks(state.z_hist[0])
    obj = float(prob.objective(z))
print('REF', hist_ref[-1]['objective'], 'SPMD', obj)
assert abs(obj - hist_ref[-1]['objective']) < 1e-3, (obj, hist_ref)
print('SPMD_OK')
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "SPMD_OK" in r.stdout
