"""Checkpoint-resume of ADMM training and SPMD execution of the flat
AsyBADMM driver on an 8-host-device mesh (subprocess — device count must
be forced before jax init) — plus the PS runtime's mid-stream resume
determinism property: under ARBITRARY snapshot cadences and worker-crash
schedules, a run resumed from any snapshot finishes with exactly the
fold log and final z of the uninterrupted run."""
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import restore, save
from repro.configs import get_smoke
from repro.configs.base import ADMMConfig
from repro.data import TokenPipeline
from repro.models import build_model
from repro.training import ADMMTrainer

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_admm_state_checkpoint_resume(tmp_path):
    """Training 10 steps straight == training 5, checkpointing the FULL
    ADMM state (z ring, duals, w cache, rng), restoring, training 5."""
    cfg = get_smoke("qwen3-1.7b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pipe = TokenPipeline(vocab_size=cfg.vocab_size, seq_len=17,
                         global_batch=8, seed=0)
    tr = ADMMTrainer(loss_fn=model.loss,
                     admm=ADMMConfig(rho=5.0, gamma=0.05, max_delay=1,
                                     block_fraction=0.5, num_blocks=4),
                     num_workers=4)
    step = jax.jit(tr.train_step)

    straight = tr.init(params)
    for i in range(10):
        straight, _ = step(straight, pipe.batch(i, num_workers=4))

    half = tr.init(params)
    for i in range(5):
        half, _ = step(half, pipe.batch(i, num_workers=4))
    path = str(tmp_path / "admm_ckpt")
    save(path, half._asdict(), step=5)
    resumed_dict = restore(path, half._asdict())
    resumed = type(half)(**resumed_dict)
    for i in range(5, 10):
        resumed, _ = step(resumed, pipe.batch(i, num_workers=4))

    for a, b in zip(jax.tree.leaves(straight.params),
                    jax.tree.leaves(resumed.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-6)


def test_flat_driver_runs_spmd():
    """The paper's Algorithm 1 driver executes under jit on a 4-device
    (2 data x 2 model) host mesh with the worker axis sharded — the
    result matches the single-device run bit-for-bit semantics."""
    code = """
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs.base import ADMMConfig
from repro.core import init_state, make_problem, make_step_fn, run
from repro.data import make_sparse_logreg

data = make_sparse_logreg(num_workers=4, samples_per_worker=32, dim=64,
                          density=0.2, seed=0)
def loss_fn(z, d):
    X, y = d
    return jnp.mean(jnp.log1p(jnp.exp(-y * (X @ z))))
prob = make_problem(loss_fn, (jnp.asarray(data.X), jnp.asarray(data.y)),
                    dim=64, num_blocks=8, support=data.support, l1_coef=1e-3)
cfg = ADMMConfig(rho=2.0, gamma=0.1, max_delay=1, block_fraction=0.5,
                 num_blocks=8)

# single device reference
state_ref, hist_ref = run(prob, cfg, 30, eval_every=30)

# SPMD: worker axis over 'data', blocks over 'model'
mesh = jax.make_mesh((2, 2), ('data', 'model'))
with mesh:
    state = init_state(prob, cfg)
    shard = lambda a, spec: jax.device_put(a, NamedSharding(mesh, spec))
    state = state._replace(
        y=shard(state.y, P('data', 'model', None)),
        w_cache=shard(state.w_cache, P('data', 'model', None)),
        x=shard(state.x, P('data', 'model', None)),
        z_hist=shard(state.z_hist, P(None, 'model', None)))
    step = make_step_fn(prob, cfg)
    for _ in range(30):
        state = step(state)
    z = prob.blocks.from_blocks(state.z_hist[0])
    obj = float(prob.objective(z))
print('REF', hist_ref[-1]['objective'], 'SPMD', obj)
assert abs(obj - hist_ref[-1]['objective']) < 1e-3, (obj, hist_ref)
print('SPMD_OK')
"""
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=600)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "SPMD_OK" in r.stdout


# ---------------------------------------------------------------------------
# PS runtime mid-stream resume: determinism property (hypothesis)
# ---------------------------------------------------------------------------

_PS_N, _PS_M, _PS_DBLK = 3, 4, 5
_PS_ROUNDS = 8


def _ps_session():
    from repro.api import ConsensusSession
    rs = np.random.RandomState(11)
    centers = jnp.asarray(rs.randn(_PS_N, _PS_M * _PS_DBLK)
                          .astype(np.float32))
    cfg = ADMMConfig(rho=2.0, gamma=0.1, max_delay=2, block_fraction=0.5,
                     num_blocks=_PS_M, block_selection="random",
                     l1_coef=1e-3, clip=0.8, seed=0)
    loss = lambda z, c: 0.5 * jnp.sum(jnp.square(z - c))
    return ConsensusSession.flat(loss, centers, dim=_PS_M * _PS_DBLK,
                                 cfg=cfg)


def _ps_runtime(faults):
    from repro.ps import ConstantService, CostProfile, PSRuntime
    sess = _ps_session()
    timing = CostProfile(t_worker=ConstantService(1.0),
                         t_server_block=ConstantService(0.25))
    return PSRuntime(sess.spec, data=sess.data, timing=timing,
                     faults=faults)


def _resume_roundtrip(every, crashes, pick):
    """One property example: run with checkpointing + worker-crash
    chaos uninterrupted, then resume from one of its snapshots;
    return both (runtime, result) pairs and the chosen snapshot."""
    from repro.ps import FaultPlan
    plan = FaultPlan.of(*[FaultPlan.crash(w, at, down)
                          for (w, at, down) in crashes]) \
        if crashes else None
    with tempfile.TemporaryDirectory() as td:
        rt_full = _ps_runtime(plan)
        full = rt_full.run(_PS_ROUNDS, checkpoint_every=every,
                           checkpoint_dir=td)
        snaps = full.metrics["snapshots"]
        assert snaps, "cadence <= rounds/2 must produce a snapshot"
        snap = snaps[pick % len(snaps)]
        rt_res = _ps_runtime(plan)
        res = rt_res.run(_PS_ROUNDS, resume_from=snap)
    return rt_full, full, rt_res, res, snap


def _assert_resume_identical(rt_full, full, rt_res, res, snap):
    for d_full, d_res in zip(rt_full.domains, rt_res.domains):
        assert d_full.fold_log == d_res.fold_log, \
            f"fold log diverged after resume from {snap}"
    np.testing.assert_array_equal(np.asarray(full.z_final),
                                  np.asarray(res.z_final),
                                  err_msg=f"final z diverged after "
                                          f"resume from {snap}")
    np.testing.assert_array_equal(full.trace.delays, res.trace.delays)
    assert full.losses == res.losses
    assert full.makespan == res.makespan


try:
    import hypothesis  # noqa: F401
    from hypothesis import given, settings, strategies as st

    _crash_st = st.lists(
        st.tuples(st.integers(0, _PS_N - 1),          # worker
                  st.floats(0.5, 7.5),                # crash time
                  st.floats(0.5, 4.0)),               # downtime
        max_size=2,
        unique_by=lambda c: c[0])                     # one crash/worker

    @given(every=st.integers(1, _PS_ROUNDS // 2), crashes=_crash_st,
           pick=st.integers(0, 7))
    @settings(max_examples=10, deadline=None)
    def test_resume_determinism_property(every, crashes, pick):
        """For ARBITRARY snapshot cadences and worker-crash schedules,
        a run resumed from ANY of its crash-consistent snapshots
        finishes with exactly the uninterrupted run's committed fold
        log, final z, staleness trace, losses, and makespan — the
        snapshot captures the complete runtime state and the resumed
        tail re-derives every event identically."""
        _assert_resume_identical(*_resume_roundtrip(every, crashes, pick))
except ImportError:                                   # pragma: no cover
    pass


def test_resume_determinism_fixed_schedule():
    """One deterministic cell of the property (runs even without
    hypothesis): cadence 2, a mid-run worker crash, resume from the
    second snapshot."""
    _assert_resume_identical(
        *_resume_roundtrip(2, [(1, 2.5, 1.5)], pick=1))
