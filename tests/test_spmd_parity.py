"""SPMD <-> single-device parity of the sharded epoch (core/sharded.py).

On an 8-host-device CPU mesh (data=4, model=2) the shard_map'd epoch
must reproduce the single-device ``asybadmm_epoch`` z trajectory for
both spaces and all three block selectors. Selection/delay draws are
computed at full (N, M) shape from the replicated key and sliced per
shard (``jax_threefry_partitionable`` is on globally), so the ONLY
float-order difference is the worker reduction's partial-sum + psum —
hence allclose at fp32 tolerance rather than bit equality.

Requires 8 host devices: run under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (scripts/ci.sh
has a dedicated step); skips otherwise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ConsensusSession
from repro.configs.base import ADMMConfig
from repro.core.blocks import LANE, TreeBlocks
from repro.core.space import DELAY_MODELS, ParetoDelay, UniformDelay

needs8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8 "
           "(scripts/ci.sh runs this file under it)")

N, M, DBLK = 4, 8, 5
DIM = M * DBLK
EPOCHS = 6
TOL = 1e-5

_r = np.random.RandomState(7)
CENTERS = _r.randn(N, DIM).astype(np.float32)
EDGE = _r.rand(N, M) < 0.8
EDGE[:, 0] = True                       # every worker touches block 0
RHO_SCALE = np.array([0.5, 1.0, 2.0, 1.5], np.float32)


def _mesh():
    from repro.launch.mesh import make_test_mesh
    return make_test_mesh(8)            # (data=4, model=2)


def _cfg(scheme, num_blocks=M, max_delay=1):
    return ADMMConfig(rho=2.0, gamma=0.1, max_delay=max_delay,
                      block_fraction=0.5, num_blocks=num_blocks,
                      block_selection=scheme, l1_coef=1e-3, clip=0.8, seed=0)


def _flat_loss(z, c):
    return 0.5 * jnp.sum(jnp.square(z - c))


def _assert_parity(make_session, to_vec, data):
    ref = make_session(None)
    sh = make_session(_mesh())
    states = {"ref": ref.init(), "sh": sh.init()}
    steps = {"ref": ref.step_fn(), "sh": sh.step_fn()}
    for t in range(EPOCHS):
        states["ref"], i_ref = steps["ref"](states["ref"], data)
        states["sh"], i_sh = steps["sh"](states["sh"], data)
        np.testing.assert_allclose(
            to_vec(sh, states["sh"]), to_vec(ref, states["ref"]),
            rtol=TOL, atol=TOL,
            err_msg=f"SPMD diverged from single device at epoch {t}")
        np.testing.assert_allclose(float(i_sh["loss"]), float(i_ref["loss"]),
                                   rtol=1e-5)
        assert float(i_sh["selected_fraction"]) == pytest.approx(
            float(i_ref["selected_fraction"]))
    assert np.max(np.abs(to_vec(ref, states["ref"]))) > 0.0   # run moved
    return states["sh"]


@needs8
@pytest.mark.parametrize("scheme", ["random", "cyclic", "gauss_southwell"])
def test_flat_spmd_parity(scheme):
    centers = jnp.asarray(CENTERS)

    def make(mesh):
        return ConsensusSession.flat(
            _flat_loss, centers, dim=DIM, cfg=_cfg(scheme), edge=EDGE,
            rho_scale=RHO_SCALE, delay_model=UniformDelay(1), mesh=mesh)

    state = _assert_parity(make, lambda s, st: np.asarray(s.z(st)), centers)
    # the state really is sharded: workers over data, blocks over model
    yspec = state.y.sharding.spec
    assert yspec[0] in ("data", ("data",)) and yspec[1] == "model"
    assert state.z_hist.sharding.spec[1] == "model"
    # block_dim is lane-rounded by the layout (DBLK=5 -> 128)
    assert state.y.addressable_shards[0].data.shape == (1, M // 2, LANE)


@needs8
@pytest.mark.parametrize("scheme", ["random", "cyclic", "gauss_southwell"])
def test_tree_spmd_parity(scheme):
    centers = jnp.asarray(CENTERS)
    params = {f"w{j}": jnp.zeros((DBLK,), jnp.float32) for j in range(4)}
    tblocks = TreeBlocks(num_blocks=4, leaf_block_ids=(0, 1, 2, 3),
                         treedef=jax.tree.structure(params))

    def tree_loss(p, c):
        z = jnp.concatenate([p[f"w{j}"] for j in range(4)])
        return 0.5 * jnp.sum(jnp.square(z - c[: 4 * DBLK]))

    def make(mesh):
        return ConsensusSession.pytree(
            tree_loss, params, _cfg(scheme, num_blocks=4), num_workers=N,
            blocks=tblocks, edge=EDGE[:, :4], rho_scale=RHO_SCALE, mesh=mesh)

    def to_vec(sess, state):
        z = sess.z(state)
        return np.asarray(jnp.concatenate([z[f"w{j}"] for j in range(4)]))

    state = _assert_parity(make, to_vec, centers)
    # the packed-layout lowering: tree worker bundles shard (data, model)
    # and the z ring shards its block axis over model — NATIVE block
    # servers, no replicated-z fallback
    yspec = state.y.sharding.spec
    assert yspec[0] in ("data", ("data",)) and yspec[1] == "model"
    assert state.z_hist.sharding.spec[1] == "model"
    assert state.y.addressable_shards[0].data.shape == (1, 2, LANE)


@needs8
def test_flat_spmd_parity_pallas_backend():
    """The PR-2 kernels run per shard on local (N/4, M/2, dblk) tiles."""
    centers = jnp.asarray(CENTERS)

    def make(mesh):
        return ConsensusSession.flat(
            _flat_loss, centers, dim=DIM, cfg=_cfg("random"), edge=EDGE,
            rho_scale=RHO_SCALE, backend="pallas", mesh=mesh)

    _assert_parity(make, lambda s, st: np.asarray(s.z(st)), centers)


@needs8
def test_flat_spmd_parity_split_grads():
    """With 8 workers on the (data=4, model=2) mesh each device holds 2
    local workers, so the gradient pass splits them over model (each
    model shard differentiates one worker against the gathered z~ and
    grads are exchanged via all_to_all). The z trajectory must match
    the single device bit-for-bit up to fp32 reduction order."""
    from repro.core.sharded import grad_split_size

    r8 = np.random.RandomState(11)
    centers8 = jnp.asarray(r8.randn(8, DIM).astype(np.float32))
    edge8 = r8.rand(8, M) < 0.8
    edge8[:, 0] = True
    rho8 = np.linspace(0.5, 2.0, 8).astype(np.float32)

    def make(mesh):
        return ConsensusSession.flat(
            _flat_loss, centers8, dim=DIM, cfg=_cfg("random"), edge=edge8,
            rho_scale=rho8, delay_model=UniformDelay(1), mesh=mesh)

    sh = make(_mesh())
    assert grad_split_size(sh.spec) == 1     # the split path really is on
    _assert_parity(make, lambda s, st: np.asarray(s.z(st)), centers8)


@needs8
def test_flat_spmd_parity_pareto_stragglers():
    """Heavy-tailed worker-asymmetric delays exercise the sharded
    history gather: each data shard pulls different ring rows."""
    centers = jnp.asarray(CENTERS)

    def make(mesh):
        return ConsensusSession.flat(
            _flat_loss, centers, dim=DIM, cfg=_cfg("random", max_delay=3),
            edge=EDGE, delay_model=ParetoDelay(3, alpha=1.2), mesh=mesh)

    _assert_parity(make, lambda s, st: np.asarray(s.z(st)), centers)


@needs8
def test_mesh_divisibility_validation():
    """Bad (mesh, problem) pairings fail eagerly with a clear message."""
    mesh = _mesh()
    with pytest.raises(ValueError, match="num_workers"):
        ConsensusSession.flat(_flat_loss, jnp.asarray(CENTERS[:3]), dim=DIM,
                              cfg=_cfg("random"), mesh=mesh)
    with pytest.raises(ValueError, match="num_blocks"):
        ConsensusSession.flat(
            _flat_loss, jnp.asarray(CENTERS), dim=DIM,
            cfg=_cfg("random", num_blocks=7), mesh=mesh)


# ---------------------------------------------------------------------------
# ParetoDelay distribution shape — device-count independent, lives here
# because test_async_delay.py needs the hypothesis extra to even collect
# ---------------------------------------------------------------------------

def test_pareto_delay_heavy_tail():
    """Most reads fresh, but the tail reaches the full delay window —
    unlike uniform, the delay histogram is front-loaded AND clipped
    mass accumulates at max_delay (the straggler profile)."""
    dm = ParetoDelay(max_delay=4, alpha=1.2)
    assert dm.depth == 5
    d = np.asarray(dm.sample(jax.random.PRNGKey(1), 64, 64)).ravel()
    assert d.min() >= 0 and d.max() <= 4
    frac0 = (d == 0).mean()
    assert frac0 > 0.4                      # P[tau=0] = 1 - 2^-alpha ~ 0.56
    assert (d == 4).sum() > 0               # stragglers hit the clip
    assert frac0 > (d == 1).mean() > (d == 2).mean()   # decreasing pmf


def test_pareto_delay_zero_window_is_sync():
    d = ParetoDelay(max_delay=0).sample(jax.random.PRNGKey(0), 3, 5)
    assert int(jnp.max(d)) == 0


def test_delay_model_registry():
    assert set(DELAY_MODELS) >= {"uniform", "constant", "pareto"}
    assert DELAY_MODELS["pareto"] is ParetoDelay
