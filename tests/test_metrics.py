"""Stationarity / KKT metrics (repro.core.metrics) under per-worker
rho — the ``_rho_b`` broadcasting pin — plus the per-block
decomposition (``block_residuals`` / ``stationarity_blocks``) the
telemetry stream carries.

Pins:

* ``_rho_b`` accepts a scalar or an (N,) per-worker vector and the two
  spellings of a uniform rho produce BITWISE-identical metrics;
* a non-uniform rho_i actually reaches the rho-dependent terms (the
  Lagrangian gradients), while the rho-free terms (consensus residual,
  Theorem-1.2 KKT conditions at the limit) are invariant to it;
* ``block_residuals`` matches a hand-computed tiny case under
  per-worker rho, including masked (non-edge) entries;
* ``stationarity_blocks`` sums (in squares) to ``stationarity``'s
  totals under a per-worker rho_vec, block by block.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ADMMConfig
from repro.core import (block_residuals, init_state, kkt_violations,
                        make_problem, make_step_fn, stationarity,
                        stationarity_blocks)
from repro.core.metrics import _rho_b
from repro.core.prox import make_prox

N, M, DBLK = 3, 4, 5
DIM = M * DBLK

_r = np.random.RandomState(7)
CENTERS = jnp.asarray(_r.randn(N, DIM).astype(np.float32))
EDGE = np.array([[1, 1, 0, 1],
                 [1, 0, 1, 0],
                 [1, 1, 1, 1]], bool)
RHO_SCALE = np.array([0.5, 1.0, 2.0], np.float32)


def _loss(z, c):
    return 0.5 * jnp.sum(jnp.square(z - c))


def _problem(rho_scale=None):
    return make_problem(_loss, CENTERS, dim=DIM, num_blocks=M,
                        edge=EDGE, l1_coef=1e-3, clip=0.8,
                        rho_scale=rho_scale)


def _cfg(**kw):
    return ADMMConfig(rho=2.0, gamma=0.1, max_delay=0, block_fraction=1.0,
                      num_blocks=M, block_selection="cyclic",
                      l1_coef=1e-3, clip=0.8, seed=0, **kw)


def _evolved_state(prob, cfg, steps=5):
    state = init_state(prob, cfg)
    step = make_step_fn(prob, cfg)
    for _ in range(steps):
        state = step(state)
    return state


# ---------------------------------------------------------------------------
# _rho_b broadcasting
# ---------------------------------------------------------------------------

def test_rho_b_shapes():
    assert _rho_b(2.0).shape == ()
    assert _rho_b(jnp.full((N,), 2.0)).shape == (N, 1, 1)
    # an already-broadcastable array passes through unchanged
    pre = jnp.ones((N, 1, 1))
    np.testing.assert_array_equal(_rho_b(pre), pre)


def test_uniform_vector_rho_matches_scalar_bitwise():
    """rho=2.0 and rho=[2.0]*N are the same math — every metric key is
    bitwise identical across the two spellings."""
    prob = _problem()
    cfg = _cfg()
    state = _evolved_state(prob, cfg)
    vec = jnp.full((N,), cfg.rho, jnp.float32)

    s_scalar = stationarity(prob, state, cfg.rho)
    s_vec = stationarity(prob, state, vec)
    for key in s_scalar:
        np.testing.assert_array_equal(np.asarray(s_scalar[key]),
                                      np.asarray(s_vec[key]),
                                      err_msg=f"stationarity[{key}]")

    k_scalar = kkt_violations(prob, state, cfg.rho)
    k_vec = kkt_violations(prob, state, vec)
    for key in k_scalar:
        np.testing.assert_array_equal(np.asarray(k_scalar[key]),
                                      np.asarray(k_vec[key]),
                                      err_msg=f"kkt[{key}]")

    b_scalar = stationarity_blocks(prob, state, cfg.rho)
    b_vec = stationarity_blocks(prob, state, vec)
    for key in b_scalar:
        np.testing.assert_array_equal(np.asarray(b_scalar[key]),
                                      np.asarray(b_vec[key]),
                                      err_msg=f"blocks[{key}]")


def test_per_worker_rho_reaches_gradient_terms():
    """A non-uniform rho_i must change the Lagrangian-gradient terms
    (rho multiplies (x_ij - z_j) there) but not the consensus residual
    (rho-free) — catching a silently-ignored rho_vec."""
    prob = _problem(rho_scale=RHO_SCALE)
    cfg = _cfg()
    state = _evolved_state(prob, cfg)
    rho_vec = cfg.rho * jnp.asarray(RHO_SCALE)

    s_vec = stationarity(prob, state, rho_vec)
    s_scalar = stationarity(prob, state, cfg.rho)
    np.testing.assert_array_equal(np.asarray(s_vec["primal_residual"]),
                                  np.asarray(s_scalar["primal_residual"]))
    assert not np.allclose(s_vec["grad_norm"], s_scalar["grad_norm"])
    assert not np.allclose(s_vec["P"], s_scalar["P"])
    for key, val in s_vec.items():
        assert np.isfinite(np.asarray(val)).all(), key

    # Theorem 1.2's limit conditions contain no rho at all
    k_vec = kkt_violations(prob, state, rho_vec)
    k_scalar = kkt_violations(prob, state, cfg.rho)
    for key in k_scalar:
        np.testing.assert_array_equal(np.asarray(k_scalar[key]),
                                      np.asarray(k_vec[key]),
                                      err_msg=f"kkt[{key}]")
        assert np.isfinite(np.asarray(k_vec[key]))


# ---------------------------------------------------------------------------
# per-block decomposition
# ---------------------------------------------------------------------------

def test_block_residuals_hand_computed():
    """Tiny packed case (N=2, M=2, dblk=1) with per-worker rho and an
    identity prox, against hand-evaluated numpy."""
    edge = np.array([[True, True],
                     [True, False]])
    z = np.array([[1.0], [2.0]], np.float32)
    x = np.array([[[1.5], [2.5]],
                  [[0.0], [9.0]]], np.float32)     # (N=2, M=2, 1)
    y = np.array([[[0.1], [-0.2]],
                  [[0.3], [7.0]]], np.float32)     # x[1,1], y[1,1] masked
    rho = np.array([1.0, 3.0], np.float32)
    grads = np.array([[[0.4], [0.6]],
                      [[-1.0], [5.0]]], np.float32)
    reg = make_prox(0.0, None)                     # identity prox

    out = block_residuals(z, y, x, edge, rho, reg=reg, grads=grads)

    # cons_ij = x_ij - z_j on edges: block 0 -> [0.5, -1.0], block 1 -> [0.5]
    np.testing.assert_allclose(out["primal"],
                               [np.sqrt(0.5**2 + 1.0**2), 0.5], rtol=1e-6)
    # gradL_z_j = sum_i -y_ij - rho_i cons_ij
    #   block 0: (-0.1 - 1*0.5) + (-0.3 - 3*(-1.0)) = 2.1
    #   block 1: (-(-0.2) - 1*0.5)                  = -0.3
    # identity prox => prox residual per block = |gradL_z_j|
    np.testing.assert_allclose(out["prox"], [2.1, 0.3], rtol=1e-6)
    # gradL_x_ij = g_ij + y_ij + rho_i cons_ij on edges
    #   block 0: (0.4 + 0.1 + 0.5) = 1.0 ; (-1.0 + 0.3 - 3.0) = -3.7
    #   block 1: (0.6 - 0.2 + 0.5) = 0.9
    np.testing.assert_allclose(out["grad"],
                               [np.sqrt(1.0**2 + 3.7**2), 0.9], rtol=1e-6)
    np.testing.assert_allclose(
        out["P"],
        np.square(out["primal"]) + np.square(out["prox"])
        + np.square(out["grad"]), rtol=1e-6)

    # optional terms drop out with their inputs
    bare = block_residuals(z, y, x, edge, rho)
    assert bare["prox"] is None and bare["grad"] is None
    np.testing.assert_allclose(bare["P"], np.square(bare["primal"]),
                               rtol=1e-6)


def test_stationarity_blocks_sums_to_totals_under_rho_vec():
    """The per-block decomposition is exactly the total metric split
    over blocks: squared sums match ``stationarity`` up to fp
    reassociation, under a genuinely per-worker rho."""
    prob = _problem(rho_scale=RHO_SCALE)
    cfg = _cfg()
    state = _evolved_state(prob, cfg)
    rho_vec = cfg.rho * jnp.asarray(RHO_SCALE)

    total = stationarity(prob, state, rho_vec)
    blocks = stationarity_blocks(prob, state, rho_vec)
    for arr in blocks.values():
        assert arr.shape == (M,)
    np.testing.assert_allclose(
        np.sqrt(np.sum(np.square(blocks["primal"]))),
        float(total["primal_residual"]), rtol=1e-5)
    np.testing.assert_allclose(
        np.sqrt(np.sum(np.square(blocks["grad"]))),
        float(total["grad_norm"]), rtol=1e-5)
    np.testing.assert_allclose(np.sum(blocks["P"]), float(total["P"]),
                               rtol=1e-5)
    # prox differs in aggregation only: stationarity's prox term is a
    # whole-vector norm, the per-block split carries one norm per block
    np.testing.assert_allclose(
        np.sqrt(np.sum(np.square(blocks["prox"]))),
        float(total["prox_residual"]), rtol=1e-5)
