"""Dry-run / sharding integration tests.

The production-mesh lowerings need 512 host devices, which must be
forced *before* jax initializes — so these tests run dryrun machinery
in a subprocess (smoke tests elsewhere must keep seeing 1 device).
"""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, timeout=600):
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    return subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True, timeout=timeout)


def test_single_device_default():
    """No global XLA_FLAGS leakage: default jax sees 1 CPU device."""
    r = _run("import jax; print(jax.device_count())")
    assert r.returncode == 0, r.stderr
    assert r.stdout.strip() == "1"


def test_mesh_construction():
    r = _run(
        "import os\n"
        "os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=512'\n"
        "from repro.launch.mesh import make_production_mesh\n"
        "m1 = make_production_mesh()\n"
        "m2 = make_production_mesh(multi_pod=True)\n"
        "print(dict(m1.shape), dict(m2.shape))\n")
    assert r.returncode == 0, r.stderr
    assert "{'data': 16, 'model': 16}" in r.stdout
    assert "{'pod': 2, 'data': 16, 'model': 16}" in r.stdout


@pytest.mark.parametrize("arch,shape", [
    ("granite-moe-1b-a400m", "train_4k"),     # MoE ADMM train
    ("mamba2-370m", "long_500k"),             # SSM sub-quadratic decode
    ("qwen3-1.7b", "prefill_32k"),            # dense prefill
])
def test_dryrun_lowers_and_compiles(arch, shape):
    code = (
        "from repro.launch.dryrun import run_one\n"
        f"row = run_one({arch!r}, {shape!r}, 'pod')\n"
        "import json; print('RESULT ' + json.dumps({k: row[k] for k in "
        "('status', 'bottleneck', 'flops_per_device')}))\n")
    r = _run(code)
    assert r.returncode == 0, r.stderr[-2000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    res = json.loads(line[len("RESULT "):])
    assert res["status"] == "ok"
    assert res["flops_per_device"] > 0


def test_dryrun_sharded_epoch_lowers_and_compiles():
    """--variant sharded_epoch: the SPMD-sharded asybadmm_epoch itself
    (shard_map, packed TreeSpace block servers over `model`) lowers and
    compiles at production shape — the ConsensusSession runtime path,
    not just the GSPMD trainer step."""
    code = (
        "from repro.launch.dryrun import run_one\n"
        "row = run_one('qwen3-1.7b', 'train_4k', 'pod', 'sharded_epoch')\n"
        "import json; print('RESULT ' + json.dumps({k: row[k] for k in "
        "('status', 'bottleneck', 'flops_per_device')}))\n")
    r = _run(code)
    assert r.returncode == 0, r.stderr[-2000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULT ")][0]
    res = json.loads(line[len("RESULT "):])
    assert res["status"] == "ok"
    assert res["flops_per_device"] > 0


def test_dryrun_multipod_lowers():
    code = (
        "from repro.launch.dryrun import run_one\n"
        "row = run_one('qwen3-1.7b', 'decode_32k', 'multipod')\n"
        "print('STATUS', row['status'], row.get('error', ''))\n")
    r = _run(code)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "STATUS ok" in r.stdout


def test_long500k_skips_full_attention():
    from repro.launch.dryrun import skip_reason
    assert skip_reason("qwen1.5-32b", "long_500k") is not None
    assert skip_reason("mamba2-370m", "long_500k") is None
    assert skip_reason("mixtral-8x7b", "long_500k") is None  # SWA
    assert skip_reason("zamba2-1.2b", "long_500k") is None   # hybrid
    assert skip_reason("qwen1.5-32b", "train_4k") is None


def test_hlo_collective_parser():
    from repro.analysis.hlo import collective_bytes
    hlo = """
  %ar = f32[1024,16]{1,0} all-reduce(%x), replica_groups={}
  %ag.1 = bf16[512]{0} all-gather(%y), dimensions={0}
  %rs = (f32[8,8]{1,0}, f32[8,8]{1,0}) reduce-scatter(%a, %b)
  %cp = u32[4]{0} collective-permute(%z)
"""
    cb = collective_bytes(hlo)
    assert cb["all-reduce"] == 1024 * 16 * 4
    assert cb["all-gather"] == 512 * 2
    assert cb["reduce-scatter"] == 2 * 64 * 4
    assert cb["collective-permute"] == 16
    assert cb["total"] == sum(v for k, v in cb.items() if k != "total")
